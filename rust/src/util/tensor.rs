//! Tiny dense tensor type used on the request path.
//!
//! Row-major f32 storage with just the operations the coordinator needs
//! (shape bookkeeping, slicing helpers). Heavy math lives in the PJRT
//! executables; this type exists to move data between point ops and the
//! runtime without pulling in an external ndarray crate.

use std::fmt;

#[derive(Clone, PartialEq)]
pub struct Tensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl Tensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(), "shape/data mismatch");
        Tensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        Tensor { shape, data: vec![0.0; n] }
    }

    pub fn scalar(v: f32) -> Self {
        Tensor { shape: vec![], data: vec![v] }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    pub fn ndim(&self) -> usize {
        self.shape.len()
    }

    /// Number of rows when viewed as (rows, cols) — requires ndim >= 1.
    pub fn rows(&self) -> usize {
        self.shape[0]
    }

    /// Row stride for 2-D views: product of trailing dims.
    pub fn row_len(&self) -> usize {
        self.shape[1..].iter().product()
    }

    pub fn row(&self, i: usize) -> &[f32] {
        let w = self.row_len();
        &self.data[i * w..(i + 1) * w]
    }

    pub fn row_mut(&mut self, i: usize) -> &mut [f32] {
        let w = self.row_len();
        &mut self.data[i * w..(i + 1) * w]
    }

    /// Gather rows by index into a new tensor.
    pub fn gather_rows(&self, idx: &[usize]) -> Tensor {
        let w = self.row_len();
        let mut data = Vec::with_capacity(idx.len() * w);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        let mut shape = self.shape.clone();
        shape[0] = idx.len();
        Tensor::new(shape, data)
    }

    /// Concatenate along axis 0 (all trailing dims must match).
    pub fn concat0(parts: &[&Tensor]) -> Tensor {
        assert!(!parts.is_empty());
        let w = parts[0].row_len();
        let mut shape = parts[0].shape.clone();
        let mut data = Vec::new();
        let mut rows = 0;
        for p in parts {
            assert_eq!(p.row_len(), w, "concat0 trailing dims mismatch");
            rows += p.shape[0];
            data.extend_from_slice(&p.data);
        }
        shape[0] = rows;
        Tensor::new(shape, data)
    }

    /// Reshape in place (must preserve element count).
    pub fn reshape(mut self, shape: Vec<usize>) -> Tensor {
        assert_eq!(shape.iter().product::<usize>(), self.data.len());
        self.shape = shape;
        self
    }

    pub fn size_bytes(&self) -> usize {
        self.data.len() * 4
    }
}

impl fmt::Debug for Tensor {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "Tensor{:?}[{} elems]", self.shape, self.data.len())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn gather_and_concat() {
        let t = Tensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        let g = t.gather_rows(&[2, 0]);
        assert_eq!(g.data, vec![5., 6., 1., 2.]);
        let c = Tensor::concat0(&[&t, &g]);
        assert_eq!(c.shape, vec![5, 2]);
        assert_eq!(c.row(4), &[1., 2.]);
    }

    #[test]
    #[should_panic]
    fn shape_mismatch_panics() {
        Tensor::new(vec![2, 2], vec![1.0]);
    }
}
