//! INT8 quantization substrate (paper §4.3) — Rust side.
//!
//! The QDQ numerics are baked into the INT8 HLO artifacts at build time;
//! this module provides (a) a standalone quantizer mirroring those numerics
//! for tests and the Table 11 parameter-count/error analysis, and (b) the
//! distribution statistics (KL divergence matrix) behind Fig. 6/7.

pub mod scheme;
pub mod stats;

pub use scheme::{derive_roles, QTensor, QuantScheme, QuantSpec, StagePrecision};

use anyhow::{anyhow, Result};

use crate::util::tensor::Tensor;

/// Quantization granularity over a layer's output channels.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Granularity {
    Layer,
    /// naive even contiguous groups
    Group(usize),
    Channel,
    /// paper's role-based groups (explicit channel partition)
    Role,
}

impl Granularity {
    pub fn name(&self) -> &'static str {
        match self {
            Granularity::Layer => "layer",
            Granularity::Group(_) => "group",
            Granularity::Channel => "channel",
            Granularity::Role => "role",
        }
    }
}

/// Channel partition for a granularity (role partition supplied by caller).
pub fn partition(g: Granularity, cout: usize, roles: &[Vec<usize>]) -> Vec<Vec<usize>> {
    match g {
        Granularity::Layer => vec![(0..cout).collect()],
        Granularity::Channel => (0..cout).map(|c| vec![c]).collect(),
        Granularity::Role => roles.to_vec(),
        Granularity::Group(n) => {
            // more groups than channels used to emit empty tail groups,
            // silently inflating param_count() and calibrating degenerate
            // 1e-8 scales; only non-empty groups are returned
            let n = n.max(1);
            let mut out = Vec::with_capacity(n.min(cout));
            for i in 0..n {
                let lo = i * cout / n;
                let hi = (i + 1) * cout / n;
                if lo < hi {
                    out.push((lo..hi).collect());
                }
            }
            out
        }
    }
}

/// Affine activation quantization parameters per channel group.
#[derive(Debug, Clone)]
pub struct ActQuant {
    /// per-channel (expanded) scale / zero-point
    pub scale: Vec<f32>,
    pub zero: Vec<f32>,
    pub num_groups: usize,
}

impl ActQuant {
    /// Calibrate from per-channel min/max (quantize.py's rule, with the
    /// zero point left unclamped — see the comment below).
    pub fn calibrate(lo: &[f32], hi: &[f32], groups: &[Vec<usize>]) -> ActQuant {
        let cout = lo.len();
        let mut scale = vec![0.0f32; cout];
        let mut zero = vec![0.0f32; cout];
        for g in groups {
            // fold with ±INFINITY identities: a 0.0 identity silently
            // widened every all-positive (post-ReLU) or all-negative
            // group's range to include zero, wasting INT8 codes
            let glo = g.iter().map(|&c| lo[c]).fold(f32::INFINITY, f32::min);
            let ghi = g.iter().map(|&c| hi[c]).fold(f32::NEG_INFINITY, f32::max);
            let s = ((ghi - glo) / 255.0).max(1e-8);
            // the zero point is a shift, not a stored i8 code, so it must
            // NOT be clamped to [-128, 127]: for a group whose range
            // excludes zero (post-ReLU positives, all-negative residuals)
            // the true zero point lies outside i8, and clamping it used to
            // shift the representable window off the calibrated range,
            // clipping extreme values with error up to |glo|
            let z = (-128.0 - glo / s).round();
            for &c in g {
                scale[c] = s;
                zero[c] = z;
            }
        }
        ActQuant { scale, zero, num_groups: groups.len() }
    }

    /// Quantize-dequantize a (N, C) activation tensor in place. A malformed
    /// activation (width != calibrated channels) is an error, not a panic,
    /// so a serving worker survives it (same treatment as
    /// `run_maybe_padded`).
    pub fn qdq(&self, t: &mut Tensor) -> Result<()> {
        let c = self.scale.len();
        if t.row_len() != c {
            return Err(anyhow!(
                "qdq: activation width {} != calibrated channels {c}",
                t.row_len()
            ));
        }
        for row in 0..t.rows() {
            let r = t.row_mut(row);
            for (i, v) in r.iter_mut().enumerate() {
                let q = (*v / self.scale[i] + self.zero[i]).round().clamp(-128.0, 127.0);
                *v = (q - self.zero[i]) * self.scale[i];
            }
        }
        Ok(())
    }

    /// Number of quantization parameters this scheme stores for the layer:
    /// per group, one weight scale + activation (scale, zero) — matching
    /// quantize.quant_param_count on the python side.
    pub fn param_count(&self) -> usize {
        3 * self.num_groups
    }
}

/// QDQ error (mean squared) introduced on a tensor by an ActQuant.
pub fn qdq_mse(t: &Tensor, q: &ActQuant) -> Result<f64> {
    let mut copy = t.clone();
    q.qdq(&mut copy)?;
    let mut acc = 0.0f64;
    for (a, b) in t.data.iter().zip(copy.data.iter()) {
        let d = (*a - *b) as f64;
        acc += d * d;
    }
    Ok(acc / t.data.len() as f64)
}

/// Per-channel min/max of a (N, C) tensor.
pub fn channel_minmax(t: &Tensor) -> (Vec<f32>, Vec<f32>) {
    let c = t.row_len();
    let mut lo = vec![f32::INFINITY; c];
    let mut hi = vec![f32::NEG_INFINITY; c];
    for row in 0..t.rows() {
        for (i, &v) in t.row(row).iter().enumerate() {
            lo[i] = lo[i].min(v);
            hi[i] = hi[i].max(v);
        }
    }
    (lo, hi)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Head-shaped test tensor: channel 0..3 small-range (xyz), 3..40
    /// wide-range logits, 40..80 medium-range regression.
    fn head_tensor(n: usize, seed: u64) -> (Tensor, Vec<Vec<usize>>) {
        let mut r = Rng::new(seed);
        let c = 80;
        let mut data = Vec::with_capacity(n * c);
        for _ in 0..n {
            for ch in 0..c {
                let sigma = if ch < 3 {
                    0.05
                } else if ch < 40 {
                    8.0
                } else {
                    0.8
                };
                data.push(r.normal_scaled(0.0, sigma) as f32);
            }
        }
        let roles =
            vec![(0..3).collect::<Vec<_>>(), (3..40).collect::<Vec<_>>(), (40..80).collect::<Vec<_>>()];
        (Tensor::new(vec![n, c], data), roles)
    }

    #[test]
    fn role_beats_layer_on_heterogeneous_channels() {
        let (t, roles) = head_tensor(256, 1);
        let (lo, hi) = channel_minmax(&t);
        let q_layer = ActQuant::calibrate(&lo, &hi, &partition(Granularity::Layer, 80, &roles));
        let q_role = ActQuant::calibrate(&lo, &hi, &partition(Granularity::Role, 80, &roles));
        let q_chan = ActQuant::calibrate(&lo, &hi, &partition(Granularity::Channel, 80, &roles));
        let e_layer = qdq_mse(&t, &q_layer).unwrap();
        let e_role = qdq_mse(&t, &q_role).unwrap();
        let e_chan = qdq_mse(&t, &q_chan).unwrap();
        assert!(e_role < e_layer / 2.0, "role {e_role} should beat layer {e_layer}");
        assert!(e_chan <= e_role * 1.5, "channel {e_chan} ~<= role {e_role}");
    }

    #[test]
    fn xyz_channels_destroyed_by_layer_scale() {
        // the collapse mechanism behind Table 7: a single layer scale is set
        // by the +-20 logits, so 0.05-magnitude xyz offsets round to ~0
        let (t, roles) = head_tensor(256, 2);
        let (lo, hi) = channel_minmax(&t);
        let q_layer = ActQuant::calibrate(&lo, &hi, &partition(Granularity::Layer, 80, &roles));
        let mut q = t.clone();
        q_layer.qdq(&mut q).unwrap();
        // relative error on xyz channels
        let mut num = 0.0f64;
        let mut den = 0.0f64;
        for row in 0..t.rows() {
            for ch in 0..3 {
                let a = t.row(row)[ch] as f64;
                let b = q.row(row)[ch] as f64;
                num += (a - b) * (a - b);
                den += a * a;
            }
        }
        assert!(num / den > 0.3, "xyz relative sq-error {} should be large", num / den);
    }

    #[test]
    fn param_counts_ordering() {
        let roles = vec![vec![0, 1, 2], (3..40).collect(), (40..80).collect()];
        let mk = |g| {
            let p = partition(g, 80, &roles);
            ActQuant::calibrate(&[0.0; 80], &[1.0; 80], &p).param_count()
        };
        assert_eq!(mk(Granularity::Layer), 3);
        assert_eq!(mk(Granularity::Role), 9);
        assert_eq!(mk(Granularity::Group(3)), 9);
        assert_eq!(mk(Granularity::Channel), 240);
    }

    #[test]
    fn all_positive_group_keeps_full_range() {
        // regression: the old 0.0 fold identity stretched an all-positive
        // group's range down to zero, wasting codes below the true minimum
        let lo = vec![2.0f32, 3.0];
        let hi = vec![4.0f32, 6.0];
        let q = ActQuant::calibrate(&lo, &hi, &[vec![0, 1]]);
        let expect = (6.0 - 2.0) / 255.0; // true group range, not [0, 6]
        assert!(
            (q.scale[0] - expect).abs() < 1e-7,
            "scale {} should cover [2, 6] only, not [0, 6]",
            q.scale[0]
        );
        // the zero point lies outside i8 here (a shift, not a stored code);
        // clamping it used to make the top of the range unrepresentable
        // (qdq(5.5) came back as 4.0 — a 1.5 clip on a 4-wide range)
        let mut top = Tensor::new(vec![1, 2], vec![5.5, 5.9]);
        q.qdq(&mut top).unwrap();
        assert!(
            (top.data[0] - 5.5).abs() <= q.scale[0] / 2.0 + 1e-6,
            "qdq(5.5) = {} must stay within scale/2 of 5.5",
            top.data[0]
        );
        // and the tighter scale must quantize an in-range tensor better
        let t = Tensor::new(vec![2, 2], vec![2.5, 3.5, 3.9, 5.5]);
        let loose = ActQuant {
            scale: vec![6.0 / 255.0; 2],
            zero: vec![(-128.0f32).round(); 2],
            num_groups: 1,
        };
        assert!(qdq_mse(&t, &q).unwrap() < qdq_mse(&t, &loose).unwrap());
    }

    #[test]
    fn all_negative_group_keeps_full_range() {
        let lo = vec![-6.0f32];
        let hi = vec![-2.0f32];
        let q = ActQuant::calibrate(&lo, &hi, &[vec![0]]);
        assert!(((q.scale[0]) - (4.0 / 255.0)).abs() < 1e-7, "scale {}", q.scale[0]);
        // mirror of the all-positive zero-point fix: -6 must round-trip
        let mut t = Tensor::new(vec![1, 1], vec![-6.0]);
        q.qdq(&mut t).unwrap();
        assert!((t.data[0] + 6.0).abs() <= q.scale[0] / 2.0 + 1e-6, "qdq(-6) = {}", t.data[0]);
    }

    #[test]
    fn qdq_idempotent() {
        let (t, roles) = head_tensor(64, 3);
        let (lo, hi) = channel_minmax(&t);
        let q = ActQuant::calibrate(&lo, &hi, &partition(Granularity::Role, 80, &roles));
        let mut once = t.clone();
        q.qdq(&mut once).unwrap();
        let mut twice = once.clone();
        q.qdq(&mut twice).unwrap();
        assert_eq!(once, twice);
    }

    #[test]
    fn group_partition_never_produces_empty_groups() {
        // regression: Group(n) with n > cout emitted empty tail groups,
        // inflating param_count and calibrating degenerate 1e-8 scales
        for (n, cout) in [(8usize, 3usize), (3, 3), (2, 5), (16, 1), (5, 12)] {
            let groups = partition(Granularity::Group(n), cout, &[]);
            assert_eq!(groups.len(), n.min(cout), "Group({n}) over {cout} channels");
            let mut covered: Vec<usize> = groups.iter().flatten().copied().collect();
            covered.sort_unstable();
            assert_eq!(covered, (0..cout).collect::<Vec<_>>(), "partition must cover 0..{cout}");
            assert!(groups.iter().all(|g| !g.is_empty()), "empty group in {groups:?}");
        }
        // param_count no longer inflated past one triple per channel
        let q = ActQuant::calibrate(
            &[0.0; 3],
            &[1.0; 3],
            &partition(Granularity::Group(8), 3, &[]),
        );
        assert_eq!(q.param_count(), 9);
        assert!(q.scale.iter().all(|&s| s > 1e-6), "degenerate scale calibrated");
    }

    #[test]
    fn qdq_width_mismatch_is_an_error_not_a_panic() {
        let q = ActQuant::calibrate(&[0.0, 0.0], &[1.0, 1.0], &[vec![0, 1]]);
        let mut bad = Tensor::zeros(vec![4, 3]);
        assert!(q.qdq(&mut bad).is_err());
        assert!(qdq_mse(&Tensor::zeros(vec![4, 3]), &q).is_err());
    }
}
