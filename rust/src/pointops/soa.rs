//! Structure-of-arrays point storage for the SIMD hot path.
//!
//! The distance loops in `fps`, `ballquery` and `interp` are bound by how
//! fast they can stream coordinates. The interleaved `[[f32; 3]]` layout
//! makes every lane load a gather; [`PointsSoA`] stores x/y/z as three flat
//! `Vec<f32>` so a fixed-width `[f32; LANES]` chunk kernel reads three
//! contiguous streams and auto-vectorizes. Arrays are kept padded to a
//! [`LANES`] multiple (zero-filled tail) so a kernel may always read a full
//! lane block starting at any live index; the live prefix is `len` points
//! and the padding never participates in results.
//!
//! `soa_bytes(n)` is the canonical padded footprint of one cloud — the sim's
//! workload accounting is checked against it by the verifier's S005 rule so
//! the layout cannot silently drift from the memory model.

/// Fixed SIMD lane width of the chunk kernels (f32 elements per block).
pub const LANES: usize = 8;

/// Storage length of an `n`-point cloud: `n` rounded up to a lane multiple.
pub fn padded_len(n: usize) -> usize {
    n.div_ceil(LANES) * LANES
}

/// Bytes of the lane-padded coordinate storage for an `n`-point cloud
/// (three f32 arrays). The verifier checks declared point-op workloads
/// cover at least this footprint.
pub fn soa_bytes(n: usize) -> u64 {
    (padded_len(n) as u64) * 3 * 4
}

/// Lane-padded structure-of-arrays point cloud.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct PointsSoA {
    xs: Vec<f32>,
    ys: Vec<f32>,
    zs: Vec<f32>,
    len: usize,
}

impl PointsSoA {
    pub fn new() -> PointsSoA {
        PointsSoA::default()
    }

    pub fn from_points(pts: &[[f32; 3]]) -> PointsSoA {
        let mut s = PointsSoA::new();
        s.fill_from_points(pts);
        s
    }

    /// Refill in place from an interleaved cloud, reusing capacity.
    pub fn fill_from_points(&mut self, pts: &[[f32; 3]]) {
        self.clear();
        for p in pts {
            self.xs.push(p[0]);
            self.ys.push(p[1]);
            self.zs.push(p[2]);
        }
        self.len = pts.len();
        self.pad();
    }

    /// Build from a subset of an interleaved cloud (`pts[idx[0]], ...`).
    pub fn from_indexed(pts: &[[f32; 3]], idx: &[usize]) -> PointsSoA {
        let mut s = PointsSoA::new();
        for &i in idx {
            s.xs.push(pts[i][0]);
            s.ys.push(pts[i][1]);
            s.zs.push(pts[i][2]);
        }
        s.len = idx.len();
        s.pad();
        s
    }

    /// Gather a subset of this cloud into a new one.
    pub fn gather(&self, idx: &[usize]) -> PointsSoA {
        let mut s = PointsSoA::new();
        for &i in idx {
            debug_assert!(i < self.len, "gather index {i} out of range for len {}", self.len);
            s.xs.push(self.xs[i]);
            s.ys.push(self.ys[i]);
            s.zs.push(self.zs[i]);
        }
        s.len = idx.len();
        s.pad();
        s
    }

    /// Append another cloud's live points (the padding of either side never
    /// leaks into the result).
    pub fn append(&mut self, other: &PointsSoA) {
        self.truncate_to_len();
        self.xs.extend_from_slice(other.xs());
        self.ys.extend_from_slice(other.ys());
        self.zs.extend_from_slice(other.zs());
        self.len += other.len;
        self.pad();
    }

    pub fn clear(&mut self) {
        self.xs.clear();
        self.ys.clear();
        self.zs.clear();
        self.len = 0;
    }

    /// Number of live points (excludes padding).
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    #[inline]
    pub fn get(&self, i: usize) -> [f32; 3] {
        debug_assert!(i < self.len, "point index {i} out of range for len {}", self.len);
        [self.xs[i], self.ys[i], self.zs[i]]
    }

    /// Live x coordinates (length `len`, padding excluded).
    #[inline]
    pub fn xs(&self) -> &[f32] {
        &self.xs[..self.len]
    }

    #[inline]
    pub fn ys(&self) -> &[f32] {
        &self.ys[..self.len]
    }

    #[inline]
    pub fn zs(&self) -> &[f32] {
        &self.zs[..self.len]
    }

    pub fn iter(&self) -> impl Iterator<Item = [f32; 3]> + '_ {
        (0..self.len).map(move |i| self.get(i))
    }

    pub fn to_points(&self) -> Vec<[f32; 3]> {
        self.iter().collect()
    }

    /// Heap bytes currently reserved (all three arrays) — the scratch-arena
    /// growth accounting reads this before/after each kernel.
    pub fn capacity_bytes(&self) -> u64 {
        ((self.xs.capacity() + self.ys.capacity() + self.zs.capacity()) * 4) as u64
    }

    /// Pre-reserve padded capacity for an `n`-point cloud.
    pub fn reserve(&mut self, n: usize) {
        let p = padded_len(n);
        self.xs.reserve(p.saturating_sub(self.xs.len()));
        self.ys.reserve(p.saturating_sub(self.ys.len()));
        self.zs.reserve(p.saturating_sub(self.zs.len()));
    }

    fn truncate_to_len(&mut self) {
        self.xs.truncate(self.len);
        self.ys.truncate(self.len);
        self.zs.truncate(self.len);
    }

    /// Restore the invariant: storage length is the lane-padded live length,
    /// padding zero-filled.
    fn pad(&mut self) {
        let p = padded_len(self.len);
        self.xs.resize(p, 0.0);
        self.ys.resize(p, 0.0);
        self.zs.resize(p, 0.0);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(n: usize) -> Vec<[f32; 3]> {
        (0..n).map(|i| [i as f32, i as f32 * 2.0, i as f32 * 3.0]).collect()
    }

    #[test]
    fn roundtrip_and_padding_invariant() {
        for n in [0usize, 1, 7, 8, 9, 100] {
            let pts = cloud(n);
            let s = PointsSoA::from_points(&pts);
            assert_eq!(s.len(), n);
            assert_eq!(s.to_points(), pts, "n={n}");
            assert_eq!(s.xs().len(), n, "live slice excludes padding");
            assert_eq!(padded_len(n) % LANES, 0);
            assert!(padded_len(n) >= n && padded_len(n) < n + LANES);
        }
    }

    #[test]
    fn gather_and_append_preserve_live_points() {
        let s = PointsSoA::from_points(&cloud(20));
        let g = s.gather(&[3, 0, 19]);
        assert_eq!(g.to_points(), vec![[3.0, 6.0, 9.0], [0.0, 0.0, 0.0], [19.0, 38.0, 57.0]]);
        let mut a = s.gather(&[1, 2]);
        a.append(&g);
        assert_eq!(a.len(), 5);
        assert_eq!(a.get(2), [3.0, 6.0, 9.0], "append starts after the live prefix");
        assert_eq!(a.get(4), [19.0, 38.0, 57.0]);
    }

    #[test]
    fn fill_reuses_capacity() {
        let mut s = PointsSoA::from_points(&cloud(64));
        let cap = s.capacity_bytes();
        s.fill_from_points(&cloud(32));
        assert_eq!(s.len(), 32);
        assert_eq!(s.capacity_bytes(), cap, "refilling smaller must not reallocate");
    }

    #[test]
    fn from_indexed_matches_gather() {
        let pts = cloud(16);
        let s = PointsSoA::from_points(&pts);
        assert_eq!(PointsSoA::from_indexed(&pts, &[5, 9]), s.gather(&[5, 9]));
    }

    #[test]
    fn soa_bytes_counts_three_padded_arrays() {
        assert_eq!(soa_bytes(0), 0);
        assert_eq!(soa_bytes(1), (LANES * 12) as u64);
        assert_eq!(soa_bytes(2048), 2048 * 12);
    }
}
