//! Deterministic host surrogate for the AOT PJRT executables.
//!
//! The vendored `xla` crate is a stub — it cannot compile or execute HLO —
//! so on machines without a real PJRT backend the functional pipeline used
//! to die at its first NN call. This module stands in for the executables
//! with small fixed-function networks whose weights are derived from a hash
//! of the artifact name: fully deterministic (same artifact + same input →
//! bit-identical output, on any thread), shape-correct per the manifest, and
//! cheap enough that the host hot path stays dominated by point ops.
//!
//! This is a *reference executor*, not the trained model: detections are
//! internally consistent (stable across runs, usable for determinism tests,
//! scheduling studies, and serving experiments) but their accuracy is
//! meaningless. Swapping `rust/Cargo.toml` to a real `xla-rs` build restores
//! execution of the exported artifacts; the surrogate then never runs.

use anyhow::{anyhow, Result};

use super::manifest::{ArtifactMeta, Manifest};
use crate::util::tensor::Tensor;

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn hash_str(s: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Pseudo-random weight in [-1, 1] for (artifact key, out channel, in channel).
#[inline]
fn weight(key: u64, j: u64, c: u64) -> f32 {
    let h = mix(
        key ^ j.wrapping_mul(0x9E3779B97F4A7C15) ^ c.wrapping_mul(0xD1B54A32D192ED03),
    );
    ((h >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32
}

/// Deterministic dense layer: rows (n, cin) -> tanh(rows @ W + b) (n, cout).
fn dense(x_rows: impl Iterator<Item = Vec<f32>>, n: usize, cin: usize, cout: usize, key: u64) -> Tensor {
    // materialize W once per call (cout x cin + bias)
    let mut w = Vec::with_capacity(cout * cin);
    for j in 0..cout {
        for c in 0..cin {
            w.push(weight(key, j as u64, c as u64));
        }
    }
    let bias: Vec<f32> = (0..cout).map(|j| 0.1 * weight(key ^ 0xB1A5, j as u64, 0)).collect();
    let scale = 1.0 / (cin.max(1) as f32).sqrt();
    let mut out = Vec::with_capacity(n * cout);
    for row in x_rows {
        debug_assert_eq!(row.len(), cin);
        for j in 0..cout {
            let wrow = &w[j * cin..(j + 1) * cin];
            let mut acc = 0.0f32;
            for (wv, xv) in wrow.iter().zip(row.iter()) {
                acc += wv * xv;
            }
            out.push((acc * scale + bias[j]).tanh());
        }
    }
    Tensor::new(vec![n, cout], out)
}

/// Mean-pool the ball dimension of a (b, k, c) tensor into (b, c) rows.
fn pooled_rows(x: &Tensor) -> impl Iterator<Item = Vec<f32>> + '_ {
    let (b, k, c) = (x.shape[0], x.shape[1], x.shape[2]);
    (0..b).map(move |i| {
        let mut pool = vec![0.0f32; c];
        let base = i * k * c;
        for kk in 0..k {
            for (p, v) in pool.iter_mut().zip(x.data[base + kk * c..base + (kk + 1) * c].iter()) {
                *p += v;
            }
        }
        let inv = 1.0 / k.max(1) as f32;
        for p in pool.iter_mut() {
            *p *= inv;
        }
        pool
    })
}

/// Execute one artifact on the surrogate. Output shapes follow the manifest
/// contract for the artifact's `net` role.
pub fn run(manifest: &Manifest, meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    let x = inputs
        .first()
        .ok_or_else(|| anyhow!("surrogate '{}': no input", meta.name))?;
    let key = hash_str(&meta.name);
    match meta.net.as_str() {
        // (H, W, 3) RGB -> (H, W, num_seg_classes) softmax scores
        "seg" => {
            let (h, w, cin) = (x.shape[0], x.shape[1], x.shape[2]);
            let nseg = manifest.num_seg_classes;
            let logits = dense(
                (0..h * w).map(|p| x.data[p * cin..(p + 1) * cin].to_vec()),
                h * w,
                cin,
                nseg,
                key,
            );
            let mut out = logits.data;
            for p in 0..h * w {
                let row = &mut out[p * nseg..(p + 1) * nseg];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut s = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    s += *v;
                }
                for v in row.iter_mut() {
                    *v /= s;
                }
            }
            Ok(vec![Tensor::new(vec![h, w, nseg], out)])
        }
        // (n, fp_in) -> (n, seed_feat)
        "fp_fc" => {
            let (n, cin) = (x.shape[0], x.shape[1]);
            Ok(vec![dense(
                (0..n).map(|i| x.row(i).to_vec()),
                n,
                cin,
                manifest.seed_feat,
                key,
            )])
        }
        // (n, seed_feat) -> (n, 3 + seed_feat) vote offsets + residuals
        "vote" => {
            let (n, cin) = (x.shape[0], x.shape[1]);
            Ok(vec![dense(
                (0..n).map(|i| x.row(i).to_vec()),
                n,
                cin,
                3 + manifest.seed_feat,
                key,
            )])
        }
        // (p, k, c) proposal groups -> (p, head channels)
        "prop" => {
            let b = x.shape[0];
            let cin = x.shape[2];
            let head_ch = manifest.head_layout.sem_cls.1;
            Ok(vec![dense(pooled_rows(x), b, cin, head_ch, key)])
        }
        // saN_full / saN_half: (b, k, cin) -> (b, mlp.last)
        net if net.starts_with("sa") => {
            let level: usize = net[2..3]
                .parse()
                .map_err(|_| anyhow!("surrogate: bad SA net name '{net}'"))?;
            let sac = manifest
                .sa_configs
                .get(level - 1)
                .ok_or_else(|| anyhow!("surrogate: SA level {level} out of range"))?;
            let cout = *sac.mlp.last().expect("sa mlp widths");
            let b = x.shape[0];
            let cin = x.shape[2];
            Ok(vec![dense(pooled_rows(x), b, cin, cout, key)])
        }
        other => Err(anyhow!("surrogate: unknown net role '{other}' ({})", meta.name)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest() -> Manifest {
        Manifest::synthetic()
    }

    fn probe(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(
            shape.to_vec(),
            (0..n).map(|i| (0.1 + 0.001 * i as f64).sin() as f32).collect(),
        )
    }

    #[test]
    fn deterministic_and_shape_correct() {
        let m = manifest();
        for name in [
            "synrgbd_seg_fp32",
            "synrgbd_pointsplit_sa1_half_int8",
            "synrgbd_pointsplit_sa4_full_int8",
            "synrgbd_pointsplit_fp_fc_int8",
            "synrgbd_pointsplit_vote_int8_role",
            "synrgbd_pointsplit_prop_int8_role",
        ] {
            let meta = m.artifact(name).expect(name).clone();
            let x = probe(&meta.input_shapes[0]);
            let a = run(&m, &meta, &[&x]).expect(name);
            let b = run(&m, &meta, &[&x]).expect(name);
            assert_eq!(a.len(), 1);
            assert_eq!(a[0], b[0], "{name} must be deterministic");
            assert!(a[0].data.iter().all(|v| v.is_finite()), "{name} non-finite");
        }
    }

    #[test]
    fn seg_rows_are_distributions() {
        let m = manifest();
        let meta = m.artifact("synrgbd_seg_fp32").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let out = run(&m, &meta, &[&x]).unwrap().remove(0);
        assert_eq!(out.shape, vec![m.img_size, m.img_size, m.num_seg_classes]);
        for p in 0..m.img_size * m.img_size {
            let s: f32 = out.data[p * m.num_seg_classes..(p + 1) * m.num_seg_classes]
                .iter()
                .sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn different_artifacts_give_different_outputs() {
        let m = manifest();
        let a = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap().clone();
        let b = m.artifact("synrgbd_pointsplit_vote_fp32").unwrap().clone();
        let x = probe(&a.input_shapes[0]);
        let ya = run(&m, &a, &[&x]).unwrap().remove(0);
        let yb = run(&m, &b, &[&x]).unwrap().remove(0);
        assert_ne!(ya, yb, "precision variants must not alias");
    }

    #[test]
    fn sa_output_width_follows_mlp() {
        let m = manifest();
        let meta = m.artifact("synrgbd_pointsplit_sa2_half_int8").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let out = run(&m, &meta, &[&x]).unwrap().remove(0);
        assert_eq!(out.shape, vec![meta.input_shapes[0][0], *m.sa_configs[1].mlp.last().unwrap()]);
    }
}
