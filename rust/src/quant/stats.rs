//! Distribution statistics behind paper Fig. 6 (per-channel weight/activation
//! ranges, grouped by role) and Fig. 7 (pairwise KL divergence of channel
//! activation distributions in the proposal module).

/// Normalized histogram of a sample over fixed edges.
pub fn histogram(xs: &[f32], lo: f32, hi: f32, bins: usize) -> Vec<f64> {
    let mut h = vec![0.0f64; bins];
    let w = (hi - lo).max(1e-12) / bins as f32;
    for &x in xs {
        let b = (((x - lo) / w) as isize).clamp(0, bins as isize - 1) as usize;
        h[b] += 1.0;
    }
    let total: f64 = h.iter().sum();
    if total > 0.0 {
        for v in h.iter_mut() {
            *v /= total;
        }
    }
    h
}

/// KL(p || q) with epsilon smoothing (distributions must share support/edges).
pub fn kl_divergence(p: &[f64], q: &[f64]) -> f64 {
    const EPS: f64 = 1e-6;
    p.iter()
        .zip(q.iter())
        .map(|(&pi, &qi)| {
            let pi = pi + EPS;
            let qi = qi + EPS;
            pi * (pi / qi).ln()
        })
        .sum()
}

/// Pairwise KL matrix across per-channel histograms (Fig. 7).
pub fn kl_matrix(hists: &[Vec<f64>]) -> Vec<Vec<f64>> {
    hists
        .iter()
        .map(|p| hists.iter().map(|q| kl_divergence(p, q)).collect())
        .collect()
}

/// Mean KL within vs across role groups — the Fig. 7 takeaway as a number.
pub fn within_across_kl(hists: &[Vec<f64>], group_of: &[usize]) -> (f64, f64) {
    let m = kl_matrix(hists);
    let (mut win, mut wn) = (0.0, 0u64);
    let (mut acc, mut an) = (0.0, 0u64);
    for i in 0..m.len() {
        for j in 0..m.len() {
            if i == j {
                continue;
            }
            if group_of[i] == group_of[j] {
                win += m[i][j];
                wn += 1;
            } else {
                acc += m[i][j];
                an += 1;
            }
        }
    }
    (win / wn.max(1) as f64, acc / an.max(1) as f64)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    #[test]
    fn histogram_sums_to_one() {
        let mut r = Rng::new(1);
        let xs: Vec<f32> = (0..1000).map(|_| r.f32()).collect();
        let h = histogram(&xs, 0.0, 1.0, 16);
        assert!((h.iter().sum::<f64>() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn kl_self_is_zero() {
        let p = vec![0.25; 4];
        assert!(kl_divergence(&p, &p).abs() < 1e-9);
    }

    #[test]
    fn kl_grows_with_divergence() {
        let p = vec![0.9, 0.1, 0.0, 0.0];
        let q_near = vec![0.8, 0.2, 0.0, 0.0];
        let q_far = vec![0.0, 0.0, 0.1, 0.9];
        assert!(kl_divergence(&p, &q_far) > kl_divergence(&p, &q_near));
    }

    #[test]
    fn prop_qdq_error_bounded_by_half_scale() {
        // property: for a tensor the quantizer was calibrated on, every
        // element's QDQ error is at most scale/2 (rounding), never clipping
        use crate::quant::{channel_minmax, ActQuant};
        use crate::util::prop::{check, PropConfig};
        use crate::util::tensor::Tensor;
        check("qdq-error-half-scale", PropConfig { cases: 48, seed: 0x51AB }, |rng, size| {
            let n = (size * 2).max(16);
            let c = 2 + rng.below(12);
            let mut data = Vec::with_capacity(n * c);
            for _ in 0..n {
                for ch in 0..c {
                    let sigma = 0.1 + (ch % 4) as f64;
                    data.push(rng.normal_scaled(0.0, sigma) as f32);
                }
            }
            let t = Tensor::new(vec![n, c], data);
            let (lo, hi) = channel_minmax(&t);
            let groups: Vec<Vec<usize>> = (0..c).map(|i| vec![i]).collect();
            let q = ActQuant::calibrate(&lo, &hi, &groups);
            let mut deq = t.clone();
            q.qdq(&mut deq).map_err(|e| e.to_string())?;
            for row in 0..n {
                for ch in 0..c {
                    let err = (t.row(row)[ch] - deq.row(row)[ch]).abs();
                    let bound = q.scale[ch] * 0.5 * (1.0 + 1e-3) + 1e-7;
                    if err > bound {
                        return Err(format!(
                            "per-element error {err} exceeds scale/2 = {} (ch {ch})",
                            q.scale[ch] * 0.5
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn prop_kl_matrix_symmetric_zero_on_identical_distributions() {
        // property: channels with the same distribution have a KL matrix
        // that is exactly symmetric and (numerically) zero everywhere
        use crate::util::prop::{check, PropConfig};
        check("kl-identical-zero", PropConfig { cases: 32, seed: 0x0FF }, |rng, size| {
            let n = (size * 16).max(64);
            let xs: Vec<f32> = (0..n).map(|_| rng.normal_scaled(0.0, 1.5) as f32).collect();
            let h = histogram(&xs, -8.0, 8.0, 24);
            let hists = vec![h; 4];
            let m = kl_matrix(&hists);
            for i in 0..m.len() {
                for j in 0..m.len() {
                    if m[i][j].abs() > 1e-9 {
                        return Err(format!("KL[{i}][{j}] = {} on identical hists", m[i][j]));
                    }
                    if m[i][j] != m[j][i] {
                        return Err(format!("KL matrix asymmetric at ({i},{j})"));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn within_group_kl_smaller_for_role_clustered_channels() {
        let mut r = Rng::new(2);
        // 6 channels: 3 narrow-gauss, 3 wide-gauss
        let mut hists = Vec::new();
        for ch in 0..6 {
            let sigma = if ch < 3 { 0.2 } else { 3.0 };
            let xs: Vec<f32> = (0..4000).map(|_| r.normal_scaled(0.0, sigma) as f32).collect();
            hists.push(histogram(&xs, -10.0, 10.0, 32));
        }
        let groups = [0, 0, 0, 1, 1, 1];
        let (win, across) = within_across_kl(&hists, &groups);
        assert!(win < across, "within {win} should be < across {across}");
    }
}
