//! Paper Table 6: per-class mAP@0.25 on the primary dataset for VoteNet /
//! PointPainting / RandomSplit / PointSplit (FP32) and PointSplit (INT8).
//!
//! Expected shape (paper): fusion variants beat VoteNet by ~3 mAP;
//! PointSplit(FP32) is best overall; PointSplit(INT8, role-based) stays
//! within ~1.5 mAP of FP32.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::data::CLASS_NAMES;
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(48);
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let configs = [
        ("VoteNet (FP32)", Variant::VoteNet, false),
        ("PointPainting (FP32)", Variant::PointPainting, false),
        ("RandomSplit (FP32)", Variant::RandomSplit, false),
        ("PointSplit (FP32)", Variant::PointSplit, false),
        ("PointSplit (INT8)", Variant::PointSplit, true),
    ];
    let mut header = vec!["method"];
    header.extend(CLASS_NAMES.iter());
    header.push("Overall");
    let mut t = Table::new(&header);
    for (name, variant, int8) in configs {
        let cfg = DetectorConfig::new("synrgbd", variant, int8, sched);
        let rep = common::eval_config(&rt, &cfg, scenes);
        let mut row = vec![name.to_string()];
        row.extend(rep.per_class_ap25.iter().map(|&a| common::ap_cell(a)));
        row.push(format!("{:.1}", rep.map_25 * 100.0));
        t.row(row);
        eprintln!("  [{name}] done ({scenes} scenes)");
    }
    t.print(&format!(
        "Table 6 — per-class mAP@0.25 on synrgbd ({scenes} scenes; paper overall: 56.9 / 60.2 / 60.4 / 61.4 / 59.9)"
    ));
}
