"""Pallas kernel: tiled pairwise squared distances.

The point-manipulation side (FPS / ball query) is dominated by N x M distance
computations. On the paper's platform these run on the mobile GPU; here the
kernel documents the TPU-shaped tiling (row tiles of A stream through VMEM
against a resident B panel) and provides the L2-side primitive used by ball
query. ``interpret=True`` as everywhere (CPU PJRT).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def _pairwise_kernel(a_ref, b_ref, o_ref):
    a = a_ref[...]  # (BN, 3)
    b = b_ref[...]  # (M, 3)
    # |a-b|^2 = |a|^2 + |b|^2 - 2 a.b — one MXU matmul + rank-1 updates
    a2 = jnp.sum(a * a, axis=1, keepdims=True)
    b2 = jnp.sum(b * b, axis=1, keepdims=True)
    ab = jnp.dot(a, b.T, preferred_element_type=jnp.float32)
    o_ref[...] = jnp.maximum(a2 + b2.T - 2.0 * ab, 0.0)


def pairwise_dist2_pallas(
    a: jnp.ndarray, b: jnp.ndarray, block_n: int = DEFAULT_BLOCK_N
) -> jnp.ndarray:
    """Squared distances between a (N, 3) and b (M, 3) -> (N, M)."""
    n = a.shape[0]
    m = b.shape[0]
    if n % block_n != 0:
        block_n = next(bb for bb in range(min(block_n, n), 0, -1) if n % bb == 0)
    return pl.pallas_call(
        _pairwise_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, 3), lambda i: (i, 0)),
            pl.BlockSpec((m, 3), lambda i: (0, 0)),
        ],
        out_specs=pl.BlockSpec((block_n, m), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, m), jnp.float32),
        interpret=True,
    )(a, b)
