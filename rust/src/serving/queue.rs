//! Bounded admission queue with priority classes, deadline expiry, and
//! drop/timeout accounting.
//!
//! This is the gateway's only waiting room: a request is either in here, in
//! flight on the accelerators, or already resolved (completed / rejected /
//! expired / shed). Admission is a hard bound — when the queue is full the
//! request is rejected immediately (fail fast beats unbounded latency).
//! Within a priority class, order is strictly FIFO; across classes, lower
//! class index pops first. Both invariants are property-tested in
//! `rust/tests/proptests.rs`.

use std::collections::VecDeque;

use super::loadgen::Request;

/// Outcome of an admission attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmitResult {
    Admitted,
    /// Queue at capacity — request dropped at the door.
    RejectedFull,
}

/// Counters accumulated over the queue's lifetime.
#[derive(Debug, Clone, Copy, Default)]
pub struct QueueStats {
    pub admitted: u64,
    pub rejected_full: u64,
    /// Admitted but removed unserved because the deadline passed in queue.
    pub expired: u64,
    /// High-water mark of instantaneous depth.
    pub max_depth: usize,
}

/// Bounded multi-class FIFO.
#[derive(Debug)]
pub struct AdmissionQueue {
    capacity: usize,
    classes: Vec<VecDeque<Request>>,
    len: usize,
    pub stats: QueueStats,
}

impl AdmissionQueue {
    /// A queue holding at most `capacity` requests across `num_classes`
    /// priority classes (class 0 pops first).
    pub fn new(capacity: usize, num_classes: usize) -> AdmissionQueue {
        AdmissionQueue {
            capacity,
            classes: (0..num_classes.max(1)).map(|_| VecDeque::new()).collect(),
            len: 0,
            stats: QueueStats::default(),
        }
    }

    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Admit or reject a request. Out-of-range classes clamp to the lowest
    /// priority rather than panicking (the load generator owns class ids).
    pub fn offer(&mut self, req: Request) -> AdmitResult {
        if self.len >= self.capacity {
            self.stats.rejected_full += 1;
            return AdmitResult::RejectedFull;
        }
        let class = req.class.min(self.classes.len() - 1);
        self.classes[class].push_back(req);
        self.len += 1;
        self.stats.admitted += 1;
        self.stats.max_depth = self.stats.max_depth.max(self.len);
        AdmitResult::Admitted
    }

    /// Remove and return every queued request whose deadline is already
    /// behind `now_ms` (they could not possibly be served on time).
    pub fn expire(&mut self, now_ms: f64) -> Vec<Request> {
        let mut dead = Vec::new();
        for q in &mut self.classes {
            let mut keep = VecDeque::with_capacity(q.len());
            for r in q.drain(..) {
                if r.deadline_ms <= now_ms {
                    dead.push(r);
                } else {
                    keep.push_back(r);
                }
            }
            *q = keep;
        }
        self.len -= dead.len();
        self.stats.expired += dead.len() as u64;
        dead
    }

    /// Pop the head request: highest priority class first, FIFO within.
    pub fn pop(&mut self) -> Option<Request> {
        for q in &mut self.classes {
            if let Some(r) = q.pop_front() {
                self.len -= 1;
                return Some(r);
            }
        }
        None
    }

    /// The request that [`pop`](Self::pop) would return, without removing it.
    pub fn peek(&self) -> Option<&Request> {
        self.classes.iter().find_map(|q| q.front())
    }

    /// Earliest arrival time among queued requests with the given batch key
    /// (how long the oldest compatible request has been waiting).
    pub fn oldest_arrival_for_key(&self, key: usize) -> Option<f64> {
        self.classes
            .iter()
            .flat_map(|q| q.iter())
            .filter(|r| r.key == key)
            .map(|r| r.arrival_ms)
            .reduce(f64::min)
    }

    /// Number of queued requests with the given batch key.
    pub fn count_key(&self, key: usize) -> usize {
        self.classes.iter().flat_map(|q| q.iter()).filter(|r| r.key == key).count()
    }

    /// Pop up to `max` requests with the given key, preserving class
    /// priority and per-class FIFO order.
    pub fn pop_key(&mut self, key: usize, max: usize) -> Vec<Request> {
        let mut out = Vec::new();
        for q in &mut self.classes {
            while out.len() < max {
                // find the first entry of this key in the class
                let Some(pos) = q.iter().position(|r| r.key == key) else { break };
                // everything before `pos` has a different key; removing at
                // pos keeps the remaining same-key entries in FIFO order
                out.push(q.remove(pos).expect("position just found"));
            }
            if out.len() >= max {
                break;
            }
        }
        self.len -= out.len();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn req(id: u64, class: usize, key: usize, arrival: f64, deadline: f64) -> Request {
        Request { id, arrival_ms: arrival, deadline_ms: deadline, seed: id, class, key, client: 0 }
    }

    #[test]
    fn rejects_at_capacity() {
        let mut q = AdmissionQueue::new(2, 2);
        assert_eq!(q.offer(req(0, 0, 0, 0.0, 10.0)), AdmitResult::Admitted);
        assert_eq!(q.offer(req(1, 1, 0, 1.0, 10.0)), AdmitResult::Admitted);
        assert_eq!(q.offer(req(2, 0, 0, 2.0, 10.0)), AdmitResult::RejectedFull);
        assert_eq!(q.len(), 2);
        assert_eq!(q.stats.rejected_full, 1);
        assert_eq!(q.stats.max_depth, 2);
    }

    #[test]
    fn priority_then_fifo() {
        let mut q = AdmissionQueue::new(8, 2);
        q.offer(req(0, 1, 0, 0.0, 99.0));
        q.offer(req(1, 0, 0, 1.0, 99.0));
        q.offer(req(2, 1, 0, 2.0, 99.0));
        q.offer(req(3, 0, 0, 3.0, 99.0));
        let order: Vec<u64> = std::iter::from_fn(|| q.pop()).map(|r| r.id).collect();
        assert_eq!(order, vec![1, 3, 0, 2]);
    }

    #[test]
    fn expiry_removes_stale() {
        let mut q = AdmissionQueue::new(8, 1);
        q.offer(req(0, 0, 0, 0.0, 5.0));
        q.offer(req(1, 0, 0, 0.0, 50.0));
        let dead = q.expire(10.0);
        assert_eq!(dead.len(), 1);
        assert_eq!(dead[0].id, 0);
        assert_eq!(q.len(), 1);
        assert_eq!(q.stats.expired, 1);
        assert_eq!(q.pop().unwrap().id, 1);
    }

    #[test]
    fn pop_key_skips_other_keys_in_order() {
        let mut q = AdmissionQueue::new(8, 1);
        q.offer(req(0, 0, 1, 0.0, 99.0));
        q.offer(req(1, 0, 0, 1.0, 99.0));
        q.offer(req(2, 0, 1, 2.0, 99.0));
        q.offer(req(3, 0, 1, 3.0, 99.0));
        let got: Vec<u64> = q.pop_key(1, 2).into_iter().map(|r| r.id).collect();
        assert_eq!(got, vec![0, 2]);
        assert_eq!(q.len(), 2);
        assert_eq!(q.count_key(1), 1);
        assert_eq!(q.oldest_arrival_for_key(0), Some(1.0));
        // remaining entries intact and ordered
        assert_eq!(q.pop().unwrap().id, 1);
        assert_eq!(q.pop().unwrap().id, 3);
    }

    #[test]
    fn pop_key_respects_class_priority() {
        let mut q = AdmissionQueue::new(8, 2);
        q.offer(req(0, 1, 0, 0.0, 99.0));
        q.offer(req(1, 0, 0, 1.0, 99.0));
        let got: Vec<u64> = q.pop_key(0, 2).into_iter().map(|r| r.id).collect();
        assert_eq!(got, vec![1, 0], "class 0 first even though it arrived later");
    }
}
