//! §Perf: wall-clock micro-benchmarks of the L3 hot path on this host.
//!
//! These numbers feed EXPERIMENTS.md §Perf (before/after optimization log).
//! Covered: FPS, biased FPS, ball query, grouping, 3-NN interpolation, scene
//! generation, full functional pipeline, and PJRT executable dispatch.

mod common;

use pointsplit::bench::bench_fn;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::pointops;
use pointsplit::sim::DeviceKind;
use pointsplit::util::tensor::Tensor;

fn main() {
    let rt = common::open_runtime();
    let scene = generate_scene(3, &SYNRGBD);
    let fg: Vec<f32> =
        scene.point_obj.iter().map(|&o| if o >= 0 { 1.0 } else { 0.0 }).collect();

    println!("=== §Perf hot-path micro-benchmarks (host wall-clock) ===\n");
    bench_fn("fps 2048->256", 3, 30, || {
        std::hint::black_box(pointops::fps(&scene.points, 256));
    })
    .print();
    bench_fn("biased_fps 2048->256 (w0=2)", 3, 30, || {
        std::hint::black_box(pointops::biased_fps(&scene.points, 256, &fg, 2.0));
    })
    .print();
    let centers = pointops::fps(&scene.points, 256);
    bench_fn("ball_query 2048x256 k=32", 3, 30, || {
        std::hint::black_box(pointops::ball_query(&scene.points, &centers, 0.3, 32));
    })
    .print();
    let groups = pointops::ball_query(&scene.points, &centers, 0.3, 32);
    let feats = pointops::build_features(&scene, None);
    bench_fn("group_features 256x32", 3, 50, || {
        std::hint::black_box(pointops::group_features(&scene.points, Some(&feats), &centers, &groups));
    })
    .print();
    let coarse: Vec<[f32; 3]> = centers.iter().map(|&i| scene.points[i]).collect();
    let cfeats = Tensor::zeros(vec![256, 128]);
    bench_fn("three_nn_interp 2048<-256 c=128", 3, 20, || {
        std::hint::black_box(pointops::three_nn_interpolate(&scene.points, &coarse, &cfeats));
    })
    .print();
    bench_fn("scene generation (synrgbd)", 2, 20, || {
        std::hint::black_box(generate_scene(11, &SYNRGBD));
    })
    .print();

    // PJRT dispatch cost: the smallest artifact round-trip
    let seeds = Tensor::zeros(vec![rt.manifest.num_seeds, rt.manifest.seed_feat]);
    bench_fn("pjrt dispatch (vote fp32)", 3, 30, || {
        std::hint::black_box(rt.run("synrgbd_pointsplit_vote_fp32", &[&seeds]).unwrap());
    })
    .print();

    // full functional pipelines
    for (name, variant, int8) in [
        ("pipeline votenet fp32", Variant::VoteNet, false),
        ("pipeline pointsplit fp32", Variant::PointSplit, false),
        ("pipeline pointsplit int8", Variant::PointSplit, true),
    ] {
        let cfg = DetectorConfig::new(
            "synrgbd",
            variant,
            int8,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        let pipe = ScenePipeline::new(&rt, cfg);
        bench_fn(name, 1, 8, || {
            std::hint::black_box(pipe.run(&scene, 3).unwrap());
        })
        .print();
    }
}
