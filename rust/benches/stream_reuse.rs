//! §Stream: temporal-reuse bench — per-frame latency and mAP-proxy of the
//! streaming path, cold vs warm session, persisted to `BENCH_stream.json`
//! (section `stream_reuse`).
//!
//! For each sequence seed a frame stream is generated once (seeded
//! ego-motion + movers + one scene cut per `cut_period`), then run twice:
//!
//! * **cold** — every frame through the full single-scene pipeline, the way
//!   a sessionless gateway would serve it;
//! * **warm** — every frame through `run_stream` against one per-session
//!   `FrameCache`, so REUSE frames ride the stream-tail sub-graph and
//!   PARTIAL frames repaint only dirty grid cells.
//!
//! Acceptance (the PR's perf bar): >= 2.0x median simulated per-frame
//! latency at >= 70% frame-reuse rate, with the warm mAP-proxy within 0.1
//! of cold.
//!
//! Knobs: POINTSPLIT_BENCH_SCENES = sequence count (default 2, CI: 1).

mod common;

use pointsplit::bench::{f2, update_bench_json, Table};
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::stream::{generate_stream, StreamCfg};
use pointsplit::data::SYNRGBD;
use pointsplit::eval::{eval_map, Detection};
use pointsplit::sim::DeviceKind;
use pointsplit::temporal::{DeltaCfg, FrameCache};
use pointsplit::util::json::Json;

fn median(xs: &[f64]) -> f64 {
    let mut s = xs.to_vec();
    s.sort_by(|a, b| a.total_cmp(b));
    if s.is_empty() { 0.0 } else { s[s.len() / 2] }
}

fn main() {
    let rt = common::open_runtime();
    let sequences = common::scene_budget(2);
    let frames_per_seq = if sequences <= 1 { 16 } else { 24 };
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let pipe = ScenePipeline::new(&rt, cfg.clone());
    let num_class = rt.manifest.classes.len();

    println!(
        "=== §Stream temporal reuse: {sequences} sequence(s) x {frames_per_seq} frames ===\n"
    );
    let mut cold_ms: Vec<f64> = Vec::new();
    let mut warm_ms: Vec<f64> = Vec::new();
    let mut cold_host: Vec<f64> = Vec::new();
    let mut warm_host: Vec<f64> = Vec::new();
    let mut cold_dets: Vec<Detection> = Vec::new();
    let mut warm_dets: Vec<Detection> = Vec::new();
    let mut gts = Vec::new();
    let (mut n_full, mut n_partial, mut n_reuse) = (0u64, 0u64, 0u64);
    let mut table =
        Table::new(&["seq", "full/part/reuse", "cold med ms", "warm med ms", "speedup"]);
    for s in 0..sequences {
        let seed = 40_000 + s as u64;
        let scfg = StreamCfg { frames: frames_per_seq, ..StreamCfg::default() };
        let stream = generate_stream(seed, &SYNRGBD, scfg);
        let mut cache = FrameCache::new(DeltaCfg::default(), 64 << 20);
        let (mut seq_cold, mut seq_warm) = (Vec::new(), Vec::new());
        for f in &stream {
            let scene_id = gts.len();
            gts.push(f.scene.gt_boxes());
            let cold = pipe.run(&f.scene, seed).expect("cold pipeline");
            seq_cold.push(cold.timeline.total_ms);
            cold_host.push(cold.host_ms);
            cold_dets
                .extend(cold.detections.iter().map(|b| Detection { scene: scene_id, b: *b }));
            let (warm, _class) = pipe.run_stream(&f.scene, seed, &mut cache).expect("warm pipeline");
            seq_warm.push(warm.timeline.total_ms);
            warm_host.push(warm.host_ms);
            warm_dets
                .extend(warm.detections.iter().map(|b| Detection { scene: scene_id, b: *b }));
        }
        let st = *cache.stats();
        n_full += st.full;
        n_partial += st.partial;
        n_reuse += st.reuse;
        table.row(vec![
            s.to_string(),
            format!("{}/{}/{}", st.full, st.partial, st.reuse),
            f2(median(&seq_cold)),
            f2(median(&seq_warm)),
            f2(median(&seq_cold) / median(&seq_warm).max(1e-9)),
        ]);
        cold_ms.extend(seq_cold);
        warm_ms.extend(seq_warm);
    }
    table.print("per-sequence latency (simulated ms, median over frames)");

    let frames = (n_full + n_partial + n_reuse).max(1);
    let reuse_rate = (n_partial + n_reuse) as f64 / frames as f64;
    let (cm, wm) = (median(&cold_ms), median(&warm_ms));
    let speedup = cm / wm.max(1e-9);
    let map_cold = eval_map(&cold_dets, &gts, num_class, 0.25).map;
    let map_warm = eval_map(&warm_dets, &gts, num_class, 0.25).map;
    let pass = speedup >= 2.0 && reuse_rate >= 0.7 && map_warm >= map_cold - 0.1;
    println!(
        "\nframes: full {n_full}  partial {n_partial}  reuse {n_reuse}  \
         (reuse rate {:.0}%)",
        100.0 * reuse_rate
    );
    println!(
        "median simulated per-frame latency: cold {cm:.1} ms  warm {wm:.1} ms  ({speedup:.2}x)"
    );
    println!(
        "median host per-frame time: cold {:.1} ms  warm {:.1} ms",
        median(&cold_host),
        median(&warm_host)
    );
    println!(
        "mAP-proxy@0.25: cold {:.1}  warm {:.1}  (delta {:+.1})",
        100.0 * map_cold,
        100.0 * map_warm,
        100.0 * (map_warm - map_cold)
    );
    println!(
        "acceptance: >= 2.0x at >= 70% reuse, mAP within 0.1 -> {}",
        if pass { "PASS" } else { "below (smoke settings?)" }
    );

    let payload = Json::obj(vec![
        ("bench", Json::Str("stream_reuse".to_string())),
        ("sequences", Json::Num(sequences as f64)),
        ("frames_per_seq", Json::Num(frames_per_seq as f64)),
        ("frames", Json::Num(frames as f64)),
        ("full", Json::Num(n_full as f64)),
        ("partial", Json::Num(n_partial as f64)),
        ("reuse", Json::Num(n_reuse as f64)),
        ("reuse_rate", Json::Num(reuse_rate)),
        ("cold_median_ms", Json::Num(cm)),
        ("warm_median_ms", Json::Num(wm)),
        ("speedup", Json::Num(speedup)),
        ("cold_host_median_ms", Json::Num(median(&cold_host))),
        ("warm_host_median_ms", Json::Num(median(&warm_host))),
        ("map_cold", Json::Num(map_cold)),
        ("map_warm", Json::Num(map_warm)),
        ("pass", Json::Bool(pass)),
    ]);
    update_bench_json("BENCH_stream.json", "stream_reuse", payload);
}
