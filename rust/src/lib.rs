//! PointSplit: on-device 3D object detection with heterogeneous low-power
//! accelerators — Rust + JAX + Pallas reproduction (see DESIGN.md).
//!
//! Layer 3 (this crate) owns the request path: synthetic RGB-D scenes flow
//! through the coordinator's two-lane (GPU/NPU) schedule; dense networks
//! execute as AOT-compiled HLO via PJRT (`runtime`), point manipulation runs
//! in `pointops`, and a calibrated device model (`sim`) provides
//! paper-comparable timing.

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod metrics;
pub mod pointops;
pub mod quant;
pub mod runtime;
pub mod sim;
pub mod util;
