//! Feature propagation: inverse-distance-weighted 3-NN interpolation
//! (mirror of sampling.three_nn_interpolate).
//!
//! §Perf: the production path searches the packed SoA [`GridStorage`] from
//! `ballquery` with an expanding-ring walk, scanning each cell's members in
//! fixed-width `[f32; LANES]` distance blocks, and writes rows into one
//! preallocated output buffer (`chunks_mut` over scoped threads — no
//! per-destination row allocation). Candidates are ranked by `(d2, index)`,
//! a total order, so the best-3 selection is independent of visit order:
//! the SIMD grid search, the scalar [`ScalarGrid`] oracle
//! ([`three_nn_interpolate_scalar`], the pre-SIMD code kept verbatim), the
//! brute-force reference, and every thread count produce identical output.
//!
//! Degenerate sources are well-defined: zero source points interpolate to
//! zeros, and 1 or 2 sources use all of them with IDW weights — no
//! `(INFINITY, 0)` sentinel ever reaches the weighting (the seed code
//! panicked on `row(0)` for empty sources and could emit NaN for Ns < 3).

use super::arena::{with_arena, ScratchArena};
use super::ballquery::{GridStorage, ScalarGrid};
use super::soa::{PointsSoA, LANES};
use crate::util::tensor::Tensor;

/// Below this source count a brute-force scan beats building a grid.
const GRID_MIN_SRC: usize = 64;
/// A destination this many empty rings away from the source bounding box
/// falls back to the O(Ns) scan — bounded work for destinations far
/// outside the cloud, where even the face-only shell walk adds up.
const FAR_BRUTE_RINGS: i32 = 64;

#[inline]
fn lex_lt(a: (f32, usize), b: (f32, usize)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// Insert a candidate into the sorted best-`kk` array (ranked by (d2, j)).
#[inline]
fn insert(best: &mut [(f32, usize); 3], kk: usize, d2: f32, j: usize) {
    if !lex_lt((d2, j), best[kk - 1]) {
        return;
    }
    best[kk - 1] = (d2, j);
    let mut i = kk - 1;
    while i > 0 && lex_lt(best[i], best[i - 1]) {
        best.swap(i, i - 1);
        i -= 1;
    }
}

#[inline]
fn dist2(a: &[f32; 3], b: &[f32; 3]) -> f32 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    dx * dx + dy * dy + dz * dz
}

/// `kk` nearest sources to `d` via expanding rings on the scalar oracle
/// grid. After finishing ring R every unvisited point is farther than
/// `R * cell`, so the search stops as soon as the current `kk`-th best is
/// within that bound. `start_ring` skips rings that provably contain no
/// source point (queries far outside the source bounding box); `max_ring`
/// bounds the search once every populated cell has been visited.
fn knn_grid(
    d: &[f32; 3],
    src: &[[f32; 3]],
    grid: &ScalarGrid,
    kk: usize,
    start_ring: i32,
    max_ring: i32,
) -> [(f32, usize); 3] {
    let cell = grid.cell_size();
    let mut best = [(f32::INFINITY, usize::MAX); 3];
    let mut ring = start_ring.max(0);
    loop {
        grid.ring(d, ring, |j| {
            let j = j as usize;
            insert(&mut best, kk, dist2(d, &src[j]), j);
        });
        let covered = (ring as f32) * cell;
        // strict <: on an exact f32 tie at the ring boundary an unvisited
        // lower-index point could still win the (d2, index) ranking, so
        // search one more ring — keeps grid == brute force even then
        if best[kk - 1].0.is_finite() && best[kk - 1].0 < covered * covered {
            break;
        }
        ring += 1;
        if ring > max_ring {
            break; // every populated cell visited
        }
    }
    best
}

/// `kk` nearest sources via expanding rings on the packed grid, scanning
/// each cell's members in `[f32; LANES]` distance blocks. Identical result
/// to [`knn_grid`]: the rings enumerate the same cells, the per-element
/// distance op order matches, and the `(d2, index)` ranking makes the
/// selection independent of visit order.
fn knn_grid_lanes(
    d: [f32; 3],
    grid: &GridStorage,
    kk: usize,
    start_ring: i32,
    max_ring: i32,
) -> [(f32, usize); 3] {
    let cell = grid.cell_size();
    let mut best = [(f32::INFINITY, usize::MAX); 3];
    let mut ring = start_ring.max(0);
    loop {
        grid.ring(d, ring, |xs, ys, zs, ids| {
            let len = ids.len();
            let mut i = 0;
            while i + LANES <= len {
                let mut d2 = [0.0f32; LANES];
                for l in 0..LANES {
                    let dx = xs[i + l] - d[0];
                    let dy = ys[i + l] - d[1];
                    let dz = zs[i + l] - d[2];
                    d2[l] = dx * dx + dy * dy + dz * dz;
                }
                for l in 0..LANES {
                    insert(&mut best, kk, d2[l], ids[i + l] as usize);
                }
                i += LANES;
            }
            for j in i..len {
                let dx = xs[j] - d[0];
                let dy = ys[j] - d[1];
                let dz = zs[j] - d[2];
                insert(&mut best, kk, dx * dx + dy * dy + dz * dz, ids[j] as usize);
            }
        });
        let covered = (ring as f32) * cell;
        if best[kk - 1].0.is_finite() && best[kk - 1].0 < covered * covered {
            break;
        }
        ring += 1;
        if ring > max_ring {
            break;
        }
    }
    best
}

/// Best-`kk` by plain scan over an SoA cloud (small-source and far-query
/// fallbacks; same op order and ranking as the reference scan).
fn brute_best(d: [f32; 3], src: &PointsSoA, kk: usize) -> [(f32, usize); 3] {
    let (xs, ys, zs) = (src.xs(), src.ys(), src.zs());
    let mut best = [(f32::INFINITY, usize::MAX); 3];
    for j in 0..src.len() {
        let dx = xs[j] - d[0];
        let dy = ys[j] - d[1];
        let dz = zs[j] - d[2];
        insert(&mut best, kk, dx * dx + dy * dy + dz * dz, j);
    }
    best
}

/// IDW-weighted feature row for one destination point.
#[inline]
fn idw_row(best: &[(f32, usize); 3], kk: usize, src_feats: &Tensor, out: &mut [f32]) {
    let mut w = [0.0f32; 3];
    let mut wsum = 0.0f32;
    for i in 0..kk {
        w[i] = 1.0 / best[i].0.max(1e-8);
        wsum += w[i];
    }
    for i in 0..kk {
        let row = src_feats.row(best[i].1);
        let wn = w[i] / wsum;
        for (o, v) in out.iter_mut().zip(row.iter()) {
            *o += wn * v;
        }
    }
}

/// Interpolate `src_feats` (Ns, C) at `dst_xyz` from `src_xyz` -> (Nd, C).
pub fn three_nn_interpolate(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
) -> Tensor {
    three_nn_interpolate_par(dst_xyz, src_xyz, src_feats, 1)
}

/// `three_nn_interpolate` with destination points spread over up to
/// `threads` scoped threads (clamped to the destination count; 0 behaves
/// as 1). Identical output for any thread count.
pub fn three_nn_interpolate_par(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
    threads: usize,
) -> Tensor {
    with_arena(|a| {
        let ScratchArena { soa, soa2, grid, .. } = a;
        soa.fill_from_points(dst_xyz);
        soa2.fill_from_points(src_xyz);
        three_nn_core(soa, soa2, src_feats, threads, grid)
    })
}

/// Interpolation over clouds already in SoA layout (the pipeline's steady
/// path — skips both conversion copies).
pub fn three_nn_interpolate_soa(
    dst: &PointsSoA,
    src: &PointsSoA,
    src_feats: &Tensor,
    threads: usize,
) -> Tensor {
    with_arena(|a| three_nn_core(dst, src, src_feats, threads, &mut a.grid))
}

/// Shared SIMD implementation over the arena's packed grid. Writes every
/// destination row into one preallocated buffer.
fn three_nn_core(
    dst: &PointsSoA,
    src: &PointsSoA,
    src_feats: &Tensor,
    threads: usize,
    grid: &mut GridStorage,
) -> Tensor {
    assert_eq!(src.len(), src_feats.rows());
    let c = src_feats.row_len();
    let nd = dst.len();
    let ns = src.len();
    let mut out = vec![0.0f32; nd * c];
    if ns == 0 {
        return Tensor::new(vec![nd, c], out);
    }
    let kk = ns.min(3);
    // grid cell sized for ~1 source point per cell; degenerate clouds
    // (tiny or near-coincident) take the bounded exact scan instead
    let grid_params = if ns >= GRID_MIN_SRC {
        let (mut lo, mut hi) = (src.get(0), src.get(0));
        for p in src.iter() {
            for a in 0..3 {
                lo[a] = lo[a].min(p[a]);
                hi[a] = hi[a].max(p[a]);
            }
        }
        let extent = (hi[0] - lo[0]).max(hi[1] - lo[1]).max(hi[2] - lo[2]);
        let cell = extent / (ns as f32).cbrt();
        if cell < 1e-4 {
            None
        } else {
            grid.build(src, cell);
            // past this ring the search has seen every populated cell no
            // matter where the query sits relative to the bounding box
            let span = ((extent / cell).ceil() as i32).saturating_add(1);
            Some((lo, hi, cell, span))
        }
    } else {
        None
    };
    let grid = &*grid;
    let row_of = |i: usize, row: &mut [f32]| {
        let d = dst.get(i);
        match grid_params {
            Some((lo, hi, cell, span)) => {
                // Chebyshev distance from the query to the source bounding
                // box: rings below floor(r/cell) - 1 cannot contain a source
                // point, and rings beyond span + ceil(r/cell) + 1 have all
                // been visited
                let mut r = 0f32;
                for a in 0..3 {
                    r = r.max((lo[a] - d[a]).max(d[a] - hi[a]).max(0.0));
                }
                let start_ring = ((r / cell).floor() as i32).saturating_sub(1);
                if start_ring > FAR_BRUTE_RINGS {
                    // far outside the cloud: a plain scan is bounded and exact
                    let best = brute_best(d, src, kk);
                    idw_row(&best, kk, src_feats, row);
                } else {
                    let max_ring =
                        span.saturating_add((r / cell).ceil() as i32).saturating_add(1);
                    let best = knn_grid_lanes(d, grid, kk, start_ring, max_ring);
                    idw_row(&best, kk, src_feats, row);
                }
            }
            None => {
                let best = brute_best(d, src, kk);
                idw_row(&best, kk, src_feats, row);
            }
        }
    };
    let nt = threads.clamp(1, nd.max(1));
    if nt <= 1 || nd < 64 {
        for (i, row) in out.chunks_mut(c.max(1)).enumerate() {
            row_of(i, row);
        }
    } else {
        // each thread owns a contiguous block of output rows — rows are
        // independent, so the result is identical for any thread count
        let rows_per = nd.div_ceil(nt);
        std::thread::scope(|scope| {
            for (t, chunk) in out.chunks_mut(rows_per * c.max(1)).enumerate() {
                let row_of = &row_of;
                scope.spawn(move || {
                    for (j, row) in chunk.chunks_mut(c.max(1)).enumerate() {
                        row_of(t * rows_per + j, row);
                    }
                });
            }
        });
    }
    Tensor::new(vec![nd, c], out)
}

/// Scalar reference implementation (the pre-SIMD grid path, kept verbatim)
/// — the oracle the SIMD path is pinned bit-identical to, and the baseline
/// `BENCH_hotpath` measures speedups against.
pub fn three_nn_interpolate_scalar(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
) -> Tensor {
    assert_eq!(src_xyz.len(), src_feats.rows());
    let c = src_feats.row_len();
    let ns = src_xyz.len();
    if ns < GRID_MIN_SRC {
        // small sources (incl. the degenerate Ns < 3 cases): the reference
        // scan is cheaper than building a grid and shares the ranking rule
        return three_nn_interpolate_bruteforce(dst_xyz, src_xyz, src_feats);
    }
    let kk = ns.min(3);
    // grid cell sized for ~1 source point per cell
    let mut lo = src_xyz[0];
    let mut hi = src_xyz[0];
    for p in src_xyz {
        for a in 0..3 {
            lo[a] = lo[a].min(p[a]);
            hi[a] = hi[a].max(p[a]);
        }
    }
    let extent = (hi[0] - lo[0]).max(hi[1] - lo[1]).max(hi[2] - lo[2]);
    let cell = extent / (ns as f32).cbrt();
    if cell < 1e-4 {
        // near-coincident cloud: grid cells would degenerate and ring
        // searches crawl; the plain scan is bounded and exact
        return three_nn_interpolate_bruteforce(dst_xyz, src_xyz, src_feats);
    }
    let grid = ScalarGrid::build(src_xyz, cell);
    let span = ((extent / cell).ceil() as i32).saturating_add(1);
    let mut out = Vec::with_capacity(dst_xyz.len() * c);
    for d in dst_xyz {
        let mut r = 0f32;
        for a in 0..3 {
            r = r.max((lo[a] - d[a]).max(d[a] - hi[a]).max(0.0));
        }
        let start_ring = ((r / cell).floor() as i32).saturating_sub(1);
        let mut row = vec![0.0f32; c];
        if start_ring > FAR_BRUTE_RINGS {
            let mut best = [(f32::INFINITY, usize::MAX); 3];
            for (j, s) in src_xyz.iter().enumerate() {
                insert(&mut best, kk, dist2(d, s), j);
            }
            idw_row(&best, kk, src_feats, &mut row);
        } else {
            let max_ring = span
                .saturating_add((r / cell).ceil() as i32)
                .saturating_add(1);
            let best = knn_grid(d, src_xyz, &grid, kk, start_ring, max_ring);
            idw_row(&best, kk, src_feats, &mut row);
        }
        out.extend_from_slice(&row);
    }
    Tensor::new(vec![dst_xyz.len(), c], out)
}

/// Reference O(Nd*Ns) scan kept for tests and the §Perf comparison.
pub fn three_nn_interpolate_bruteforce(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
) -> Tensor {
    assert_eq!(src_xyz.len(), src_feats.rows());
    let c = src_feats.row_len();
    let ns = src_xyz.len();
    if ns == 0 {
        return Tensor::zeros(vec![dst_xyz.len(), c]);
    }
    let kk = ns.min(3);
    let mut out = vec![0.0f32; dst_xyz.len() * c];
    for (d, orow) in dst_xyz.iter().zip(out.chunks_mut(c.max(1))) {
        let mut best = [(f32::INFINITY, usize::MAX); 3];
        for (j, s) in src_xyz.iter().enumerate() {
            insert(&mut best, kk, dist2(d, s), j);
        }
        idw_row(&best, kk, src_feats, orow);
    }
    Tensor::new(vec![dst_xyz.len(), c], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cloud(n: usize, seed: u64) -> Vec<[f32; 3]> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| [r.f32() * 3.0, r.f32() * 3.0, r.f32()]).collect()
    }

    fn feats(n: usize, c: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(vec![n, c], (0..n * c).map(|_| r.f32() * 4.0 - 2.0).collect())
    }

    #[test]
    fn exact_at_source_points() {
        let src = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [1.0, 1.0, 0.0]];
        let f = Tensor::new(vec![4, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        let out = three_nn_interpolate(&src, &src, &f);
        // at a source point the nearest neighbor has d2~0 -> dominates
        assert!((out.row(2)[0] - 3.0).abs() < 1e-3);
        assert!((out.row(2)[1] - 30.0).abs() < 1e-2);
    }

    #[test]
    fn interpolation_is_convex_combination() {
        let src = vec![[0.0, 0.0, 0.0], [2.0, 0.0, 0.0], [0.0, 2.0, 0.0]];
        let f = Tensor::new(vec![3, 1], vec![0.0, 6.0, 12.0]);
        let out = three_nn_interpolate(&[[0.5, 0.5, 0.0]], &src, &f);
        let v = out.data[0];
        assert!(v > 0.0 && v < 12.0);
    }

    #[test]
    fn grid_matches_bruteforce() {
        for seed in 0..4 {
            let src = cloud(400, seed); // > GRID_MIN_SRC -> grid path
            let f = feats(400, 7, seed + 100);
            let dst = cloud(150, seed + 200);
            let a = three_nn_interpolate(&dst, &src, &f);
            let b = three_nn_interpolate_bruteforce(&dst, &src, &f);
            assert_eq!(a, b, "seed {seed}");
        }
    }

    #[test]
    fn simd_matches_scalar_oracle() {
        for seed in 0..4 {
            let src = cloud(450, seed + 10);
            let f = feats(450, 6, seed + 110);
            let dst = cloud(173, seed + 210); // odd count exercises lane tails
            assert_eq!(
                three_nn_interpolate(&dst, &src, &f),
                three_nn_interpolate_scalar(&dst, &src, &f),
                "seed {seed}"
            );
        }
    }

    #[test]
    fn soa_entry_point_matches_interleaved() {
        let src = cloud(300, 51);
        let f = feats(300, 4, 52);
        let dst = cloud(140, 53);
        let s_src = PointsSoA::from_points(&src);
        let s_dst = PointsSoA::from_points(&dst);
        for threads in [1, 4] {
            assert_eq!(
                three_nn_interpolate_soa(&s_dst, &s_src, &f, threads),
                three_nn_interpolate(&dst, &src, &f),
                "threads {threads}"
            );
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let src = cloud(500, 21);
        let f = feats(500, 5, 22);
        let dst = cloud(300, 23);
        let seq = three_nn_interpolate(&dst, &src, &f);
        for threads in [2, 3, 8] {
            assert_eq!(three_nn_interpolate_par(&dst, &src, &f, threads), seq);
        }
    }

    #[test]
    fn thread_budget_is_clamped() {
        let src = cloud(400, 25);
        let f = feats(400, 5, 26);
        let dst = cloud(200, 27);
        let seq = three_nn_interpolate(&dst, &src, &f);
        assert_eq!(three_nn_interpolate_par(&dst, &src, &f, 0), seq, "threads=0");
        assert_eq!(
            three_nn_interpolate_par(&dst, &src, &f, usize::MAX),
            seq,
            "threads=usize::MAX"
        );
    }

    #[test]
    fn faraway_destinations_still_find_sources() {
        // dst far outside the src bounding box exercises the ring cap
        let src = cloud(200, 31);
        let f = feats(200, 3, 32);
        let dst = vec![[50.0, -40.0, 10.0], [-9.0, 0.0, 0.0]];
        let a = three_nn_interpolate(&dst, &src, &f);
        let b = three_nn_interpolate_bruteforce(&dst, &src, &f);
        assert_eq!(a, b);
    }

    #[test]
    fn degenerate_extent_far_destination_terminates() {
        // >= GRID_MIN_SRC near-coincident sources clamp the cell size to
        // 1e-4; a far destination must take the bounded fallback scan, not
        // an astronomically long ring search
        let src: Vec<[f32; 3]> = (0..80).map(|i| [1.0 + i as f32 * 1e-7, 2.0, 0.5]).collect();
        let f = feats(80, 2, 40);
        let dst = vec![[60.0, -10.0, 3.0], [1.0, 2.0, 0.5]];
        let a = three_nn_interpolate(&dst, &src, &f);
        let b = three_nn_interpolate_bruteforce(&dst, &src, &f);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_source_interpolates_to_zeros() {
        let src: Vec<[f32; 3]> = Vec::new();
        let f = Tensor::zeros(vec![0, 4]);
        let out = three_nn_interpolate(&[[0.0, 0.0, 0.0], [1.0, 1.0, 1.0]], &src, &f);
        assert_eq!(out.shape, vec![2, 4]);
        assert!(out.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn single_source_copies_features() {
        let src = vec![[1.0, 2.0, 3.0]];
        let f = Tensor::new(vec![1, 3], vec![7.0, -1.0, 0.5]);
        let out = three_nn_interpolate(&[[0.0, 0.0, 0.0], [9.0, 9.0, 9.0]], &src, &f);
        for i in 0..2 {
            assert_eq!(out.row(i), &[7.0, -1.0, 0.5], "dst {i}");
        }
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn two_sources_interpolate_without_nan() {
        let src = vec![[0.0, 0.0, 0.0], [2.0, 0.0, 0.0]];
        let f = Tensor::new(vec![2, 1], vec![0.0, 10.0]);
        let out = three_nn_interpolate(&[[1.0, 0.0, 0.0], [0.0, 0.0, 0.0]], &src, &f);
        assert!(out.data.iter().all(|v| v.is_finite()));
        // midpoint: equal weights
        assert!((out.data[0] - 5.0).abs() < 1e-4);
        // at src 0 the near point dominates
        assert!(out.data[1] < 1.0);
    }
}
