//! Latency summary statistics shared by the closed-loop serve report and the
//! open-loop traffic gateway (single source of the percentile convention).

/// Summary of a latency (or any scalar) sample set, in the sample's unit.
#[derive(Debug, Clone, Default)]
pub struct Stats {
    pub mean: f64,
    pub p50: f64,
    pub p95: f64,
    pub p99: f64,
    pub min: f64,
    pub max: f64,
}

/// Index-based percentile over an ascending-sorted slice: `xs[n*q/100]`,
/// clamped to the last element (the seed convention — nearest-rank, no
/// interpolation). Returns 0 for an empty slice.
pub fn percentile(sorted: &[f64], q: usize) -> f64 {
    if sorted.is_empty() {
        return 0.0;
    }
    let n = sorted.len();
    sorted[(n * q / 100).min(n - 1)]
}

impl Stats {
    /// Summarize a sample set (consumes and sorts it).
    // the seed crate established `Stats::from(samples)` as the call-site
    // idiom; keep it rather than a `From` impl
    #[allow(clippy::should_implement_trait)]
    pub fn from(mut xs: Vec<f64>) -> Stats {
        if xs.is_empty() {
            return Stats::default();
        }
        xs.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let n = xs.len();
        Stats {
            mean: xs.iter().sum::<f64>() / n as f64,
            p50: percentile(&xs, 50),
            p95: percentile(&xs, 95),
            p99: percentile(&xs, 99),
            min: xs[0],
            max: xs[n - 1],
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn empty_is_zeroed() {
        let s = Stats::from(Vec::new());
        assert_eq!(s.mean, 0.0);
        assert_eq!(s.p99, 0.0);
    }

    #[test]
    fn percentiles_ordered() {
        let xs: Vec<f64> = (1..=1000).map(|i| i as f64).collect();
        let s = Stats::from(xs);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 1000.0);
        assert_eq!(s.p50, 501.0); // index n/2 of 1..=1000
        assert_eq!(s.p95, 951.0);
        assert_eq!(s.p99, 991.0);
        assert!(s.p50 <= s.p95 && s.p95 <= s.p99 && s.p99 <= s.max);
        assert!((s.mean - 500.5).abs() < 1e-9);
    }

    #[test]
    fn matches_seed_indexing_convention() {
        // seed code used xs[n/2] and xs[(n*95/100).min(n-1)]
        let xs = vec![3.0, 1.0, 2.0];
        let s = Stats::from(xs);
        assert_eq!(s.p50, 2.0); // sorted [1,2,3], index 3/2 = 1
        assert_eq!(s.p95, 3.0); // index min(2,2)
    }

    #[test]
    fn singleton() {
        let s = Stats::from(vec![7.5]);
        assert_eq!(s.mean, 7.5);
        assert_eq!(s.p50, 7.5);
        assert_eq!(s.p99, 7.5);
    }
}
