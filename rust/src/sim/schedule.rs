//! Dependency-respecting schedule simulator for the two-lane (GPU/NPU)
//! pipelines of Fig. 2 (naive sequential) and Fig. 3 (PointSplit overlap).
//!
//! Each stage carries a workload descriptor and a device assignment; the
//! simulator performs a list-scheduling pass that honours stage dependencies
//! and single-occupancy devices, charging interconnect transfers whenever a
//! dependency crosses a device boundary. Output is a [`Timeline`] with
//! per-stage intervals, per-device busy/idle, and comm/comp split — the raw
//! material for Tables 12/13 and Figs. 9/10.

use std::collections::HashMap;

use super::device::{Device, DeviceKind, Precision, Workload};

/// One schedulable stage.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct StageSpec {
    pub name: String,
    pub device: DeviceKind,
    /// Numeric regime of the stage — the QuantScheme property the scheduler
    /// prices (device eligibility + per-precision throughput). Carried by
    /// the same declaration the [`crate::exec::DagExecutor`] runs.
    pub precision: Precision,
    pub workload: Workload,
    /// indices of stages that must finish first
    pub deps: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct StageInterval {
    pub name: String,
    pub device: DeviceKind,
    /// numeric regime the stage executed at (from its [`StageSpec`])
    pub precision: Precision,
    /// transfer start (equals compute start when no transfer needed)
    pub start_ms: f64,
    pub compute_start_ms: f64,
    pub end_ms: f64,
    pub comm_ms: f64,
}

#[derive(Debug, Clone)]
pub struct Timeline {
    pub stages: Vec<StageInterval>,
    pub total_ms: f64,
    pub busy_ms: HashMap<DeviceKind, f64>,
    pub comm_ms: HashMap<DeviceKind, f64>,
}

impl Timeline {
    pub fn idle_ms(&self, kind: DeviceKind) -> f64 {
        self.total_ms - self.busy_ms.get(&kind).copied().unwrap_or(0.0)
    }

    pub fn stage(&self, name: &str) -> Option<&StageInterval> {
        self.stages.iter().find(|s| s.name == name)
    }
}

/// Per-batch cost summary extracted from a simulated [`Timeline`] — a pure
/// reduction, so it lives with the simulator (the serving planner and the
/// placement search both consume it).
#[derive(Debug, Clone, Copy)]
pub struct PlanCost {
    /// Critical-path latency of the batch, ms.
    pub total_ms: f64,
    pub busy_gpu_ms: f64,
    pub busy_npu_ms: f64,
    pub busy_cpu_ms: f64,
    /// Total interconnect time charged, ms.
    pub comm_ms: f64,
    /// Largest per-device occupancy (compute + transfers), ms. In steady
    /// state the pipeline admits a new batch every `bottleneck_ms`, so this
    /// sets the gateway's service rate while `total_ms` sets its latency.
    pub bottleneck_ms: f64,
}

impl PlanCost {
    /// Uniform service-time stretch (straggler model: a thermally throttled
    /// or contended box does everything `f`× slower). `f == 1.0` returns
    /// `self` bit-for-bit, so healthy boxes stay byte-identical to the
    /// unscaled cost and determinism tests hold.
    pub fn scaled(&self, f: f64) -> PlanCost {
        if f == 1.0 {
            return *self;
        }
        PlanCost {
            total_ms: self.total_ms * f,
            busy_gpu_ms: self.busy_gpu_ms * f,
            busy_npu_ms: self.busy_npu_ms * f,
            busy_cpu_ms: self.busy_cpu_ms * f,
            comm_ms: self.comm_ms * f,
            bottleneck_ms: self.bottleneck_ms * f,
        }
    }
}

/// Reduce a simulated timeline to the dispatcher's cost summary.
pub fn cost_of(tl: &Timeline) -> PlanCost {
    let busy = |k: DeviceKind| tl.busy_ms.get(&k).copied().unwrap_or(0.0);
    let comm = |k: DeviceKind| tl.comm_ms.get(&k).copied().unwrap_or(0.0);
    let occupancy = |k: DeviceKind| busy(k) + comm(k);
    let bottleneck = [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::EdgeTpu]
        .into_iter()
        .map(occupancy)
        .fold(0.0, f64::max);
    PlanCost {
        total_ms: tl.total_ms,
        busy_gpu_ms: busy(DeviceKind::Gpu),
        busy_npu_ms: busy(DeviceKind::EdgeTpu),
        busy_cpu_ms: busy(DeviceKind::Cpu),
        comm_ms: tl.comm_ms.values().sum(),
        bottleneck_ms: bottleneck.max(1e-6),
    }
}

/// Deterministic list scheduler over a stage DAG.
pub struct ScheduleSim {
    devices: HashMap<DeviceKind, Device>,
}

impl Default for ScheduleSim {
    fn default() -> Self {
        Self::new()
    }
}

impl ScheduleSim {
    pub fn new() -> Self {
        let mut devices = HashMap::new();
        for k in [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::EdgeTpu] {
            devices.insert(k, Device::by_kind(k));
        }
        ScheduleSim { devices }
    }

    /// Override a device model (tests / what-if analyses).
    pub fn with_device(mut self, d: Device) -> Self {
        self.devices.insert(d.kind, d);
        self
    }

    pub fn device(&self, kind: DeviceKind) -> &Device {
        &self.devices[&kind]
    }

    /// Simulate the DAG with greedy earliest-start scheduling: at each step,
    /// among stages whose dependencies are all finished, dispatch the one
    /// that can begin earliest (ties broken by submission index). This models
    /// a work-conserving per-device executor, so independent pipelines
    /// interleave on a device regardless of submission order — exactly the
    /// overlap PointSplit exploits (Fig. 3).
    pub fn run(&self, stages: &[StageSpec]) -> Timeline {
        let n = stages.len();
        // Occupancy resource: accelerators are single-occupancy; the
        // quad-core CPU runs its point-op and NN thread pools concurrently
        // (the paper's CPU-CPU pairing still gains 1.7x from pipelining),
        // so CPU occupancy is keyed per workload kind.
        let res_key = |s: &StageSpec| -> (DeviceKind, u8) {
            match s.device {
                DeviceKind::Cpu => (
                    DeviceKind::Cpu,
                    match s.workload.kind {
                        super::device::WorkloadKind::PointOp => 0,
                        super::device::WorkloadKind::NeuralNet => 1,
                    },
                ),
                d => (d, 0),
            }
        };
        let mut dev_free: HashMap<(DeviceKind, u8), f64> = HashMap::new();
        let mut busy: HashMap<DeviceKind, f64> = HashMap::new();
        let mut comm: HashMap<DeviceKind, f64> = HashMap::new();
        let mut done: Vec<Option<StageInterval>> = vec![None; n];
        let mut scheduled = vec![false; n];

        for s in stages {
            assert!(
                self.devices[&s.device].supports(s.workload.kind, s.precision),
                "stage '{}' ({}) assigned to {:?} which cannot run it",
                s.name,
                s.precision.name(),
                s.device
            );
        }

        for _ in 0..n {
            // candidate = ready stage with the earliest feasible start
            let mut best: Option<(f64, f64, usize, u64)> = None; // (start, comm, idx, xfer)
            for (i, s) in stages.iter().enumerate() {
                if scheduled[i] {
                    continue;
                }
                if !s.deps.iter().all(|&d| done[d].is_some()) {
                    continue;
                }
                let dev = &self.devices[&s.device];
                let mut xfer_bytes = 0u64;
                let mut deps_ready: f64 = 0.0;
                for &d in &s.deps {
                    let di = done[d].as_ref().unwrap();
                    deps_ready = deps_ready.max(di.end_ms);
                    if di.device != s.device {
                        xfer_bytes += stages[d].workload.wire_bytes;
                    }
                }
                // the transfer is charged on whichever endpoint sits behind
                // the slow interconnect (EdgeTPU's PCIe link)
                let link_dev = if dev.link_bytes_per_ms.is_finite() {
                    dev
                } else {
                    s.deps
                        .iter()
                        .map(|&d| &self.devices[&done[d].as_ref().unwrap().device])
                        .find(|pd| pd.link_bytes_per_ms.is_finite())
                        .unwrap_or(dev)
                };
                let t_comm = link_dev.transfer_ms(xfer_bytes);
                let free = dev_free.get(&res_key(s)).copied().unwrap_or(0.0);
                let start = deps_ready.max(free);
                if best.is_none_or(|(bs, _, bi, _)| start < bs || (start == bs && i < bi)) {
                    best = Some((start, t_comm, i, xfer_bytes));
                }
            }
            let (start, t_comm, i, _) = best.expect("cyclic or broken stage DAG");
            let s = &stages[i];
            let dev = &self.devices[&s.device];
            let compute_start = start + t_comm;
            let t_comp = dev.compute_ms(&s.workload, s.precision);
            let end = compute_start + t_comp;
            dev_free.insert(res_key(s), end);
            *busy.entry(s.device).or_insert(0.0) += t_comp;
            *comm.entry(s.device).or_insert(0.0) += t_comm;
            scheduled[i] = true;
            done[i] = Some(StageInterval {
                name: s.name.clone(),
                device: s.device,
                precision: s.precision,
                start_ms: start,
                compute_start_ms: compute_start,
                end_ms: end,
                comm_ms: t_comm,
            });
        }
        let mut stages_out: Vec<StageInterval> = done.into_iter().map(|d| d.unwrap()).collect();
        let total = stages_out.iter().map(|s| s.end_ms).fold(0.0, f64::max);
        stages_out.sort_by(|a, b| a.start_ms.partial_cmp(&b.start_ms).unwrap());
        Timeline { stages: stages_out, total_ms: total, busy_ms: busy, comm_ms: comm }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::device::{Precision, WorkloadKind};

    fn wl(kind: WorkloadKind, flops: u64) -> Workload {
        Workload { kind, flops, mem_bytes: 0, wire_bytes: 4000 }
    }

    fn pointop_stage(name: &str, device: DeviceKind, flops: u64, deps: Vec<usize>) -> StageSpec {
        StageSpec {
            name: name.into(),
            device,
            precision: Precision::Fp32,
            workload: wl(WorkloadKind::PointOp, flops),
            deps,
        }
    }

    fn nn_stage(name: &str, device: DeviceKind, flops: u64, deps: Vec<usize>) -> StageSpec {
        StageSpec {
            name: name.into(),
            device,
            precision: Precision::Int8,
            workload: wl(WorkloadKind::NeuralNet, flops),
            deps,
        }
    }

    #[test]
    fn sequential_deps_respected() {
        let sim = ScheduleSim::new();
        let stages = vec![
            pointop_stage("a", DeviceKind::Gpu, 1_000_000, vec![]),
            nn_stage("b", DeviceKind::EdgeTpu, 10_000_000, vec![0]),
            pointop_stage("c", DeviceKind::Gpu, 1_000_000, vec![1]),
        ];
        let t = sim.run(&stages);
        assert!(t.stages[1].compute_start_ms >= t.stages[0].end_ms);
        assert!(t.stages[2].compute_start_ms >= t.stages[1].end_ms);
        assert!(t.stages[1].comm_ms > 0.0, "GPU->EdgeTPU crossing must pay PCIe");
    }

    #[test]
    fn independent_stages_overlap_across_devices() {
        let sim = ScheduleSim::new();
        let stages = vec![
            pointop_stage("g", DeviceKind::Gpu, 5_000_000, vec![]),
            nn_stage("t", DeviceKind::EdgeTpu, 50_000_000, vec![]),
        ];
        let t = sim.run(&stages);
        let seq = sim
            .device(DeviceKind::Gpu)
            .compute_ms(&wl(WorkloadKind::PointOp, 5_000_000), Precision::Fp32)
            + sim
                .device(DeviceKind::EdgeTpu)
                .compute_ms(&wl(WorkloadKind::NeuralNet, 50_000_000), Precision::Int8);
        assert!(t.total_ms < seq, "parallel {t:?} must beat sequential {seq}");
    }

    #[test]
    fn same_device_serializes() {
        let sim = ScheduleSim::new();
        let stages = vec![
            pointop_stage("a", DeviceKind::Gpu, 2_000_000, vec![]),
            pointop_stage("b", DeviceKind::Gpu, 2_000_000, vec![]),
        ];
        let t = sim.run(&stages);
        let (a, b) = (&t.stages[0], &t.stages[1]);
        assert!(b.compute_start_ms >= a.end_ms || a.compute_start_ms >= b.end_ms);
    }

    #[test]
    fn busy_plus_idle_equals_total() {
        let sim = ScheduleSim::new();
        let stages = vec![
            pointop_stage("a", DeviceKind::Gpu, 3_000_000, vec![]),
            nn_stage("b", DeviceKind::EdgeTpu, 30_000_000, vec![0]),
        ];
        let t = sim.run(&stages);
        let busy = t.busy_ms[&DeviceKind::Gpu];
        assert!((busy + t.idle_ms(DeviceKind::Gpu) - t.total_ms).abs() < 1e-9);
    }

    #[test]
    fn per_precision_latency_reflected_in_timeline() {
        // same NN workload on the CPU: the int8 stage must finish faster
        let sim = ScheduleSim::new();
        let mut fp = nn_stage("nn", DeviceKind::Cpu, 60_000_000, vec![]);
        fp.precision = Precision::Fp32;
        let t_fp = sim.run(std::slice::from_ref(&fp));
        let t_i8 = sim.run(&[nn_stage("nn", DeviceKind::Cpu, 60_000_000, vec![])]);
        assert!(
            t_i8.total_ms < t_fp.total_ms,
            "int8 {} ms must beat fp32 {} ms on the CPU",
            t_i8.total_ms,
            t_fp.total_ms
        );
    }

    #[test]
    #[should_panic(expected = "cannot run it")]
    fn pointop_on_edgetpu_panics() {
        let sim = ScheduleSim::new();
        sim.run(&[pointop_stage("x", DeviceKind::EdgeTpu, 1000, vec![])]);
    }

    #[test]
    #[should_panic(expected = "cannot run it")]
    fn fp32_nn_on_edgetpu_panics() {
        let sim = ScheduleSim::new();
        let mut s = nn_stage("x", DeviceKind::EdgeTpu, 1000, vec![]);
        s.precision = Precision::Fp32;
        sim.run(&[s]);
    }
}
