//! Reactive autoscaling: grow/shrink the fleet on observed queue depth.
//!
//! A deliberately simple threshold controller, split so the policy itself
//! is a pure function ([`decide`]): the runner samples mean queue fill
//! every `check_interval_ms`, and outside the cooldown window acts on the
//! decision — scale-up provisions the box type with the best capacity per
//! cost unit (after a `spawn_delay_ms` provisioning lag), scale-down
//! retires the most recently added *idle* box (never one holding queued
//! work, so scaling down cannot lose requests). The run's bill is the
//! per-box cost-unit rate integrated over alive time.

/// Autoscaler knobs — all times on the simulated clock.
#[derive(Debug, Clone, Copy)]
pub struct AutoscalePolicy {
    /// Sampling period for fleet queue depth.
    pub check_interval_ms: f64,
    /// Provisioning lag between a scale-up decision and the box joining.
    pub spawn_delay_ms: f64,
    /// Minimum time between consecutive scaling actions.
    pub cooldown_ms: f64,
    /// Scale up when mean queue fill (len/capacity) exceeds this.
    pub up_depth_frac: f64,
    /// Scale down when mean queue fill drops below this.
    pub down_depth_frac: f64,
    pub min_boxes: usize,
    pub max_boxes: usize,
}

impl Default for AutoscalePolicy {
    fn default() -> Self {
        AutoscalePolicy {
            check_interval_ms: 2_000.0,
            spawn_delay_ms: 1_000.0,
            cooldown_ms: 4_000.0,
            up_depth_frac: 0.5,
            down_depth_frac: 0.05,
            min_boxes: 1,
            max_boxes: 16,
        }
    }
}

/// Outcome of one autoscaler observation.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScaleDecision {
    Up,
    Down,
    Hold,
}

/// Pure threshold policy: map (mean queue fill, provisioned box count) to
/// a decision. `provisioned` counts alive boxes plus in-flight spawns so
/// one burst cannot order `max_boxes` duplicates during the spawn lag.
pub fn decide(p: &AutoscalePolicy, mean_depth_frac: f64, provisioned: usize) -> ScaleDecision {
    if mean_depth_frac > p.up_depth_frac && provisioned < p.max_boxes {
        ScaleDecision::Up
    } else if mean_depth_frac < p.down_depth_frac && provisioned > p.min_boxes {
        ScaleDecision::Down
    } else {
        ScaleDecision::Hold
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thresholds_drive_decisions() {
        let p = AutoscalePolicy::default();
        assert_eq!(decide(&p, 0.8, 2), ScaleDecision::Up);
        assert_eq!(decide(&p, 0.01, 2), ScaleDecision::Down);
        assert_eq!(decide(&p, 0.2, 2), ScaleDecision::Hold);
    }

    #[test]
    fn bounds_are_respected() {
        let p = AutoscalePolicy { min_boxes: 2, max_boxes: 3, ..AutoscalePolicy::default() };
        assert_eq!(decide(&p, 0.9, 3), ScaleDecision::Hold, "at max_boxes");
        assert_eq!(decide(&p, 0.0, 2), ScaleDecision::Hold, "at min_boxes");
        assert_eq!(decide(&p, 0.9, 2), ScaleDecision::Up);
        assert_eq!(decide(&p, 0.0, 3), ScaleDecision::Down);
    }
}
