//! Paper Fig. 4: the views produced by semantics-aware biased sampling.
//! Quantified as foreground-fraction and per-region sample counts for
//! w0 in {1, 2, 10} over many scenes (the paper shows one scene visually).

mod common;

use pointsplit::bench::Table;
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::pointops::fps::fg_fraction as fg_frac;
use pointsplit::pointops::{biased_fps, fps};

fn main() {
    let scenes = common::scene_budget(24);
    let m = 256;
    let mut rows: Vec<(f32, f32, f32)> = Vec::new(); // (w0, fg_frac, cloud_fg)
    for &w0 in &[1.0f32, 2.0, 10.0] {
        let mut acc = 0.0;
        let mut cloud = 0.0;
        for seed in 0..scenes as u64 {
            let s = generate_scene(40_000 + seed, &SYNRGBD);
            // GT-oracle foreground (the figure illustrates ideal painting)
            let fg: Vec<f32> =
                s.point_obj.iter().map(|&o| if o >= 0 { 1.0 } else { 0.0 }).collect();
            let idx = if w0 == 1.0 {
                fps(&s.points, m)
            } else {
                biased_fps(&s.points, m, &fg, w0)
            };
            acc += fg_frac(&idx, &fg);
            cloud += fg.iter().sum::<f32>() / fg.len() as f32;
        }
        rows.push((w0, acc / scenes as f32, cloud / scenes as f32));
    }
    let mut t = Table::new(&["w0", "sampled fg fraction", "cloud fg fraction", "bias gain"]);
    for (w0, frac, cloud) in rows {
        t.row(vec![
            format!("{w0}"),
            format!("{:.1}%", frac * 100.0),
            format!("{:.1}%", cloud * 100.0),
            format!("{:.2}x", frac / cloud),
        ]);
    }
    t.print(&format!(
        "Fig. 4 — biased FPS foreground share vs w0 ({scenes} scenes, 256 samples each)"
    ));
    println!("\npaper: w0=1 samples fg/bg evenly; w0=10 draws nearly all samples from painted regions.");
}
