//! Cluster-wide metrics: the aggregate report, per-box rows, and the
//! membership/fault event log — printable for the CLI and serializable to
//! `BENCH_cluster.json`-style payloads via [`ClusterReport::to_json`].

use crate::util::json::Json;
use crate::util::stats::Stats;

/// Per-box slice of a cluster run.
#[derive(Debug, Clone)]
pub struct BoxReport {
    pub id: usize,
    pub type_name: String,
    /// Admission-weighted capacity of this box's plan.
    pub capacity_rps: f64,
    /// Still in the fleet when the run ended.
    pub alive: bool,
    /// Seconds of the run this box was provisioned.
    pub alive_s: f64,
    /// Requests the router sent here (including re-routes).
    pub routed: usize,
    pub completed: usize,
    pub on_time: usize,
    pub rejected_full: usize,
    pub expired: usize,
    pub shed_slo: usize,
    pub degraded: usize,
    pub batches: usize,
    pub mean_batch: f64,
    pub util_gpu: f64,
    pub util_npu: f64,
    pub util_cpu: f64,
    /// Streaming frames served from cached state / all streaming frames on
    /// this box (0 for sessionless traffic).
    pub stream_reuse_rate: f64,
    /// Sessions evicted from this box's bounded session cache.
    pub session_evictions: usize,
}

/// One membership or fault event on the cluster timeline.
#[derive(Debug, Clone)]
pub struct ClusterEvent {
    pub at_ms: f64,
    pub what: String,
}

/// Aggregated result of one cluster scenario.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub scenario: String,
    pub pattern: &'static str,
    pub policy: &'static str,
    pub router: &'static str,
    pub offered_rps: f64,
    /// Sum of the initial fleet's per-box capacities.
    pub capacity_rps: f64,
    pub duration_s: f64,
    pub makespan_s: f64,
    pub arrivals: usize,
    pub completed: usize,
    pub on_time: usize,
    pub rejected_full: usize,
    pub expired: usize,
    pub shed_slo: usize,
    pub degraded: usize,
    /// Requests drained from a dying box and re-offered elsewhere.
    pub rerouted: usize,
    pub batches: usize,
    pub mean_batch: f64,
    pub latency_ms: Stats,
    pub queue_wait_ms: Stats,
    /// On-time completions / arrivals.
    pub slo_attainment: f64,
    pub goodput_rps: f64,
    /// max/mean of per-box routed-per-alive-second (1.0 = perfectly even).
    pub routing_imbalance: f64,
    /// Streaming frames served at each temporal class across the fleet
    /// (all zero for sessionless traffic).
    pub stream_full: usize,
    pub stream_partial: usize,
    pub stream_reuse: usize,
    /// Sessions evicted from the per-box bounded session caches.
    pub session_evictions: usize,
    /// Batches served on the stale-tracks SLO rung.
    pub stale_batches: usize,
    /// Sessions the router re-bound after their box left the fleet.
    pub session_rebinds: usize,
    /// Σ box cost-units × alive seconds — the run's provisioning bill.
    pub cost_units: f64,
    pub boxes: Vec<BoxReport>,
    pub events: Vec<ClusterEvent>,
}

impl ClusterReport {
    /// Human-readable block (mirrors `ServeTrafficReport::print`).
    pub fn print(&self) {
        println!(
            "=== {} [{} arrivals, pattern={}, policy={}, router={}] ===",
            self.scenario, self.arrivals, self.pattern, self.policy, self.router
        );
        println!(
            "offered {:.1} rps vs fleet capacity {:.1} rps ({:.0}% load), {:.1}s window, \
             {:.1}s makespan",
            self.offered_rps,
            self.capacity_rps,
            100.0 * self.offered_rps / self.capacity_rps.max(1e-9),
            self.duration_s,
            self.makespan_s
        );
        println!(
            "completed {} ({} on time)  rejected {}  expired {}  shed {}  degraded {}  \
             rerouted {}",
            self.completed,
            self.on_time,
            self.rejected_full,
            self.expired,
            self.shed_slo,
            self.degraded,
            self.rerouted
        );
        println!(
            "latency: p50 {:.0} ms  p95 {:.0}  p99 {:.0}  (queue wait p95 {:.0} ms)",
            self.latency_ms.p50, self.latency_ms.p95, self.latency_ms.p99, self.queue_wait_ms.p95
        );
        println!(
            "SLO attainment {:.1}%  goodput {:.1} rps  mean batch {:.2} over {} batches  \
             imbalance {:.2}  bill {:.0} unit-s",
            100.0 * self.slo_attainment,
            self.goodput_rps,
            self.mean_batch,
            self.batches,
            self.routing_imbalance,
            self.cost_units
        );
        let frames = self.stream_full + self.stream_partial + self.stream_reuse;
        if frames > 0 {
            println!(
                "stream frames: full {}  partial {}  reuse {}  (reuse rate {:.0}%)  \
                 evictions {}  stale batches {}  rebinds {}",
                self.stream_full,
                self.stream_partial,
                self.stream_reuse,
                100.0 * (self.stream_partial + self.stream_reuse) as f64 / frames as f64,
                self.session_evictions,
                self.stale_batches,
                self.session_rebinds
            );
        }
        for b in &self.boxes {
            println!(
                "  box {:>2} {:<12} {}  alive {:>6.1}s  routed {:>6}  done {:>6}  \
                 batch {:.2}  util GPU {:>3.0}% NPU {:>3.0}% CPU {:>3.0}%",
                b.id,
                b.type_name,
                if b.alive { "up  " } else { "down" },
                b.alive_s,
                b.routed,
                b.completed,
                b.mean_batch,
                100.0 * b.util_gpu,
                100.0 * b.util_npu,
                100.0 * b.util_cpu
            );
        }
        for e in &self.events {
            println!("  t={:>7.1}s  {}", e.at_ms / 1000.0, e.what);
        }
    }

    /// Machine-readable payload (the `BENCH_cluster.json` row format).
    pub fn to_json(&self) -> Json {
        let boxes: Vec<Json> = self
            .boxes
            .iter()
            .map(|b| {
                Json::obj(vec![
                    ("id", Json::Num(b.id as f64)),
                    ("type", Json::Str(b.type_name.clone())),
                    ("capacity_rps", Json::Num(b.capacity_rps)),
                    ("alive", Json::Bool(b.alive)),
                    ("alive_s", Json::Num(b.alive_s)),
                    ("routed", Json::Num(b.routed as f64)),
                    ("completed", Json::Num(b.completed as f64)),
                    ("on_time", Json::Num(b.on_time as f64)),
                    ("rejected_full", Json::Num(b.rejected_full as f64)),
                    ("expired", Json::Num(b.expired as f64)),
                    ("shed_slo", Json::Num(b.shed_slo as f64)),
                    ("degraded", Json::Num(b.degraded as f64)),
                    ("batches", Json::Num(b.batches as f64)),
                    ("mean_batch", Json::Num(b.mean_batch)),
                    ("util_gpu", Json::Num(b.util_gpu)),
                    ("util_npu", Json::Num(b.util_npu)),
                    ("util_cpu", Json::Num(b.util_cpu)),
                    ("stream_reuse_rate", Json::Num(b.stream_reuse_rate)),
                    ("session_evictions", Json::Num(b.session_evictions as f64)),
                ])
            })
            .collect();
        let events: Vec<Json> = self
            .events
            .iter()
            .map(|e| {
                Json::obj(vec![
                    ("at_s", Json::Num(e.at_ms / 1000.0)),
                    ("what", Json::Str(e.what.clone())),
                ])
            })
            .collect();
        Json::obj(vec![
            ("scenario", Json::Str(self.scenario.clone())),
            ("pattern", Json::Str(self.pattern.to_string())),
            ("policy", Json::Str(self.policy.to_string())),
            ("router", Json::Str(self.router.to_string())),
            ("offered_rps", Json::Num(self.offered_rps)),
            ("capacity_rps", Json::Num(self.capacity_rps)),
            ("duration_s", Json::Num(self.duration_s)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("arrivals", Json::Num(self.arrivals as f64)),
            ("completed", Json::Num(self.completed as f64)),
            ("on_time", Json::Num(self.on_time as f64)),
            ("rejected_full", Json::Num(self.rejected_full as f64)),
            ("expired", Json::Num(self.expired as f64)),
            ("shed_slo", Json::Num(self.shed_slo as f64)),
            ("degraded", Json::Num(self.degraded as f64)),
            ("rerouted", Json::Num(self.rerouted as f64)),
            ("batches", Json::Num(self.batches as f64)),
            ("mean_batch", Json::Num(self.mean_batch)),
            ("latency_p50_ms", Json::Num(self.latency_ms.p50)),
            ("latency_p95_ms", Json::Num(self.latency_ms.p95)),
            ("latency_p99_ms", Json::Num(self.latency_ms.p99)),
            ("queue_wait_p95_ms", Json::Num(self.queue_wait_ms.p95)),
            ("slo_attainment", Json::Num(self.slo_attainment)),
            ("goodput_rps", Json::Num(self.goodput_rps)),
            ("routing_imbalance", Json::Num(self.routing_imbalance)),
            ("stream_full", Json::Num(self.stream_full as f64)),
            ("stream_partial", Json::Num(self.stream_partial as f64)),
            ("stream_reuse", Json::Num(self.stream_reuse as f64)),
            ("session_evictions", Json::Num(self.session_evictions as f64)),
            ("stale_batches", Json::Num(self.stale_batches as f64)),
            ("session_rebinds", Json::Num(self.session_rebinds as f64)),
            ("cost_units", Json::Num(self.cost_units)),
            ("boxes", Json::Arr(boxes)),
            ("events", Json::Arr(events)),
        ])
    }
}
