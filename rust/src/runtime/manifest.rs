//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single contract between the build-time Python stack
//! and the Rust request path: artifact shapes + workload descriptors for the
//! device simulator, plus every model constant the coordinator needs
//! (SA configs, head layout, role groups, dataset parameters).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::quant::{Granularity, QuantSpec, StagePrecision};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub dataset: String,
    pub model: String,
    pub net: String,
    pub precision: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub flops: u64,
    pub bytes_in: u64,
    /// bytes per element on the interconnect (1 for int8 executables)
    pub wire_bytes_per_elem: u64,
    /// declared output element count (head/backbone widths differ wildly;
    /// wire/memory accounting must not use a magic constant). Older
    /// manifests without the field fall back to the historical 4096.
    pub out_elems: u64,
}

#[derive(Debug, Clone)]
pub struct SaConfig {
    pub m: usize,
    pub radius: f32,
    pub k: usize,
    pub mlp: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct DatasetMeta {
    pub num_points: usize,
    pub room_min: f64,
    pub room_max: f64,
    pub min_objects: usize,
    pub max_objects: usize,
    pub single_view: bool,
    pub depth_noise: f64,
    pub seg_noise: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct HeadLayout {
    pub center: (usize, usize),
    pub objectness: (usize, usize),
    pub heading_cls: (usize, usize),
    pub heading_reg: (usize, usize),
    pub size_cls: (usize, usize),
    pub size_reg: (usize, usize),
    pub sem_cls: (usize, usize),
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub classes: Vec<String>,
    pub mean_sizes: Vec<[f32; 3]>,
    pub num_heading_bin: usize,
    pub num_seg_classes: usize,
    pub img_size: usize,
    pub sa_configs: Vec<SaConfig>,
    pub num_seeds: usize,
    pub num_proposals: usize,
    pub proposal_radius: f32,
    pub proposal_k: usize,
    pub seed_feat: usize,
    pub fp_in: usize,
    pub feat_dim_painted: usize,
    pub feat_dim_plain: usize,
    pub head_layout: HeadLayout,
    pub role_groups_vote: Vec<Vec<usize>>,
    pub role_groups_prop: Vec<Vec<usize>>,
    pub quant_param_count: HashMap<String, usize>,
    /// (params, madds) for orig / pointsplit FP stage at mini & paper scale
    pub fp_layer_cost_mini: ((u64, u64), (u64, u64)),
    pub fp_layer_cost_paper: ((u64, u64), (u64, u64)),
    pub datasets: HashMap<String, DatasetMeta>,
    pub default_w0: f32,
    pub default_bias_layers: usize,
    pub artifacts: Vec<ArtifactMeta>,
    by_name: HashMap<String, usize>,
}

fn pair(j: &Json) -> (usize, usize) {
    let v = j.usize_vec();
    (v[0], v[1])
}

fn cost_pair(j: &Json) -> ((u64, u64), (u64, u64)) {
    let o = j.req("orig").f64_vec();
    let p = j.req("pointsplit").f64_vec();
    ((o[0] as u64, o[1] as u64), (p[0] as u64, p[1] as u64))
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let classes = j
            .req("classes")
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        let mean_sizes = j
            .req("mean_sizes")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| {
                let v = s.f64_vec();
                [v[0] as f32, v[1] as f32, v[2] as f32]
            })
            .collect();
        let sa_configs = j
            .req("sa_configs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| SaConfig {
                m: s.req("m").as_usize().unwrap(),
                radius: s.req("radius").as_f64().unwrap() as f32,
                k: s.req("k").as_usize().unwrap(),
                mlp: s.req("mlp").usize_vec(),
            })
            .collect();
        let hl = j.req("head_layout");
        let head_layout = HeadLayout {
            center: pair(hl.req("center")),
            objectness: pair(hl.req("objectness")),
            heading_cls: pair(hl.req("heading_cls")),
            heading_reg: pair(hl.req("heading_reg")),
            size_cls: pair(hl.req("size_cls")),
            size_reg: pair(hl.req("size_reg")),
            sem_cls: pair(hl.req("sem_cls")),
        };
        let rg = j.req("role_groups");
        let groups = |key: &str| -> Vec<Vec<usize>> {
            rg.req(key).as_arr().unwrap().iter().map(|g| g.usize_vec()).collect()
        };
        let quant_param_count = j
            .req("quant_param_count")
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.as_usize().unwrap()))
            .collect();
        let datasets = j
            .req("datasets")
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                (
                    k.clone(),
                    DatasetMeta {
                        num_points: v.req("num_points").as_usize().unwrap(),
                        room_min: v.req("room_min").as_f64().unwrap(),
                        room_max: v.req("room_max").as_f64().unwrap(),
                        min_objects: v.req("min_objects").as_usize().unwrap(),
                        max_objects: v.req("max_objects").as_usize().unwrap(),
                        single_view: v.req("single_view").as_bool().unwrap(),
                        depth_noise: v.req("depth_noise").as_f64().unwrap(),
                        seg_noise: v.req("seg_noise").as_f64().unwrap(),
                    },
                )
            })
            .collect();
        let artifacts: Vec<ArtifactMeta> = j
            .req("artifacts")
            .as_arr()
            .unwrap()
            .iter()
            .map(|a| ArtifactMeta {
                name: a.req("name").as_str().unwrap().to_string(),
                file: a.req("file").as_str().unwrap().to_string(),
                dataset: a.req("dataset").as_str().unwrap().to_string(),
                model: a.req("model").as_str().unwrap().to_string(),
                net: a.req("net").as_str().unwrap().to_string(),
                precision: a.req("precision").as_str().unwrap().to_string(),
                input_shapes: a
                    .req("inputs")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|i| i.req("shape").usize_vec())
                    .collect(),
                flops: a.req("flops").as_f64().unwrap() as u64,
                bytes_in: a.req("bytes_in").as_f64().unwrap() as u64,
                wire_bytes_per_elem: a.req("wire_bytes_per_elem").as_f64().unwrap() as u64,
                out_elems: a
                    .get("out_elems")
                    .and_then(|v| v.as_f64())
                    .map(|v| v as u64)
                    .unwrap_or(4096),
            })
            .collect();
        let by_name = artifacts.iter().enumerate().map(|(i, a)| (a.name.clone(), i)).collect();
        let fpc = j.req("fp_layer_cost");
        Ok(Manifest {
            classes,
            mean_sizes,
            num_heading_bin: j.req("num_heading_bin").as_usize().unwrap(),
            num_seg_classes: j.req("num_seg_classes").as_usize().unwrap(),
            img_size: j.req("img_size").as_usize().unwrap(),
            sa_configs,
            num_seeds: j.req("num_seeds").as_usize().unwrap(),
            num_proposals: j.req("num_proposals").as_usize().unwrap(),
            proposal_radius: j.req("proposal_radius").as_f64().unwrap() as f32,
            proposal_k: j.req("proposal_k").as_usize().unwrap(),
            seed_feat: j.req("seed_feat").as_usize().unwrap(),
            fp_in: j.req("fp_in").as_usize().unwrap(),
            feat_dim_painted: j.req("feat_dim_painted").as_usize().unwrap(),
            feat_dim_plain: j.req("feat_dim_plain").as_usize().unwrap(),
            head_layout,
            role_groups_vote: groups("vote"),
            role_groups_prop: groups("prop"),
            quant_param_count,
            fp_layer_cost_mini: cost_pair(fpc.req("mini")),
            fp_layer_cost_paper: cost_pair(fpc.req("paper_scale")),
            datasets,
            default_w0: j.req("default_w0").as_f64().unwrap() as f32,
            default_bias_layers: j.req("default_bias_layers").as_usize().unwrap(),
            artifacts,
            by_name,
        })
    }

    /// Build a fully synthetic manifest mirroring the python/compile
    /// constants (common.py SA_CONFIGS, head layout, aot.py FLOP formulas).
    ///
    /// This is the contract the serving gateway's analytic planner runs on
    /// when `artifacts/manifest.json` has not been exported: every artifact
    /// name the coordinator can reference resolves, with the same workload
    /// descriptors `aot.py` would write. Functional execution still requires
    /// the real exported artifacts — the synthetic manifest only feeds the
    /// calibrated device simulator.
    pub fn synthetic() -> Manifest {
        // VoteNet-mini architecture (python/compile/common.py)
        let sa_m = [256usize, 128, 64, 32];
        let sa_r = [0.3f32, 0.6, 1.2, 2.4];
        let sa_k = [32usize, 16, 8, 8];
        let sa_mlp: [&[usize]; 4] = [&[32, 32, 64], &[64, 64, 128], &[96, 96, 128], &[128, 128, 128]];
        let num_class = crate::data::NUM_CLASS;
        let num_seg_classes = num_class + 1;
        let num_heading_bin = 12usize;
        let (num_seeds, num_proposals, proposal_k) = (128usize, 32usize, 8usize);
        let seed_feat = 128usize;
        let fp_in = sa_mlp[1][2] + sa_mlp[2][2] + sa_mlp[3][2]; // 384
        let feat_dim_painted = 1 + num_seg_classes;
        let feat_dim_plain = 1usize;
        let vote_ch = 3 + seed_feat; // 131
        let proposal_ch = 3 + 2 + 2 * num_heading_bin + num_class + 3 * num_class + num_class; // 79

        // head channel layout (common.py SLICE_*)
        let head_layout = HeadLayout {
            center: (0, 3),
            objectness: (3, 5),
            heading_cls: (5, 5 + num_heading_bin),
            heading_reg: (17, 17 + num_heading_bin),
            size_cls: (29, 29 + num_class),
            size_reg: (39, 39 + 3 * num_class),
            sem_cls: (69, 69 + num_class),
        };
        let role_groups_vote = vec![(0..3).collect::<Vec<_>>(), (3..vote_ch).collect()];
        let role_groups_prop = vec![
            (0..3).collect::<Vec<_>>(),
            (3..5).chain(5..17).chain(29..39).chain(69..79).collect(),
            (17..29).chain(39..69).collect::<Vec<_>>(),
        ];
        // quantize.quant_param_count: 3 params per channel group, heads only
        let quant_param_count: HashMap<String, usize> = [
            ("layer".to_string(), 3 * 2),
            ("group".to_string(), 3 * (2 + 3)),
            ("channel".to_string(), 3 * (vote_ch + proposal_ch)),
            ("role".to_string(), 3 * (2 + 3)),
        ]
        .into_iter()
        .collect();

        // model.fp_layer_cost at both scales
        let fp_cost = |fps: &[&[(usize, usize)]], ns: &[usize], ps: &[(usize, usize)], n_ps: usize| {
            let mut p_orig = 0u64;
            let mut m_orig = 0u64;
            for (layers, &n) in fps.iter().zip(ns) {
                for &(ci, co) in *layers {
                    p_orig += (ci * co + co) as u64;
                    m_orig += (ci * co * n) as u64;
                }
            }
            let p_ps: u64 = ps.iter().map(|&(ci, co)| (ci * co + co) as u64).sum();
            let m_ps: u64 = ps.iter().map(|&(ci, co)| (ci * co * n_ps) as u64).sum();
            ((p_orig, m_orig), (p_ps, m_ps))
        };
        let mini_fp: [&[(usize, usize)]; 2] =
            [&[(fp_in - sa_mlp[1][2], 128), (128, 128)], &[(128 + 128, 128), (128, 128)]];
        let fp_layer_cost_mini = fp_cost(&mini_fp, &[64, num_seeds], &[(fp_in, seed_feat)], num_seeds);
        let paper_fp: [&[(usize, usize)]; 2] = [&[(512, 256), (256, 256)], &[(512, 256), (256, 256)]];
        let fp_layer_cost_paper = fp_cost(&paper_fp, &[512, 1024], &[(512, 384)], 1024);

        let datasets: HashMap<String, DatasetMeta> = ["synrgbd", "synscan"]
            .iter()
            .map(|name| {
                let d = crate::data::dataset(name).expect("builtin dataset");
                (
                    name.to_string(),
                    DatasetMeta {
                        num_points: d.num_points,
                        room_min: d.room_min,
                        room_max: d.room_max,
                        min_objects: d.min_objects,
                        max_objects: d.max_objects,
                        single_view: d.single_view,
                        depth_noise: d.depth_noise,
                        seg_noise: d.seg_noise,
                    },
                )
            })
            .collect();

        // aot.py mlp_flops: n rows through a dense chain
        let mlp_flops = |n: usize, widths: &[usize]| -> u64 {
            widths.windows(2).map(|w| 2 * n as u64 * (w[0] * w[1]) as u64).sum()
        };
        // aot.py conv_flops: encoder-decoder segmenter at 64x64
        let seg_flops = {
            let c = [16u64, 32, 48, 64];
            let hw = (crate::data::IMG_SIZE * crate::data::IMG_SIZE) as u64;
            2 * hw * 9 * 3 * c[0]
                + 2 * (hw / 4) * 9 * c[0] * c[1]
                + 2 * (hw / 16) * 9 * c[1] * c[2]
                + 2 * (hw / 16) * 9 * c[2] * c[3]
                + 2 * (hw / 4) * 9 * c[3] * c[1]
                + 2 * hw * 9 * (c[1] + c[1]) * c[0]
                + 2 * hw * (c[0] + c[0]) * num_seg_classes as u64
        };

        let mut artifacts: Vec<ArtifactMeta> = Vec::new();
        let mut add = |name: String,
                       dataset: &str,
                       model: &str,
                       net: &str,
                       precision: &str,
                       shape: Vec<usize>,
                       flops: u64,
                       out_elems: u64| {
            let bytes_in = shape.iter().product::<usize>() as u64 * 4;
            artifacts.push(ArtifactMeta {
                file: format!("{name}.hlo.txt"),
                name,
                dataset: dataset.to_string(),
                model: model.to_string(),
                net: net.to_string(),
                precision: precision.to_string(),
                input_shapes: vec![shape],
                flops,
                bytes_in,
                wire_bytes_per_elem: if precision.contains("int8") { 1 } else { 4 },
                out_elems,
            });
        };

        let backbone_precs = ["fp32", "int8"];
        let head_precs = ["fp32", "int8_layer", "int8_group", "int8_channel", "int8_role"];
        for ds in ["synrgbd", "synscan"] {
            for prec in backbone_precs {
                add(
                    format!("{ds}_seg_{prec}"),
                    ds,
                    "seg",
                    "seg",
                    prec,
                    vec![crate::data::IMG_SIZE, crate::data::IMG_SIZE, 3],
                    seg_flops,
                    (crate::data::IMG_SIZE * crate::data::IMG_SIZE * num_seg_classes) as u64,
                );
            }
            for model in ["votenet", "painted", "pointsplit"] {
                let feat = if model == "votenet" { feat_dim_plain } else { feat_dim_painted };
                let cin_per_level = [feat, sa_mlp[0][2], sa_mlp[1][2], sa_mlp[2][2]];
                for prec in backbone_precs {
                    for l in 0..4 {
                        let cin = 3 + cin_per_level[l];
                        let mut widths = vec![cin];
                        widths.extend_from_slice(sa_mlp[l]);
                        for shape in ["full", "half"] {
                            if l == 3 && shape == "half" {
                                continue; // SA4 runs on the fused set only
                            }
                            let b = if shape == "half" { sa_m[l] / 2 } else { sa_m[l] };
                            let net = format!("sa{}_{shape}", l + 1);
                            add(
                                format!("{ds}_{model}_{net}_{prec}"),
                                ds,
                                model,
                                &net,
                                prec,
                                vec![b, sa_k[l], cin],
                                mlp_flops(b * sa_k[l], &widths),
                                (b * sa_mlp[l][2]) as u64,
                            );
                        }
                    }
                    add(
                        format!("{ds}_{model}_fp_fc_{prec}"),
                        ds,
                        model,
                        "fp_fc",
                        prec,
                        vec![num_seeds, fp_in],
                        mlp_flops(num_seeds, &[fp_in, seed_feat]),
                        (num_seeds * seed_feat) as u64,
                    );
                }
                for prec in head_precs {
                    add(
                        format!("{ds}_{model}_vote_{prec}"),
                        ds,
                        model,
                        "vote",
                        prec,
                        vec![num_seeds, seed_feat],
                        mlp_flops(num_seeds, &[seed_feat, 128, 128, vote_ch]),
                        (num_seeds * vote_ch) as u64,
                    );
                    add(
                        format!("{ds}_{model}_prop_{prec}"),
                        ds,
                        model,
                        "prop",
                        prec,
                        vec![num_proposals, proposal_k, 3 + seed_feat],
                        mlp_flops(num_proposals * proposal_k, &[3 + seed_feat, 128, 64])
                            + mlp_flops(num_proposals, &[64, 64, proposal_ch]),
                        (num_proposals * proposal_ch) as u64,
                    );
                }
            }
        }

        let by_name = artifacts.iter().enumerate().map(|(i, a)| (a.name.clone(), i)).collect();
        Manifest {
            classes: crate::data::CLASS_NAMES.iter().map(|c| c.to_string()).collect(),
            mean_sizes: vec![
                [1.85, 1.65, 0.50],
                [1.40, 0.85, 0.72],
                [1.85, 0.90, 0.75],
                [0.48, 0.48, 0.85],
                [0.40, 0.55, 0.75],
                [1.30, 0.70, 0.74],
                [1.00, 0.50, 0.95],
                [0.50, 0.50, 0.60],
                [0.80, 0.30, 1.75],
                [1.60, 0.80, 0.55],
            ],
            num_heading_bin,
            num_seg_classes,
            img_size: crate::data::IMG_SIZE,
            sa_configs: (0..4)
                .map(|l| SaConfig {
                    m: sa_m[l],
                    radius: sa_r[l],
                    k: sa_k[l],
                    mlp: sa_mlp[l].to_vec(),
                })
                .collect(),
            num_seeds,
            num_proposals,
            proposal_radius: 0.6,
            proposal_k,
            seed_feat,
            fp_in,
            feat_dim_painted,
            feat_dim_plain,
            head_layout,
            role_groups_vote,
            role_groups_prop,
            quant_param_count,
            fp_layer_cost_mini,
            fp_layer_cost_paper,
            datasets,
            default_w0: 2.0,
            default_bias_layers: 2,
            artifacts,
            by_name,
        }
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    /// Resolve an artifact by (dataset, model, net, precision).
    pub fn find(&self, dataset: &str, model: &str, net: &str, precision: &str) -> Option<&ArtifactMeta> {
        self.artifact(&format!("{dataset}_{model}_{net}_{precision}"))
    }

    pub fn num_class(&self) -> usize {
        self.classes.len()
    }

    /// Output channel count and declared role partition of a network role
    /// (`"vote"`, `"prop"`, `"seg"`, `"fp_fc"`, `"sa1_full"`, ...). The head
    /// partitions come from the manifest's role groups; other stages have no
    /// declared roles (a `Role` spec derives them from data at calibration).
    pub fn stage_channels(&self, net: &str) -> (usize, Vec<Vec<usize>>) {
        match net {
            "vote" => (3 + self.seed_feat, self.role_groups_vote.clone()),
            "prop" => (self.head_layout.sem_cls.1, self.role_groups_prop.clone()),
            "seg" => (self.num_seg_classes, Vec::new()),
            "fp_fc" => (self.seed_feat, Vec::new()),
            n if n.starts_with("sa") => {
                let level = n[2..3].parse::<usize>().unwrap_or(1);
                let cout = self
                    .sa_configs
                    .get(level.saturating_sub(1))
                    .and_then(|s| s.mlp.last().copied())
                    .unwrap_or(1);
                (cout, Vec::new())
            }
            _ => (1, Vec::new()),
        }
    }

    /// Per-stage quant spec the manifest declares for an artifact, with the
    /// stage executed at `precision` (the QuantScheme override point — the
    /// serving degrade path runs "int8" backbone artifacts at an even-group
    /// granularity the artifact name does not encode).
    pub fn stage_quant_for(&self, meta: &ArtifactMeta, precision: StagePrecision) -> QuantSpec {
        let (cout, roles) = self.stage_channels(&meta.net);
        // an even-group head follows its role count, matching
        // quantize.quant_param_count's group accounting
        let precision = match precision {
            StagePrecision::Int8(Granularity::Group(_)) if !roles.is_empty() => {
                StagePrecision::Int8(Granularity::Group(roles.len()))
            }
            p => p,
        };
        QuantSpec::new(precision, cout, roles)
    }

    /// Per-stage quant spec at the artifact's own precision label.
    pub fn stage_quant(&self, meta: &ArtifactMeta) -> QuantSpec {
        let precision = StagePrecision::parse(&meta.precision).unwrap_or(StagePrecision::Fp32);
        self.stage_quant_for(meta, precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_is_complete() {
        let m = Manifest::synthetic();
        assert_eq!(m.num_class(), 10);
        assert_eq!(m.num_seg_classes, 11);
        assert_eq!(m.sa_configs.len(), 4);
        assert_eq!(m.fp_in, 384);
        assert_eq!(m.head_layout.sem_cls, (69, 79));
        assert_eq!(m.mean_sizes.len(), 10);
        assert_eq!(m.quant_param_count["channel"], 3 * (131 + 79));
        // every artifact name the coordinator can form must resolve
        for ds in ["synrgbd", "synscan"] {
            for prec in ["fp32", "int8"] {
                assert!(m.artifact(&format!("{ds}_seg_{prec}")).is_some());
            }
            for model in ["votenet", "painted", "pointsplit"] {
                for prec in ["fp32", "int8"] {
                    for net in ["sa1_full", "sa1_half", "sa2_half", "sa3_full", "sa4_full", "fp_fc"]
                    {
                        assert!(
                            m.find(ds, model, net, prec).is_some(),
                            "missing {ds}_{model}_{net}_{prec}"
                        );
                    }
                }
                for prec in ["fp32", "int8_layer", "int8_group", "int8_channel", "int8_role"] {
                    assert!(m.find(ds, model, "vote", prec).is_some());
                    assert!(m.find(ds, model, "prop", prec).is_some());
                }
            }
        }
        // aot.py formulas: fp_fc = 2 * 128 * 384 * 128 flops
        let fp = m.artifact("synrgbd_pointsplit_fp_fc_int8").unwrap();
        assert_eq!(fp.flops, 2 * 128 * 384 * 128);
        assert_eq!(fp.wire_bytes_per_elem, 1);
        assert_eq!(fp.out_elems, 128 * 128);
        let seg = m.artifact("synrgbd_seg_fp32").unwrap();
        assert_eq!(seg.input_shapes[0], vec![64, 64, 3]);
        assert_eq!(seg.wire_bytes_per_elem, 4);
        assert_eq!(seg.out_elems, (64 * 64 * 11) as u64);
        // per-artifact output widths, not a shared constant
        let vote = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap();
        assert_eq!(vote.out_elems, (128 * 131) as u64);
        let sa1 = m.artifact("synrgbd_pointsplit_sa1_full_int8").unwrap();
        assert_eq!(sa1.out_elems, (256 * 64) as u64);
        // no duplicate names
        let mut names: Vec<&str> = m.artifacts.iter().map(|a| a.name.as_str()).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate artifact names");
    }

    #[test]
    fn stage_quant_declares_per_stage_specs() {
        use crate::quant::{Granularity, StagePrecision};
        let m = Manifest::synthetic();
        // role heads carry the declared partitions over the right widths
        let vote = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap();
        let sv = m.stage_quant(vote);
        assert_eq!(sv.precision, StagePrecision::Int8(Granularity::Role));
        assert_eq!(sv.cout, 131);
        assert_eq!(sv.roles, m.role_groups_vote);
        let covered: usize = sv.roles.iter().map(|g| g.len()).sum();
        assert_eq!(covered, sv.cout, "vote role partition must cover all channels");
        let prop = m.artifact("synrgbd_pointsplit_prop_int8_role").unwrap();
        let sp = m.stage_quant(prop);
        assert_eq!(sp.cout, 79);
        assert_eq!(sp.roles.iter().map(|g| g.len()).sum::<usize>(), 79);
        // group heads follow their role count (param-count parity)
        let pg = m.artifact("synrgbd_pointsplit_prop_int8_group").unwrap();
        assert_eq!(
            m.stage_quant(pg).precision,
            StagePrecision::Int8(Granularity::Group(3))
        );
        // backbone "int8" is layer-wise by default, overridable per call
        let sa = m.artifact("synrgbd_pointsplit_sa1_full_int8").unwrap();
        assert_eq!(m.stage_quant(sa).precision, StagePrecision::Int8(Granularity::Layer));
        assert_eq!(m.stage_quant(sa).cout, 64);
        let over = m.stage_quant_for(sa, StagePrecision::Int8(Granularity::Group(4)));
        assert_eq!(over.precision, StagePrecision::Int8(Granularity::Group(4)));
        // fp32 artifacts quantize nothing
        let fp = m.artifact("synrgbd_pointsplit_vote_fp32").unwrap();
        assert_eq!(m.stage_quant(fp).precision, StagePrecision::Fp32);
        assert_eq!(m.stage_quant(fp).param_count(), 0);
    }
}
