//! End-to-end validation driver (DESIGN.md / EXPERIMENTS.md).
//!
//! Serves a batch of synthetic RGB-D scenes through every detector variant
//! on its paper-relevant platform configuration and reports the headline
//! result: **PointSplit (INT8, GPU+NPU) vs PointPainting (FP32, GPU-only)
//! speedup at comparable mAP** — the paper's 11.4x (SUN RGB-D) / 24.7x
//! (ScanNet) claim, on this repo's calibrated simulator.
//!
//! ```bash
//! cargo run --release --example e2e_serve -- [scenes] [dataset]
//! ```

use pointsplit::bench::Table;
use pointsplit::coordinator::serve::serve;
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::data;
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;

fn main() -> anyhow::Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let scenes: usize = args.get(1).and_then(|s| s.parse().ok()).unwrap_or(32);
    let ds_name = args.get(2).cloned().unwrap_or_else(|| "synrgbd".to_string());
    let ds = data::dataset(&ds_name).expect("dataset: synrgbd|synscan");
    let workers: usize = std::thread::available_parallelism().map(|p| p.get().min(6)).unwrap_or(4);

    let rt = Runtime::open("artifacts")?;
    println!(
        "end-to-end: {scenes} {ds_name} scenes/variant, {workers} workers, platform {}",
        rt.platform()
    );

    let gpu_only = Schedule::SingleDevice(DeviceKind::Gpu);
    let split = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let seq = Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };

    let configs: Vec<(&str, DetectorConfig)> = vec![
        ("VoteNet fp32 / GPU", DetectorConfig::new(&ds_name, Variant::VoteNet, false, gpu_only)),
        (
            "PointPainting fp32 / GPU",
            DetectorConfig::new(&ds_name, Variant::PointPainting, false, gpu_only),
        ),
        (
            "PointPainting int8 / GPU>NPU",
            DetectorConfig::new(&ds_name, Variant::PointPainting, true, seq),
        ),
        (
            "PointSplit int8 / GPU+NPU",
            DetectorConfig::new(&ds_name, Variant::PointSplit, true, split),
        ),
    ];

    let mut table = Table::new(&[
        "configuration",
        "mAP@0.25",
        "mAP@0.5",
        "sim ms/scene",
        "peak MB",
        "host ms",
        "scenes/s",
    ]);
    let mut baseline_ms = None;
    let mut pointsplit_ms = None;
    let mut baseline_map = None;
    let mut pointsplit_map = None;
    for (name, cfg) in &configs {
        let rep = serve(&rt, cfg, ds, scenes, workers, 500_000)?;
        if name.starts_with("PointPainting fp32") {
            baseline_ms = Some(rep.sim_latency_ms.mean);
            baseline_map = Some(rep.map_25);
        }
        if name.starts_with("PointSplit") {
            pointsplit_ms = Some(rep.sim_latency_ms.mean);
            pointsplit_map = Some(rep.map_25);
        }
        table.row(vec![
            name.to_string(),
            format!("{:.1}", rep.map_25 * 100.0),
            format!("{:.1}", rep.map_50 * 100.0),
            format!("{:.0}", rep.sim_latency_ms.mean),
            format!("{:.0}", rep.peak_memory_mb),
            format!("{:.0}", rep.host_latency_ms.mean),
            format!("{:.1}", rep.scenes as f64 / rep.wall_s),
        ]);
    }
    table.print(&format!("end-to-end serving on {ds_name}"));

    if let (Some(b), Some(p), Some(bm), Some(pm)) =
        (baseline_ms, pointsplit_ms, baseline_map, pointsplit_map)
    {
        println!("\nHEADLINE: PointSplit(INT8, GPU+NPU) is {:.1}x faster than", b / p);
        println!(
            "PointPainting(FP32, GPU-only) at {:+.1} mAP@0.25 (paper: 11.4x on SUN RGB-D, 24.7x on ScanNet)",
            (pm - bm) * 100.0
        );
    }
    Ok(())
}
