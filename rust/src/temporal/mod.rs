//! Cross-frame reuse for streaming scenes (the temporal workload class).
//!
//! Consecutive frames of a video point-cloud stream overlap almost entirely;
//! recomputing 2D semantics, biased FPS, and the SA chain per frame wastes
//! most of the accelerator budget. This module holds the per-session state
//! that lets the pipeline skip that work:
//!
//! * [`FrameCache`] — the previous frame's cloud, painted semantics,
//!   biased-sampling index set, and seed features (everything the head of
//!   the detector needs to warm-start).
//! * a cheap **delta estimator**: a grid-occupancy histogram over the same
//!   cell keys as the PR 8 `GridStorage`; diffing the incoming frame's
//!   histogram against the cached anchor classifies the frame as
//!   [`FrameClass::Reuse`] / [`FrameClass::Partial`] / [`FrameClass::Full`]
//!   in one O(N) pass — far cheaper than the work it saves.
//!
//! The pipeline-side consumers live in `coordinator::pipeline`
//! (`run_stream`); the gateway keys one cache per client session in
//! `serving::dispatch`. Design notes: `docs/STREAMING.md`.

use std::collections::HashMap;

use crate::pointops::ballquery::ScalarGrid;
use crate::pointops::{soa_bytes, PointsSoA};
use crate::util::tensor::Tensor;

/// How much of the previous frame's work a new frame may inherit.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FrameClass {
    /// frame is near-identical: skip paint + biased FPS, warm-start the head
    Reuse,
    /// localized change: recompute painting only for dirty grid cells
    Partial,
    /// scene change (or no cache): run the full pipeline, bit-identically
    Full,
}

impl FrameClass {
    pub fn name(&self) -> &'static str {
        match self {
            FrameClass::Reuse => "reuse",
            FrameClass::Partial => "partial",
            FrameClass::Full => "full",
        }
    }
}

/// Delta-estimator thresholds.
#[derive(Debug, Clone, Copy)]
pub struct DeltaCfg {
    /// occupancy grid cell edge (meters) — matches the ball-query grid scale
    pub cell: f32,
    /// changed-mass fraction at or below which a frame is REUSE. The
    /// default (0.10) absorbs one default-speed mover (~3% changed mass
    /// per frame) for a few frames; because REUSE never re-anchors, the
    /// accumulated drift then tips the frame into PARTIAL and re-anchors.
    pub reuse_max: f64,
    /// changed-mass fraction at or below which a frame is PARTIAL
    pub partial_max: f64,
}

impl Default for DeltaCfg {
    fn default() -> Self {
        DeltaCfg { cell: 0.4, reuse_max: 0.10, partial_max: 0.45 }
    }
}

/// Verdict of the delta estimator for one incoming frame.
#[derive(Debug, Clone)]
pub struct FrameDelta {
    pub class: FrameClass,
    /// fraction of point mass whose grid cell occupancy changed, in [0, 1]
    pub changed_frac: f64,
    /// per-point dirty flag: point i sits in a cell whose occupancy changed
    pub dirty: Vec<bool>,
}

/// Everything the pipeline can inherit from the previous frame. Stored per
/// session; repopulated on every FULL / PARTIAL frame.
#[derive(Debug, Clone, Default)]
pub struct StreamArtifacts {
    /// 2D segmentation scores (H, W, C) — lets PARTIAL/REUSE skip the seg net
    pub scores: Option<Tensor>,
    /// painted per-point semantics (N, C)
    pub paint: Option<Tensor>,
    /// foreground mask used by biased sampling (N)
    pub fg: Vec<f32>,
    /// biased-sampling index set: seed point indices into the frame cloud,
    /// in SA-chain concat order. Within a shot point index identity holds, so
    /// re-gathering these indices from the *current* cloud applies the exact
    /// ego-motion / object-motion transform to the cached seed centers.
    pub seed_src: Vec<usize>,
    /// seed features entering the vote stage (num_seeds, 3 + C)
    pub seeds: Option<Tensor>,
    /// the frame's point cloud in SoA layout
    pub points: PointsSoA,
}

impl StreamArtifacts {
    /// Actual heap footprint of the cached artifacts (bytes).
    pub fn bytes(&self) -> u64 {
        let t = |t: &Option<Tensor>| t.as_ref().map_or(0, |t| t.size_bytes() as u64);
        t(&self.scores)
            + t(&self.paint)
            + t(&self.seeds)
            + (self.fg.len() * 4) as u64
            + (self.seed_src.len() * 8) as u64
            + soa_bytes(self.points.len())
    }
}

/// Reuse counters for one session (exported into serving stats).
#[derive(Debug, Clone, Copy, Default)]
pub struct CacheStats {
    pub full: u64,
    pub partial: u64,
    pub reuse: u64,
}

impl CacheStats {
    pub fn frames(&self) -> u64 {
        self.full + self.partial + self.reuse
    }

    pub fn record(&mut self, class: FrameClass) {
        match class {
            FrameClass::Full => self.full += 1,
            FrameClass::Partial => self.partial += 1,
            FrameClass::Reuse => self.reuse += 1,
        }
    }
}

/// Canonical declared memory of one streaming session cache. The gateway
/// sizes its session map with this and the verifier's S006 rule checks the
/// declared total against the configured bound — keep in sync with
/// [`StreamArtifacts::bytes`].
pub fn session_footprint_bytes(
    num_points: usize,
    num_seeds: usize,
    seed_feat: usize,
    num_classes: usize,
    img_size: usize,
) -> u64 {
    let scores = (img_size * img_size * num_classes * 4) as u64;
    let paint = (num_points * num_classes * 4) as u64;
    let fg = (num_points * 4) as u64;
    let seed_src = (num_seeds * 8) as u64;
    let seeds = (num_seeds * (3 + seed_feat) * 4) as u64;
    // occupancy histogram: key (12 B) + count (4 B) + map overhead, one
    // entry per occupied cell, bounded by one cell per point
    let occ = (num_points * 24) as u64;
    scores + paint + fg + seed_src + seeds + occ + soa_bytes(num_points)
}

/// Per-session temporal cache: occupancy anchor + reusable artifacts.
#[derive(Debug, Clone)]
pub struct FrameCache {
    cfg: DeltaCfg,
    /// grid-occupancy histogram of the last *installed* frame
    occ: HashMap<(i32, i32, i32), u32>,
    n_anchor: usize,
    arts: Option<StreamArtifacts>,
    bound_bytes: u64,
    stats: CacheStats,
}

impl FrameCache {
    pub fn new(cfg: DeltaCfg, bound_bytes: u64) -> Self {
        FrameCache {
            cfg,
            occ: HashMap::new(),
            n_anchor: 0,
            arts: None,
            bound_bytes,
            stats: CacheStats::default(),
        }
    }

    pub fn cfg(&self) -> &DeltaCfg {
        &self.cfg
    }

    /// Raise the REUSE threshold (the SLO "stale tracks" rung): more frames
    /// ride the cheap tail path at the cost of staler semantics.
    pub fn set_reuse_max(&mut self, reuse_max: f64) {
        self.cfg.reuse_max = reuse_max;
    }

    pub fn stats(&self) -> &CacheStats {
        &self.stats
    }

    pub fn record(&mut self, class: FrameClass) {
        self.stats.record(class);
    }

    pub fn bound_bytes(&self) -> u64 {
        self.bound_bytes
    }

    /// Current heap use: artifacts + occupancy anchor.
    pub fn footprint_bytes(&self) -> u64 {
        self.arts.as_ref().map_or(0, |a| a.bytes()) + (self.occ.len() * 24) as u64
    }

    pub fn artifacts(&self) -> Option<&StreamArtifacts> {
        self.arts.as_ref()
    }

    pub fn take_artifacts(&mut self) -> Option<StreamArtifacts> {
        self.arts.take()
    }

    fn histogram(&self, points: &[[f32; 3]]) -> HashMap<(i32, i32, i32), u32> {
        let mut h = HashMap::with_capacity(points.len() / 4 + 1);
        for p in points {
            *h.entry(ScalarGrid::key(p, self.cfg.cell)).or_insert(0) += 1;
        }
        h
    }

    /// Classify an incoming frame against the anchor. O(N); does not mutate
    /// the cache. With no anchor (cold session) every frame is FULL.
    pub fn classify(&self, points: &[[f32; 3]]) -> FrameDelta {
        if self.n_anchor == 0 || self.arts.is_none() || points.len() != self.n_anchor {
            return FrameDelta {
                class: FrameClass::Full,
                changed_frac: 1.0,
                dirty: vec![true; points.len()],
            };
        }
        let now = self.histogram(points);
        // changed mass = sum over the union of cells of |count delta|
        let mut diff: u64 = 0;
        for (k, &c) in now.iter() {
            let prev = self.occ.get(k).copied().unwrap_or(0);
            diff += c.abs_diff(prev) as u64;
        }
        for (k, &c) in self.occ.iter() {
            if !now.contains_key(k) {
                diff += c as u64;
            }
        }
        let changed_frac = (diff as f64 / points.len() as f64).min(1.0);
        let class = if changed_frac <= self.cfg.reuse_max {
            FrameClass::Reuse
        } else if changed_frac <= self.cfg.partial_max {
            FrameClass::Partial
        } else {
            FrameClass::Full
        };
        let dirty = points
            .iter()
            .map(|p| {
                let k = ScalarGrid::key(p, self.cfg.cell);
                now.get(&k).copied().unwrap_or(0) != self.occ.get(&k).copied().unwrap_or(0)
            })
            .collect();
        FrameDelta { class, changed_frac, dirty }
    }

    /// Install a freshly computed frame as the new anchor. Called after every
    /// FULL or PARTIAL frame; REUSE frames deliberately do *not* re-anchor,
    /// so slow drift accumulates against the last real compute and
    /// eventually tips the estimator into PARTIAL.
    pub fn install(&mut self, points: &[[f32; 3]], arts: StreamArtifacts) {
        self.occ = self.histogram(points);
        self.n_anchor = points.len();
        self.arts = Some(arts);
    }

    /// Drop all cached state (e.g. on session eviction + readmission).
    pub fn reset(&mut self) {
        self.occ.clear();
        self.n_anchor = 0;
        self.arts = None;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cloud(n: usize, off: f32) -> Vec<[f32; 3]> {
        (0..n)
            .map(|i| {
                let f = i as f32 / n as f32;
                [f * 4.0 + off, (f * 31.0) % 3.0, (f * 17.0) % 2.0]
            })
            .collect()
    }

    fn arts(n: usize) -> StreamArtifacts {
        StreamArtifacts {
            fg: vec![0.5; n],
            seed_src: (0..n / 4).collect(),
            points: PointsSoA::from_points(&cloud(n, 0.0)),
            ..Default::default()
        }
    }

    #[test]
    fn cold_cache_is_full() {
        let cache = FrameCache::new(DeltaCfg::default(), 1 << 20);
        let d = cache.classify(&cloud(256, 0.0));
        assert_eq!(d.class, FrameClass::Full);
        assert!(d.dirty.iter().all(|&b| b));
    }

    #[test]
    fn identical_frame_is_reuse_and_clean() {
        let pts = cloud(512, 0.0);
        let mut cache = FrameCache::new(DeltaCfg::default(), 1 << 20);
        cache.install(&pts, arts(512));
        let d = cache.classify(&pts);
        assert_eq!(d.class, FrameClass::Reuse);
        assert_eq!(d.changed_frac, 0.0);
        assert!(d.dirty.iter().all(|&b| !b));
    }

    #[test]
    fn local_motion_is_partial_and_marks_dirty_cells() {
        let pts = cloud(512, 0.0);
        let mut cache = FrameCache::new(DeltaCfg::default(), 1 << 20);
        cache.install(&pts, arts(512));
        // move 20% of the points a full cell over
        let mut moved = pts.clone();
        for p in moved.iter_mut().take(102) {
            p[0] += 0.8;
        }
        let d = cache.classify(&moved);
        assert_eq!(d.class, FrameClass::Partial, "changed_frac {}", d.changed_frac);
        assert!(d.dirty[0], "moved point must be dirty");
        assert!(d.dirty.iter().filter(|&&b| b).count() < 512, "some points stay clean");
    }

    #[test]
    fn global_change_is_full() {
        let pts = cloud(512, 0.0);
        let mut cache = FrameCache::new(DeltaCfg::default(), 1 << 20);
        cache.install(&pts, arts(512));
        let d = cache.classify(&cloud(512, 10.0));
        assert_eq!(d.class, FrameClass::Full);
        assert!(d.changed_frac > 0.9);
    }

    #[test]
    fn point_count_change_forces_full() {
        let pts = cloud(512, 0.0);
        let mut cache = FrameCache::new(DeltaCfg::default(), 1 << 20);
        cache.install(&pts, arts(512));
        assert_eq!(cache.classify(&cloud(500, 0.0)).class, FrameClass::Full);
    }

    #[test]
    fn footprint_tracks_artifacts_and_reset_clears() {
        let pts = cloud(512, 0.0);
        let mut cache = FrameCache::new(DeltaCfg::default(), 1 << 20);
        assert_eq!(cache.footprint_bytes(), 0);
        cache.install(&pts, arts(512));
        assert!(cache.footprint_bytes() > soa_bytes(512));
        cache.reset();
        assert_eq!(cache.footprint_bytes(), 0);
        assert_eq!(cache.classify(&pts).class, FrameClass::Full);
    }

    #[test]
    fn session_footprint_formula_covers_real_artifacts() {
        let n = 512;
        let mut a = arts(n);
        a.scores = Some(Tensor::zeros(vec![64, 64, 11]));
        a.paint = Some(Tensor::zeros(vec![n, 11]));
        a.seeds = Some(Tensor::zeros(vec![n / 4, 3 + 128]));
        let declared = session_footprint_bytes(n, n / 4, 128, 11, 64);
        assert!(declared >= a.bytes(), "declared {declared} < actual {}", a.bytes());
    }

    #[test]
    fn stats_record_counts() {
        let mut s = CacheStats::default();
        s.record(FrameClass::Full);
        s.record(FrameClass::Reuse);
        s.record(FrameClass::Reuse);
        assert_eq!((s.full, s.partial, s.reuse, s.frames()), (1, 0, 2, 3));
    }
}
