//! Class-agnostic 3D non-maximum suppression over decoded proposals.

use crate::data::Box3;
use crate::eval::iou::iou3d;

/// Greedy NMS: keep highest-score boxes, drop overlaps above `iou_thresh`.
/// Returns indices into `boxes` in descending score order.
pub fn nms3d(boxes: &[Box3], iou_thresh: f64) -> Vec<usize> {
    let mut order: Vec<usize> = (0..boxes.len()).collect();
    order.sort_by(|&a, &b| boxes[b].score.partial_cmp(&boxes[a].score).unwrap());
    let mut keep = Vec::new();
    let mut suppressed = vec![false; boxes.len()];
    for &i in &order {
        if suppressed[i] {
            continue;
        }
        keep.push(i);
        for &j in &order {
            if !suppressed[j] && j != i && iou3d(&boxes[i], &boxes[j]) > iou_thresh {
                suppressed[j] = true;
            }
        }
    }
    keep
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(c: [f32; 3], score: f32) -> Box3 {
        Box3 { center: c, size: [1.0, 1.0, 1.0], heading: 0.0, class: 0, score }
    }

    #[test]
    fn suppresses_duplicates_keeps_best() {
        let boxes = vec![mk([0.0, 0.0, 0.0], 0.5), mk([0.05, 0.0, 0.0], 0.9), mk([5.0, 0.0, 0.0], 0.3)];
        let keep = nms3d(&boxes, 0.25);
        assert_eq!(keep, vec![1, 2]);
    }

    #[test]
    fn no_overlap_keeps_all() {
        let boxes: Vec<Box3> = (0..5).map(|i| mk([3.0 * i as f32, 0.0, 0.0], 0.1 * i as f32)).collect();
        let keep = nms3d(&boxes, 0.25);
        assert_eq!(keep.len(), 5);
        // descending score
        for w in keep.windows(2) {
            assert!(boxes[w[0]].score >= boxes[w[1]].score);
        }
    }

    #[test]
    fn empty_input() {
        assert!(nms3d(&[], 0.5).is_empty());
    }
}
