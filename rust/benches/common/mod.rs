//! Shared helpers for the paper-table bench binaries.

// each bench target compiles this module and uses a different subset
#![allow(dead_code)]

use pointsplit::coordinator::serve::{serve, ServeReport};
use pointsplit::coordinator::DetectorConfig;
use pointsplit::data;
use pointsplit::runtime::Runtime;

/// Scene budget per configuration (override: POINTSPLIT_BENCH_SCENES).
pub fn scene_budget(default: usize) -> usize {
    std::env::var("POINTSPLIT_BENCH_SCENES")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(default)
}

pub fn workers() -> usize {
    std::thread::available_parallelism().map(|p| p.get().min(6)).unwrap_or(4)
}

/// Evaluate one detector configuration over the shared validation seed range.
pub fn eval_config(rt: &Runtime, cfg: &DetectorConfig, scenes: usize) -> ServeReport {
    let ds = data::dataset(&cfg.dataset).expect("dataset");
    serve(rt, cfg, ds, scenes, workers(), 500_000).expect("serve")
}

pub fn open_runtime() -> Runtime {
    if std::path::Path::new("artifacts/manifest.json").exists() {
        Runtime::open("artifacts").expect("artifacts present but unreadable")
    } else {
        eprintln!("note: no artifacts — benching on the synthetic manifest + host surrogate");
        Runtime::synthetic()
    }
}

/// Format an Option<f64> AP as the paper does (x100, '-' when absent).
pub fn ap_cell(ap: Option<f64>) -> String {
    match ap {
        Some(v) => format!("{:.1}", v * 100.0),
        None => "-".to_string(),
    }
}
