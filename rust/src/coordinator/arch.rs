//! Architecture accounting: per-stage workload descriptors for the device
//! simulator, model parameter counts (Fig. 9 memory model), and the Table 1
//! FP-layer comparison.

use crate::pointops::{ball_query_flops, fps_flops};
use crate::runtime::{ArtifactMeta, Manifest};
use crate::sim::{Workload, WorkloadKind};

/// Point-manipulation workload of one SA layer: FPS + ball query + gather.
pub fn sa_pointmanip_workload(n_in: usize, m_out: usize, k: usize, c_in: usize) -> Workload {
    Workload {
        kind: WorkloadKind::PointOp,
        flops: fps_flops(n_in, m_out) + ball_query_flops(n_in, m_out),
        mem_bytes: (m_out * k * (3 + c_in) * 4) as u64,
        // grouped tensor that must reach the NN device
        wire_bytes: (m_out * k * (3 + c_in)) as u64 * 4,
    }
}

/// NN workload straight from artifact metadata. Memory traffic covers the
/// activations the stage streams (one byte per element on int8, four on
/// fp32) *plus* the packed weights its dense layer touches — the resident
/// footprint the GEMM layer actually holds per `(cin, cout, precision)`
/// ([`crate::runtime::gemm::packed_weight_bytes`]), which verifier rule
/// S007 checks declared graphs against. Wire traffic stays activations
/// only: weights are cached on-device after the first execution, never
/// re-shipped per scene. Output traffic uses the artifact's declared
/// `out_elems` (per-artifact head widths, not a magic constant).
///
/// Artifact *lookup* (and its missing-artifact `Result`) lives with the
/// only consumer, `graph::StageGraph::build` — a malformed manifest is a
/// recoverable build error there, never a worker-killing panic.
pub fn nn_workload_of(manifest: &Manifest, meta: &ArtifactMeta) -> Workload {
    let per_elem = meta.wire_bytes_per_elem;
    // a net role the surrogate cannot shape (unknown in a hand-built
    // manifest) contributes no weight term rather than failing the build
    let weight_bytes = crate::runtime::surrogate::layer_dims(manifest, meta)
        .map(|(_, cin, cout)| crate::runtime::gemm::packed_weight_bytes(cin, cout, per_elem == 1))
        .unwrap_or(0);
    Workload {
        kind: WorkloadKind::NeuralNet,
        flops: meta.flops,
        mem_bytes: (meta.bytes_in / 4) * per_elem + weight_bytes,
        wire_bytes: (meta.bytes_in / 4 + meta.out_elems) * per_elem,
    }
}

/// Small fixed-cost point op (painting, FP interpolation, decode).
pub fn small_pointop(flops: u64, wire_bytes: u64) -> Workload {
    Workload { kind: WorkloadKind::PointOp, flops, mem_bytes: wire_bytes, wire_bytes }
}

/// Total trainable parameters of the detector (from manifest widths).
pub fn detector_params(manifest: &Manifest, painted: bool) -> u64 {
    let feat = if painted { manifest.feat_dim_painted } else { manifest.feat_dim_plain };
    let mut total = 0u64;
    let mut prev = feat;
    for sa in &manifest.sa_configs {
        let mut cin = 3 + prev;
        for &cout in &sa.mlp {
            total += (cin * cout + cout) as u64;
            cin = cout;
        }
        prev = *sa.mlp.last().unwrap();
    }
    // fp_fc + vote mlp/out + proposal pointnet/mlp/out (fixed widths)
    let sf = manifest.seed_feat;
    total += (manifest.fp_in * sf + sf) as u64;
    total += (sf * 128 + 128 + 128 * 128 + 128) as u64;
    total += (128 * (3 + sf) + (3 + sf)) as u64; // vote_out (131 ch)
    total += ((3 + sf) * 128 + 128 + 128 * 64 + 64) as u64; // prop pointnet
    total += (64 * 64 + 64) as u64;
    let ch = manifest.head_layout.sem_cls.1;
    total += (64 * ch + ch) as u64; // prop_out
    total
}

/// Segmenter parameter count (encoder-decoder stand-in).
pub fn segmenter_params(manifest: &Manifest) -> u64 {
    let c = [16u64, 32, 48, 64];
    let nseg = manifest.num_seg_classes as u64;
    9 * 3 * c[0]
        + 9 * c[0] * c[1]
        + 9 * c[1] * c[2]
        + 9 * c[2] * c[3]
        + 9 * c[3] * c[1]
        + 9 * (c[1] + c[1]) * c[0]
        + (c[0] + c[0]) * nseg
        + c.iter().sum::<u64>()
        + nseg
}

/// Fig. 9 peak-memory model (MB): framework base + weights + activations.
///
/// The paper's numbers separate TensorFlow (GPU fp32, ~2.2 GB) from
/// TensorFlow Lite (quantized, ~100s MB); we use the same two-regime model
/// with the measured bases from Fig. 9 and our (much smaller) weights.
pub fn peak_memory_mb(
    manifest: &Manifest,
    painted: bool,
    fp32_framework: bool,
    num_points: usize,
) -> f64 {
    let weight_bytes = (detector_params(manifest, painted)
        + if painted { segmenter_params(manifest) } else { 0 }) as f64
        * if fp32_framework { 4.0 } else { 1.0 };
    let act_bytes = (num_points * 16 * 4) as f64; // cloud + painted feats + groups
    let base_mb = if fp32_framework { 1900.0 } else { 95.0 };
    base_mb + (weight_bytes + act_bytes) / 1e6
}

/// Table 1: (params, MAdd) of the FP stage — PointNet++'s two PointNets vs
/// PointSplit's single shared FC, at mini and paper scale (from manifest).
pub struct FpLayerCost {
    pub orig_params: u64,
    pub orig_madds: u64,
    pub ps_params: u64,
    pub ps_madds: u64,
}

pub fn fp_layer_cost(manifest: &Manifest, paper_scale: bool) -> FpLayerCost {
    let ((op, om), (pp, pm)) =
        if paper_scale { manifest.fp_layer_cost_paper } else { manifest.fp_layer_cost_mini };
    FpLayerCost { orig_params: op, orig_madds: om, ps_params: pp, ps_madds: pm }
}
