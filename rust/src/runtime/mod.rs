//! PJRT runtime: loads AOT-compiled HLO-text artifacts and executes them.
//!
//! This is the only place the `xla` crate is touched. The interchange format
//! is HLO **text** (see python/compile/export_utils.py and DESIGN.md): jax
//! ≥ 0.5 serializes protos with 64-bit instruction ids that xla_extension
//! 0.5.1 rejects; the text parser reassigns ids and round-trips cleanly.
//!
//! The [`Runtime`] owns one PJRT CPU client plus a lazily-compiled executable
//! cache keyed by artifact name; [`Manifest`] mirrors
//! `artifacts/manifest.json` (shapes, workload descriptors, model constants).

pub mod manifest;

pub use manifest::{ArtifactMeta, Manifest};

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::Mutex;

use anyhow::{anyhow, Context, Result};

use crate::util::tensor::Tensor;

/// PJRT-backed executor for the AOT artifacts.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, std::sync::Arc<xla::PjRtLoadedExecutable>>>,
}

impl Runtime {
    /// Open `artifacts/` (must contain manifest.json) on the PJRT CPU client.
    pub fn open(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest_path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&manifest_path)
            .with_context(|| format!("reading {manifest_path:?} — run `make artifacts` first"))?;
        let manifest = Manifest::parse(&text)?;
        let client = xla::PjRtClient::cpu().map_err(|e| anyhow!("PJRT cpu client: {e:?}"))?;
        Ok(Runtime { client, dir, manifest, cache: Mutex::new(HashMap::new()) })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Artifacts directory this runtime loads from.
    pub fn dir(&self) -> &Path {
        &self.dir
    }

    /// Compile (or fetch from cache) an artifact by name.
    pub fn executable(&self, name: &str) -> Result<std::sync::Arc<xla::PjRtLoadedExecutable>> {
        if let Some(e) = self.cache.lock().unwrap().get(name) {
            return Ok(e.clone());
        }
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?;
        let path = self.dir.join(&meta.file);
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().ok_or_else(|| anyhow!("bad path"))?,
        )
        .map_err(|e| anyhow!("parsing HLO text {path:?}: {e:?}"))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .map_err(|e| anyhow!("compiling artifact '{name}': {e:?}"))?;
        let exe = std::sync::Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    /// Number of executables compiled so far (for metrics/tests).
    pub fn compiled_count(&self) -> usize {
        self.cache.lock().unwrap().len()
    }

    /// Execute an artifact on f32 tensors. Inputs are validated against the
    /// manifest shapes; outputs come back as a tuple of tensors.
    pub fn run(&self, name: &str, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
        let meta = self
            .manifest
            .artifact(name)
            .ok_or_else(|| anyhow!("artifact '{name}' not in manifest"))?
            .clone();
        if inputs.len() != meta.input_shapes.len() {
            return Err(anyhow!(
                "artifact '{name}': expected {} inputs, got {}",
                meta.input_shapes.len(),
                inputs.len()
            ));
        }
        for (i, (t, s)) in inputs.iter().zip(meta.input_shapes.iter()).enumerate() {
            if &t.shape != s {
                return Err(anyhow!(
                    "artifact '{name}' input {i}: shape {:?} != manifest {:?}",
                    t.shape,
                    s
                ));
            }
        }
        let exe = self.executable(name)?;
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .map(|t| {
                let dims: Vec<i64> = t.shape.iter().map(|&d| d as i64).collect();
                xla::Literal::vec1(&t.data)
                    .reshape(&dims)
                    .map_err(|e| anyhow!("literal reshape: {e:?}"))
            })
            .collect::<Result<_>>()?;
        let result = exe
            .execute::<xla::Literal>(&lits)
            .map_err(|e| anyhow!("executing '{name}': {e:?}"))?[0][0]
            .to_literal_sync()
            .map_err(|e| anyhow!("fetching result of '{name}': {e:?}"))?;
        // exports lower with return_tuple=True
        let parts = result.to_tuple().map_err(|e| anyhow!("untuple: {e:?}"))?;
        parts
            .into_iter()
            .map(|lit| {
                let shape = lit.shape().map_err(|e| anyhow!("shape: {e:?}"))?;
                let dims: Vec<usize> = match shape {
                    xla::Shape::Array(a) => a.dims().iter().map(|&d| d as usize).collect(),
                    _ => return Err(anyhow!("non-array output")),
                };
                let data = lit.to_vec::<f32>().map_err(|e| anyhow!("to_vec: {e:?}"))?;
                Ok(Tensor::new(dims, data))
            })
            .collect()
    }

    /// Compile every artifact in the manifest; returns (ok, failures).
    pub fn check_all(&self) -> (usize, Vec<(String, String)>) {
        let mut ok = 0;
        let mut failures = Vec::new();
        let names: Vec<String> =
            self.manifest.artifacts.iter().map(|a| a.name.clone()).collect();
        for name in names {
            match self.executable(&name) {
                Ok(_) => ok += 1,
                Err(e) => failures.push((name, format!("{e:#}"))),
            }
        }
        (ok, failures)
    }
}
