"""Post-training INT8 quantization (paper §4.3) at four granularities.

The paper's claim: the last layers of the voting/proposal modules emit
channels with *role-dependent* distributions (Table 2, Fig. 6/7); a single
per-layer scale destroys the small-magnitude regression channels, per-channel
is parameter-hungry, and grouping channels **by role** hits the sweet spot.

This module does PTQ calibration on a handful of scenes and builds
``model.QConfig`` objects for each scheme:

- ``layer``   — one (scale, zero) per head layer
- ``group``   — channels split into N *even contiguous* groups (the naive
                group-wise baseline in Table 11)
- ``channel`` — per-channel scales
- ``role``    — the paper's role groups (common.proposal_role_groups etc.)

Backbone layers are always per-tensor weight-QDQ (that granularity is
harmless there — the paper quantizes the whole model and attributes the
collapse to the heads). It also exports head weight/activation statistics for
the Fig. 6/7 benches.
"""

from __future__ import annotations

from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import common, model, sampling
from .kernels.ref import mlp_ref, pointnet_ref
from .model import QConfig

SCHEMES = ["layer", "group", "channel", "role"]

# head layers subject to the granularity study: name -> (C_out, role groups)
HEAD_LAYERS = {
    "vote_out": (common.VOTE_CH, common.vote_role_groups()),
    "prop_out": (common.PROPOSAL_CH, common.proposal_role_groups()),
}

# backbone layers quantized per-tensor in every INT8 scheme
BACKBONE_MLPS = ["sa1", "sa2", "sa3", "sa4", "vote_mlp", "prop_pointnet", "prop_mlp"]


def channel_groups(scheme: str, cout: int, roles: List[List[int]]) -> List[List[int]]:
    """Channel partition for a scheme."""
    if scheme == "layer":
        return [list(range(cout))]
    if scheme == "channel":
        return [[c] for c in range(cout)]
    if scheme == "role":
        return roles
    if scheme == "group":
        n = len(roles)  # same number of groups as the role scheme (paper)
        bounds = [round(i * cout / n) for i in range(n + 1)]
        return [list(range(bounds[i], bounds[i + 1])) for i in range(n)]
    raise ValueError(scheme)


def _expand(groups: List[List[int]], values: np.ndarray, cout: int) -> np.ndarray:
    out = np.zeros(cout, np.float32)
    for g, v in zip(groups, values):
        out[g] = v
    return out


def weight_scale_vector(w: np.ndarray, groups: List[List[int]]) -> np.ndarray:
    """Symmetric per-group weight scales, expanded to per-channel."""
    cout = w.shape[1]
    vals = np.array([max(np.abs(w[:, g]).max(), 1e-8) / 127.0 for g in groups], np.float32)
    return _expand(groups, vals, cout)


def act_qparams(lo: np.ndarray, hi: np.ndarray, groups: List[List[int]]):
    """Affine per-group activation qparams from per-channel min/max.

    Mirrors rust/src/quant/mod.rs ``ActQuant::calibrate``: the range is NOT
    widened to include zero (that wasted INT8 codes on every post-ReLU
    group), and the zero point is NOT clamped to [-128, 127] — it is a
    shift, not a stored i8 code, and for a group whose range excludes zero
    the true zero point lies outside i8; clamping it shifted the
    representable window off the calibrated range, clipping extremes with
    error up to ``|glo|``.
    """
    cout = len(lo)
    scales = np.zeros(cout, np.float32)
    zeros = np.zeros(cout, np.float32)
    for g in groups:
        glo = float(lo[g].min())
        ghi = float(hi[g].max())
        s = max((ghi - glo) / 255.0, 1e-8)
        z = float(round(-128 - glo / s))
        scales[g] = s
        zeros[g] = z
    return scales, zeros


# ---------------------------------------------------------------------------
# Calibration: collect head activation ranges over a few scenes
# ---------------------------------------------------------------------------


def calibrate(
    params,
    scenes_inputs: List[Tuple[np.ndarray, Optional[np.ndarray], np.ndarray]],
    variant: str = "full",
    w0: float = common.DEFAULT_W0,
) -> Dict[str, np.ndarray]:
    """Run fp32 forward on calibration scenes, returning per-channel
    min/max of the head outputs plus raw activations (for Fig. 6/7 stats).

    scenes_inputs: list of (xyz, feats_or_None, fg).
    """
    vote_outs, prop_outs = [], []

    @jax.jit
    def fwd(xyz, feats, fg, key):
        seed_xyz, seed_feats = model.backbone_forward(
            params, xyz, feats, variant=variant, fg=fg, w0=w0, split_key=key
        )
        h = mlp_ref(seed_feats, params["vote_mlp"])
        vote_out = jnp.dot(h, params["vote_out"][0]) + params["vote_out"][1]
        vote_xyz = seed_xyz + vote_out[:, :3]
        vote_feats = seed_feats + vote_out[:, 3:]
        idx = sampling.fps(vote_xyz, common.NUM_PROPOSALS)
        gidx = sampling.ball_query(
            vote_xyz[idx], vote_xyz, common.PROPOSAL_RADIUS, common.PROPOSAL_K, use_pallas=False
        )
        groups = sampling.group_features(vote_xyz, vote_feats, idx, gidx)
        cf = pointnet_ref(groups, params["prop_pointnet"])
        h2 = mlp_ref(cf, params["prop_mlp"])
        prop_out = jnp.dot(h2, params["prop_out"][0]) + params["prop_out"][1]
        return vote_out, prop_out

    for i, (xyz, feats, fg) in enumerate(scenes_inputs):
        v, p = fwd(
            jnp.asarray(xyz),
            jnp.asarray(feats) if feats is not None else None,
            jnp.asarray(fg),
            jax.random.PRNGKey(i),
        )
        vote_outs.append(np.asarray(v))
        prop_outs.append(np.asarray(p))

    vote_all = np.concatenate(vote_outs)
    prop_all = np.concatenate(prop_outs)
    return {
        "vote_out_min": vote_all.min(0),
        "vote_out_max": vote_all.max(0),
        "prop_out_min": prop_all.min(0),
        "prop_out_max": prop_all.max(0),
        "vote_acts": vote_all,
        "prop_acts": prop_all,
    }


# ---------------------------------------------------------------------------
# QConfig construction
# ---------------------------------------------------------------------------


def _per_tensor_scales(weights, name: str) -> Dict[str, jnp.ndarray]:
    out = {}
    for i, (w, _) in enumerate(weights):
        s = float(max(np.abs(np.asarray(w)).max(), 1e-8)) / 127.0
        out[f"{name}.{i}"] = jnp.full((w.shape[1],), s, jnp.float32)
    return out


def build_qconfig(params, calib: Dict[str, np.ndarray], scheme: str) -> QConfig:
    """Full-model INT8 QConfig with the head layers at `scheme` granularity."""
    wsc: Dict[str, jnp.ndarray] = {}
    act: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]] = {}
    for name in BACKBONE_MLPS:
        if name in params:
            wsc.update(_per_tensor_scales(params[name], name))
    if "fp_fc" in params:
        wsc.update(_per_tensor_scales([params["fp_fc"]], "fp_fc"))

    for name, (cout, roles) in HEAD_LAYERS.items():
        groups = channel_groups(scheme, cout, roles)
        w = np.asarray(params[name][0])
        wsc[name + ".w"] = jnp.asarray(weight_scale_vector(w, groups))
        lo = calib[f"{name}_min"]
        hi = calib[f"{name}_max"]
        s, z = act_qparams(lo, hi, groups)
        act[name] = (jnp.asarray(s), jnp.asarray(z))
    return QConfig(wsc, act)


def quant_param_count(scheme: str) -> int:
    """Number of quantization parameters the head layers need (Table 11):
    per channel group, one weight scale + one activation (scale, zero)."""
    total = 0
    for _, (cout, roles) in HEAD_LAYERS.items():
        total += 3 * len(channel_groups(scheme, cout, roles))
    return total


# ---------------------------------------------------------------------------
# Fig. 6/7 statistics
# ---------------------------------------------------------------------------


def head_stats(params, calib: Dict[str, np.ndarray], bins: int = 24) -> Dict:
    """Per-channel weight ranges + activation histograms for the distribution
    figures. Channels are reported in role-group order (as in Fig. 6)."""
    out: Dict = {}
    for name, (cout, roles) in HEAD_LAYERS.items():
        w = np.asarray(params[name][0])
        acts = calib[name.replace("_out", "_acts")]
        order = [c for g in roles for c in g]
        group_of = np.zeros(cout, np.int32)
        for gi, g in enumerate(roles):
            group_of[g] = gi
        hists = []
        lo, hi = float(acts.min()), float(acts.max())
        edges = np.linspace(lo, hi, bins + 1)
        for c in order:
            h, _ = np.histogram(acts[:, c], bins=edges)
            hists.append((h / max(h.sum(), 1)).tolist())
        out[name] = {
            "channel_order": order,
            "group_of_ordered": [int(group_of[c]) for c in order],
            "weight_min": [float(w[:, c].min()) for c in order],
            "weight_max": [float(w[:, c].max()) for c in order],
            "weight_std": [float(w[:, c].std()) for c in order],
            "act_min": [float(acts[:, c].min()) for c in order],
            "act_max": [float(acts[:, c].max()) for c in order],
            "act_hist": hists,
            "act_hist_lo": lo,
            "act_hist_hi": hi,
        }
    return out
