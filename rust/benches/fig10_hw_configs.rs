//! Paper Fig. 10: PointPainting(INT8) vs PointSplit(INT8) across the four
//! processor pairings (CPU-CPU, CPU-EdgeTPU, GPU-CPU, GPU-EdgeTPU).
//!
//! Expected shape: PointSplit reduces latency on EVERY pairing; largest
//! relative gains where the "first" processor is the bottleneck (paper:
//! 1.7x on CPU-CPU, 1.8x on CPU-EdgeTPU).

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(4);
    let pairs = [
        ("CPU-CPU", DeviceKind::Cpu, DeviceKind::Cpu),
        ("CPU-EdgeTPU", DeviceKind::Cpu, DeviceKind::EdgeTpu),
        ("GPU-CPU", DeviceKind::Gpu, DeviceKind::Cpu),
        ("GPU-EdgeTPU", DeviceKind::Gpu, DeviceKind::EdgeTpu),
    ];
    let paper = [(8545.0, 5016.0), (4243.0, 2407.0), (4341.0, 3563.0), (1224.0, 1113.0)];
    let mut t = Table::new(&[
        "config",
        "PointPainting (ms)",
        "PointSplit (ms)",
        "speedup",
        "paper speedup",
    ]);
    for ((name, pd, nd), (ppp, pps)) in pairs.iter().zip(paper.iter()) {
        let mut pp = 0.0;
        let mut ps = 0.0;
        for seed in 0..scenes as u64 {
            let scene = generate_scene(70_000 + seed, &SYNRGBD);
            let cfg_pp = DetectorConfig::new(
                "synrgbd",
                Variant::PointPainting,
                true,
                Schedule::Sequential { point_dev: *pd, nn_dev: *nd },
            );
            let cfg_ps = DetectorConfig::new(
                "synrgbd",
                Variant::PointSplit,
                true,
                Schedule::Pipelined { point_dev: *pd, nn_dev: *nd },
            );
            pp += ScenePipeline::new(&rt, cfg_pp).run(&scene, seed).unwrap().timeline.total_ms;
            ps += ScenePipeline::new(&rt, cfg_ps).run(&scene, seed).unwrap().timeline.total_ms;
        }
        pp /= scenes as f64;
        ps /= scenes as f64;
        t.row(vec![
            name.to_string(),
            format!("{pp:.0}"),
            format!("{ps:.0}"),
            format!("{:.2}x", pp / ps),
            format!("{:.2}x", ppp / pps),
        ]);
    }
    t.print(&format!("Fig. 10 — latency across processor pairings, INT8 ({scenes} scenes)"));
}
