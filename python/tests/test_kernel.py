"""L1 correctness: Pallas kernels vs pure-jnp oracles (the CORE signal).

Hypothesis sweeps shapes; every kernel must match its ref to float tolerance
under interpret=True (the same lowering the AOT artifacts embed).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.pairwise import pairwise_dist2_pallas
from compile.kernels.pointnet import (
    mxu_utilization_estimate,
    pointnet_pallas,
    vmem_footprint_bytes,
)
from compile.kernels.qmlp import qmlp_pallas

settings.register_profile("ci", max_examples=12, deadline=None)
settings.load_profile("ci")


def mk_weights(key, widths):
    ws = []
    for i in range(len(widths) - 1):
        key, k1, k2 = jax.random.split(key, 3)
        ws.append(
            (
                jax.random.normal(k1, (widths[i], widths[i + 1])) * 0.3,
                jax.random.normal(k2, (widths[i + 1],)) * 0.1,
            )
        )
    return ws


@given(
    b=st.sampled_from([8, 32, 64, 96]),
    k=st.sampled_from([4, 8, 16, 32]),
    cin=st.sampled_from([4, 15, 67]),
    seed=st.integers(0, 2**16),
)
def test_pointnet_matches_ref(b, k, cin, seed):
    key = jax.random.PRNGKey(seed)
    widths = [cin, 16, 16, 24]
    ws = mk_weights(key, widths)
    g = jax.random.normal(jax.random.fold_in(key, 1), (b, k, cin))
    out = pointnet_pallas(g, ws)
    expect = ref.pointnet_ref(g, ws)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-5, atol=1e-5)


def test_pointnet_block_not_dividing_b():
    # b=40 with default block 32 -> falls back to a divisor
    key = jax.random.PRNGKey(0)
    ws = mk_weights(key, [6, 8, 8])
    g = jax.random.normal(key, (40, 4, 6))
    out = pointnet_pallas(g, ws)
    np.testing.assert_allclose(
        np.asarray(out), np.asarray(ref.pointnet_ref(g, ws)), rtol=1e-5, atol=1e-5
    )


def test_pointnet_under_jit():
    key = jax.random.PRNGKey(1)
    ws = mk_weights(key, [15, 32, 32, 64])
    g = jax.random.normal(key, (128, 32, 15))
    f = jax.jit(lambda x: pointnet_pallas(x, ws))
    np.testing.assert_allclose(
        np.asarray(f(g)), np.asarray(ref.pointnet_ref(g, ws)), rtol=1e-5, atol=1e-5
    )


@given(
    n=st.sampled_from([16, 64, 128]),
    cin=st.sampled_from([16, 64]),
    cout=st.sampled_from([8, 79, 131]),
    seed=st.integers(0, 2**16),
)
def test_qmlp_matches_ref(n, cin, cout, seed):
    key = jax.random.PRNGKey(seed)
    k1, k2, k3 = jax.random.split(key, 3)
    x = jax.random.normal(k1, (n, cin))
    w = jax.random.normal(k2, (cin, cout)) * 0.2
    b = jax.random.normal(k3, (cout,)) * 0.1
    ws = jnp.abs(jax.random.normal(k1, (cout,))) * 0.01 + 1e-4
    a_scale = jnp.abs(jax.random.normal(k2, (cout,))) * 0.05 + 1e-4
    a_zero = jnp.round(jax.random.normal(k3, (cout,)) * 10)
    out = np.asarray(qmlp_pallas(x, w, b, ws, a_scale, a_zero))
    expect = np.asarray(ref.qmlp_ref(x, w, b, ws, a_scale, a_zero))
    # rounding at a .5 boundary may flip a rare element by exactly one
    # quantization step (fp summation-order difference between the pallas
    # grid and the fused ref); bound by one step and require near-exactness
    step = np.asarray(a_scale)[None, :]
    diff = np.abs(out - expect)
    assert (diff <= step + 1e-5).all(), f"off-grid deviation {diff.max()}"
    frac_exact = (diff < 1e-5).mean()
    assert frac_exact > 0.99, f"too many boundary flips: {1 - frac_exact:.4f}"


def test_qmlp_output_on_quantization_grid():
    """Outputs must land on the affine int8 grid: (q - z) * s for integer q."""
    key = jax.random.PRNGKey(3)
    x = jax.random.normal(key, (32, 16))
    w = jax.random.normal(key, (16, 8)) * 0.3
    b = jnp.zeros(8)
    s = jnp.full((8,), 0.05)
    z = jnp.zeros(8)
    out = np.asarray(qmlp_pallas(x, w, b, jnp.full((8,), 0.01), s, z))
    q = out / 0.05
    np.testing.assert_allclose(q, np.round(q), atol=1e-4)
    assert out.min() >= -128 * 0.05 - 1e-6 and out.max() <= 127 * 0.05 + 1e-6


@given(
    n=st.sampled_from([64, 256, 1000]),
    m=st.sampled_from([16, 128]),
    seed=st.integers(0, 2**16),
)
def test_pairwise_matches_ref(n, m, seed):
    key = jax.random.PRNGKey(seed)
    a = jax.random.normal(key, (n, 3)) * 3
    b = jax.random.normal(jax.random.fold_in(key, 1), (m, 3)) * 3
    out = pairwise_dist2_pallas(a, b)
    expect = ref.pairwise_dist2_ref(a, b)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect), rtol=1e-4, atol=1e-4)


def test_pairwise_nonnegative():
    key = jax.random.PRNGKey(7)
    a = jax.random.normal(key, (128, 3)) * 10
    out = np.asarray(pairwise_dist2_pallas(a, a))
    assert (out >= 0).all()
    # |x|^2-form suffers f32 cancellation on the diagonal: bound relative
    # to the squared magnitudes, not absolutely
    np.testing.assert_allclose(np.diag(out), 0.0, atol=1e-2)


def test_vmem_footprint_within_budget():
    """§Perf structural check: SA1's tile fits VMEM with double-buffer room."""
    for widths, k in [([15, 32, 32, 64], 32), ([67, 64, 64, 128], 16), ([131, 128, 128, 128], 8)]:
        assert vmem_footprint_bytes(256, k, widths) < 1 << 20, (widths, k)


def test_mxu_utilization_monotone_in_width():
    narrow = mxu_utilization_estimate(32, [15, 32, 32, 64])
    wide = mxu_utilization_estimate(8, [131, 128, 128, 128])
    assert 0.0 < narrow < wide <= 1.0
