//! Oriented 3D IoU: exact rotated-rectangle intersection in bird's-eye view
//! (Sutherland–Hodgman polygon clipping) times vertical overlap.

use crate::data::Box3;

/// BEV corners of a box (counter-clockwise).
fn bev_corners(b: &Box3) -> [[f64; 2]; 4] {
    let (s, c) = (b.heading as f64).sin_cos();
    let hw = b.size[0] as f64 / 2.0;
    let hd = b.size[1] as f64 / 2.0;
    let cx = b.center[0] as f64;
    let cy = b.center[1] as f64;
    let rot = |x: f64, y: f64| [cx + c * x - s * y, cy + s * x + c * y];
    [rot(hw, hd), rot(-hw, hd), rot(-hw, -hd), rot(hw, -hd)]
}

fn polygon_area(poly: &[[f64; 2]]) -> f64 {
    let n = poly.len();
    if n < 3 {
        return 0.0;
    }
    let mut a = 0.0;
    for i in 0..n {
        let j = (i + 1) % n;
        a += poly[i][0] * poly[j][1] - poly[j][0] * poly[i][1];
    }
    a.abs() / 2.0
}

/// Clip polygon `subject` against the half-plane left of edge (a -> b).
fn clip_edge(subject: &[[f64; 2]], a: [f64; 2], b: [f64; 2]) -> Vec<[f64; 2]> {
    let inside = |p: [f64; 2]| (b[0] - a[0]) * (p[1] - a[1]) - (b[1] - a[1]) * (p[0] - a[0]) >= 0.0;
    let mut out = Vec::with_capacity(subject.len() + 2);
    let n = subject.len();
    for i in 0..n {
        let cur = subject[i];
        let prev = subject[(i + n - 1) % n];
        let (ci, pi) = (inside(cur), inside(prev));
        if ci != pi {
            // intersection of (prev, cur) with edge line
            let d1 = [cur[0] - prev[0], cur[1] - prev[1]];
            let d2 = [b[0] - a[0], b[1] - a[1]];
            let denom = d1[0] * d2[1] - d1[1] * d2[0];
            if denom.abs() > 1e-12 {
                let t = ((a[0] - prev[0]) * d2[1] - (a[1] - prev[1]) * d2[0]) / denom;
                out.push([prev[0] + t * d1[0], prev[1] + t * d1[1]]);
            }
        }
        if ci {
            out.push(cur);
        }
    }
    out
}

/// Intersection area of two convex BEV rectangles.
fn bev_intersection(a: &Box3, b: &Box3) -> f64 {
    let ca = bev_corners(a);
    let cb = bev_corners(b);
    // ensure clip polygon is counter-clockwise (it is, by construction)
    let mut poly: Vec<[f64; 2]> = ca.to_vec();
    for i in 0..4 {
        if poly.is_empty() {
            return 0.0;
        }
        poly = clip_edge(&poly, cb[i], cb[(i + 1) % 4]);
    }
    polygon_area(&poly)
}

/// Oriented 3D IoU of two boxes.
pub fn iou3d(a: &Box3, b: &Box3) -> f64 {
    let inter_bev = bev_intersection(a, b);
    if inter_bev <= 0.0 {
        return 0.0;
    }
    let az = (a.center[2] as f64 - a.size[2] as f64 / 2.0, a.center[2] as f64 + a.size[2] as f64 / 2.0);
    let bz = (b.center[2] as f64 - b.size[2] as f64 / 2.0, b.center[2] as f64 + b.size[2] as f64 / 2.0);
    let zi = (az.1.min(bz.1) - az.0.max(bz.0)).max(0.0);
    if zi <= 0.0 {
        return 0.0;
    }
    let inter = inter_bev * zi;
    let va = a.size.iter().map(|&x| x as f64).product::<f64>();
    let vb = b.size.iter().map(|&x| x as f64).product::<f64>();
    (inter / (va + vb - inter)).clamp(0.0, 1.0)
}

/// Axis-aligned 3D IoU (ignores heading) — used to quantify how much the
/// oriented evaluation matters (and by quick sanity tests).
pub fn iou3d_axis_aligned(a: &Box3, b: &Box3) -> f64 {
    let mut inter = 1.0f64;
    for d in 0..3 {
        let al = a.center[d] as f64 - a.size[d] as f64 / 2.0;
        let ah = a.center[d] as f64 + a.size[d] as f64 / 2.0;
        let bl = b.center[d] as f64 - b.size[d] as f64 / 2.0;
        let bh = b.center[d] as f64 + b.size[d] as f64 / 2.0;
        let o = (ah.min(bh) - al.max(bl)).max(0.0);
        inter *= o;
    }
    let va = a.size.iter().map(|&x| x as f64).product::<f64>();
    let vb = b.size.iter().map(|&x| x as f64).product::<f64>();
    if inter <= 0.0 {
        0.0
    } else {
        inter / (va + vb - inter)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(center: [f32; 3], size: [f32; 3], heading: f32) -> Box3 {
        Box3 { center, size, heading, class: 0, score: 1.0 }
    }

    #[test]
    fn identical_boxes_iou_one() {
        let b = mk([1.0, 2.0, 0.5], [2.0, 1.0, 1.0], 0.7);
        assert!((iou3d(&b, &b) - 1.0).abs() < 1e-6);
    }

    #[test]
    fn disjoint_boxes_iou_zero() {
        let a = mk([0.0, 0.0, 0.5], [1.0, 1.0, 1.0], 0.0);
        let b = mk([5.0, 0.0, 0.5], [1.0, 1.0, 1.0], 1.0);
        assert_eq!(iou3d(&a, &b), 0.0);
    }

    #[test]
    fn symmetric() {
        let a = mk([0.0, 0.0, 0.5], [2.0, 1.0, 1.0], 0.3);
        let b = mk([0.5, 0.2, 0.6], [1.5, 1.2, 0.8], 1.1);
        assert!((iou3d(&a, &b) - iou3d(&b, &a)).abs() < 1e-9);
    }

    #[test]
    fn half_overlap_axis_aligned() {
        let a = mk([0.0, 0.0, 0.5], [2.0, 2.0, 1.0], 0.0);
        let b = mk([1.0, 0.0, 0.5], [2.0, 2.0, 1.0], 0.0);
        // intersection 1x2x1=2, union 4+4-2=6
        assert!((iou3d(&a, &b) - 2.0 / 6.0).abs() < 1e-6);
    }

    #[test]
    fn rotation_invariance_of_self_pair() {
        // rotating BOTH boxes by the same angle must not change IoU
        let a0 = mk([0.0, 0.0, 0.5], [2.0, 1.0, 1.0], 0.0);
        let b0 = mk([0.5, 0.3, 0.5], [1.0, 1.5, 1.0], 0.4);
        let base = iou3d(&a0, &b0);
        for rot in [0.3f32, 1.2, 2.9] {
            let (s, c) = rot.sin_cos();
            let rotp = |p: [f32; 3]| [c * p[0] - s * p[1], s * p[0] + c * p[1], p[2]];
            let a = mk(rotp(a0.center), a0.size, a0.heading + rot);
            let b = mk(rotp(b0.center), b0.size, b0.heading + rot);
            assert!((iou3d(&a, &b) - base).abs() < 1e-6, "rot={rot}");
        }
    }

    #[test]
    fn rotated_cross_overlap() {
        // two long boxes crossed at 90 deg: intersection = 1x1 square x height
        let a = mk([0.0, 0.0, 0.5], [4.0, 1.0, 1.0], 0.0);
        let b = mk([0.0, 0.0, 0.5], [4.0, 1.0, 1.0], std::f32::consts::FRAC_PI_2);
        let expect = 1.0 / (4.0 + 4.0 - 1.0);
        assert!((iou3d(&a, &b) - expect).abs() < 1e-4);
    }

    #[test]
    fn oriented_differs_from_axis_aligned() {
        let a = mk([0.0, 0.0, 0.5], [3.0, 0.5, 1.0], 0.6);
        let b = mk([0.0, 0.0, 0.5], [3.0, 0.5, 1.0], 0.0);
        assert!(iou3d(&a, &b) < iou3d_axis_aligned(&a, &b) + 1e-9);
    }

    #[test]
    fn heading_two_pi_periodic() {
        let a = mk([0.0, 0.0, 0.5], [2.0, 1.0, 1.0], 0.4);
        let b = mk([0.0, 0.0, 0.5], [2.0, 1.0, 1.0], 0.4 + 2.0 * std::f32::consts::PI);
        assert!((iou3d(&a, &b) - 1.0).abs() < 1e-5);
    }
}
