"""Training machinery: Adam, pools, save/load round-trip, smoke steps."""

import os

import jax
import jax.numpy as jnp
import numpy as np

from compile import common, model, train


def test_adam_descends_quadratic():
    p = {"w": jnp.asarray([5.0, -3.0])}
    opt = train.adam_init(p)
    for _ in range(300):
        g = jax.grad(lambda q: jnp.sum(q["w"] ** 2))(p)
        p, opt = train.adam_step(p, g, opt, lr=0.05)
    assert float(jnp.abs(p["w"]).max()) < 0.1


def test_params_save_load_roundtrip(tmp_path):
    p = model.detector_init(jax.random.PRNGKey(0), painted=True)
    path = str(tmp_path / "w.npz")
    train.save_params(path, p)
    q = train.load_params(path)
    flat_p = train.flatten_params(p)
    flat_q = train.flatten_params(q)
    assert set(flat_p) == set(flat_q)
    for k in flat_p:
        np.testing.assert_array_equal(np.asarray(flat_p[k]), np.asarray(flat_q[k]))
    # structure usable by the model
    xyz = jnp.zeros((256, 3))
    feats = jnp.zeros((256, common.FEAT_DIM))
    out = model.detector_forward(q, xyz, feats, variant="full")
    assert out["proposal"].shape == (common.NUM_PROPOSALS, common.PROPOSAL_CH)


def test_scene_pool_batches():
    seg = model.segmenter_init(jax.random.PRNGKey(0))
    pool = train.ScenePool(common.SYNRGBD, seg, size=6)
    rng = np.random.default_rng(0)
    xyz, feats, fg, gt = pool.batch(rng, painted=True, n_points=256)
    assert xyz.shape == (train.BATCH, 256, 3)
    assert feats.shape == (train.BATCH, 256, common.FEAT_DIM)
    assert set(gt) == {"centers", "sizes", "headings", "classes", "mask"}
    xyz2, feats2, _, _ = pool.batch(rng, painted=False, n_points=256)
    assert feats2.shape == (train.BATCH, 256, common.FEAT_DIM_PLAIN)


def test_detector_training_reduces_loss():
    """A few steps on a fixed tiny pool must reduce the loss measurably."""
    seg = model.segmenter_init(jax.random.PRNGKey(0))
    pool = train.ScenePool(common.SYNRGBD, seg, size=4)
    lf = train.make_loss_fn("full", 1.0, 0)
    params = model.detector_init(jax.random.PRNGKey(1), painted=True)
    opt = train.adam_init(params)

    @jax.jit
    def step(p, o, *args):
        l, g = jax.value_and_grad(lf)(p, *args)
        p, o = train.adam_step(p, g, o, lr=1e-3)
        return p, o, l

    rng = np.random.default_rng(0)
    batch = pool.batch(rng, painted=True, n_points=512)
    keys = jax.random.split(jax.random.PRNGKey(0), train.BATCH)
    losses = []
    for _ in range(60):
        params, opt, l = step(params, opt, *batch, keys)
        losses.append(float(l))
    # the loss is noisy (proposal clustering flips objectness assignments),
    # so compare a robust statistic, not adjacent samples
    early = float(np.mean(losses[:5]))
    late = float(np.min(losses[-25:]))
    assert late < early * 0.85, f"loss {early} -> best-late {late}"
