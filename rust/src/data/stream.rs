//! Sequential scene generator: temporal frame streams over synthetic rooms.
//!
//! Each stream is a sequence of *shots*. A shot opens with a scene-change cut
//! (a fresh `generate_scene` room) and then evolves deterministically under
//! seeded camera ego-motion (the camera continues its orbit around the room
//! center), per-object jitter, and a few "mover" objects that translate and
//! bounce off the walls. Within a shot, point index `i` refers to the *same*
//! physical surface point in every frame — points translate rigidly with
//! their object — which is exactly the property the temporal reuse cache
//! (`crate::temporal`) relies on for index-based feature warm-starting.

use super::{generate_scene, look_at, render, DatasetCfg, Scene};
use crate::util::rng::Rng;

/// Stream evolution parameters.
#[derive(Debug, Clone)]
pub struct StreamCfg {
    /// frames emitted by `generate_stream`
    pub frames: usize,
    /// shot length: a scene-change cut fires every `cut_period` frames
    pub cut_period: usize,
    /// camera orbit step per frame (radians)
    pub ego_step: f64,
    /// per-frame Gaussian jitter applied to every object (meters)
    pub jitter_sigma: f64,
    /// number of objects per shot that translate continuously
    pub movers: usize,
    /// mover translation speed (meters per frame)
    pub mover_speed: f64,
}

impl Default for StreamCfg {
    fn default() -> Self {
        StreamCfg {
            frames: 32,
            cut_period: 16,
            ego_step: 0.01,
            jitter_sigma: 0.002,
            movers: 1,
            mover_speed: 0.03,
        }
    }
}

/// Position of a frame within its stream.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FrameMeta {
    pub index: usize,
    pub shot: usize,
    pub frame_in_shot: usize,
    /// true on the first frame of a shot (scene-change cut)
    pub is_cut: bool,
}

/// One frame of a temporal stream: a full `Scene` plus stream position.
#[derive(Debug, Clone)]
pub struct Frame {
    pub scene: Scene,
    pub meta: FrameMeta,
}

fn mix(seed: u64, k: u64) -> u64 {
    let mut z = seed ^ k.wrapping_mul(0x9E3779B97F4A7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z ^ (z >> 31)
}

/// Stateful frame-sequence generator. Deterministic in (seed, cfg): two
/// generators with the same inputs emit bit-identical frame sequences.
pub struct StreamGen {
    seed: u64,
    ds: &'static DatasetCfg,
    cfg: StreamCfg,
    index: usize,
    shot: usize,
    frame_in_shot: usize,
    cur: Option<Scene>,
    // orbit state recovered from the shot's opening camera
    angle: f64,
    radius: f64,
    height: f64,
    /// wall bound for mover bounce (half room extent minus margin)
    room_lim: f64,
    /// per-object velocity, zero for non-movers
    vel: Vec<[f64; 2]>,
}

impl StreamGen {
    pub fn new(seed: u64, ds: &'static DatasetCfg, cfg: StreamCfg) -> Self {
        StreamGen {
            seed,
            ds,
            cfg,
            index: 0,
            shot: 0,
            frame_in_shot: 0,
            cur: None,
            angle: 0.0,
            radius: 1.0,
            height: 1.4,
            room_lim: 1.0,
            vel: Vec::new(),
        }
    }

    pub fn cfg(&self) -> &StreamCfg {
        &self.cfg
    }

    /// Open a new shot: fresh room, orbit state derived from its camera.
    fn cut(&mut self) {
        let shot_seed = mix(self.seed, 0xC07 ^ ((self.shot as u64) << 12));
        let scene = generate_scene(shot_seed, self.ds);
        let cam = scene.cam_pos;
        self.angle = cam[1].atan2(cam[0]);
        self.radius = (cam[0] * cam[0] + cam[1] * cam[1]).sqrt();
        self.height = cam[2];
        // camera orbits at room * 0.55, so half room extent = radius / 1.1
        self.room_lim = (self.radius / 1.1 - 0.3).max(0.3);
        let mut srng = Rng::new(shot_seed ^ 0xA11CE);
        self.vel = scene
            .objects
            .iter()
            .enumerate()
            .map(|(oi, _)| {
                if oi < self.cfg.movers {
                    let dir = srng.uniform(0.0, 2.0 * std::f64::consts::PI);
                    [dir.cos() * self.cfg.mover_speed, dir.sin() * self.cfg.mover_speed]
                } else {
                    [0.0, 0.0]
                }
            })
            .collect();
        self.cur = Some(scene);
    }

    /// Advance the current shot by one frame of ego-motion + object motion.
    fn advance(&mut self) {
        let mut rng = Rng::new(mix(self.seed, 0x0F0F ^ self.index as u64));
        let scene = match self.cur.as_mut() {
            Some(s) => s,
            None => return,
        };
        // camera ego-motion: continue the orbit, slight step noise
        self.angle += self.cfg.ego_step + rng.normal_scaled(0.0, self.cfg.ego_step * 0.1);
        let cam = [self.angle.cos() * self.radius, self.angle.sin() * self.radius, self.height];
        scene.cam_pos = cam;
        scene.cam_rot = look_at(cam);
        // object motion: mover velocity (wall bounce) + isotropic jitter
        let mut deltas: Vec<[f32; 2]> = Vec::with_capacity(scene.objects.len());
        for (oi, o) in scene.objects.iter_mut().enumerate() {
            for a in 0..2 {
                let next = o.center[a] as f64 + self.vel[oi][a];
                if next.abs() > self.room_lim {
                    self.vel[oi][a] = -self.vel[oi][a];
                }
            }
            let dx = (self.vel[oi][0] + rng.normal_scaled(0.0, self.cfg.jitter_sigma)) as f32;
            let dy = (self.vel[oi][1] + rng.normal_scaled(0.0, self.cfg.jitter_sigma)) as f32;
            o.center[0] += dx;
            o.center[1] += dy;
            deltas.push([dx, dy]);
        }
        // points translate rigidly with their object — index identity holds
        for (p, &oi) in scene.points.iter_mut().zip(scene.point_obj.iter()) {
            if oi >= 0 {
                p[0] += deltas[oi as usize][0];
                p[1] += deltas[oi as usize][1];
            }
        }
        // re-render under the new camera (image + seg mask move with it)
        let pts: Vec<[f64; 3]> =
            scene.points.iter().map(|p| [p[0] as f64, p[1] as f64, p[2] as f64]).collect();
        let obj = scene.point_obj.clone();
        render(&mut rng, &pts, &obj, self.ds, scene);
    }

    /// Emit the next frame of the stream (infinite; callers bound it).
    pub fn next_frame(&mut self) -> Frame {
        let is_cut = self.frame_in_shot == 0;
        if is_cut {
            self.cut();
        } else {
            self.advance();
        }
        let meta = FrameMeta {
            index: self.index,
            shot: self.shot,
            frame_in_shot: self.frame_in_shot,
            is_cut,
        };
        let scene = self.cur.clone().unwrap_or_else(|| generate_scene(self.seed, self.ds));
        self.index += 1;
        self.frame_in_shot += 1;
        if self.frame_in_shot >= self.cfg.cut_period.max(1) {
            self.frame_in_shot = 0;
            self.shot += 1;
        }
        Frame { scene, meta }
    }
}

/// Generate a bounded frame sequence (`cfg.frames` long).
pub fn generate_stream(seed: u64, ds: &'static DatasetCfg, cfg: StreamCfg) -> Vec<Frame> {
    let frames = cfg.frames;
    let mut g = StreamGen::new(seed, ds, cfg);
    (0..frames).map(|_| g.next_frame()).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::SYNRGBD;

    #[test]
    fn stream_is_deterministic() {
        let a = generate_stream(7, &SYNRGBD, StreamCfg::default());
        let b = generate_stream(7, &SYNRGBD, StreamCfg::default());
        assert_eq!(a.len(), b.len());
        for (fa, fb) in a.iter().zip(b.iter()) {
            assert_eq!(fa.meta, fb.meta);
            assert_eq!(fa.scene.points, fb.scene.points);
            assert_eq!(fa.scene.seg_mask, fb.scene.seg_mask);
        }
    }

    #[test]
    fn point_identity_within_shot() {
        let cfg = StreamCfg { frames: 6, cut_period: 8, ..StreamCfg::default() };
        let frames = generate_stream(3, &SYNRGBD, cfg);
        for w in frames.windows(2) {
            assert!(!w[1].meta.is_cut);
            let (a, b) = (&w[0].scene, &w[1].scene);
            assert_eq!(a.points.len(), b.points.len());
            assert_eq!(a.point_obj, b.point_obj);
            // background points are static; object points move < 10 cm / frame
            for ((pa, pb), &oi) in a.points.iter().zip(b.points.iter()).zip(a.point_obj.iter()) {
                if oi < 0 {
                    assert_eq!(pa, pb);
                } else {
                    let d = ((pa[0] - pb[0]).powi(2) + (pa[1] - pb[1]).powi(2)).sqrt();
                    assert!(d < 0.1, "object point jumped {d}");
                }
            }
        }
    }

    #[test]
    fn cuts_reset_the_scene() {
        let cfg = StreamCfg { frames: 10, cut_period: 4, ..StreamCfg::default() };
        let frames = generate_stream(11, &SYNRGBD, cfg);
        for (i, f) in frames.iter().enumerate() {
            assert_eq!(f.meta.index, i);
            assert_eq!(f.meta.is_cut, i % 4 == 0);
            assert_eq!(f.meta.shot, i / 4);
        }
        // frames across a cut come from different rooms
        let before = &frames[3].scene;
        let after = &frames[4].scene;
        assert_ne!(before.points, after.points);
        assert_ne!(before.objects.len(), 0);
    }

    #[test]
    fn camera_moves_every_frame() {
        let frames = generate_stream(5, &SYNRGBD, StreamCfg { frames: 4, ..Default::default() });
        for w in frames.windows(2) {
            if w[1].meta.is_cut {
                continue;
            }
            assert_ne!(w[0].scene.cam_pos, w[1].scene.cam_pos);
            assert_ne!(w[0].scene.image, w[1].scene.image);
        }
    }

    #[test]
    fn movers_stay_inside_the_room() {
        let cfg = StreamCfg { frames: 48, cut_period: 48, mover_speed: 0.08, ..Default::default() };
        let frames = generate_stream(9, &SYNRGBD, cfg);
        let lim = {
            let c = frames[0].scene.cam_pos;
            ((c[0] * c[0] + c[1] * c[1]).sqrt() / 1.1 - 0.3).max(0.3) + 0.5
        };
        for f in &frames {
            for o in &f.scene.objects {
                assert!(
                    (o.center[0] as f64).abs() < lim + 1.0 && (o.center[1] as f64).abs() < lim + 1.0,
                    "mover escaped: {:?}",
                    o.center
                );
            }
        }
    }
}
