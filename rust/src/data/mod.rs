//! SynRGBD / SynScan procedural scene generator (Rust mirror of
//! python/compile/scene.py — see DESIGN.md §2 for the substitution argument).
//!
//! The Python generator feeds training; this one feeds the serving/eval path.
//! The two are *distributionally* identical: same shape programs, same
//! parameter ranges, same visibility / noise models. Statistical parity is
//! asserted in tests on both sides.

pub mod shapes;
pub mod stream;

use crate::util::rng::Rng;

pub const IMG_SIZE: usize = 64;
pub const NUM_CLASS: usize = 10;

pub const CLASS_NAMES: [&str; NUM_CLASS] = [
    "bed", "table", "sofa", "chair", "toilet", "desk", "dresser", "nightstand", "bookshelf",
    "bathtub",
];

/// Base render color per class (mirrors scene.py `_CLASS_COLORS`).
pub const CLASS_COLORS: [[f32; 3]; NUM_CLASS] = [
    [0.85, 0.30, 0.30],
    [0.55, 0.35, 0.20],
    [0.30, 0.55, 0.85],
    [0.90, 0.65, 0.20],
    [0.90, 0.90, 0.95],
    [0.45, 0.30, 0.55],
    [0.35, 0.60, 0.35],
    [0.70, 0.55, 0.35],
    [0.60, 0.20, 0.45],
    [0.25, 0.75, 0.75],
];
const BG_COLOR: [f32; 3] = [0.55, 0.55, 0.58];

/// Dataset generation parameters (mirrors common.DatasetConfig).
#[derive(Debug, Clone)]
pub struct DatasetCfg {
    pub name: &'static str,
    pub num_points: usize,
    pub room_min: f64,
    pub room_max: f64,
    pub min_objects: usize,
    pub max_objects: usize,
    pub single_view: bool,
    pub depth_noise: f64,
    pub seg_noise: f64,
}

pub const SYNRGBD: DatasetCfg = DatasetCfg {
    name: "synrgbd",
    num_points: 2048,
    room_min: 3.0,
    room_max: 4.5,
    min_objects: 3,
    max_objects: 7,
    single_view: true,
    depth_noise: 0.008,
    seg_noise: 0.05,
};

pub const SYNSCAN: DatasetCfg = DatasetCfg {
    name: "synscan",
    num_points: 4096,
    room_min: 5.0,
    room_max: 8.0,
    min_objects: 6,
    max_objects: 12,
    single_view: false,
    depth_noise: 0.004,
    seg_noise: 0.03,
};

pub fn dataset(name: &str) -> Option<&'static DatasetCfg> {
    match name {
        "synrgbd" => Some(&SYNRGBD),
        "synscan" => Some(&SYNSCAN),
        _ => None,
    }
}

/// Oriented 3D bounding box ground truth / detection container.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Box3 {
    pub center: [f32; 3],
    pub size: [f32; 3], // full extents (w, d, h)
    pub heading: f32,   // yaw in [0, 2pi)
    pub class: usize,
    pub score: f32, // 1.0 for GT; detector confidence otherwise
}

#[derive(Debug, Clone)]
pub struct SceneObject {
    pub class: usize,
    pub center: [f32; 3],
    pub size: [f32; 3],
    pub heading: f32,
    /// canonical cuboid parts (cx, cy, cz, sx, sy, sz)
    pub parts: Vec<[f64; 6]>,
}

/// One synthetic RGB-D scene with full ground truth.
#[derive(Debug, Clone)]
pub struct Scene {
    pub points: Vec<[f32; 3]>,
    /// index into `objects`, -1 for background
    pub point_obj: Vec<i32>,
    /// RGB render, row-major HxWx3 in [0,1]
    pub image: Vec<f32>,
    /// GT segmentation mask, 0 = background, 1+class otherwise
    pub seg_mask: Vec<u8>,
    pub objects: Vec<SceneObject>,
    pub cam_pos: [f64; 3],
    /// world->camera rotation rows: right, -up, forward
    pub cam_rot: [[f64; 3]; 3],
    pub fx: f64,
}

impl Scene {
    pub fn gt_boxes(&self) -> Vec<Box3> {
        self.objects
            .iter()
            .map(|o| Box3 {
                center: o.center,
                size: o.size,
                heading: o.heading,
                class: o.class,
                score: 1.0,
            })
            .collect()
    }

    /// Pinhole projection of a world point -> (u, v, depth).
    pub fn project(&self, p: [f32; 3]) -> (f64, f64, f64) {
        let d = [
            p[0] as f64 - self.cam_pos[0],
            p[1] as f64 - self.cam_pos[1],
            p[2] as f64 - self.cam_pos[2],
        ];
        let r = &self.cam_rot;
        let x = r[0][0] * d[0] + r[0][1] * d[1] + r[0][2] * d[2];
        let y = r[1][0] * d[0] + r[1][1] * d[1] + r[1][2] * d[2];
        let z = (r[2][0] * d[0] + r[2][1] * d[1] + r[2][2] * d[2]).max(1e-6);
        (self.fx * x / z + IMG_SIZE as f64 / 2.0, self.fx * y / z + IMG_SIZE as f64 / 2.0, z)
    }
}

fn rot_z(theta: f64) -> [[f64; 2]; 2] {
    let (s, c) = theta.sin_cos();
    [[c, -s], [s, c]]
}

/// Sample n points on a cuboid part surface (bottom face skipped).
fn sample_cuboid_surface(
    rng: &mut Rng,
    part: &[f64; 6],
    n: usize,
    pts: &mut Vec<[f64; 3]>,
    nrm: &mut Vec<[f64; 3]>,
) {
    let [cx, cy, cz, sx, sy, sz] = *part;
    let areas = [sy * sz, sy * sz, sx * sz, sx * sz, sx * sy];
    for _ in 0..n {
        let f = rng.weighted(&areas);
        let u = rng.uniform(-0.5, 0.5);
        let v = rng.uniform(-0.5, 0.5);
        let (p, normal) = match f {
            0 => ([sx / 2.0, u * sy, v * sz], [1.0, 0.0, 0.0]),
            1 => ([-sx / 2.0, u * sy, v * sz], [-1.0, 0.0, 0.0]),
            2 => ([u * sx, sy / 2.0, v * sz], [0.0, 1.0, 0.0]),
            3 => ([u * sx, -sy / 2.0, v * sz], [0.0, -1.0, 0.0]),
            _ => ([u * sx, v * sy, sz / 2.0], [0.0, 0.0, 1.0]),
        };
        pts.push([p[0] + cx, p[1] + cy, p[2] + cz]);
        nrm.push(normal);
    }
}

fn place_objects(rng: &mut Rng, cfg: &DatasetCfg, room: f64) -> Vec<SceneObject> {
    let n_obj = rng.int_range(cfg.min_objects as i64, cfg.max_objects as i64) as usize;
    let mut objects: Vec<SceneObject> = Vec::new();
    let mut tries = 0;
    while objects.len() < n_obj && tries < 80 {
        tries += 1;
        let class = rng.below(NUM_CLASS);
        let spec = &shapes::CLASS_SPECS[class];
        let w = rng.uniform(spec.w.0, spec.w.1);
        let d = rng.uniform(spec.d.0, spec.d.1);
        let h = rng.uniform(spec.h.0, spec.h.1);
        let heading = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
        let rad = 0.5 * (w * w + d * d).sqrt();
        if room / 2.0 - rad - 0.1 <= 0.3 {
            continue;
        }
        let lim = room / 2.0 - rad - 0.1;
        let cx = rng.uniform(-lim, lim);
        let cy = rng.uniform(-lim, lim);
        let ok = objects.iter().all(|o| {
            let orad = 0.5 * ((o.size[0] * o.size[0] + o.size[1] * o.size[1]) as f64).sqrt();
            let dx = cx - o.center[0] as f64;
            let dy = cy - o.center[1] as f64;
            (dx * dx + dy * dy).sqrt() >= rad + orad + 0.05
        });
        if !ok {
            continue;
        }
        objects.push(SceneObject {
            class,
            center: [cx as f32, cy as f32, (h / 2.0) as f32],
            size: [w as f32, d as f32, h as f32],
            heading: heading as f32,
            parts: (spec.program)(w, d, h),
        });
    }
    objects
}

/// World->camera look-at rotation for a camera at `cam` targeting the room
/// center (rows: right, -up, forward) — shared by the static camera
/// placement and the streaming ego-motion path (`stream`).
pub(crate) fn look_at(cam: [f64; 3]) -> [[f64; 3]; 3] {
    let target = [0.0, 0.0, 0.8];
    let mut fwd = [target[0] - cam[0], target[1] - cam[1], target[2] - cam[2]];
    let n = (fwd[0] * fwd[0] + fwd[1] * fwd[1] + fwd[2] * fwd[2]).sqrt();
    fwd = [fwd[0] / n, fwd[1] / n, fwd[2] / n];
    // right = fwd x up(z)
    let mut right = [fwd[1], -fwd[0], 0.0];
    let rn = (right[0] * right[0] + right[1] * right[1]).sqrt();
    right = [right[0] / rn, right[1] / rn, 0.0];
    // up = right x fwd
    let up = [
        right[1] * fwd[2] - right[2] * fwd[1],
        right[2] * fwd[0] - right[0] * fwd[2],
        right[0] * fwd[1] - right[1] * fwd[0],
    ];
    [right, [-up[0], -up[1], -up[2]], fwd]
}

fn camera(rng: &mut Rng, room: f64) -> ([f64; 3], [[f64; 3]; 3], f64) {
    let ang = rng.uniform(0.0, 2.0 * std::f64::consts::PI);
    let cam = [ang.cos() * room * 0.55, ang.sin() * room * 0.55, rng.uniform(1.2, 1.7)];
    (cam, look_at(cam), IMG_SIZE as f64 * 0.9)
}

/// Generate one deterministic scene (same procedural family as scene.py).
pub fn generate_scene(seed: u64, cfg: &DatasetCfg) -> Scene {
    let mut rng = Rng::new(seed.wrapping_mul(0x5851F42D4C957F2D).wrapping_add(0xDA3E39CB94B95BDB));
    let room = rng.uniform(cfg.room_min, cfg.room_max);
    let objects = place_objects(&mut rng, cfg, room);
    let (cam, rot, fx) = camera(&mut rng, room);

    let raw = 6 * cfg.num_points;
    let mut pts: Vec<[f64; 3]> = Vec::with_capacity(raw);
    let mut nrm: Vec<[f64; 3]> = Vec::with_capacity(raw);
    let mut obj: Vec<i32> = Vec::with_capacity(raw);

    let part_area =
        |p: &[f64; 6]| 2.0 * (p[3] * p[4] + p[4] * p[5] + p[3] * p[5]);
    let total_area: f64 =
        objects.iter().map(|o| o.parts.iter().map(part_area).sum::<f64>()).sum();
    let n_obj_pts = raw * 55 / 100;
    for (oi, o) in objects.iter().enumerate() {
        let area: f64 = o.parts.iter().map(part_area).sum();
        let n_o = ((n_obj_pts as f64 * area / total_area.max(1e-6)) as usize).max(32);
        let weights: Vec<f64> = o.parts.iter().map(part_area).collect();
        let counts = rng.multinomial(n_o, &weights);
        let r = rot_z(o.heading as f64);
        for (part, &c) in o.parts.iter().zip(counts.iter()) {
            let start = pts.len();
            sample_cuboid_surface(&mut rng, part, c, &mut pts, &mut nrm);
            for i in start..pts.len() {
                let p = pts[i];
                pts[i] = [
                    r[0][0] * p[0] + r[0][1] * p[1] + o.center[0] as f64,
                    r[1][0] * p[0] + r[1][1] * p[1] + o.center[1] as f64,
                    p[2],
                ];
                let nv = nrm[i];
                nrm[i] = [r[0][0] * nv[0] + r[0][1] * nv[1], r[1][0] * nv[0] + r[1][1] * nv[1], nv[2]];
                obj.push(oi as i32);
            }
        }
    }

    // background: floor + two far walls
    let n_bg = raw.saturating_sub(pts.len());
    let n_floor = n_bg * 6 / 10;
    for _ in 0..n_floor {
        pts.push([rng.uniform(-room / 2.0, room / 2.0), rng.uniform(-room / 2.0, room / 2.0), 0.0]);
        nrm.push([0.0, 0.0, 1.0]);
        obj.push(-1);
    }
    let n_wall = n_bg - n_floor;
    let wx = -cam[0].signum() * room / 2.0;
    let wy = -cam[1].signum() * room / 2.0;
    let half = n_wall / 2;
    for _ in 0..half {
        pts.push([wx, rng.uniform(-room / 2.0, room / 2.0), rng.uniform(0.0, 2.2)]);
        nrm.push([cam[0].signum(), 0.0, 0.0]);
        obj.push(-1);
    }
    for _ in 0..(n_wall - half) {
        pts.push([rng.uniform(-room / 2.0, room / 2.0), wy, rng.uniform(0.0, 2.2)]);
        nrm.push([0.0, cam[1].signum(), 0.0]);
        obj.push(-1);
    }

    // single-view visibility culling
    if cfg.single_view {
        let mut kept_p = Vec::with_capacity(pts.len());
        let mut kept_o = Vec::with_capacity(pts.len());
        for i in 0..pts.len() {
            let to_cam = [cam[0] - pts[i][0], cam[1] - pts[i][1], cam[2] - pts[i][2]];
            let facing =
                to_cam[0] * nrm[i][0] + to_cam[1] * nrm[i][1] + to_cam[2] * nrm[i][2] > 0.0;
            let d = [pts[i][0] - cam[0], pts[i][1] - cam[1], pts[i][2] - cam[2]];
            let in_front = rot[2][0] * d[0] + rot[2][1] * d[1] + rot[2][2] * d[2] > 0.3;
            if facing && in_front {
                kept_p.push(pts[i]);
                kept_o.push(obj[i]);
            }
        }
        pts = kept_p;
        obj = kept_o;
    }

    // render before subsampling (dense coverage)
    let mut scene = Scene {
        points: Vec::new(),
        point_obj: Vec::new(),
        image: Vec::new(),
        seg_mask: Vec::new(),
        objects,
        cam_pos: cam,
        cam_rot: rot,
        fx,
    };
    render(&mut rng, &pts, &obj, cfg, &mut scene);

    // subsample to budget + depth noise
    let n = cfg.num_points;
    let sel = if pts.len() >= n {
        rng.choice_no_replace(pts.len(), n)
    } else {
        rng.choice_replace(pts.len().max(1), n)
    };
    scene.points = sel
        .iter()
        .map(|&i| {
            [
                (pts[i][0] + rng.normal_scaled(0.0, cfg.depth_noise)) as f32,
                (pts[i][1] + rng.normal_scaled(0.0, cfg.depth_noise)) as f32,
                (pts[i][2] + rng.normal_scaled(0.0, cfg.depth_noise)) as f32,
            ]
        })
        .collect();
    scene.point_obj = sel.iter().map(|&i| obj[i]).collect();
    scene
}

pub(crate) fn render(
    rng: &mut Rng,
    pts: &[[f64; 3]],
    obj: &[i32],
    cfg: &DatasetCfg,
    scene: &mut Scene,
) {
    let hw = IMG_SIZE * IMG_SIZE;
    let mut img = vec![0.0f32; hw * 3];
    let mut seg = vec![0u8; hw];
    let mut zbuf = vec![f64::INFINITY; hw];
    // background shading gradient (rows from 0.9 to 1.1)
    for y in 0..IMG_SIZE {
        let f = 0.9 + 0.2 * y as f32 / (IMG_SIZE - 1) as f32;
        for x in 0..IMG_SIZE {
            for c in 0..3 {
                img[(y * IMG_SIZE + x) * 3 + c] = BG_COLOR[c] * f;
            }
        }
    }
    let cls_of: Vec<i32> = scene.objects.iter().map(|o| o.class as i32).collect();
    for (p, &oi) in pts.iter().zip(obj.iter()) {
        let (u, v, z) = scene.project([p[0] as f32, p[1] as f32, p[2] as f32]);
        let ui = u.floor() as i64;
        let vi = v.floor() as i64;
        if ui < 0 || ui >= IMG_SIZE as i64 || vi < 0 || vi >= IMG_SIZE as i64 || z <= 0.05 {
            continue;
        }
        let idx = vi as usize * IMG_SIZE + ui as usize;
        if z >= zbuf[idx] {
            continue;
        }
        zbuf[idx] = z;
        let lab = if oi >= 0 { cls_of[oi as usize] } else { -1 };
        seg[idx] = (lab + 1) as u8;
        if lab >= 0 {
            let shade = (1.0 - z / 12.0).clamp(0.45, 1.0) as f32;
            let col = CLASS_COLORS[lab as usize];
            for c in 0..3 {
                img[idx * 3 + c] = col[c] * shade;
            }
        }
    }
    // pixel noise + label corruption
    for v in img.iter_mut() {
        *v = (*v + rng.normal_scaled(0.0, 0.03) as f32).clamp(0.0, 1.0);
    }
    let n_noise = (cfg.seg_noise * hw as f64) as usize;
    for _ in 0..n_noise {
        let idx = rng.below(hw);
        seg[idx] = rng.below(NUM_CLASS + 1) as u8;
    }
    scene.image = img;
    scene.seg_mask = seg;
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn scene_shapes() {
        let s = generate_scene(3, &SYNRGBD);
        assert_eq!(s.points.len(), SYNRGBD.num_points);
        assert_eq!(s.image.len(), IMG_SIZE * IMG_SIZE * 3);
        assert_eq!(s.seg_mask.len(), IMG_SIZE * IMG_SIZE);
        assert!(!s.objects.is_empty() && s.objects.len() <= SYNRGBD.max_objects);
    }

    #[test]
    fn deterministic() {
        let a = generate_scene(11, &SYNRGBD);
        let b = generate_scene(11, &SYNRGBD);
        assert_eq!(a.points, b.points);
        assert_eq!(a.seg_mask, b.seg_mask);
    }

    #[test]
    fn objects_inside_room_and_boxes_contain_points() {
        for seed in 0..8 {
            let s = generate_scene(seed, &SYNSCAN);
            for o in &s.objects {
                assert!(o.center[0].abs() < 5.0 && o.center[1].abs() < 5.0);
                assert!(o.size.iter().all(|&d| d > 0.1 && d < 3.0));
            }
            // every object-labelled point is near its object's bbox
            for (p, &oi) in s.points.iter().zip(s.point_obj.iter()) {
                if oi < 0 {
                    continue;
                }
                let o = &s.objects[oi as usize];
                let dx = p[0] - o.center[0];
                let dy = p[1] - o.center[1];
                let r = 0.5 * (o.size[0] * o.size[0] + o.size[1] * o.size[1]).sqrt() + 0.15;
                assert!(
                    (dx * dx + dy * dy).sqrt() <= r,
                    "point {:?} too far from object {:?}",
                    p,
                    o.center
                );
            }
        }
    }

    #[test]
    fn single_view_culls_points() {
        // SynRGBD scenes must not contain surfaces facing away from camera;
        // proxy: fewer distinct wall points than the full-scan dataset
        let s1 = generate_scene(5, &SYNRGBD);
        let bg1 = s1.point_obj.iter().filter(|&&o| o < 0).count();
        assert!(bg1 > 0, "background should remain visible");
    }

    #[test]
    fn seg_mask_classes_in_range() {
        let s = generate_scene(2, &SYNRGBD);
        assert!(s.seg_mask.iter().all(|&m| m as usize <= NUM_CLASS));
        // some foreground should be visible
        assert!(s.seg_mask.iter().filter(|&&m| m > 0).count() > 20);
    }
}
