//! Open-loop arrival generators: Poisson, bursty (2-state MMPP), and a
//! diurnal ramp, emitting timestamped scene requests with deadlines.
//!
//! Open-loop means arrivals do not wait for completions — exactly the regime
//! where queueing delay and overload behaviour appear (a closed loop can
//! never drive the system past 100% utilization). Everything is generated
//! from the deterministic [`Rng`], so a scenario is a pure function of its
//! seed: reports are reproducible and policies can be A/B-compared on the
//! *identical* arrival trace.

use crate::util::rng::Rng;

/// One inbound detection request.
#[derive(Debug, Clone)]
pub struct Request {
    /// Monotonically increasing arrival index (ids order arrivals).
    pub id: u64,
    /// Arrival timestamp on the simulated clock, ms.
    pub arrival_ms: f64,
    /// Absolute deadline on the simulated clock, ms.
    pub deadline_ms: f64,
    /// Scene seed (which synthetic scene this request asks about).
    pub seed: u64,
    /// Priority class: 0 is served first; FIFO within a class.
    pub class: usize,
    /// Index into the scenario's detector-config list — the batching
    /// compatibility key (same dataset + precision variant batch together).
    pub key: usize,
    /// Streaming session id: consecutive frames from one camera share a
    /// client id so the gateway can reuse that session's cached frame state
    /// (see [`crate::temporal`]). `0` means a sessionless one-shot request.
    pub client: u64,
}

/// Arrival process shapes. Rates are requests per second of simulated time.
#[derive(Debug, Clone, Copy)]
pub enum ArrivalPattern {
    /// Memoryless arrivals at a constant rate.
    Poisson { rate_rps: f64 },
    /// Two-state Markov-modulated Poisson process: calm at `base_rps`,
    /// bursts at `burst_rps`; exponential dwell times in each state.
    Bursty { base_rps: f64, burst_rps: f64, mean_burst_ms: f64, mean_calm_ms: f64 },
    /// Sinusoidal rate ramp between `base_rps` and `peak_rps` with the given
    /// period (a day compressed to seconds), sampled by thinning.
    Diurnal { base_rps: f64, peak_rps: f64, period_s: f64 },
}

impl ArrivalPattern {
    /// Long-run average arrival rate (for load accounting / reports).
    pub fn mean_rps(&self) -> f64 {
        match *self {
            ArrivalPattern::Poisson { rate_rps } => rate_rps,
            ArrivalPattern::Bursty { base_rps, burst_rps, mean_burst_ms, mean_calm_ms } => {
                (base_rps * mean_calm_ms + burst_rps * mean_burst_ms)
                    / (mean_calm_ms + mean_burst_ms)
            }
            ArrivalPattern::Diurnal { base_rps, peak_rps, .. } => (base_rps + peak_rps) / 2.0,
        }
    }

    /// Scale every rate by `f` (offered-load sweeps).
    pub fn scaled(&self, f: f64) -> ArrivalPattern {
        match *self {
            ArrivalPattern::Poisson { rate_rps } => {
                ArrivalPattern::Poisson { rate_rps: rate_rps * f }
            }
            ArrivalPattern::Bursty { base_rps, burst_rps, mean_burst_ms, mean_calm_ms } => {
                ArrivalPattern::Bursty {
                    base_rps: base_rps * f,
                    burst_rps: burst_rps * f,
                    mean_burst_ms,
                    mean_calm_ms,
                }
            }
            ArrivalPattern::Diurnal { base_rps, peak_rps, period_s } => {
                ArrivalPattern::Diurnal { base_rps: base_rps * f, peak_rps: peak_rps * f, period_s }
            }
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            ArrivalPattern::Poisson { .. } => "poisson",
            ArrivalPattern::Bursty { .. } => "bursty",
            ArrivalPattern::Diurnal { .. } => "diurnal",
        }
    }
}

/// Traffic generator configuration.
#[derive(Debug, Clone)]
pub struct LoadGen {
    pub pattern: ArrivalPattern,
    /// Length of the arrival window, ms (completions may run past it).
    pub duration_ms: f64,
    /// Relative deadline granted to every request, ms after arrival.
    pub deadline_ms: f64,
    /// Fraction of requests in the high-priority class 0 (rest class 1).
    pub hi_frac: f64,
    /// Mix weights over the scenario's detector configs (batch keys).
    pub mix: Vec<f64>,
    /// Number of distinct streaming clients arrivals are spread over
    /// (round-robin). `0` = every request is sessionless (`client == 0`).
    pub clients: usize,
    /// Base seed: both the arrival trace and the per-request scene seeds.
    pub seed: u64,
}

impl LoadGen {
    /// Single-config, single-class trace (the common case).
    pub fn simple(pattern: ArrivalPattern, duration_ms: f64, deadline_ms: f64, seed: u64) -> LoadGen {
        LoadGen { pattern, duration_ms, deadline_ms, hi_frac: 0.0, mix: vec![1.0], clients: 0, seed }
    }

    /// Generate the arrival trace, sorted by arrival time.
    pub fn generate(&self) -> Vec<Request> {
        let mut rng = Rng::new(self.seed ^ 0x5EED_7AFF);
        let times = match self.pattern {
            ArrivalPattern::Poisson { rate_rps } => {
                poisson_times(&mut rng, rate_rps, self.duration_ms)
            }
            ArrivalPattern::Bursty { base_rps, burst_rps, mean_burst_ms, mean_calm_ms } => {
                mmpp_times(&mut rng, base_rps, burst_rps, mean_burst_ms, mean_calm_ms, self.duration_ms)
            }
            ArrivalPattern::Diurnal { base_rps, peak_rps, period_s } => {
                diurnal_times(&mut rng, base_rps, peak_rps, period_s * 1000.0, self.duration_ms)
            }
        };
        times
            .into_iter()
            .enumerate()
            .map(|(i, t)| Request {
                id: i as u64,
                arrival_ms: t,
                deadline_ms: t + self.deadline_ms,
                seed: self.seed.wrapping_mul(0x9E37).wrapping_add(i as u64),
                class: if rng.f64() < self.hi_frac { 0 } else { 1 },
                key: if self.mix.len() > 1 { rng.weighted(&self.mix) } else { 0 },
                // round-robin, no RNG draw: adding clients never perturbs the
                // class/key sequence of an existing trace
                client: if self.clients > 0 { 1 + (i as u64) % self.clients as u64 } else { 0 },
            })
            .collect()
    }
}

/// Exponential inter-arrival sample for a rate in events/sec, returned in ms.
fn exp_gap_ms(rng: &mut Rng, rate_rps: f64) -> f64 {
    debug_assert!(rate_rps > 0.0);
    -(1.0 - rng.f64()).ln() / rate_rps * 1000.0
}

fn poisson_times(rng: &mut Rng, rate_rps: f64, duration_ms: f64) -> Vec<f64> {
    let mut out = Vec::new();
    if rate_rps <= 0.0 {
        return out;
    }
    let mut t = exp_gap_ms(rng, rate_rps);
    while t < duration_ms {
        out.push(t);
        t += exp_gap_ms(rng, rate_rps);
    }
    out
}

fn mmpp_times(
    rng: &mut Rng,
    base_rps: f64,
    burst_rps: f64,
    mean_burst_ms: f64,
    mean_calm_ms: f64,
    duration_ms: f64,
) -> Vec<f64> {
    let mut out = Vec::new();
    let mut t = 0.0f64;
    let mut bursting = false;
    // exponential dwell in the current state, then switch
    let mut state_end = exp_gap_ms(rng, 1000.0 / mean_calm_ms);
    while t < duration_ms {
        let rate = if bursting { burst_rps } else { base_rps };
        let next = if rate > 0.0 { t + exp_gap_ms(rng, rate) } else { f64::INFINITY };
        if next < state_end {
            t = next;
            if t < duration_ms {
                out.push(t);
            }
        } else {
            t = state_end;
            bursting = !bursting;
            let mean = if bursting { mean_burst_ms } else { mean_calm_ms };
            state_end = t + exp_gap_ms(rng, 1000.0 / mean);
        }
    }
    out
}

/// Lewis–Shedler thinning against the peak rate.
fn diurnal_times(
    rng: &mut Rng,
    base_rps: f64,
    peak_rps: f64,
    period_ms: f64,
    duration_ms: f64,
) -> Vec<f64> {
    let mut out = Vec::new();
    let lambda_max = peak_rps.max(base_rps);
    if lambda_max <= 0.0 {
        return out;
    }
    let rate_at = |t_ms: f64| -> f64 {
        let phase = (t_ms / period_ms) * std::f64::consts::TAU;
        base_rps + (peak_rps - base_rps) * 0.5 * (1.0 - phase.cos())
    };
    let mut t = 0.0f64;
    loop {
        t += exp_gap_ms(rng, lambda_max);
        if t >= duration_ms {
            break;
        }
        if rng.f64() * lambda_max < rate_at(t) {
            out.push(t);
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn count_of(pattern: ArrivalPattern, duration_ms: f64, seed: u64) -> usize {
        LoadGen::simple(pattern, duration_ms, 500.0, seed).generate().len()
    }

    #[test]
    fn poisson_rate_matches() {
        // 20 rps over 50 simulated seconds -> ~1000 arrivals
        let n = count_of(ArrivalPattern::Poisson { rate_rps: 20.0 }, 50_000.0, 1);
        assert!((800..1200).contains(&n), "got {n}");
    }

    #[test]
    fn arrivals_sorted_with_deadlines() {
        let reqs = LoadGen::simple(ArrivalPattern::Poisson { rate_rps: 50.0 }, 5_000.0, 300.0, 7)
            .generate();
        assert!(!reqs.is_empty());
        for w in reqs.windows(2) {
            assert!(w[0].arrival_ms <= w[1].arrival_ms);
            assert!(w[0].id < w[1].id);
        }
        for r in &reqs {
            assert!((r.deadline_ms - r.arrival_ms - 300.0).abs() < 1e-9);
            assert!(r.arrival_ms < 5_000.0);
        }
    }

    #[test]
    fn deterministic_per_seed() {
        let mk = || {
            LoadGen::simple(ArrivalPattern::Bursty {
                base_rps: 5.0,
                burst_rps: 50.0,
                mean_burst_ms: 400.0,
                mean_calm_ms: 1600.0,
            }, 20_000.0, 500.0, 42)
            .generate()
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.len(), b.len());
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.arrival_ms, y.arrival_ms);
            assert_eq!(x.seed, y.seed);
        }
    }

    #[test]
    fn bursty_mean_rate_near_nominal() {
        let p = ArrivalPattern::Bursty {
            base_rps: 5.0,
            burst_rps: 45.0,
            mean_burst_ms: 500.0,
            mean_calm_ms: 1500.0,
        };
        // mean = (5*1500 + 45*500) / 2000 = 15 rps
        assert!((p.mean_rps() - 15.0).abs() < 1e-9);
        let n = count_of(p, 100_000.0, 3);
        let measured = n as f64 / 100.0;
        assert!((measured - 15.0).abs() < 4.0, "measured {measured} rps");
    }

    #[test]
    fn bursty_is_burstier_than_poisson() {
        // dispersion of per-second counts: MMPP must exceed Poisson
        let disp = |pattern: ArrivalPattern| {
            let reqs = LoadGen::simple(pattern, 100_000.0, 500.0, 11).generate();
            let mut counts = vec![0.0f64; 100];
            for r in &reqs {
                counts[(r.arrival_ms / 1000.0) as usize % 100] += 1.0;
            }
            let mean = counts.iter().sum::<f64>() / 100.0;
            let var = counts.iter().map(|c| (c - mean) * (c - mean)).sum::<f64>() / 100.0;
            var / mean.max(1e-9)
        };
        let poisson = disp(ArrivalPattern::Poisson { rate_rps: 15.0 });
        let bursty = disp(ArrivalPattern::Bursty {
            base_rps: 5.0,
            burst_rps: 45.0,
            mean_burst_ms: 500.0,
            mean_calm_ms: 1500.0,
        });
        assert!(bursty > poisson * 1.5, "bursty {bursty:.2} vs poisson {poisson:.2}");
    }

    #[test]
    fn diurnal_peaks_mid_period() {
        let reqs = LoadGen::simple(
            ArrivalPattern::Diurnal { base_rps: 2.0, peak_rps: 40.0, period_s: 100.0 },
            100_000.0,
            500.0,
            5,
        )
        .generate();
        let mid = reqs.iter().filter(|r| (25_000.0..75_000.0).contains(&r.arrival_ms)).count();
        let edge = reqs.len() - mid;
        assert!(mid > 2 * edge, "mid {mid} vs edge {edge}");
    }

    #[test]
    fn mix_and_priority_assignment() {
        let mut lg = LoadGen::simple(ArrivalPattern::Poisson { rate_rps: 40.0 }, 30_000.0, 500.0, 9);
        lg.hi_frac = 0.3;
        lg.mix = vec![3.0, 1.0];
        let reqs = lg.generate();
        let hi = reqs.iter().filter(|r| r.class == 0).count() as f64 / reqs.len() as f64;
        let k0 = reqs.iter().filter(|r| r.key == 0).count() as f64 / reqs.len() as f64;
        assert!((hi - 0.3).abs() < 0.08, "hi frac {hi}");
        assert!((k0 - 0.75).abs() < 0.08, "key0 frac {k0}");
    }

    #[test]
    fn client_assignment_is_round_robin_and_off_by_default() {
        let mut lg = LoadGen::simple(ArrivalPattern::Poisson { rate_rps: 40.0 }, 5_000.0, 500.0, 9);
        let plain = lg.generate();
        assert!(plain.iter().all(|r| r.client == 0), "clients=0 must stay sessionless");
        lg.clients = 3;
        let streamed = lg.generate();
        assert_eq!(plain.len(), streamed.len());
        for (p, s) in plain.iter().zip(streamed.iter()) {
            // adding clients must not perturb the rest of the trace
            assert_eq!(p.arrival_ms, s.arrival_ms);
            assert_eq!(p.class, s.class);
            assert_eq!(p.key, s.key);
            assert_eq!(s.client, 1 + s.id % 3);
        }
    }

    #[test]
    fn scaled_scales_mean() {
        let p = ArrivalPattern::Poisson { rate_rps: 10.0 };
        assert!((p.scaled(1.7).mean_rps() - 17.0).abs() < 1e-12);
    }
}
