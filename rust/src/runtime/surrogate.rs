//! Deterministic host surrogate for the AOT PJRT executables.
//!
//! The vendored `xla` crate is a stub — it cannot compile or execute HLO —
//! so on machines without a real PJRT backend the functional pipeline used
//! to die at its first NN call. This module stands in for the executables
//! with small fixed-function networks whose weights are derived from a hash
//! of the artifact's (dataset, model, net) identity: fully deterministic
//! (same artifact + same input → bit-identical output, on any thread),
//! shape-correct per the manifest, and cheap enough that the host hot path
//! stays dominated by point ops.
//!
//! The dense layers themselves execute on [`super::gemm`]: pre-packed
//! weights fetched from the process-wide cache (generated once per
//! `(key, cin, cout)`, shared across scenes, threads, and precision
//! variants) and blocked lane/tile kernels with row-tile parallelism. This
//! module only prepares activations (flattening, ball pooling), drives
//! calibration, and applies the per-net output structure (head scales,
//! output QDQ, seg softmax).
//!
//! # INT8 execution
//!
//! Precision variants of an artifact share the same underlying weights —
//! they are the *same trained network* at different numerics. An INT8
//! artifact executes a genuine quantized path, not the fp path with a
//! renamed artifact:
//!
//! 1. activations are calibrated per input-channel group (the stage's
//!    [`QuantSpec`] granularity) and quantized to real `i8` codes
//!    ([`QTensor`], bit-consistent with the `ActQuant` QDQ reference);
//! 2. the matmul runs in integer arithmetic — `i8 × i8` products
//!    accumulated in wide integers per channel group, with the zero-point
//!    correction folded in as an integer weight-sum term;
//! 3. the accumulator is dequantized through the group scales, and the
//!    stage's *output* activations are quantized at the spec's granularity
//!    over its output channels — which is exactly where the paper's
//!    role-based partition preserves the heads' tiny xyz offsets while
//!    layer-wise scales crush them (Table 7/11).
//!
//! # Fused batched execution
//!
//! [`run_batch_with_spec`] executes one artifact over k scenes' inputs as a
//! single `(k·n, cin)` GEMM — one weight fetch, one kernel sweep, one
//! calibration — instead of k separate runs. On the fp32 path each row's
//! arithmetic is independent, so batched output is bit-identical to k
//! sequential runs. On the int8 path activation calibration observes the
//! *joint* batch (exactly what a real batched int8 runtime does), so codes
//! can differ from per-scene calibration by quantization error; a batch of
//! one is bit-identical to the sequential path by construction — the
//! single-scene entry points delegate here with k = 1.
//!
//! This is a *reference executor*, not the trained model: detections are
//! internally consistent (stable across runs, usable for determinism tests,
//! scheduling studies, and serving experiments) but their accuracy is
//! meaningful only relative to other surrogate configurations. Swapping
//! `rust/Cargo.toml` to a real `xla-rs` build restores execution of the
//! exported artifacts; the surrogate then never runs.

use std::cell::RefCell;

use anyhow::{anyhow, Result};

use super::gemm;
use super::manifest::{ArtifactMeta, Manifest};
use crate::quant::{QTensor, QuantSpec};
use crate::util::tensor::Tensor;

/// Weight key shared by every precision variant of a network: the artifact
/// name *minus* the precision suffix, so `vote_fp32` and `vote_int8_role`
/// execute the same weights and differ only by quantization error.
fn weight_key(meta: &ArtifactMeta) -> u64 {
    gemm::hash_str(&format!("{}_{}_{}", meta.dataset, meta.model, meta.net))
}

thread_local! {
    /// Per-thread scratch for activation codes: the int8 hot path
    /// re-quantizes into the same buffer every call instead of allocating
    /// a fresh `QTensor` per stage ([`QTensor::quantize_into`]).
    static QSCRATCH: RefCell<QTensor> = RefCell::new(QTensor::empty());
}

/// Deterministic fp32 dense layer on a flat `(n * cin)` activation slice:
/// rows -> tanh(rows @ W * scale + b), on the packed lane kernel.
fn dense(data: &[f32], cin: usize, cout: usize, key: u64, threads: usize) -> Result<Tensor> {
    let cin = cin.max(1);
    if data.len() % cin != 0 {
        return Err(anyhow!(
            "surrogate dense: activation length {} is not a multiple of cin {cin}",
            data.len()
        ));
    }
    let n = data.len() / cin;
    let pw = gemm::packed(key, cin, cout);
    let mut out = vec![0.0f32; n * cout];
    gemm::dense_fp32(&pw, data, &mut out, threads);
    Ok(Tensor::new(vec![n, cout], out))
}

/// Genuine INT8 dense layer: quantize → integer matmul → dequantize.
///
/// Activations are calibrated over the batch at the spec's granularity on
/// the *input* channels (a `Role` spec derives the partition from the
/// observed ranges — the calibration pass), weights come pre-quantized from
/// the packed cache (symmetric per-output-channel `i8`, the exact codes the
/// pre-PR path computed per call). Within a channel group the scale and
/// zero point are shared, so the matmul factors into pure integer dot
/// products plus an integer zero-point correction; the weight-sum terms are
/// recomputed per call because a `Role` partition is data-dependent.
fn dense_q(
    data: &[f32],
    cin: usize,
    cout: usize,
    key: u64,
    spec: &QuantSpec,
    threads: usize,
) -> Result<Tensor> {
    let cin = cin.max(1);
    if data.len() % cin != 0 {
        return Err(anyhow!(
            "surrogate dense_q: activation length {} is not a multiple of cin {cin}",
            data.len()
        ));
    }
    let n = data.len() / cin;
    let pw = gemm::packed(key, cin, cout);

    // dynamic activation calibration over the batch, grouped per the spec's
    // granularity applied to the input channels
    let flat = Tensor::new(vec![n, cin], data.to_vec());
    let in_spec = QuantSpec::new(spec.precision, cin, Vec::new());
    let (lo, hi) = crate::quant::channel_minmax(&flat);
    let groups = in_spec.groups_for(&lo, &hi);
    let act = crate::quant::ActQuant::calibrate(&lo, &hi, &groups);

    // per-(output, group) integer weight sums for the zero-point correction
    // (i64: a degenerate constant channel far from zero calibrates a huge
    // zero point — the f32->i64 cast saturates instead of overflowing)
    let ng = groups.len().max(1);
    let mut wsum = vec![0i64; cout * ng];
    for j in 0..cout {
        for (gi, g) in groups.iter().enumerate() {
            wsum[j * ng + gi] = g.iter().map(|&c| pw.wq[j * cin + c] as i64).sum();
        }
    }
    let gscale: Vec<f32> = groups.iter().map(|g| act.scale[g[0]]).collect();
    let gzero: Vec<i64> = groups.iter().map(|g| act.zero[g[0]] as i64).collect();
    let ctx = gemm::Int8Ctx::new(&groups, &gscale, &gzero, &wsum);

    let mut out = vec![0.0f32; n * cout];
    QSCRATCH.with(|q| -> Result<()> {
        let mut qx = q.borrow_mut();
        qx.quantize_into(&flat, &act)?;
        gemm::dense_int8(&pw, &ctx, &qx.data, &mut out, threads);
        Ok(())
    })?;
    Ok(Tensor::new(vec![n, cout], out))
}

/// Per-channel output magnitudes of the head networks — the heterogeneous
/// ranges of paper Fig. 6: tight center offsets and regression residuals
/// next to wide classification logits. This is the structure the role
/// partition exploits (and a single layer scale crushes, Table 7/11).
fn head_scales(manifest: &Manifest, net: &str, cout: usize) -> Option<Vec<f32>> {
    match net {
        "vote" => {
            // xyz vote offsets are small; feature residuals stay unit-scale
            let mut s = vec![1.0f32; cout];
            for v in s.iter_mut().take(3) {
                *v = 0.25;
            }
            Some(s)
        }
        "prop" => {
            let hl = manifest.head_layout;
            let mut s = vec![1.0f32; cout];
            let mut fill = |range: (usize, usize), v: f32| {
                for c in range.0..range.1.min(cout) {
                    s[c] = v;
                }
            };
            fill(hl.center, 0.25);
            fill(hl.objectness, 6.0);
            fill(hl.heading_cls, 6.0);
            fill(hl.heading_reg, 0.5);
            fill(hl.size_cls, 6.0);
            fill(hl.size_reg, 0.5);
            fill(hl.sem_cls, 6.0);
            Some(s)
        }
        _ => None,
    }
}

/// One dense stage at the spec's precision: fp32 or the quantized integer
/// path, optional per-channel output magnitudes, and (int8 only, `out_qdq`)
/// output-activation quantization over the stage's output-channel partition
/// (role groups for the heads).
fn forward(
    data: &[f32],
    cin: usize,
    cout: usize,
    key: u64,
    spec: &QuantSpec,
    scales: Option<&[f32]>,
    out_qdq: bool,
    threads: usize,
) -> Result<Tensor> {
    let mut t = if spec.precision.is_int8() {
        dense_q(data, cin, cout, key, spec, threads)?
    } else {
        dense(data, cin, cout, key, threads)?
    };
    if let Some(sc) = scales {
        for r in 0..t.rows() {
            for (v, s) in t.row_mut(r).iter_mut().zip(sc.iter()) {
                *v *= s;
            }
        }
    }
    if spec.precision.is_int8() && out_qdq {
        let act = spec.calibrate(&t);
        act.qdq(&mut t)?;
    }
    Ok(t)
}

/// Mean-pool the ball dimension of a (b, k, c) tensor into a flat (b * c)
/// row-major buffer.
fn pooled_flat(x: &Tensor) -> Vec<f32> {
    let (b, k, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let inv = 1.0 / k.max(1) as f32;
    let mut out = vec![0.0f32; b * c];
    for i in 0..b {
        let pool = &mut out[i * c..(i + 1) * c];
        let base = i * k * c;
        for kk in 0..k {
            for (p, v) in pool.iter_mut().zip(x.data[base + kk * c..base + (kk + 1) * c].iter()) {
                *p += v;
            }
        }
        for p in pool.iter_mut() {
            *p *= inv;
        }
    }
    out
}

/// `(rows, cin, cout)` of the dense layer an artifact executes, derived
/// from the manifest contract alone (no activation tensor needed). This is
/// the shape the workload accounting
/// ([`crate::coordinator::arch::nn_workload_of`]) and verifier rule S007
/// price the packed-weight + activation footprint from.
pub fn layer_dims(m: &Manifest, meta: &ArtifactMeta) -> Result<(usize, usize, usize)> {
    let s = meta
        .input_shapes
        .first()
        .ok_or_else(|| anyhow!("surrogate '{}': no declared input shape", meta.name))?;
    let dim = |i: usize| -> Result<usize> {
        s.get(i).copied().ok_or_else(|| {
            anyhow!("surrogate '{}': input rank {} has no dim {i}", meta.name, s.len())
        })
    };
    match meta.net.as_str() {
        "seg" => Ok((dim(0)? * dim(1)?, dim(2)?, m.num_seg_classes)),
        "fp_fc" => Ok((dim(0)?, dim(1)?, m.seed_feat)),
        "vote" => Ok((dim(0)?, dim(1)?, 3 + m.seed_feat)),
        "prop" => Ok((dim(0)?, dim(2)?, m.head_layout.sem_cls.1)),
        net if net.starts_with("sa") => {
            let level: usize = net[2..3]
                .parse()
                .map_err(|_| anyhow!("surrogate: bad SA net name '{net}'"))?;
            let sac = m
                .sa_configs
                .get(level - 1)
                .ok_or_else(|| anyhow!("surrogate: SA level {level} out of range"))?;
            let cout = *sac
                .mlp
                .last()
                .ok_or_else(|| anyhow!("surrogate: SA level {level} has empty mlp"))?;
            Ok((dim(0)?, dim(2)?, cout))
        }
        other => Err(anyhow!("surrogate: unknown net role '{other}' ({})", meta.name)),
    }
}

/// Execute one artifact over a batch of k scenes' (first) inputs as a
/// single fused GEMM. Returns one output tensor per scene, in order. See
/// the module docs for the fp32-bitwise / int8-joint-calibration semantics;
/// the single-scene entry points are the k = 1 case of this function.
pub fn run_batch_with_spec(
    manifest: &Manifest,
    meta: &ArtifactMeta,
    inputs: &[&Tensor],
    spec: Option<&QuantSpec>,
    threads: usize,
) -> Result<Vec<Tensor>> {
    if inputs.is_empty() {
        return Err(anyhow!("surrogate '{}': empty batch", meta.name));
    }
    let spec = match spec {
        Some(s) => s.clone(),
        None => manifest.stage_quant(meta),
    };
    let key = weight_key(meta);
    let net = meta.net.as_str();

    // per-net layer plan: output width, head magnitudes, output QDQ
    let (cout, scales, out_qdq) = match net {
        // logits quantize on the int8 path; softmax renormalizes, so no
        // output QDQ after it
        "seg" => (manifest.num_seg_classes, None, false),
        "fp_fc" => (manifest.seed_feat, None, true),
        "vote" => {
            let cout = 3 + manifest.seed_feat;
            (cout, head_scales(manifest, "vote", cout), true)
        }
        "prop" => {
            let head_ch = manifest.head_layout.sem_cls.1;
            (head_ch, head_scales(manifest, "prop", head_ch), true)
        }
        n if n.starts_with("sa") => {
            let level: usize = n[2..3]
                .parse()
                .map_err(|_| anyhow!("surrogate: bad SA net name '{n}'"))?;
            let sac = manifest
                .sa_configs
                .get(level - 1)
                .ok_or_else(|| anyhow!("surrogate: SA level {level} out of range"))?;
            let cout = *sac
                .mlp
                .last()
                .ok_or_else(|| anyhow!("surrogate: SA level {level} has empty mlp"))?;
            (cout, None, true)
        }
        other => return Err(anyhow!("surrogate: unknown net role '{other}' ({})", meta.name)),
    };

    // pre: flatten each scene to `(rows, cin)` activations (ball-pooled for
    // the grouped nets), borrowing when no transform is needed
    let mut flats: Vec<std::borrow::Cow<'_, [f32]>> = Vec::with_capacity(inputs.len());
    let mut cin = 0usize;
    let mut rows = Vec::with_capacity(inputs.len());
    for x in inputs {
        let (flat, c): (std::borrow::Cow<'_, [f32]>, usize) = match net {
            "seg" => (std::borrow::Cow::Borrowed(&x.data[..]), x.shape[2]),
            "fp_fc" | "vote" => (std::borrow::Cow::Borrowed(&x.data[..]), x.shape[1]),
            // prop + sa*: (b, k, c) ball groups pool to (b, c)
            _ => (std::borrow::Cow::Owned(pooled_flat(x)), x.shape[2]),
        };
        if cin == 0 {
            cin = c.max(1);
        } else if c != cin {
            return Err(anyhow!(
                "surrogate '{}': batch mixes channel widths {cin} and {c}",
                meta.name
            ));
        }
        rows.push(flat.len() / cin);
        flats.push(flat);
    }
    let joined: std::borrow::Cow<'_, [f32]> = if flats.len() == 1 {
        flats.remove(0)
    } else {
        let mut all = Vec::with_capacity(flats.iter().map(|f| f.len()).sum());
        for f in &flats {
            all.extend_from_slice(f);
        }
        std::borrow::Cow::Owned(all)
    };

    let y = forward(&joined, cin, cout, key, &spec, scales.as_deref(), out_qdq, threads)?;

    // split the fused rows back into per-scene outputs + per-net post step
    let mut outs = Vec::with_capacity(inputs.len());
    let mut r0 = 0usize;
    for (x, &n) in inputs.iter().zip(rows.iter()) {
        let mut part = y.data[r0 * cout..(r0 + n) * cout].to_vec();
        r0 += n;
        if net == "seg" {
            let (h, w) = (x.shape[0], x.shape[1]);
            for p in 0..h * w {
                let row = &mut part[p * cout..(p + 1) * cout];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut s = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    s += *v;
                }
                for v in row.iter_mut() {
                    *v /= s;
                }
            }
            outs.push(Tensor::new(vec![h, w, cout], part));
        } else {
            outs.push(Tensor::new(vec![n, cout], part));
        }
    }
    Ok(outs)
}

/// Execute one artifact on the surrogate with an explicit per-stage quant
/// spec (`None` uses the manifest-declared spec for the artifact) and a
/// row-tile thread budget for the GEMM kernels. Output shapes follow the
/// manifest contract for the artifact's `net` role.
pub fn run_with_spec_t(
    manifest: &Manifest,
    meta: &ArtifactMeta,
    inputs: &[&Tensor],
    spec: Option<&QuantSpec>,
    threads: usize,
) -> Result<Vec<Tensor>> {
    let x = inputs
        .first()
        .ok_or_else(|| anyhow!("surrogate '{}': no input", meta.name))?;
    run_batch_with_spec(manifest, meta, &[x], spec, threads)
}

/// [`run_with_spec_t`] at a single-thread GEMM budget.
pub fn run_with_spec(
    manifest: &Manifest,
    meta: &ArtifactMeta,
    inputs: &[&Tensor],
    spec: Option<&QuantSpec>,
) -> Result<Vec<Tensor>> {
    run_with_spec_t(manifest, meta, inputs, spec, 1)
}

/// Execute one artifact at its manifest-declared quant spec.
pub fn run(manifest: &Manifest, meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    run_with_spec(manifest, meta, inputs, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Granularity, StagePrecision};
    use crate::util::prop::{check, PropConfig};
    use crate::util::rng::Rng;

    fn manifest() -> Manifest {
        Manifest::synthetic()
    }

    fn probe(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(
            shape.to_vec(),
            (0..n).map(|i| (0.1 + 0.001 * i as f64).sin() as f32).collect(),
        )
    }

    /// The int8 dense path exactly as it existed before the packed-GEMM
    /// layer: weights re-derived and re-quantized per call, per-element
    /// `i64` accumulation, `QTensor::quantize` allocating fresh codes. The
    /// live path must stay **bit-identical** to this.
    fn dense_q_pre_pr(
        data: &[f32],
        cin: usize,
        cout: usize,
        key: u64,
        spec: &QuantSpec,
    ) -> Result<Tensor> {
        let cin = cin.max(1);
        let n = data.len() / cin;
        let mut wq: Vec<i8> = Vec::with_capacity(cout * cin);
        let mut sw = Vec::with_capacity(cout);
        for j in 0..cout {
            let wrow: Vec<f32> =
                (0..cin).map(|c| gemm::weight(key, j as u64, c as u64)).collect();
            let amax = wrow.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let s = (amax / 127.0).max(1e-12);
            sw.push(s);
            wq.extend(wrow.iter().map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8));
        }
        let bias = gemm::bias_vec(key, cout);

        let flat = Tensor::new(vec![n, cin], data.to_vec());
        let in_spec = QuantSpec::new(spec.precision, cin, Vec::new());
        let (lo, hi) = crate::quant::channel_minmax(&flat);
        let groups = in_spec.groups_for(&lo, &hi);
        let act = crate::quant::ActQuant::calibrate(&lo, &hi, &groups);
        let qx = QTensor::quantize(&flat, &act)?;

        let ng = groups.len().max(1);
        let mut wsum = vec![0i64; cout * ng];
        for j in 0..cout {
            for (gi, g) in groups.iter().enumerate() {
                wsum[j * ng + gi] = g.iter().map(|&c| wq[j * cin + c] as i64).sum();
            }
        }
        let gscale: Vec<f32> = groups.iter().map(|g| act.scale[g[0]]).collect();
        let gzero: Vec<i64> = groups.iter().map(|g| act.zero[g[0]] as i64).collect();

        let scale = 1.0 / (cin.max(1) as f32).sqrt();
        let mut out = Vec::with_capacity(n * cout);
        for r in 0..n {
            let x = &qx.data[r * cin..(r + 1) * cin];
            for j in 0..cout {
                let wrow = &wq[j * cin..(j + 1) * cin];
                let mut acc = 0.0f32;
                for (gi, g) in groups.iter().enumerate() {
                    let mut dot = 0i64;
                    for &c in g {
                        dot += wrow[c] as i64 * x[c] as i64;
                    }
                    acc += gscale[gi] * (dot - gzero[gi] * wsum[j * ng + gi]) as f32;
                }
                out.push((sw[j] * acc * scale + bias[j]).tanh());
            }
        }
        Ok(Tensor::new(vec![n, cout], out))
    }

    #[test]
    fn deterministic_and_shape_correct() {
        let m = manifest();
        for name in [
            "synrgbd_seg_fp32",
            "synrgbd_seg_int8",
            "synrgbd_pointsplit_sa1_half_int8",
            "synrgbd_pointsplit_sa4_full_int8",
            "synrgbd_pointsplit_fp_fc_int8",
            "synrgbd_pointsplit_vote_int8_role",
            "synrgbd_pointsplit_prop_int8_role",
            "synrgbd_pointsplit_prop_int8_layer",
        ] {
            let meta = m.artifact(name).expect(name).clone();
            let x = probe(&meta.input_shapes[0]);
            let a = run(&m, &meta, &[&x]).expect(name);
            let b = run(&m, &meta, &[&x]).expect(name);
            assert_eq!(a.len(), 1);
            assert_eq!(a[0], b[0], "{name} must be deterministic");
            assert!(a[0].data.iter().all(|v| v.is_finite()), "{name} non-finite");
        }
    }

    #[test]
    fn int8_path_bit_identical_to_pre_pr_reference() {
        // the packed weights, tiled kernel, scratch quantization, and
        // row-tile parallelism must not move a single int8 output bit
        let m = manifest();
        for name in [
            "synrgbd_seg_int8",
            "synrgbd_pointsplit_sa1_half_int8",
            "synrgbd_pointsplit_fp_fc_int8",
            "synrgbd_pointsplit_vote_int8_role",
            "synrgbd_pointsplit_prop_int8_role",
            "synrgbd_pointsplit_prop_int8_layer",
        ] {
            let meta = m.artifact(name).expect(name).clone();
            let spec = m.stage_quant(&meta);
            let x = probe(&meta.input_shapes[0]);
            let (flat, cin): (Vec<f32>, usize) = match meta.net.as_str() {
                "seg" => (x.data.clone(), x.shape[2]),
                "fp_fc" | "vote" => (x.data.clone(), x.shape[1]),
                _ => (pooled_flat(&x), x.shape[2]),
            };
            let (_, _, cout) = layer_dims(&m, &meta).expect(name);
            let key = weight_key(&meta);
            let old = dense_q_pre_pr(&flat, cin, cout, key, &spec).expect(name);
            for threads in [1usize, 4] {
                let new = dense_q(&flat, cin, cout, key, &spec, threads).expect(name);
                assert_eq!(old, new, "{name} int8 output moved (threads={threads})");
            }
        }
    }

    #[test]
    fn dense_rejects_partial_trailing_row() {
        // 10 values at cin=4 is 2.5 rows: the pre-PR chunks_exact silently
        // dropped the trailing half row; now it is a shape error
        let key = gemm::hash_str("partial-row-regression");
        let data = vec![0.5f32; 10];
        assert!(dense(&data, 4, 3, key, 1).is_err());
        let spec = QuantSpec::new(StagePrecision::Int8(Granularity::Layer), 3, Vec::new());
        assert!(dense_q(&data, 4, 3, key, &spec, 1).is_err());
        // exact multiples still pass
        assert!(dense(&data[..8], 4, 3, key, 1).is_ok());
        assert!(dense_q(&data[..8], 4, 3, key, &spec, 1).is_ok());
    }

    #[test]
    fn dense_q_tracks_dense_within_qdq_bound() {
        // per-element: |yq - yf| <= Lipschitz(tanh)=1 times the layer-scaled
        // sum of activation rounding (act.scale/2 per channel, exact zero
        // point) and weight rounding (sw/2 per element); small slack for
        // f32 accumulation order
        check("dense_q within qdq bound of dense", PropConfig { cases: 32, seed: 0xD0_5E }, |rng, size| {
            let cin = 2 + size % 24;
            let cout = 1 + size % 9;
            let n = 2 + size % 12;
            let key = rng.next_u64();
            let data: Vec<f32> = (0..n * cin).map(|_| rng.f32() * 4.0 - 2.0).collect();
            let precision = match size % 4 {
                0 => StagePrecision::Int8(Granularity::Layer),
                1 => StagePrecision::Int8(Granularity::Channel),
                2 => StagePrecision::Int8(Granularity::Group(1 + size % 5)),
                _ => StagePrecision::Int8(Granularity::Role),
            };
            let spec = QuantSpec::new(precision, cout, Vec::new());
            let yf = dense(&data, cin, cout, key, 1).map_err(|e| e.to_string())?;
            let yq = dense_q(&data, cin, cout, key, &spec, 1).map_err(|e| e.to_string())?;

            // replicate the calibration dense_q performs to price the bound
            let flat = Tensor::new(vec![n, cin], data.clone());
            let in_spec = QuantSpec::new(spec.precision, cin, Vec::new());
            let (lo, hi) = crate::quant::channel_minmax(&flat);
            let groups = in_spec.groups_for(&lo, &hi);
            let act = crate::quant::ActQuant::calibrate(&lo, &hi, &groups);
            let pw = gemm::packed(key, cin, cout);
            let lscale = 1.0 / (cin as f32).sqrt();

            for r in 0..n {
                let x = &data[r * cin..(r + 1) * cin];
                for j in 0..cout {
                    let mut bound = 0.0f64;
                    for c in 0..cin {
                        let w = gemm::weight(key, j as u64, c as u64).abs() as f64;
                        let ea = (act.scale[c] / 2.0) as f64;
                        let ew = (pw.sw[j] / 2.0) as f64;
                        bound += (w + ew) * ea + ew * x[c].abs() as f64;
                    }
                    bound = bound * lscale as f64 * 1.5 + 1e-4;
                    let d = (yq.row(r)[j] - yf.row(r)[j]).abs() as f64;
                    if d > bound {
                        return Err(format!(
                            "row {r} ch {j}: |yq-yf|={d} past bound {bound} \
                             (cin={cin} cout={cout} {precision:?})"
                        ));
                    }
                }
            }
            Ok(())
        });
    }

    #[test]
    fn batched_fp32_is_bitwise_equal_to_sequential() {
        let m = manifest();
        let meta = m.artifact("synrgbd_pointsplit_vote_fp32").expect("vote fp32").clone();
        let xs: Vec<Tensor> = (0..3)
            .map(|i| {
                let mut t = probe(&meta.input_shapes[0]);
                for v in t.data.iter_mut() {
                    *v += 0.01 * i as f32;
                }
                t
            })
            .collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let fused = run_batch_with_spec(&m, &meta, &refs, None, 2).expect("fused");
        for (x, y) in xs.iter().zip(fused.iter()) {
            let solo = run(&m, &meta, &[x]).expect("solo").remove(0);
            assert_eq!(&solo, y, "fp32 fused rows must match sequential bitwise");
        }
    }

    #[test]
    fn batched_int8_calibrates_jointly_and_stays_close() {
        let m = manifest();
        let meta = m.artifact("synrgbd_pointsplit_vote_int8_role").expect("vote role").clone();
        let xs: Vec<Tensor> = (0..4)
            .map(|i| {
                let mut t = probe(&meta.input_shapes[0]);
                for v in t.data.iter_mut() {
                    *v *= 1.0 + 0.05 * i as f32;
                }
                t
            })
            .collect();
        let refs: Vec<&Tensor> = xs.iter().collect();
        let fused = run_batch_with_spec(&m, &meta, &refs, None, 2).expect("fused");
        let fused2 = run_batch_with_spec(&m, &meta, &refs, None, 1).expect("fused2");
        assert_eq!(fused, fused2, "batched int8 must be thread-count invariant");
        for (x, y) in xs.iter().zip(fused.iter()) {
            let solo = run(&m, &meta, &[x]).expect("solo").remove(0);
            assert_eq!(solo.shape, y.shape);
            let mut err = 0.0f64;
            let mut mag = 0.0f64;
            for (a, b) in solo.data.iter().zip(y.data.iter()) {
                err += ((a - b) as f64).powi(2);
                mag += (*a as f64).powi(2);
            }
            assert!(
                err / mag.max(1e-12) < 0.05,
                "joint calibration drifted too far: rel err {}",
                err / mag
            );
        }
    }

    #[test]
    fn layer_dims_match_executed_shapes() {
        let m = manifest();
        for name in [
            "synrgbd_seg_int8",
            "synrgbd_pointsplit_sa1_half_int8",
            "synrgbd_pointsplit_sa4_full_int8",
            "synrgbd_pointsplit_fp_fc_int8",
            "synrgbd_pointsplit_vote_int8_role",
            "synrgbd_pointsplit_prop_int8_role",
        ] {
            let meta = m.artifact(name).expect(name).clone();
            let (rows, cin, cout) = layer_dims(&m, &meta).expect(name);
            let x = probe(&meta.input_shapes[0]);
            let out = run(&m, &meta, &[&x]).expect(name).remove(0);
            assert_eq!(rows * cout, out.data.len(), "{name} rows*cout");
            let expect_cin = match meta.net.as_str() {
                "seg" => x.shape[2],
                "fp_fc" | "vote" => x.shape[1],
                _ => x.shape[2],
            };
            assert_eq!(cin, expect_cin, "{name} cin");
        }
    }

    #[test]
    fn seg_rows_are_distributions() {
        let m = manifest();
        let meta = m.artifact("synrgbd_seg_fp32").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let out = run(&m, &meta, &[&x]).unwrap().remove(0);
        assert_eq!(out.shape, vec![m.img_size, m.img_size, m.num_seg_classes]);
        for p in 0..m.img_size * m.img_size {
            let s: f32 = out.data[p * m.num_seg_classes..(p + 1) * m.num_seg_classes]
                .iter()
                .sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn int8_variants_share_weights_and_track_fp32() {
        // precision variants are the same network: the int8 output must be
        // a small perturbation of the fp32 output, not a different model
        let m = manifest();
        let fp = m.artifact("synrgbd_pointsplit_vote_fp32").unwrap().clone();
        let role = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap().clone();
        let x = probe(&fp.input_shapes[0]);
        let yf = run(&m, &fp, &[&x]).unwrap().remove(0);
        let yr = run(&m, &role, &[&x]).unwrap().remove(0);
        assert_ne!(yf, yr, "quantization must not be a no-op");
        let mut err = 0.0f64;
        let mut mag = 0.0f64;
        for (a, b) in yf.data.iter().zip(yr.data.iter()) {
            err += ((a - b) as f64).powi(2);
            mag += (*a as f64).powi(2);
        }
        assert!(
            err / mag.max(1e-12) < 0.05,
            "int8_role relative error {} should be small",
            err / mag
        );
    }

    #[test]
    fn role_preserves_small_channels_better_than_layer() {
        // the Table 11 mechanism, now on the execution path: vote channels
        // 0..3 are the xyz offsets; the role partition isolates them while
        // a single layer scale is set by the widest feature channels
        let m = manifest();
        let fp = m.artifact("synrgbd_pointsplit_vote_fp32").unwrap().clone();
        let role = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap().clone();
        let layer = m.artifact("synrgbd_pointsplit_vote_int8_layer").unwrap().clone();
        let x = probe(&fp.input_shapes[0]);
        let yf = run(&m, &fp, &[&x]).unwrap().remove(0);
        let yr = run(&m, &role, &[&x]).unwrap().remove(0);
        let yl = run(&m, &layer, &[&x]).unwrap().remove(0);
        let xyz_err = |y: &Tensor| {
            let mut e = 0.0f64;
            for r in 0..y.rows() {
                for c in 0..3 {
                    e += ((y.row(r)[c] - yf.row(r)[c]) as f64).powi(2);
                }
            }
            e
        };
        assert!(
            xyz_err(&yr) <= xyz_err(&yl),
            "role xyz error {} must not exceed layer {}",
            xyz_err(&yr),
            xyz_err(&yl)
        );
    }

    #[test]
    fn explicit_spec_overrides_manifest_default() {
        let m = manifest();
        let meta = m.artifact("synrgbd_pointsplit_sa1_full_int8").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let default = run(&m, &meta, &[&x]).unwrap().remove(0);
        let spec = m.stage_quant_for(&meta, StagePrecision::Int8(Granularity::Channel));
        let grouped = run_with_spec(&m, &meta, &[&x], Some(&spec)).unwrap().remove(0);
        assert_ne!(default, grouped, "granularity override must change the numerics");
    }

    #[test]
    fn sa_output_width_follows_mlp() {
        let m = manifest();
        let meta = m.artifact("synrgbd_pointsplit_sa2_half_int8").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let out = run(&m, &meta, &[&x]).unwrap().remove(0);
        assert_eq!(out.shape, vec![meta.input_shapes[0][0], *m.sa_configs[1].mlp.last().unwrap()]);
    }
}
