//! Per-worker scratch arena for the point-op hot path.
//!
//! Every distance kernel needs the same transient buffers — an SoA copy of
//! the cloud when the caller hands interleaved points, the rolling FPS
//! `min_d2` array, the packed uniform grid, and the ball-query candidate
//! list. Allocating them per call dominated the per-scene profile, so they
//! live in a [`ScratchArena`] owned by whichever thread runs the kernel:
//!
//! - each thread lazily checks an arena out of a global pool on first use
//!   (`with_arena`) and keeps it in thread-local storage;
//! - when the thread exits — scoped pool threads of `exec::DagExecutor` and
//!   `par_map` included — the TLS destructor returns the arena to the pool,
//!   so the *buffers* survive the threads and the steady-state per-scene
//!   path allocates nothing after warm-up;
//! - `serving::dispatch` workers call [`warm`] once at startup to pre-size
//!   their arena for the dataset's cloud size.
//!
//! Growth accounting: `with_arena` snapshots the arena's reserved bytes
//! around the closure and reports any increase to [`scratch_tracker`] (one
//! `metrics::MemTracker::alloc` event per growing call). The steady-state
//! test asserts `alloc_count()` is flat across scenes after warm-up.
//!
//! Re-entrancy: `with_arena` must not be nested on one thread (the arena is
//! behind a `RefCell`). Kernels uphold this by taking every buffer they need
//! from a single checkout; worker threads they spawn get their own arenas.

use std::cell::RefCell;
use std::sync::{Mutex, OnceLock, PoisonError};

use super::ballquery::GridStorage;
use super::soa::PointsSoA;
use crate::metrics::MemTracker;

/// Reusable scratch buffers for one kernel invocation.
#[derive(Debug, Default)]
pub struct ScratchArena {
    /// SoA conversion buffer for the primary cloud of an interleaved call.
    pub soa: PointsSoA,
    /// Second conversion buffer (interpolation has two clouds).
    pub soa2: PointsSoA,
    /// Rolling per-point min squared distance of the FPS scan.
    pub min_d2: Vec<f32>,
    /// Packed uniform grid (ball query and 3-NN interpolation).
    pub grid: GridStorage,
    /// In-radius candidate list of one ball-query center.
    pub hits: Vec<(f32, usize)>,
}

impl ScratchArena {
    /// Total heap bytes currently reserved by the arena's buffers.
    fn reserved_bytes(&self) -> u64 {
        self.soa.capacity_bytes()
            + self.soa2.capacity_bytes()
            + (self.min_d2.capacity() * std::mem::size_of::<f32>()) as u64
            + self.grid.capacity_bytes()
            + (self.hits.capacity() * std::mem::size_of::<(f32, usize)>()) as u64
    }

    /// Pre-size every buffer for an `n`-point cloud.
    fn reserve(&mut self, n: usize) {
        self.soa.reserve(n);
        self.soa2.reserve(n);
        let p = super::soa::padded_len(n);
        self.min_d2.reserve(p.saturating_sub(self.min_d2.len()));
        self.grid.reserve(n);
        self.hits.reserve(256usize.saturating_sub(self.hits.len()));
    }
}

/// Arenas parked by exited threads, awaiting reuse.
static POOL: Mutex<Vec<Box<ScratchArena>>> = Mutex::new(Vec::new());

/// Tracker fed by `with_arena` growth deltas (shared across all workers).
static TRACKER: OnceLock<MemTracker> = OnceLock::new();

/// The allocation tracker behind the scratch arenas. `alloc_count()` going
/// flat across scenes is the zero-steady-state-allocation property.
pub fn scratch_tracker() -> &'static MemTracker {
    TRACKER.get_or_init(MemTracker::new)
}

/// TLS cell whose drop glue parks the arena back in the pool when the
/// owning thread (worker or scoped pool thread) exits.
struct TlsArena(Option<Box<ScratchArena>>);

impl Drop for TlsArena {
    fn drop(&mut self) {
        if let Some(a) = self.0.take() {
            POOL.lock().unwrap_or_else(PoisonError::into_inner).push(a);
        }
    }
}

thread_local! {
    static TLS: RefCell<TlsArena> = RefCell::new(TlsArena(None));
}

/// Run `f` with this thread's scratch arena, checking one out of the pool
/// (or creating it) on first use. Must not be nested on a single thread.
pub fn with_arena<R>(f: impl FnOnce(&mut ScratchArena) -> R) -> R {
    TLS.with(|cell| {
        let mut slot = cell.borrow_mut();
        let arena = slot.0.get_or_insert_with(|| {
            POOL.lock().unwrap_or_else(PoisonError::into_inner).pop().unwrap_or_default()
        });
        let before = arena.reserved_bytes();
        let r = f(arena);
        let after = arena.reserved_bytes();
        if after > before {
            scratch_tracker().alloc(after - before);
        }
        r
    })
}

/// Pre-size the calling thread's arena for `n`-point clouds (one warm-up
/// allocation burst instead of growth during the first request).
pub fn warm(n: usize) {
    with_arena(|a| a.reserve(n));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_reuse_stops_growing() {
        let pts: Vec<[f32; 3]> = (0..500).map(|i| [i as f32, 0.5, -1.0]).collect();
        with_arena(|a| a.soa.fill_from_points(&pts));
        let grown = with_arena(|a| {
            let before = a.reserved_bytes();
            a.soa.fill_from_points(&pts);
            a.reserved_bytes() > before
        });
        assert!(!grown, "refilling the same-size cloud must not grow the arena");
    }

    #[test]
    fn growth_is_reported_to_the_tracker() {
        let before = scratch_tracker().alloc_count();
        // a dedicated thread gets a fresh-or-pooled arena; growing it by an
        // outsized cloud must record at least one tracked allocation
        std::thread::spawn(|| warm(1 << 16)).join().expect("warm thread");
        let after = scratch_tracker().alloc_count();
        assert!(after > before, "arena growth must be recorded ({before} -> {after})");
    }

    #[test]
    fn exited_threads_park_arenas_in_the_pool() {
        // several sequential workers: each parks its arena on exit, so the
        // pool holds at least one even if concurrent tests check some out
        for _ in 0..4 {
            std::thread::spawn(|| with_arena(|_| ())).join().expect("worker");
        }
        let pooled = POOL.lock().unwrap_or_else(PoisonError::into_inner).len();
        assert!(pooled >= 1, "TLS drop must return arenas to the pool");
    }
}
