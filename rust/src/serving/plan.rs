//! Analytic service model: the per-scene stage DAG, timed without
//! functional execution by the calibrated [`ScheduleSim`].
//!
//! The dispatcher needs to know — *before* committing accelerator time —
//! what a batch will cost on each device. The planner obtains the stage
//! DAG from the **same** [`StageGraph`] constructor the pipeline executes
//! (it used to keep a hand-written mirror of `ScenePipeline::run`; that
//! mirror and its drift-bug class are gone), so its timelines match what
//! the pipeline itself would report *by construction* — pinned
//! stage-for-stage by `rust/tests/graph_equivalence.rs`. It needs no PJRT
//! artifacts: with [`Manifest::synthetic`] it runs anywhere.
//!
//! Batching model: the graph's **batch-fold(k)** pass — `k` compatible
//! scenes fold into one DAG with every stage's FLOPs/bytes scaled by `k`
//! while per-stage dispatch and transfer *setup* costs are paid once. That
//! is precisely where dynamic batching wins on this hardware — the
//! EdgeTPU's 20 ms per-transfer setup and the GPU's 14 ms per-dispatch
//! overhead amortize across the batch.
//!
//! Cost-cache keys are [`StageGraph::fingerprint`]s: whatever changes the
//! graph changes the key, and configurations differing only in quant
//! granularity never share an entry (pinned by
//! `quant_scheme_never_shares_cache`).

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::DetectorConfig;
use crate::graph::StageGraph;
use crate::runtime::Manifest;
use crate::sim::{ScheduleSim, StageSpec, Timeline};

// the cost summary is a pure Timeline reduction and lives with the
// simulator; re-exported here for the serving-facing API surface
pub use crate::sim::{cost_of, PlanCost};

/// Stage-graph planner with a fingerprint-keyed cost cache.
pub struct ServicePlanner {
    manifest: Manifest,
    sim: ScheduleSim,
    cache: RefCell<HashMap<(u64, usize), PlanCost>>,
}

impl ServicePlanner {
    pub fn new(manifest: Manifest) -> ServicePlanner {
        ServicePlanner { manifest, sim: ScheduleSim::new(), cache: RefCell::new(HashMap::new()) }
    }

    /// Planner over the synthetic manifest (no exported artifacts needed).
    pub fn synthetic() -> ServicePlanner {
        ServicePlanner::new(Manifest::synthetic())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// The calibrated device model the planner prices schedules with (the
    /// verifier runs its schedule rules against the same one).
    pub fn sim(&self) -> &ScheduleSim {
        &self.sim
    }

    /// The configuration's stage graph — the same object
    /// `ScenePipeline::run` lowers to execution.
    pub fn graph(
        &self,
        cfg: &DetectorConfig,
        num_points: usize,
        skip_seg: bool,
    ) -> Result<StageGraph> {
        StageGraph::build(&self.manifest, cfg, num_points, skip_seg)
    }

    /// The single-scene `StageSpec` sequence (lower-to-sim pass).
    pub fn stages(
        &self,
        cfg: &DetectorConfig,
        num_points: usize,
        skip_seg: bool,
    ) -> Result<Vec<StageSpec>> {
        Ok(self.graph(cfg, num_points, skip_seg)?.specs())
    }

    /// Simulated timeline of `batch` compatible scenes — for batch 1 this
    /// is identical, stage for stage, to what the pipeline reports.
    pub fn timeline(
        &self,
        cfg: &DetectorConfig,
        num_points: usize,
        batch: usize,
        skip_seg: bool,
    ) -> Result<Timeline> {
        let graph = self.graph(cfg, num_points, skip_seg)?;
        Ok(self.sim.run(&graph.batch_fold(batch)))
    }

    /// Simulated cost of running `batch` compatible scenes of `num_points`
    /// points under `cfg`. `skip_seg` models consecutive matching (2D scores
    /// reused from a previous frame — the degraded fast path). Costs are
    /// cached by ([`StageGraph::fingerprint`], batch).
    pub fn cost(
        &self,
        cfg: &DetectorConfig,
        num_points: usize,
        batch: usize,
        skip_seg: bool,
    ) -> Result<PlanCost> {
        let graph = self.graph(cfg, num_points, skip_seg)?;
        Ok(self.cost_of_graph(&graph, batch))
    }

    /// Cost of an already-built graph (callers holding a graph — e.g. a
    /// quant-rewrite result — skip the rebuild).
    pub fn cost_of_graph(&self, graph: &StageGraph, batch: usize) -> PlanCost {
        let key = (graph.fingerprint(), batch.max(1));
        if let Some(c) = self.cache.borrow().get(&key) {
            return *c;
        }
        let cost = cost_of(&self.sim.run(&graph.batch_fold(batch)));
        self.cache.borrow_mut().insert(key, cost);
        cost
    }

    /// Number of distinct (graph, batch) cost entries computed so far
    /// (cache observability for tests and reports).
    pub fn cache_len(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Steady-state service capacity (requests/sec) at a given batch size:
    /// the pipeline finishes `batch` requests every `bottleneck_ms`.
    pub fn capacity_rps(
        &self,
        cfg: &DetectorConfig,
        num_points: usize,
        batch: usize,
    ) -> Result<f64> {
        Ok(self.capacity_rps_of_graph(&self.graph(cfg, num_points, false)?, batch))
    }

    /// Capacity of an already-built graph (the one capacity formula —
    /// every report row goes through here or [`Self::capacity_rps`]).
    pub fn capacity_rps_of_graph(&self, graph: &StageGraph, batch: usize) -> f64 {
        let b = batch.max(1);
        b as f64 / self.cost_of_graph(graph, b).bottleneck_ms * 1000.0
    }

    /// Admission-weighted capacity of a multi-config gateway: the weighted
    /// *harmonic* mean of per-config capacities under the load mix — a unit
    /// of mixed traffic occupies `sum(w_i / cap_i)` bottleneck-seconds, so
    /// that is what the lane sustains, not config 0's rate.
    ///
    /// Weight folding mirrors admission exactly: with a single-entry mix
    /// every request carries key 0 (the load generator's gate), and keys
    /// beyond the config list clamp to the last config (the dispatcher's
    /// clamp), so the reported number matches what the lane actually serves.
    pub fn mixed_capacity_rps(
        &self,
        configs: &[DetectorConfig],
        num_points: usize,
        batch: usize,
        mix: &[f64],
    ) -> Result<f64> {
        assert!(!configs.is_empty(), "capacity of an empty config set");
        let mut weights = vec![0.0f64; configs.len()];
        if mix.len() > 1 {
            for (k, &m) in mix.iter().enumerate() {
                weights[k.min(configs.len() - 1)] += m.max(0.0);
            }
        }
        if weights.iter().sum::<f64>() <= 0.0 {
            weights[0] = 1.0;
        }
        let total: f64 = weights.iter().sum();
        let mut inv = 0.0f64;
        for (cfg, &w) in configs.iter().zip(&weights) {
            if w <= 0.0 {
                continue; // never admitted under this mix; cost is irrelevant
            }
            let cap = self.capacity_rps(cfg, num_points, batch)?;
            inv += (w / total) / cap.max(1e-9);
        }
        Ok(1.0 / inv.max(1e-12))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Schedule, Variant};
    use crate::quant::{Granularity, StagePrecision};
    use crate::sim::DeviceKind;

    fn planner() -> ServicePlanner {
        ServicePlanner::synthetic()
    }

    fn split_cfg() -> DetectorConfig {
        DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        )
    }

    #[test]
    fn plan_produces_connected_dag() {
        let p = planner();
        let stages = p.stages(&split_cfg(), 2048, false).unwrap();
        assert!(stages.len() > 15, "expected a full two-pipeline DAG, got {}", stages.len());
        for (i, s) in stages.iter().enumerate() {
            for &d in &s.deps {
                assert!(d < i, "stage {i} depends forward on {d}");
            }
        }
        assert!(stages.iter().any(|s| s.name == "seg"));
        assert!(stages.iter().any(|s| s.name == "decode"));
    }

    #[test]
    fn cost_is_cached_and_deterministic() {
        let p = planner();
        let a = p.cost(&split_cfg(), 2048, 2, false).unwrap();
        let b = p.cost(&split_cfg(), 2048, 2, false).unwrap();
        assert_eq!(a.total_ms, b.total_ms);
        assert!(a.total_ms > 0.0 && a.bottleneck_ms > 0.0);
        assert!(a.bottleneck_ms <= a.total_ms + 1e-9);
        assert_eq!(p.cache_len(), 1, "identical queries share one cache entry");
    }

    /// Regression (cache-key satellite): two configurations differing
    /// **only** in QuantScheme must never share a cached PlanCost — even
    /// when the difference (backbone granularity) is invisible to the
    /// device model.
    #[test]
    fn quant_scheme_never_shares_cache() {
        let p = planner();
        let a = split_cfg();
        let mut b = split_cfg();
        b.scheme.backbone = StagePrecision::Int8(Granularity::Group(4));
        assert_ne!(a.scheme, b.scheme);
        let ca = p.cost(&a, 2048, 1, false).unwrap();
        let cb = p.cost(&b, 2048, 1, false).unwrap();
        assert_eq!(
            p.cache_len(),
            2,
            "granularity-only config change must occupy its own cache entry"
        );
        // (their *values* may coincide — the device model does not price
        // granularity — but the entries must be distinct)
        let _ = (ca, cb);
        // and a head-granularity change as well
        let mut c = split_cfg();
        c.scheme = c.scheme.with_head(StagePrecision::Int8(Granularity::Channel));
        p.cost(&c, 2048, 1, false).unwrap();
        assert_eq!(p.cache_len(), 3);
    }

    /// Regression (fingerprint-completeness satellite): the decode
    /// thresholds and sampling-bias knobs change what the executor outputs
    /// without touching a single StageSpec — the cache key must still
    /// separate them, or one config's plan gets served for the other.
    #[test]
    fn executor_knobs_never_share_cache() {
        let p = planner();
        p.cost(&split_cfg(), 2048, 1, false).unwrap();
        let mut w = split_cfg();
        w.w0 = 3.0;
        p.cost(&w, 2048, 1, false).unwrap();
        let mut t = split_cfg();
        t.obj_thresh = 0.05;
        p.cost(&t, 2048, 1, false).unwrap();
        let mut n = split_cfg();
        n.nms_iou = 0.5;
        p.cost(&n, 2048, 1, false).unwrap();
        assert_eq!(p.cache_len(), 4, "each executor-visible knob needs its own cache entry");
    }

    #[test]
    fn batching_amortizes_overheads() {
        let p = planner();
        let one = p.cost(&split_cfg(), 2048, 1, false).unwrap();
        let four = p.cost(&split_cfg(), 2048, 4, false).unwrap();
        assert!(four.total_ms > one.total_ms, "bigger batch cannot be faster in latency");
        assert!(
            four.total_ms < 4.0 * one.total_ms * 0.9,
            "batch of 4 ({:.0} ms) should beat 4x single ({:.0} ms) by >10%",
            four.total_ms,
            4.0 * one.total_ms
        );
        // throughput must improve with batch size
        assert!(
            p.capacity_rps(&split_cfg(), 2048, 4).unwrap()
                > p.capacity_rps(&split_cfg(), 2048, 1).unwrap()
        );
    }

    #[test]
    fn skip_seg_is_faster_when_sequential() {
        // on the sequential schedule every stage sits on the critical path,
        // so dropping the 2D segmenter must strictly cut latency (in the
        // overlapped schedule it can hide behind the GPU lane)
        let p = planner();
        let mut cfg = split_cfg();
        cfg.schedule =
            Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
        let full = p.cost(&cfg, 2048, 1, false).unwrap();
        let skip = p.cost(&cfg, 2048, 1, true).unwrap();
        assert!(skip.total_ms < full.total_ms, "skipping 2D work must cut latency");
    }

    #[test]
    fn degraded_fast_path_is_faster() {
        // the SLO fast path = int8 + role heads + consecutive matching +
        // half point budget; it must beat the full path on latency AND on
        // the bottleneck (i.e. it raises capacity, not just responsiveness)
        let p = planner();
        let cfg = split_cfg();
        let fast_cfg = crate::serving::slo::degraded_config(&cfg);
        let fast_pts = crate::serving::slo::degraded_points(2048);
        for (batch, factor) in [(1usize, 0.9), (4, 0.8)] {
            // at batch 1 the serial NN tail (fixed dispatch + PCIe setup
            // costs) floors the gain; at batch 4 those amortize and the
            // halved GPU lane dominates
            let full = p.cost(&cfg, 2048, batch, false).unwrap();
            let fast = p.cost(&fast_cfg, fast_pts, batch, true).unwrap();
            assert!(
                fast.total_ms < factor * full.total_ms,
                "batch {batch}: fast {:.0} ms vs full {:.0} ms",
                fast.total_ms,
                full.total_ms
            );
            assert!(fast.bottleneck_ms < full.bottleneck_ms);
        }
    }

    #[test]
    fn fp32_single_device_slower_than_int8_split() {
        let p = planner();
        let fp32 = DetectorConfig::new(
            "synrgbd",
            Variant::PointPainting,
            false,
            Schedule::SingleDevice(DeviceKind::Gpu),
        );
        let slow = p.cost(&fp32, 2048, 1, false).unwrap();
        let fast = p.cost(&split_cfg(), 2048, 1, false).unwrap();
        assert!(
            slow.total_ms > 3.0 * fast.total_ms,
            "paper direction: fp32 GPU-only ({:.0} ms) >> int8 split ({:.0} ms)",
            slow.total_ms,
            fast.total_ms
        );
    }

    #[test]
    fn all_variants_plan_on_both_datasets() {
        let p = planner();
        for ds in ["synrgbd", "synscan"] {
            let n = p.manifest().datasets[ds].num_points;
            for v in
                [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit]
            {
                for int8 in [false, true] {
                    let cfg = DetectorConfig::new(
                        ds,
                        v,
                        int8,
                        Schedule::Pipelined {
                            point_dev: DeviceKind::Gpu,
                            nn_dev: DeviceKind::EdgeTpu,
                        },
                    );
                    let c = p.cost(&cfg, n, 1, false).unwrap();
                    assert!(c.total_ms > 0.0, "{ds}/{v:?}/int8={int8}");
                }
            }
        }
    }

    #[test]
    fn malformed_config_is_an_error_not_a_panic() {
        let p = planner();
        let mut cfg = split_cfg();
        cfg.dataset = "nosuch".to_string();
        assert!(p.cost(&cfg, 2048, 1, false).is_err());
        assert!(p.capacity_rps(&cfg, 2048, 4).is_err());
    }
}
