//! Paper Table 10: which SA layers get biased FPS in the SA-bias pipeline.
//! Expected shape: SA1-2 best (the trained configuration); biasing deeper
//! layers compounds the bias and hurts.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(40);
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let mut t = Table::new(&["biased layers", "mAP@0.25", "paper"]);
    for (layers, label, paper_map) in [
        (1usize, "SA1 only", 60.4),
        (2, "SA1 and SA2", 61.4),
        (3, "SA1, SA2 and SA3", 60.1),
        (4, "All SA layers", 60.8),
    ] {
        let mut cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, false, sched);
        cfg.bias_layers = layers;
        let rep = common::eval_config(&rt, &cfg, scenes);
        t.row(vec![
            label.to_string(),
            format!("{:.1}", rep.map_25 * 100.0),
            format!("{paper_map}"),
        ]);
        eprintln!("  [{label}] mAP {:.1}", rep.map_25 * 100.0);
    }
    t.print(&format!("Table 10 — biased FPS layer ablation on synrgbd ({scenes} scenes)"));
}
