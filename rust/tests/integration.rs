//! Integration tests over the full stack: artifacts -> PJRT runtime ->
//! coordinator pipelines -> evaluation.
//!
//! These tests require `make artifacts` to have run; they are skipped (with
//! a message) when artifacts/manifest.json is absent so `cargo test` stays
//! usable on a fresh checkout.

use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;
use pointsplit::util::tensor::Tensor;

fn runtime() -> Option<Runtime> {
    if !std::path::Path::new("artifacts/manifest.json").exists() {
        eprintln!("SKIP: artifacts not built (run `make artifacts`)");
        return None;
    }
    Some(Runtime::open("artifacts").expect("open runtime"))
}

#[test]
fn manifest_describes_all_files() {
    let Some(rt) = runtime() else { return };
    assert!(rt.manifest.artifacts.len() > 80, "expected a full artifact set");
    for a in &rt.manifest.artifacts {
        assert!(
            std::path::Path::new("artifacts").join(&a.file).exists(),
            "missing artifact file {}",
            a.file
        );
        assert!(a.flops > 0, "{} has no workload", a.name);
    }
    assert_eq!(rt.manifest.num_class(), 10);
    assert_eq!(rt.manifest.sa_configs.len(), 4);
}

#[test]
fn segmenter_executes_and_normalizes() {
    let Some(rt) = runtime() else { return };
    let scene = generate_scene(1, &SYNRGBD);
    let img = Tensor::new(vec![64, 64, 3], scene.image.clone());
    let out = rt.run("synrgbd_seg_fp32", &[&img]).expect("seg").remove(0);
    assert_eq!(out.shape, vec![64, 64, rt.manifest.num_seg_classes]);
    for p in 0..64 * 64 {
        let s: f32 = out.data[p * out.shape[2]..(p + 1) * out.shape[2]].iter().sum();
        assert!((s - 1.0).abs() < 1e-3, "softmax rows must normalize");
    }
}

#[test]
fn fixture_parity_rust_vs_jax() {
    let Some(rt) = runtime() else { return };
    let text = std::fs::read_to_string("artifacts/fixtures.json").expect("fixtures");
    let fixtures = pointsplit::util::json::Json::parse(&text).unwrap();
    for (name, fx) in fixtures.as_obj().unwrap() {
        let meta = rt.manifest.artifact(name).unwrap();
        let inputs: Vec<Tensor> = meta
            .input_shapes
            .iter()
            .map(|shape| {
                let n: usize = shape.iter().product();
                Tensor::new(
                    shape.clone(),
                    (0..n).map(|i| (0.1 + 0.001 * i as f64).sin() as f32).collect(),
                )
            })
            .collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let out = rt.run(name, &refs).expect("run")[0].clone();
        let expect = fx.req("first").f64_vec();
        let scale = fx.req("l1").as_f64().unwrap().max(1e-3);
        for (i, e) in expect.iter().enumerate() {
            let got = out.data[i] as f64;
            assert!(
                (got - e).abs() / scale < 1e-3,
                "{name}[{i}]: rust {got} vs jax {e}"
            );
        }
    }
}

#[test]
fn all_variants_produce_detections() {
    let Some(rt) = runtime() else { return };
    let scene = generate_scene(5, &SYNRGBD);
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    for variant in
        [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit]
    {
        for int8 in [false, true] {
            let cfg = DetectorConfig::new("synrgbd", variant, int8, sched);
            let pipe = ScenePipeline::new(&rt, cfg);
            let out = pipe.run(&scene, 5).expect("pipeline");
            assert!(!out.detections.is_empty(), "{variant:?} int8={int8}: no detections");
            assert!(out.timeline.total_ms > 0.0);
            for d in &out.detections {
                assert!(d.size.iter().all(|&s| s > 0.0));
                assert!(d.class < 10);
                assert!((0.0..=1.0).contains(&d.score));
            }
        }
    }
}

#[test]
fn pipeline_deterministic() {
    let Some(rt) = runtime() else { return };
    let scene = generate_scene(6, &SYNRGBD);
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let pipe = ScenePipeline::new(&rt, cfg);
    let a = pipe.run(&scene, 6).unwrap();
    let b = pipe.run(&scene, 6).unwrap();
    assert_eq!(a.detections.len(), b.detections.len());
    for (x, y) in a.detections.iter().zip(b.detections.iter()) {
        assert_eq!(x, y);
    }
    assert!((a.timeline.total_ms - b.timeline.total_ms).abs() < 1e-9);
}

#[test]
fn pointsplit_pipelined_faster_than_sequential() {
    let Some(rt) = runtime() else { return };
    let scene = generate_scene(7, &SYNRGBD);
    let mk = |sched| {
        let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, true, sched);
        ScenePipeline::new(&rt, cfg).run(&scene, 7).unwrap().timeline.total_ms
    };
    let seq = mk(Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu });
    let par = mk(Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu });
    assert!(par < seq * 0.9, "pipelined {par} must beat sequential {seq} by >10%");
}

#[test]
fn gpu_only_fp32_fusion_is_slowest() {
    let Some(rt) = runtime() else { return };
    let scene = generate_scene(8, &SYNRGBD);
    let gpu_only = {
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointPainting,
            false,
            Schedule::SingleDevice(DeviceKind::Gpu),
        );
        ScenePipeline::new(&rt, cfg).run(&scene, 8).unwrap().timeline.total_ms
    };
    let split = {
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        ScenePipeline::new(&rt, cfg).run(&scene, 8).unwrap().timeline.total_ms
    };
    // the paper's headline direction: heterogeneous INT8 PointSplit is
    // several times faster than the FP32 GPU-only fusion baseline
    assert!(
        gpu_only > 3.0 * split,
        "expected >3x speedup, got {:.1}x ({gpu_only:.0} vs {split:.0} ms)",
        gpu_only / split
    );
}

#[test]
fn int8_head_schemes_all_execute() {
    let Some(rt) = runtime() else { return };
    let scene = generate_scene(9, &SYNRGBD);
    for head in ["int8_layer", "int8_group", "int8_channel", "int8_role"] {
        let mut cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        cfg.set_head_precision(head).expect(head);
        let out = ScenePipeline::new(&rt, cfg).run(&scene, 9).expect(head);
        assert!(!out.detections.is_empty(), "{head}: no detections");
    }
}

#[test]
fn serve_loop_aggregates() {
    let Some(rt) = runtime() else { return };
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let rep =
        pointsplit::coordinator::serve::serve(&rt, &cfg, &SYNRGBD, 6, 2, 900_000).expect("serve");
    assert_eq!(rep.scenes, 6);
    assert!(rep.sim_latency_ms.mean > 0.0);
    assert!(rep.map_25 >= 0.0 && rep.map_25 <= 1.0);
    assert!(rep.map_50 <= rep.map_25 + 1e-9, "mAP@0.5 cannot exceed mAP@0.25");
}

#[test]
fn attn_variants_run() {
    let Some(rt) = runtime() else { return };
    use pointsplit::coordinator::attn::{run_attn, AttnVariant};
    let scene = generate_scene(10, &SYNRGBD);
    let mut total = 0;
    for v in [
        AttnVariant::Baseline,
        AttnVariant::Painted,
        AttnVariant::RandomSplit,
        AttnVariant::Split,
    ] {
        let dets = run_attn(&rt, v, &scene, 2.0, 10).expect("attn");
        for d in &dets {
            assert!(d.class < 10 && d.size.iter().all(|&s| s > 0.0));
        }
        total += dets.len();
    }
    // individual variants may be under-confident on a single scene (the
    // attention heads train briefly); collectively they must detect
    assert!(total > 0, "no attn variant produced any detection");
}
