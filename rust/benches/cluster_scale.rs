//! Cluster scaling sweep: goodput, SLO attainment, and cost as the fleet
//! grows, plus the config-affinity vs random routing comparison at equal
//! offered load (the whole point of affinity: same-config traffic lands on
//! the same boxes, so per-box dynamic batchers still coalesce).
//!
//! Runs entirely on the simulated clock with the synthetic manifest.
//!
//! ```bash
//! cargo bench --bench cluster_scale
//! POINTSPLIT_BENCH_SCENES=120 cargo bench --bench cluster_scale   # longer windows
//! ```

#[allow(dead_code)]
mod common;

use pointsplit::bench::{write_bench_json, Table};
use pointsplit::cluster::{
    config_mix, plan_box, run_cluster, ClusterReport, ClusterScenario, ClusterSpec, RouterPolicy,
};
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::serving::{ArrivalPattern, BatchPolicy, LoadGen, ServicePlanner, SloPolicy};
use pointsplit::sim::DeviceKind;
use pointsplit::util::json::Json;

fn base_cfg() -> DetectorConfig {
    DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    )
}

/// Sum of per-box planned capacities for a spec (what run_cluster reports
/// as `capacity_rps`), computed up front so offered load can be set
/// relative to it.
fn fleet_capacity(
    planner: &ServicePlanner,
    spec: &ClusterSpec,
    configs: &[DetectorConfig],
    batch: &BatchPolicy,
    mix: &[f64],
) -> f64 {
    spec.boxes
        .iter()
        .map(|bt| {
            plan_box(planner, bt, configs, 2048, batch, mix)
                .expect("synthetic planner plans every box type")
                .capacity_rps
        })
        .sum()
}

#[allow(clippy::too_many_arguments)]
fn run_one(
    planner: &ServicePlanner,
    spec: ClusterSpec,
    configs: Vec<DetectorConfig>,
    rate_rps: f64,
    duration_s: f64,
    deadline_ms: f64,
    policy: SloPolicy,
    router: RouterPolicy,
) -> ClusterReport {
    let n = configs.len();
    let mut load = LoadGen::simple(
        ArrivalPattern::Poisson { rate_rps },
        duration_s * 1000.0,
        deadline_ms,
        4242,
    );
    load.mix = vec![1.0; n];
    let sc = ClusterScenario {
        name: format!("{}boxes-{}", spec.boxes.len(), router.name()),
        spec,
        configs,
        num_points: 2048,
        queue_capacity: 16,
        load,
        batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
        policy,
        router,
        router_seed: 4242,
        faults: Vec::new(),
        autoscale: None,
    };
    run_cluster(&sc, planner).expect("cluster run").report
}

fn report_row(spec_str: &str, r: &ClusterReport) -> Json {
    Json::obj(vec![
        ("spec", Json::Str(spec_str.to_string())),
        ("router", Json::Str(r.router.to_string())),
        ("boxes", Json::Num(r.boxes.len() as f64)),
        ("capacity_rps", Json::Num(r.capacity_rps)),
        ("offered_rps", Json::Num(r.offered_rps)),
        ("goodput_rps", Json::Num(r.goodput_rps)),
        ("slo_attainment", Json::Num(r.slo_attainment)),
        ("p99_ms", Json::Num(r.latency_ms.p99)),
        ("mean_batch", Json::Num(r.mean_batch)),
        ("routing_imbalance", Json::Num(r.routing_imbalance)),
        ("cost_units", Json::Num(r.cost_units)),
    ])
}

fn main() {
    let planner = ServicePlanner::synthetic();
    let configs = config_mix(&base_cfg(), 4);
    let batch = BatchPolicy { max_batch: 4, max_wait_ms: 25.0 };
    let mix = vec![1.0; configs.len()];
    // reuse the shared bench budget knob: here it scales the traffic window
    let duration_s = common::scene_budget(40) as f64;
    println!(
        "cluster_scale: 4 detector configs, batch 4, {duration_s:.0}s simulated windows, \
         affinity router width 2\n"
    );

    // ---- part 1: fleet scaling sweep at 0.8x offered load ----------------
    let specs = [
        "gpu+edgetpu",
        "gpu+edgetpu:2,gpu:1",
        "gpu+edgetpu:2,gpu:2,cpu+edgetpu:2",
        "gpu+edgetpu:4,gpu:2,cpu+edgetpu:2",
    ];
    let mut t = Table::new(&[
        "spec",
        "boxes",
        "capacity rps",
        "offered rps",
        "goodput rps",
        "SLO%",
        "p99 ms",
        "mean batch",
        "imbalance",
        "cost units",
    ]);
    let mut scale_rows: Vec<Json> = Vec::new();
    for spec_str in specs {
        let spec = ClusterSpec::parse(spec_str).expect("valid bench spec");
        let cap = fleet_capacity(&planner, &spec, &configs, &batch, &mix);
        let r = run_one(
            &planner,
            spec,
            configs.clone(),
            cap * 0.8,
            duration_s,
            1_000.0,
            SloPolicy::Degrade,
            RouterPolicy::ConfigAffinity,
        );
        t.row(vec![
            spec_str.to_string(),
            r.boxes.len().to_string(),
            format!("{:.1}", r.capacity_rps),
            format!("{:.1}", r.offered_rps),
            format!("{:.2}", r.goodput_rps),
            format!("{:.1}", 100.0 * r.slo_attainment),
            format!("{:.0}", r.latency_ms.p99),
            format!("{:.2}", r.mean_batch),
            format!("{:.2}", r.routing_imbalance),
            format!("{:.0}", r.cost_units),
        ]);
        scale_rows.push(report_row(spec_str, &r));
    }
    t.print("cluster scaling — affinity router, degrade policy, 0.8x offered load");
    println!();

    // ---- part 2: config-affinity vs random routing at equal load ---------
    // Identical fleet, identical arrival trace; only the router differs.
    // Affinity should batch better (same-config traffic coalesces on the
    // same boxes) and therefore carry more goodput.
    let spec_str = "gpu+edgetpu:6";
    let spec = ClusterSpec::parse(spec_str).expect("valid bench spec");
    let cap = fleet_capacity(&planner, &spec, &configs, &batch, &mix);
    let rate = cap * 0.9;
    let affinity = run_one(
        &planner,
        spec.clone(),
        configs.clone(),
        rate,
        (duration_s * 2.0).max(60.0),
        2_500.0,
        SloPolicy::None,
        RouterPolicy::ConfigAffinity,
    );
    let random = run_one(
        &planner,
        spec,
        configs.clone(),
        rate,
        (duration_s * 2.0).max(60.0),
        2_500.0,
        SloPolicy::None,
        RouterPolicy::Random,
    );
    let mut t = Table::new(&[
        "router",
        "offered rps",
        "goodput rps",
        "SLO%",
        "p99 ms",
        "mean batch",
        "imbalance",
    ]);
    for r in [&affinity, &random] {
        t.row(vec![
            r.router.to_string(),
            format!("{:.1}", r.offered_rps),
            format!("{:.2}", r.goodput_rps),
            format!("{:.1}", 100.0 * r.slo_attainment),
            format!("{:.0}", r.latency_ms.p99),
            format!("{:.2}", r.mean_batch),
            format!("{:.2}", r.routing_imbalance),
        ]);
    }
    t.print(&format!(
        "routing policy — {spec_str}, 0.9x offered load, identical arrival trace"
    ));
    let ok = affinity.mean_batch > random.mean_batch && affinity.goodput_rps > random.goodput_rps;
    println!(
        "affinity vs random: mean batch {:.2} vs {:.2}, goodput {:.2} vs {:.2} rps  [{}]",
        affinity.mean_batch,
        random.mean_batch,
        affinity.goodput_rps,
        random.goodput_rps,
        if ok { "OK: affinity wins" } else { "REGRESSION" }
    );

    let payload = Json::obj(vec![
        ("bench", Json::Str("cluster_scale".to_string())),
        ("duration_s", Json::Num(duration_s)),
        ("num_configs", Json::Num(configs.len() as f64)),
        ("scale", Json::Arr(scale_rows)),
        (
            "routing",
            Json::obj(vec![
                ("affinity", report_row(spec_str, &affinity)),
                ("random", report_row(spec_str, &random)),
                ("affinity_wins", Json::Bool(ok)),
            ]),
        ),
    ]);
    write_bench_json("BENCH_cluster.json", &payload);
}
