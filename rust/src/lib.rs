//! PointSplit: on-device 3D object detection with heterogeneous low-power
//! accelerators — Rust + JAX + Pallas reproduction (see DESIGN.md).
//!
//! Layer 3 (this crate) owns the request path: synthetic RGB-D scenes flow
//! through the coordinator's two-lane (GPU/NPU) schedule; dense networks
//! execute as AOT-compiled HLO via PJRT (`runtime`), point manipulation runs
//! in `pointops`, and a calibrated device model (`sim`) provides
//! paper-comparable timing.
//!
//! The detector's stage DAG is a first-class IR (`graph::StageGraph`),
//! built exactly once per configuration and consumed by passes: the
//! executor and the simulator lower the same graph (`coordinator`), the
//! serving planner batch-folds it (`serving::plan`), the SLO degrade
//! move's precision swap is a quant-rewrite over its nodes
//! (`serving::slo`; the fast path additionally halves the point budget
//! and reuses 2D scores), and a placement-search pass (`graph::place`)
//! picks device assignments under capability/memory constraints. See
//! `docs/ARCHITECTURE.md`.
//!
//! # Serving
//!
//! On top of the per-scene pipeline sits the open-loop traffic gateway
//! (`serving`): arrival generators (Poisson / bursty MMPP / diurnal), a
//! bounded admission queue with priority classes, a dynamic batcher that
//! coalesces compatible requests, and SLO-aware policies that degrade to the
//! INT8 fast path or shed doomed work under overload. The gateway runs on
//! **simulated time**: queueing and batching delay compose with the
//! calibrated `sim::ScheduleSim` device timeline, so overload behaviour
//! (p99 blow-up, goodput collapse, the win from degradation) reflects the
//! paper's GPU+EdgeTPU box rather than the build host. Entry points:
//! `serving::run_traffic` from code, `pointsplit serve-traffic` from the
//! CLI, and `benches/serving_overload.rs` for the load sweep. Architecture
//! notes live in `docs/SERVING.md`.
//!
//! # Cluster
//!
//! One box caps out at its `capacity_rps`; the `cluster` layer shards the
//! gateway across a fleet of heterogeneous edge boxes. A `ClusterSpec`
//! describes N boxes by device mix (GPU-only, GPU+EdgeTPU, CPU+EdgeTPU,
//! …), the placement search plans each box, and a config-affinity router
//! spreads traffic so per-box batchers still coalesce. Failure/straggler
//! injection and a reactive autoscaler complete the fleet model. Entry
//! points: `cluster::run_cluster` from code, `pointsplit serve-cluster`
//! from the CLI, and `benches/cluster_scale.rs` for the scaling sweep. See
//! `docs/CLUSTER.md`.
//!
//! # Verifier
//!
//! Every IR pass output can be checked statically (`verify`): graph
//! soundness, precision/capability flow, schedule resource fit, executor
//! slot-race freedom, and cluster-plan conservation, as structured
//! diagnostics with stable rule ids. Passes self-verify under
//! `debug_assertions`; `pointsplit verify` runs the full rule set from the
//! CLI. Rule catalog: `docs/VERIFIER.md`.

pub mod bench;
pub mod cluster;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod eval;
pub mod exec;
// the IR and its verifier stay panic-free: unwrap is denied outside tests
#[cfg_attr(not(test), deny(clippy::unwrap_used))]
pub mod graph;
pub mod metrics;
pub mod pointops;
pub mod quant;
// the NN execution layer (GEMM kernels, weight cache, surrogate) runs
// inside long-lived serving workers: unwrap is denied outside tests
#[cfg_attr(not(test), deny(clippy::unwrap_used))]
pub mod runtime;
pub mod serving;
pub mod sim;
pub mod temporal;
pub mod util;
#[cfg_attr(not(test), deny(clippy::unwrap_used))]
pub mod verify;
