//! Runtime metrics: counters, latency histograms, allocation tracking.
pub mod trace;
pub mod viz;


use std::collections::BTreeMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;

/// Log-bucketed latency histogram (microseconds).
#[derive(Debug, Default)]
pub struct LatencyHistogram {
    /// bucket i counts samples in [2^i, 2^(i+1)) us
    buckets: [AtomicU64; 32],
    count: AtomicU64,
    sum_us: AtomicU64,
}

impl LatencyHistogram {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn record_us(&self, us: u64) {
        let b = (64 - us.max(1).leading_zeros() as usize - 1).min(31);
        self.buckets[b].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn mean_us(&self) -> f64 {
        let c = self.count();
        if c == 0 {
            0.0
        } else {
            self.sum_us.load(Ordering::Relaxed) as f64 / c as f64
        }
    }

    /// Approximate quantile from bucket boundaries.
    pub fn quantile_us(&self, q: f64) -> u64 {
        let total = self.count();
        if total == 0 {
            return 0;
        }
        let target = (q * total as f64) as u64;
        let mut seen = 0;
        for (i, b) in self.buckets.iter().enumerate() {
            seen += b.load(Ordering::Relaxed);
            if seen > target {
                return 1u64 << (i + 1);
            }
        }
        1u64 << 31
    }
}

/// Named monotonically-increasing counters.
#[derive(Debug, Default)]
pub struct Counters {
    inner: Mutex<BTreeMap<String, u64>>,
}

impl Counters {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&self, name: &str, v: u64) {
        *self.inner.lock().unwrap().entry(name.to_string()).or_insert(0) += v;
    }

    pub fn get(&self, name: &str) -> u64 {
        self.inner.lock().unwrap().get(name).copied().unwrap_or(0)
    }

    pub fn snapshot(&self) -> BTreeMap<String, u64> {
        self.inner.lock().unwrap().clone()
    }
}

/// Coarse allocation tracker for the Fig. 9 peak-memory accounting of
/// request-path buffers (framework bases are modeled in arch.rs).
///
/// Besides the byte accounting it counts discrete allocation *events*
/// (`alloc_count`), which is what the steady-state tests assert on: the
/// scratch-arena hot path reports every buffer growth here, so a flat count
/// across scenes proves the per-scene path stopped allocating after warm-up.
#[derive(Debug, Default)]
pub struct MemTracker {
    current: AtomicU64,
    peak: AtomicU64,
    allocs: AtomicU64,
}

impl MemTracker {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn alloc(&self, bytes: u64) {
        let cur = self.current.fetch_add(bytes, Ordering::Relaxed) + bytes;
        self.peak.fetch_max(cur, Ordering::Relaxed);
        self.allocs.fetch_add(1, Ordering::Relaxed);
    }

    pub fn free(&self, bytes: u64) {
        self.current.fetch_sub(bytes, Ordering::Relaxed);
    }

    pub fn peak_bytes(&self) -> u64 {
        self.peak.load(Ordering::Relaxed)
    }

    /// Number of allocation events recorded so far.
    pub fn alloc_count(&self) -> u64 {
        self.allocs.load(Ordering::Relaxed)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn histogram_quantiles_ordered() {
        let h = LatencyHistogram::new();
        for us in [10u64, 20, 30, 100, 1000, 5000] {
            h.record_us(us);
        }
        assert_eq!(h.count(), 6);
        assert!(h.quantile_us(0.5) <= h.quantile_us(0.99));
        assert!(h.mean_us() > 0.0);
    }

    #[test]
    fn counters_accumulate() {
        let c = Counters::new();
        c.add("scenes", 2);
        c.add("scenes", 3);
        assert_eq!(c.get("scenes"), 5);
    }

    #[test]
    fn mem_tracker_peak() {
        let m = MemTracker::new();
        m.alloc(100);
        m.alloc(200);
        m.free(150);
        m.alloc(50);
        assert_eq!(m.peak_bytes(), 300);
        assert_eq!(m.alloc_count(), 3, "three discrete allocation events");
    }
}
