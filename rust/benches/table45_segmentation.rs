//! Paper Tables 4/5: 2D semantic segmentation per-class mIoU on both
//! datasets (Deeplabv3+ in the paper; the encoder-decoder stand-in here).

mod common;

use pointsplit::bench::Table;
use pointsplit::data::{self, CLASS_NAMES, NUM_CLASS};
use pointsplit::eval::miou::ConfusionMiou;
use pointsplit::util::tensor::Tensor;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(48);
    for (ds_name, paper_overall) in [("synrgbd", 40.7), ("synscan", 47.8)] {
        let ds = data::dataset(ds_name).unwrap();
        let mut conf = ConfusionMiou::new(NUM_CLASS + 1);
        for seed in 0..scenes as u64 {
            let scene = data::generate_scene(700_000 + seed, ds);
            let img = Tensor::new(vec![64, 64, 3], scene.image.clone());
            let scores = rt.run(&format!("{ds_name}_seg_fp32"), &[&img]).unwrap().remove(0);
            // argmax prediction per pixel
            let c = scores.shape[2];
            let pred: Vec<u8> = (0..64 * 64)
                .map(|p| {
                    let row = &scores.data[p * c..(p + 1) * c];
                    let mut best = 0;
                    for (i, &v) in row.iter().enumerate() {
                        if v > row[best] {
                            best = i;
                        }
                    }
                    best as u8
                })
                .collect();
            conf.add(&scene.seg_mask, &pred);
        }
        let ious = conf.per_class_iou();
        let mut t = Table::new(&["class", "mIoU"]);
        for (i, name) in CLASS_NAMES.iter().enumerate() {
            t.row(vec![name.to_string(), common::ap_cell(ious[i + 1])]);
        }
        t.row(vec!["Overall".into(), format!("{:.1}", conf.miou_foreground() * 100.0)]);
        t.print(&format!(
            "Table {} — segmenter per-class mIoU on {ds_name} ({scenes} scenes; paper overall: {paper_overall})",
            if ds_name == "synrgbd" { "4" } else { "5" }
        ));
    }
}
