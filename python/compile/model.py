"""L2: VoteNet-mini + PointSplit variants + segmenter + attention head, in JAX.

Everything here is build-time only; the request path executes the HLO that
``aot.py`` lowers from these functions. The module provides:

- a small encoder-decoder **segmenter** (Deeplabv3+ stand-in, DESIGN.md §2),
- the **VoteNet-mini** detector: 4 SA layers (PointNet++), simplified FP
  (paper Table 1), voting and proposal modules with the paper's role-grouped
  head channels (Table 2),
- the three sampling **variants**: ``full`` (VoteNet / PointPainting),
  ``randsplit`` (ablation) and ``split`` (PointSplit: SA-normal + SA-bias
  with biased FPS, fused before SA4, Fig. 5),
- a **GroupFree3D-mini** attention head (Table 8),
- network-only subgraphs (`sa_pointnet_apply`, `vote_apply`, ...) that are
  exported as individual HLO artifacts — these receive *grouped* tensors so
  that all point manipulation stays outside (on the "GPU"/Rust side).

Parameters are nested dicts of jnp arrays; initialization is deterministic.
"""

from __future__ import annotations

import dataclasses
from typing import Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from . import common, sampling
from .common import (
    DEFAULT_BIAS_LAYERS,
    DEFAULT_W0,
    FEAT_DIM,
    FEAT_DIM_PLAIN,
    IMG_SIZE,
    NUM_CLASS,
    NUM_HEADING_BIN,
    NUM_PROPOSALS,
    NUM_SEEDS,
    NUM_SEG_CLASSES,
    PROPOSAL_CH,
    PROPOSAL_K,
    PROPOSAL_RADIUS,
    SA_CONFIGS,
    SEED_FEAT,
    VOTE_CH,
)
from .kernels.pointnet import pointnet_pallas
from .kernels.qmlp import qmlp_pallas
from .kernels.ref import mlp_ref, pointnet_ref, qmlp_ref

Params = Dict[str, object]


# ---------------------------------------------------------------------------
# Initialization helpers
# ---------------------------------------------------------------------------


def _dense_init(key, cin: int, cout: int, scale: float = 1.0):
    k1, _ = jax.random.split(key)
    w = jax.random.normal(k1, (cin, cout), jnp.float32) * scale * jnp.sqrt(2.0 / cin)
    return w, jnp.zeros((cout,), jnp.float32)


def _mlp_init(key, widths: Sequence[int]) -> List[Tuple[jnp.ndarray, jnp.ndarray]]:
    keys = jax.random.split(key, len(widths) - 1)
    return [_dense_init(k, widths[i], widths[i + 1]) for i, k in enumerate(keys)]


def _conv_init(key, cin: int, cout: int, ksize: int = 3):
    k1, _ = jax.random.split(key)
    fan_in = cin * ksize * ksize
    w = jax.random.normal(k1, (ksize, ksize, cin, cout), jnp.float32) * jnp.sqrt(2.0 / fan_in)
    return w, jnp.zeros((cout,), jnp.float32)


# ---------------------------------------------------------------------------
# Segmenter (2D semantic segmentation, Deeplabv3+ stand-in)
# ---------------------------------------------------------------------------

SEG_CHANNELS = [16, 32, 48, 64]


def segmenter_init(key) -> Params:
    ks = jax.random.split(key, 8)
    return {
        "enc1": _conv_init(ks[0], 3, SEG_CHANNELS[0]),
        "enc2": _conv_init(ks[1], SEG_CHANNELS[0], SEG_CHANNELS[1]),  # stride 2
        "enc3": _conv_init(ks[2], SEG_CHANNELS[1], SEG_CHANNELS[2]),  # stride 2
        "enc4": _conv_init(ks[3], SEG_CHANNELS[2], SEG_CHANNELS[3]),
        "dec1": _conv_init(ks[4], SEG_CHANNELS[3], SEG_CHANNELS[1]),
        "dec2": _conv_init(ks[5], SEG_CHANNELS[1] + SEG_CHANNELS[1], SEG_CHANNELS[0]),
        "out": _conv_init(ks[6], SEG_CHANNELS[0] + SEG_CHANNELS[0], NUM_SEG_CLASSES, 1),
    }


def _conv2d(x, wb, stride: int = 1):
    w, b = wb
    y = jax.lax.conv_general_dilated(
        x[None],
        w,
        window_strides=(stride, stride),
        padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )[0]
    return y + b


def _resize2x(x):
    return jnp.repeat(jnp.repeat(x, 2, axis=0), 2, axis=1)


def segmenter_forward(params: Params, img: jnp.ndarray) -> jnp.ndarray:
    """img (H, W, 3) -> logits (H, W, NUM_SEG_CLASSES)."""
    e1 = jax.nn.relu(_conv2d(img, params["enc1"]))  # 64
    e2 = jax.nn.relu(_conv2d(e1, params["enc2"], stride=2))  # 32
    e3 = jax.nn.relu(_conv2d(e2, params["enc3"], stride=2))  # 16
    e4 = jax.nn.relu(_conv2d(e3, params["enc4"]))  # 16
    d1 = jax.nn.relu(_conv2d(_resize2x(e4), params["dec1"]))  # 32
    d1 = jnp.concatenate([d1, e2], axis=-1)  # skip connection
    d2 = jax.nn.relu(_conv2d(_resize2x(d1), params["dec2"]))  # 64
    d2 = jnp.concatenate([d2, e1], axis=-1)
    return _conv2d(d2, params["out"])


def segmenter_scores(params: Params, img: jnp.ndarray) -> jnp.ndarray:
    return jax.nn.softmax(segmenter_forward(params, img), axis=-1)


# ---------------------------------------------------------------------------
# Detector parameters
# ---------------------------------------------------------------------------


def sa_widths(painted: bool) -> List[List[int]]:
    """Per-SA-layer MLP widths including the input width (rel-xyz + feats)."""
    feat_in = FEAT_DIM if painted else FEAT_DIM_PLAIN
    widths = []
    prev = feat_in
    for _, _, _, mlp in SA_CONFIGS:
        widths.append([3 + prev] + list(mlp))
        prev = mlp[-1]
    return widths


FP_IN = SA_CONFIGS[1][3][-1] + (SA_CONFIGS[2][3][-1] + SA_CONFIGS[3][3][-1])  # 128+(128+128)


def detector_init(key, painted: bool) -> Params:
    ks = jax.random.split(key, 12)
    widths = sa_widths(painted)
    params: Params = {}
    for i, w in enumerate(widths):
        params[f"sa{i + 1}"] = _mlp_init(ks[i], w)
    # simplified FP: one shared FC (paper Table 1)
    params["fp_fc"] = _dense_init(ks[4], FP_IN, SEED_FEAT)
    params["vote_mlp"] = _mlp_init(ks[5], [SEED_FEAT, 128, 128])
    params["vote_out"] = _dense_init(ks[6], 128, VOTE_CH, scale=0.5)
    params["prop_pointnet"] = _mlp_init(ks[7], [3 + SEED_FEAT, 128, 64])
    params["prop_mlp"] = _mlp_init(ks[8], [64, 64])
    params["prop_out"] = _dense_init(ks[9], 64, PROPOSAL_CH, scale=0.5)
    return params


# ---------------------------------------------------------------------------
# Quantization wrappers (QDQ). QConfig is produced by quantize.py.
# When a layer has no entry it runs in fp32.
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class QConfig:
    """Per-layer QDQ parameters (missing entry => fp32)."""

    weight_scales: Dict[str, jnp.ndarray]
    act_q: Dict[str, Tuple[jnp.ndarray, jnp.ndarray]]  # name -> (scale, zero)

    @staticmethod
    def empty() -> "QConfig":
        return QConfig({}, {})


def _maybe_qdq_weights(weights, name: str, qc: Optional[QConfig]):
    if qc is None:
        return weights
    out = []
    for i, (w, b) in enumerate(weights):
        key = f"{name}.{i}"
        if key in qc.weight_scales:
            s = qc.weight_scales[key]
            wq = jnp.clip(jnp.round(w / s[None, :]), -127, 127) * s[None, :]
            out.append((wq, b))
        else:
            out.append((w, b))
    return out


def _pointnet(groups, weights, use_pallas: bool):
    if use_pallas:
        return pointnet_pallas(groups, weights)
    return pointnet_ref(groups, weights)


def _head_layer(x, wb, name: str, qc: Optional[QConfig], use_pallas: bool):
    """Final head layer: fp32 matmul or fused QDQ kernel (group-wise quant)."""
    w, b = wb
    if qc is not None and name in qc.act_q:
        ws = qc.weight_scales[name + ".w"]
        a_scale, a_zero = qc.act_q[name]
        if use_pallas:
            return qmlp_pallas(x, w, b, ws, a_scale, a_zero)
        return qmlp_ref(x, w, b, ws, a_scale, a_zero)
    return jnp.dot(x, w) + b


# ---------------------------------------------------------------------------
# SA / FP / voting / proposal building blocks (per-scene, vmap for batches)
# ---------------------------------------------------------------------------


def sa_apply(
    params_sa,
    xyz: jnp.ndarray,
    feats: Optional[jnp.ndarray],
    m: int,
    radius: float,
    k: int,
    fg: Optional[jnp.ndarray] = None,
    w0: float = 1.0,
    use_pallas: bool = False,
    qc: Optional[QConfig] = None,
    name: str = "",
    start: int = 0,
):
    """One set-abstraction layer. Returns (new_xyz, new_feats, new_fg, idx)."""
    idx = sampling.fps(xyz, m, fg if w0 != 1.0 else None, w0, start=start)
    centers = xyz[idx]
    group_idx = sampling.ball_query(centers, xyz, radius, k, use_pallas=use_pallas)
    groups = sampling.group_features(xyz, feats, idx, group_idx)
    weights = _maybe_qdq_weights(params_sa, name, qc)
    new_feats = _pointnet(groups, weights, use_pallas)
    new_fg = fg[idx] if fg is not None else None
    return centers, new_feats, new_fg, idx


def backbone_forward(
    params: Params,
    xyz: jnp.ndarray,
    feats: Optional[jnp.ndarray],
    variant: str = "full",
    fg: Optional[jnp.ndarray] = None,
    w0: float = DEFAULT_W0,
    bias_layers: int = DEFAULT_BIAS_LAYERS,
    split_key: Optional[jax.Array] = None,
    use_pallas: bool = False,
    qc: Optional[QConfig] = None,
):
    """PointNet++ backbone with the three sampling variants.

    variant: 'full'      — regular FPS with the full centroid budget
             'split'     — PointSplit: SA-normal + SA-bias (biased FPS with
                           weight w0 on the first `bias_layers` SA layers),
                           fused before SA4 (paper Fig. 5)
             'randsplit' — RandomSplit ablation: random halves, regular FPS
    Returns (seed_xyz (NUM_SEEDS, 3), seed_feats (NUM_SEEDS, SEED_FEAT)).
    """
    cfgs = SA_CONFIGS

    def run_pipeline(xyz_p, feats_p, fg_p, halves: bool, biased: bool):
        """SA1..SA3 of one pipeline; centroid budget halved when split. The
        bias pipeline's SA1 starts FPS at a different index so the two views
        decorrelate (start 0 for both would duplicate the sampled sets
        wherever the bias weight has no effect)."""
        out = []
        cur_xyz, cur_feats, cur_fg = xyz_p, feats_p, fg_p
        for li in range(3):
            m, r, k, _ = cfgs[li]
            if halves:
                m = m // 2
            wl = w0 if (biased and li < bias_layers) else 1.0
            start = int(xyz_p.shape[0]) // 2 if (biased and li == 0) else 0
            cur_xyz, cur_feats, cur_fg, _ = sa_apply(
                params[f"sa{li + 1}"],
                cur_xyz,
                cur_feats,
                m,
                r,
                k,
                fg=cur_fg,
                w0=wl,
                use_pallas=use_pallas,
                qc=qc,
                name=f"sa{li + 1}",
                start=start,
            )
            out.append((cur_xyz, cur_feats))
        return out

    if variant == "full":
        levels = run_pipeline(xyz, feats, fg, halves=False, biased=False)
        sa2, sa3 = levels[1], levels[2]
    elif variant == "split":
        ln = run_pipeline(xyz, feats, fg, halves=True, biased=False)
        lb = run_pipeline(xyz, feats, fg, halves=True, biased=True)
        sa2 = (jnp.concatenate([ln[1][0], lb[1][0]]), jnp.concatenate([ln[1][1], lb[1][1]]))
        sa3 = (jnp.concatenate([ln[2][0], lb[2][0]]), jnp.concatenate([ln[2][1], lb[2][1]]))
    elif variant == "randsplit":
        assert split_key is not None
        ia, ib = sampling.random_split(xyz.shape[0], split_key)
        fa = feats[ia] if feats is not None else None
        fb = feats[ib] if feats is not None else None
        ln = run_pipeline(xyz[ia], fa, None, halves=True, biased=False)
        lb = run_pipeline(xyz[ib], fb, None, halves=True, biased=False)
        sa2 = (jnp.concatenate([ln[1][0], lb[1][0]]), jnp.concatenate([ln[1][1], lb[1][1]]))
        sa3 = (jnp.concatenate([ln[2][0], lb[2][0]]), jnp.concatenate([ln[2][1], lb[2][1]]))
    else:
        raise ValueError(variant)

    # SA4 over the (fused) SA3 set — always regular FPS (paper §4.2)
    m4, r4, k4, _ = cfgs[3]
    sa4_xyz, sa4_feats, _, _ = sa_apply(
        params["sa4"], sa3[0], sa3[1], m4, r4, k4, use_pallas=use_pallas, qc=qc, name="sa4"
    )

    # Simplified FP (Table 1): 3-NN interpolation twice + one shared FC.
    f3 = jnp.concatenate(
        [sa3[1], sampling.three_nn_interpolate(sa3[0], sa4_xyz, sa4_feats)], axis=-1
    )
    f2 = jnp.concatenate([sa2[1], sampling.three_nn_interpolate(sa2[0], sa3[0], f3)], axis=-1)
    seed_feats = fp_fc_apply(params, f2, qc=qc)
    return sa2[0], seed_feats


def voting_forward(params, seed_xyz, seed_feats, use_pallas=False, qc: Optional[QConfig] = None):
    """Voting module: seeds -> votes (xyz offset + feature residual)."""
    out = vote_apply(params, seed_feats, use_pallas=use_pallas, qc=qc)
    vote_xyz = seed_xyz + out[:, :3]
    vote_feats = seed_feats + out[:, 3:]
    return vote_xyz, vote_feats


def proposal_forward(params, vote_xyz, vote_feats, use_pallas=False, qc: Optional[QConfig] = None):
    """Proposal module: cluster votes, PointNet, role-grouped head (Table 2)."""
    idx = sampling.fps(vote_xyz, NUM_PROPOSALS)
    centers = vote_xyz[idx]
    gidx = sampling.ball_query(centers, vote_xyz, PROPOSAL_RADIUS, PROPOSAL_K, use_pallas)
    groups = sampling.group_features(vote_xyz, vote_feats, idx, gidx)
    out = proposal_apply(params, groups, use_pallas=use_pallas, qc=qc)
    return centers, out


def detector_forward(
    params: Params,
    xyz: jnp.ndarray,
    feats: Optional[jnp.ndarray],
    variant: str = "full",
    fg: Optional[jnp.ndarray] = None,
    w0: float = DEFAULT_W0,
    bias_layers: int = DEFAULT_BIAS_LAYERS,
    split_key: Optional[jax.Array] = None,
    use_pallas: bool = False,
    qc: Optional[QConfig] = None,
):
    """Full per-scene detector. Returns dict of raw outputs (pre-decode)."""
    seed_xyz, seed_feats = backbone_forward(
        params,
        xyz,
        feats,
        variant=variant,
        fg=fg,
        w0=w0,
        bias_layers=bias_layers,
        split_key=split_key,
        use_pallas=use_pallas,
        qc=qc,
    )
    vote_xyz, vote_feats = voting_forward(params, seed_xyz, seed_feats, use_pallas, qc)
    centers, prop = proposal_forward(params, vote_xyz, vote_feats, use_pallas, qc)
    return {
        "seed_xyz": seed_xyz,
        "vote_xyz": vote_xyz,
        "cluster_xyz": centers,
        "proposal": prop,
    }


# ---------------------------------------------------------------------------
# Box decoding (mirrored in rust/src/coordinator/decode.rs)
# ---------------------------------------------------------------------------


def decode_proposals(cluster_xyz: jnp.ndarray, prop: jnp.ndarray, mean_sizes: jnp.ndarray):
    """Raw head channels -> boxes. Returns dict with arrays over proposals."""
    center = cluster_xyz + prop[:, slice(*common.SLICE_CENTER)]
    objness = jax.nn.softmax(prop[:, slice(*common.SLICE_OBJECTNESS)], axis=-1)[:, 1]
    h_cls = prop[:, slice(*common.SLICE_HEADING_CLS)]
    h_reg = prop[:, slice(*common.SLICE_HEADING_REG)]
    hbin = jnp.argmax(h_cls, axis=-1)
    per = 2 * jnp.pi / NUM_HEADING_BIN
    h_res = jnp.take_along_axis(h_reg, hbin[:, None], axis=1)[:, 0] * (per / 2)
    heading = hbin * per + h_res
    s_cls = prop[:, slice(*common.SLICE_SIZE_CLS)]
    s_reg = prop[:, slice(*common.SLICE_SIZE_REG)].reshape(-1, NUM_CLASS, 3)
    sbin = jnp.argmax(s_cls, axis=-1)
    base = mean_sizes[sbin]
    res = jnp.take_along_axis(s_reg, sbin[:, None, None].repeat(3, -1), axis=1)[:, 0]
    size = base * (1.0 + jnp.clip(res, -0.9, 2.0))
    sem = jax.nn.softmax(prop[:, slice(*common.SLICE_SEM_CLS)], axis=-1)
    return {
        "center": center,
        "heading": heading % (2 * jnp.pi),
        "size": size,
        "objectness": objness,
        "sem_scores": sem,
    }


# ---------------------------------------------------------------------------
# GroupFree3D-mini: attention-based detection head (Table 8)
# ---------------------------------------------------------------------------

ATTN_DIM = 64
ATTN_HEADS = 4
ATTN_LAYERS = 2


def attn_head_init(key) -> Params:
    ks = jax.random.split(key, 4 + ATTN_LAYERS * 8)
    p: Params = {
        "in_proj": _dense_init(ks[0], SEED_FEAT, ATTN_DIM),
        "out": _dense_init(ks[1], ATTN_DIM, PROPOSAL_CH, scale=0.5),
    }
    for l in range(ATTN_LAYERS):
        base = 4 + l * 8
        p[f"l{l}"] = {
            "q_self": _dense_init(ks[base], ATTN_DIM, ATTN_DIM),
            "kv_self": _dense_init(ks[base + 1], ATTN_DIM, 2 * ATTN_DIM),
            "q_cross": _dense_init(ks[base + 2], ATTN_DIM, ATTN_DIM),
            "kv_cross": _dense_init(ks[base + 3], ATTN_DIM, 2 * ATTN_DIM),
            "ff1": _dense_init(ks[base + 4], ATTN_DIM, 2 * ATTN_DIM),
            "ff2": _dense_init(ks[base + 5], 2 * ATTN_DIM, ATTN_DIM),
            "o_self": _dense_init(ks[base + 6], ATTN_DIM, ATTN_DIM),
            "o_cross": _dense_init(ks[base + 7], ATTN_DIM, ATTN_DIM),
        }
    return p


def _mha(q, k, v, nheads: int):
    d = q.shape[-1] // nheads
    qh = q.reshape(q.shape[0], nheads, d).transpose(1, 0, 2)
    kh = k.reshape(k.shape[0], nheads, d).transpose(1, 0, 2)
    vh = v.reshape(v.shape[0], nheads, d).transpose(1, 0, 2)
    att = jax.nn.softmax(qh @ kh.transpose(0, 2, 1) / jnp.sqrt(d), axis=-1)
    return (att @ vh).transpose(1, 0, 2).reshape(q.shape[0], -1)


def _ln(x):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + 1e-5)


def attn_proj(params: Params, seed_feats):
    """Project seed features into the attention width (network-only)."""
    return jnp.dot(seed_feats, params["in_proj"][0]) + params["in_proj"][1]


def attn_decode(params: Params, cand_feats, all_feats):
    """Transformer decoder over candidates (network-only; candidates were
    selected by FPS on the point-manipulation side)."""
    x, feats = cand_feats, all_feats
    for l in range(ATTN_LAYERS):
        lp = params[f"l{l}"]
        q = jnp.dot(_ln(x), lp["q_self"][0]) + lp["q_self"][1]
        kv = jnp.dot(_ln(x), lp["kv_self"][0]) + lp["kv_self"][1]
        sa = _mha(q, kv[:, :ATTN_DIM], kv[:, ATTN_DIM:], ATTN_HEADS)
        x = x + jnp.dot(sa, lp["o_self"][0]) + lp["o_self"][1]
        q = jnp.dot(_ln(x), lp["q_cross"][0]) + lp["q_cross"][1]
        kv = jnp.dot(_ln(feats), lp["kv_cross"][0]) + lp["kv_cross"][1]
        ca = _mha(q, kv[:, :ATTN_DIM], kv[:, ATTN_DIM:], ATTN_HEADS)
        x = x + jnp.dot(ca, lp["o_cross"][0]) + lp["o_cross"][1]
        h = jax.nn.relu(jnp.dot(_ln(x), lp["ff1"][0]) + lp["ff1"][1])
        x = x + jnp.dot(h, lp["ff2"][0]) + lp["ff2"][1]
    return jnp.dot(_ln(x), params["out"][0]) + params["out"][1]


def attn_head_forward(params: Params, seed_xyz, seed_feats):
    """GroupFree3D-mini: candidates attend to each other and to all seeds."""
    feats = attn_proj(params, seed_feats)
    # initial candidates: FPS over seeds (the KPS of GroupFree3D)
    idx = sampling.fps(seed_xyz, NUM_PROPOSALS)
    out = attn_decode(params, feats[idx], feats)
    return seed_xyz[idx], out


def attn_detector_forward(
    det_params,
    attn_params,
    xyz,
    feats,
    variant="full",
    fg=None,
    w0=DEFAULT_W0,
    bias_layers=DEFAULT_BIAS_LAYERS,
    split_key=None,
):
    seed_xyz, seed_feats = backbone_forward(
        det_params,
        xyz,
        feats,
        variant=variant,
        fg=fg,
        w0=w0,
        bias_layers=bias_layers,
        split_key=split_key,
    )
    centers, out = attn_head_forward(attn_params, seed_xyz, seed_feats)
    return {"seed_xyz": seed_xyz, "vote_xyz": seed_xyz, "cluster_xyz": centers, "proposal": out}


# ---------------------------------------------------------------------------
# Network-only subgraphs for AOT export (all point manipulation excluded).
# Each takes already-grouped tensors; rust/src/pointops produces them.
# ---------------------------------------------------------------------------


def sa_pointnet_apply(params, layer: int, groups, use_pallas=True, qc=None):
    """groups (B, K, 3+C) -> (B, C_out). The per-SA-layer NPU workload."""
    weights = _maybe_qdq_weights(params[f"sa{layer}"], f"sa{layer}", qc)
    return _pointnet(groups, weights, use_pallas)


def fp_fc_apply(params, f2, qc: Optional[QConfig] = None):
    """Fused-FP features (NUM_SEEDS, FP_IN) -> seed feats."""
    w, b = params["fp_fc"]
    if qc is not None and "fp_fc.0" in qc.weight_scales:
        s = qc.weight_scales["fp_fc.0"]
        w = jnp.clip(jnp.round(w / s[None, :]), -127, 127) * s[None, :]
    return jax.nn.relu(jnp.dot(f2, w) + b)


def vote_apply(params, seed_feats, use_pallas=True, qc=None):
    """Seed feats -> raw vote output (NUM_SEEDS, VOTE_CH)."""
    weights = _maybe_qdq_weights(params["vote_mlp"], "vote_mlp", qc)
    h = mlp_ref(seed_feats, weights)
    return _head_layer(h, params["vote_out"], "vote_out", qc, use_pallas)


def proposal_apply(params, groups, use_pallas=True, qc=None):
    """Grouped votes (NUM_PROPOSALS, K, 3+C) -> raw head (NUM_PROPOSALS, 79)."""
    weights = _maybe_qdq_weights(params["prop_pointnet"], "prop_pointnet", qc)
    cluster_feats = _pointnet(groups, weights, use_pallas)
    weights2 = _maybe_qdq_weights(params["prop_mlp"], "prop_mlp", qc)
    h = mlp_ref(cluster_feats, weights2)
    return _head_layer(h, params["prop_out"], "prop_out", qc, use_pallas)


def attn_apply(attn_params, cand_feats, all_feats):
    """Network-only attention head: (candidates, all projected seeds) -> raw
    head channels. FPS candidate selection happens on the Rust side."""
    return attn_decode(attn_params, cand_feats, all_feats)


# ---------------------------------------------------------------------------
# Parameter counting (Table 1)
# ---------------------------------------------------------------------------


def count_params(tree) -> int:
    leaves = jax.tree_util.tree_leaves(tree)
    return int(sum(x.size for x in leaves if hasattr(x, "size")))


def fp_layer_cost(paper_scale: bool = False):
    """(params, madds) of the FP stage: PointNet++ (two FP PointNets) vs
    PointSplit (one shared FC). ``paper_scale=True`` uses the original VoteNet
    widths (256-ch FP MLPs over 512/1024 points) to reproduce Table 1's
    absolute numbers; otherwise the VoteNet-mini widths.
    """
    if paper_scale:
        fp1 = [(512, 256), (256, 256)]
        fp2 = [(512, 256), (256, 256)]
        n1, n2 = 512, 1024
        ps = [(512, 384)]
        n_ps = 1024
    else:
        fp1 = [(FP_IN - SA_CONFIGS[1][3][-1], 128), (128, 128)]
        fp2 = [(128 + 128, 128), (128, 128)]
        n1, n2 = 64, NUM_SEEDS
        ps = [(FP_IN, SEED_FEAT)]
        n_ps = NUM_SEEDS
    p_orig = sum(ci * co + co for ci, co in fp1 + fp2)
    m_orig = sum(ci * co * n1 for ci, co in fp1) + sum(ci * co * n2 for ci, co in fp2)
    p_ps = sum(ci * co + co for ci, co in ps)
    m_ps = sum(ci * co * n_ps for ci, co in ps)
    return (p_orig, m_orig), (p_ps, m_ps)
