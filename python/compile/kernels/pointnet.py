"""Pallas kernel: fused shared-MLP + max-pool (the PointNet core).

This is the paper's NPU hot-spot. On the EdgeTPU the shared MLP is a chain of
1x1 convolutions over grouped points followed by a max-pool across each ball.
The TPU adaptation (DESIGN.md §Hardware-Adaptation): grid over ball blocks;
each program stages a ``(BB*K, C_in)`` tile in VMEM, runs the whole MLP as
chained MXU matmuls with the weight panels resident in VMEM, max-reduces over
the K axis in-register, and writes a ``(BB, C_out)`` tile — i.e. one
HBM→VMEM→HBM pass for the entire fused layer instead of one per conv.

Run with ``interpret=True`` everywhere: the CPU PJRT client cannot execute
Mosaic custom calls; real-TPU perf is estimated from the VMEM footprint / MXU
utilization (see EXPERIMENTS.md §Perf).
"""

from __future__ import annotations

import functools
from typing import Sequence, Tuple

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Default number of balls processed per program instance. 32 balls x 32
# neighbors x 64 ch fp32 = 256 KiB of VMEM for the widest SA1 tile — well
# under the ~16 MiB VMEM budget, leaving room for double buffering.
DEFAULT_BLOCK_B = 32


def _pointnet_kernel(x_ref, *refs, num_layers: int):
    """One grid step: x_ref (BB, K, C_in) -> o_ref (BB, C_out)."""
    o_ref = refs[-1]
    wb = refs[:-1]  # alternating W, b
    bb, k, cin = x_ref.shape
    x = x_ref[...].reshape(bb * k, cin)
    for layer in range(num_layers):
        w = wb[2 * layer][...]
        b = wb[2 * layer + 1][...]
        # MXU matmul; keep accumulation in f32.
        x = jnp.dot(x, w, preferred_element_type=jnp.float32) + b
        x = jnp.maximum(x, 0.0)
    cout = x.shape[-1]
    o_ref[...] = jnp.max(x.reshape(bb, k, cout), axis=1)


def pointnet_pallas(
    groups: jnp.ndarray,
    weights: Sequence[Tuple[jnp.ndarray, jnp.ndarray]],
    block_b: int = DEFAULT_BLOCK_B,
) -> jnp.ndarray:
    """Fused PointNet over grouped points.

    groups:  (B, K, C_in); B must be a multiple of ``block_b`` (callers pad).
    weights: [(W1, b1), (W2, b2), ...] of the shared MLP.
    returns: (B, C_out).
    """
    b, k, cin = groups.shape
    if b % block_b != 0:
        block_b = next(bb for bb in range(min(block_b, b), 0, -1) if b % bb == 0)
    cout = weights[-1][0].shape[1]
    num_layers = len(weights)

    in_specs = [pl.BlockSpec((block_b, k, cin), lambda i: (i, 0, 0))]
    flat_wb = []
    for w, bias in weights:
        # weight panels are small; keep them whole in VMEM for every program
        in_specs.append(pl.BlockSpec(w.shape, lambda i, nd=w.ndim: (0,) * nd))
        in_specs.append(pl.BlockSpec(bias.shape, lambda i, nd=bias.ndim: (0,) * nd))
        flat_wb += [w, bias]

    return pl.pallas_call(
        functools.partial(_pointnet_kernel, num_layers=num_layers),
        grid=(b // block_b,),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((block_b, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, cout), jnp.float32),
        interpret=True,
    )(groups, *flat_wb)


def vmem_footprint_bytes(
    b: int, k: int, widths: Sequence[int], block_b: int = DEFAULT_BLOCK_B
) -> int:
    """Estimated per-program VMEM footprint of :func:`pointnet_pallas`.

    widths = (C_in, C1, ..., C_out). Used by the §Perf structural analysis:
    input tile + the two widest chained activations + all weight panels.
    """
    del b
    acts = sorted((block_b * k * c for c in widths), reverse=True)
    act_bytes = sum(acts[:2]) * 4  # current + next activation, f32
    w_bytes = sum(widths[i] * widths[i + 1] + widths[i + 1] for i in range(len(widths) - 1)) * 4
    return act_bytes + w_bytes


def mxu_utilization_estimate(k: int, widths: Sequence[int]) -> float:
    """Fraction of 128x128 MXU lanes busy for the chained matmuls.

    Each matmul is (BB*K, C_l) x (C_l, C_{l+1}); the systolic array is padded
    to 128 on both contraction and output dims, so utilization is the mean of
    (C_l/128 * C_{l+1}/128) clipped at 1 per layer.
    """
    del k
    utils = []
    for i in range(len(widths) - 1):
        utils.append(min(widths[i] / 128.0, 1.0) * min(widths[i + 1] / 128.0, 1.0))
    return float(sum(utils) / len(utils))
