//! Paper Table 9: PointSplit accuracy vs the biased-FPS weight w0.
//! Expected shape: peak at moderate bias (paper: w0 = 2.0), degradation when
//! the background is starved (w0 >= 2.5).

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(40);
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let mut t = Table::new(&["w0", "mAP@0.25", "paper"]);
    let paper = [(0.5, 60.3), (1.0, 60.4), (1.5, 61.3), (2.0, 61.4), (2.5, 59.6), (3.5, 59.4)];
    for (w0, paper_map) in paper {
        let mut cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, false, sched);
        cfg.w0 = w0 as f32;
        let rep = common::eval_config(&rt, &cfg, scenes);
        t.row(vec![
            format!("{w0}"),
            format!("{:.1}", rep.map_25 * 100.0),
            format!("{paper_map}"),
        ]);
        eprintln!("  [w0={w0}] mAP {:.1}", rep.map_25 * 100.0);
    }
    t.print(&format!("Table 9 — biased-FPS weight sweep on synrgbd ({scenes} scenes)"));
}
