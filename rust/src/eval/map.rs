//! mAP@IoU evaluation (VoteNet / PASCAL-style 11-point-free AP).
//!
//! Detections across scenes are pooled per class, sorted by confidence,
//! greedily matched to unmatched GT boxes with IoU >= threshold, and AP is
//! the area under the interpolated precision-recall curve.

use std::collections::HashMap;

use crate::data::Box3;
use crate::eval::iou::iou3d;

/// One detection attributed to a scene.
#[derive(Debug, Clone)]
pub struct Detection {
    pub scene: usize,
    pub b: Box3, // class + score inside
}

#[derive(Debug, Clone)]
pub struct MapResult {
    /// per-class AP (None when the class has no GT instances)
    pub ap: Vec<Option<f64>>,
    pub map: f64,
}

/// Compute per-class AP and mAP at the given IoU threshold.
///
/// `gts[s]` are the ground-truth boxes of scene s.
pub fn eval_map(
    detections: &[Detection],
    gts: &[Vec<Box3>],
    num_class: usize,
    iou_thresh: f64,
) -> MapResult {
    let mut ap = vec![None; num_class];
    for cls in 0..num_class {
        // GT per scene for this class
        let mut gt_count = 0usize;
        let mut gt_by_scene: HashMap<usize, Vec<&Box3>> = HashMap::new();
        for (s, boxes) in gts.iter().enumerate() {
            let v: Vec<&Box3> = boxes.iter().filter(|b| b.class == cls).collect();
            gt_count += v.len();
            if !v.is_empty() {
                gt_by_scene.insert(s, v);
            }
        }
        if gt_count == 0 {
            continue;
        }
        let mut dets: Vec<&Detection> = detections.iter().filter(|d| d.b.class == cls).collect();
        dets.sort_by(|a, b| b.b.score.partial_cmp(&a.b.score).unwrap());
        let mut matched: HashMap<(usize, usize), bool> = HashMap::new();
        let mut tp = Vec::with_capacity(dets.len());
        for d in &dets {
            let mut best = (0.0f64, usize::MAX);
            if let Some(gt) = gt_by_scene.get(&d.scene) {
                for (gi, g) in gt.iter().enumerate() {
                    let iou = iou3d(&d.b, g);
                    if iou > best.0 {
                        best = (iou, gi);
                    }
                }
            }
            let hit = best.0 >= iou_thresh
                && !matched.get(&(d.scene, best.1)).copied().unwrap_or(false);
            if hit {
                matched.insert((d.scene, best.1), true);
            }
            tp.push(hit);
        }
        // precision-recall with monotone interpolation
        let mut cum_tp = 0usize;
        let mut prec = Vec::with_capacity(tp.len());
        let mut rec = Vec::with_capacity(tp.len());
        for (i, &hit) in tp.iter().enumerate() {
            if hit {
                cum_tp += 1;
            }
            prec.push(cum_tp as f64 / (i + 1) as f64);
            rec.push(cum_tp as f64 / gt_count as f64);
        }
        // interpolate precision to be monotone non-increasing
        for i in (0..prec.len().saturating_sub(1)).rev() {
            if prec[i] < prec[i + 1] {
                prec[i] = prec[i + 1];
            }
        }
        let mut auc = 0.0;
        let mut prev_r = 0.0;
        for i in 0..prec.len() {
            auc += (rec[i] - prev_r).max(0.0) * prec[i];
            prev_r = rec[i];
        }
        ap[cls] = Some(auc);
    }
    let present: Vec<f64> = ap.iter().flatten().copied().collect();
    let map = if present.is_empty() { 0.0 } else { present.iter().sum::<f64>() / present.len() as f64 };
    MapResult { ap, map }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(c: [f32; 3], class: usize, score: f32) -> Box3 {
        Box3 { center: c, size: [1.0, 1.0, 1.0], heading: 0.0, class, score }
    }

    #[test]
    fn perfect_detections_give_map_one() {
        let gts = vec![vec![mk([0.0; 3], 0, 1.0), mk([3.0, 0.0, 0.0], 1, 1.0)]];
        let dets = vec![
            Detection { scene: 0, b: mk([0.0; 3], 0, 0.9) },
            Detection { scene: 0, b: mk([3.0, 0.0, 0.0], 1, 0.8) },
        ];
        let r = eval_map(&dets, &gts, 2, 0.25);
        assert!((r.map - 1.0).abs() < 1e-9);
    }

    #[test]
    fn misses_reduce_ap() {
        let gts = vec![vec![mk([0.0; 3], 0, 1.0), mk([5.0, 0.0, 0.0], 0, 1.0)]];
        let dets = vec![Detection { scene: 0, b: mk([0.0; 3], 0, 0.9) }];
        let r = eval_map(&dets, &gts, 1, 0.25);
        assert!((r.ap[0].unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn duplicate_detections_count_once() {
        let gts = vec![vec![mk([0.0; 3], 0, 1.0)]];
        let dets = vec![
            Detection { scene: 0, b: mk([0.0; 3], 0, 0.9) },
            Detection { scene: 0, b: mk([0.02, 0.0, 0.0], 0, 0.8) },
        ];
        let r = eval_map(&dets, &gts, 1, 0.25);
        // second det is a false positive at full recall -> AP stays 1.0
        assert!((r.ap[0].unwrap() - 1.0).abs() < 1e-9);
        // but a lower-scored miss then a hit gives AP < 1
        let dets2 = vec![
            Detection { scene: 0, b: mk([4.0, 0.0, 0.0], 0, 0.95) },
            Detection { scene: 0, b: mk([0.0; 3], 0, 0.8) },
        ];
        let r2 = eval_map(&dets2, &gts, 1, 0.25);
        assert!((r2.ap[0].unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn class_without_gt_is_skipped() {
        let gts = vec![vec![mk([0.0; 3], 0, 1.0)]];
        let dets = vec![Detection { scene: 0, b: mk([0.0; 3], 0, 0.9) }];
        let r = eval_map(&dets, &gts, 3, 0.25);
        assert!(r.ap[1].is_none() && r.ap[2].is_none());
        assert!((r.map - 1.0).abs() < 1e-9);
    }

    #[test]
    fn wrong_scene_does_not_match() {
        let gts = vec![vec![mk([0.0; 3], 0, 1.0)], vec![]];
        let dets = vec![Detection { scene: 1, b: mk([0.0; 3], 0, 0.9) }];
        let r = eval_map(&dets, &gts, 1, 0.25);
        assert_eq!(r.ap[0].unwrap(), 0.0);
    }
}
