"""Build-time training for the PointSplit reproduction (CPU, minutes).

Trains, per dataset: the 2D segmenter, then the detector variants (VoteNet
plain / painted-full / painted-split) on a pool of procedural scenes. A
hand-rolled Adam (optax is not available in this environment) and vmapped
per-scene losses keep this self-contained. ``aot.py`` caches the resulting
weights under ``artifacts/weights/``; training only reruns when those caches
are deleted.
"""

from __future__ import annotations

import functools
import os
import time
from typing import Dict, List, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from . import common, losses, model, scene
from .common import DatasetConfig, IMG_SIZE, MEAN_SIZES, NUM_SEG_CLASSES
from .losses import MAX_OBJ

# Tunable via env for quick smoke runs (tests set these small).
SEG_STEPS = int(os.environ.get("POINTSPLIT_SEG_STEPS", 240))
DET_STEPS = int(os.environ.get("POINTSPLIT_DET_STEPS", 420))
BATCH = int(os.environ.get("POINTSPLIT_BATCH", 4))
POOL_SIZE = int(os.environ.get("POINTSPLIT_POOL", 384))
TRAIN_POINTS = int(os.environ.get("POINTSPLIT_TRAIN_POINTS", 2048))

MEAN_SIZES_J = jnp.array(MEAN_SIZES, jnp.float32)


# ---------------------------------------------------------------------------
# Adam (hand-rolled)
# ---------------------------------------------------------------------------


def adam_init(params):
    zeros = jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params)
    return {"m": zeros, "v": jax.tree_util.tree_map(lambda p: jnp.zeros_like(p), params), "t": 0}


def adam_step(params, grads, state, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    t = state["t"] + 1
    m = jax.tree_util.tree_map(lambda m_, g: b1 * m_ + (1 - b1) * g, state["m"], grads)
    v = jax.tree_util.tree_map(lambda v_, g: b2 * v_ + (1 - b2) * g * g, state["v"], grads)
    mh = jax.tree_util.tree_map(lambda m_: m_ / (1 - b1**t), m)
    vh = jax.tree_util.tree_map(lambda v_: v_ / (1 - b2**t), v)
    new = jax.tree_util.tree_map(
        lambda p, m_, v_: p - lr * m_ / (jnp.sqrt(v_) + eps), params, mh, vh
    )
    return new, {"m": m, "v": v, "t": t}


# ---------------------------------------------------------------------------
# Scene pool -> padded numpy batches
# ---------------------------------------------------------------------------


def pad_gt(sc: scene.Scene) -> Dict[str, np.ndarray]:
    boxes = sc.boxes()
    k = min(len(boxes), MAX_OBJ)
    out = {
        "centers": np.zeros((MAX_OBJ, 3), np.float32),
        "sizes": np.ones((MAX_OBJ, 3), np.float32),
        "headings": np.zeros((MAX_OBJ,), np.float32),
        "classes": np.zeros((MAX_OBJ,), np.int32),
        "mask": np.zeros((MAX_OBJ,), np.float32),
    }
    if k:
        out["centers"][:k] = boxes[:k, 0:3]
        out["sizes"][:k] = boxes[:k, 3:6]
        out["headings"][:k] = boxes[:k, 6]
        out["classes"][:k] = boxes[:k, 7].astype(np.int32)
        out["mask"][:k] = 1.0
    return out


class ScenePool:
    """Pre-generated training scenes with painted features."""

    def __init__(self, cfg: DatasetConfig, seg_params, size=None, seed0: int = 10_000):
        size = POOL_SIZE if size is None else size
        self.cfg = cfg
        self.scenes: List[scene.Scene] = [
            scene.generate_scene(seed0 + i, cfg) for i in range(size)
        ]
        self.gts = [pad_gt(s) for s in self.scenes]
        # paint once with the trained segmenter
        seg_batch = jax.jit(jax.vmap(lambda im: model.segmenter_scores(seg_params, im)))
        self.scores: List[np.ndarray] = []
        imgs = np.stack([s.image for s in self.scenes])
        bs = 32
        outs = []
        for i in range(0, len(imgs), bs):
            outs.append(np.asarray(seg_batch(jnp.asarray(imgs[i : i + bs]))))
        seg_scores = np.concatenate(outs)
        for s, sc_ in zip(self.scenes, seg_scores):
            self.scores.append(scene.paint_points(s.points, sc_, s.cam_pos, s.cam_rot, s.fx))

    def batch(self, rng: np.random.Generator, painted: bool, n_points: int = TRAIN_POINTS):
        idx = rng.integers(0, len(self.scenes), BATCH)
        xyz, feats, fg, gts = [], [], [], []
        for i in idx:
            s = self.scenes[i]
            n = len(s.points)
            sel = rng.choice(n, n_points, replace=n < n_points)
            p = s.points[sel]
            xyz.append(p)
            h = p[:, 2:3]  # height above floor
            if painted:
                sc_ = self.scores[i][sel]
                feats.append(np.concatenate([h, sc_], axis=1))
                fg.append((1.0 - sc_[:, 0] > 0.5).astype(np.float32))
            else:
                feats.append(h)
                fg.append(np.zeros(n_points, np.float32))
            gts.append(self.gts[i])
        stack = lambda key: jnp.asarray(np.stack([g[key] for g in gts]))
        return (
            jnp.asarray(np.stack(xyz)),
            jnp.asarray(np.stack(feats).astype(np.float32)),
            jnp.asarray(np.stack(fg)),
            {k: stack(k) for k in gts[0]},
        )


# ---------------------------------------------------------------------------
# Segmenter training
# ---------------------------------------------------------------------------


def train_segmenter(cfg: DatasetConfig, steps=None, log=print):
    steps = SEG_STEPS if steps is None else steps
    key = jax.random.PRNGKey(7)
    params = model.segmenter_init(key)
    opt = adam_init(params)

    def loss_fn(p, imgs, masks):
        logits = jax.vmap(lambda im: model.segmenter_forward(p, im))(imgs)
        return jax.vmap(losses.seg_loss)(logits, masks).mean()

    @jax.jit
    def step(p, o, imgs, masks):
        l, g = jax.value_and_grad(loss_fn)(p, imgs, masks)
        p, o = adam_step(p, g, o, lr=2e-3)
        return p, o, l

    rng = np.random.default_rng(1)
    pool = [scene.generate_scene(50_000 + i, cfg) for i in range(min(POOL_SIZE, 256))]
    imgs = np.stack([s.image for s in pool])
    masks = np.stack([s.seg_mask for s in pool])
    t0 = time.time()
    for it in range(steps):
        sel = rng.integers(0, len(pool), 8)
        params, opt, l = step(params, opt, jnp.asarray(imgs[sel]), jnp.asarray(masks[sel]))
        if it % 60 == 0 or it == steps - 1:
            log(f"  [seg/{cfg.name}] step {it:4d} loss {float(l):.4f} ({time.time()-t0:.0f}s)")
    return params


# ---------------------------------------------------------------------------
# Detector training
# ---------------------------------------------------------------------------


def make_loss_fn(variant: str, w0: float, bias_layers: int):
    def loss_fn(params, xyz, feats, fg, gt, keys):
        def one(x, f, g, c, s, h, cl, m, k):
            ep = model.detector_forward(
                params,
                x,
                f if feats.shape[-1] > 0 else None,
                variant=variant,
                fg=g,
                w0=w0,
                bias_layers=bias_layers,
                split_key=k,
            )
            gt_one = {"centers": c, "sizes": s, "headings": h, "classes": cl, "mask": m}
            return losses.scene_loss(ep, gt_one, MEAN_SIZES_J)["total"]

        ls = jax.vmap(one)(
            xyz, feats, fg, gt["centers"], gt["sizes"], gt["headings"], gt["classes"],
            gt["mask"], keys,
        )
        return ls.mean()

    return loss_fn


def train_detector(
    pool: ScenePool,
    painted: bool,
    variant: str,
    w0: float = common.DEFAULT_W0,
    bias_layers: int = common.DEFAULT_BIAS_LAYERS,
    steps=None,
    seed: int = 3,
    log=print,
    init_params=None,
    head: str = "vote",
):
    """Train one detector configuration. head: 'vote' | 'attn'."""
    steps = DET_STEPS if steps is None else steps
    key = jax.random.PRNGKey(seed)
    params = init_params if init_params is not None else model.detector_init(key, painted)
    attn_params = model.attn_head_init(jax.random.PRNGKey(seed + 100)) if head == "attn" else None

    if head == "vote":
        loss_core = make_loss_fn(variant, w0, bias_layers)

        def full_loss(p, *args):
            return loss_core(p, *args)

        trainable = params
    else:
        def full_loss(p, xyz, feats, fg, gt, keys):
            det, attn = p

            def one(x, f, g, c, s, h, cl, m, k):
                ep = model.attn_detector_forward(
                    det, attn, x, f if feats.shape[-1] > 0 else None, variant=variant,
                    fg=g, w0=w0, bias_layers=bias_layers, split_key=k,
                )
                gt_one = {"centers": c, "sizes": s, "headings": h, "classes": cl, "mask": m}
                return losses.scene_loss(ep, gt_one, MEAN_SIZES_J)["total"]

            return jax.vmap(one)(
                xyz, feats, fg, gt["centers"], gt["sizes"], gt["headings"],
                gt["classes"], gt["mask"], keys,
            ).mean()

        trainable = (params, attn_params)

    opt = adam_init(trainable)

    @jax.jit
    def step(p, o, xyz, feats, fg, gt, keys, lr):
        l, g = jax.value_and_grad(full_loss)(p, xyz, feats, fg, gt, keys)
        p, o = adam_step(p, g, o, lr=lr)
        return p, o, l

    rng = np.random.default_rng(seed)
    t0 = time.time()
    name = f"{variant}{'_attn' if head == 'attn' else ''}{'_painted' if painted else ''}"
    for it in range(steps):
        # step-decay schedule (the paper decays 10x at epochs 80/120 of 180)
        frac = it / max(steps, 1)
        lr = 1.5e-3 if frac < 0.45 else (4e-4 if frac < 0.8 else 1e-4)
        xyz, feats, fg, gt = pool.batch(rng, painted)
        keys = jax.random.split(jax.random.PRNGKey(seed * 100_000 + it), BATCH)
        trainable, opt, l = step(trainable, opt, xyz, feats, fg, gt, keys, jnp.float32(lr))
        if it % 60 == 0 or it == steps - 1:
            log(f"  [det/{name}] step {it:4d} loss {float(l):.4f} ({time.time()-t0:.0f}s)")
    if head == "attn":
        return trainable  # (det_params, attn_params)
    return trainable


# ---------------------------------------------------------------------------
# Weight (de)serialization — flat npz with path-encoded keys
# ---------------------------------------------------------------------------


def flatten_params(tree, prefix="") -> Dict[str, np.ndarray]:
    out = {}
    if isinstance(tree, dict):
        for k, v in tree.items():
            if k == "painted":
                out[f"{prefix}{k}"] = np.array(1 if v else 0)
            else:
                out.update(flatten_params(v, f"{prefix}{k}/"))
    elif isinstance(tree, (list, tuple)):
        for i, v in enumerate(tree):
            out.update(flatten_params(v, f"{prefix}{i}/"))
    else:
        out[prefix.rstrip("/")] = np.asarray(tree)
    return out


def save_params(path: str, tree):
    np.savez(path, **flatten_params(tree))


def _set_path(d, keys, val):
    k = keys[0]
    if len(keys) == 1:
        d[k] = val
        return
    d.setdefault(k, {})
    _set_path(d[k], keys[1:], val)


def load_params(path: str):
    """Inverse of save_params: rebuilds dicts; integer-keyed dicts -> lists
    of (w, b) tuples (matching _mlp_init / _dense_init layout)."""
    raw = np.load(path)
    nest: Dict = {}
    for k in raw.files:
        if k == "painted":
            nest["painted"] = bool(raw[k])
            continue
        _set_path(nest, k.split("/"), jnp.asarray(raw[k]))

    def fix(node):
        if isinstance(node, dict):
            keys = list(node.keys())
            if keys and all(k.isdigit() for k in keys):
                items = [fix(node[str(i)]) for i in range(len(keys))]
                # (w, b) pairs are dicts {0: w, 1: b} -> tuples
                if len(items) == 2 and all(not isinstance(x, (list, tuple)) for x in items):
                    return (items[0], items[1])
                return items
            return {k: fix(v) for k, v in node.items()}
        return node

    return fix(nest)
