//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single contract between the build-time Python stack
//! and the Rust request path: artifact shapes + workload descriptors for the
//! device simulator, plus every model constant the coordinator needs
//! (SA configs, head layout, role groups, dataset parameters).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::quant::{Granularity, QuantSpec, StagePrecision};
use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub dataset: String,
    pub model: String,
    pub net: String,
    pub precision: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub flops: u64,
    pub bytes_in: u64,
    /// bytes per element on the interconnect (1 for int8 executables)
    pub wire_bytes_per_elem: u64,
    /// declared output element count (head/backbone widths differ wildly;
    /// wire/memory accounting must not use a magic constant). Older
    /// manifests without the field fall back to the historical 4096.
    pub out_elems: u64,
}

#[derive(Debug, Clone)]
pub struct SaConfig {
    pub m: usize,
    pub radius: f32,
    pub k: usize,
    pub mlp: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct DatasetMeta {
    pub num_points: usize,
    pub room_min: f64,
    pub room_max: f64,
    pub min_objects: usize,
    pub max_objects: usize,
    pub single_view: bool,
    pub depth_noise: f64,
    pub seg_noise: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct HeadLayout {
    pub center: (usize, usize),
    pub objectness: (usize, usize),
    pub heading_cls: (usize, usize),
    pub heading_reg: (usize, usize),
    pub size_cls: (usize, usize),
    pub size_reg: (usize, usize),
    pub sem_cls: (usize, usize),
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub classes: Vec<String>,
    pub mean_sizes: Vec<[f32; 3]>,
    pub num_heading_bin: usize,
    pub num_seg_classes: usize,
    pub img_size: usize,
    pub sa_configs: Vec<SaConfig>,
    pub num_seeds: usize,
    pub num_proposals: usize,
    pub proposal_radius: f32,
    pub proposal_k: usize,
    pub seed_feat: usize,
    pub fp_in: usize,
    pub feat_dim_painted: usize,
    pub feat_dim_plain: usize,
    pub head_layout: HeadLayout,
    pub role_groups_vote: Vec<Vec<usize>>,
    pub role_groups_prop: Vec<Vec<usize>>,
    pub quant_param_count: HashMap<String, usize>,
    /// (params, madds) for orig / pointsplit FP stage at mini & paper scale
    pub fp_layer_cost_mini: ((u64, u64), (u64, u64)),
    pub fp_layer_cost_paper: ((u64, u64), (u64, u64)),
    pub datasets: HashMap<String, DatasetMeta>,
    pub default_w0: f32,
    pub default_bias_layers: usize,
    pub artifacts: Vec<ArtifactMeta>,
    by_name: HashMap<String, usize>,
}

// Fallible typed readers over [`Json`]. `Manifest::parse` consumes an
// externally-written file, so every missing key and shape mismatch must
// surface as a recoverable error naming the offending key — never a panic.

fn req<'a>(j: &'a Json, key: &str) -> Result<&'a Json> {
    j.get(key).ok_or_else(|| anyhow!("manifest: missing required key '{key}'"))
}

fn str_field(j: &Json, key: &str) -> Result<String> {
    Ok(req(j, key)?
        .as_str()
        .ok_or_else(|| anyhow!("manifest: '{key}' must be a string"))?
        .to_string())
}

fn usize_field(j: &Json, key: &str) -> Result<usize> {
    req(j, key)?.as_usize().ok_or_else(|| anyhow!("manifest: '{key}' must be a number"))
}

fn f64_field(j: &Json, key: &str) -> Result<f64> {
    req(j, key)?.as_f64().ok_or_else(|| anyhow!("manifest: '{key}' must be a number"))
}

fn bool_field(j: &Json, key: &str) -> Result<bool> {
    req(j, key)?.as_bool().ok_or_else(|| anyhow!("manifest: '{key}' must be a boolean"))
}

fn arr_field<'a>(j: &'a Json, key: &str) -> Result<&'a [Json]> {
    req(j, key)?.as_arr().ok_or_else(|| anyhow!("manifest: '{key}' must be an array"))
}

fn f64s(j: &Json, ctx: &str) -> Result<Vec<f64>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("manifest: '{ctx}' must be an array"))?
        .iter()
        .map(|v| v.as_f64().ok_or_else(|| anyhow!("manifest: '{ctx}' must hold numbers")))
        .collect()
}

fn usizes(j: &Json, ctx: &str) -> Result<Vec<usize>> {
    Ok(f64s(j, ctx)?.into_iter().map(|x| x as usize).collect())
}

fn pair(j: &Json, ctx: &str) -> Result<(usize, usize)> {
    let v = usizes(j, ctx)?;
    if v.len() != 2 {
        return Err(anyhow!("manifest: '{ctx}' must be a [lo, hi] pair, got {} entries", v.len()));
    }
    Ok((v[0], v[1]))
}

fn cost_pair(j: &Json, ctx: &str) -> Result<((u64, u64), (u64, u64))> {
    let o = f64s(req(j, "orig")?, ctx)?;
    let p = f64s(req(j, "pointsplit")?, ctx)?;
    if o.len() != 2 || p.len() != 2 {
        return Err(anyhow!("manifest: '{ctx}' entries must be [params, madds] pairs"));
    }
    Ok(((o[0] as u64, o[1] as u64), (p[0] as u64, p[1] as u64)))
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let classes = arr_field(&j, "classes")?
            .iter()
            .map(|c| {
                Ok(c.as_str()
                    .ok_or_else(|| anyhow!("manifest: 'classes' must hold strings"))?
                    .to_string())
            })
            .collect::<Result<Vec<String>>>()?;
        let mean_sizes = arr_field(&j, "mean_sizes")?
            .iter()
            .map(|s| {
                let v = f64s(s, "mean_sizes")?;
                if v.len() != 3 {
                    return Err(anyhow!("manifest: each mean size must be [l, w, h]"));
                }
                Ok([v[0] as f32, v[1] as f32, v[2] as f32])
            })
            .collect::<Result<Vec<_>>>()?;
        let sa_configs = arr_field(&j, "sa_configs")?
            .iter()
            .map(|s| {
                Ok(SaConfig {
                    m: usize_field(s, "m")?,
                    radius: f64_field(s, "radius")? as f32,
                    k: usize_field(s, "k")?,
                    mlp: usizes(req(s, "mlp")?, "sa_configs.mlp")?,
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let hl = req(&j, "head_layout")?;
        let head_layout = HeadLayout {
            center: pair(req(hl, "center")?, "head_layout.center")?,
            objectness: pair(req(hl, "objectness")?, "head_layout.objectness")?,
            heading_cls: pair(req(hl, "heading_cls")?, "head_layout.heading_cls")?,
            heading_reg: pair(req(hl, "heading_reg")?, "head_layout.heading_reg")?,
            size_cls: pair(req(hl, "size_cls")?, "head_layout.size_cls")?,
            size_reg: pair(req(hl, "size_reg")?, "head_layout.size_reg")?,
            sem_cls: pair(req(hl, "sem_cls")?, "head_layout.sem_cls")?,
        };
        let rg = req(&j, "role_groups")?;
        let groups = |key: &str| -> Result<Vec<Vec<usize>>> {
            arr_field(rg, key)?.iter().map(|g| usizes(g, "role_groups")).collect()
        };
        let quant_param_count = req(&j, "quant_param_count")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: 'quant_param_count' must be an object"))?
            .iter()
            .map(|(k, v)| {
                let n = v.as_usize().ok_or_else(|| {
                    anyhow!("manifest: 'quant_param_count.{k}' must be a number")
                })?;
                Ok((k.clone(), n))
            })
            .collect::<Result<HashMap<_, _>>>()?;
        let datasets = req(&j, "datasets")?
            .as_obj()
            .ok_or_else(|| anyhow!("manifest: 'datasets' must be an object"))?
            .iter()
            .map(|(k, v)| {
                Ok((
                    k.clone(),
                    DatasetMeta {
                        num_points: usize_field(v, "num_points")?,
                        room_min: f64_field(v, "room_min")?,
                        room_max: f64_field(v, "room_max")?,
                        min_objects: usize_field(v, "min_objects")?,
                        max_objects: usize_field(v, "max_objects")?,
                        single_view: bool_field(v, "single_view")?,
                        depth_noise: f64_field(v, "depth_noise")?,
                        seg_noise: f64_field(v, "seg_noise")?,
                    },
                ))
            })
            .collect::<Result<HashMap<_, _>>>()?;
        let artifacts = arr_field(&j, "artifacts")?
            .iter()
            .map(|a| {
                Ok(ArtifactMeta {
                    name: str_field(a, "name")?,
                    file: str_field(a, "file")?,
                    dataset: str_field(a, "dataset")?,
                    model: str_field(a, "model")?,
                    net: str_field(a, "net")?,
                    precision: str_field(a, "precision")?,
                    input_shapes: arr_field(a, "inputs")?
                        .iter()
                        .map(|i| usizes(req(i, "shape")?, "artifacts.inputs.shape"))
                        .collect::<Result<Vec<_>>>()?,
                    flops: f64_field(a, "flops")? as u64,
                    bytes_in: f64_field(a, "bytes_in")? as u64,
                    wire_bytes_per_elem: f64_field(a, "wire_bytes_per_elem")? as u64,
                    out_elems: a
                        .get("out_elems")
                        .and_then(|v| v.as_f64())
                        .map(|v| v as u64)
                        .unwrap_or(4096),
                })
            })
            .collect::<Result<Vec<ArtifactMeta>>>()?;
        let by_name = artifacts.iter().enumerate().map(|(i, a)| (a.name.clone(), i)).collect();
        let fpc = req(&j, "fp_layer_cost")?;
        Ok(Manifest {
            classes,
            mean_sizes,
            num_heading_bin: usize_field(&j, "num_heading_bin")?,
            num_seg_classes: usize_field(&j, "num_seg_classes")?,
            img_size: usize_field(&j, "img_size")?,
            sa_configs,
            num_seeds: usize_field(&j, "num_seeds")?,
            num_proposals: usize_field(&j, "num_proposals")?,
            proposal_radius: f64_field(&j, "proposal_radius")? as f32,
            proposal_k: usize_field(&j, "proposal_k")?,
            seed_feat: usize_field(&j, "seed_feat")?,
            fp_in: usize_field(&j, "fp_in")?,
            feat_dim_painted: usize_field(&j, "feat_dim_painted")?,
            feat_dim_plain: usize_field(&j, "feat_dim_plain")?,
            head_layout,
            role_groups_vote: groups("vote")?,
            role_groups_prop: groups("prop")?,
            quant_param_count,
            fp_layer_cost_mini: cost_pair(req(fpc, "mini")?, "fp_layer_cost.mini")?,
            fp_layer_cost_paper: cost_pair(req(fpc, "paper_scale")?, "fp_layer_cost.paper_scale")?,
            datasets,
            default_w0: f64_field(&j, "default_w0")? as f32,
            default_bias_layers: usize_field(&j, "default_bias_layers")?,
            artifacts,
            by_name,
        })
    }

    /// Build a fully synthetic manifest mirroring the python/compile
    /// constants (common.py SA_CONFIGS, head layout, aot.py FLOP formulas).
    ///
    /// This is the contract the serving gateway's analytic planner runs on
    /// when `artifacts/manifest.json` has not been exported: every artifact
    /// name the coordinator can reference resolves, with the same workload
    /// descriptors `aot.py` would write. Functional execution still requires
    /// the real exported artifacts — the synthetic manifest only feeds the
    /// calibrated device simulator.
    pub fn synthetic() -> Manifest {
        // VoteNet-mini architecture (python/compile/common.py)
        let sa_m = [256usize, 128, 64, 32];
        let sa_r = [0.3f32, 0.6, 1.2, 2.4];
        let sa_k = [32usize, 16, 8, 8];
        let sa_mlp: [&[usize]; 4] = [&[32, 32, 64], &[64, 64, 128], &[96, 96, 128], &[128, 128, 128]];
        let num_class = crate::data::NUM_CLASS;
        let num_seg_classes = num_class + 1;
        let num_heading_bin = 12usize;
        let (num_seeds, num_proposals, proposal_k) = (128usize, 32usize, 8usize);
        let seed_feat = 128usize;
        let fp_in = sa_mlp[1][2] + sa_mlp[2][2] + sa_mlp[3][2]; // 384
        let feat_dim_painted = 1 + num_seg_classes;
        let feat_dim_plain = 1usize;
        let vote_ch = 3 + seed_feat; // 131
        let proposal_ch = 3 + 2 + 2 * num_heading_bin + num_class + 3 * num_class + num_class; // 79

        // head channel layout (common.py SLICE_*)
        let head_layout = HeadLayout {
            center: (0, 3),
            objectness: (3, 5),
            heading_cls: (5, 5 + num_heading_bin),
            heading_reg: (17, 17 + num_heading_bin),
            size_cls: (29, 29 + num_class),
            size_reg: (39, 39 + 3 * num_class),
            sem_cls: (69, 69 + num_class),
        };
        let role_groups_vote = vec![(0..3).collect::<Vec<_>>(), (3..vote_ch).collect()];
        let role_groups_prop = vec![
            (0..3).collect::<Vec<_>>(),
            (3..5).chain(5..17).chain(29..39).chain(69..79).collect(),
            (17..29).chain(39..69).collect::<Vec<_>>(),
        ];
        // quantize.quant_param_count: 3 params per channel group, heads only
        let quant_param_count: HashMap<String, usize> = [
            ("layer".to_string(), 3 * 2),
            ("group".to_string(), 3 * (2 + 3)),
            ("channel".to_string(), 3 * (vote_ch + proposal_ch)),
            ("role".to_string(), 3 * (2 + 3)),
        ]
        .into_iter()
        .collect();

        // model.fp_layer_cost at both scales
        let fp_cost = |fps: &[&[(usize, usize)]], ns: &[usize], ps: &[(usize, usize)], n_ps: usize| {
            let mut p_orig = 0u64;
            let mut m_orig = 0u64;
            for (layers, &n) in fps.iter().zip(ns) {
                for &(ci, co) in *layers {
                    p_orig += (ci * co + co) as u64;
                    m_orig += (ci * co * n) as u64;
                }
            }
            let p_ps: u64 = ps.iter().map(|&(ci, co)| (ci * co + co) as u64).sum();
            let m_ps: u64 = ps.iter().map(|&(ci, co)| (ci * co * n_ps) as u64).sum();
            ((p_orig, m_orig), (p_ps, m_ps))
        };
        let mini_fp: [&[(usize, usize)]; 2] =
            [&[(fp_in - sa_mlp[1][2], 128), (128, 128)], &[(128 + 128, 128), (128, 128)]];
        let fp_layer_cost_mini = fp_cost(&mini_fp, &[64, num_seeds], &[(fp_in, seed_feat)], num_seeds);
        let paper_fp: [&[(usize, usize)]; 2] = [&[(512, 256), (256, 256)], &[(512, 256), (256, 256)]];
        let fp_layer_cost_paper = fp_cost(&paper_fp, &[512, 1024], &[(512, 384)], 1024);

        let datasets: HashMap<String, DatasetMeta> = ["synrgbd", "synscan"]
            .iter()
            .map(|name| {
                // infallible: both names are compiled-in data::DATASETS keys
                let d = crate::data::dataset(name).expect("builtin dataset");
                (
                    name.to_string(),
                    DatasetMeta {
                        num_points: d.num_points,
                        room_min: d.room_min,
                        room_max: d.room_max,
                        min_objects: d.min_objects,
                        max_objects: d.max_objects,
                        single_view: d.single_view,
                        depth_noise: d.depth_noise,
                        seg_noise: d.seg_noise,
                    },
                )
            })
            .collect();

        // aot.py mlp_flops: n rows through a dense chain
        let mlp_flops = |n: usize, widths: &[usize]| -> u64 {
            widths.windows(2).map(|w| 2 * n as u64 * (w[0] * w[1]) as u64).sum()
        };
        // aot.py conv_flops: encoder-decoder segmenter at 64x64
        let seg_flops = {
            let c = [16u64, 32, 48, 64];
            let hw = (crate::data::IMG_SIZE * crate::data::IMG_SIZE) as u64;
            2 * hw * 9 * 3 * c[0]
                + 2 * (hw / 4) * 9 * c[0] * c[1]
                + 2 * (hw / 16) * 9 * c[1] * c[2]
                + 2 * (hw / 16) * 9 * c[2] * c[3]
                + 2 * (hw / 4) * 9 * c[3] * c[1]
                + 2 * hw * 9 * (c[1] + c[1]) * c[0]
                + 2 * hw * (c[0] + c[0]) * num_seg_classes as u64
        };

        let mut artifacts: Vec<ArtifactMeta> = Vec::new();
        let mut add = |name: String,
                       dataset: &str,
                       model: &str,
                       net: &str,
                       precision: &str,
                       shape: Vec<usize>,
                       flops: u64,
                       out_elems: u64| {
            let bytes_in = shape.iter().product::<usize>() as u64 * 4;
            artifacts.push(ArtifactMeta {
                file: format!("{name}.hlo.txt"),
                name,
                dataset: dataset.to_string(),
                model: model.to_string(),
                net: net.to_string(),
                precision: precision.to_string(),
                input_shapes: vec![shape],
                flops,
                bytes_in,
                wire_bytes_per_elem: if precision.contains("int8") { 1 } else { 4 },
                out_elems,
            });
        };

        let backbone_precs = ["fp32", "int8"];
        let head_precs = ["fp32", "int8_layer", "int8_group", "int8_channel", "int8_role"];
        for ds in ["synrgbd", "synscan"] {
            for prec in backbone_precs {
                add(
                    format!("{ds}_seg_{prec}"),
                    ds,
                    "seg",
                    "seg",
                    prec,
                    vec![crate::data::IMG_SIZE, crate::data::IMG_SIZE, 3],
                    seg_flops,
                    (crate::data::IMG_SIZE * crate::data::IMG_SIZE * num_seg_classes) as u64,
                );
            }
            for model in ["votenet", "painted", "pointsplit"] {
                let feat = if model == "votenet" { feat_dim_plain } else { feat_dim_painted };
                let cin_per_level = [feat, sa_mlp[0][2], sa_mlp[1][2], sa_mlp[2][2]];
                for prec in backbone_precs {
                    for l in 0..4 {
                        let cin = 3 + cin_per_level[l];
                        let mut widths = vec![cin];
                        widths.extend_from_slice(sa_mlp[l]);
                        for shape in ["full", "half"] {
                            if l == 3 && shape == "half" {
                                continue; // SA4 runs on the fused set only
                            }
                            let b = if shape == "half" { sa_m[l] / 2 } else { sa_m[l] };
                            let net = format!("sa{}_{shape}", l + 1);
                            add(
                                format!("{ds}_{model}_{net}_{prec}"),
                                ds,
                                model,
                                &net,
                                prec,
                                vec![b, sa_k[l], cin],
                                mlp_flops(b * sa_k[l], &widths),
                                (b * sa_mlp[l][2]) as u64,
                            );
                        }
                    }
                    add(
                        format!("{ds}_{model}_fp_fc_{prec}"),
                        ds,
                        model,
                        "fp_fc",
                        prec,
                        vec![num_seeds, fp_in],
                        mlp_flops(num_seeds, &[fp_in, seed_feat]),
                        (num_seeds * seed_feat) as u64,
                    );
                }
                for prec in head_precs {
                    add(
                        format!("{ds}_{model}_vote_{prec}"),
                        ds,
                        model,
                        "vote",
                        prec,
                        vec![num_seeds, seed_feat],
                        mlp_flops(num_seeds, &[seed_feat, 128, 128, vote_ch]),
                        (num_seeds * vote_ch) as u64,
                    );
                    add(
                        format!("{ds}_{model}_prop_{prec}"),
                        ds,
                        model,
                        "prop",
                        prec,
                        vec![num_proposals, proposal_k, 3 + seed_feat],
                        mlp_flops(num_proposals * proposal_k, &[3 + seed_feat, 128, 64])
                            + mlp_flops(num_proposals, &[64, 64, proposal_ch]),
                        (num_proposals * proposal_ch) as u64,
                    );
                }
            }
        }

        let by_name = artifacts.iter().enumerate().map(|(i, a)| (a.name.clone(), i)).collect();
        Manifest {
            classes: crate::data::CLASS_NAMES.iter().map(|c| c.to_string()).collect(),
            mean_sizes: vec![
                [1.85, 1.65, 0.50],
                [1.40, 0.85, 0.72],
                [1.85, 0.90, 0.75],
                [0.48, 0.48, 0.85],
                [0.40, 0.55, 0.75],
                [1.30, 0.70, 0.74],
                [1.00, 0.50, 0.95],
                [0.50, 0.50, 0.60],
                [0.80, 0.30, 1.75],
                [1.60, 0.80, 0.55],
            ],
            num_heading_bin,
            num_seg_classes,
            img_size: crate::data::IMG_SIZE,
            sa_configs: (0..4)
                .map(|l| SaConfig {
                    m: sa_m[l],
                    radius: sa_r[l],
                    k: sa_k[l],
                    mlp: sa_mlp[l].to_vec(),
                })
                .collect(),
            num_seeds,
            num_proposals,
            proposal_radius: 0.6,
            proposal_k,
            seed_feat,
            fp_in,
            feat_dim_painted,
            feat_dim_plain,
            head_layout,
            role_groups_vote,
            role_groups_prop,
            quant_param_count,
            fp_layer_cost_mini,
            fp_layer_cost_paper,
            datasets,
            default_w0: 2.0,
            default_bias_layers: 2,
            artifacts,
            by_name,
        }
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    /// Resolve an artifact by (dataset, model, net, precision).
    pub fn find(&self, dataset: &str, model: &str, net: &str, precision: &str) -> Option<&ArtifactMeta> {
        self.artifact(&format!("{dataset}_{model}_{net}_{precision}"))
    }

    pub fn num_class(&self) -> usize {
        self.classes.len()
    }

    /// Output channel count and declared role partition of a network role
    /// (`"vote"`, `"prop"`, `"seg"`, `"fp_fc"`, `"sa1_full"`, ...). The head
    /// partitions come from the manifest's role groups; other stages have no
    /// declared roles (a `Role` spec derives them from data at calibration).
    pub fn stage_channels(&self, net: &str) -> (usize, Vec<Vec<usize>>) {
        match net {
            "vote" => (3 + self.seed_feat, self.role_groups_vote.clone()),
            "prop" => (self.head_layout.sem_cls.1, self.role_groups_prop.clone()),
            "seg" => (self.num_seg_classes, Vec::new()),
            "fp_fc" => (self.seed_feat, Vec::new()),
            n if n.starts_with("sa") => {
                // defensive slice: a manifest net label of bare "sa" must
                // not panic the request path
                let level = n.get(2..3).and_then(|d| d.parse::<usize>().ok()).unwrap_or(1);
                let cout = self
                    .sa_configs
                    .get(level.saturating_sub(1))
                    .and_then(|s| s.mlp.last().copied())
                    .unwrap_or(1);
                (cout, Vec::new())
            }
            _ => (1, Vec::new()),
        }
    }

    /// Per-stage quant spec the manifest declares for an artifact, with the
    /// stage executed at `precision` (the QuantScheme override point — the
    /// serving degrade path runs "int8" backbone artifacts at an even-group
    /// granularity the artifact name does not encode).
    pub fn stage_quant_for(&self, meta: &ArtifactMeta, precision: StagePrecision) -> QuantSpec {
        let (cout, roles) = self.stage_channels(&meta.net);
        // an even-group head follows its role count, matching
        // quantize.quant_param_count's group accounting
        let precision = match precision {
            StagePrecision::Int8(Granularity::Group(_)) if !roles.is_empty() => {
                StagePrecision::Int8(Granularity::Group(roles.len()))
            }
            p => p,
        };
        QuantSpec::new(precision, cout, roles)
    }

    /// Per-stage quant spec at the artifact's own precision label.
    pub fn stage_quant(&self, meta: &ArtifactMeta) -> QuantSpec {
        let precision = StagePrecision::parse(&meta.precision).unwrap_or(StagePrecision::Fp32);
        self.stage_quant_for(meta, precision)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn synthetic_manifest_is_complete() {
        let m = Manifest::synthetic();
        assert_eq!(m.num_class(), 10);
        assert_eq!(m.num_seg_classes, 11);
        assert_eq!(m.sa_configs.len(), 4);
        assert_eq!(m.fp_in, 384);
        assert_eq!(m.head_layout.sem_cls, (69, 79));
        assert_eq!(m.mean_sizes.len(), 10);
        assert_eq!(m.quant_param_count["channel"], 3 * (131 + 79));
        // every artifact name the coordinator can form must resolve
        for ds in ["synrgbd", "synscan"] {
            for prec in ["fp32", "int8"] {
                assert!(m.artifact(&format!("{ds}_seg_{prec}")).is_some());
            }
            for model in ["votenet", "painted", "pointsplit"] {
                for prec in ["fp32", "int8"] {
                    for net in ["sa1_full", "sa1_half", "sa2_half", "sa3_full", "sa4_full", "fp_fc"]
                    {
                        assert!(
                            m.find(ds, model, net, prec).is_some(),
                            "missing {ds}_{model}_{net}_{prec}"
                        );
                    }
                }
                for prec in ["fp32", "int8_layer", "int8_group", "int8_channel", "int8_role"] {
                    assert!(m.find(ds, model, "vote", prec).is_some());
                    assert!(m.find(ds, model, "prop", prec).is_some());
                }
            }
        }
        // aot.py formulas: fp_fc = 2 * 128 * 384 * 128 flops
        let fp = m.artifact("synrgbd_pointsplit_fp_fc_int8").unwrap();
        assert_eq!(fp.flops, 2 * 128 * 384 * 128);
        assert_eq!(fp.wire_bytes_per_elem, 1);
        assert_eq!(fp.out_elems, 128 * 128);
        let seg = m.artifact("synrgbd_seg_fp32").unwrap();
        assert_eq!(seg.input_shapes[0], vec![64, 64, 3]);
        assert_eq!(seg.wire_bytes_per_elem, 4);
        assert_eq!(seg.out_elems, (64 * 64 * 11) as u64);
        // per-artifact output widths, not a shared constant
        let vote = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap();
        assert_eq!(vote.out_elems, (128 * 131) as u64);
        let sa1 = m.artifact("synrgbd_pointsplit_sa1_full_int8").unwrap();
        assert_eq!(sa1.out_elems, (256 * 64) as u64);
        // no duplicate names
        let mut names: Vec<&str> = m.artifacts.iter().map(|a| a.name.as_str()).collect();
        let before = names.len();
        names.sort_unstable();
        names.dedup();
        assert_eq!(names.len(), before, "duplicate artifact names");
    }

    /// Regression (unwrap-audit satellite): a manifest file a user hands us
    /// is arbitrary input — malformed shapes must come back as errors that
    /// name the offending key, never panic the gateway.
    #[test]
    fn malformed_manifest_is_an_error_not_a_panic() {
        assert!(Manifest::parse("{").is_err(), "syntax error");
        let missing = format!("{:#}", Manifest::parse("{}").unwrap_err());
        assert!(missing.contains("classes"), "{missing}");
        let wrong_type = format!("{:#}", Manifest::parse(r#"{"classes": 3}"#).unwrap_err());
        assert!(wrong_type.contains("classes"), "{wrong_type}");
        // deep mismatch: a mean-size entry that is not an [l, w, h] triple
        let bad = r#"{"classes": ["a"], "mean_sizes": [[1, 2]]}"#;
        let e = format!("{:#}", Manifest::parse(bad).unwrap_err());
        assert!(e.contains("mean size"), "{e}");
    }

    #[test]
    fn stage_quant_declares_per_stage_specs() {
        use crate::quant::{Granularity, StagePrecision};
        let m = Manifest::synthetic();
        // role heads carry the declared partitions over the right widths
        let vote = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap();
        let sv = m.stage_quant(vote);
        assert_eq!(sv.precision, StagePrecision::Int8(Granularity::Role));
        assert_eq!(sv.cout, 131);
        assert_eq!(sv.roles, m.role_groups_vote);
        let covered: usize = sv.roles.iter().map(|g| g.len()).sum();
        assert_eq!(covered, sv.cout, "vote role partition must cover all channels");
        let prop = m.artifact("synrgbd_pointsplit_prop_int8_role").unwrap();
        let sp = m.stage_quant(prop);
        assert_eq!(sp.cout, 79);
        assert_eq!(sp.roles.iter().map(|g| g.len()).sum::<usize>(), 79);
        // group heads follow their role count (param-count parity)
        let pg = m.artifact("synrgbd_pointsplit_prop_int8_group").unwrap();
        assert_eq!(
            m.stage_quant(pg).precision,
            StagePrecision::Int8(Granularity::Group(3))
        );
        // backbone "int8" is layer-wise by default, overridable per call
        let sa = m.artifact("synrgbd_pointsplit_sa1_full_int8").unwrap();
        assert_eq!(m.stage_quant(sa).precision, StagePrecision::Int8(Granularity::Layer));
        assert_eq!(m.stage_quant(sa).cout, 64);
        let over = m.stage_quant_for(sa, StagePrecision::Int8(Granularity::Group(4)));
        assert_eq!(over.precision, StagePrecision::Int8(Granularity::Group(4)));
        // fp32 artifacts quantize nothing
        let fp = m.artifact("synrgbd_pointsplit_vote_fp32").unwrap();
        assert_eq!(m.stage_quant(fp).precision, StagePrecision::Fp32);
        assert_eq!(m.stage_quant(fp).param_count(), 0);
    }
}
