//! GroupFree3D-mini execution path (Table 8): PointNet++ backbone +
//! transformer decoder head. Accuracy-focused (no timeline) — the paper's
//! Table 8 evaluates mAP only, explicitly excluding the efficiency
//! machinery (two FP PointNets are restored, no quantization).

use anyhow::Result;

use crate::data::{Box3, Scene};
use crate::pointops;
use crate::runtime::Runtime;
use crate::util::tensor::Tensor;

use super::decode::decode_detections;

/// Table 8 configurations for the attention detector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AttnVariant {
    /// GroupFree3D-mini baseline (no 2D fusion)
    Baseline,
    /// + PointPainting (painted, full sampling)
    Painted,
    /// + RandomSplit (painted weights, random halves)
    RandomSplit,
    /// + PointSplit (split sampling with biased FPS)
    Split,
}

impl AttnVariant {
    pub fn model_name(&self) -> &'static str {
        match self {
            AttnVariant::Baseline => "attn_plain",
            AttnVariant::Painted | AttnVariant::RandomSplit => "attn_painted",
            AttnVariant::Split => "attn_split",
        }
    }

    pub fn painted(&self) -> bool {
        !matches!(self, AttnVariant::Baseline)
    }

    pub fn name(&self) -> &'static str {
        match self {
            AttnVariant::Baseline => "GroupFree3D-mini",
            AttnVariant::Painted => "+ PointPainting",
            AttnVariant::RandomSplit => "+ RandomSplit",
            AttnVariant::Split => "+ PointSplit",
        }
    }
}

/// Run one scene through the attention detector. Only exists for the
/// primary dataset's attn artifacts.
pub fn run_attn(
    rt: &Runtime,
    variant: AttnVariant,
    scene: &Scene,
    w0: f32,
    seed: u64,
) -> Result<Vec<Box3>> {
    let m = &rt.manifest;
    let model = variant.model_name();
    let art = |net: &str| format!("synrgbd_{model}_{net}_fp32");

    // paint
    let (paint, fg) = if variant.painted() {
        let img = Tensor::new(vec![m.img_size, m.img_size, 3], scene.image.clone());
        let scores2d = rt.run(&format!("synrgbd_seg_fp32"), &[&img])?.remove(0);
        let paint = pointops::paint_points(scene, &scores2d);
        let fg = pointops::fg_mask(&paint, 0.5);
        (Some(paint), fg)
    } else {
        (None, vec![0.0; scene.points.len()])
    };
    let feats = pointops::build_features(scene, paint.as_ref());

    // backbone (split only for the Split/RandomSplit variants)
    let split = matches!(variant, AttnVariant::Split | AttnVariant::RandomSplit);
    let run_chain = |xyz0: Vec<[f32; 3]>, feats0: Tensor, fg0: Vec<f32>, biased: bool| -> Result<_> {
        let mut xyz = xyz0;
        let mut f = feats0;
        let mut fgv = fg0;
        let mut levels = Vec::new();
        for l in 0..3 {
            let sac = &m.sa_configs[l];
            let mm = if split { sac.m / 2 } else { sac.m };
            let start = if biased && l == 0 { xyz.len() / 2 } else { 0 };
            let idx = if biased && l < 2 {
                pointops::biased_fps_from(&xyz, mm, &fgv, w0, start)
            } else {
                pointops::fps_from(&xyz, mm, start)
            };
            let groups = pointops::ball_query(&xyz, &idx, sac.radius, sac.k);
            let g = pointops::group_features(&xyz, Some(&f), &idx, &groups);
            let shape = if split { "half" } else { "full" };
            // attn models exported half shapes only for the split variant
            let name = art(&format!("sa{}_{}", l + 1, shape));
            let name = if rt.manifest.artifact(&name).is_some() {
                name
            } else {
                art(&format!("sa{}_full", l + 1))
            };
            let meta = rt.manifest.artifact(&name).unwrap();
            let want = meta.input_shapes[0][0];
            let out = if want == g.shape[0] {
                rt.run(&name, &[&g])?.remove(0)
            } else {
                let mut padded = Tensor::zeros(vec![want, g.shape[1], g.shape[2]]);
                padded.data[..g.data.len()].copy_from_slice(&g.data);
                let o = rt.run(&name, &[&padded])?.remove(0);
                o.gather_rows(&(0..g.shape[0]).collect::<Vec<_>>())
            };
            xyz = idx.iter().map(|&i| xyz[i]).collect();
            fgv = idx.iter().map(|&i| fgv[i]).collect();
            f = out;
            levels.push((xyz.clone(), f.clone()));
        }
        Ok(levels)
    };

    let (sa2, sa3) = if split {
        let (xa, fa, ga, xb, fb, gb) = if variant == AttnVariant::RandomSplit {
            let mut rng = crate::util::rng::Rng::new(seed ^ 0xB5);
            let perm = rng.choice_no_replace(scene.points.len(), scene.points.len());
            let half = scene.points.len() / 2;
            let pick = |idx: &[usize]| {
                (
                    idx.iter().map(|&i| scene.points[i]).collect::<Vec<_>>(),
                    feats.gather_rows(idx),
                    idx.iter().map(|&i| fg[i]).collect::<Vec<_>>(),
                )
            };
            let a = pick(&perm[..half]);
            let b = pick(&perm[half..]);
            (a.0, a.1, a.2, b.0, b.1, b.2)
        } else {
            (
                scene.points.clone(),
                feats.clone(),
                fg.clone(),
                scene.points.clone(),
                feats.clone(),
                fg.clone(),
            )
        };
        let la = run_chain(xa, fa, ga, false)?;
        let lb = run_chain(xb, fb, gb, variant == AttnVariant::Split)?;
        let cat = |i: usize| {
            let mut xyz = la[i].0.clone();
            xyz.extend_from_slice(&lb[i].0);
            (xyz, Tensor::concat0(&[&la[i].1, &lb[i].1]))
        };
        (cat(1), cat(2))
    } else {
        let levels = run_chain(scene.points.clone(), feats, fg, false)?;
        (levels[1].clone(), levels[2].clone())
    };

    // SA4 + FP + attention head
    let sac4 = &m.sa_configs[3];
    let idx4 = pointops::fps(&sa3.0, sac4.m);
    let groups4 = pointops::ball_query(&sa3.0, &idx4, sac4.radius, sac4.k);
    let g4 = pointops::group_features(&sa3.0, Some(&sa3.1), &idx4, &groups4);
    let sa4_feats = rt.run(&art("sa4_full"), &[&g4])?.remove(0);
    let sa4_xyz: Vec<[f32; 3]> = idx4.iter().map(|&i| sa3.0[i]).collect();

    let f3up = pointops::three_nn_interpolate(&sa3.0, &sa4_xyz, &sa4_feats);
    let f3 = hcat(&sa3.1, &f3up);
    let f2up = pointops::three_nn_interpolate(&sa2.0, &sa3.0, &f3);
    let f2 = hcat(&sa2.1, &f2up);
    let seeds = rt.run(&art("fp_fc"), &[&f2])?.remove(0);

    let proj = rt.run(&art("attn_proj"), &[&seeds])?.remove(0);
    let cand_idx = pointops::fps(&sa2.0, m.num_proposals);
    let cand = proj.gather_rows(&cand_idx);
    let out = rt.run(&art("attn_decode"), &[&cand, &proj])?.remove(0);
    let centers: Vec<[f32; 3]> = cand_idx.iter().map(|&i| sa2.0[i]).collect();
    Ok(decode_detections(m, &centers, &out, 0.01, 0.25))
}

fn hcat(a: &Tensor, b: &Tensor) -> Tensor {
    let (ca, cb) = (a.row_len(), b.row_len());
    let mut data = Vec::with_capacity(a.rows() * (ca + cb));
    for i in 0..a.rows() {
        data.extend_from_slice(a.row(i));
        data.extend_from_slice(b.row(i));
    }
    Tensor::new(vec![a.rows(), ca + cb], data)
}
