//! Hardware-configuration sweep (Fig. 10 analog): how much does PointSplit's
//! pipelining buy on each processor pairing, and where is the crossover?
//!
//! ```bash
//! cargo run --release --example hw_sweep -- [scenes]
//! ```

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::runtime::Runtime;
use pointsplit::sim::DeviceKind;

fn main() -> anyhow::Result<()> {
    let scenes: usize =
        std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(4);
    let rt = Runtime::open("artifacts")?;
    let pairs = [
        ("CPU-CPU", DeviceKind::Cpu, DeviceKind::Cpu),
        ("CPU-EdgeTPU", DeviceKind::Cpu, DeviceKind::EdgeTpu),
        ("GPU-CPU", DeviceKind::Gpu, DeviceKind::Cpu),
        ("GPU-EdgeTPU", DeviceKind::Gpu, DeviceKind::EdgeTpu),
    ];
    let mut table =
        Table::new(&["config", "PointPainting (ms)", "PointSplit (ms)", "speedup"]);
    for (name, point_dev, nn_dev) in pairs {
        let mut pp = 0.0;
        let mut ps = 0.0;
        for seed in 0..scenes as u64 {
            let scene = generate_scene(seed + 31, &SYNRGBD);
            let cfg_pp = DetectorConfig::new(
                "synrgbd",
                Variant::PointPainting,
                true,
                Schedule::Sequential { point_dev, nn_dev },
            );
            let cfg_ps = DetectorConfig::new(
                "synrgbd",
                Variant::PointSplit,
                true,
                Schedule::Pipelined { point_dev, nn_dev },
            );
            pp += ScenePipeline::new(&rt, cfg_pp).run(&scene, seed)?.timeline.total_ms;
            ps += ScenePipeline::new(&rt, cfg_ps).run(&scene, seed)?.timeline.total_ms;
        }
        pp /= scenes as f64;
        ps /= scenes as f64;
        table.row(vec![
            name.to_string(),
            format!("{pp:.0}"),
            format!("{ps:.0}"),
            format!("{:.2}x", pp / ps),
        ]);
    }
    table.print("per-scene latency across processor pairings (Fig. 10 analog, INT8)");
    println!("\npaper: PointSplit helps on EVERY pairing; largest gains on CPU-CPU and CPU-EdgeTPU (1.7x / 1.8x).");
    Ok(())
}
