//! Fleet-scale cluster serving: shard the gateway across heterogeneous
//! edge boxes.
//!
//! The paper proves one GPU+EdgeTPU box runs the fused detector 24.7×
//! faster than a GPU-only device — but one box caps out at its
//! `capacity_rps`. This layer scales *out*: a [`ClusterSpec`] describes N
//! boxes with different accelerator mixes (GPU-only, GPU+EdgeTPU,
//! CPU+EdgeTPU, …), each box gets its per-config [`Schedule`] from the
//! placement search (`graph::place::best_schedule` — the same pass behind
//! `plan-search`), and a [`Router`] spreads admitted traffic over the
//! fleet.
//!
//! ```text
//!             arrivals (loadgen, virtual time)
//!                  │
//!                  ▼
//!              ┌────────┐   config-affinity + least-loaded
//!              │ Router │──────────────┬──────────────┐
//!              └────────┘              │              │
//!                  │                   │              │
//!            ┌───────────┐      ┌───────────┐   ┌───────────┐
//!            │ BoxEngine │      │ BoxEngine │   │ BoxEngine │
//!            │ gpu+tpu   │      │ gpu       │   │ cpu+tpu   │
//!            └───────────┘      └───────────┘   └───────────┘
//!              queue+batcher+SLO per box, one shared virtual clock
//! ```
//!
//! Routing is **config-affinity** by default: rendezvous hashing pins each
//! `DetectorConfig` key to a small set of boxes so their dynamic batchers
//! actually coalesce same-config requests (random routing scatters keys,
//! starving every batcher — pinned by `tests/cluster.rs`), with
//! least-loaded tie-breaking inside the affinity set. Fault injection
//! ([`inject`]) kills or slows boxes mid-run — a killed box's queue is
//! drained and rerouted, so no request is ever lost — and a reactive
//! autoscaler ([`autoscale`]) grows/shrinks the fleet on queue depth,
//! priced in per-box cost units.
//!
//! Everything runs on the simulated clock of `serving::dispatch`; see
//! `docs/CLUSTER.md` for the spec grammar and knobs.
//!
//! [`Schedule`]: crate::coordinator::Schedule
//! [`ClusterSpec`]: spec::ClusterSpec
//! [`Router`]: router::Router

pub mod autoscale;
pub mod inject;
pub mod metrics;
pub mod router;
pub mod run;
pub mod spec;

pub use autoscale::{AutoscalePolicy, ScaleDecision};
pub use inject::{Fault, FaultAction};
pub use metrics::{BoxReport, ClusterEvent, ClusterReport};
pub use router::{RouteTarget, Router, RouterPolicy};
pub use run::{run_cluster, ClusterScenario, ClusterTrace};
pub use spec::{config_mix, plan_box, BoxPlan, BoxType, ClusterSpec};
