//! Virtual-time dispatcher: drains the admission queue through the batcher
//! and SLO policy, charging every batch into the calibrated device timeline.
//!
//! The loop runs on the **simulated clock**. Each dispatched batch is costed
//! by the [`ServicePlanner`] (the same stage DAG `ScenePipeline` records,
//! scaled by batch size); its critical path sets request latency and its
//! bottleneck-device occupancy sets when the *next* batch may enter. That
//! second number is the two-lane overlap: while a batch's NPU tail is still
//! draining, the following batch's GPU point-manipulation front has already
//! started — exactly the Fig. 3 pipelining, applied across requests instead
//! of within one scene.
//!
//! The per-box state machine is [`BoxEngine`]: queue + batcher + SLO policy
//! + lane clock for one box, drivable event by event. [`run_traffic_trace`]
//! wraps a single engine in an arrival loop (the one-box gateway);
//! `cluster::run_cluster` drives one engine per box behind a router.
//!
//! A request's life ends in exactly one of four ways — completed, rejected
//! at admission, expired in queue, or shed by the SLO policy — and the
//! dispatcher emits one [`RequestOutcome`] per arrival (property-tested in
//! `rust/tests/proptests.rs`).

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::coordinator::{DetectorConfig, ScenePipeline};
use crate::data::{generate_scene, Box3, DatasetCfg};
use crate::eval::{eval_map, Detection};
use crate::exec::HostExec;
use crate::graph::{StageClass, StageGraph};
use crate::runtime::{Runtime, RuntimeSource};
use crate::sim::PlanCost;
use crate::temporal::FrameClass;
use crate::util::stats::Stats;
use crate::util::tensor::Tensor;

use super::batcher::{self, BatchPolicy};
use super::loadgen::{LoadGen, Request};
use super::plan::ServicePlanner;
use super::queue::{AdmissionQueue, AdmitResult};
use super::slo::{self, SloPolicy};

/// One open-loop serving experiment.
#[derive(Debug, Clone)]
pub struct TrafficScenario {
    pub name: String,
    /// Detector configurations addressable by `Request::key`.
    pub configs: Vec<DetectorConfig>,
    /// Points per scene (from the dataset config).
    pub num_points: usize,
    pub load: LoadGen,
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    pub policy: SloPolicy,
}

/// How a single request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutcomeKind {
    Completed,
    RejectedFull,
    Expired,
    ShedSlo,
}

/// Terminal record for one arrival.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub id: u64,
    pub kind: OutcomeKind,
    /// Completed within its deadline (always false for non-completions).
    pub on_time: bool,
}

/// Aggregated result of one scenario run.
#[derive(Debug, Clone)]
pub struct ServeTrafficReport {
    pub scenario: String,
    pub pattern: &'static str,
    pub policy: &'static str,
    pub offered_rps: f64,
    /// Admission-weighted steady-state capacity across the scenario's
    /// configs at the full batch size (harmonic mean under the load mix —
    /// a single-config scenario reports that config's capacity).
    pub capacity_rps: f64,
    /// Arrival-window length, seconds (simulated).
    pub duration_s: f64,
    /// Time the last batch finished, seconds (simulated).
    pub makespan_s: f64,
    pub arrivals: usize,
    pub completed: usize,
    pub on_time: usize,
    pub rejected_full: usize,
    pub expired: usize,
    pub shed_slo: usize,
    /// Requests served on the degraded fast path.
    pub degraded: usize,
    pub batches: usize,
    pub mean_batch: f64,
    /// End-to-end (arrival -> batch completion) simulated latency.
    pub latency_ms: Stats,
    /// Arrival -> dispatch delay (queueing + batching).
    pub queue_wait_ms: Stats,
    /// On-time completions / arrivals.
    pub slo_attainment: f64,
    /// On-time completions per simulated second.
    pub goodput_rps: f64,
    pub util_gpu: f64,
    pub util_npu: f64,
    pub max_queue_depth: usize,
    /// Streaming frames served at each temporal class (all zero for
    /// sessionless traffic).
    pub stream_full: usize,
    pub stream_partial: usize,
    pub stream_reuse: usize,
    /// Sessions evicted from the bounded per-box session cache.
    pub session_evictions: usize,
    /// Batches served on the stale-tracks SLO rung.
    pub stale_batches: usize,
    /// mAP@0.25 over functionally executed scenes (None without a real
    /// PJRT backend + artifacts).
    pub map_25: Option<f64>,
}

impl ServeTrafficReport {
    /// Human-readable block (mirrors `cmd_serve`'s style).
    pub fn print(&self) {
        println!(
            "--- {} [{} arrivals, pattern={}, policy={}] ---",
            self.scenario, self.arrivals, self.pattern, self.policy
        );
        println!(
            "offered {:.1} rps vs capacity {:.1} rps ({:.0}% load), {:.1}s window, {:.1}s makespan",
            self.offered_rps,
            self.capacity_rps,
            100.0 * self.offered_rps / self.capacity_rps.max(1e-9),
            self.duration_s,
            self.makespan_s
        );
        println!(
            "completed {} ({} on time)  rejected {}  expired {}  shed {}  degraded {}",
            self.completed, self.on_time, self.rejected_full, self.expired, self.shed_slo,
            self.degraded
        );
        println!(
            "latency: p50 {:.0} ms  p95 {:.0}  p99 {:.0}  (queue wait p95 {:.0} ms)",
            self.latency_ms.p50, self.latency_ms.p95, self.latency_ms.p99, self.queue_wait_ms.p95
        );
        println!(
            "SLO attainment {:.1}%  goodput {:.1} rps  mean batch {:.2} over {} batches",
            100.0 * self.slo_attainment,
            self.goodput_rps,
            self.mean_batch,
            self.batches
        );
        println!(
            "device util: GPU {:.0}%  NPU {:.0}%  peak queue depth {}",
            100.0 * self.util_gpu,
            100.0 * self.util_npu,
            self.max_queue_depth
        );
        let frames = self.stream_full + self.stream_partial + self.stream_reuse;
        if frames > 0 {
            println!(
                "stream frames: full {}  partial {}  reuse {}  (reuse rate {:.0}%)  \
                 evictions {}  stale batches {}",
                self.stream_full,
                self.stream_partial,
                self.stream_reuse,
                100.0 * (self.stream_partial + self.stream_reuse) as f64 / frames as f64,
                self.session_evictions,
                self.stale_batches
            );
        }
        match self.map_25 {
            Some(m) => println!("mAP@0.25 (functional) = {:.1}", m * 100.0),
            None => println!("mAP: n/a (simulated-time run; needs artifacts + PJRT)"),
        }
    }
}

/// One scene execution request handed to the worker pool.
struct ExecJob {
    cfg: DetectorConfig,
    seed: u64,
    slot: usize,
    /// 2D segmentation scores computed ahead of dispatch by the fused
    /// batched GEMM pre-pass; `Some` makes the worker skip its seg stage.
    scores: Option<Tensor>,
}

type ExecResult = (usize, Result<(Vec<Box3>, Vec<Box3>)>);

/// Cache key discriminating every config field that changes pipeline
/// behaviour (the planner keys its cost cache by the stage graph's
/// fingerprint; here a config-derived string suffices — both discriminate
/// the full QuantScheme).
fn pipe_key(cfg: &DetectorConfig) -> String {
    format!(
        "{}|{}|{}|{:?}|{}|{}|{}",
        cfg.dataset,
        cfg.variant.name(),
        cfg.scheme.key(),
        cfg.schedule,
        cfg.w0,
        cfg.bias_layers,
        cfg.seg_passes
    )
}

/// Functional batch executor: runs dispatched scenes through the real
/// [`ScenePipeline`] on a pool of long-lived worker threads, so serving
/// throughput scales with host cores (each worker owns a private runtime —
/// PJRT handles are not `Send` with a real `xla` backend — and a pipeline
/// cache keyed by config). Reports then carry accuracy next to simulated
/// latency. Without a real PJRT backend the runtime's deterministic host
/// surrogate executes the NN stages, so this works offline too; if a worker
/// cannot open a runtime at all, execution errors surface on the first
/// batch and the dispatcher falls back to simulation-only (`map_25 = None`).
pub struct PipelineExecutor {
    job_tx: Option<mpsc::Sender<ExecJob>>,
    res_rx: mpsc::Receiver<ExecResult>,
    workers: Vec<std::thread::JoinHandle<()>>,
    /// Runtime owned by the dispatcher thread for the fused segmentation
    /// pre-pass: the batch's 2D images run as one `(k·h·w, cin)` GEMM
    /// through the shared weight cache before scenes fan out to workers.
    /// `None` (open failure) just disables fusion — workers still run seg.
    batch_rt: Option<Runtime>,
    ds: &'static DatasetCfg,
    batch_threads: usize,
}

impl PipelineExecutor {
    /// Pool sized to the host (capped at 4 workers).
    pub fn new(rt: &Runtime, ds: &'static DatasetCfg) -> PipelineExecutor {
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        PipelineExecutor::with_workers(rt, ds, cores.min(4))
    }

    /// Pool with an explicit per-scene worker count.
    pub fn with_workers(
        rt: &Runtime,
        ds: &'static DatasetCfg,
        workers: usize,
    ) -> PipelineExecutor {
        let workers = workers.max(1);
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        // split the host's threads between scene-level and stage-level
        // parallelism so a full batch doesn't oversubscribe
        let per_worker = (cores / workers).clamp(1, 4);
        let host_exec = if per_worker > 1 {
            HostExec::Parallel { threads: per_worker }
        } else {
            HostExec::Sequential
        };
        let (job_tx, job_rx) = mpsc::channel::<ExecJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = mpsc::channel::<ExecResult>();
        let handles = (0..workers)
            .map(|_| {
                let rx = job_rx.clone();
                let tx = res_tx.clone();
                let source: RuntimeSource = rt.source();
                std::thread::spawn(move || worker_loop(source, ds, host_exec, &rx, &tx))
            })
            .collect();
        PipelineExecutor {
            job_tx: Some(job_tx),
            res_rx,
            workers: handles,
            batch_rt: rt.source().open().ok(),
            ds,
            batch_threads: cores.clamp(1, 4),
        }
    }

    /// Fused segmentation pre-pass: when a batch has ≥ 2 painted scenes,
    /// run every scene's 2D image through ONE batched GEMM
    /// ([`Runtime::run_batch_with_spec`]) instead of one per worker — the
    /// per-call calibration/packing overhead amortizes across the batch
    /// and the weight cache is touched once. fp32 rows are independent, so
    /// the fused scores are bitwise identical to per-scene execution; int8
    /// calibrates over the joint batch (documented batching semantics).
    /// Any failure (or `POINTSPLIT_FUSED_BATCH=0`) falls back to all-`None`
    /// and workers run their own seg stage unchanged.
    fn fused_seg_scores(&self, cfg: &DetectorConfig, reqs: &[Request]) -> Vec<Option<Tensor>> {
        let none = vec![None; reqs.len()];
        if reqs.len() < 2 || !cfg.variant.painted() {
            return none;
        }
        if std::env::var("POINTSPLIT_FUSED_BATCH").is_ok_and(|v| v == "0") {
            return none;
        }
        let Some(rt) = &self.batch_rt else { return none };
        // the seg node of this config's graph names the artifact + QDQ spec
        let Ok(graph) = StageGraph::build(&rt.manifest, cfg, self.ds.num_points, false) else {
            return none;
        };
        let Some(seg) = graph.nodes.iter().find(|n| n.class == StageClass::Seg) else {
            return none;
        };
        let Some(art) = seg.artifact.clone() else { return none };
        let img_size = rt.manifest.img_size;
        let imgs: Vec<Tensor> = reqs
            .iter()
            .map(|r| {
                let scene = generate_scene(r.seed, self.ds);
                Tensor::new(vec![img_size, img_size, 3], scene.image)
            })
            .collect();
        let refs: Vec<&Tensor> = imgs.iter().collect();
        match rt.run_batch_with_spec(&art, &refs, seg.qspec.as_ref(), self.batch_threads) {
            Ok(scores) => scores.into_iter().map(Some).collect(),
            Err(_) => none,
        }
    }

    /// Execute each request's scene; returns (detections, ground truth) per
    /// request in order. Scenes of one batch run concurrently across the
    /// worker pool.
    ///
    /// Fidelity caveat: degraded batches run with the degraded *precisions*
    /// (the dispatcher passes the fast config), but at the full point budget
    /// and with fresh 2D segmentation — the accuracy reported for degraded
    /// traffic is therefore an upper bound on the fast path's true mAP.
    #[allow(clippy::type_complexity)]
    pub fn execute(
        &self,
        cfg: &DetectorConfig,
        reqs: &[Request],
    ) -> Result<Vec<(Vec<Box3>, Vec<Box3>)>> {
        // invariant, not input-dependent: `job_tx` is only taken in Drop,
        // so it is always Some while `self` can still be called
        let tx = self.job_tx.as_ref().expect("executor pool alive");
        let scores = self.fused_seg_scores(cfg, reqs);
        for ((slot, r), s) in reqs.iter().enumerate().zip(scores) {
            tx.send(ExecJob { cfg: cfg.clone(), seed: r.seed, slot, scores: s })
                .map_err(|_| anyhow!("pipeline executor workers exited"))?;
        }
        let mut out: Vec<Option<(Vec<Box3>, Vec<Box3>)>> =
            (0..reqs.len()).map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        // drain exactly one result per job even on error, so a failed batch
        // cannot leak stale results into the next one
        for _ in 0..reqs.len() {
            match self.res_rx.recv() {
                Ok((slot, Ok(pair))) => out[slot] = Some(pair),
                Ok((_, Err(e))) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => return Err(anyhow!("pipeline executor workers exited")),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        // invariant: the loop above received exactly one result per job and
        // any per-slot error returned early, so every slot is Some here
        Ok(out.into_iter().map(|o| o.expect("every slot filled")).collect())
    }
}

impl Drop for PipelineExecutor {
    fn drop(&mut self) {
        self.job_tx.take(); // close the channel; workers drain and exit
        for h in self.workers.drain(..) {
            h.join().ok();
        }
    }
}

/// Poison-tolerant job receive: a worker that panicked while holding the
/// lock leaves the `Receiver` itself in a consistent state (panics happen
/// in pipeline code, never mid-`recv`), so surviving workers keep serving
/// instead of cascading the panic across the whole pool.
fn recv_job(rx: &Mutex<mpsc::Receiver<ExecJob>>) -> Result<ExecJob, mpsc::RecvError> {
    rx.lock().unwrap_or_else(std::sync::PoisonError::into_inner).recv()
}

fn worker_loop(
    source: RuntimeSource,
    ds: &'static DatasetCfg,
    host_exec: HostExec,
    rx: &Mutex<mpsc::Receiver<ExecJob>>,
    tx: &mpsc::Sender<ExecResult>,
) {
    let rt = match source.open() {
        Ok(rt) => rt,
        Err(e) => {
            // still answer every job so the dispatcher never blocks
            let msg = format!("{e:#}");
            loop {
                let Ok(job) = recv_job(rx) else { return };
                let err = anyhow!("worker runtime unavailable: {msg}");
                if tx.send((job.slot, Err(err))).is_err() {
                    return;
                }
            }
        }
    };
    // pre-size this worker's point-op scratch arena for the dataset's cloud
    // size: one allocation burst here instead of growth during the first
    // request — the steady-state per-scene path then allocates nothing
    crate::pointops::arena::warm(ds.num_points);
    let mut pipes: HashMap<String, ScenePipeline<'_>> = HashMap::new();
    loop {
        let Ok(job) = recv_job(rx) else { return };
        let pipe = pipes.entry(pipe_key(&job.cfg)).or_insert_with(|| {
            ScenePipeline::new(&rt, job.cfg.clone()).with_host_exec(host_exec)
        });
        let scene = generate_scene(job.seed, ds);
        let gt = scene.gt_boxes();
        // a panic inside the pipeline must still produce a result, or the
        // dispatcher's recv() for this slot would block forever
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            match &job.scores {
                // fused pre-pass already ran 2D seg for this scene: skip
                // the seg stage and patch its scores in
                Some(s) => pipe.run_with_scores(&scene, job.seed, Some(s)).map(|(o, _)| o),
                None => pipe.run(&scene, job.seed),
            }
        }))
        .unwrap_or_else(|_| Err(anyhow!("worker panicked executing scene {}", job.seed)))
        .map(|out| (out.detections, gt));
        if tx.send((job.slot, res)).is_err() {
            return;
        }
    }
}

/// Per-config plan bundle a [`BoxEngine`] dispatches against: the full
/// stage graph, the SLO degrade fast path, and the two temporal-reuse
/// shapes ([`crate::temporal`]) — all built once at construction.
struct ConfigPlan {
    cfg: DetectorConfig,
    full: StageGraph,
    fast_cfg: DetectorConfig,
    fast: StageGraph,
    /// PARTIAL frames: full precision and point budget, but the 2D
    /// segmentation pass is skipped (painted scores patched from the
    /// session cache).
    partial: StageGraph,
    /// REUSE frames: only the detection head re-runs over cached SA
    /// features ([`StageGraph::stream_tail`]).
    tail: StageGraph,
}

/// Session-model knobs for the virtual-time dispatcher. The dispatcher only
/// needs per-frame *costs*, so frame classes are modelled deterministically
/// (mirroring the measured delta estimator in [`crate::temporal`]): a
/// forced-FULL cut every `CUT_PERIOD` frames, a PARTIAL roughly every
/// `PARTIAL_EVERY` frames (seeded per client), REUSE otherwise.
const SESSION_CAP_DEFAULT: usize = 64;
const CUT_PERIOD: u64 = 16;
const PARTIAL_EVERY: u64 = 8;

/// SplitMix64 finalizer (same family as the router's rendezvous hash).
fn session_hash(x: u64) -> u64 {
    let mut z = x.wrapping_add(0x9E37_79B9_7F4A_7C15);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

/// Frame class of a session's `frame`-th dispatch (0-based; frame 0 and
/// every cut are FULL).
fn frame_class_of(client: u64, frame: u64) -> FrameClass {
    if frame % CUT_PERIOD == 0 {
        return FrameClass::Full;
    }
    if session_hash(client ^ frame.wrapping_mul(0x9E37)) % PARTIAL_EVERY == 0 {
        FrameClass::Partial
    } else {
        FrameClass::Reuse
    }
}

struct SessionEntry {
    /// Logical-clock timestamp of the last dispatched frame (LRU key;
    /// unique per entry, so eviction is deterministic despite `HashMap`
    /// iteration order).
    last_used: u64,
    /// Frames dispatched for this session so far.
    frames: u64,
}

/// Bounded per-client session table of one box. Holds the frame-class state
/// machine only; the artifact bytes it stands for are accounted by
/// [`crate::temporal::session_footprint_bytes`] and checked by verifier
/// rule S006.
struct SessionMap {
    map: HashMap<u64, SessionEntry>,
    cap: usize,
    clock: u64,
    evictions: usize,
}

impl SessionMap {
    fn new(cap: usize) -> SessionMap {
        SessionMap { map: HashMap::new(), cap: cap.max(1), clock: 0, evictions: 0 }
    }

    /// Class the session's next frame would be served at (cold = FULL).
    fn peek_class(&self, client: u64) -> FrameClass {
        match self.map.get(&client) {
            None => FrameClass::Full,
            Some(e) => frame_class_of(client, e.frames),
        }
    }

    /// A warm session has cached state a stale-tracks rung can serve from.
    fn is_warm(&self, client: u64) -> bool {
        self.map.get(&client).is_some_and(|e| e.frames > 0)
    }

    /// Record one dispatched frame, evicting the least-recently-used
    /// session when a new client would exceed the capacity bound (the
    /// evicted client restarts cold, i.e. FULL).
    fn commit(&mut self, client: u64) {
        self.clock += 1;
        if !self.map.contains_key(&client) && self.map.len() >= self.cap {
            let victim = self
                .map
                .iter()
                .min_by_key(|(id, e)| (e.last_used, **id))
                .map(|(id, _)| *id);
            if let Some(v) = victim {
                self.map.remove(&v);
                self.evictions += 1;
            }
        }
        let e = self.map.entry(client).or_insert(SessionEntry { last_used: 0, frames: 0 });
        e.last_used = self.clock;
        e.frames += 1;
    }
}

const ZERO_COST: PlanCost = PlanCost {
    total_ms: 0.0,
    busy_gpu_ms: 0.0,
    busy_npu_ms: 0.0,
    busy_cpu_ms: 0.0,
    comm_ms: 0.0,
    bottleneck_ms: 0.0,
};

/// Sequential composition of two sub-batch costs (the lane runs the FULL,
/// PARTIAL and REUSE sub-batches back to back, so times and occupancies
/// add).
fn add_cost(a: PlanCost, b: PlanCost) -> PlanCost {
    PlanCost {
        total_ms: a.total_ms + b.total_ms,
        busy_gpu_ms: a.busy_gpu_ms + b.busy_gpu_ms,
        busy_npu_ms: a.busy_npu_ms + b.busy_npu_ms,
        busy_cpu_ms: a.busy_cpu_ms + b.busy_cpu_ms,
        comm_ms: a.comm_ms + b.comm_ms,
        bottleneck_ms: a.bottleneck_ms + b.bottleneck_ms,
    }
}

/// Lifetime counters of one [`BoxEngine`] — everything a per-box report
/// row needs, in one `Copy` snapshot.
#[derive(Debug, Clone, Copy, Default)]
pub struct EngineStats {
    pub completed: usize,
    pub on_time: usize,
    pub shed_slo: usize,
    pub degraded: usize,
    pub batches: usize,
    pub batched_reqs: usize,
    pub rejected_full: usize,
    pub expired: usize,
    pub max_queue_depth: usize,
    pub busy_gpu_ms: f64,
    pub busy_npu_ms: f64,
    pub busy_cpu_ms: f64,
    /// Completion time of the last batch, ms on the simulated clock.
    pub makespan_ms: f64,
    /// Streaming frames served at each temporal class (sessionless
    /// requests count nowhere; degraded redos count nowhere).
    pub stream_full: usize,
    pub stream_partial: usize,
    pub stream_reuse: usize,
    /// Sessions evicted from the bounded session cache (LRU).
    pub stream_evictions: usize,
    /// Live sessions in the cache at snapshot time.
    pub stream_sessions: usize,
    /// Batches served on the stale-tracks SLO rung.
    pub stale_batches: usize,
}

impl EngineStats {
    pub fn mean_batch(&self) -> f64 {
        if self.batches > 0 { self.batched_reqs as f64 / self.batches as f64 } else { 0.0 }
    }

    /// Streaming frames served from cached state / all streaming frames
    /// (the session-cache hit rate; 0 for sessionless traffic).
    pub fn stream_reuse_rate(&self) -> f64 {
        let frames = self.stream_full + self.stream_partial + self.stream_reuse;
        if frames > 0 {
            (self.stream_partial + self.stream_reuse) as f64 / frames as f64
        } else {
            0.0
        }
    }
}

/// The per-box dispatch state machine: bounded admission queue, dynamic
/// batcher, SLO policy, and the virtual-time lane clock, packaged so an
/// external driver (the single-box arrival loop or the cluster router) can
/// feed it requests and step it event by event.
///
/// Protocol: [`offer`](Self::offer) admits arrivals at the current time;
/// [`advance`](Self::advance) expires stale work and dispatches while the
/// lane is open, returning the next time this box needs attention (`None`
/// when idle). The driver owns the clock and must call `advance` with
/// non-decreasing `now` values.
pub struct BoxEngine {
    plans: Vec<ConfigPlan>,
    batch: BatchPolicy,
    policy: SloPolicy,
    queue: AdmissionQueue,
    lane_free: f64,
    /// Straggler multiplier: every service time is stretched by this factor
    /// (1.0 = healthy; fault injection sets it above).
    slow: f64,
    makespan_ms: f64,
    busy_gpu: f64,
    busy_npu: f64,
    busy_cpu: f64,
    lat: Vec<f64>,
    qwait: Vec<f64>,
    completed: usize,
    on_time: usize,
    shed_slo: usize,
    degraded: usize,
    batches: usize,
    batched_reqs: usize,
    // streaming-session state and counters
    sessions: SessionMap,
    stream_full: usize,
    stream_partial: usize,
    stream_reuse: usize,
    stale_batches: usize,
    // functional-accuracy accumulators (only with a working executor)
    exec_ok: bool,
    gts: Vec<Vec<Box3>>,
    dets: Vec<Detection>,
}

impl BoxEngine {
    /// Build the engine's stage graphs once, up front — full path and
    /// degraded fast path per config. Per-batch costing on the hot path is
    /// then a cache lookup / simulation over these; no graph construction
    /// per dispatch event, and a malformed config fails construction here
    /// instead of killing a worker mid-traffic.
    pub fn new(
        planner: &ServicePlanner,
        configs: &[DetectorConfig],
        num_points: usize,
        queue_capacity: usize,
        batch: BatchPolicy,
        policy: SloPolicy,
    ) -> Result<BoxEngine> {
        // scenario specs come from CLI flags and cluster plans — an empty
        // config list is malformed input, not a programming error
        if configs.is_empty() {
            return Err(anyhow!("engine needs at least one detector config"));
        }
        let fast_pts = slo::degraded_points(num_points);
        let mut plans = Vec::with_capacity(configs.len());
        for cfg in configs {
            let full = planner.graph(cfg, num_points, false)?;
            let fast_cfg = slo::degraded_config(cfg);
            let fast = planner.graph(&fast_cfg, fast_pts, true)?;
            let partial = planner.graph(cfg, num_points, true)?;
            let tail = full.stream_tail();
            plans.push(ConfigPlan { cfg: cfg.clone(), full, fast_cfg, fast, partial, tail });
        }
        Ok(BoxEngine {
            plans,
            batch,
            policy,
            queue: AdmissionQueue::new(queue_capacity, 2),
            lane_free: 0.0,
            slow: 1.0,
            makespan_ms: 0.0,
            busy_gpu: 0.0,
            busy_npu: 0.0,
            busy_cpu: 0.0,
            lat: Vec::new(),
            qwait: Vec::new(),
            completed: 0,
            on_time: 0,
            shed_slo: 0,
            degraded: 0,
            batches: 0,
            batched_reqs: 0,
            sessions: SessionMap::new(SESSION_CAP_DEFAULT),
            stream_full: 0,
            stream_partial: 0,
            stream_reuse: 0,
            stale_batches: 0,
            exec_ok: true,
            gts: Vec::new(),
            dets: Vec::new(),
        })
    }

    /// Override the streaming session-cache capacity (default
    /// 64 live client sessions per box). Resets session state, so call it
    /// before offering traffic.
    pub fn with_session_cap(mut self, cap: usize) -> BoxEngine {
        self.sessions = SessionMap::new(cap);
        self
    }

    /// Configured session-cache capacity (for memory-bound verification).
    pub fn session_cap(&self) -> usize {
        self.sessions.cap
    }

    /// Class request `r` would be served at right now (sessionless = FULL).
    fn peek_class(&self, r: &Request) -> FrameClass {
        if r.client == 0 { FrameClass::Full } else { self.sessions.peek_class(r.client) }
    }

    /// Price a batch whose members are served at the given frame classes:
    /// the FULL, PARTIAL and REUSE sub-batches each cost their own graph,
    /// run back to back. An all-FULL batch degenerates to exactly the full
    /// graph's cost, so sessionless traffic is priced bit-identically to
    /// the pre-streaming dispatcher.
    fn classed_cost(
        &self,
        planner: &ServicePlanner,
        ci: usize,
        classes: &[FrameClass],
    ) -> PlanCost {
        let (mut kf, mut kp, mut kr) = (0usize, 0usize, 0usize);
        for c in classes {
            match c {
                FrameClass::Full => kf += 1,
                FrameClass::Partial => kp += 1,
                FrameClass::Reuse => kr += 1,
            }
        }
        let p = &self.plans[ci];
        let mut cost = ZERO_COST;
        if kf > 0 {
            cost = add_cost(cost, planner.cost_of_graph(&p.full, kf));
        }
        if kp > 0 {
            cost = add_cost(cost, planner.cost_of_graph(&p.partial, kp));
        }
        if kr > 0 {
            cost = add_cost(cost, planner.cost_of_graph(&p.tail, kr));
        }
        cost
    }

    /// Admit one arrival. A rejection emits its terminal outcome here so
    /// every request resolves exactly once no matter which box it hit.
    pub fn offer(&mut self, r: Request, outcomes: &mut Vec<RequestOutcome>) -> AdmitResult {
        let id = r.id;
        let res = self.queue.offer(r);
        if res == AdmitResult::RejectedFull {
            outcomes.push(RequestOutcome { id, kind: OutcomeKind::RejectedFull, on_time: false });
        }
        res
    }

    /// Expire stale queue entries, then dispatch while the lane is open.
    /// Returns the next simulated time this box needs attention (batch
    /// window closing or lane reopening with work queued), `None` if it is
    /// fully idle until the next arrival.
    pub fn advance(
        &mut self,
        now: f64,
        planner: &ServicePlanner,
        exec: Option<&PipelineExecutor>,
        outcomes: &mut Vec<RequestOutcome>,
    ) -> Option<f64> {
        for r in self.queue.expire(now) {
            outcomes.push(RequestOutcome { id: r.id, kind: OutcomeKind::Expired, on_time: false });
        }
        let mut wait_hint: Option<f64> = None;
        while self.lane_free <= now {
            match batcher::decide(&mut self.queue, &self.batch, now) {
                batcher::BatchDecision::Dispatch(batch) => {
                    let ci = batch.key.min(self.plans.len() - 1);
                    let k0 = batch.reqs.len();
                    // price the batch at each member's temporal frame class;
                    // the stale rung additionally forces every warm session
                    // onto its REUSE tail
                    let classes: Vec<FrameClass> =
                        batch.reqs.iter().map(|r| self.peek_class(r)).collect();
                    let stale_classes: Vec<FrameClass> = batch
                        .reqs
                        .iter()
                        .zip(&classes)
                        .map(|(r, &c)| {
                            if r.client != 0 && self.sessions.is_warm(r.client) {
                                FrameClass::Reuse
                            } else {
                                c
                            }
                        })
                        .collect();
                    let full = self.classed_cost(planner, ci, &classes).scaled(self.slow);
                    let stale = self.classed_cost(planner, ci, &stale_classes).scaled(self.slow);
                    let fast = planner.cost_of_graph(&self.plans[ci].fast, k0).scaled(self.slow);
                    let dec = slo::apply_stream(
                        self.policy,
                        batch.reqs,
                        now,
                        full.total_ms,
                        stale.total_ms,
                        fast.total_ms,
                    );
                    for r in &dec.shed {
                        self.shed_slo += 1;
                        outcomes.push(RequestOutcome {
                            id: r.id,
                            kind: OutcomeKind::ShedSlo,
                            on_time: false,
                        });
                    }
                    if dec.dispatch.is_empty() {
                        continue; // whole batch shed; lane still open
                    }
                    let k = dec.dispatch.len();
                    // class each dispatched request is actually served at
                    // (None = degraded redo, priced on the fast graph)
                    let served: Option<Vec<FrameClass>> = (!dec.degraded).then(|| {
                        dec.dispatch
                            .iter()
                            .map(|r| {
                                if dec.stale
                                    && r.client != 0
                                    && self.sessions.is_warm(r.client)
                                {
                                    FrameClass::Reuse
                                } else {
                                    self.peek_class(r)
                                }
                            })
                            .collect()
                    });
                    let cost = match &served {
                        Some(cls) => self.classed_cost(planner, ci, cls).scaled(self.slow),
                        None => planner.cost_of_graph(&self.plans[ci].fast, k).scaled(self.slow),
                    };
                    if dec.stale {
                        self.stale_batches += 1;
                    }
                    let done = now + cost.total_ms;
                    self.lane_free = now + cost.bottleneck_ms;
                    self.makespan_ms = self.makespan_ms.max(done);
                    self.busy_gpu += cost.busy_gpu_ms;
                    self.busy_npu += cost.busy_npu_ms;
                    self.busy_cpu += cost.busy_cpu_ms;
                    self.batches += 1;
                    self.batched_reqs += k;
                    if self.exec_ok {
                        if let Some(pool) = exec {
                            let run_cfg = if dec.degraded {
                                &self.plans[ci].fast_cfg
                            } else {
                                &self.plans[ci].cfg
                            };
                            match pool.execute(run_cfg, &dec.dispatch) {
                                Ok(pairs) => {
                                    for (d, gt) in pairs {
                                        let scene_idx = self.gts.len();
                                        self.gts.push(gt);
                                        self.dets.extend(
                                            d.into_iter()
                                                .map(|b| Detection { scene: scene_idx, b }),
                                        );
                                    }
                                }
                                Err(e) => {
                                    eprintln!(
                                        "functional execution disabled ({e:#}); continuing \
                                         simulated-only"
                                    );
                                    self.exec_ok = false;
                                }
                            }
                        }
                    }
                    for (j, r) in dec.dispatch.iter().enumerate() {
                        self.lat.push(done - r.arrival_ms);
                        self.qwait.push(now - r.arrival_ms);
                        self.completed += 1;
                        let met = done <= r.deadline_ms;
                        if met {
                            self.on_time += 1;
                        }
                        if dec.degraded {
                            self.degraded += 1;
                        }
                        if r.client != 0 {
                            if let Some(cls) = &served {
                                match cls[j] {
                                    FrameClass::Full => self.stream_full += 1,
                                    FrameClass::Partial => self.stream_partial += 1,
                                    FrameClass::Reuse => self.stream_reuse += 1,
                                }
                            }
                            // degraded redos also advance the session: the
                            // fast-path run refreshes its cached state
                            self.sessions.commit(r.client);
                        }
                        outcomes.push(RequestOutcome {
                            id: r.id,
                            kind: OutcomeKind::Completed,
                            on_time: met,
                        });
                    }
                }
                batcher::BatchDecision::WaitUntil(t) => {
                    wait_hint = Some(t);
                    break;
                }
                batcher::BatchDecision::Idle => break,
            }
        }
        let mut hint = f64::INFINITY;
        if !self.queue.is_empty() {
            if self.lane_free > now {
                hint = hint.min(self.lane_free);
            }
            if let Some(t) = wait_hint {
                hint = hint.min(t);
            }
        }
        if hint.is_finite() {
            Some(hint)
        } else {
            None
        }
    }

    /// Pull every queued request out (box death / decommission) so the
    /// caller can reroute them. In-flight batches are unaffected — work
    /// already dispatched keeps its completion times.
    pub fn drain(&mut self) -> Vec<Request> {
        let mut out = Vec::with_capacity(self.queue.len());
        while let Some(r) = self.queue.pop() {
            out.push(r);
        }
        out
    }

    /// Set the straggler multiplier applied to every subsequent dispatch
    /// (1.0 restores nominal speed). In-flight work is not re-priced.
    pub fn set_slow(&mut self, factor: f64) {
        self.slow = factor.max(1e-6);
    }

    pub fn slow(&self) -> f64 {
        self.slow
    }

    pub fn queue_len(&self) -> usize {
        self.queue.len()
    }

    pub fn queue_capacity(&self) -> usize {
        self.queue.capacity()
    }

    /// Idle = nothing queued and the lane already reopened.
    pub fn is_idle(&self, now: f64) -> bool {
        self.queue.is_empty() && self.lane_free <= now
    }

    pub fn stats(&self) -> EngineStats {
        EngineStats {
            completed: self.completed,
            on_time: self.on_time,
            shed_slo: self.shed_slo,
            degraded: self.degraded,
            batches: self.batches,
            batched_reqs: self.batched_reqs,
            rejected_full: self.queue.stats.rejected_full as usize,
            expired: self.queue.stats.expired as usize,
            max_queue_depth: self.queue.stats.max_depth,
            busy_gpu_ms: self.busy_gpu,
            busy_npu_ms: self.busy_npu,
            busy_cpu_ms: self.busy_cpu,
            makespan_ms: self.makespan_ms,
            stream_full: self.stream_full,
            stream_partial: self.stream_partial,
            stream_reuse: self.stream_reuse,
            stream_evictions: self.sessions.evictions,
            stream_sessions: self.sessions.map.len(),
            stale_batches: self.stale_batches,
        }
    }

    pub fn latencies(&self) -> &[f64] {
        &self.lat
    }

    pub fn queue_waits(&self) -> &[f64] {
        &self.qwait
    }

    /// mAP@0.25 over functionally executed scenes (None without a working
    /// executor, or if execution was disabled mid-run).
    pub fn map_25(&self, planner: &ServicePlanner) -> Option<f64> {
        if self.exec_ok && !self.gts.is_empty() {
            Some(eval_map(&self.dets, &self.gts, planner.manifest().num_class(), 0.25).map)
        } else {
            None
        }
    }
}

/// Run a scenario to completion on the simulated clock. Returns the report
/// plus one terminal outcome per arrival (in resolution order).
///
/// A configuration the planner cannot cost (malformed manifest, unknown
/// dataset) surfaces as an error instead of panicking a serving worker.
pub fn run_traffic_trace(
    sc: &TrafficScenario,
    planner: &ServicePlanner,
    exec: Option<&PipelineExecutor>,
) -> Result<(ServeTrafficReport, Vec<RequestOutcome>)> {
    // an empty config list errors inside BoxEngine::new
    let mut engine = BoxEngine::new(
        planner,
        &sc.configs,
        sc.num_points,
        sc.queue_capacity,
        sc.batch,
        sc.policy,
    )?;
    let arrivals = sc.load.generate();
    let total = arrivals.len();
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(total);
    let mut now = 0.0f64;
    let mut i = 0usize;
    loop {
        // 1) ingest every arrival due at or before `now`
        while i < total && arrivals[i].arrival_ms <= now {
            engine.offer(arrivals[i].clone(), &mut outcomes);
            i += 1;
        }
        // 2+3) expire, then dispatch while the lane is open
        let hint = engine.advance(now, planner, exec, &mut outcomes);
        // 4) advance the clock to the next event
        let mut t_next = f64::INFINITY;
        if let Some(r) = arrivals.get(i) {
            t_next = t_next.min(r.arrival_ms);
        }
        if let Some(h) = hint {
            t_next = t_next.min(h);
        }
        if !t_next.is_finite() {
            break;
        }
        debug_assert!(t_next > now, "virtual clock must advance ({t_next} vs {now})");
        now = t_next;
    }

    let st = engine.stats();
    let makespan_s = (st.makespan_ms / 1000.0).max(sc.load.duration_ms / 1000.0).max(1e-9);
    let report = ServeTrafficReport {
        scenario: sc.name.clone(),
        pattern: sc.load.pattern.name(),
        policy: sc.policy.name(),
        offered_rps: sc.load.pattern.mean_rps(),
        capacity_rps: planner.mixed_capacity_rps(
            &sc.configs,
            sc.num_points,
            sc.batch.max_batch,
            &sc.load.mix,
        )?,
        duration_s: sc.load.duration_ms / 1000.0,
        makespan_s,
        arrivals: total,
        completed: st.completed,
        on_time: st.on_time,
        rejected_full: st.rejected_full,
        expired: st.expired,
        shed_slo: st.shed_slo,
        degraded: st.degraded,
        batches: st.batches,
        mean_batch: st.mean_batch(),
        latency_ms: Stats::from(engine.latencies().to_vec()),
        queue_wait_ms: Stats::from(engine.queue_waits().to_vec()),
        slo_attainment: if total > 0 { st.on_time as f64 / total as f64 } else { 1.0 },
        goodput_rps: st.on_time as f64 / makespan_s,
        util_gpu: st.busy_gpu_ms / 1000.0 / makespan_s,
        util_npu: st.busy_npu_ms / 1000.0 / makespan_s,
        max_queue_depth: st.max_queue_depth,
        stream_full: st.stream_full,
        stream_partial: st.stream_partial,
        stream_reuse: st.stream_reuse,
        session_evictions: st.stream_evictions,
        stale_batches: st.stale_batches,
        map_25: engine.map_25(planner),
    };
    Ok((report, outcomes))
}

/// Run a scenario and return just the report.
pub fn run_traffic(
    sc: &TrafficScenario,
    planner: &ServicePlanner,
    exec: Option<&PipelineExecutor>,
) -> Result<ServeTrafficReport> {
    Ok(run_traffic_trace(sc, planner, exec)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Schedule, Variant};
    use crate::serving::loadgen::ArrivalPattern;
    use crate::sim::DeviceKind;

    fn split_cfg() -> DetectorConfig {
        DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        )
    }

    fn scenario(rate_mult: f64, policy: SloPolicy, seed: u64) -> TrafficScenario {
        let cfg = split_cfg();
        let planner = ServicePlanner::synthetic();
        let cap = planner.capacity_rps(&cfg, 2048, 4).unwrap();
        TrafficScenario {
            name: format!("test-{rate_mult}x"),
            configs: vec![cfg],
            num_points: 2048,
            load: LoadGen::simple(
                ArrivalPattern::Poisson { rate_rps: cap * rate_mult },
                20_000.0,
                2_000.0,
                seed,
            ),
            queue_capacity: 32,
            batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
            policy,
        }
    }

    #[test]
    fn underload_meets_slo() {
        let planner = ServicePlanner::synthetic();
        let sc = scenario(0.25, SloPolicy::None, 3);
        let (rep, outcomes) = run_traffic_trace(&sc, &planner, None).unwrap();
        assert_eq!(outcomes.len(), rep.arrivals);
        assert!(rep.arrivals > 0);
        assert!(rep.slo_attainment > 0.9, "underload attainment {}", rep.slo_attainment);
        assert_eq!(rep.completed + rep.rejected_full + rep.expired + rep.shed_slo, rep.arrivals);
        assert!(rep.map_25.is_none());
    }

    #[test]
    fn deterministic_runs() {
        let planner = ServicePlanner::synthetic();
        let sc = scenario(1.2, SloPolicy::Degrade, 9);
        let a = run_traffic(&sc, &planner, None).unwrap();
        let b = run_traffic(&sc, &planner, None).unwrap();
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.latency_ms.p99, b.latency_ms.p99);
    }

    #[test]
    fn overload_policy_beats_none() {
        let planner = ServicePlanner::synthetic();
        let none = run_traffic(&scenario(2.0, SloPolicy::None, 17), &planner, None).unwrap();
        let deg = run_traffic(&scenario(2.0, SloPolicy::Degrade, 17), &planner, None).unwrap();
        assert!(
            deg.goodput_rps > none.goodput_rps,
            "degradation must raise goodput under 2x overload: {} vs {}",
            deg.goodput_rps,
            none.goodput_rps
        );
        assert!(deg.degraded > 0, "2x overload must trigger degradation");
    }

    #[test]
    fn overload_batches_grow() {
        let planner = ServicePlanner::synthetic();
        let under = run_traffic(&scenario(0.3, SloPolicy::None, 21), &planner, None).unwrap();
        let over = run_traffic(&scenario(1.8, SloPolicy::None, 21), &planner, None).unwrap();
        assert!(
            over.mean_batch > under.mean_batch,
            "queueing pressure should fill batches: {} vs {}",
            over.mean_batch,
            under.mean_batch
        );
    }

    /// Regression (capacity satellite): a single-config scenario must keep
    /// reporting exactly that config's capacity.
    #[test]
    fn single_config_capacity_matches_planner() {
        let planner = ServicePlanner::synthetic();
        let sc = scenario(0.5, SloPolicy::None, 5);
        let rep = run_traffic(&sc, &planner, None).unwrap();
        let cap = planner.capacity_rps(&sc.configs[0], 2048, 4).unwrap();
        assert!(
            (rep.capacity_rps - cap).abs() < 1e-9 * cap,
            "single-config capacity drifted: {} vs {}",
            rep.capacity_rps,
            cap
        );
    }

    /// Regression (capacity satellite): a mixed scenario must report the
    /// admission-weighted capacity, not config 0's — previously a scenario
    /// mixing a fast and a slow config claimed the fast config's capacity
    /// for the whole gateway.
    #[test]
    fn capacity_reports_admission_weighted_mix() {
        let planner = ServicePlanner::synthetic();
        let fast = split_cfg();
        let slow = DetectorConfig::new(
            "synrgbd",
            Variant::PointPainting,
            false,
            Schedule::SingleDevice(DeviceKind::Gpu),
        );
        let cap_fast = planner.capacity_rps(&fast, 2048, 4).unwrap();
        let cap_slow = planner.capacity_rps(&slow, 2048, 4).unwrap();
        assert!(cap_fast > cap_slow, "precondition: the fp32 single-device config is slower");
        let mut sc = scenario(0.5, SloPolicy::None, 5);
        sc.configs = vec![fast, slow];
        sc.load.mix = vec![1.0, 1.0];
        let rep = run_traffic(&sc, &planner, None).unwrap();
        let expect = 2.0 / (1.0 / cap_fast + 1.0 / cap_slow);
        assert!(
            (rep.capacity_rps - expect).abs() < 1e-6 * expect,
            "mixed capacity {} vs harmonic mean {}",
            rep.capacity_rps,
            expect
        );
        // strictly between the two single-config capacities
        assert!(rep.capacity_rps < cap_fast && rep.capacity_rps > cap_slow);
    }

    fn stream_req(id: u64, client: u64, arrival: f64, deadline: f64) -> Request {
        Request {
            id,
            arrival_ms: arrival,
            deadline_ms: deadline,
            seed: id,
            class: 0,
            key: 0,
            client,
        }
    }

    fn one_shot_engine(planner: &ServicePlanner, policy: SloPolicy) -> BoxEngine {
        BoxEngine::new(
            planner,
            std::slice::from_ref(&split_cfg()),
            2048,
            8,
            BatchPolicy { max_batch: 1, max_wait_ms: 0.0 },
            policy,
        )
        .unwrap()
    }

    /// Streaming traffic rides the reuse tail, which must cost less than
    /// recomputing every frame — under overload that shows up as goodput.
    #[test]
    fn streaming_sessions_raise_goodput_under_overload() {
        let planner = ServicePlanner::synthetic();
        let mut sc = scenario(1.5, SloPolicy::None, 13);
        let cold = run_traffic(&sc, &planner, None).unwrap();
        assert_eq!(cold.stream_full + cold.stream_partial + cold.stream_reuse, 0);
        sc.load.clients = 4;
        let warm = run_traffic(&sc, &planner, None).unwrap();
        assert!(warm.stream_reuse > 0, "streaming trace must hit the reuse tail");
        assert!(
            warm.goodput_rps > cold.goodput_rps,
            "frame reuse should raise goodput under overload: {} vs {}",
            warm.goodput_rps,
            cold.goodput_rps
        );
    }

    /// The session cache is bounded: a new client beyond the capacity
    /// evicts the least-recently-used session, which restarts cold (FULL).
    #[test]
    fn session_cache_evicts_lru_when_over_cap() {
        let planner = ServicePlanner::synthetic();
        let mut e = one_shot_engine(&planner, SloPolicy::None).with_session_cap(2);
        assert_eq!(e.session_cap(), 2);
        let mut outcomes = Vec::new();
        let mut now = 0.0;
        for (i, client) in [1u64, 2, 3, 1].into_iter().enumerate() {
            let r = stream_req(i as u64, client, now, 1e12);
            assert_eq!(e.offer(r, &mut outcomes), AdmitResult::Admitted);
            e.advance(now, &planner, None, &mut outcomes);
            now += 60_000.0; // lane surely free again
        }
        let st = e.stats();
        assert_eq!(st.completed, 4);
        // client 3 evicts client 1; client 1's return evicts client 2
        assert_eq!(st.stream_evictions, 2);
        assert_eq!(st.stream_sessions, 2);
        // every dispatch was a cold first frame (client 1 lost its state)
        assert_eq!(st.stream_full, 4);
        assert_eq!(st.stream_partial + st.stream_reuse, 0);
    }

    /// The stale-tracks rung: a warm session hitting a forced-FULL cut
    /// under deadline pressure is served from its cached REUSE tail instead
    /// of being quantize-degraded.
    #[test]
    fn stale_tracks_serves_cut_frames_from_the_cache_under_pressure() {
        let planner = ServicePlanner::synthetic();
        let mut e = one_shot_engine(&planner, SloPolicy::StaleTracks);
        let mut outcomes = Vec::new();
        let mut now = 0.0;
        // warm the session past the first cut window: frames 0..=15
        for i in 0..16u64 {
            let r = stream_req(i, 7, now, f64::INFINITY);
            assert_eq!(e.offer(r, &mut outcomes), AdmitResult::Admitted);
            e.advance(now, &planner, None, &mut outcomes);
            now += 60_000.0;
        }
        let before = e.stats();
        assert_eq!(before.stream_full, 1, "only frame 0 recomputes in the first window");
        assert_eq!(before.stale_batches, 0);
        // frame 16 is a cut (FULL); give it a deadline only the tail makes
        let full_ms = planner.cost_of_graph(&e.plans[0].full, 1).total_ms;
        let tail_ms = planner.cost_of_graph(&e.plans[0].tail, 1).total_ms;
        assert!(tail_ms < full_ms, "reuse tail must be cheaper than the full graph");
        let r = stream_req(16, 7, now, now + 0.5 * (full_ms + tail_ms));
        assert_eq!(e.offer(r, &mut outcomes), AdmitResult::Admitted);
        e.advance(now, &planner, None, &mut outcomes);
        let st = e.stats();
        assert_eq!(st.completed, 17);
        assert_eq!(st.stale_batches, 1, "cut frame should ride the stale rung");
        assert_eq!(st.stream_full, 1, "the cut was served stale, not recomputed");
        assert_eq!(st.degraded, 0, "stale rung preempts quantize-degradation");
        assert_eq!(st.on_time, 17);
    }

    /// The straggler knob scales every charged service time uniformly.
    #[test]
    fn straggler_factor_stretches_service_times() {
        let planner = ServicePlanner::synthetic();
        let cfg = split_cfg();
        let run_one = |slow: f64| {
            let mut e = BoxEngine::new(
                &planner,
                std::slice::from_ref(&cfg),
                2048,
                8,
                BatchPolicy { max_batch: 1, max_wait_ms: 0.0 },
                SloPolicy::None,
            )
            .unwrap();
            e.set_slow(slow);
            let mut outcomes = Vec::new();
            let r = Request {
                id: 0,
                arrival_ms: 0.0,
                deadline_ms: 1e9,
                seed: 1,
                class: 0,
                key: 0,
                client: 0,
            };
            assert_eq!(e.offer(r, &mut outcomes), AdmitResult::Admitted);
            let hint = e.advance(0.0, &planner, None, &mut outcomes);
            assert!(hint.is_none(), "single request dispatches immediately");
            assert_eq!(e.stats().completed, 1);
            e.stats().makespan_ms
        };
        let base = run_one(1.0);
        let slowed = run_one(3.0);
        assert!(base > 0.0);
        assert!(
            (slowed - 3.0 * base).abs() < 1e-6 * base,
            "3x straggler: {slowed} ms vs base {base} ms"
        );
    }

    /// The fused segmentation pre-pass must be invisible in the results:
    /// fp32 batched GEMM rows are bitwise identical to per-scene execution
    /// (canonical lane-reduction order), so a batch served with fused seg
    /// scores pins the exact detections a direct [`ScenePipeline::run`]
    /// produces for each seed.
    #[test]
    fn fused_seg_batch_matches_direct_pipeline() {
        let rt = Runtime::synthetic();
        let ds = crate::data::dataset("synrgbd").unwrap();
        let cfg = split_cfg(); // painted fp32 → fusion engages and is exact
        let exec = PipelineExecutor::with_workers(&rt, ds, 1);
        let reqs: Vec<Request> = (0..3).map(|i| stream_req(40 + i, i, 0.0, 1e12)).collect();
        let got = exec.execute(&cfg, &reqs).unwrap();
        // mirror the single worker's host-exec policy so any thread-count
        // sensitivity would be the fused path's fault, not the pool's
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        let per = cores.clamp(1, 4);
        let host_exec =
            if per > 1 { HostExec::Parallel { threads: per } } else { HostExec::Sequential };
        let pipe = ScenePipeline::new(&rt, cfg).with_host_exec(host_exec);
        for (r, (dets, gt)) in reqs.iter().zip(&got) {
            let scene = generate_scene(r.seed, ds);
            assert_eq!(gt, &scene.gt_boxes());
            let direct = pipe.run(&scene, r.seed).unwrap();
            assert_eq!(
                dets, &direct.detections,
                "fused seg scores changed seed {} detections",
                r.seed
            );
        }
    }
}
