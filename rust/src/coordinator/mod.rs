//! L3 coordinator — the paper's system contribution.
//!
//! Owns the request path: for each RGB-D scene it executes the 2D-3D fusion
//! detector *functionally* (Rust pointops + PJRT executables) while building
//! the two-lane stage DAG that the calibrated device simulator times. The
//! three schedules of the paper are all expressible:
//!
//! - `Schedule::SingleDevice` — Fig. 9 baseline: everything on one device
//! - `Schedule::Sequential`   — Fig. 2: naive GPU+NPU split, no overlap
//! - `Schedule::Pipelined`    — Fig. 3: PointSplit two-pipeline overlap with
//!                              jump-started SA-normal
//!
//! These are the *named placement policies* of the stage graph's
//! placement-search space (`graph::place` enumerates every schedule over
//! the available devices and recovers `Pipelined { GPU, EdgeTPU }` as
//! optimal on the default calibration).
//!
//! Submodules: `arch` (workload descriptors, Table 1), `decode` (box
//! decoding + NMS), `pipeline` (per-scene executor), `serve` (multi-scene
//! request loop on std threads).

pub mod arch;
pub mod attn;
pub mod decode;
pub mod pipeline;
pub mod serve;

pub use pipeline::{DetectorConfig, PipelineOutput, ScenePipeline};

use crate::sim::DeviceKind;

/// Detector variants evaluated in Tables 6/7.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Variant {
    /// point-cloud-only VoteNet (no 2D fusion)
    VoteNet,
    /// PointPainting: sequential 2D-3D fusion, single full pipeline
    PointPainting,
    /// ablation: random halves, regular FPS both
    RandomSplit,
    /// the paper's system: SA-normal + SA-bias pipelines
    PointSplit,
}

impl Variant {
    /// Which trained model's artifacts this variant executes.
    pub fn model_name(&self) -> &'static str {
        match self {
            Variant::VoteNet => "votenet",
            Variant::PointPainting | Variant::RandomSplit => "painted",
            Variant::PointSplit => "pointsplit",
        }
    }

    pub fn painted(&self) -> bool {
        !matches!(self, Variant::VoteNet)
    }

    pub fn split(&self) -> bool {
        matches!(self, Variant::RandomSplit | Variant::PointSplit)
    }

    pub fn name(&self) -> &'static str {
        match self {
            Variant::VoteNet => "VoteNet",
            Variant::PointPainting => "PointPainting",
            Variant::RandomSplit => "RandomSplit",
            Variant::PointSplit => "PointSplit",
        }
    }
}

/// Device placement + overlap policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Schedule {
    /// single device runs everything (paper's GPU-only TF baseline)
    SingleDevice(DeviceKind),
    /// point ops on `point_dev`, NNs on `nn_dev`, strictly sequential (Fig. 2)
    Sequential { point_dev: DeviceKind, nn_dev: DeviceKind },
    /// PointSplit overlap (Fig. 3); falls back to Sequential when the
    /// variant has a single pipeline
    Pipelined { point_dev: DeviceKind, nn_dev: DeviceKind },
}

impl Schedule {
    pub fn point_dev(&self) -> DeviceKind {
        match self {
            Schedule::SingleDevice(d) => *d,
            Schedule::Sequential { point_dev, .. } | Schedule::Pipelined { point_dev, .. } => {
                *point_dev
            }
        }
    }

    pub fn nn_dev(&self) -> DeviceKind {
        match self {
            Schedule::SingleDevice(d) => *d,
            Schedule::Sequential { nn_dev, .. } | Schedule::Pipelined { nn_dev, .. } => *nn_dev,
        }
    }

    pub fn overlapped(&self) -> bool {
        matches!(self, Schedule::Pipelined { .. })
    }
}
