//! Paper Figs. 6/7: weight/activation distributions of the voting and
//! proposal heads, grouped by channel role, and the KL-divergence structure.
//!
//! Reads `artifacts/head_stats.json` (per-channel stats captured during
//! calibration of the trained PointSplit model). Expected shape: within-role
//! KL much smaller than across-role KL; role groups have visibly different
//! ranges (tight xyz, wide logits).

mod common;

use pointsplit::bench::Table;
use pointsplit::quant::stats::within_across_kl;
use pointsplit::util::json::Json;

fn main() {
    let text = std::fs::read_to_string("artifacts/head_stats.json")
        .expect("head_stats.json missing — run `make artifacts`");
    let stats = Json::parse(&text).unwrap();
    let model = stats.req("synrgbd_pointsplit");
    for layer in ["vote_out", "prop_out"] {
        let s = model.req(layer);
        let group_of: Vec<usize> =
            s.req("group_of_ordered").usize_vec();
        let wmin = s.req("weight_min").f64_vec();
        let wmax = s.req("weight_max").f64_vec();
        let amin = s.req("act_min").f64_vec();
        let amax = s.req("act_max").f64_vec();
        let n_groups = group_of.iter().max().unwrap() + 1;
        let mut t = Table::new(&[
            "role group",
            "#ch",
            "weight range (mean)",
            "act range (mean)",
            "act |max|",
        ]);
        for g in 0..n_groups {
            let idx: Vec<usize> =
                (0..group_of.len()).filter(|&i| group_of[i] == g).collect();
            let wrange: f64 =
                idx.iter().map(|&i| wmax[i] - wmin[i]).sum::<f64>() / idx.len() as f64;
            let arange: f64 =
                idx.iter().map(|&i| amax[i] - amin[i]).sum::<f64>() / idx.len() as f64;
            let amaxv = idx.iter().map(|&i| amax[i].abs().max(amin[i].abs())).fold(0.0, f64::max);
            t.row(vec![
                format!("group {}", g + 1),
                idx.len().to_string(),
                format!("{wrange:.3}"),
                format!("{arange:.3}"),
                format!("{amaxv:.2}"),
            ]);
        }
        t.print(&format!("Fig. 6 — {layer} per-role distribution ranges (synrgbd PointSplit)"));

        // Fig. 7: KL structure over activation histograms
        let hists: Vec<Vec<f64>> = s
            .req("act_hist")
            .as_arr()
            .unwrap()
            .iter()
            .map(|h| h.f64_vec())
            .collect();
        let (within, across) = within_across_kl(&hists, &group_of);
        println!(
            "Fig. 7 — {layer}: mean KL within role-groups {within:.3}, across {across:.3} ({:.1}x)",
            across / within.max(1e-9)
        );
        // The paper's Fig. 7 shows the PROPOSAL module; its role structure is
        // the load-bearing claim (the voting module's 3-channel xyz group is
        // too small for a stable within-group KL estimate).
        if layer == "prop_out" {
            assert!(
                across > within,
                "role grouping must explain the proposal activation structure"
            );
        }
    }
    println!("\npaper: channels cluster by role; KL across role-groups >> within (Fig. 7 heatmap).");
}
