//! Detection evaluation: oriented 3D IoU, NMS, mAP@IoU, segmentation mIoU.

pub mod iou;
pub mod map;
pub mod miou;
pub mod nms;

pub use iou::iou3d;
pub use map::{eval_map, Detection, MapResult};
pub use miou::confusion_miou;
pub use nms::nms3d;
