//! Stub of the `xla-rs` PJRT surface used by `pointsplit::runtime`.
//!
//! The real backend (LaurentMazare's `xla` crate + an XLA/PJRT install)
//! cannot be vendored offline, so this crate mirrors exactly the types and
//! signatures the runtime calls and fails *late*: clients open and literals
//! construct fine, but anything that would compile or execute an HLO module
//! returns [`Error::Unavailable`]. That keeps `Runtime::open` + manifest
//! introspection working (and lets the rest of the crate — device simulator,
//! coordinator planning, serving gateway — run end-to-end) while making
//! functional NN execution an explicit opt-in: swap the `xla` path
//! dependency in `rust/Cargo.toml` for the real crate to enable it.
//!
//! Everything here is intentionally minimal; see `rust/src/runtime/mod.rs`
//! for the only call sites.

use std::fmt;

/// Errors surfaced by the stub (mirrors xla-rs's error enum shape).
pub enum Error {
    /// The operation needs a real PJRT backend.
    Unavailable(String),
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "PJRT unavailable ({what}): the vendored `xla` crate is a stub; \
                        point rust/Cargo.toml at a real xla-rs build to execute artifacts")
            }
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{self:?}")
    }
}

pub type Result<T> = std::result::Result<T, Error>;

fn unavailable<T>(what: &str) -> Result<T> {
    Err(Error::Unavailable(what.to_string()))
}

/// Stub PJRT client. Opens successfully so manifest-only workflows run.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "pjrt-stub".to_string()
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        unavailable("compile")
    }
}

/// Parsed HLO module handle (never actually constructed by the stub).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        unavailable("HloModuleProto::from_text_file")
    }
}

/// Computation wrapper accepted by [`PjRtClient::compile`].
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// Compiled executable handle (never actually constructed by the stub).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute<T>(&self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        unavailable("execute")
    }
}

/// Device buffer handle returned by execution.
pub struct PjRtBuffer;

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        unavailable("to_literal_sync")
    }
}

/// Host tensor literal. Construction and reshape work (pure metadata); any
/// data readback requires the real backend.
pub struct Literal {
    dims: Vec<i64>,
}

impl Literal {
    pub fn vec1(data: &[f32]) -> Literal {
        Literal { dims: vec![data.len() as i64] }
    }

    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = self.dims.iter().product();
        let m: i64 = dims.iter().product();
        if n != m {
            return unavailable("reshape: element count mismatch");
        }
        Ok(Literal { dims: dims.to_vec() })
    }

    pub fn shape(&self) -> Result<Shape> {
        Ok(Shape::Array(ArrayShape { dims: self.dims.clone() }))
    }

    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        unavailable("to_tuple")
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        unavailable("to_vec")
    }
}

/// Shape of a literal.
pub enum Shape {
    Array(ArrayShape),
    Tuple(Vec<Shape>),
}

/// Dense array shape.
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_opens_but_compile_fails() {
        let client = PjRtClient::cpu().unwrap();
        assert_eq!(client.platform_name(), "pjrt-stub");
        let comp = XlaComputation::from_proto(&HloModuleProto);
        assert!(client.compile(&comp).is_err());
    }

    #[test]
    fn literal_metadata_roundtrip() {
        let lit = Literal::vec1(&[0.0; 12]);
        let lit = lit.reshape(&[3, 4]).unwrap();
        match lit.shape().unwrap() {
            Shape::Array(a) => assert_eq!(a.dims(), &[3, 4]),
            Shape::Tuple(_) => panic!("expected array shape"),
        }
        assert!(lit.reshape(&[5, 5]).is_err());
        assert!(lit.to_vec::<f32>().is_err());
    }

    #[test]
    fn error_message_mentions_stub() {
        let e = PjRtClient.compile(&XlaComputation);
        let msg = format!("{:?}", e.unwrap_err());
        assert!(msg.contains("stub"));
    }
}
