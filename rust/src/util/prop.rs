//! Property-based testing harness (proptest is not vendored).
//!
//! `check` runs a property over N randomly generated cases; on failure it
//! attempts a bounded greedy shrink (halving sizes) and reports the minimal
//! failing seed so the case can be replayed deterministically.

use crate::util::rng::Rng;

/// Configuration for a property run.
pub struct PropConfig {
    pub cases: usize,
    pub seed: u64,
}

impl Default for PropConfig {
    fn default() -> Self {
        PropConfig { cases: 64, seed: 0xC0FFEE }
    }
}

/// Run `prop(rng, size)` for `cfg.cases` cases with growing size. The
/// property returns `Err(description)` to signal failure; panics inside the
/// property are NOT caught (use Result style).
pub fn check<F>(name: &str, cfg: PropConfig, mut prop: F)
where
    F: FnMut(&mut Rng, usize) -> Result<(), String>,
{
    for case in 0..cfg.cases {
        let seed = cfg.seed.wrapping_add(case as u64 * 0x9E3779B9);
        // size grows with the case index: small cases first
        let size = 4 + case * 4;
        let mut rng = Rng::new(seed);
        if let Err(msg) = prop(&mut rng, size) {
            // greedy shrink: retry with smaller sizes, same seed
            let mut min_size = size;
            let mut min_msg = msg;
            let mut s = size / 2;
            while s >= 2 {
                let mut r2 = Rng::new(seed);
                match prop(&mut r2, s) {
                    Err(m) => {
                        min_size = s;
                        min_msg = m;
                        s /= 2;
                    }
                    Ok(()) => break,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed:#x}, size {min_size}): {min_msg}"
            );
        }
    }
}

/// Generate a random point cloud of `n` points in a `scale`-sized box.
pub fn gen_cloud(rng: &mut Rng, n: usize, scale: f32) -> Vec<[f32; 3]> {
    (0..n)
        .map(|_| [rng.f32() * scale, rng.f32() * scale, rng.f32() * scale * 0.4])
        .collect()
}

/// Generate a random oriented box whose center lies in the cloud's range.
pub fn gen_box(rng: &mut Rng, scale: f32) -> crate::data::Box3 {
    crate::data::Box3 {
        center: [rng.f32() * scale, rng.f32() * scale, rng.f32() * 1.2],
        size: [
            0.2 + rng.f32() * 2.0,
            0.2 + rng.f32() * 2.0,
            0.2 + rng.f32() * 1.5,
        ],
        heading: rng.f32() * std::f32::consts::TAU,
        class: rng.below(10),
        score: rng.f32(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        check("always-true", PropConfig { cases: 10, seed: 1 }, |_, _| {
            count += 1;
            Ok(())
        });
        assert_eq!(count, 10);
    }

    #[test]
    #[should_panic(expected = "property 'fails-on-big'")]
    fn failing_property_reports_seed() {
        check("fails-on-big", PropConfig::default(), |_, size| {
            if size > 20 {
                Err(format!("size {size} too big"))
            } else {
                Ok(())
            }
        });
    }
}
