//! Per-device roofline cost model.

/// Processor classes available on the paper's platform.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DeviceKind {
    Cpu,
    /// 128-core Maxwell mobile GPU (512 GFLOPS fp32)
    Gpu,
    /// Coral EdgeTPU (4 TOPS int8, PCIe Gen2 x1)
    EdgeTpu,
}

impl DeviceKind {
    pub fn name(&self) -> &'static str {
        match self {
            DeviceKind::Cpu => "CPU",
            DeviceKind::Gpu => "GPU",
            DeviceKind::EdgeTpu => "EdgeTPU",
        }
    }
}

/// Numeric regime a stage executes at. Carried per stage (not per
/// workload): it is the schedulable property of the QuantScheme layer —
/// the EdgeTPU accepts int8 NN stages only, and compute/memory rates
/// differ per precision.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Precision {
    Fp32,
    Int8,
}

impl Precision {
    pub fn name(&self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum WorkloadKind {
    /// FPS / ball query / gather — irregular, branchy
    PointOp,
    /// dense NN inference (PointNet, segmenter, heads)
    NeuralNet,
}

/// One stage's computational footprint. The byte counts already reflect
/// the stage's precision (int8 stages stream and ship 1 byte per element).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Workload {
    pub kind: WorkloadKind,
    pub flops: u64,
    /// bytes streamed through memory during compute
    pub mem_bytes: u64,
    /// bytes that must cross the interconnect if the consumer sits on
    /// another device (activation sizes; int8 artifacts move 1B/elem)
    pub wire_bytes: u64,
}

/// Calibrated device parameters. All times in milliseconds.
#[derive(Debug, Clone, Copy)]
pub struct Device {
    pub kind: DeviceKind,
    /// fixed per-dispatch cost
    pub overhead_ms: f64,
    /// effective FLOP/ms for point ops (None = cannot run them)
    pub pointop_flops_per_ms: Option<f64>,
    /// effective FLOP/ms for NN by precision (None = unsupported)
    pub nn_fp32_flops_per_ms: Option<f64>,
    pub nn_int8_flops_per_ms: Option<f64>,
    /// memory bandwidth bytes/ms for the irregular point-op traffic
    pub mem_bytes_per_ms: f64,
    /// working-set capacity a single stage may stream through this device
    /// (placement-search constraint; the EdgeTPU's on-chip SRAM is the
    /// binding one — oversized stages must stay off it)
    pub mem_capacity_bytes: u64,
    /// interconnect: bytes/ms and per-transfer setup cost to reach this
    /// device from the host side
    pub link_bytes_per_ms: f64,
    pub link_overhead_ms: f64,
}

impl Device {
    /// ARM A57 quad-core: both op kinds, slowly. NN rates are fitted to the
    /// paper's Fig. 10 cross-pairing ratios (GPU-CPU ≈ 3.2x GPU-EdgeTPU,
    /// CPU-CPU ≈ 2.1x CPU-EdgeTPU): the CPU lane must be slow enough that
    /// pairing it as the NN device loses to the EdgeTPU despite the
    /// EdgeTPU's 20 ms/transfer PCIe setup.
    pub fn cpu() -> Device {
        Device {
            kind: DeviceKind::Cpu,
            overhead_ms: 1.0,
            pointop_flops_per_ms: Some(18_000.0),       // ~18 MFLOP/s eff (irregular)
            nn_fp32_flops_per_ms: Some(160_000.0),      // 0.16 GFLOP/s eff (TF on A57)
            nn_int8_flops_per_ms: Some(250_000.0),      // 0.25 GOP/s eff (TFLite)
            mem_bytes_per_ms: 18_000.0,
            mem_capacity_bytes: 4_000_000_000,          // 4 GB shared LPDDR4
            link_bytes_per_ms: f64::INFINITY,           // shares DRAM
            link_overhead_ms: 0.0,
        }
    }

    /// 128-core Maxwell (Jetson Nano). Point ops are irregular and batch-1,
    /// so effective throughput is far below the 512 GFLOPS peak — constants
    /// fitted to Table 12's GPU column (199/52/25/20 ms).
    pub fn gpu() -> Device {
        Device {
            kind: DeviceKind::Gpu,
            overhead_ms: 14.0,
            pointop_flops_per_ms: Some(55_000.0),       // 55 MFLOP/s eff
            // TensorFlow fp32 on the Nano GPU is the paper's slow regime
            // (Fig. 9: 8.5 s PointPainting); calibrated to our mini
            // workload's FLOP count so the end-to-end ratios transfer
            nn_fp32_flops_per_ms: Some(50_000.0),       // 50 MFLOP/s eff (TF)
            nn_int8_flops_per_ms: Some(50_000.0),       // Maxwell: no int8 gain
            mem_bytes_per_ms: 35_000.0,                 // 35 MB/s eff for gathers
            mem_capacity_bytes: 4_000_000_000,          // unified 4 GB with the CPU
            link_bytes_per_ms: f64::INFINITY,           // unified memory
            link_overhead_ms: 0.0,
        }
    }

    /// Coral EdgeTPU over PCIe Gen2 x1 (0.5 GB/s). Int8 NN only; per-call
    /// transaction overhead dominates small tensors (paper Table 13: 360 ms
    /// of communication across ~10 invocations).
    pub fn edgetpu() -> Device {
        Device {
            kind: DeviceKind::EdgeTpu,
            overhead_ms: 3.0,
            pointop_flops_per_ms: None,
            nn_fp32_flops_per_ms: None,
            nn_int8_flops_per_ms: Some(1_800_000.0),    // 1.8 GOP/s eff on tiny nets
            mem_bytes_per_ms: 500_000.0,
            mem_capacity_bytes: 8_000_000,              // 8 MB on-chip SRAM
            link_bytes_per_ms: 500_000.0,               // 0.5 GB/s PCIe Gen2 x1
            link_overhead_ms: 20.0,                     // per-transfer setup
        }
    }

    pub fn by_kind(kind: DeviceKind) -> Device {
        match kind {
            DeviceKind::Cpu => Device::cpu(),
            DeviceKind::Gpu => Device::gpu(),
            DeviceKind::EdgeTpu => Device::edgetpu(),
        }
    }

    /// Can this device execute a stage of this kind/precision at all?
    pub fn supports(&self, kind: WorkloadKind, precision: Precision) -> bool {
        match kind {
            WorkloadKind::PointOp => self.pointop_flops_per_ms.is_some(),
            WorkloadKind::NeuralNet => match precision {
                Precision::Fp32 => self.nn_fp32_flops_per_ms.is_some(),
                Precision::Int8 => self.nn_int8_flops_per_ms.is_some(),
            },
        }
    }

    /// Does a stage's working set fit this device's memory capacity?
    /// (Placement-search constraint, checked per stage: capability says
    /// whether the device can run the op at all, `fits` whether this
    /// particular workload's streamed bytes are admissible.)
    pub fn fits(&self, w: &Workload) -> bool {
        w.mem_bytes <= self.mem_capacity_bytes
    }

    /// Compute time (ms) at a precision, excluding interconnect transfers.
    pub fn compute_ms(&self, w: &Workload, precision: Precision) -> f64 {
        let thr = match w.kind {
            WorkloadKind::PointOp => self
                .pointop_flops_per_ms
                .unwrap_or_else(|| panic!("{:?} cannot run point ops", self.kind)),
            WorkloadKind::NeuralNet => match precision {
                Precision::Fp32 => self
                    .nn_fp32_flops_per_ms
                    .unwrap_or_else(|| panic!("{:?} cannot run fp32 NN", self.kind)),
                Precision::Int8 => self
                    .nn_int8_flops_per_ms
                    .unwrap_or_else(|| panic!("{:?} cannot run int8 NN", self.kind)),
            },
        };
        let t_flops = w.flops as f64 / thr;
        let t_mem = w.mem_bytes as f64 / self.mem_bytes_per_ms;
        self.overhead_ms + t_flops.max(t_mem)
    }

    /// Interconnect cost of moving `bytes` onto/off this device.
    pub fn transfer_ms(&self, bytes: u64) -> f64 {
        if bytes == 0 || self.link_bytes_per_ms.is_infinite() {
            return 0.0;
        }
        self.link_overhead_ms + bytes as f64 / self.link_bytes_per_ms
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pointop(flops: u64, mem: u64) -> Workload {
        Workload { kind: WorkloadKind::PointOp, flops, mem_bytes: mem, wire_bytes: 0 }
    }

    fn nn(flops: u64) -> Workload {
        Workload { kind: WorkloadKind::NeuralNet, flops, mem_bytes: 0, wire_bytes: 0 }
    }

    #[test]
    fn edgetpu_rejects_pointops_and_fp32() {
        let t = Device::edgetpu();
        assert!(!t.supports(WorkloadKind::PointOp, Precision::Fp32));
        assert!(!t.supports(WorkloadKind::NeuralNet, Precision::Fp32));
        assert!(t.supports(WorkloadKind::NeuralNet, Precision::Int8));
    }

    #[test]
    fn gpu_faster_than_cpu_on_pointops() {
        let w = pointop(5_000_000, 500_000);
        assert!(
            Device::gpu().compute_ms(&w, Precision::Fp32)
                < Device::cpu().compute_ms(&w, Precision::Fp32)
        );
    }

    #[test]
    fn edgetpu_faster_than_cpu_on_int8_nn() {
        let w = nn(60_000_000);
        assert!(
            Device::edgetpu().compute_ms(&w, Precision::Int8)
                < Device::cpu().compute_ms(&w, Precision::Int8)
        );
    }

    #[test]
    fn per_precision_latency_differs_where_hardware_does() {
        // CPU int8 beats CPU fp32 on the same workload; Maxwell sees no gain
        let w = nn(60_000_000);
        let cpu = Device::cpu();
        assert!(cpu.compute_ms(&w, Precision::Int8) < cpu.compute_ms(&w, Precision::Fp32));
        let gpu = Device::gpu();
        assert_eq!(
            gpu.compute_ms(&w, Precision::Int8),
            gpu.compute_ms(&w, Precision::Fp32)
        );
    }

    #[test]
    fn table12_sa1_calibration() {
        // paper: SA1 point manipulation on GPU = 199 ms (INT8 pipeline)
        // our SA1 workload: FPS + ball query on 2048 pts -> 256 centroids,
        // grouping moves 256*32*15 f32
        let flops = crate::pointops::fps_flops(2048, 256) + crate::pointops::ball_query_flops(2048, 256);
        let mem = (256 * 32 * 15 * 4) as u64;
        let t = Device::gpu().compute_ms(&pointop(flops, mem), Precision::Fp32);
        assert!((t - 199.0).abs() < 30.0, "SA1 GPU ~199ms (paper Table 12), got {t:.0}");
    }

    #[test]
    fn table12_sa1_pointnet_calibration() {
        // paper: SA1 PointNet on EdgeTPU = 47 ms incl. transfer
        let flops = 58_000_000u64; // mini SA1 PointNet
        let wire = (2048 * 15) as u64; // int8 painted cloud in
        let t = Device::edgetpu().compute_ms(&nn(flops), Precision::Int8)
            + Device::edgetpu().transfer_ms(wire);
        assert!((t - 47.0).abs() < 15.0, "SA1 EdgeTPU ~47ms (paper Table 12), got {t:.0}");
    }

    #[test]
    fn memory_capacity_gates_placement() {
        let t = Device::edgetpu();
        let small = Workload {
            kind: WorkloadKind::NeuralNet,
            flops: 1_000_000,
            mem_bytes: 1_000_000,
            wire_bytes: 0,
        };
        let huge = Workload { mem_bytes: 1_000_000_000, ..small };
        assert!(t.fits(&small), "1 MB stage fits the EdgeTPU SRAM");
        assert!(!t.fits(&huge), "1 GB stage cannot stream through the EdgeTPU");
        assert!(Device::gpu().fits(&huge), "unified-memory GPU takes it");
    }

    #[test]
    fn transfer_dominated_by_setup_for_small_tensors() {
        let t = Device::edgetpu();
        let small = t.transfer_ms(1000);
        assert!(small > 19.0 && small < 23.0);
    }
}
