//! Paper Table 11: quantization granularity — mAP, quantization error
//! (FP32 mAP minus INT8 mAP) and quantization-parameter count for
//! layer / even-group / channel / role-based schemes on both datasets.
//!
//! Expected shape: layer & naive-group collapse; channel ~ fp32 but needs
//! 40-70x more parameters; role-based matches channel at group-wise cost.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::quant::QuantScheme;
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(40);
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let schemes = [
        ("No quant.", "fp32", "fp32"),
        ("Layer-wise", "int8", "int8_layer"),
        ("Group-wise", "int8", "int8_group"),
        ("Channel-wise", "int8", "int8_channel"),
        ("Role-based (ours)", "int8", "int8_role"),
    ];
    for ds in ["synrgbd", "synscan"] {
        let mut fp32_map = 0.0;
        let mut t = Table::new(&["quant. method", "mAP@0.25", "quant. error", "# quant. params"]);
        for (name, backbone, head) in schemes {
            let mut cfg = DetectorConfig::new(ds, Variant::PointSplit, false, sched);
            cfg.scheme = QuantScheme::from_names(backbone, head).expect("quant scheme");
            let rep = common::eval_config(&rt, &cfg, scenes);
            let map = rep.map_25 * 100.0;
            if head == "fp32" {
                fp32_map = map;
            }
            let params = match head {
                "fp32" => "-".to_string(),
                h => rt.manifest.quant_param_count[h.trim_start_matches("int8_")].to_string(),
            };
            t.row(vec![
                name.to_string(),
                format!("{map:.1}"),
                if head == "fp32" { "-".into() } else { format!("{:.1}", fp32_map - map) },
                params,
            ]);
            eprintln!("  [{ds} {name}] mAP {map:.1}");
        }
        t.print(&format!(
            "Table 11 — quantization granularity on {ds} ({scenes} scenes; paper {}: layer collapses, role ~= channel with {}x fewer params)",
            ds,
            rt.manifest.quant_param_count["channel"] / rt.manifest.quant_param_count["role"]
        ));
    }
}
