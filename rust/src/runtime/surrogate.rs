//! Deterministic host surrogate for the AOT PJRT executables.
//!
//! The vendored `xla` crate is a stub — it cannot compile or execute HLO —
//! so on machines without a real PJRT backend the functional pipeline used
//! to die at its first NN call. This module stands in for the executables
//! with small fixed-function networks whose weights are derived from a hash
//! of the artifact's (dataset, model, net) identity: fully deterministic
//! (same artifact + same input → bit-identical output, on any thread),
//! shape-correct per the manifest, and cheap enough that the host hot path
//! stays dominated by point ops.
//!
//! # INT8 execution
//!
//! Precision variants of an artifact share the same underlying weights —
//! they are the *same trained network* at different numerics. An INT8
//! artifact executes a genuine quantized path, not the fp path with a
//! renamed artifact:
//!
//! 1. activations are calibrated per input-channel group (the stage's
//!    [`QuantSpec`] granularity) and quantized to real `i8` codes
//!    ([`QTensor`], bit-consistent with the `ActQuant` QDQ reference);
//! 2. the matmul runs in integer arithmetic — `i8 × i8` products
//!    accumulated in wide integers per channel group, with the zero-point
//!    correction folded in as an integer weight-sum term;
//! 3. the accumulator is dequantized through the group scales, and the
//!    stage's *output* activations are quantized at the spec's granularity
//!    over its output channels — which is exactly where the paper's
//!    role-based partition preserves the heads' tiny xyz offsets while
//!    layer-wise scales crush them (Table 7/11).
//!
//! This is a *reference executor*, not the trained model: detections are
//! internally consistent (stable across runs, usable for determinism tests,
//! scheduling studies, and serving experiments) but their accuracy is
//! meaningful only relative to other surrogate configurations. Swapping
//! `rust/Cargo.toml` to a real `xla-rs` build restores execution of the
//! exported artifacts; the surrogate then never runs.

use anyhow::{anyhow, Result};

use super::manifest::{ArtifactMeta, Manifest};
use crate::quant::{QTensor, QuantSpec};
use crate::util::tensor::Tensor;

#[inline]
fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

fn hash_str(s: &str) -> u64 {
    // FNV-1a
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Weight key shared by every precision variant of a network: the artifact
/// name *minus* the precision suffix, so `vote_fp32` and `vote_int8_role`
/// execute the same weights and differ only by quantization error.
fn weight_key(meta: &ArtifactMeta) -> u64 {
    hash_str(&format!("{}_{}_{}", meta.dataset, meta.model, meta.net))
}

/// Pseudo-random weight in [-1, 1] for (artifact key, out channel, in channel).
#[inline]
fn weight(key: u64, j: u64, c: u64) -> f32 {
    let h = mix(
        key ^ j.wrapping_mul(0x9E3779B97F4A7C15) ^ c.wrapping_mul(0xD1B54A32D192ED03),
    );
    ((h >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32
}

fn bias_vec(key: u64, cout: usize) -> Vec<f32> {
    (0..cout).map(|j| 0.1 * weight(key ^ 0xB1A5, j as u64, 0)).collect()
}

/// Deterministic fp32 dense layer on a flat `(n * cin)` activation slice:
/// rows -> tanh(rows @ W + b).
fn dense(data: &[f32], cin: usize, cout: usize, key: u64) -> Tensor {
    let n = data.len() / cin.max(1);
    // materialize W once per call (cout x cin + bias)
    let mut w = Vec::with_capacity(cout * cin);
    for j in 0..cout {
        for c in 0..cin {
            w.push(weight(key, j as u64, c as u64));
        }
    }
    let bias = bias_vec(key, cout);
    let scale = 1.0 / (cin.max(1) as f32).sqrt();
    let mut out = Vec::with_capacity(n * cout);
    for row in data.chunks_exact(cin.max(1)) {
        for j in 0..cout {
            let wrow = &w[j * cin..(j + 1) * cin];
            let mut acc = 0.0f32;
            for (wv, xv) in wrow.iter().zip(row.iter()) {
                acc += wv * xv;
            }
            out.push((acc * scale + bias[j]).tanh());
        }
    }
    Tensor::new(vec![n, cout], out)
}

/// Genuine INT8 dense layer: quantize → integer matmul → dequantize.
///
/// Activations are calibrated over the batch at the spec's granularity on
/// the *input* channels (a `Role` spec derives the partition from the
/// observed ranges — the calibration pass), weights are symmetric
/// per-output-channel `i8`. Within a channel group the scale and zero point
/// are shared, so the matmul factors into pure integer dot products plus an
/// integer zero-point correction.
fn dense_q(data: &[f32], cin: usize, cout: usize, key: u64, spec: &QuantSpec) -> Result<Tensor> {
    let cin = cin.max(1);
    let n = data.len() / cin;
    // same fp weights as the fp32 path, quantized symmetric per output row
    let mut wq: Vec<i8> = Vec::with_capacity(cout * cin);
    let mut sw = Vec::with_capacity(cout);
    for j in 0..cout {
        let wrow: Vec<f32> = (0..cin).map(|c| weight(key, j as u64, c as u64)).collect();
        let amax = wrow.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
        let s = (amax / 127.0).max(1e-12);
        sw.push(s);
        wq.extend(wrow.iter().map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8));
    }
    let bias = bias_vec(key, cout);

    // dynamic activation calibration over the batch, grouped per the spec's
    // granularity applied to the input channels
    let flat = Tensor::new(vec![n, cin], data.to_vec());
    let in_spec = QuantSpec::new(spec.precision, cin, Vec::new());
    let (lo, hi) = crate::quant::channel_minmax(&flat);
    let groups = in_spec.groups_for(&lo, &hi);
    let act = crate::quant::ActQuant::calibrate(&lo, &hi, &groups);
    let qx = QTensor::quantize(&flat, &act)?;

    // per-(output, group) integer weight sums for the zero-point correction
    // (i64: a degenerate constant channel far from zero calibrates a huge
    // zero point — the f32->i64 cast saturates instead of overflowing)
    let ng = groups.len().max(1);
    let mut wsum = vec![0i64; cout * ng];
    for j in 0..cout {
        for (gi, g) in groups.iter().enumerate() {
            wsum[j * ng + gi] = g.iter().map(|&c| wq[j * cin + c] as i64).sum();
        }
    }
    let gscale: Vec<f32> = groups.iter().map(|g| act.scale[g[0]]).collect();
    let gzero: Vec<i64> = groups.iter().map(|g| act.zero[g[0]] as i64).collect();

    let scale = 1.0 / (cin.max(1) as f32).sqrt();
    let mut out = Vec::with_capacity(n * cout);
    for r in 0..n {
        let x = &qx.data[r * cin..(r + 1) * cin];
        for j in 0..cout {
            let wrow = &wq[j * cin..(j + 1) * cin];
            let mut acc = 0.0f32;
            for (gi, g) in groups.iter().enumerate() {
                let mut dot = 0i64;
                for &c in g {
                    dot += wrow[c] as i64 * x[c] as i64;
                }
                acc += gscale[gi] * (dot - gzero[gi] * wsum[j * ng + gi]) as f32;
            }
            out.push((sw[j] * acc * scale + bias[j]).tanh());
        }
    }
    Ok(Tensor::new(vec![n, cout], out))
}

/// Per-channel output magnitudes of the head networks — the heterogeneous
/// ranges of paper Fig. 6: tight center offsets and regression residuals
/// next to wide classification logits. This is the structure the role
/// partition exploits (and a single layer scale crushes, Table 7/11).
fn head_scales(manifest: &Manifest, net: &str, cout: usize) -> Option<Vec<f32>> {
    match net {
        "vote" => {
            // xyz vote offsets are small; feature residuals stay unit-scale
            let mut s = vec![1.0f32; cout];
            for v in s.iter_mut().take(3) {
                *v = 0.25;
            }
            Some(s)
        }
        "prop" => {
            let hl = manifest.head_layout;
            let mut s = vec![1.0f32; cout];
            let mut fill = |range: (usize, usize), v: f32| {
                for c in range.0..range.1.min(cout) {
                    s[c] = v;
                }
            };
            fill(hl.center, 0.25);
            fill(hl.objectness, 6.0);
            fill(hl.heading_cls, 6.0);
            fill(hl.heading_reg, 0.5);
            fill(hl.size_cls, 6.0);
            fill(hl.size_reg, 0.5);
            fill(hl.sem_cls, 6.0);
            Some(s)
        }
        _ => None,
    }
}

/// One dense stage at the spec's precision: fp32 or the quantized integer
/// path, optional per-channel output magnitudes, and (int8 only, `out_qdq`)
/// output-activation quantization over the stage's output-channel partition
/// (role groups for the heads).
fn forward(
    data: &[f32],
    cin: usize,
    cout: usize,
    key: u64,
    spec: &QuantSpec,
    scales: Option<&[f32]>,
    out_qdq: bool,
) -> Result<Tensor> {
    let mut t = if spec.precision.is_int8() {
        dense_q(data, cin, cout, key, spec)?
    } else {
        dense(data, cin, cout, key)
    };
    if let Some(sc) = scales {
        for r in 0..t.rows() {
            for (v, s) in t.row_mut(r).iter_mut().zip(sc.iter()) {
                *v *= s;
            }
        }
    }
    if spec.precision.is_int8() && out_qdq {
        let act = spec.calibrate(&t);
        act.qdq(&mut t)?;
    }
    Ok(t)
}

/// Mean-pool the ball dimension of a (b, k, c) tensor into a flat (b * c)
/// row-major buffer.
fn pooled_flat(x: &Tensor) -> Vec<f32> {
    let (b, k, c) = (x.shape[0], x.shape[1], x.shape[2]);
    let inv = 1.0 / k.max(1) as f32;
    let mut out = vec![0.0f32; b * c];
    for i in 0..b {
        let pool = &mut out[i * c..(i + 1) * c];
        let base = i * k * c;
        for kk in 0..k {
            for (p, v) in pool.iter_mut().zip(x.data[base + kk * c..base + (kk + 1) * c].iter()) {
                *p += v;
            }
        }
        for p in pool.iter_mut() {
            *p *= inv;
        }
    }
    out
}

/// Execute one artifact on the surrogate with an explicit per-stage quant
/// spec (`None` uses the manifest-declared spec for the artifact). Output
/// shapes follow the manifest contract for the artifact's `net` role.
pub fn run_with_spec(
    manifest: &Manifest,
    meta: &ArtifactMeta,
    inputs: &[&Tensor],
    spec: Option<&QuantSpec>,
) -> Result<Vec<Tensor>> {
    let x = inputs
        .first()
        .ok_or_else(|| anyhow!("surrogate '{}': no input", meta.name))?;
    let spec = match spec {
        Some(s) => s.clone(),
        None => manifest.stage_quant(meta),
    };
    let key = weight_key(meta);
    match meta.net.as_str() {
        // (H, W, 3) RGB -> (H, W, num_seg_classes) softmax scores
        "seg" => {
            let (h, w, cin) = (x.shape[0], x.shape[1], x.shape[2]);
            let nseg = manifest.num_seg_classes;
            // logits quantize on the int8 path; softmax renormalizes, so no
            // output QDQ after it
            let logits = forward(&x.data, cin, nseg, key, &spec, None, false)?;
            let mut out = logits.data;
            for p in 0..h * w {
                let row = &mut out[p * nseg..(p + 1) * nseg];
                let m = row.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
                let mut s = 0.0f32;
                for v in row.iter_mut() {
                    *v = (*v - m).exp();
                    s += *v;
                }
                for v in row.iter_mut() {
                    *v /= s;
                }
            }
            Ok(vec![Tensor::new(vec![h, w, nseg], out)])
        }
        // (n, fp_in) -> (n, seed_feat)
        "fp_fc" => {
            let cin = x.shape[1];
            Ok(vec![forward(&x.data, cin, manifest.seed_feat, key, &spec, None, true)?])
        }
        // (n, seed_feat) -> (n, 3 + seed_feat) vote offsets + residuals
        "vote" => {
            let cin = x.shape[1];
            let cout = 3 + manifest.seed_feat;
            let sc = head_scales(manifest, "vote", cout);
            Ok(vec![forward(&x.data, cin, cout, key, &spec, sc.as_deref(), true)?])
        }
        // (p, k, c) proposal groups -> (p, head channels)
        "prop" => {
            let head_ch = manifest.head_layout.sem_cls.1;
            let sc = head_scales(manifest, "prop", head_ch);
            let pooled = pooled_flat(x);
            Ok(vec![forward(&pooled, x.shape[2], head_ch, key, &spec, sc.as_deref(), true)?])
        }
        // saN_full / saN_half: (b, k, cin) -> (b, mlp.last)
        net if net.starts_with("sa") => {
            let level: usize = net[2..3]
                .parse()
                .map_err(|_| anyhow!("surrogate: bad SA net name '{net}'"))?;
            let sac = manifest
                .sa_configs
                .get(level - 1)
                .ok_or_else(|| anyhow!("surrogate: SA level {level} out of range"))?;
            let cout = *sac.mlp.last().expect("sa mlp widths");
            let pooled = pooled_flat(x);
            Ok(vec![forward(&pooled, x.shape[2], cout, key, &spec, None, true)?])
        }
        other => Err(anyhow!("surrogate: unknown net role '{other}' ({})", meta.name)),
    }
}

/// Execute one artifact at its manifest-declared quant spec.
pub fn run(manifest: &Manifest, meta: &ArtifactMeta, inputs: &[&Tensor]) -> Result<Vec<Tensor>> {
    run_with_spec(manifest, meta, inputs, None)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::{Granularity, StagePrecision};

    fn manifest() -> Manifest {
        Manifest::synthetic()
    }

    fn probe(shape: &[usize]) -> Tensor {
        let n: usize = shape.iter().product();
        Tensor::new(
            shape.to_vec(),
            (0..n).map(|i| (0.1 + 0.001 * i as f64).sin() as f32).collect(),
        )
    }

    #[test]
    fn deterministic_and_shape_correct() {
        let m = manifest();
        for name in [
            "synrgbd_seg_fp32",
            "synrgbd_seg_int8",
            "synrgbd_pointsplit_sa1_half_int8",
            "synrgbd_pointsplit_sa4_full_int8",
            "synrgbd_pointsplit_fp_fc_int8",
            "synrgbd_pointsplit_vote_int8_role",
            "synrgbd_pointsplit_prop_int8_role",
            "synrgbd_pointsplit_prop_int8_layer",
        ] {
            let meta = m.artifact(name).expect(name).clone();
            let x = probe(&meta.input_shapes[0]);
            let a = run(&m, &meta, &[&x]).expect(name);
            let b = run(&m, &meta, &[&x]).expect(name);
            assert_eq!(a.len(), 1);
            assert_eq!(a[0], b[0], "{name} must be deterministic");
            assert!(a[0].data.iter().all(|v| v.is_finite()), "{name} non-finite");
        }
    }

    #[test]
    fn seg_rows_are_distributions() {
        let m = manifest();
        let meta = m.artifact("synrgbd_seg_fp32").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let out = run(&m, &meta, &[&x]).unwrap().remove(0);
        assert_eq!(out.shape, vec![m.img_size, m.img_size, m.num_seg_classes]);
        for p in 0..m.img_size * m.img_size {
            let s: f32 = out.data[p * m.num_seg_classes..(p + 1) * m.num_seg_classes]
                .iter()
                .sum();
            assert!((s - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn int8_variants_share_weights_and_track_fp32() {
        // precision variants are the same network: the int8 output must be
        // a small perturbation of the fp32 output, not a different model
        let m = manifest();
        let fp = m.artifact("synrgbd_pointsplit_vote_fp32").unwrap().clone();
        let role = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap().clone();
        let x = probe(&fp.input_shapes[0]);
        let yf = run(&m, &fp, &[&x]).unwrap().remove(0);
        let yr = run(&m, &role, &[&x]).unwrap().remove(0);
        assert_ne!(yf, yr, "quantization must not be a no-op");
        let mut err = 0.0f64;
        let mut mag = 0.0f64;
        for (a, b) in yf.data.iter().zip(yr.data.iter()) {
            err += ((a - b) as f64).powi(2);
            mag += (*a as f64).powi(2);
        }
        assert!(
            err / mag.max(1e-12) < 0.05,
            "int8_role relative error {} should be small",
            err / mag
        );
    }

    #[test]
    fn role_preserves_small_channels_better_than_layer() {
        // the Table 11 mechanism, now on the execution path: vote channels
        // 0..3 are the xyz offsets; the role partition isolates them while
        // a single layer scale is set by the widest feature channels
        let m = manifest();
        let fp = m.artifact("synrgbd_pointsplit_vote_fp32").unwrap().clone();
        let role = m.artifact("synrgbd_pointsplit_vote_int8_role").unwrap().clone();
        let layer = m.artifact("synrgbd_pointsplit_vote_int8_layer").unwrap().clone();
        let x = probe(&fp.input_shapes[0]);
        let yf = run(&m, &fp, &[&x]).unwrap().remove(0);
        let yr = run(&m, &role, &[&x]).unwrap().remove(0);
        let yl = run(&m, &layer, &[&x]).unwrap().remove(0);
        let xyz_err = |y: &Tensor| {
            let mut e = 0.0f64;
            for r in 0..y.rows() {
                for c in 0..3 {
                    e += ((y.row(r)[c] - yf.row(r)[c]) as f64).powi(2);
                }
            }
            e
        };
        assert!(
            xyz_err(&yr) <= xyz_err(&yl),
            "role xyz error {} must not exceed layer {}",
            xyz_err(&yr),
            xyz_err(&yl)
        );
    }

    #[test]
    fn explicit_spec_overrides_manifest_default() {
        let m = manifest();
        let meta = m.artifact("synrgbd_pointsplit_sa1_full_int8").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let default = run(&m, &meta, &[&x]).unwrap().remove(0);
        let spec = m.stage_quant_for(&meta, StagePrecision::Int8(Granularity::Channel));
        let grouped = run_with_spec(&m, &meta, &[&x], Some(&spec)).unwrap().remove(0);
        assert_ne!(default, grouped, "granularity override must change the numerics");
    }

    #[test]
    fn sa_output_width_follows_mlp() {
        let m = manifest();
        let meta = m.artifact("synrgbd_pointsplit_sa2_half_int8").unwrap().clone();
        let x = probe(&meta.input_shapes[0]);
        let out = run(&m, &meta, &[&x]).unwrap().remove(0);
        assert_eq!(out.shape, vec![meta.input_shapes[0][0], *m.sa_configs[1].mlp.last().unwrap()]);
    }
}
