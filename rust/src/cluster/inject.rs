//! Failure and straggler injection.
//!
//! Faults are scripted against the simulated clock. The model is
//! deliberately simple and deterministic:
//!
//! - **Kill** is fail-stop at dispatch granularity: the box leaves the
//!   fleet at `at_ms`, batches already dispatched complete (their
//!   completion times were committed at dispatch), and everything still
//!   queued is drained and pushed back through the router — no request is
//!   ever lost to a fault.
//! - **Slow** is a uniform service-time stretch (thermal throttling, a
//!   noisy co-tenant): every batch the box dispatches during the window is
//!   priced at `factor ×` its nominal cost ([`PlanCost::scaled`]).
//!
//! [`PlanCost::scaled`]: crate::sim::PlanCost::scaled

use anyhow::{anyhow, Result};

/// A scripted mid-run fault.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Fault {
    /// Fail-stop: the box leaves the fleet at `at_ms`.
    Kill { box_id: usize, at_ms: f64 },
    /// Straggler: service times stretch by `factor` in `[at_ms, until_ms)`.
    Slow { box_id: usize, at_ms: f64, until_ms: f64, factor: f64 },
}

/// What the runner applies at an injection instant.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FaultAction {
    Kill(usize),
    /// Set the box's service-time multiplier (1.0 restores nominal speed).
    SetSlow(usize, f64),
}

/// Parse a kill list `"1@15,2@20.5"`: box id `@` kill time in **seconds**.
pub fn parse_kills(s: &str) -> Result<Vec<Fault>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let (id, t) = part
            .split_once('@')
            .ok_or_else(|| anyhow!("bad kill spec '{part}' (want BOX@SECONDS)"))?;
        let box_id: usize =
            id.trim().parse().map_err(|_| anyhow!("bad box id in kill spec '{part}'"))?;
        let at_s: f64 =
            t.trim().parse().map_err(|_| anyhow!("bad kill time in '{part}'"))?;
        if !at_s.is_finite() || at_s < 0.0 {
            return Err(anyhow!("kill time must be a non-negative number of seconds: '{part}'"));
        }
        out.push(Fault::Kill { box_id, at_ms: at_s * 1000.0 });
    }
    Ok(out)
}

/// Parse a straggler list `"0@10x3:5"`: box 0, from second 10, runs 3×
/// slower for 5 seconds. Comma-separated for multiple windows.
pub fn parse_slows(s: &str) -> Result<Vec<Fault>> {
    let mut out = Vec::new();
    for part in s.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let err = || anyhow!("bad slow spec '{part}' (want BOX@SECONDSxFACTOR:DURATION)");
        let (id, rest) = part.split_once('@').ok_or_else(err)?;
        let (t, rest) = rest.split_once('x').ok_or_else(err)?;
        let (factor, dur) = rest.split_once(':').ok_or_else(err)?;
        let box_id: usize = id.trim().parse().map_err(|_| err())?;
        let at_s: f64 = t.trim().parse().map_err(|_| err())?;
        let factor: f64 = factor.trim().parse().map_err(|_| err())?;
        let dur_s: f64 = dur.trim().parse().map_err(|_| err())?;
        if !(at_s.is_finite() && factor.is_finite() && dur_s.is_finite())
            || at_s < 0.0
            || dur_s <= 0.0
            || factor < 1.0
        {
            return Err(anyhow!(
                "slow spec '{part}': need start >= 0s, duration > 0s, factor >= 1"
            ));
        }
        out.push(Fault::Slow {
            box_id,
            at_ms: at_s * 1000.0,
            until_ms: (at_s + dur_s) * 1000.0,
            factor,
        });
    }
    Ok(out)
}

/// Expand faults into a time-sorted `(at_ms, action)` schedule — each
/// `Slow` becomes a set-factor edge plus a restore-to-nominal edge.
pub fn schedule(faults: &[Fault]) -> Vec<(f64, FaultAction)> {
    let mut out = Vec::new();
    for f in faults {
        match *f {
            Fault::Kill { box_id, at_ms } => out.push((at_ms, FaultAction::Kill(box_id))),
            Fault::Slow { box_id, at_ms, until_ms, factor } => {
                out.push((at_ms, FaultAction::SetSlow(box_id, factor)));
                out.push((until_ms, FaultAction::SetSlow(box_id, 1.0)));
            }
        }
    }
    out.sort_by(|a, b| a.0.total_cmp(&b.0));
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_kills_and_slows() {
        let kills = parse_kills("1@15, 2@20.5").unwrap();
        assert_eq!(kills.len(), 2);
        assert_eq!(kills[0], Fault::Kill { box_id: 1, at_ms: 15_000.0 });
        assert_eq!(kills[1], Fault::Kill { box_id: 2, at_ms: 20_500.0 });
        let slows = parse_slows("0@10x3:5").unwrap();
        assert_eq!(
            slows,
            vec![Fault::Slow { box_id: 0, at_ms: 10_000.0, until_ms: 15_000.0, factor: 3.0 }]
        );
    }

    #[test]
    fn rejects_malformed_fault_specs() {
        assert!(parse_kills("1").is_err());
        assert!(parse_kills("x@5").is_err());
        assert!(parse_kills("1@-5").is_err());
        assert!(parse_slows("0@10").is_err());
        assert!(parse_slows("0@10x0.5:5").is_err(), "factor < 1 is a speed-up, not a fault");
        assert!(parse_slows("0@10x3:0").is_err());
    }

    #[test]
    fn schedule_expands_and_sorts() {
        let faults = [
            Fault::Kill { box_id: 2, at_ms: 8_000.0 },
            Fault::Slow { box_id: 0, at_ms: 2_000.0, until_ms: 5_000.0, factor: 3.0 },
        ];
        let sched = schedule(&faults);
        assert_eq!(
            sched,
            vec![
                (2_000.0, FaultAction::SetSlow(0, 3.0)),
                (5_000.0, FaultAction::SetSlow(0, 1.0)),
                (8_000.0, FaultAction::Kill(2)),
            ]
        );
    }
}
