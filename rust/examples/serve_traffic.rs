//! Traffic-gateway walkthrough: the same detector box under calm Poisson
//! traffic, a bursty overload, and a diurnal ramp — with and without the
//! SLO-degradation policy.
//!
//! Runs entirely on the simulated clock (synthetic manifest), so it needs no
//! artifacts:
//!
//! ```bash
//! cargo run --release --example serve_traffic
//! ```

use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::serving::{
    run_traffic, ArrivalPattern, BatchPolicy, LoadGen, ServicePlanner, SloPolicy, TrafficScenario,
};
use pointsplit::sim::DeviceKind;

fn main() {
    let planner = ServicePlanner::synthetic();
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let batch = BatchPolicy { max_batch: 4, max_wait_ms: 25.0 };
    let cap = planner.capacity_rps(&cfg, 2048, batch.max_batch).expect("capacity");
    println!("PointSplit INT8 on GPU+EdgeTPU: steady-state capacity {cap:.2} rps at batch 4\n");

    let cases: Vec<(&str, ArrivalPattern, SloPolicy)> = vec![
        ("calm poisson 0.6x", ArrivalPattern::Poisson { rate_rps: cap * 0.6 }, SloPolicy::Degrade),
        (
            "bursty 1.0x mean, 2.5x bursts — no policy",
            ArrivalPattern::Bursty {
                base_rps: cap * 0.4,
                burst_rps: cap * 2.5,
                mean_burst_ms: 2_000.0,
                mean_calm_ms: 6_000.0,
            },
            SloPolicy::None,
        ),
        (
            "bursty 1.0x mean, 2.5x bursts — degrade policy",
            ArrivalPattern::Bursty {
                base_rps: cap * 0.4,
                burst_rps: cap * 2.5,
                mean_burst_ms: 2_000.0,
                mean_calm_ms: 6_000.0,
            },
            SloPolicy::Degrade,
        ),
        (
            "diurnal ramp peaking at 1.6x",
            ArrivalPattern::Diurnal { base_rps: cap * 0.4, peak_rps: cap * 1.6, period_s: 60.0 },
            SloPolicy::Degrade,
        ),
    ];
    for (name, pattern, policy) in cases {
        let sc = TrafficScenario {
            name: name.to_string(),
            configs: vec![cfg.clone()],
            num_points: 2048,
            load: LoadGen::simple(pattern, 60_000.0, 1_000.0, 7),
            queue_capacity: 64,
            batch,
            policy,
        };
        run_traffic(&sc, &planner, None).expect("traffic run").print();
        println!();
    }
    println!(
        "takeaway: same arrival trace, same hardware — the degrade policy converts\n\
         burst-time deadline misses into on-time (slightly lower-fidelity) answers."
    );
}
