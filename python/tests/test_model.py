"""VoteNet-mini model: shapes, variants, decode, attention head."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model

KEY = jax.random.PRNGKey(0)


@pytest.fixture(scope="module")
def painted_params():
    return model.detector_init(KEY, painted=True)


@pytest.fixture(scope="module")
def plain_params():
    return model.detector_init(KEY, painted=False)


def scene_inputs(painted, n=512, seed=0):
    rng = np.random.default_rng(seed)
    xyz = jnp.asarray(rng.uniform(-2, 2, (n, 3)).astype(np.float32))
    c = common.FEAT_DIM if painted else common.FEAT_DIM_PLAIN
    feats = jnp.asarray(rng.normal(size=(n, c)).astype(np.float32))
    fg = jnp.asarray((rng.uniform(size=n) < 0.3).astype(np.float32))
    return xyz, feats, fg


@pytest.mark.parametrize("variant", ["full", "split", "randsplit"])
def test_forward_shapes(painted_params, variant):
    xyz, feats, fg = scene_inputs(True)
    ep = model.detector_forward(
        painted_params,
        xyz,
        feats,
        variant=variant,
        fg=fg,
        split_key=jax.random.PRNGKey(1),
    )
    assert ep["seed_xyz"].shape == (common.NUM_SEEDS, 3)
    assert ep["vote_xyz"].shape == (common.NUM_SEEDS, 3)
    assert ep["cluster_xyz"].shape == (common.NUM_PROPOSALS, 3)
    assert ep["proposal"].shape == (common.NUM_PROPOSALS, common.PROPOSAL_CH)


def test_plain_variant_narrow_features(plain_params):
    xyz, feats, _ = scene_inputs(False)
    ep = model.detector_forward(plain_params, xyz, feats, variant="full")
    assert ep["proposal"].shape == (common.NUM_PROPOSALS, common.PROPOSAL_CH)


def test_forward_deterministic(painted_params):
    xyz, feats, fg = scene_inputs(True, seed=3)
    a = model.detector_forward(painted_params, xyz, feats, variant="split", fg=fg)
    b = model.detector_forward(painted_params, xyz, feats, variant="split", fg=fg)
    np.testing.assert_array_equal(np.asarray(a["proposal"]), np.asarray(b["proposal"]))


def test_split_uses_bias_weight(painted_params):
    """w0 != 1 must change which points the bias pipeline samples."""
    xyz, feats, fg = scene_inputs(True, seed=4)
    a = model.detector_forward(painted_params, xyz, feats, variant="split", fg=fg, w0=1.0)
    b = model.detector_forward(painted_params, xyz, feats, variant="split", fg=fg, w0=3.0)
    assert not np.allclose(np.asarray(a["seed_xyz"]), np.asarray(b["seed_xyz"]))


def test_pallas_and_ref_paths_agree(painted_params):
    xyz, feats, fg = scene_inputs(True, seed=5, n=256)
    a = model.detector_forward(painted_params, xyz, feats, variant="full", fg=fg, use_pallas=False)
    b = model.detector_forward(painted_params, xyz, feats, variant="full", fg=fg, use_pallas=True)
    np.testing.assert_allclose(
        np.asarray(a["proposal"]), np.asarray(b["proposal"]), rtol=1e-4, atol=1e-4
    )


def test_decode_shapes_and_ranges(painted_params):
    xyz, feats, fg = scene_inputs(True, seed=6)
    ep = model.detector_forward(painted_params, xyz, feats, variant="full", fg=fg)
    dec = model.decode_proposals(
        ep["cluster_xyz"], ep["proposal"], jnp.asarray(common.MEAN_SIZES)
    )
    assert dec["center"].shape == (common.NUM_PROPOSALS, 3)
    obj = np.asarray(dec["objectness"])
    assert (obj >= 0).all() and (obj <= 1).all()
    size = np.asarray(dec["size"])
    assert (size > 0).all()
    h = np.asarray(dec["heading"])
    assert (h >= 0).all() and (h < 2 * np.pi + 1e-5).all()


def test_segmenter_shapes():
    p = model.segmenter_init(KEY)
    img = jnp.zeros((common.IMG_SIZE, common.IMG_SIZE, 3))
    logits = model.segmenter_forward(p, img)
    assert logits.shape == (common.IMG_SIZE, common.IMG_SIZE, common.NUM_SEG_CLASSES)
    scores = np.asarray(model.segmenter_scores(p, img))
    np.testing.assert_allclose(scores.sum(-1), 1.0, atol=1e-5)


def test_attn_head_shapes(painted_params):
    ap = model.attn_head_init(jax.random.PRNGKey(2))
    xyz, feats, fg = scene_inputs(True, seed=7)
    ep = model.attn_detector_forward(painted_params, ap, xyz, feats, variant="full", fg=fg)
    assert ep["proposal"].shape == (common.NUM_PROPOSALS, common.PROPOSAL_CH)
    assert ep["cluster_xyz"].shape == (common.NUM_PROPOSALS, 3)


def test_attn_apply_matches_full_forward(painted_params):
    """The exported network-only subgraphs must compose to the full head."""
    ap = model.attn_head_init(jax.random.PRNGKey(2))
    seed_xyz = jnp.asarray(np.random.default_rng(0).normal(size=(common.NUM_SEEDS, 3)).astype(np.float32))
    seed_feats = jnp.asarray(
        np.random.default_rng(1).normal(size=(common.NUM_SEEDS, common.SEED_FEAT)).astype(np.float32)
    )
    centers, out = model.attn_head_forward(ap, seed_xyz, seed_feats)
    from compile import sampling

    proj = model.attn_proj(ap, seed_feats)
    idx = sampling.fps(seed_xyz, common.NUM_PROPOSALS)
    out2 = model.attn_apply(ap, proj[idx], proj)
    np.testing.assert_allclose(np.asarray(out), np.asarray(out2), rtol=1e-5, atol=1e-5)


def test_role_groups_partition_head():
    groups = common.proposal_role_groups()
    assert sorted(c for g in groups for c in g) == list(range(common.PROPOSAL_CH))
    assert len(groups) == 3
    vgroups = common.vote_role_groups()
    assert sorted(c for g in vgroups for c in g) == list(range(common.VOTE_CH))


def test_fp_layer_cost_table1_shape():
    """Table 1: PointSplit FP must halve params and cut MAdds by ~1/3."""
    (p_orig, m_orig), (p_ps, m_ps) = model.fp_layer_cost(paper_scale=True)
    assert p_ps < 0.55 * p_orig
    assert m_ps < 0.75 * m_orig
    # paper-scale absolute numbers (Table 1: 398,336 params / 304 MAdd)
    assert abs(p_orig - 398_336) / 398_336 < 0.05
    assert abs(m_orig - 304e6) / 304e6 < 0.1
