//! Semantic-segmentation mIoU (paper Tables 4/5).

/// Accumulates a confusion matrix over (prediction, ground-truth) label
/// pairs and reports per-class IoU.
pub struct ConfusionMiou {
    num_classes: usize,
    /// confusion[gt * C + pred]
    confusion: Vec<u64>,
}

impl ConfusionMiou {
    pub fn new(num_classes: usize) -> Self {
        ConfusionMiou { num_classes, confusion: vec![0; num_classes * num_classes] }
    }

    pub fn add(&mut self, gt: &[u8], pred: &[u8]) {
        assert_eq!(gt.len(), pred.len());
        for (&g, &p) in gt.iter().zip(pred.iter()) {
            self.confusion[g as usize * self.num_classes + p as usize] += 1;
        }
    }

    /// Per-class IoU = TP / (TP + FP + FN). Classes with no presence -> None.
    pub fn per_class_iou(&self) -> Vec<Option<f64>> {
        let c = self.num_classes;
        (0..c)
            .map(|k| {
                let tp = self.confusion[k * c + k];
                let fn_: u64 = (0..c).map(|j| self.confusion[k * c + j]).sum::<u64>() - tp;
                let fp: u64 = (0..c).map(|j| self.confusion[j * c + k]).sum::<u64>() - tp;
                let denom = tp + fp + fn_;
                if denom == 0 {
                    None
                } else {
                    Some(tp as f64 / denom as f64)
                }
            })
            .collect()
    }

    /// Mean IoU over foreground classes (index 0 = background excluded),
    /// matching the paper's per-object-class mIoU tables.
    pub fn miou_foreground(&self) -> f64 {
        let ious = self.per_class_iou();
        let present: Vec<f64> = ious.iter().skip(1).flatten().copied().collect();
        if present.is_empty() {
            0.0
        } else {
            present.iter().sum::<f64>() / present.len() as f64
        }
    }
}

/// One-shot helper.
pub fn confusion_miou(gt: &[u8], pred: &[u8], num_classes: usize) -> f64 {
    let mut m = ConfusionMiou::new(num_classes);
    m.add(gt, pred);
    m.miou_foreground()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn perfect_prediction_iou_one() {
        let gt = vec![0u8, 1, 2, 1, 0, 2];
        let m = confusion_miou(&gt, &gt, 3);
        assert!((m - 1.0).abs() < 1e-9);
    }

    #[test]
    fn half_wrong_class() {
        // class1: gt {1,1}, pred {1,2} -> IoU(1) = 1/2; class2: gt {2}, pred {2,2}...
        let gt = vec![1u8, 1, 2];
        let pred = vec![1u8, 2, 2];
        let m = ConfusionMiou::new(3);
        let mut m = m;
        m.add(&gt, &pred);
        let ious = m.per_class_iou();
        assert!((ious[1].unwrap() - 0.5).abs() < 1e-9);
        assert!((ious[2].unwrap() - 0.5).abs() < 1e-9);
    }

    #[test]
    fn background_excluded_from_miou() {
        let gt = vec![0u8, 0, 0, 1];
        let pred = vec![0u8, 0, 0, 1];
        let m = confusion_miou(&gt, &pred, 2);
        assert!((m - 1.0).abs() < 1e-9);
    }
}
