//! Proposal decoding (mirror of model.decode_proposals) + NMS into [`Box3`].

use crate::data::Box3;
use crate::eval::nms3d;
use crate::runtime::Manifest;
use crate::util::tensor::Tensor;

fn softmax(xs: &[f32]) -> Vec<f32> {
    let m = xs.iter().fold(f32::NEG_INFINITY, |a, &b| a.max(b));
    let exps: Vec<f32> = xs.iter().map(|&x| (x - m).exp()).collect();
    let s: f32 = exps.iter().sum();
    exps.iter().map(|&e| e / s).collect()
}

fn argmax(xs: &[f32]) -> usize {
    // first-max tie-break (matches jnp.argmax)
    let mut best = 0;
    for (i, &x) in xs.iter().enumerate() {
        if x > xs[best] {
            best = i;
        }
    }
    best
}

/// Decode raw head channels into per-class detections.
///
/// cluster_xyz: (P, 3) proposal base centers; prop: (P, 79) raw channels.
/// Emits one detection per (proposal, argmax class) with
/// score = P(object) * P(class), then class-agnostic NMS.
pub fn decode_detections(
    manifest: &Manifest,
    cluster_xyz: &[[f32; 3]],
    prop: &Tensor,
    obj_thresh: f32,
    nms_iou: f64,
) -> Vec<Box3> {
    let hl = &manifest.head_layout;
    let nh = manifest.num_heading_bin;
    let nc = manifest.num_class();
    let per = 2.0 * std::f32::consts::PI / nh as f32;
    let mut boxes = Vec::new();
    for p in 0..prop.rows() {
        let row = prop.row(p);
        let obj = softmax(&row[hl.objectness.0..hl.objectness.1])[1];
        if obj < obj_thresh {
            continue;
        }
        let center = [
            cluster_xyz[p][0] + row[hl.center.0],
            cluster_xyz[p][1] + row[hl.center.0 + 1],
            cluster_xyz[p][2] + row[hl.center.0 + 2],
        ];
        let hbin = argmax(&row[hl.heading_cls.0..hl.heading_cls.1]);
        let hres = row[hl.heading_reg.0 + hbin] * (per / 2.0);
        let heading = (hbin as f32 * per + hres).rem_euclid(2.0 * std::f32::consts::PI);
        let sbin = argmax(&row[hl.size_cls.0..hl.size_cls.1]);
        let mean = manifest.mean_sizes[sbin];
        let mut size = [0.0f32; 3];
        for d in 0..3 {
            let res = row[hl.size_reg.0 + sbin * 3 + d].clamp(-0.9, 2.0);
            size[d] = mean[d] * (1.0 + res);
        }
        let sem = softmax(&row[hl.sem_cls.0..hl.sem_cls.1]);
        let cls = argmax(&sem[..nc]);
        boxes.push(Box3 { center, size, heading, class: cls, score: obj * sem[cls] });
    }
    let keep = nms3d(&boxes, nms_iou);
    keep.into_iter().map(|i| boxes[i]).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn softmax_normalizes() {
        let s = softmax(&[1.0, 2.0, 3.0]);
        assert!((s.iter().sum::<f32>() - 1.0).abs() < 1e-6);
        assert!(s[2] > s[1] && s[1] > s[0]);
    }

    #[test]
    fn argmax_first_max() {
        assert_eq!(argmax(&[1.0, 3.0, 3.0]), 1);
    }
}
