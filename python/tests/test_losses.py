"""VoteNet loss components: supervised signals behave as specified."""

import jax
import jax.numpy as jnp
import numpy as np

from compile import common, losses

MEAN = jnp.asarray(common.MEAN_SIZES)


def fake_gt(centers, classes=None):
    k = losses.MAX_OBJ
    n = len(centers)
    gt = {
        "centers": jnp.zeros((k, 3)).at[:n].set(jnp.asarray(centers, jnp.float32)),
        "sizes": jnp.ones((k, 3)).at[:n].set(jnp.asarray([[1.0, 1.0, 1.0]] * n)),
        "headings": jnp.zeros((k,)),
        "classes": jnp.zeros((k,), jnp.int32).at[:n].set(
            jnp.asarray(classes if classes is not None else [0] * n, jnp.int32)
        ),
        "mask": jnp.zeros((k,)).at[:n].set(1.0),
    }
    return gt


def fake_endpoints(cluster_centers, prop=None):
    p = len(cluster_centers)
    return {
        "seed_xyz": jnp.asarray(cluster_centers, jnp.float32),
        "vote_xyz": jnp.asarray(cluster_centers, jnp.float32),
        "cluster_xyz": jnp.asarray(cluster_centers, jnp.float32),
        "proposal": prop if prop is not None else jnp.zeros((p, common.PROPOSAL_CH)),
    }


def test_perfect_votes_zero_vote_loss():
    centers = [[0.0, 0.0, 0.5]]
    ep = fake_endpoints([[0.0, 0.0, 0.5]])
    out = losses.scene_loss(ep, fake_gt(centers), MEAN)
    assert float(out["vote"]) < 1e-6


def test_bad_votes_penalized():
    centers = [[0.0, 0.0, 0.5]]
    ep = fake_endpoints([[0.0, 0.0, 0.5]])
    ep["vote_xyz"] = jnp.asarray([[3.0, 3.0, 0.5]])  # vote far away
    out = losses.scene_loss(ep, fake_gt(centers), MEAN)
    assert float(out["vote"]) > 1.0


def test_objectness_ce_direction():
    """Raising the positive logit on a near-GT proposal lowers the loss."""
    centers = [[0.0, 0.0, 0.5]]
    gt = fake_gt(centers)
    prop_bad = jnp.zeros((1, common.PROPOSAL_CH)).at[0, 3].set(5.0)  # 'no object'
    prop_good = jnp.zeros((1, common.PROPOSAL_CH)).at[0, 4].set(5.0)  # 'object'
    l_bad = losses.scene_loss(fake_endpoints(centers, prop_bad), gt, MEAN)
    l_good = losses.scene_loss(fake_endpoints(centers, prop_good), gt, MEAN)
    assert float(l_good["objectness"]) < float(l_bad["objectness"])


def test_far_proposal_is_negative():
    gt = fake_gt([[0.0, 0.0, 0.5]])
    far = [[5.0, 5.0, 0.5]]
    prop_obj = jnp.zeros((1, common.PROPOSAL_CH)).at[0, 4].set(5.0)  # claims object
    prop_no = jnp.zeros((1, common.PROPOSAL_CH)).at[0, 3].set(5.0)
    l_claim = losses.scene_loss(fake_endpoints(far, prop_obj), gt, MEAN)
    l_deny = losses.scene_loss(fake_endpoints(far, prop_no), gt, MEAN)
    assert float(l_deny["objectness"]) < float(l_claim["objectness"])


def test_heading_targets_in_unit_interval():
    for h in np.linspace(0, 2 * np.pi - 1e-3, 20):
        per = 2 * np.pi / common.NUM_HEADING_BIN
        hbin = int(h // per)
        hres = (h - (hbin * per + per / 2)) / (per / 2)
        assert -1.0 - 1e-6 <= hres <= 1.0 + 1e-6


def test_total_is_weighted_sum():
    gt = fake_gt([[0.0, 0.0, 0.5]])
    ep = fake_endpoints([[0.1, 0.0, 0.5]])
    out = losses.scene_loss(ep, gt, MEAN)
    expect = (
        losses.W_VOTE * out["vote"]
        + losses.W_OBJ * out["objectness"]
        + losses.W_CENTER * out["center"]
        + losses.W_HEAD_CLS * out["heading_cls"]
        + losses.W_HEAD_REG * out["heading_reg"]
        + losses.W_SIZE_CLS * out["size_cls"]
        + losses.W_SIZE_REG * out["size_reg"]
        + losses.W_SEM * out["sem"]
    )
    np.testing.assert_allclose(float(out["total"]), float(expect), rtol=1e-6)


def test_seg_loss_prefers_correct_mask():
    rng = np.random.default_rng(0)
    mask = jnp.asarray(rng.integers(0, common.NUM_SEG_CLASSES, (16, 16)), jnp.int32)
    good = jax.nn.one_hot(mask, common.NUM_SEG_CLASSES) * 10.0
    bad = jnp.zeros_like(good)
    assert float(losses.seg_loss(good, mask)) < float(losses.seg_loss(bad, mask))


def test_loss_differentiable():
    gt = fake_gt([[0.0, 0.0, 0.5]])

    def f(prop):
        return losses.scene_loss(fake_endpoints([[0.1, 0.0, 0.5]], prop), gt, MEAN)["total"]

    g = jax.grad(f)(jnp.zeros((1, common.PROPOSAL_CH)))
    assert np.isfinite(np.asarray(g)).all()
    assert np.abs(np.asarray(g)).sum() > 0
