//! Integration tests for the fleet-level cluster layer: config-affinity
//! routing vs the random baseline, fail-stop box kills with graceful
//! rerouting, the rendezvous failover property across seeds, reactive
//! autoscaling in both directions, and the report's JSON round-trip.
//! Everything runs on the synthetic manifest and the simulated clock.

use pointsplit::cluster::{
    config_mix, plan_box, run_cluster, AutoscalePolicy, ClusterScenario, ClusterSpec, ClusterTrace,
    Fault, RouterPolicy,
};
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::serving::{ArrivalPattern, BatchPolicy, LoadGen, ServicePlanner, SloPolicy};
use pointsplit::sim::DeviceKind;
use pointsplit::util::json::Json;

fn base_cfg() -> DetectorConfig {
    DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    )
}

fn fleet_capacity(planner: &ServicePlanner, spec: &ClusterSpec, configs: &[DetectorConfig]) -> f64 {
    let batch = BatchPolicy { max_batch: 4, max_wait_ms: 25.0 };
    let mix = vec![1.0; configs.len()];
    spec.boxes
        .iter()
        .map(|bt| plan_box(planner, bt, configs, 2048, &batch, &mix).unwrap().capacity_rps)
        .sum()
}

#[allow(clippy::too_many_arguments)]
fn scenario(
    spec: &str,
    configs: Vec<DetectorConfig>,
    rate_rps: f64,
    duration_s: f64,
    deadline_ms: f64,
    policy: SloPolicy,
    router: RouterPolicy,
    seed: u64,
) -> ClusterScenario {
    let n = configs.len();
    let mut load = LoadGen::simple(
        ArrivalPattern::Poisson { rate_rps },
        duration_s * 1000.0,
        deadline_ms,
        seed,
    );
    load.mix = vec![1.0; n];
    ClusterScenario {
        name: format!("test-{spec}"),
        spec: ClusterSpec::parse(spec).unwrap(),
        configs,
        num_points: 2048,
        queue_capacity: 16,
        load,
        batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
        policy,
        router,
        router_seed: seed,
        faults: Vec::new(),
        autoscale: None,
    }
}

fn assert_conserved(trace: &ClusterTrace) {
    let r = &trace.report;
    assert_eq!(trace.outcomes.len(), r.arrivals, "one outcome per arrival");
    assert_eq!(
        r.completed + r.rejected_full + r.expired + r.shed_slo,
        r.arrivals,
        "outcome counts must partition the arrivals"
    );
    let mut ids: Vec<u64> = trace.outcomes.iter().map(|o| o.id).collect();
    ids.sort_unstable();
    let n = ids.len();
    ids.dedup();
    assert_eq!(ids.len(), n, "a request resolved twice (double dispatch)");
}

/// Acceptance: at equal offered load on the identical arrival trace,
/// config-affinity routing must batch better than random routing — and the
/// better batching must show up as goodput.
#[test]
fn affinity_beats_random_on_batching_and_goodput() {
    let planner = ServicePlanner::synthetic();
    let configs = config_mix(&base_cfg(), 4);
    let spec = "gpu+edgetpu:6";
    let cap = fleet_capacity(&planner, &ClusterSpec::parse(spec).unwrap(), &configs);
    let rate = cap * 0.9;
    let mk = |router: RouterPolicy| {
        scenario(spec, configs.clone(), rate, 90.0, 2_500.0, SloPolicy::None, router, 77)
    };
    let affinity = run_cluster(&mk(RouterPolicy::ConfigAffinity), &planner).unwrap();
    let random = run_cluster(&mk(RouterPolicy::Random), &planner).unwrap();
    assert_conserved(&affinity);
    assert_conserved(&random);
    // identical trace: both runs saw the same arrivals
    assert_eq!(affinity.report.arrivals, random.report.arrivals);
    assert!(
        affinity.report.mean_batch > random.report.mean_batch,
        "affinity mean batch {:.2} must beat random {:.2}",
        affinity.report.mean_batch,
        random.report.mean_batch
    );
    assert!(
        affinity.report.goodput_rps > random.report.goodput_rps,
        "affinity goodput {:.2} must beat random {:.2}",
        affinity.report.goodput_rps,
        random.report.goodput_rps
    );
}

/// Acceptance: a box killed mid-run degrades attainment gracefully — its
/// queue is drained and rerouted (visible in the report), nothing is lost,
/// and no request is routed to the dead box afterwards.
#[test]
fn killed_box_reroutes_without_losing_requests() {
    let planner = ServicePlanner::synthetic();
    let spec = "gpu+edgetpu,gpu,cpu+edgetpu";
    let configs = config_mix(&base_cfg(), 2);
    let cap = fleet_capacity(&planner, &ClusterSpec::parse(spec).unwrap(), &configs);
    let kill_ms = 15_000.0;
    let mk = |faults: Vec<Fault>| {
        let mut sc = scenario(
            spec,
            configs.clone(),
            cap * 1.3,
            30.0,
            1_000.0,
            SloPolicy::Degrade,
            RouterPolicy::ConfigAffinity,
            13,
        );
        sc.queue_capacity = 32;
        sc.faults = faults;
        sc
    };
    let healthy = run_cluster(&mk(Vec::new()), &planner).unwrap();
    let sc = mk(vec![Fault::Kill { box_id: 0, at_ms: kill_ms }]);
    let faulted = run_cluster(&sc, &planner).unwrap();
    assert_conserved(&healthy);
    assert_conserved(&faulted);
    assert_eq!(healthy.report.arrivals, faulted.report.arrivals, "same trace");

    let fr = &faulted.report;
    assert!(fr.rerouted > 0, "a saturated box must have had queued work to drain");
    assert!(
        fr.events.iter().any(|e| e.what.contains("killed")),
        "kill must appear in the event log"
    );
    assert!(!fr.boxes[0].alive, "box 0 must end the run dead");
    assert!(fr.boxes[0].alive_s < fr.duration_s, "billed only while provisioned");
    // graceful: still completing work, but strictly worse than the
    // fault-free run on the same arrivals
    assert!(fr.on_time > 0, "surviving boxes must keep serving");
    assert!(
        fr.on_time < healthy.report.on_time,
        "losing a box mid-run cannot improve on-time count ({} vs {})",
        fr.on_time,
        healthy.report.on_time
    );
    // no request was routed to the dead box after the kill: any route to
    // box 0 belongs to an arrival from before the fault fired
    let arrivals = sc.load.generate();
    for (id, box_id, _) in &faulted.routes {
        if *box_id == 0 {
            assert!(
                arrivals[*id as usize].arrival_ms <= kill_ms,
                "request {id} routed to the dead box after the kill"
            );
        }
    }
}

/// Rendezvous-hash property, across seeds: while membership is stable each
/// config key lands on at most `width` (2) boxes, and one fail-stop kill
/// adds at most one replacement box per key. Conservation holds throughout.
#[test]
fn affinity_property_holds_under_failover_across_seeds() {
    let planner = ServicePlanner::synthetic();
    let spec = "gpu+edgetpu:5";
    let configs = config_mix(&base_cfg(), 4);
    let cap = fleet_capacity(&planner, &ClusterSpec::parse(spec).unwrap(), &configs);
    for seed in [1u64, 5, 9] {
        let mut sc = scenario(
            spec,
            configs.clone(),
            cap * 0.8,
            25.0,
            1_500.0,
            SloPolicy::Degrade,
            RouterPolicy::ConfigAffinity,
            seed,
        );
        sc.faults = vec![Fault::Kill { box_id: 2, at_ms: 10_000.0 }];
        let trace = run_cluster(&sc, &planner).unwrap();
        assert_conserved(&trace);
        let num_keys = sc.configs.len();
        let mut per_key: Vec<Vec<usize>> = vec![Vec::new(); num_keys];
        for (_, box_id, key) in &trace.routes {
            per_key[*key].push(*box_id);
        }
        for (key, boxes) in per_key.iter_mut().enumerate() {
            boxes.sort_unstable();
            boxes.dedup();
            assert!(
                boxes.len() <= 3,
                "seed {seed}: key {key} spread over {} boxes (width 2 + 1 failover max)",
                boxes.len()
            );
        }
    }
}

#[test]
fn autoscaler_adds_boxes_under_overload_and_improves_on_time() {
    let planner = ServicePlanner::synthetic();
    let spec = "gpu+edgetpu";
    let configs = config_mix(&base_cfg(), 2);
    let cap = fleet_capacity(&planner, &ClusterSpec::parse(spec).unwrap(), &configs);
    let mk = |autoscale: Option<AutoscalePolicy>| {
        let mut sc = scenario(
            spec,
            configs.clone(),
            cap * 2.5,
            30.0,
            1_000.0,
            SloPolicy::Degrade,
            RouterPolicy::ConfigAffinity,
            21,
        );
        sc.autoscale = autoscale;
        sc
    };
    let fixed = run_cluster(&mk(None), &planner).unwrap();
    let scaled =
        run_cluster(&mk(Some(AutoscalePolicy { max_boxes: 6, ..Default::default() })), &planner)
            .unwrap();
    assert_conserved(&fixed);
    assert_conserved(&scaled);
    let sr = &scaled.report;
    assert!(sr.events.iter().any(|e| e.what.contains("scale-up")), "scale-up must fire at 2.5x");
    assert!(sr.boxes.len() > 1, "the fleet must actually have grown");
    assert!(sr.boxes.len() <= 6, "max_boxes bound respected");
    assert!(
        sr.on_time > fixed.report.on_time,
        "extra capacity must convert to on-time completions ({} vs {})",
        sr.on_time,
        fixed.report.on_time
    );
    assert!(sr.cost_units > fixed.report.cost_units, "extra boxes must show up on the bill");
}

#[test]
fn autoscaler_retires_idle_boxes_at_low_load() {
    let planner = ServicePlanner::synthetic();
    let spec = "gpu+edgetpu:4";
    let configs = config_mix(&base_cfg(), 2);
    let cap = fleet_capacity(&planner, &ClusterSpec::parse(spec).unwrap(), &configs);
    let mut sc = scenario(
        spec,
        configs,
        cap * 0.05,
        30.0,
        1_000.0,
        SloPolicy::Degrade,
        RouterPolicy::ConfigAffinity,
        33,
    );
    sc.autoscale = Some(AutoscalePolicy::default());
    let trace = run_cluster(&sc, &planner).unwrap();
    assert_conserved(&trace);
    let r = &trace.report;
    assert!(r.events.iter().any(|e| e.what.contains("retired")), "scale-down must fire at 5% load");
    let alive = r.boxes.iter().filter(|b| b.alive).count();
    assert!(alive < 4, "an idle fleet of 4 must shrink");
    assert!(alive >= 1, "min_boxes floor respected");
    // retired boxes stop billing: the bill must undercut 4 boxes all run
    assert!(
        r.cost_units < 4.0 * 4.0 * r.duration_s,
        "bill {:.0} must reflect retired boxes (4 gpu+edgetpu boxes all run would be {:.0})",
        r.cost_units,
        4.0 * 4.0 * r.duration_s
    );
}

#[test]
fn cluster_report_json_roundtrips() {
    let planner = ServicePlanner::synthetic();
    let spec = "gpu+edgetpu,gpu,cpu+edgetpu";
    let configs = config_mix(&base_cfg(), 2);
    let cap = fleet_capacity(&planner, &ClusterSpec::parse(spec).unwrap(), &configs);
    let mut sc = scenario(
        spec,
        configs,
        cap,
        20.0,
        1_000.0,
        SloPolicy::Degrade,
        RouterPolicy::ConfigAffinity,
        3,
    );
    sc.faults = vec![Fault::Kill { box_id: 1, at_ms: 10_000.0 }];
    let trace = run_cluster(&sc, &planner).unwrap();
    let text = trace.report.to_json().to_string();
    let parsed = Json::parse(&text).expect("report JSON must parse back");
    assert_eq!(parsed.req("arrivals").as_usize().unwrap(), trace.report.arrivals);
    assert_eq!(parsed.req("router").as_str(), Some("affinity"));
    assert_eq!(parsed.req("boxes").as_arr().unwrap().len(), 3);
    assert!(!parsed.req("events").as_arr().unwrap().is_empty(), "kill event serialized");
    let att = parsed.req("slo_attainment").as_f64().unwrap();
    assert!((0.0..=1.0).contains(&att));
    assert!(parsed.req("goodput_rps").as_f64().unwrap() >= 0.0);
    for b in parsed.req("boxes").as_arr().unwrap() {
        assert!(b.req("capacity_rps").as_f64().unwrap() > 0.0);
        assert!(b.req("type").as_str().is_some());
    }
}
