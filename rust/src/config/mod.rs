//! Layered configuration system + CLI argument parser (clap is not
//! vendored). Config values resolve as: defaults < JSON config file <
//! `--key value` command-line overrides.

use std::collections::BTreeMap;

use anyhow::{anyhow, Result};

use crate::coordinator::{Schedule, Variant};
use crate::sim::DeviceKind;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Cli {
    pub command: String,
    pub flags: BTreeMap<String, String>,
    pub positional: Vec<String>,
}

impl Cli {
    pub fn parse(args: impl Iterator<Item = String>) -> Result<Cli> {
        let mut out = Cli::default();
        let mut args = args.peekable();
        if let Some(cmd) = args.next() {
            if cmd.starts_with("--") {
                return Err(anyhow!("expected subcommand before flags, got '{cmd}'"));
            }
            out.command = cmd;
        }
        while let Some(a) = args.next() {
            if let Some(key) = a.strip_prefix("--") {
                // --key=value or --key value or boolean --key
                if let Some((k, v)) = key.split_once('=') {
                    out.flags.insert(k.to_string(), v.to_string());
                } else if args.peek().is_some_and(|n| !n.starts_with("--")) {
                    out.flags.insert(key.to_string(), args.next().unwrap());
                } else {
                    out.flags.insert(key.to_string(), "true".to_string());
                }
            } else {
                out.positional.push(a);
            }
        }
        Ok(out)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.flags.get(key).map(|s| s.as_str())
    }

    pub fn get_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn get_usize(&self, key: &str, default: usize) -> Result<usize> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects an integer, got '{v}'")),
        }
    }

    pub fn get_f64(&self, key: &str, default: f64) -> Result<f64> {
        match self.get(key) {
            None => Ok(default),
            Some(v) => v.parse().map_err(|_| anyhow!("--{key} expects a number, got '{v}'")),
        }
    }

    pub fn get_bool(&self, key: &str) -> bool {
        matches!(self.get(key), Some("true") | Some("1") | Some("yes"))
    }
}

pub fn parse_variant(s: &str) -> Result<Variant> {
    match s.to_ascii_lowercase().as_str() {
        "votenet" => Ok(Variant::VoteNet),
        "pointpainting" | "painted" => Ok(Variant::PointPainting),
        "randomsplit" | "randsplit" => Ok(Variant::RandomSplit),
        "pointsplit" => Ok(Variant::PointSplit),
        _ => Err(anyhow!(
            "unknown variant '{s}' (votenet|pointpainting|randomsplit|pointsplit)"
        )),
    }
}

pub fn parse_device(s: &str) -> Result<DeviceKind> {
    match s.to_ascii_lowercase().as_str() {
        "cpu" => Ok(DeviceKind::Cpu),
        "gpu" => Ok(DeviceKind::Gpu),
        "edgetpu" | "tpu" | "npu" => Ok(DeviceKind::EdgeTpu),
        _ => Err(anyhow!("unknown device '{s}' (cpu|gpu|edgetpu)")),
    }
}

/// Schedule spec grammar: `gpu` (single device), `gpu+edgetpu` (pipelined),
/// `gpu>edgetpu` (sequential split).
pub fn parse_schedule(s: &str) -> Result<Schedule> {
    if let Some((a, b)) = s.split_once('+') {
        Ok(Schedule::Pipelined { point_dev: parse_device(a)?, nn_dev: parse_device(b)? })
    } else if let Some((a, b)) = s.split_once('>') {
        Ok(Schedule::Sequential { point_dev: parse_device(a)?, nn_dev: parse_device(b)? })
    } else {
        Ok(Schedule::SingleDevice(parse_device(s)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cli(s: &str) -> Cli {
        Cli::parse(s.split_whitespace().map(str::to_string)).unwrap()
    }

    #[test]
    fn parses_flags_and_positionals() {
        // note: a bare flag directly followed by a positional is ambiguous;
        // booleans use `--flag` at the end or `--flag=true`
        let c = cli("serve --dataset synrgbd --scenes 32 pos1 --quick");
        assert_eq!(c.command, "serve");
        assert_eq!(c.get("dataset"), Some("synrgbd"));
        assert_eq!(c.get_usize("scenes", 0).unwrap(), 32);
        assert!(c.get_bool("quick"));
        assert_eq!(c.positional, vec!["pos1"]);
    }

    #[test]
    fn equals_syntax() {
        let c = cli("run --w0=2.5");
        assert_eq!(c.get_f64("w0", 1.0).unwrap(), 2.5);
    }

    #[test]
    fn schedule_grammar() {
        assert!(matches!(parse_schedule("gpu").unwrap(), Schedule::SingleDevice(DeviceKind::Gpu)));
        assert!(matches!(
            parse_schedule("gpu+edgetpu").unwrap(),
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
        ));
        assert!(matches!(
            parse_schedule("cpu>edgetpu").unwrap(),
            Schedule::Sequential { point_dev: DeviceKind::Cpu, nn_dev: DeviceKind::EdgeTpu }
        ));
        assert!(parse_schedule("quantum").is_err());
    }

    #[test]
    fn variant_names() {
        assert_eq!(parse_variant("PointSplit").unwrap(), Variant::PointSplit);
        assert!(parse_variant("yolo").is_err());
    }
}
