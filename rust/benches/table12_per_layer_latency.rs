//! Paper Table 12: per-layer latency of the sequential INT8 pipeline —
//! point manipulation on GPU vs PointNet on EdgeTPU, layer by layer.
//!
//! Expected shape: GPU cost decreases monotonically (fewer points per
//! layer); EdgeTPU cost peaks mid-network (input-size vs channel-count
//! trade-off); 2D-3D fusion is the single largest NPU stage.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scene = generate_scene(9, &SYNRGBD);
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointPainting,
        true,
        Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let out = ScenePipeline::new(&rt, cfg).run(&scene, 9).expect("pipeline");
    let tl = &out.timeline;
    let stage_ms = |name: &str| {
        tl.stage(name).map(|s| s.end_ms - s.compute_start_ms + s.comm_ms).unwrap_or(0.0)
    };
    let mut t = Table::new(&["layer", "GPU (ms)", "EdgeTPU (ms)", "paper GPU", "paper TPU"]);
    t.row(vec![
        "2D-3D fusion".into(),
        format!("{:.0}", stage_ms("paint")),
        format!("{:.0}", stage_ms("seg")),
        "-".into(),
        "222".into(),
    ]);
    for (l, pg, pt) in [(1, 199, 47), (2, 52, 71), (3, 25, 84), (4, 20, 21)] {
        let (pm, nn) = if l < 4 {
            (format!("sa{l}_full_pm"), format!("sa{l}_full_nn"))
        } else {
            ("sa4_pm".to_string(), "sa4_nn".to_string())
        };
        t.row(vec![
            format!("SA{l}"),
            format!("{:.0}", stage_ms(&pm)),
            format!("{:.0}", stage_ms(&nn)),
            format!("{pg}"),
            format!("{pt}"),
        ]);
    }
    t.print("Table 12 — per-layer latency, sequential INT8 PointPainting (simulated vs paper)");
    println!("\n(total sequential: {:.0} ms)", tl.total_ms);
}
