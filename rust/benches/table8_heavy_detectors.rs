//! Paper Table 8: PointSplit applied to a transformer-based detector
//! (GroupFree3D / RepSurf in the paper; GroupFree3D-mini attention head
//! here). Accuracy-only, FP32, primary dataset.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::attn::{run_attn, AttnVariant};
use pointsplit::data::{self, SYNRGBD};
use pointsplit::eval::{eval_map, Detection};

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(40);
    let mut t = Table::new(&["method", "mAP@0.25", "mAP@0.5"]);
    for variant in [
        AttnVariant::Baseline,
        AttnVariant::Painted,
        AttnVariant::RandomSplit,
        AttnVariant::Split,
    ] {
        let mut dets: Vec<Detection> = Vec::new();
        let mut gts = Vec::new();
        for i in 0..scenes {
            let scene = data::generate_scene(500_000 + i as u64, &SYNRGBD);
            gts.push(scene.gt_boxes());
            let boxes = run_attn(&rt, variant, &scene, 2.0, i as u64).expect("attn run");
            dets.extend(boxes.into_iter().map(|b| Detection { scene: i, b }));
        }
        let r25 = eval_map(&dets, &gts, rt.manifest.num_class(), 0.25);
        let r50 = eval_map(&dets, &gts, rt.manifest.num_class(), 0.50);
        t.row(vec![
            variant.name().to_string(),
            format!("{:.1}", r25.map * 100.0),
            format!("{:.1}", r50.map * 100.0),
        ]);
        eprintln!("  [{}] done", variant.name());
    }
    t.print(&format!(
        "Table 8 — attention-head detector +/- PointSplit on synrgbd ({scenes} scenes; paper GF3D: 58.0 -> 62.6 with PointSplit)"
    ));
}
