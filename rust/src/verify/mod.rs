//! `pallas-verify`: a static verifier + lint pass over the [`StageGraph`]
//! IR, its pass outputs, and the cluster plans derived from it.
//!
//! Compilers earn trust with a verifier that runs after every pass; this
//! module is that verifier for the detector's IR. Checks are composable and
//! return structured [`Diagnostic`]s (rule id, severity, node/edge locus,
//! fix hint) instead of booleans or panics, so the same rules serve three
//! consumers:
//!
//! - the `verify` CLI command — non-zero exit iff any error-severity
//!   diagnostic fires across graphs, schedules, and cluster specs;
//! - debug-assertion auto-verification after every pass
//!   ([`StageGraph::build`], [`StageGraph::quant_rewrite`] and
//!   [`StageGraph::batch_fold`] self-check in debug builds, at zero
//!   release cost);
//! - the metamorphic suite (`rust/tests/verify.rs`) asserting each pass is
//!   invariant-preserving: a clean graph stays clean under batch-fold,
//!   quant-rewrite, degrade, and placement.
//!
//! Rule families (full catalog with example diagnostics: `docs/VERIFIER.md`):
//!
//! - **G — graph soundness** (G001–G004): dependency order including
//!   `extra_deps` (submission order must be topological, exactly what
//!   [`crate::exec::DagExecutor`] and [`crate::sim::ScheduleSim`] require),
//!   no dangling dep indices, every node's artifact / [`QuantSpec`] /
//!   workload consistent with the [`Manifest`] under the shared
//!   `nn_assign`/`nn_device` derivation, SA-chain metadata matching the
//!   topology.
//! - **P — precision & capability flow** (P001–P003): each node's device
//!   `supports()` its (workload kind, precision); no fp32→int8 edge into an
//!   NN consumer without an explicit int8 QDQ spec; degenerate placements
//!   (an NN device assigned but nothing runnable there) flagged.
//! - **S — schedule / resource analysis** (S001–S007): per-stage memory
//!   fit at the folded batch, per-device memory across *live intervals* of
//!   the simulated timeline, every cross-device transfer priced (no free
//!   edges), batch-fold(k) output exactly k-scalable, every point-op
//!   stage's declared memory covering at least the SoA-padded coordinate
//!   buffer the lane kernels actually stream, a streaming gateway's
//!   session cache fitting its declared memory bound
//!   ([`verify_session_cache`]), and every NN stage's declared memory
//!   covering the packed-weight + activation footprint its dense layer
//!   touches ([`crate::runtime::gemm::nn_footprint_bytes`]).
//! - **E — executor race/deadlock soundness** (E001–E003, [`verify_exec`]):
//!   for the `exec::DagExecutor` lowering, every [`crate::exec::Slot`] a
//!   stage closure reads is covered by its transitive declared deps, and no
//!   slot has two producers — the class of bug the `sa4_pm` merge fix
//!   closed by hand, caught mechanically.
//! - **C — cluster-plan conservation** (C001–C004, [`verify_cluster`]):
//!   every [`crate::cluster::ClusterSpec`] box plan serves every config key
//!   the router can pin to it, on devices the box actually has; autoscale
//!   templates verify under the same rules.
//!
//! [`QuantSpec`]: crate::quant::QuantSpec

mod cluster_check;
mod exec_check;

pub use cluster_check::{verify_box_plan, verify_cluster};
pub use exec_check::verify_exec;

use std::fmt;

use crate::graph::{StageClass, StageGraph};
use crate::runtime::Manifest;
use crate::sim::{Device, DeviceKind, Precision, ScheduleSim, StageSpec, WorkloadKind};

/// How bad a finding is. `Error` means the graph/plan would panic, deadlock
/// or mis-serve at runtime; `Warning` means it executes correctly but is
/// degenerate or wasteful (reported, never fatal).
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(&self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding: a stable rule id, a severity, the node/edge it anchors to,
/// what is wrong, and how to fix it.
#[derive(Debug, Clone)]
pub struct Diagnostic {
    /// Stable rule id (`"G001"`, `"P002"`, …) — pinned by the bad-graph
    /// corpus in `rust/tests/verify.rs` and cataloged in `docs/VERIFIER.md`.
    pub rule: &'static str,
    pub severity: Severity,
    /// Where: `"node 12 'sa4_pm'"`, `"edge 3->7"`, `"box 'gpu' key 1"`, …
    pub locus: String,
    pub message: String,
    /// Actionable fix hint.
    pub hint: String,
}

impl fmt::Display for Diagnostic {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}[{}] {}: {} (hint: {})",
            self.severity.name(),
            self.rule,
            self.locus,
            self.message,
            self.hint
        )
    }
}

/// Outcome of a verification run: every diagnostic, in rule-firing order.
#[derive(Debug, Clone, Default)]
pub struct Report {
    pub diagnostics: Vec<Diagnostic>,
}

impl Report {
    pub fn new() -> Report {
        Report::default()
    }

    fn push(
        &mut self,
        rule: &'static str,
        severity: Severity,
        locus: impl Into<String>,
        message: impl Into<String>,
        hint: impl Into<String>,
    ) {
        self.diagnostics.push(Diagnostic {
            rule,
            severity,
            locus: locus.into(),
            message: message.into(),
            hint: hint.into(),
        });
    }

    pub fn errors(&self) -> Vec<&Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).collect()
    }

    pub fn warnings(&self) -> Vec<&Diagnostic> {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).collect()
    }

    pub fn has_errors(&self) -> bool {
        self.diagnostics.iter().any(|d| d.severity == Severity::Error)
    }

    /// No diagnostics at all (not even warnings).
    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// Did a specific rule fire?
    pub fn fired(&self, rule: &str) -> bool {
        self.diagnostics.iter().any(|d| d.rule == rule)
    }

    /// Absorb another report's diagnostics.
    pub fn merge(&mut self, other: Report) {
        self.diagnostics.extend(other.diagnostics);
    }

    /// Absorb another report's diagnostics with every locus prefixed
    /// (cluster checks nest per-config graph reports this way).
    pub fn merge_prefixed(&mut self, prefix: &str, other: Report) {
        for mut d in other.diagnostics {
            d.locus = format!("{prefix}{}", d.locus);
            self.diagnostics.push(d);
        }
    }
}

impl fmt::Display for Report {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        for d in &self.diagnostics {
            writeln!(f, "{d}")?;
        }
        write!(f, "{} error(s), {} warning(s)", self.errors().len(), self.warnings().len())
    }
}

/// Verify a graph's structure against its manifest: rule families G
/// (soundness) and P (precision/capability flow).
pub fn verify_graph(m: &Manifest, g: &StageGraph) -> Report {
    let mut r = verify_structure(m, g);
    if r.has_errors() {
        // dangling indices make every downstream check unsafe to evaluate
        return r;
    }
    check_capabilities(g, &mut r);
    check_precision_flow(g, &mut r);
    check_placement_degeneracy(g, &mut r);
    check_soa_footprint(g, &mut r);
    check_nn_footprint(m, g, &mut r);
    r
}

/// The *placement-independent* subset of [`verify_graph`]: edge sanity,
/// manifest consistency, chain metadata, and executor slot soundness. This
/// is what every pass self-checks under `debug_assertions` — capability
/// rules are deliberately excluded because the placement search builds
/// graphs for infeasible schedules on purpose (and then rejects them).
pub fn verify_structure(m: &Manifest, g: &StageGraph) -> Report {
    let mut r = Report::new();
    check_edges(g, &mut r);
    if r.has_errors() {
        return r;
    }
    check_manifest_consistency(m, g, &mut r);
    check_chains(g, &mut r);
    r.merge(verify_exec(g));
    r
}

/// Verify a schedule lowering at a batch size: rule family S (resources),
/// plus the structural/capability preconditions that make simulating it
/// safe at all (a cyclic or unsupported spec list would panic the
/// simulator — the verifier reports instead).
pub fn verify_schedule(sim: &ScheduleSim, g: &StageGraph, batch: usize) -> Report {
    let mut r = Report::new();
    check_edges(g, &mut r);
    if r.has_errors() {
        return r;
    }
    let folded = g.batch_fold(batch);
    r.merge(check_specs(sim, &folded));
    r.merge(check_fold(&g.specs(), &folded, batch.max(1)));
    check_priced_edges(g, &mut r);
    if r.has_errors() {
        return r;
    }
    check_live_memory(sim, &folded, &mut r);
    r
}

/// Everything about one graph: structure + executor lowering
/// ([`verify_graph`] includes the E rules) and the schedule at `batch`.
pub fn verify_all(sim: &ScheduleSim, m: &Manifest, g: &StageGraph, batch: usize) -> Report {
    let mut r = verify_graph(m, g);
    if r.has_errors() {
        return r; // schedule checks would only repeat the structural errors
    }
    r.merge(verify_schedule(sim, g, batch));
    r
}

// --------------------------------------------------------------- G family

/// G001/G002: every dep (timeline and host-ordering alike) must point to an
/// existing, *earlier* node. Submission order is the topological order both
/// the executor and the simulator rely on, so a forward or self edge is the
/// static form of a cycle/deadlock: `DagExecutor::run` would reject it and
/// `ScheduleSim::run` would panic on it.
pub(crate) fn check_edges(g: &StageGraph, r: &mut Report) {
    for (i, node) in g.nodes.iter().enumerate() {
        let kinds = [("dep", &node.spec.deps), ("extra_dep", &node.extra_deps)];
        for (kind, deps) in kinds {
            for &d in deps.iter() {
                if d >= g.nodes.len() {
                    r.push(
                        "G002",
                        Severity::Error,
                        format!("node {i} '{}'", node.spec.name),
                        format!("{kind} {d} dangles: the graph has {} nodes", g.nodes.len()),
                        "remove the edge or re-point it at an existing node",
                    );
                } else if d >= i {
                    r.push(
                        "G001",
                        Severity::Error,
                        format!("edge {d}->{i} '{}'", node.spec.name),
                        format!(
                            "{kind} on {} node {d}: submission order must be topological \
                             (a forward/self edge is a cycle to the executor)",
                            if d == i { "its own" } else { "a later" }
                        ),
                        "declare producers before consumers; never edge forward",
                    );
                }
            }
        }
    }
}

/// G003: every NN node's artifact, quant spec, precision and workload must
/// equal what the shared `nn_assign`/`nn_device` derivation produces for
/// its class under the graph's config — i.e. the node is consistent with
/// the [`Manifest`] (artifact exists, channel widths match the declared
/// quant roles) and with the per-precision placement rule. Point-op nodes
/// must carry neither artifact nor quant spec.
fn check_manifest_consistency(m: &Manifest, g: &StageGraph, r: &mut Report) {
    let cfg = g.cfg();
    for (i, node) in g.nodes.iter().enumerate() {
        let locus = format!("node {i} '{}'", node.spec.name);
        let derived = match crate::graph::nn_assign(m, cfg, node.class) {
            Ok(d) => d,
            Err(e) => {
                r.push(
                    "G003",
                    Severity::Error,
                    locus,
                    format!("manifest cannot satisfy this node's class: {e:#}"),
                    "export the artifact (make artifacts) or fix the config's dataset/scheme",
                );
                continue;
            }
        };
        match derived {
            None => {
                if node.artifact.is_some() || node.qspec.is_some() {
                    r.push(
                        "G003",
                        Severity::Error,
                        locus,
                        "point-op node carries an artifact or quant spec",
                        "only NN stage classes execute manifest artifacts",
                    );
                }
            }
            Some((art, precision, wl, qspec)) => {
                let mut bad: Vec<&str> = Vec::new();
                if node.artifact.as_deref() != Some(art.as_str()) {
                    bad.push("artifact");
                }
                if node.qspec.as_ref() != Some(&qspec) {
                    bad.push("quant spec");
                }
                if node.spec.precision != precision {
                    bad.push("precision");
                }
                if node.spec.workload != wl {
                    bad.push("workload");
                }
                if node.spec.device != crate::graph::nn_device(cfg, node.class, precision) {
                    bad.push("device");
                }
                if !bad.is_empty() {
                    r.push(
                        "G003",
                        Severity::Error,
                        locus,
                        format!(
                            "{} drifted from the manifest derivation for {:?} \
                             (expected artifact '{art}')",
                            bad.join(" + "),
                            node.class
                        ),
                        "re-derive NN nodes through nn_assign/nn_device; never hand-edit them",
                    );
                }
            }
        }
    }
}

/// G004: the SA-chain metadata (`chains`) must match the node topology —
/// right number of chains and levels, indices of the declared classes,
/// PointNet depending on its point-manip stage, and the point budget
/// chaining `level[l+1].n_in == level[l].m` the exec lowering assumes.
fn check_chains(g: &StageGraph, r: &mut Report) {
    let want_chains = if g.cfg().variant.split() { 2 } else { 1 };
    if g.chains.len() != want_chains {
        r.push(
            "G004",
            Severity::Error,
            "chains".to_string(),
            format!("{} chains declared, variant implies {want_chains}", g.chains.len()),
            "chain metadata must mirror the variant's pipeline structure",
        );
    }
    for (ci, chain) in g.chains.iter().enumerate() {
        let locus = format!("chain {ci} '{}'", chain.tag);
        if chain.levels.len() != 3 {
            r.push(
                "G004",
                Severity::Error,
                locus,
                format!("{} SA levels declared, the backbone has exactly 3", chain.levels.len()),
                "declare SA1..SA3 per chain; SA4 is a fused top-level stage",
            );
            continue;
        }
        let mut n_in = chain.n0;
        for (l, lvl) in chain.levels.iter().enumerate() {
            let locus = format!("chain {ci} '{}' level {l}", chain.tag);
            let pm_ok = g
                .nodes
                .get(lvl.pm)
                .is_some_and(|n| n.class == StageClass::SaPm { chain: ci, level: l });
            let nn_ok = g
                .nodes
                .get(lvl.nn)
                .is_some_and(|n| n.class == StageClass::SaNn { chain: ci, level: l });
            if !pm_ok || !nn_ok {
                r.push(
                    "G004",
                    Severity::Error,
                    locus,
                    format!(
                        "level points at nodes {}/{} which are not its SaPm/SaNn stages",
                        lvl.pm, lvl.nn
                    ),
                    "chain level indices must reference the matching stage-class nodes",
                );
                continue;
            }
            if !g.nodes[lvl.nn].spec.deps.contains(&lvl.pm) {
                r.push(
                    "G004",
                    Severity::Error,
                    locus,
                    format!(
                        "PointNet node {} does not depend on its point-manip {}",
                        lvl.nn, lvl.pm
                    ),
                    "the NN stage consumes the grouping its pm stage produces",
                );
            }
            if lvl.n_in != n_in {
                r.push(
                    "G004",
                    Severity::Error,
                    locus,
                    format!("n_in {} breaks the chain: previous level sampled {n_in}", lvl.n_in),
                    "level l+1 consumes exactly the centroids level l sampled",
                );
            }
            n_in = lvl.m;
        }
    }
}

// --------------------------------------------------------------- P family

/// P001 at batch 1 — see [`check_specs`] for the shared per-spec rule.
fn check_capabilities(g: &StageGraph, r: &mut Report) {
    for (i, node) in g.nodes.iter().enumerate() {
        let s = &node.spec;
        if !Device::by_kind(s.device).supports(s.workload.kind, s.precision) {
            r.push(
                "P001",
                Severity::Error,
                format!("node {i} '{}'", s.name),
                format!(
                    "stage ({:?}, {}) unsupported on {} — it would panic at dispatch",
                    s.workload.kind,
                    s.precision.name(),
                    s.device.name()
                ),
                "re-place via the precision rule (fp32 NN falls back off the EdgeTPU)",
            );
        }
    }
}

/// P002: an fp32→int8 edge into an NN consumer needs an explicit quantize
/// step. In this IR the QDQ boundary is the consumer's [`QuantSpec`]
/// (`crate::runtime` quantizes activations under it before the int8
/// matmul), so an int8 NN node fed fp32 data without an int8 spec has no
/// defined numeric behaviour.
fn check_precision_flow(g: &StageGraph, r: &mut Report) {
    for (i, node) in g.nodes.iter().enumerate() {
        let s = &node.spec;
        if s.precision != Precision::Int8 || s.workload.kind != WorkloadKind::NeuralNet {
            continue;
        }
        let fp32_feed = s.deps.iter().any(|&d| g.nodes[d].spec.precision == Precision::Fp32);
        let has_qdq = node.qspec.as_ref().is_some_and(|q| q.precision.is_int8());
        if fp32_feed && !has_qdq {
            r.push(
                "P002",
                Severity::Error,
                format!("node {i} '{}'", s.name),
                "fp32->int8 edge without a QDQ role: int8 NN consumer of fp32 data \
                 carries no int8 quant spec",
                "attach the scheme's QuantSpec so activations are quantized at the boundary",
            );
        }
    }
}

/// P003 (warning): the schedule names an NN device but no node actually
/// lands there (e.g. an fp32 scheme with an EdgeTPU NN assignment — every
/// NN stage falls back to the point device). The graph executes correctly,
/// but the placement label is a degenerate alias of a cheaper assignment;
/// the placement search refuses to rank such candidates for the same
/// reason.
fn check_placement_degeneracy(g: &StageGraph, r: &mut Report) {
    let sched = g.cfg().schedule;
    let (pd, nd) = (sched.point_dev(), sched.nn_dev());
    if nd != pd && !g.nodes.iter().any(|n| n.spec.device == nd) {
        r.push(
            "P003",
            Severity::Warning,
            format!("schedule {sched:?}"),
            format!(
                "degenerate placement: no stage of this scheme can execute on {} \
                 (fp32 NN falls back to {})",
                nd.name(),
                pd.name()
            ),
            "quantize the scheme or drop the unused device from the schedule",
        );
    }
}

// --------------------------------------------------------------- S family

/// P001 + S001 over an explicit (possibly folded) spec list: capability and
/// single-stage memory fit against the given device models. Shared with
/// the placement search's feasibility check, so search rejections and
/// verifier diagnostics can never disagree.
pub fn check_specs(sim: &ScheduleSim, specs: &[StageSpec]) -> Report {
    let mut r = Report::new();
    for (i, s) in specs.iter().enumerate() {
        let dev = sim.device(s.device);
        if !dev.supports(s.workload.kind, s.precision) {
            r.push(
                "P001",
                Severity::Error,
                format!("node {i} '{}'", s.name),
                format!(
                    "stage '{}' ({:?}, {}) unsupported on {}",
                    s.name,
                    s.workload.kind,
                    s.precision.name(),
                    s.device.name()
                ),
                "re-place via the precision rule (fp32 NN falls back off the EdgeTPU)",
            );
        } else if !dev.fits(&s.workload) {
            r.push(
                "S001",
                Severity::Error,
                format!("node {i} '{}'", s.name),
                format!(
                    "stage '{}' streams {} B, over the {} capacity of {} B",
                    s.name,
                    s.workload.mem_bytes,
                    s.device.name(),
                    dev.mem_capacity_bytes
                ),
                "shrink the batch or place the stage on a device with more memory",
            );
        }
    }
    r
}

/// S004: `batch_fold(k)` must be *exactly* k-scalable — identical names,
/// devices, precisions and dependency edges, with every workload dimension
/// scaled by exactly k (dispatch/transfer setup costs are per-stage and
/// amortize by construction; anything else is a broken pass).
pub fn check_fold(base: &[StageSpec], folded: &[StageSpec], k: usize) -> Report {
    let mut r = Report::new();
    let k64 = k.max(1) as u64;
    if base.len() != folded.len() {
        r.push(
            "S004",
            Severity::Error,
            "batch-fold".to_string(),
            format!("fold changed the stage count: {} -> {}", base.len(), folded.len()),
            "batch-fold scales workloads; it never reshapes the DAG",
        );
        return r;
    }
    for (i, (b, f)) in base.iter().zip(folded.iter()).enumerate() {
        let locus = format!("node {i} '{}'", b.name);
        if b.name != f.name
            || b.device != f.device
            || b.precision != f.precision
            || b.deps != f.deps
            || b.workload.kind != f.workload.kind
        {
            r.push(
                "S004",
                Severity::Error,
                locus,
                "fold changed a non-workload field (name/device/precision/deps/kind)",
                "batch-fold scales workloads; it never reshapes the DAG",
            );
            continue;
        }
        let pairs = [
            ("flops", b.workload.flops, f.workload.flops),
            ("mem_bytes", b.workload.mem_bytes, f.workload.mem_bytes),
            ("wire_bytes", b.workload.wire_bytes, f.workload.wire_bytes),
        ];
        for (field, bv, fv) in pairs {
            if fv != bv * k64 {
                r.push(
                    "S004",
                    Severity::Error,
                    format!("node {i} '{}'", b.name),
                    format!("{field} not k-scalable: {bv} folded to {fv}, expected {}", bv * k64),
                    "every workload dimension scales by exactly the batch size",
                );
            }
        }
    }
    r
}

/// S003: every cross-device edge must be priced — a producer whose output
/// crosses a device boundary with `wire_bytes == 0` would make the
/// simulator (and hence the planner, dispatcher and autoscaler) treat the
/// transfer as free.
fn check_priced_edges(g: &StageGraph, r: &mut Report) {
    for (i, node) in g.nodes.iter().enumerate() {
        for &d in &node.spec.deps {
            let p = &g.nodes[d].spec;
            if p.device != node.spec.device && p.workload.wire_bytes == 0 {
                r.push(
                    "S003",
                    Severity::Error,
                    format!("edge {d}->{i} '{}'->'{}'", p.name, node.spec.name),
                    format!(
                        "free cross-device edge: '{}' ({}) feeds '{}' ({}) with 0 wire bytes",
                        p.name,
                        p.device.name(),
                        node.spec.name,
                        node.spec.device.name()
                    ),
                    "set the producer's wire_bytes to its activation size",
                );
            }
        }
    }
}

/// S002 (warning): per-device memory fit across *live intervals* of the
/// simulated timeline. Single-stage fit (S001) is necessary but not
/// sufficient — stages whose intervals overlap on one device (the CPU's
/// concurrent point-op and NN lanes) must fit together.
fn check_live_memory(sim: &ScheduleSim, folded: &[StageSpec], r: &mut Report) {
    let tl = sim.run(folded);
    for kind in [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::EdgeTpu] {
        let cap = sim.device(kind).mem_capacity_bytes;
        // (time, +/- working set) events over [start, end) of each stage
        let mut events: Vec<(f64, i128)> = Vec::new();
        for iv in tl.stages.iter().filter(|iv| iv.device == kind) {
            let mem = folded
                .iter()
                .find(|s| s.name == iv.name)
                .map_or(0i128, |s| s.workload.mem_bytes as i128);
            if mem > 0 {
                events.push((iv.start_ms, mem));
                events.push((iv.end_ms, -mem));
            }
        }
        // releases before acquisitions at equal timestamps
        events.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));
        let (mut live, mut peak) = (0i128, 0i128);
        for (_, delta) in events {
            live += delta;
            peak = peak.max(live);
        }
        if peak > cap as i128 {
            r.push(
                "S002",
                Severity::Warning,
                format!("device {}", kind.name()),
                format!(
                    "live working sets peak at {peak} B, over the {} capacity of {cap} B",
                    kind.name()
                ),
                "reduce the batch or serialize the overlapping stages",
            );
        }
    }
}

/// S005 (warning): a point-manipulation stage's declared `mem_bytes` must
/// cover at least the SoA coordinate buffer the lane kernels stream — the
/// input cloud padded to a lane multiple ([`crate::pointops::soa_bytes`]).
/// A smaller declaration means the memory-fit analyses (S001/S002) and the
/// placement search reason about less memory than the executor touches.
/// Input sizes come from the chain metadata (validated by G004 before this
/// check runs): `SaPm` reads its level's `n_in`, `Sa4Pm` fuses every
/// chain's SA3 output, `PropPm` clusters the seed set (SA2-sized).
fn check_soa_footprint(g: &StageGraph, r: &mut Report) {
    let level_sum = |l: usize| -> usize {
        g.chains.iter().filter_map(|c| c.levels.get(l)).map(|lvl| lvl.m).sum()
    };
    for (i, node) in g.nodes.iter().enumerate() {
        let n_in = match node.class {
            StageClass::SaPm { chain, level } => {
                match g.chains.get(chain).and_then(|c| c.levels.get(level)) {
                    Some(lvl) => lvl.n_in,
                    None => continue, // G004 already reported the broken metadata
                }
            }
            StageClass::Sa4Pm => level_sum(2),
            StageClass::PropPm => level_sum(1),
            _ => continue,
        };
        let need = crate::pointops::soa_bytes(n_in);
        let declared = node.spec.workload.mem_bytes;
        if declared < need {
            r.push(
                "S005",
                Severity::Warning,
                format!("node {i} '{}'", node.spec.name),
                format!(
                    "declared workload streams {declared} B but the SoA-padded input \
                     cloud alone is {need} B ({n_in} points, lane-padded x/y/z)"
                ),
                "size the stage's mem_bytes from its real input cloud, not the output",
            );
        }
    }
}

/// S007 (warning, mirroring S005 for the NN stages): an NN stage's declared
/// `mem_bytes` must cover at least the packed-weight + input-activation
/// footprint of the dense layer it executes —
/// [`crate::runtime::gemm::nn_footprint_bytes`] over the `(rows, cin, cout)`
/// the surrogate derives from the manifest contract
/// ([`crate::runtime::surrogate::layer_dims`]) at the stage's precision.
/// A smaller declaration means the memory-fit analyses (S001/S002) and the
/// placement search reason about less memory than the GEMM layer resident
/// weights + streamed activations actually touch. Stages whose artifact is
/// missing or whose net role the surrogate cannot shape are skipped (G003
/// owns manifest consistency).
fn check_nn_footprint(m: &Manifest, g: &StageGraph, r: &mut Report) {
    for (i, node) in g.nodes.iter().enumerate() {
        let Some(art) = node.artifact.as_deref() else { continue };
        let Some(meta) = m.artifact(art) else { continue };
        let Ok((rows, cin, cout)) = crate::runtime::surrogate::layer_dims(m, meta) else {
            continue;
        };
        let int8 = node.spec.precision == Precision::Int8;
        let need = crate::runtime::gemm::nn_footprint_bytes(rows, cin, cout, int8);
        let declared = node.spec.workload.mem_bytes;
        if declared < need {
            r.push(
                "S007",
                Severity::Warning,
                format!("node {i} '{}'", node.spec.name),
                format!(
                    "declared workload streams {declared} B but the packed weights + \
                     input activations of its ({cin} -> {cout}) dense layer over {rows} \
                     rows need {need} B"
                ),
                "size the stage's mem_bytes from its packed weights and real activation rows",
            );
        }
    }
}

/// S006 (error): a streaming gateway's per-box session cache must fit its
/// configured memory bound: `sessions × per-session footprint ≤ bound`.
/// The per-session footprint is what [`crate::temporal::FrameCache`]
/// actually retains between frames
/// ([`crate::temporal::session_footprint_bytes`]); a cache declared over
/// its bound would OOM the box under a full client load, exactly when the
/// reuse path matters most.
pub fn verify_session_cache(
    sessions: usize,
    per_session_bytes: u64,
    bound_bytes: u64,
) -> Report {
    let mut r = Report::new();
    let declared = sessions as u64 * per_session_bytes;
    if declared > bound_bytes {
        r.push(
            "S006",
            Severity::Error,
            format!("session cache ({sessions} sessions)"),
            format!(
                "declared session memory {declared} B ({sessions} sessions x \
                 {per_session_bytes} B) exceeds the configured bound {bound_bytes} B"
            ),
            "lower the session capacity, shrink the cached artifacts, or raise the bound",
        );
    }
    r
}
