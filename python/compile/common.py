"""Shared constants and configuration for the PointSplit reproduction.

Everything here is mirrored on the Rust side via ``artifacts/manifest.json``:
class names, canonical mean sizes, head channel layout, role groups, and the
per-dataset generation parameters. Keep this file the single source of truth.
"""

from __future__ import annotations

import dataclasses
from typing import List, Tuple

# ---------------------------------------------------------------------------
# Classes (mirrors the 10 SUN RGB-D evaluation categories)
# ---------------------------------------------------------------------------

CLASSES: List[str] = [
    "bed",
    "table",
    "sofa",
    "chair",
    "toilet",
    "desk",
    "dresser",
    "nightstand",
    "bookshelf",
    "bathtub",
]
NUM_CLASS = len(CLASSES)

# Background + per-class channels produced by the 2D segmenter and appended to
# each painted point (PointPainting appends the full score vector).
NUM_SEG_CLASSES = NUM_CLASS + 1  # index 0 == background

NUM_HEADING_BIN = 12

# Canonical mean sizes (w, d, h) per class, the "size clusters" of VoteNet.
# These are the midpoints of the procedural generator ranges in scene.py; the
# Rust generator uses the same table (exported in the manifest).
MEAN_SIZES: List[Tuple[float, float, float]] = [
    (1.85, 1.65, 0.50),  # bed
    (1.40, 0.85, 0.72),  # table
    (1.85, 0.90, 0.75),  # sofa
    (0.48, 0.48, 0.85),  # chair
    (0.40, 0.55, 0.75),  # toilet
    (1.30, 0.70, 0.74),  # desk
    (1.00, 0.50, 0.95),  # dresser
    (0.50, 0.50, 0.60),  # nightstand
    (0.80, 0.30, 1.75),  # bookshelf
    (1.60, 0.80, 0.55),  # bathtub
]

# ---------------------------------------------------------------------------
# Proposal-head channel layout (paper Table 2) — 79 channels for 10 classes.
# ---------------------------------------------------------------------------
# [0:3)    center offset (xyz)                      -> role group 1
# [3:5)    objectness (2)                           -> role group 2
# [5:17)   heading-bin classification (12)          -> role group 2
# [17:29)  heading-bin regression (12)              -> role group 3
# [29:39)  size classification (10)                 -> role group 2
# [39:69)  size regression (10*3)                   -> role group 3
# [69:79)  semantic classification (10)             -> role group 2

PROPOSAL_CH = 3 + 2 + NUM_HEADING_BIN + NUM_HEADING_BIN + NUM_CLASS + 3 * NUM_CLASS + NUM_CLASS

SLICE_CENTER = (0, 3)
SLICE_OBJECTNESS = (3, 5)
SLICE_HEADING_CLS = (5, 5 + NUM_HEADING_BIN)
SLICE_HEADING_REG = (17, 17 + NUM_HEADING_BIN)
SLICE_SIZE_CLS = (29, 29 + NUM_CLASS)
SLICE_SIZE_REG = (39, 39 + 3 * NUM_CLASS)
SLICE_SEM_CLS = (69, 69 + NUM_CLASS)


def proposal_role_groups() -> List[List[int]]:
    """Role groups of the proposal head (paper Table 2).

    Group1: xyz regression; Group2: all classification-style channels;
    Group3: all box-regression channels.
    """
    g1 = list(range(*SLICE_CENTER))
    g2 = (
        list(range(*SLICE_OBJECTNESS))
        + list(range(*SLICE_HEADING_CLS))
        + list(range(*SLICE_SIZE_CLS))
        + list(range(*SLICE_SEM_CLS))
    )
    g3 = list(range(*SLICE_HEADING_REG)) + list(range(*SLICE_SIZE_REG))
    assert sorted(g1 + g2 + g3) == list(range(PROPOSAL_CH))
    return [g1, g2, g3]


VOTE_CH = 3 + 128  # xyz offset + feature residual


def vote_role_groups() -> List[List[int]]:
    """Role groups of the voting head: xyz offsets vs feature residuals."""
    return [list(range(3)), list(range(3, VOTE_CH))]


# ---------------------------------------------------------------------------
# Model architecture (VoteNet-mini, DESIGN.md §4)
# ---------------------------------------------------------------------------

FEAT_DIM = 1 + NUM_SEG_CLASSES  # height + painted seg scores (painted variants)
FEAT_DIM_PLAIN = 1  # height only (VoteNet variant)

# (num_centroids, radius, num_neighbors, mlp widths)
SA_CONFIGS = [
    (256, 0.3, 32, (32, 32, 64)),
    (128, 0.6, 16, (64, 64, 128)),
    (64, 1.2, 8, (96, 96, 128)),
    (32, 2.4, 8, (128, 128, 128)),
]

SEED_FEAT = 128  # seed feature width after FP
NUM_SEEDS = 128  # seeds live at the SA2 level
NUM_PROPOSALS = 32
PROPOSAL_RADIUS = 0.6
PROPOSAL_K = 8

IMG_SIZE = 64  # 2D render resolution (square)

# Default biased-FPS settings (paper Table 9/10 best config)
DEFAULT_W0 = 2.0
DEFAULT_BIAS_LAYERS = 2  # biased FPS on SA1 and SA2 of the bias pipeline


@dataclasses.dataclass(frozen=True)
class DatasetConfig:
    """Procedural dataset parameters (mirrored by rust/src/data)."""

    name: str
    num_points: int
    room_min: float  # room side length range
    room_max: float
    min_objects: int
    max_objects: int
    single_view: bool  # SynRGBD: single-shot visibility; SynScan: full scan
    depth_noise: float
    seg_noise: float  # label corruption prob in the rendered image


SYNRGBD = DatasetConfig(
    name="synrgbd",
    num_points=2048,
    room_min=3.0,
    room_max=4.5,
    min_objects=3,
    max_objects=7,
    single_view=True,
    depth_noise=0.008,
    seg_noise=0.05,
)

SYNSCAN = DatasetConfig(
    name="synscan",
    num_points=4096,
    room_min=5.0,
    room_max=8.0,
    min_objects=6,
    max_objects=12,
    single_view=False,
    depth_noise=0.004,
    seg_noise=0.03,
)

DATASETS = {d.name: d for d in (SYNRGBD, SYNSCAN)}
