//! Feature propagation: inverse-distance-weighted 3-NN interpolation
//! (mirror of sampling.three_nn_interpolate).
//!
//! §Perf: the production path reuses the uniform hash [`Grid`] from
//! `ballquery` with an expanding-ring search, replacing the O(Nd*Ns)
//! brute-force scan, and `three_nn_interpolate_par` spreads destination
//! points over scoped threads. Candidates are ranked by `(d2, index)` so the
//! grid search, the brute-force reference, and every thread count produce
//! identical neighbor sets (exact-tie handling included).
//!
//! Degenerate sources are well-defined: zero source points interpolate to
//! zeros, and 1 or 2 sources use all of them with IDW weights — no
//! `(INFINITY, 0)` sentinel ever reaches the weighting (the seed code
//! panicked on `row(0)` for empty sources and could emit NaN for Ns < 3).

use super::ballquery::Grid;
use crate::exec::par_map;
use crate::util::tensor::Tensor;

/// Below this source count a brute-force scan beats building a grid.
const GRID_MIN_SRC: usize = 64;
/// A destination this many empty rings away from the source bounding box
/// falls back to the O(Ns) scan — bounded work for destinations far
/// outside the cloud, where even the face-only shell walk adds up.
const FAR_BRUTE_RINGS: i32 = 64;

#[inline]
fn lex_lt(a: (f32, usize), b: (f32, usize)) -> bool {
    a.0 < b.0 || (a.0 == b.0 && a.1 < b.1)
}

/// Insert a candidate into the sorted best-`kk` array (ranked by (d2, j)).
#[inline]
fn insert(best: &mut [(f32, usize); 3], kk: usize, d2: f32, j: usize) {
    if !lex_lt((d2, j), best[kk - 1]) {
        return;
    }
    best[kk - 1] = (d2, j);
    let mut i = kk - 1;
    while i > 0 && lex_lt(best[i], best[i - 1]) {
        best.swap(i, i - 1);
        i -= 1;
    }
}

#[inline]
fn dist2(a: &[f32; 3], b: &[f32; 3]) -> f32 {
    let dx = a[0] - b[0];
    let dy = a[1] - b[1];
    let dz = a[2] - b[2];
    dx * dx + dy * dy + dz * dz
}

/// `kk` nearest sources to `d` via expanding grid rings. After finishing
/// ring R every unvisited point is farther than `R * cell`, so the search
/// stops as soon as the current `kk`-th best is within that bound.
/// `start_ring` skips rings that provably contain no source point (queries
/// far outside the source bounding box); `max_ring` bounds the search once
/// every populated cell has been visited.
fn knn_grid(
    d: &[f32; 3],
    src: &[[f32; 3]],
    grid: &Grid,
    kk: usize,
    start_ring: i32,
    max_ring: i32,
) -> [(f32, usize); 3] {
    let cell = grid.cell_size();
    let mut best = [(f32::INFINITY, usize::MAX); 3];
    let mut ring = start_ring.max(0);
    loop {
        grid.ring(d, ring, |j| {
            let j = j as usize;
            insert(&mut best, kk, dist2(d, &src[j]), j);
        });
        let covered = (ring as f32) * cell;
        // strict <: on an exact f32 tie at the ring boundary an unvisited
        // lower-index point could still win the (d2, index) ranking, so
        // search one more ring — keeps grid == brute force even then
        if best[kk - 1].0.is_finite() && best[kk - 1].0 < covered * covered {
            break;
        }
        ring += 1;
        if ring > max_ring {
            break; // every populated cell visited
        }
    }
    best
}

/// IDW-weighted feature row for one destination point.
#[inline]
fn idw_row(best: &[(f32, usize); 3], kk: usize, src_feats: &Tensor, out: &mut [f32]) {
    let mut w = [0.0f32; 3];
    let mut wsum = 0.0f32;
    for i in 0..kk {
        w[i] = 1.0 / best[i].0.max(1e-8);
        wsum += w[i];
    }
    for i in 0..kk {
        let row = src_feats.row(best[i].1);
        let wn = w[i] / wsum;
        for (o, v) in out.iter_mut().zip(row.iter()) {
            *o += wn * v;
        }
    }
}

/// Interpolate `src_feats` (Ns, C) at `dst_xyz` from `src_xyz` -> (Nd, C).
pub fn three_nn_interpolate(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
) -> Tensor {
    three_nn_interpolate_par(dst_xyz, src_xyz, src_feats, 1)
}

/// `three_nn_interpolate` with destination points spread over up to
/// `threads` scoped threads. Identical output for any thread count.
pub fn three_nn_interpolate_par(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
    threads: usize,
) -> Tensor {
    assert_eq!(src_xyz.len(), src_feats.rows());
    let c = src_feats.row_len();
    let ns = src_xyz.len();
    if ns < GRID_MIN_SRC {
        // small sources (incl. the degenerate Ns < 3 cases): the reference
        // scan is cheaper than building a grid and shares the ranking rule
        return three_nn_interpolate_bruteforce(dst_xyz, src_xyz, src_feats);
    }
    let kk = ns.min(3);
    // grid cell sized for ~1 source point per cell
    let mut lo = src_xyz[0];
    let mut hi = src_xyz[0];
    for p in src_xyz {
        for a in 0..3 {
            lo[a] = lo[a].min(p[a]);
            hi[a] = hi[a].max(p[a]);
        }
    }
    let extent = (hi[0] - lo[0]).max(hi[1] - lo[1]).max(hi[2] - lo[2]);
    let cell = extent / (ns as f32).cbrt();
    if cell < 1e-4 {
        // near-coincident cloud: grid cells would degenerate and ring
        // searches crawl; the plain scan is bounded and exact
        return three_nn_interpolate_bruteforce(dst_xyz, src_xyz, src_feats);
    }
    let grid = Grid::build(src_xyz, cell);
    // past this ring the search has seen every populated cell no matter
    // where the query sits relative to the source bounding box
    let span = ((extent / cell).ceil() as i32).saturating_add(1);
    let rows = par_map(dst_xyz, threads, |_, d| {
        // Chebyshev distance from the query to the source bounding box:
        // rings below floor(r/cell) - 1 cannot contain a source point, and
        // rings beyond span + ceil(r/cell) + 1 have all been visited
        let mut r = 0f32;
        for a in 0..3 {
            r = r.max((lo[a] - d[a]).max(d[a] - hi[a]).max(0.0));
        }
        let start_ring = ((r / cell).floor() as i32).saturating_sub(1);
        let mut row = vec![0.0f32; c];
        if start_ring > FAR_BRUTE_RINGS {
            // far outside the cloud: a plain scan is bounded and exact
            let mut best = [(f32::INFINITY, usize::MAX); 3];
            for (j, s) in src_xyz.iter().enumerate() {
                insert(&mut best, kk, dist2(d, s), j);
            }
            idw_row(&best, kk, src_feats, &mut row);
        } else {
            let max_ring = span
                .saturating_add((r / cell).ceil() as i32)
                .saturating_add(1);
            let best = knn_grid(d, src_xyz, &grid, kk, start_ring, max_ring);
            idw_row(&best, kk, src_feats, &mut row);
        }
        row
    });
    let mut out = Vec::with_capacity(dst_xyz.len() * c);
    for r in rows {
        out.extend_from_slice(&r);
    }
    Tensor::new(vec![dst_xyz.len(), c], out)
}

/// Reference O(Nd*Ns) scan kept for tests and the §Perf comparison.
pub fn three_nn_interpolate_bruteforce(
    dst_xyz: &[[f32; 3]],
    src_xyz: &[[f32; 3]],
    src_feats: &Tensor,
) -> Tensor {
    assert_eq!(src_xyz.len(), src_feats.rows());
    let c = src_feats.row_len();
    let ns = src_xyz.len();
    if ns == 0 {
        return Tensor::zeros(vec![dst_xyz.len(), c]);
    }
    let kk = ns.min(3);
    let mut out = vec![0.0f32; dst_xyz.len() * c];
    for (d, orow) in dst_xyz.iter().zip(out.chunks_mut(c.max(1))) {
        let mut best = [(f32::INFINITY, usize::MAX); 3];
        for (j, s) in src_xyz.iter().enumerate() {
            insert(&mut best, kk, dist2(d, s), j);
        }
        idw_row(&best, kk, src_feats, orow);
    }
    Tensor::new(vec![dst_xyz.len(), c], out)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cloud(n: usize, seed: u64) -> Vec<[f32; 3]> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| [r.f32() * 3.0, r.f32() * 3.0, r.f32()]).collect()
    }

    fn feats(n: usize, c: usize, seed: u64) -> Tensor {
        let mut r = Rng::new(seed);
        Tensor::new(vec![n, c], (0..n * c).map(|_| r.f32() * 4.0 - 2.0).collect())
    }

    #[test]
    fn exact_at_source_points() {
        let src = vec![[0.0, 0.0, 0.0], [1.0, 0.0, 0.0], [0.0, 1.0, 0.0], [1.0, 1.0, 0.0]];
        let f = Tensor::new(vec![4, 2], vec![1., 10., 2., 20., 3., 30., 4., 40.]);
        let out = three_nn_interpolate(&src, &src, &f);
        // at a source point the nearest neighbor has d2~0 -> dominates
        assert!((out.row(2)[0] - 3.0).abs() < 1e-3);
        assert!((out.row(2)[1] - 30.0).abs() < 1e-2);
    }

    #[test]
    fn interpolation_is_convex_combination() {
        let src = vec![[0.0, 0.0, 0.0], [2.0, 0.0, 0.0], [0.0, 2.0, 0.0]];
        let f = Tensor::new(vec![3, 1], vec![0.0, 6.0, 12.0]);
        let out = three_nn_interpolate(&[[0.5, 0.5, 0.0]], &src, &f);
        let v = out.data[0];
        assert!(v > 0.0 && v < 12.0);
    }

    #[test]
    fn grid_matches_bruteforce() {
        for seed in 0..4 {
            let src = cloud(400, seed); // > GRID_MIN_SRC -> grid path
            let f = feats(400, 7, seed + 100);
            let dst = cloud(150, seed + 200);
            let a = three_nn_interpolate(&dst, &src, &f);
            let b = three_nn_interpolate_bruteforce(&dst, &src, &f);
            assert_eq!(a, b, "seed {seed}");
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let src = cloud(500, 21);
        let f = feats(500, 5, 22);
        let dst = cloud(300, 23);
        let seq = three_nn_interpolate(&dst, &src, &f);
        for threads in [2, 3, 8] {
            assert_eq!(three_nn_interpolate_par(&dst, &src, &f, threads), seq);
        }
    }

    #[test]
    fn faraway_destinations_still_find_sources() {
        // dst far outside the src bounding box exercises the ring cap
        let src = cloud(200, 31);
        let f = feats(200, 3, 32);
        let dst = vec![[50.0, -40.0, 10.0], [-9.0, 0.0, 0.0]];
        let a = three_nn_interpolate(&dst, &src, &f);
        let b = three_nn_interpolate_bruteforce(&dst, &src, &f);
        assert_eq!(a, b);
    }

    #[test]
    fn degenerate_extent_far_destination_terminates() {
        // >= GRID_MIN_SRC near-coincident sources clamp the cell size to
        // 1e-4; a far destination must take the bounded fallback scan, not
        // an astronomically long ring search
        let src: Vec<[f32; 3]> = (0..80).map(|i| [1.0 + i as f32 * 1e-7, 2.0, 0.5]).collect();
        let f = feats(80, 2, 40);
        let dst = vec![[60.0, -10.0, 3.0], [1.0, 2.0, 0.5]];
        let a = three_nn_interpolate(&dst, &src, &f);
        let b = three_nn_interpolate_bruteforce(&dst, &src, &f);
        assert_eq!(a, b);
    }

    #[test]
    fn empty_source_interpolates_to_zeros() {
        let src: Vec<[f32; 3]> = Vec::new();
        let f = Tensor::zeros(vec![0, 4]);
        let out = three_nn_interpolate(&[[0.0, 0.0, 0.0], [1.0, 1.0, 1.0]], &src, &f);
        assert_eq!(out.shape, vec![2, 4]);
        assert!(out.data.iter().all(|&v| v == 0.0));
    }

    #[test]
    fn single_source_copies_features() {
        let src = vec![[1.0, 2.0, 3.0]];
        let f = Tensor::new(vec![1, 3], vec![7.0, -1.0, 0.5]);
        let out = three_nn_interpolate(&[[0.0, 0.0, 0.0], [9.0, 9.0, 9.0]], &src, &f);
        for i in 0..2 {
            assert_eq!(out.row(i), &[7.0, -1.0, 0.5], "dst {i}");
        }
        assert!(out.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn two_sources_interpolate_without_nan() {
        let src = vec![[0.0, 0.0, 0.0], [2.0, 0.0, 0.0]];
        let f = Tensor::new(vec![2, 1], vec![0.0, 10.0]);
        let out = three_nn_interpolate(&[[1.0, 0.0, 0.0], [0.0, 0.0, 0.0]], &src, &f);
        assert!(out.data.iter().all(|v| v.is_finite()));
        // midpoint: equal weights
        assert!((out.data[0] - 5.0).abs() < 1e-4);
        // at src 0 the near point dominates
        assert!(out.data[1] < 1.0);
    }
}
