//! Per-scene detection pipeline: functional execution + simulated timeline.
//!
//! Every stage is declared exactly **once** as a [`StageDecl`] — (name,
//! device, workload, deps, compute closure) — and that single declaration
//! feeds both sides:
//!
//! - the [`exec::DagExecutor`] runs the closures on the host, in parallel
//!   when dependencies allow (the SA-normal / SA-bias chains of PointSplit
//!   and the two RandomSplit halves overlap on host threads, mirroring the
//!   paper's two-lane GPU/NPU overlap, Fig. 3);
//! - the embedded [`StageSpec`]s replay through the calibrated
//!   [`ScheduleSim`] device model.
//!
//! Because the simulated DAG and the executed DAG are the same object,
//! dependency drift between them is impossible by construction (the class
//! of bug where `merge()` collapsed two pipelines' last NN stages into
//! `max(a, b)` and let `sa4_pm` start before the slower pipeline finished).
//!
//! Stage closures exchange data through single-producer [`Slot`]s, so
//! parallel execution is bit-identical to sequential execution (see
//! `rust/tests/parallelism.rs`).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::arch::{nn_precision, nn_workload, peak_memory_mb, sa_pointmanip_workload, small_pointop};
use super::decode::decode_detections;
use super::{Schedule, Variant};
use crate::data::{Box3, Scene};
use crate::exec::{Compute, DagExecutor, HostExec, Slot, StageDecl};
use crate::pointops;
use crate::quant::{Granularity, QuantScheme, QuantSpec, StagePrecision};
use crate::runtime::Runtime;
use crate::sim::{DeviceKind, Precision, ScheduleSim, StageSpec, Timeline, Workload};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Full configuration of one detector instantiation.
#[derive(Debug, Clone)]
pub struct DetectorConfig {
    pub dataset: String,
    pub variant: Variant,
    /// Per-stage-class precision assignment (paper §4.3 as an execution
    /// property, not a config flag): backbone, vote head, proposal head.
    pub scheme: QuantScheme,
    pub schedule: Schedule,
    pub w0: f32,
    pub bias_layers: usize,
    pub obj_thresh: f32,
    pub nms_iou: f64,
    /// number of segmentation passes per scene (paper: 3 for ScanNet)
    pub seg_passes: usize,
}

impl DetectorConfig {
    pub fn new(dataset: &str, variant: Variant, int8: bool, schedule: Schedule) -> Self {
        DetectorConfig {
            dataset: dataset.to_string(),
            variant,
            scheme: if int8 {
                // paper Table 7: role-based for PointSplit, layer-wise others
                QuantScheme::int8(if variant == Variant::PointSplit {
                    Granularity::Role
                } else {
                    Granularity::Layer
                })
            } else {
                QuantScheme::fp32()
            },
            schedule,
            w0: 2.0,
            bias_layers: 2,
            obj_thresh: 0.02,
            nms_iou: 0.25,
            seg_passes: if dataset == "synscan" { 3 } else { 1 },
        }
    }

    /// Artifact name for one of this configuration's networks (shared with
    /// the serving planner, which builds the same DAG without executing it).
    pub(crate) fn art(&self, net: &str) -> String {
        let prec = match net {
            "vote" | "prop" => self.scheme.for_net(net).head_name(),
            _ => self.scheme.backbone.backbone_name(),
        };
        format!("{}_{}_{}_{}", self.dataset, self.variant.model_name(), net, prec)
    }

    pub(crate) fn seg_art(&self) -> String {
        format!("{}_seg_{}", self.dataset, self.scheme.backbone.backbone_name())
    }

    pub fn int8(&self) -> bool {
        self.scheme.backbone.is_int8()
    }

    /// Set both head stages' precision from an artifact label
    /// ("fp32", "int8_layer", "int8_group", "int8_channel", "int8_role").
    pub fn set_head_precision(&mut self, name: &str) -> Result<()> {
        let p = StagePrecision::parse(name)
            .ok_or_else(|| anyhow!("unknown head precision '{name}'"))?;
        self.scheme = self.scheme.with_head(p);
        Ok(())
    }
}

/// Result of running one scene through the pipeline.
#[derive(Debug, Clone)]
pub struct PipelineOutput {
    pub detections: Vec<Box3>,
    pub timeline: Timeline,
    /// The stage DAG as declared (same object the executor ran and the
    /// simulator timed) — for tests, tracing, and the serving planner's
    /// drift check.
    pub stage_specs: Vec<StageSpec>,
    pub peak_memory_mb: f64,
    /// wall-clock of the functional execution on this host (for §Perf)
    pub host_ms: f64,
}

/// Chain-local geometry after a sampling step: positions plus the composed
/// index of every point back into the original cloud (so any stage can look
/// up per-point metadata like the painted fg mask without carrying it).
#[derive(Clone)]
struct Geo {
    xyz: Vec<[f32; 3]>,
    src: Vec<usize>,
}

/// Where an SA chain's level-0 points come from.
#[derive(Clone)]
enum ChainInput {
    /// the full original cloud
    Full,
    /// a fixed subset of the original cloud (RandomSplit halves)
    Subset(Arc<Vec<usize>>),
}

/// One declared SA level of a chain, as seen by downstream stages.
#[derive(Clone)]
struct ChainLevel {
    geo: Slot<Geo>,
    feats: Slot<Tensor>,
    /// sim index of this level's NN stage
    nn: usize,
    /// points after this level's sampling (static)
    n: usize,
    /// feature width after this level's PointNet (static)
    c: usize,
}

/// Stage-list accumulator with the sequential-schedule chaining rule.
struct StageBuilder<'s> {
    decls: Vec<StageDecl<'s>>,
    sequential: bool,
    prev_any: Option<usize>,
}

impl<'s> StageBuilder<'s> {
    #[allow(clippy::too_many_arguments)]
    fn stage(
        &mut self,
        name: String,
        device: DeviceKind,
        precision: Precision,
        workload: Workload,
        mut deps: Vec<usize>,
        extra_deps: Vec<usize>,
        compute: Compute<'s>,
    ) -> usize {
        if self.sequential {
            if let Some(p) = self.prev_any {
                if !deps.contains(&p) {
                    deps.push(p);
                }
            }
        }
        let idx = self.decls.len();
        self.decls.push(StageDecl {
            spec: StageSpec { name, device, precision, workload, deps },
            extra_deps,
            compute,
        });
        self.prev_any = Some(idx);
        idx
    }
}

pub struct ScenePipeline<'a> {
    pub rt: &'a Runtime,
    pub cfg: DetectorConfig,
    sim: ScheduleSim,
    host_exec: HostExec,
}

impl<'a> ScenePipeline<'a> {
    pub fn new(rt: &'a Runtime, cfg: DetectorConfig) -> Self {
        ScenePipeline { rt, cfg, sim: ScheduleSim::new(), host_exec: HostExec::auto() }
    }

    /// Override the host execution policy (sequential / parallel).
    pub fn with_host_exec(mut self, host_exec: HostExec) -> Self {
        self.host_exec = host_exec;
        self
    }

    pub fn host_exec(&self) -> HostExec {
        self.host_exec
    }

    /// Run one scene. `seed` feeds the RandomSplit permutation.
    pub fn run(&self, scene: &Scene, seed: u64) -> Result<PipelineOutput> {
        self.run_with_scores(scene, seed, None).map(|(out, _)| out)
    }

    /// Run one scene, optionally reusing 2D segmentation scores from a
    /// previous frame ("consecutive matching", paper §3.2): when
    /// `prev_scores` is given, the segmenter stage is skipped entirely —
    /// zero NPU time for 2D — at the cost of stale semantics. Returns the
    /// pipeline output plus the scores used (for the caller to carry
    /// forward to the next frame).
    pub fn run_with_scores(
        &self,
        scene: &Scene,
        seed: u64,
        prev_scores: Option<&Tensor>,
    ) -> Result<(PipelineOutput, Option<Tensor>)> {
        let t_host = std::time::Instant::now();
        let cfg = &self.cfg;
        let m = &self.rt.manifest;
        let threads = self.host_exec.threads();
        let point_dev = cfg.schedule.point_dev();
        // the EdgeTPU executes int8 only (the paper's motivation for full
        // quantization); placement is decided *per stage* from its
        // precision, so a mixed scheme keeps int8 stages on the NPU while
        // fp32 ones fall back to the point device
        let nn_dev_raw = cfg.schedule.nn_dev();
        let nn_dev_for = |p: Precision| {
            if p == Precision::Fp32 && nn_dev_raw == DeviceKind::EdgeTpu {
                point_dev
            } else {
                nn_dev_raw
            }
        };
        let nn_dev = nn_dev_for(cfg.scheme.backbone.sim());
        // explicit per-stage quant spec handed to the runtime (the scheme's
        // granularity may refine what the artifact name encodes)
        let qspec_for = |art: &str, p: StagePrecision| -> Option<QuantSpec> {
            m.artifact(art).map(|a| m.stage_quant_for(a, p))
        };
        let n = scene.points.len();
        let mut b = StageBuilder {
            decls: Vec::new(),
            sequential: !cfg.schedule.overlapped(),
            prev_any: None,
        };

        // ------------------------------------------------------ 2D segment
        // scores_slot: segmenter output (or the previous frame's scores);
        // feat_slot: per-point detector features + fg mask of the full cloud
        let scores_slot: Slot<Tensor> = Slot::new("seg scores");
        let feat_slot: Slot<(Tensor, Vec<f32>)> = Slot::new("point features");
        let painted = cfg.variant.painted();
        let (seg_stage, paint_stage, c0) = if painted {
            let seg_stage = match prev_scores {
                // consecutive matching: reuse the previous frame's scores
                Some(prev) => {
                    scores_slot.set(prev.clone());
                    None
                }
                None => {
                    let mut wl = nn_workload(m, &cfg.seg_art());
                    wl.flops *= cfg.seg_passes as u64;
                    let art = cfg.seg_art();
                    let qspec = qspec_for(&art, cfg.scheme.backbone);
                    let sl = scores_slot.clone();
                    let img_size = m.img_size;
                    Some(b.stage(
                        "seg".into(),
                        nn_dev,
                        nn_precision(m, &art),
                        wl,
                        vec![],
                        vec![],
                        Compute::Host(Box::new(move || {
                            let img =
                                Tensor::new(vec![img_size, img_size, 3], scene.image.clone());
                            sl.set(
                                self.rt.run_with_spec(&art, &[&img], qspec.as_ref())?.remove(0),
                            );
                            Ok(())
                        })),
                    ))
                }
            };
            let sl = scores_slot.clone();
            let fs = feat_slot.clone();
            let paint_stage = b.stage(
                "paint".into(),
                point_dev,
                Precision::Fp32,
                small_pointop((n * 8) as u64, (n * m.num_seg_classes) as u64),
                seg_stage.into_iter().collect(),
                vec![],
                Compute::Pool(Box::new(move || {
                    sl.with(|scores| {
                        let paint = pointops::paint_points(scene, scores);
                        let fg = pointops::fg_mask(&paint, 0.5);
                        fs.set((pointops::build_features(scene, Some(&paint)), fg));
                    });
                    Ok(())
                })),
            );
            (seg_stage, Some(paint_stage), 1 + m.num_seg_classes)
        } else {
            feat_slot.set((pointops::build_features(scene, None), vec![0.0; n]));
            (None, None, 1)
        };

        // ------------------------------------------------------ backbone
        let (sa2s, sa3s): (Vec<ChainLevel>, Vec<ChainLevel>) = match cfg.variant {
            Variant::VoteNet | Variant::PointPainting => {
                let (s2, s3) = self.declare_sa_chain(
                    &mut b, scene, ChainInput::Full, n, &feat_slot, c0, "full", false, point_dev,
                    nn_dev, seg_stage, paint_stage, threads,
                );
                (vec![s2], vec![s3])
            }
            Variant::PointSplit => {
                // SA-normal jump-starts (its point manip does not need seg);
                // SA-bias waits for painting (biased FPS needs fg)
                let (n2, n3) = self.declare_sa_chain(
                    &mut b, scene, ChainInput::Full, n, &feat_slot, c0, "normal", false,
                    point_dev, nn_dev, seg_stage, paint_stage, threads,
                );
                let (b2, b3) = self.declare_sa_chain(
                    &mut b, scene, ChainInput::Full, n, &feat_slot, c0, "bias", true, point_dev,
                    nn_dev, seg_stage, paint_stage, threads,
                );
                (vec![n2, b2], vec![n3, b3])
            }
            Variant::RandomSplit => {
                let mut rng = Rng::new(seed ^ 0xB5);
                let perm = rng.choice_no_replace(n, n);
                let half = n / 2;
                let ia = Arc::new(perm[..half].to_vec());
                let ib = Arc::new(perm[half..].to_vec());
                let (a2, a3) = self.declare_sa_chain(
                    &mut b, scene, ChainInput::Subset(ia), half, &feat_slot, c0, "randA", false,
                    point_dev, nn_dev, seg_stage, paint_stage, threads,
                );
                let (b2, b3) = self.declare_sa_chain(
                    &mut b, scene, ChainInput::Subset(ib), n - half, &feat_slot, c0, "randB",
                    false, point_dev, nn_dev, seg_stage, paint_stage, threads,
                );
                (vec![a2, b2], vec![a3, b3])
            }
        };
        let sa2_n: usize = sa2s.iter().map(|l| l.n).sum();
        let sa3_n: usize = sa3s.iter().map(|l| l.n).sum();
        let sa3_c = sa3s[0].c;

        // SA4 over the fused SA3 set (biased only in the Table 10 "all SA
        // layers" ablation: bias_layers >= 4). The merged set is ready when
        // **every** contributing pipeline's SA3 PointNet is done — both
        // deps are recorded, which is exactly the fix for the old
        // `max(a.last_nn, b.last_nn)` merge bug.
        let sa4cfg = &m.sa_configs[3];
        let mut deps4: Vec<usize> = sa3s.iter().map(|l| l.nn).collect();
        deps4.sort_unstable();
        let use_bias4 = cfg.bias_layers >= 4 && cfg.variant == Variant::PointSplit;
        let sa3_fused: Slot<Geo> = Slot::new("sa3 fused geo");
        let grp4: Slot<(Vec<usize>, Vec<Vec<usize>>)> = Slot::new("sa4 groups");
        let geo4: Slot<Geo> = Slot::new("sa4 geo");
        let pm4 = {
            let sa3_geos: Vec<Slot<Geo>> = sa3s.iter().map(|l| l.geo.clone()).collect();
            let (sa3_fused, grp4, geo4) = (sa3_fused.clone(), grp4.clone(), geo4.clone());
            let fgsrc = if use_bias4 { Some(feat_slot.clone()) } else { None };
            let (m4, r4, k4, w0) = (sa4cfg.m, sa4cfg.radius, sa4cfg.k, cfg.w0);
            b.stage(
                "sa4_pm".into(),
                point_dev,
                Precision::Fp32,
                sa_pointmanip_workload(sa3_n, sa4cfg.m, sa4cfg.k, sa3_c),
                deps4,
                if use_bias4 && painted { paint_stage.into_iter().collect() } else { vec![] },
                Compute::Pool(Box::new(move || {
                    let mut xyz = Vec::new();
                    let mut src = Vec::new();
                    for g in &sa3_geos {
                        g.with(|geo| {
                            xyz.extend_from_slice(&geo.xyz);
                            src.extend_from_slice(&geo.src);
                        });
                    }
                    let idx4 = match &fgsrc {
                        Some(fs) => {
                            let fg: Vec<f32> =
                                fs.with(|(_, fg)| src.iter().map(|&i| fg[i]).collect());
                            pointops::biased_fps_par(&xyz, m4, &fg, w0, threads)
                        }
                        None => pointops::fps_par(&xyz, m4, threads),
                    };
                    let groups4 = pointops::ball_query_par(&xyz, &idx4, r4, k4, threads);
                    geo4.set(Geo {
                        xyz: idx4.iter().map(|&i| xyz[i]).collect(),
                        src: idx4.iter().map(|&i| src[i]).collect(),
                    });
                    grp4.set((idx4, groups4));
                    sa3_fused.set(Geo { xyz, src });
                    Ok(())
                })),
            )
        };
        let sa3_feats_fused: Slot<Tensor> = Slot::new("sa3 fused feats");
        let sa4_feats: Slot<Tensor> = Slot::new("sa4 feats");
        let nn4 = {
            let sa3_fs: Vec<Slot<Tensor>> = sa3s.iter().map(|l| l.feats.clone()).collect();
            let (sa3_fused, sa3_feats_fused, grp4, sa4_feats) = (
                sa3_fused.clone(),
                sa3_feats_fused.clone(),
                grp4.clone(),
                sa4_feats.clone(),
            );
            let art = cfg.art("sa4_full");
            let qspec = qspec_for(&art, cfg.scheme.backbone);
            b.stage(
                "sa4_nn".into(),
                nn_dev,
                nn_precision(m, &art),
                nn_workload(m, &art),
                vec![pm4],
                vec![],
                Compute::Host(Box::new(move || {
                    let parts: Vec<Tensor> = sa3_fs.iter().map(|f| f.cloned()).collect();
                    let refs: Vec<&Tensor> = parts.iter().collect();
                    let fused = Tensor::concat0(&refs);
                    let (idx4, groups4) = grp4.take();
                    let g4 = sa3_fused.with(|geo| {
                        pointops::group_features(&geo.xyz, Some(&fused), &idx4, &groups4)
                    });
                    sa4_feats.set(self.rt.run_with_spec(&art, &[&g4], qspec.as_ref())?.remove(0));
                    sa3_feats_fused.set(fused);
                    Ok(())
                })),
            )
        };

        // ------------------------------------------------------ FP + heads
        let f2_slot: Slot<Tensor> = Slot::new("fp features");
        let seed_xyz_slot: Slot<Vec<[f32; 3]>> = Slot::new("seed xyz");
        let fp_pm = {
            let sa2s_c = sa2s.clone();
            let (sa3_fused, sa3_feats_fused, geo4, sa4_feats) = (
                sa3_fused.clone(),
                sa3_feats_fused.clone(),
                geo4.clone(),
                sa4_feats.clone(),
            );
            let (f2_slot, seed_xyz_slot) = (f2_slot.clone(), seed_xyz_slot.clone());
            b.stage(
                "fp_interp".into(),
                point_dev,
                Precision::Fp32,
                small_pointop((sa2_n * sa3_n * 4) as u64, (sa2_n * m.fp_in * 4) as u64),
                vec![nn4],
                vec![],
                Compute::Pool(Box::new(move || {
                    let sa4_f = sa4_feats.take();
                    let sa4_xyz = geo4.with(|g| g.xyz.clone());
                    let sa3_f = sa3_feats_fused.take();
                    let f3 = sa3_fused.with(|sa3| {
                        let f3up = pointops::three_nn_interpolate_par(
                            &sa3.xyz, &sa4_xyz, &sa4_f, threads,
                        );
                        hconcat(&sa3_f, &f3up)
                    });
                    let mut sa2_xyz = Vec::new();
                    for l in &sa2s_c {
                        l.geo.with(|g| sa2_xyz.extend_from_slice(&g.xyz));
                    }
                    let f2up = sa3_fused.with(|sa3| {
                        pointops::three_nn_interpolate_par(&sa2_xyz, &sa3.xyz, &f3, threads)
                    });
                    let parts: Vec<Tensor> = sa2s_c.iter().map(|l| l.feats.cloned()).collect();
                    let refs: Vec<&Tensor> = parts.iter().collect();
                    let sa2_f = Tensor::concat0(&refs);
                    f2_slot.set(hconcat(&sa2_f, &f2up));
                    seed_xyz_slot.set(sa2_xyz);
                    Ok(())
                })),
            )
        };
        let seeds_slot: Slot<Tensor> = Slot::new("seeds");
        let fp_nn = {
            let art = cfg.art("fp_fc");
            let qspec = qspec_for(&art, cfg.scheme.backbone);
            let (f2_slot, seeds_slot) = (f2_slot.clone(), seeds_slot.clone());
            b.stage(
                "fp_fc".into(),
                nn_dev,
                nn_precision(m, &art),
                nn_workload(m, &art),
                vec![fp_pm],
                vec![],
                Compute::Host(Box::new(move || {
                    let f2 = f2_slot.take();
                    seeds_slot.set(self.rt.run_with_spec(&art, &[&f2], qspec.as_ref())?.remove(0));
                    Ok(())
                })),
            )
        };
        let vote_slot: Slot<(Vec<[f32; 3]>, Tensor)> = Slot::new("votes");
        let vote_nn = {
            let art = cfg.art("vote");
            let qspec = qspec_for(&art, cfg.scheme.vote);
            let vote_prec = nn_precision(m, &art);
            let (seeds_slot, seed_xyz_slot, vote_slot) =
                (seeds_slot.clone(), seed_xyz_slot.clone(), vote_slot.clone());
            b.stage(
                "vote".into(),
                nn_dev_for(vote_prec),
                vote_prec,
                nn_workload(m, &art),
                vec![fp_nn],
                vec![],
                Compute::Host(Box::new(move || {
                    let seeds = seeds_slot.take();
                    let vote_out =
                        self.rt.run_with_spec(&art, &[&seeds], qspec.as_ref())?.remove(0);
                    let seed_xyz = seed_xyz_slot.take();
                    let cfeat = seeds.row_len();
                    let mut vote_xyz: Vec<[f32; 3]> = Vec::with_capacity(seed_xyz.len());
                    let mut vote_feats = Tensor::zeros(vec![seed_xyz.len(), cfeat]);
                    for i in 0..seed_xyz.len() {
                        let row = vote_out.row(i);
                        vote_xyz.push([
                            seed_xyz[i][0] + row[0],
                            seed_xyz[i][1] + row[1],
                            seed_xyz[i][2] + row[2],
                        ]);
                        for c in 0..cfeat {
                            vote_feats.row_mut(i)[c] = seeds.row(i)[c] + row[3 + c];
                        }
                    }
                    vote_slot.set((vote_xyz, vote_feats));
                    Ok(())
                })),
            )
        };

        // proposal: cluster votes (point manip) then PointNet+head (NN)
        let pgrp_slot: Slot<(Vec<usize>, Vec<Vec<usize>>)> = Slot::new("proposal groups");
        let cluster_slot: Slot<Vec<[f32; 3]>> = Slot::new("cluster xyz");
        let prop_pm = {
            let (vote_slot, pgrp_slot, cluster_slot) =
                (vote_slot.clone(), pgrp_slot.clone(), cluster_slot.clone());
            let (np, pr, pk) = (m.num_proposals, m.proposal_radius, m.proposal_k);
            b.stage(
                "prop_pm".into(),
                point_dev,
                Precision::Fp32,
                sa_pointmanip_workload(sa2_n, m.num_proposals, m.proposal_k, m.seed_feat),
                vec![vote_nn],
                vec![],
                Compute::Pool(Box::new(move || {
                    vote_slot.with(|(vote_xyz, _)| {
                        let pidx = pointops::fps_par(vote_xyz, np, threads);
                        let pgroups = pointops::ball_query_par(vote_xyz, &pidx, pr, pk, threads);
                        cluster_slot.set(pidx.iter().map(|&i| vote_xyz[i]).collect());
                        pgrp_slot.set((pidx, pgroups));
                    });
                    Ok(())
                })),
            )
        };
        let prop_slot: Slot<Tensor> = Slot::new("proposals");
        let prop_nn = {
            let art = cfg.art("prop");
            let qspec = qspec_for(&art, cfg.scheme.prop);
            let prop_prec = nn_precision(m, &art);
            let (vote_slot, pgrp_slot, prop_slot) =
                (vote_slot.clone(), pgrp_slot.clone(), prop_slot.clone());
            b.stage(
                "prop".into(),
                nn_dev_for(prop_prec),
                prop_prec,
                nn_workload(m, &art),
                vec![prop_pm],
                vec![],
                Compute::Host(Box::new(move || {
                    let (pidx, pgroups) = pgrp_slot.take();
                    let pg = vote_slot.with(|(vote_xyz, vote_feats)| {
                        pointops::group_features(vote_xyz, Some(vote_feats), &pidx, &pgroups)
                    });
                    prop_slot.set(self.rt.run_with_spec(&art, &[&pg], qspec.as_ref())?.remove(0));
                    Ok(())
                })),
            )
        };

        // decode + NMS on the host CPU
        let det_slot: Slot<Vec<Box3>> = Slot::new("detections");
        {
            let (cluster_slot, prop_slot, det_slot) =
                (cluster_slot.clone(), prop_slot.clone(), det_slot.clone());
            let (obj_thresh, nms_iou) = (cfg.obj_thresh, cfg.nms_iou);
            b.stage(
                "decode".into(),
                DeviceKind::Cpu,
                Precision::Fp32,
                small_pointop((m.num_proposals * m.num_proposals) as u64 * 20, 4096),
                vec![prop_nn],
                vec![],
                Compute::Pool(Box::new(move || {
                    let cluster_xyz = cluster_slot.take();
                    let prop = prop_slot.take();
                    det_slot.set(decode_detections(m, &cluster_xyz, &prop, obj_thresh, nms_iou));
                    Ok(())
                })),
            );
        }

        // ---------------------------------------------- execute + simulate
        let specs = DagExecutor::new(self.host_exec).run(b.decls)?;
        let detections = det_slot.take();
        let used_scores = if painted { Some(scores_slot.take()) } else { None };
        let timeline = self.sim.run(&specs);
        let fp32_framework = !cfg.int8() && matches!(cfg.schedule, Schedule::SingleDevice(_));
        let peak = peak_memory_mb(m, painted, fp32_framework, n);
        Ok((
            PipelineOutput {
                detections,
                timeline,
                stage_specs: specs,
                peak_memory_mb: peak,
                host_ms: t_host.elapsed().as_secs_f64() * 1000.0,
            },
            used_scores,
        ))
    }

    /// Declare SA1..SA3 of one pipeline (full or half centroid budget).
    /// Returns the SA2 and SA3 level handles for the FP stage.
    #[allow(clippy::too_many_arguments)]
    fn declare_sa_chain<'s>(
        &'s self,
        b: &mut StageBuilder<'s>,
        scene: &'s Scene,
        input: ChainInput,
        n0: usize,
        feat_slot: &Slot<(Tensor, Vec<f32>)>,
        c0: usize,
        tag: &str,
        biased: bool,
        point_dev: DeviceKind,
        nn_dev: DeviceKind,
        seg_stage: Option<usize>,
        paint_stage: Option<usize>,
        threads: usize,
    ) -> (ChainLevel, ChainLevel) {
        let cfg = &self.cfg;
        let m = &self.rt.manifest;
        let halves = cfg.variant.split();
        let shape = if halves { "half" } else { "full" };
        let painted = cfg.variant.painted();
        let mut prev: Option<ChainLevel> = None;
        let mut sa2 = None;
        let (mut n_in, mut c_in) = (n0, c0);
        for l in 0..3 {
            let sac = &m.sa_configs[l];
            let mm = if halves { sac.m / 2 } else { sac.m };
            let use_bias = biased && l < cfg.bias_layers && cfg.w0 != 1.0;
            // the SA-bias pipeline's SA1 starts FPS at n/2 so the two views
            // decorrelate even where the bias weight has no effect (mirrors
            // model.backbone_forward's run_pipeline)
            let start = if biased && l == 0 { n_in / 2 } else { 0 };
            // point-manip deps: previous NN of this pipeline produced the
            // features we gather; biased FPS additionally needs the painted
            // fg mask (jump-start rule, Fig. 3)
            let mut deps: Vec<usize> = match &prev {
                Some(p) => vec![p.nn],
                None => seg_stage.into_iter().collect(),
            };
            if use_bias {
                if let Some(s) = seg_stage {
                    if !deps.contains(&s) {
                        deps.push(s);
                    }
                }
            }
            // SA1-normal point manip of a painted pipeline needs nothing: it
            // jump-starts before segmentation finishes (gather happens in the
            // NN stage's transfer) — but its PointNet needs the paint.
            let deps_pm = if l == 0 && !use_bias { Vec::new() } else { deps.clone() };
            // host-ordering: biased FPS reads the fg mask produced by paint
            let extra_pm = if use_bias && painted {
                paint_stage.into_iter().collect()
            } else {
                Vec::new()
            };
            let geo_out: Slot<Geo> = Slot::new("chain geo");
            let grp_out: Slot<(Vec<usize>, Vec<Vec<usize>>)> = Slot::new("chain groups");
            let pm = {
                let geo_out = geo_out.clone();
                let grp_out = grp_out.clone();
                let prev_geo = prev.as_ref().map(|p| p.geo.clone());
                let input = input.clone();
                let fgsrc = if use_bias { Some(feat_slot.clone()) } else { None };
                let (radius, k, w0) = (sac.radius, sac.k, cfg.w0);
                b.stage(
                    format!("sa{}_{}_pm", l + 1, tag),
                    point_dev,
                    Precision::Fp32,
                    sa_pointmanip_workload(n_in, mm, sac.k, c_in),
                    deps_pm,
                    extra_pm,
                    Compute::Pool(Box::new(move || {
                        let geo = resolve_geo(&prev_geo, &input, scene);
                        let idx = match &fgsrc {
                            Some(fs) => {
                                let fg: Vec<f32> = fs
                                    .with(|(_, fg)| geo.src.iter().map(|&i| fg[i]).collect());
                                pointops::biased_fps_from_par(
                                    &geo.xyz, mm, &fg, w0, start, threads,
                                )
                            }
                            None => pointops::fps_from_par(&geo.xyz, mm, start, threads),
                        };
                        let groups = pointops::ball_query_par(&geo.xyz, &idx, radius, k, threads);
                        geo_out.set(Geo {
                            xyz: idx.iter().map(|&i| geo.xyz[i]).collect(),
                            src: idx.iter().map(|&i| geo.src[i]).collect(),
                        });
                        grp_out.set((idx, groups));
                        Ok(())
                    })),
                )
            };
            let mut deps_nn = vec![pm];
            if l == 0 {
                if let Some(s) = seg_stage {
                    deps_nn.push(s); // painted features required
                }
            }
            // host-ordering: the level-0 gather reads features built by the
            // paint stage (seg alone finishing is not enough)
            let extra_nn = if l == 0 && painted {
                paint_stage.into_iter().collect()
            } else {
                Vec::new()
            };
            let art = cfg.art(&format!("sa{}_{shape}", l + 1));
            let qspec = m
                .artifact(&art)
                .map(|a| m.stage_quant_for(a, cfg.scheme.backbone));
            let feats_out: Slot<Tensor> = Slot::new("chain feats");
            let nn = {
                let feats_out = feats_out.clone();
                let grp_out = grp_out.clone();
                let prev_level = prev.clone();
                let input = input.clone();
                let feat_src = feat_slot.clone();
                b.stage(
                    format!("sa{}_{}_nn", l + 1, tag),
                    nn_dev,
                    nn_precision(m, &art),
                    nn_workload(m, &art),
                    deps_nn,
                    extra_nn,
                    Compute::Host(Box::new(move || {
                        let (idx, groups) = grp_out.take();
                        let g = match &prev_level {
                            // level > 0: gather from the previous level's
                            // chain-local geometry and features
                            Some(p) => p.geo.with(|geo| {
                                p.feats.with(|f| {
                                    pointops::group_features(&geo.xyz, Some(f), &idx, &groups)
                                })
                            }),
                            // level 0: gather straight from the (possibly
                            // subsetted) original cloud
                            None => match &input {
                                ChainInput::Full => feat_src.with(|(f, _)| {
                                    pointops::group_features(
                                        &scene.points,
                                        Some(f),
                                        &idx,
                                        &groups,
                                    )
                                }),
                                ChainInput::Subset(sub) => {
                                    let xyz: Vec<[f32; 3]> =
                                        sub.iter().map(|&i| scene.points[i]).collect();
                                    let f = feat_src.with(|(f, _)| f.gather_rows(sub));
                                    pointops::group_features(&xyz, Some(&f), &idx, &groups)
                                }
                            },
                        };
                        feats_out.set(self.run_maybe_padded(&art, &g, mm, qspec.as_ref())?);
                        Ok(())
                    })),
                )
            };
            let level = ChainLevel {
                geo: geo_out,
                feats: feats_out,
                nn,
                n: mm,
                c: *sac.mlp.last().expect("sa mlp widths"),
            };
            if l == 1 {
                sa2 = Some(level.clone());
            }
            n_in = mm;
            c_in = level.c;
            prev = Some(level);
        }
        (sa2.expect("three SA levels declared"), prev.expect("three SA levels declared"))
    }

    /// Execute an SA artifact whose ball-batch dimension may exceed ours
    /// (RandomSplit halves reuse the `half` artifacts of matching size; the
    /// padding path covers residual mismatches defensively). A *smaller*
    /// artifact is a malformed export — reported as an error, not a panic,
    /// so the serving path degrades instead of dying.
    fn run_maybe_padded(
        &self,
        art: &str,
        g: &Tensor,
        b: usize,
        spec: Option<&QuantSpec>,
    ) -> Result<Tensor> {
        let meta = self
            .rt
            .manifest
            .artifact(art)
            .ok_or_else(|| anyhow!("artifact '{art}' missing"))?;
        let want = meta.input_shapes[0][0];
        if want == b {
            return Ok(self.rt.run_with_spec(art, &[g], spec)?.remove(0));
        }
        if want < b {
            return Err(anyhow!(
                "artifact '{art}' ball dimension {want} smaller than workload {b} \
                 (malformed export?)"
            ));
        }
        let mut padded = Tensor::zeros(vec![want, g.shape[1], g.shape[2]]);
        padded.data[..g.data.len()].copy_from_slice(&g.data);
        let out = self.rt.run_with_spec(art, &[&padded], spec)?.remove(0);
        let rows: Vec<usize> = (0..b).collect();
        Ok(out.gather_rows(&rows))
    }
}

/// Resolve a level's input geometry: the previous level's output, or the
/// (possibly subsetted) original cloud for level 0.
fn resolve_geo(prev: &Option<Slot<Geo>>, input: &ChainInput, scene: &Scene) -> Geo {
    match prev {
        Some(s) => s.cloned(),
        None => match input {
            ChainInput::Full => Geo {
                xyz: scene.points.clone(),
                src: (0..scene.points.len()).collect(),
            },
            ChainInput::Subset(idx) => Geo {
                xyz: idx.iter().map(|&i| scene.points[i]).collect(),
                src: idx.as_ref().clone(),
            },
        },
    }
}

/// Horizontal concat of two (N, C) tensors.
fn hconcat(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rows(), b.rows());
    let (ca, cb) = (a.row_len(), b.row_len());
    let mut data = Vec::with_capacity(a.rows() * (ca + cb));
    for i in 0..a.rows() {
        data.extend_from_slice(a.row(i));
        data.extend_from_slice(b.row(i));
    }
    Tensor::new(vec![a.rows(), ca + cb], data)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pipeline(rt: &Runtime) -> ScenePipeline<'_> {
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        ScenePipeline::new(rt, cfg)
    }

    #[test]
    fn run_maybe_padded_pads_smaller_workloads() {
        let rt = Runtime::synthetic();
        let p = pipeline(&rt);
        // sa1_full expects 256 balls of (32, 15); feed 200
        let g = Tensor::zeros(vec![200, 32, 15]);
        let out = p
            .run_maybe_padded("synrgbd_pointsplit_sa1_full_int8", &g, 200, None)
            .unwrap();
        assert_eq!(out.rows(), 200);
    }

    #[test]
    fn run_maybe_padded_rejects_oversized_workloads_gracefully() {
        let rt = Runtime::synthetic();
        let p = pipeline(&rt);
        let g = Tensor::zeros(vec![300, 32, 15]);
        let err = p
            .run_maybe_padded("synrgbd_pointsplit_sa1_full_int8", &g, 300, None)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("smaller than workload"), "unexpected error: {msg}");
    }
}
