"""L2 point-manipulation ops: FPS / biased FPS / ball query / 3-NN interp."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings, strategies as st

from compile import sampling

settings.register_profile("ci", max_examples=10, deadline=None)
settings.load_profile("ci")


def cloud(seed, n=400, scale=4.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.uniform(0, scale, (n, 3)).astype(np.float32))


def fps_numpy(xyz, m):
    """Independent numpy re-implementation as oracle."""
    xyz = np.asarray(xyz)
    n = len(xyz)
    out = [0]
    mind = np.full(n, np.inf)
    for _ in range(1, m):
        d = np.sum((xyz - xyz[out[-1]]) ** 2, axis=1)
        mind = np.minimum(mind, d)
        out.append(int(np.argmax(mind)))
    return np.array(out)


@given(seed=st.integers(0, 1000), m=st.sampled_from([2, 16, 64]))
def test_fps_matches_numpy_oracle(seed, m):
    xyz = cloud(seed)
    got = np.asarray(sampling.fps(xyz, m))
    expect = fps_numpy(xyz, m)
    np.testing.assert_array_equal(got, expect)


def test_fps_indices_distinct():
    xyz = cloud(1, n=300)
    idx = np.asarray(sampling.fps(xyz, 100))
    assert len(set(idx.tolist())) == 100


def test_biased_fps_prefers_foreground():
    xyz = cloud(2, n=600)
    fg = jnp.asarray((np.asarray(xyz)[:, 0] < 1.0).astype(np.float32))
    base = np.asarray(sampling.fps(xyz, 128))
    biased = np.asarray(sampling.fps(xyz, 128, fg, w0=3.0))
    fgn = np.asarray(fg)
    assert fgn[biased].mean() > fgn[base].mean()


def test_biased_fps_w0_one_is_regular():
    xyz = cloud(3)
    fg = jnp.ones(xyz.shape[0])
    a = np.asarray(sampling.fps(xyz, 50))
    b = np.asarray(sampling.fps(xyz, 50, fg, w0=1.0))
    np.testing.assert_array_equal(a, b)


@given(seed=st.integers(0, 1000), r=st.sampled_from([0.3, 0.8]), k=st.sampled_from([4, 16]))
def test_ball_query_within_radius_or_fill(seed, r, k):
    xyz = cloud(seed, n=300, scale=2.0)
    centers_idx = sampling.fps(xyz, 16)
    centers = xyz[centers_idx]
    groups = np.asarray(sampling.ball_query(centers, xyz, r, k, use_pallas=False))
    x = np.asarray(xyz)
    c = np.asarray(centers)
    for i in range(16):
        first = groups[i, 0]
        for j in groups[i]:
            d = np.linalg.norm(x[j] - c[i])
            assert d <= r + 1e-5 or j == first


def test_ball_query_pallas_path_matches_ref_path():
    xyz = cloud(5, n=256)
    centers = xyz[sampling.fps(xyz, 32)]
    a = np.asarray(sampling.ball_query(centers, xyz, 0.5, 8, use_pallas=True))
    b = np.asarray(sampling.ball_query(centers, xyz, 0.5, 8, use_pallas=False))
    np.testing.assert_array_equal(a, b)


def test_group_features_relative_coords():
    xyz = jnp.asarray([[0.0, 0, 0], [1, 0, 0], [0, 2, 0]], jnp.float32)
    feats = jnp.asarray([[5.0], [6.0], [7.0]])
    g = sampling.group_features(xyz, feats, jnp.asarray([1]), jnp.asarray([[0, 2]]))
    assert g.shape == (1, 2, 4)
    np.testing.assert_allclose(np.asarray(g)[0, 0], [-1, 0, 0, 5])
    np.testing.assert_allclose(np.asarray(g)[0, 1], [-1, 2, 0, 7])


def test_three_nn_interpolate_exact_at_sources():
    src = cloud(6, n=32)
    feats = jnp.asarray(np.random.default_rng(0).normal(size=(32, 5)).astype(np.float32))
    out = np.asarray(sampling.three_nn_interpolate(src, src, feats))
    np.testing.assert_allclose(out, np.asarray(feats), rtol=1e-3, atol=1e-3)


def test_random_split_partitions():
    ia, ib = sampling.random_split(100, jax.random.PRNGKey(0))
    merged = sorted(np.concatenate([np.asarray(ia), np.asarray(ib)]).tolist())
    assert merged == list(range(100))
    assert len(np.asarray(ia)) == 50


def test_fps_start_parameter():
    xyz = cloud(7)
    idx = np.asarray(sampling.fps(xyz, 16, start=123))
    assert idx[0] == 123
    # different starts decorrelate the sampled views (the PointSplit fix)
    a = set(np.asarray(sampling.fps(xyz, 64, start=0)).tolist())
    b = set(np.asarray(sampling.fps(xyz, 64, start=200)).tolist())
    assert len(a & b) < 60


def test_fps_start_matches_rust_convention():
    """start index becomes out[0]; remaining selection is standard FPS."""
    xyz = cloud(8, n=100)
    idx = np.asarray(sampling.fps(xyz, 3, start=50))
    x = np.asarray(xyz)
    d = np.linalg.norm(x - x[50], axis=1)
    assert idx[1] == int(np.argmax(d))
