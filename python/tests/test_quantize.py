"""Role-based group-wise quantization (paper §4.3): the Table 11 mechanics."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import common, model, quantize
from compile.kernels import ref


def head_like_activations(n=512, seed=0):
    """Heterogeneous channels mimicking the proposal head: tight xyz,
    wide logits, medium regression — the distribution split of Fig. 6."""
    rng = np.random.default_rng(seed)
    cout = common.PROPOSAL_CH
    acts = np.zeros((n, cout), np.float32)
    g1, g2, g3 = common.proposal_role_groups()
    acts[:, g1] = rng.normal(0, 0.05, (n, len(g1)))
    acts[:, g2] = rng.normal(0, 6.0, (n, len(g2)))
    acts[:, g3] = rng.normal(0, 0.6, (n, len(g3)))
    return acts


def qdq_with(acts, scheme):
    roles = common.proposal_role_groups()
    groups = quantize.channel_groups(scheme, acts.shape[1], roles)
    s, z = quantize.act_qparams(acts.min(0), acts.max(0), groups)
    q = ref.qdq_act(jnp.asarray(acts), jnp.asarray(s), jnp.asarray(z))
    return np.asarray(q)


@pytest.mark.parametrize("scheme", quantize.SCHEMES)
def test_qdq_bounded_error(scheme):
    acts = head_like_activations()
    q = qdq_with(acts, scheme)
    # error can never exceed one quantization step of the widest group
    assert np.abs(q - acts).max() < (acts.max() - acts.min()) / 255.0 + 1e-5


def rel_group_error(acts, scheme):
    """Scale-normalized quantization error: mean over role groups of
    MSE_g / Var_g — what actually predicts mAP damage (a 0.04 absolute
    error is fatal for xyz offsets yet invisible for +-20 logits)."""
    q = qdq_with(acts, scheme)
    errs = []
    for g in common.proposal_role_groups():
        mse = np.mean((q[:, g] - acts[:, g]) ** 2)
        errs.append(mse / np.var(acts[:, g]))
    return float(np.mean(errs))


def test_role_vs_layer_error_ordering():
    """The paper core claim: layer >> group >> role ~ channel (when errors
    are normalized per role group, i.e. weighted by task relevance)."""
    acts = head_like_activations()
    err = {s: rel_group_error(acts, s) for s in quantize.SCHEMES}
    assert err["layer"] > 10 * err["role"], err
    assert err["group"] > err["role"], err
    assert err["channel"] <= err["role"] * 1.2, err


def test_xyz_channels_destroyed_by_layer_scale():
    acts = head_like_activations()
    q = qdq_with(acts, "layer")
    g1 = common.proposal_role_groups()[0]
    rel = np.sum((q[:, g1] - acts[:, g1]) ** 2) / np.sum(acts[:, g1] ** 2)
    assert rel > 0.3, f"xyz relative error {rel} should be catastrophic under layer-wise"


def test_param_counts_match_paper_shape():
    counts = {s: quantize.quant_param_count(s) for s in quantize.SCHEMES}
    assert counts["layer"] < counts["role"] == counts["group"] < counts["channel"]
    # channel/role ratio ~ the paper's 67x (ours: 210 channels vs 5 groups = 42x)
    assert counts["channel"] / counts["role"] > 30


def test_channel_groups_partition():
    roles = common.proposal_role_groups()
    for scheme in quantize.SCHEMES:
        groups = quantize.channel_groups(scheme, common.PROPOSAL_CH, roles)
        flat = sorted(c for g in groups for c in g)
        assert flat == list(range(common.PROPOSAL_CH)), scheme


def test_build_qconfig_covers_backbone_and_heads():
    params = model.detector_init(jax.random.PRNGKey(0), painted=True)
    calib = {
        "vote_out_min": np.full(common.VOTE_CH, -1.0, np.float32),
        "vote_out_max": np.full(common.VOTE_CH, 1.0, np.float32),
        "prop_out_min": np.full(common.PROPOSAL_CH, -1.0, np.float32),
        "prop_out_max": np.full(common.PROPOSAL_CH, 1.0, np.float32),
    }
    qc = quantize.build_qconfig(params, calib, "role")
    assert "vote_out" in qc.act_q and "prop_out" in qc.act_q
    assert "sa1.0" in qc.weight_scales and "fp_fc.0" in qc.weight_scales
    # role granularity: vote scales take exactly 2 distinct values
    vs = np.asarray(qc.act_q["vote_out"][0])
    assert len(np.unique(vs)) <= 2


def test_weight_qdq_error_small():
    params = model.detector_init(jax.random.PRNGKey(1), painted=True)
    w = np.asarray(params["prop_out"][0])
    roles = common.proposal_role_groups()
    sv = quantize.weight_scale_vector(w, quantize.channel_groups("role", w.shape[1], roles))
    wq = np.asarray(ref.qdq_weight(jnp.asarray(w), jnp.asarray(sv)))
    rel = np.abs(wq - w).max() / (np.abs(w).max() + 1e-9)
    assert rel < 0.02


def test_head_stats_structure():
    params = model.detector_init(jax.random.PRNGKey(2), painted=True)
    acts_v = np.random.default_rng(0).normal(size=(64, common.VOTE_CH)).astype(np.float32)
    acts_p = head_like_activations(64)
    calib = {
        "vote_out_min": acts_v.min(0),
        "vote_out_max": acts_v.max(0),
        "prop_out_min": acts_p.min(0),
        "prop_out_max": acts_p.max(0),
        "vote_acts": acts_v,
        "prop_acts": acts_p,
    }
    stats = quantize.head_stats(params, calib)
    assert set(stats) == {"vote_out", "prop_out"}
    s = stats["prop_out"]
    assert len(s["channel_order"]) == common.PROPOSAL_CH
    assert len(s["act_hist"]) == common.PROPOSAL_CH
    np.testing.assert_allclose(np.sum(s["act_hist"][0]), 1.0, atol=1e-6)
