//! Multi-scene request loop (std threads; tokio is not vendored).
//!
//! A scene source thread feeds a channel; worker threads run the per-scene
//! pipeline; the collector aggregates detections, simulated latency
//! statistics, and host wall-clock throughput. The `xla` crate's PJRT
//! handles are `Rc`-based (not `Send`), so each worker owns a private
//! [`Runtime`] — executable compilation is per-worker but cached for the
//! worker's lifetime.

use std::sync::mpsc;
use std::sync::{Arc, Mutex};

use anyhow::Result;

use super::pipeline::{DetectorConfig, ScenePipeline};
use crate::data::{generate_scene, Box3, DatasetCfg, Scene};
use crate::eval::{eval_map, Detection};
use crate::runtime::Runtime;

/// Aggregated serving statistics.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub scenes: usize,
    /// simulated per-scene latency (device model), ms
    pub sim_latency_ms: Stats,
    /// host wall-clock per scene (functional execution), ms
    pub host_latency_ms: Stats,
    pub peak_memory_mb: f64,
    pub map_25: f64,
    pub map_50: f64,
    pub per_class_ap25: Vec<Option<f64>>,
    /// simulated device busy totals across all scenes, ms
    pub busy_gpu_ms: f64,
    pub busy_npu_ms: f64,
    pub comm_ms: f64,
    pub wall_s: f64,
}

pub use crate::util::stats::Stats;

/// Serve `num_scenes` synthetic scenes through `workers` threads and report
/// accuracy + latency. Scene seeds start at `seed0` (use the same seed range
/// across variants for paired comparisons). `rt` supplies the manifest and
/// the artifacts directory; workers open their own PJRT clients against it.
pub fn serve(
    rt: &Runtime,
    cfg: &DetectorConfig,
    ds: &DatasetCfg,
    num_scenes: usize,
    workers: usize,
    seed0: u64,
) -> Result<ServeReport> {
    let source = rt.source();
    // split the host's threads between scene-level workers and each
    // pipeline's stage-level parallelism so a full pool doesn't oversubscribe
    let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let per_worker = (cores / workers.max(1)).clamp(1, 4);
    let host_exec = if per_worker > 1 {
        crate::exec::HostExec::Parallel { threads: per_worker }
    } else {
        crate::exec::HostExec::Sequential
    };
    let t0 = std::time::Instant::now();
    let (tx_scene, rx_scene) = mpsc::channel::<(usize, Scene)>();
    let rx_scene = Arc::new(Mutex::new(rx_scene));
    let (tx_out, rx_out) = mpsc::channel();

    // source: generate scenes (cheap, single thread)
    let src = {
        let tx = tx_scene.clone();
        let ds = ds.clone();
        std::thread::spawn(move || {
            for i in 0..num_scenes {
                let scene = generate_scene(seed0 + i as u64, &ds);
                if tx.send((i, scene)).is_err() {
                    break;
                }
            }
        })
    };
    drop(tx_scene);

    std::thread::scope(|scope| {
        for _ in 0..workers.max(1) {
            let rx = rx_scene.clone();
            let tx = tx_out.clone();
            let cfg = cfg.clone();
            let source = source.clone();
            scope.spawn(move || {
                // private PJRT client per worker (xla handles are !Send)
                let rt = match source.open() {
                    Ok(rt) => rt,
                    Err(e) => {
                        eprintln!("worker failed to open runtime: {e:#}");
                        return;
                    }
                };
                let pipe = ScenePipeline::new(&rt, cfg).with_host_exec(host_exec);
                loop {
                    let msg = { rx.lock().unwrap().recv() };
                    match msg {
                        Ok((i, scene)) => {
                            let gt = scene.gt_boxes();
                            let out = pipe.run(&scene, seed0 + i as u64);
                            if tx.send((i, gt, out)).is_err() {
                                return;
                            }
                        }
                        Err(_) => return,
                    }
                }
            });
        }
        drop(tx_out);

        let mut gts: Vec<Vec<Box3>> = vec![Vec::new(); num_scenes];
        let mut dets: Vec<Detection> = Vec::new();
        let mut sim_lat = Vec::new();
        let mut host_lat = Vec::new();
        let mut peak = 0.0f64;
        let mut busy_gpu = 0.0;
        let mut busy_npu = 0.0;
        let mut comm = 0.0;
        for (i, gt, out) in rx_out.iter() {
            let out = out?;
            gts[i] = gt;
            for b in &out.detections {
                dets.push(Detection { scene: i, b: *b });
            }
            sim_lat.push(out.timeline.total_ms);
            host_lat.push(out.host_ms);
            peak = peak.max(out.peak_memory_mb);
            for (k, v) in &out.timeline.busy_ms {
                match k {
                    crate::sim::DeviceKind::Gpu => busy_gpu += v,
                    crate::sim::DeviceKind::EdgeTpu => busy_npu += v,
                    _ => {}
                }
            }
            comm += out.timeline.comm_ms.values().sum::<f64>();
        }
        src.join().ok();

        let nc = rt.manifest.num_class();
        let r25 = eval_map(&dets, &gts, nc, 0.25);
        let r50 = eval_map(&dets, &gts, nc, 0.50);
        Ok(ServeReport {
            scenes: num_scenes,
            sim_latency_ms: Stats::from(sim_lat),
            host_latency_ms: Stats::from(host_lat),
            peak_memory_mb: peak,
            map_25: r25.map,
            map_50: r50.map,
            per_class_ap25: r25.ap,
            busy_gpu_ms: busy_gpu,
            busy_npu_ms: busy_npu,
            comm_ms: comm,
            wall_s: t0.elapsed().as_secs_f64(),
        })
    })
}
