//! Deadline-aware dispatch policies: degrade gracefully under pressure,
//! shed what cannot be saved.
//!
//! At dispatch time the gateway knows (from the [`plan`](super::plan)
//! cache) what a batch will cost on the full configuration and on the
//! degraded fast path. The policy compares predicted completion against the
//! batch's deadlines and picks one of three moves:
//!
//! - run **full** quality when it still makes every deadline it can make,
//! - **degrade** — int8 backbone + role-quantized heads, consecutive
//!   matching (2D segmentation reused, paper §3.2), and a halved point
//!   budget (attacks the GPU point-manipulation lane, which dominates the
//!   critical path) — when full quality would blow deadlines the fast path
//!   can still meet,
//! - **shed** requests that even the fast path cannot save, so the
//!   accelerators never burn time on work that is already dead (doing so is
//!   what collapses goodput in the no-policy baseline).
//!
//! Streaming gateways get a fourth move between full and degrade:
//! **stale tracks** ([`SloPolicy::StaleTracks`]) serves warm sessions from
//! their cached frame state (REUSE tail only, see [`crate::temporal`]) —
//! stale-but-fast tracks at full precision instead of a quantized redo.

use anyhow::Result;

use crate::coordinator::DetectorConfig;
use crate::graph::StageGraph;
use crate::runtime::Manifest;

use super::loadgen::Request;

/// Overload-response policy of the gateway.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloPolicy {
    /// Dispatch everything at full quality, deadlines be damned (baseline).
    None,
    /// Drop requests whose deadline the full-quality path would miss; never
    /// change quality.
    Shed,
    /// Prefer the degraded fast path when it saves deadlines; shed only what
    /// even degradation cannot save.
    Degrade,
    /// Streaming rung above Degrade: under pressure, first serve warm
    /// sessions stale — force their frames onto the cached REUSE tail
    /// (raising the effective reuse threshold) — and only then fall through
    /// to the degraded fast path and shedding. Sessionless traffic sees
    /// exactly the Degrade ladder.
    StaleTracks,
}

impl SloPolicy {
    pub fn parse(s: &str) -> Option<SloPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "none" | "off" => Some(SloPolicy::None),
            "shed" => Some(SloPolicy::Shed),
            "degrade" | "slo" => Some(SloPolicy::Degrade),
            "stale-tracks" | "stale" => Some(SloPolicy::StaleTracks),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            SloPolicy::None => "none",
            SloPolicy::Shed => "shed",
            SloPolicy::Degrade => "degrade",
            SloPolicy::StaleTracks => "stale-tracks",
        }
    }
}

/// The degraded fast path for a configuration: swap the stage subset's
/// quant specs — backbone groups dropped to plain INT8 (EdgeTPU-eligible),
/// heads kept at role-based fidelity (the paper's accuracy-preserving
/// scheme) — plus 2D segmentation reuse. The planner is additionally given
/// `skip_seg = true` and the reduced [`degraded_points`] budget.
///
/// At the graph level this is the quant-rewrite pass
/// ([`degraded_graph`]); this function is its config-level view for
/// callers that rebuild the graph anyway (different point budget).
pub fn degraded_config(cfg: &DetectorConfig) -> DetectorConfig {
    let mut fast = cfg.clone();
    fast.scheme = cfg.scheme.degraded();
    fast
}

/// The degrade move as a spec rewrite over the stage graph's nodes:
/// the same topology with every NN node's artifact, precision, workload,
/// device and quant spec re-derived from the degraded `QuantScheme`
/// ([`StageGraph::quant_rewrite`]). Point-op nodes and dependency edges
/// are untouched — degradation swaps specs, it never reshapes the DAG.
pub fn degraded_graph(m: &Manifest, full: &StageGraph) -> Result<StageGraph> {
    full.quant_rewrite(m, full.cfg().scheme.degraded())
}

/// Point budget of the degraded fast path: half the cloud, floored so the
/// SA hierarchy (SA1 samples 256 centroids) stays well-posed.
pub fn degraded_points(num_points: usize) -> usize {
    (num_points / 2).max(512)
}

/// Outcome of the policy decision for one batch.
#[derive(Debug)]
pub struct SloDecision {
    /// Requests to dispatch now (empty means the whole batch was shed).
    pub dispatch: Vec<Request>,
    /// Whether the dispatched work runs on the degraded fast path.
    pub degraded: bool,
    /// Whether warm sessions in the dispatched work are served stale (forced
    /// onto their cached REUSE tail). Only [`SloPolicy::StaleTracks`] sets it.
    pub stale: bool,
    /// Requests dropped because no available path meets their deadline.
    pub shed: Vec<Request>,
}

/// Apply `policy` to a batch at time `now_ms`, given the predicted service
/// times of the full and degraded paths.
///
/// Predictions are for the batch as formed; after shedding, the remaining
/// smaller batch can only finish sooner, so decisions err conservative.
pub fn apply(
    policy: SloPolicy,
    reqs: Vec<Request>,
    now_ms: f64,
    full_ms: f64,
    fast_ms: f64,
) -> SloDecision {
    // with no stale pricing the stale rung is never cheaper than full, so
    // StaleTracks collapses onto the Degrade ladder
    apply_stream(policy, reqs, now_ms, full_ms, full_ms, fast_ms)
}

/// [`apply`] with the streaming rung priced in: `stale_ms` is the predicted
/// batch service time when every warm session is forced onto its cached
/// REUSE tail. The ladder is full → stale → degraded fast → shed; the stale
/// rung only exists under [`SloPolicy::StaleTracks`] and only fires when it
/// is actually cheaper than full (a batch of cold or sessionless requests
/// prices stale == full and falls straight through).
pub fn apply_stream(
    policy: SloPolicy,
    reqs: Vec<Request>,
    now_ms: f64,
    full_ms: f64,
    stale_ms: f64,
    fast_ms: f64,
) -> SloDecision {
    match policy {
        SloPolicy::None => {
            SloDecision { dispatch: reqs, degraded: false, stale: false, shed: Vec::new() }
        }
        SloPolicy::Shed => {
            let done = now_ms + full_ms;
            let (keep, shed) = reqs.into_iter().partition(|r| r.deadline_ms >= done);
            SloDecision { dispatch: keep, degraded: false, stale: false, shed }
        }
        SloPolicy::Degrade | SloPolicy::StaleTracks => {
            let full_done = now_ms + full_ms;
            let all_make_full = reqs.iter().all(|r| r.deadline_ms >= full_done);
            if all_make_full {
                return SloDecision {
                    dispatch: reqs,
                    degraded: false,
                    stale: false,
                    shed: Vec::new(),
                };
            }
            if policy == SloPolicy::StaleTracks && stale_ms < full_ms {
                // full quality would miss someone: serve stale-but-fast tracks
                let stale_done = now_ms + stale_ms;
                if reqs.iter().all(|r| r.deadline_ms >= stale_done) {
                    return SloDecision {
                        dispatch: reqs,
                        degraded: false,
                        stale: true,
                        shed: Vec::new(),
                    };
                }
            }
            // last resort before shedding: the degraded fast path
            let fast_done = now_ms + fast_ms;
            let (keep, shed): (Vec<Request>, Vec<Request>) =
                reqs.into_iter().partition(|r| r.deadline_ms >= fast_done);
            SloDecision { dispatch: keep, degraded: true, stale: false, shed }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Schedule, Variant};
    use crate::sim::DeviceKind;

    fn req(id: u64, deadline: f64) -> Request {
        Request { id, arrival_ms: 0.0, deadline_ms: deadline, seed: id, class: 0, key: 0, client: 0 }
    }

    #[test]
    fn none_dispatches_everything() {
        let d = apply(SloPolicy::None, vec![req(0, 1.0), req(1, 2.0)], 100.0, 50.0, 20.0);
        assert_eq!(d.dispatch.len(), 2);
        assert!(!d.degraded);
        assert!(d.shed.is_empty());
    }

    #[test]
    fn shed_drops_doomed_only() {
        let d = apply(SloPolicy::Shed, vec![req(0, 120.0), req(1, 200.0)], 100.0, 50.0, 20.0);
        assert_eq!(d.dispatch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(d.shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
        assert!(!d.degraded);
    }

    #[test]
    fn degrade_prefers_full_when_safe() {
        let d = apply(SloPolicy::Degrade, vec![req(0, 200.0)], 100.0, 50.0, 20.0);
        assert!(!d.degraded);
        assert_eq!(d.dispatch.len(), 1);
    }

    #[test]
    fn degrade_switches_when_full_misses() {
        let d = apply(SloPolicy::Degrade, vec![req(0, 130.0), req(1, 300.0)], 100.0, 50.0, 20.0);
        assert!(d.degraded, "req 0 misses full (150) but makes fast (120)");
        assert_eq!(d.dispatch.len(), 2);
        assert!(d.shed.is_empty());
    }

    #[test]
    fn degrade_sheds_the_unsavable() {
        let d = apply(SloPolicy::Degrade, vec![req(0, 110.0), req(1, 300.0)], 100.0, 50.0, 20.0);
        assert!(d.degraded);
        assert_eq!(d.dispatch.iter().map(|r| r.id).collect::<Vec<_>>(), vec![1]);
        assert_eq!(d.shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn degraded_config_swaps_quant_specs_not_flags() {
        use crate::quant::{Granularity, StagePrecision};
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            false,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        let fast = degraded_config(&cfg);
        assert!(fast.scheme.backbone.is_int8());
        assert!(matches!(
            fast.scheme.backbone,
            StagePrecision::Int8(Granularity::Group(_))
        ));
        assert_eq!(fast.scheme.vote, StagePrecision::Int8(Granularity::Role));
        assert_eq!(fast.scheme.prop, StagePrecision::Int8(Granularity::Role));
        assert!(fast.int8());
        assert_eq!(fast.dataset, cfg.dataset);
        // artifact naming still resolves (backbone granularity is a spec
        // refinement, not a new artifact set)
        assert_eq!(fast.seg_art(), "synrgbd_seg_int8");
    }

    #[test]
    fn degraded_graph_is_the_quant_rewrite_of_the_full_graph() {
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            false,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        let m = Manifest::synthetic();
        let full = StageGraph::build(&m, &cfg, 2048, false).expect("full graph");
        let fast = degraded_graph(&m, &full).expect("degraded graph");
        // identical to rebuilding from the config-level view
        let rebuilt = StageGraph::build(&m, &degraded_config(&cfg), 2048, false).expect("rebuild");
        assert_eq!(fast.specs(), rebuilt.specs());
        assert_eq!(fast.fingerprint(), rebuilt.fingerprint());
        // same topology, swapped specs: deps match node for node
        assert_eq!(full.nodes.len(), fast.nodes.len());
        for (a, b) in full.nodes.iter().zip(fast.nodes.iter()) {
            assert_eq!(a.spec.name, b.spec.name);
            assert_eq!(a.spec.deps, b.spec.deps);
        }
    }

    #[test]
    fn policy_parse_roundtrip() {
        for p in [SloPolicy::None, SloPolicy::Shed, SloPolicy::Degrade, SloPolicy::StaleTracks] {
            assert_eq!(SloPolicy::parse(p.name()), Some(p));
        }
        assert_eq!(SloPolicy::parse("bogus"), None);
    }

    #[test]
    fn stale_tracks_prefers_full_when_safe() {
        let d = apply_stream(SloPolicy::StaleTracks, vec![req(0, 200.0)], 100.0, 50.0, 10.0, 20.0);
        assert!(!d.stale && !d.degraded);
        assert_eq!(d.dispatch.len(), 1);
    }

    #[test]
    fn stale_tracks_serves_stale_before_degrading() {
        // full misses (done 150 > 130), stale makes it (done 110)
        let d = apply_stream(
            SloPolicy::StaleTracks,
            vec![req(0, 130.0), req(1, 300.0)],
            100.0,
            50.0,
            10.0,
            20.0,
        );
        assert!(d.stale, "stale rung should save req 0 without degrading");
        assert!(!d.degraded);
        assert_eq!(d.dispatch.len(), 2);
        assert!(d.shed.is_empty());
    }

    #[test]
    fn stale_tracks_falls_through_to_fast_then_shed() {
        // stale done = 140 still misses req 0 (deadline 135); fast done = 120 saves it
        let d = apply_stream(
            SloPolicy::StaleTracks,
            vec![req(0, 135.0), req(1, 300.0)],
            100.0,
            50.0,
            40.0,
            20.0,
        );
        assert!(d.degraded && !d.stale);
        assert_eq!(d.dispatch.len(), 2);
        // and a deadline even fast cannot save is shed
        let d = apply_stream(
            SloPolicy::StaleTracks,
            vec![req(0, 110.0), req(1, 300.0)],
            100.0,
            50.0,
            40.0,
            20.0,
        );
        assert!(d.degraded);
        assert_eq!(d.shed.iter().map(|r| r.id).collect::<Vec<_>>(), vec![0]);
    }

    #[test]
    fn stale_rung_requires_a_real_saving() {
        // stale == full (cold batch): StaleTracks must behave exactly like Degrade
        let d = apply_stream(
            SloPolicy::StaleTracks,
            vec![req(0, 130.0), req(1, 300.0)],
            100.0,
            50.0,
            50.0,
            20.0,
        );
        assert!(d.degraded && !d.stale);
    }
}
