//! Paper Table 7: mAP@0.25/0.5 on both datasets, FP32 + INT8.
//!
//! Expected shape: fusion > VoteNet in FP32; under INT8, VoteNet and
//! PointPainting (layer-wise quantization) collapse while PointSplit
//! (role-based group-wise) stays near its FP32 accuracy — the paper's
//! up-to +30.6 mAP@0.25 margin.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(40);
    let sched = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let fp32: [(&str, Variant); 4] = [
        ("VoteNet", Variant::VoteNet),
        ("PointPainting", Variant::PointPainting),
        ("RandomSplit", Variant::RandomSplit),
        ("PointSplit", Variant::PointSplit),
    ];
    let int8: [(&str, Variant); 3] = [
        ("VoteNet", Variant::VoteNet),
        ("PointPainting", Variant::PointPainting),
        ("PointSplit", Variant::PointSplit),
    ];
    let mut t = Table::new(&["precision", "method", "synrgbd @0.25/@0.5", "synscan @0.25/@0.5"]);
    for (prec, list, is_int8) in
        [("FP32", fp32.as_slice(), false), ("INT8", int8.as_slice(), true)]
    {
        for (name, variant) in list {
            let mut cells = vec![prec.to_string(), name.to_string()];
            for ds in ["synrgbd", "synscan"] {
                let cfg = DetectorConfig::new(ds, *variant, is_int8, sched);
                let rep = common::eval_config(&rt, &cfg, scenes);
                cells.push(format!("{:.1} / {:.1}", rep.map_25 * 100.0, rep.map_50 * 100.0));
                eprintln!("  [{prec} {name} {ds}] mAP@0.25 {:.1}", rep.map_25 * 100.0);
            }
            t.row(cells);
        }
    }
    t.print(&format!(
        "Table 7 — mAP across datasets and precisions ({scenes} scenes each; paper: INT8 layer-wise collapses, role-based holds)"
    ));
}
