//! §Perf: wall-clock micro-benchmarks of the NN surrogate GEMM hot path.
//!
//! These numbers feed EXPERIMENTS.md §Perf and are persisted to
//! `BENCH_gemm.json` (section `perf_gemm`) so the naive → canonical-scalar
//! → tiled → tiled-parallel trajectory of every layer shape is diffable
//! across runs. Covered: the three dominant dense shapes of the detector
//! (backbone FP, vote, proposal head) in fp32 and int8, the weight-cache
//! cold/warm asymmetry, and the fused batched execution path against the
//! graph's priced k-scalability.
//!
//! Knobs:
//!   POINTSPLIT_BENCH_POINTS   GEMM row count          (default 4096, CI: 1024)
//!   POINTSPLIT_BENCH_SCENES   fused-batch iterations  (default 8, CI: 1)

mod common;

use pointsplit::bench::{bench_fn, f2, update_bench_json, BenchResult, Table};
use pointsplit::coordinator::{DetectorConfig, Schedule, Variant};
use pointsplit::graph::StageGraph;
use pointsplit::runtime::gemm;
use pointsplit::sim::DeviceKind;
use pointsplit::util::json::Json;
use pointsplit::util::rng::Rng;
use pointsplit::util::tensor::Tensor;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|s| s.parse().ok()).unwrap_or(default)
}

/// One layer shape's naive → scalar → tiled → parallel trajectory.
fn traj(naive: &BenchResult, scalar: &BenchResult, tiled: &BenchResult, par: &BenchResult) -> Json {
    Json::obj(vec![
        ("naive_ms", Json::Num(naive.mean_us / 1e3)),
        ("scalar_ms", Json::Num(scalar.mean_us / 1e3)),
        ("tiled_ms", Json::Num(tiled.mean_us / 1e3)),
        ("par_ms", Json::Num(par.mean_us / 1e3)),
        ("speedup_tiled", Json::Num(naive.mean_us / tiled.mean_us.max(1e-9))),
        ("speedup_par", Json::Num(naive.mean_us / par.mean_us.max(1e-9))),
    ])
}

fn main() {
    let rt = common::open_runtime();
    let n = env_usize("POINTSPLIT_BENCH_POINTS", 4096);
    let threads = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
    let m = &rt.manifest;

    // the three dominant dense shapes of the detector (manifest widths)
    let shapes: [(&str, usize, usize); 3] = [
        ("backbone_fp", m.fp_in, m.seed_feat),         // 384 -> 128
        ("vote", m.seed_feat, 3 + m.seed_feat),        // 128 -> 131
        ("prop", 3 + m.seed_feat, m.head_layout.sem_cls.1), // 131 -> 79
    ];

    println!("=== §Perf GEMM micro-benchmarks (n={n} rows, {threads} threads) ===\n");

    // --------------------------------------------------- weight cache
    // cold pack (generate + insert) vs warm hit (lock + Arc clone)
    gemm::clear_cache();
    let key = 0xA11CE;
    let cold = bench_fn("weight pack cold (384x128)", 0, 8, || {
        gemm::clear_cache();
        std::hint::black_box(gemm::packed(key, 384, 128));
    });
    cold.print();
    let warm = bench_fn("weight cache warm hit", 1, 64, || {
        std::hint::black_box(gemm::packed(key, 384, 128));
    });
    warm.print();
    let (hits, misses) = gemm::cache_stats();
    println!("cache stats: {hits} hits / {misses} misses, {} resident\n", gemm::cache_len());

    // --------------------------------------- fp32 kernel trajectories
    let mut rng = Rng::new(0x6E44);
    let mut fp_rows = Vec::new();
    let mut fp_wins = 0usize;
    let mut t = Table::new(&["layer", "naive ms", "scalar ms", "tiled ms", "par ms", "tiled speedup"]);
    for (name, cin, cout) in shapes {
        let lkey = gemm::packed(0x6E44 ^ cout as u64, cin, cout);
        let data: Vec<f32> = (0..n * cin).map(|_| rng.f32() * 2.0 - 1.0).collect();
        let mut out = vec![0.0f32; n * cout];
        let naive = bench_fn(&format!("{name} {cin}x{cout} fp32 naive"), 1, 10, || {
            std::hint::black_box(gemm::dense_fp32_naive(0x6E44 ^ cout as u64, cin, cout, &data));
        });
        naive.print();
        let scalar = bench_fn(&format!("{name} {cin}x{cout} fp32 scalar"), 1, 10, || {
            gemm::dense_fp32_scalar(&lkey, &data, &mut out);
            std::hint::black_box(&out);
        });
        scalar.print();
        let tiled = bench_fn(&format!("{name} {cin}x{cout} fp32 tiled x1"), 1, 10, || {
            gemm::dense_fp32(&lkey, &data, &mut out, 1);
            std::hint::black_box(&out);
        });
        tiled.print();
        let par = bench_fn(&format!("{name} {cin}x{cout} fp32 tiled x{threads}"), 1, 10, || {
            gemm::dense_fp32(&lkey, &data, &mut out, threads);
            std::hint::black_box(&out);
        });
        par.print();
        let speedup = naive.mean_us / tiled.mean_us.max(1e-9);
        if speedup >= 2.0 {
            fp_wins += 1;
        }
        t.row(vec![
            name.to_string(),
            f2(naive.mean_us / 1e3),
            f2(scalar.mean_us / 1e3),
            f2(tiled.mean_us / 1e3),
            f2(par.mean_us / 1e3),
            f2(speedup),
        ]);
        fp_rows.push((name, traj(&naive, &scalar, &tiled, &par)));
    }
    t.print("fp32 layer trajectory: pre-PR naive vs canonical scalar vs tiled lanes");
    println!(
        "\nacceptance: >= 2x tiled speedup (single thread, vs pre-PR naive) on >= 2 of 3 \
         shapes -> {}\n",
        if fp_wins >= 2 { "PASS" } else { "below (smoke settings or tiny row count)" }
    );

    // --------------------------------------- int8 kernel trajectory
    // one contiguous layer-granularity group: the common case the run
    // detector fast-paths; scattered role groups are covered by tests
    let (cin, cout) = (m.fp_in, m.seed_feat);
    let pw = gemm::packed(0x17E8, cin, cout);
    let qx: Vec<i8> = (0..n * cin).map(|_| (rng.below(255) as i64 - 127) as i8).collect();
    let groups = vec![(0..cin).collect::<Vec<usize>>()];
    let gscale = vec![0.05f32];
    let gzero = vec![3i64];
    let wsum: Vec<i64> = (0..cout)
        .map(|j| pw.wq[j * cin..(j + 1) * cin].iter().map(|&w| w as i64).sum())
        .collect();
    let ctx = gemm::Int8Ctx::new(&groups, &gscale, &gzero, &wsum);
    let mut qout = vec![0.0f32; n * cout];
    let i8_scalar = bench_fn(&format!("int8 {cin}x{cout} scalar (pre-PR i64)"), 1, 10, || {
        gemm::dense_int8_scalar(&pw, &ctx, &qx, &mut qout);
        std::hint::black_box(&qout);
    });
    i8_scalar.print();
    let i8_tiled = bench_fn(&format!("int8 {cin}x{cout} tiled x1"), 1, 10, || {
        gemm::dense_int8(&pw, &ctx, &qx, &mut qout, 1);
        std::hint::black_box(&qout);
    });
    i8_tiled.print();
    let i8_par = bench_fn(&format!("int8 {cin}x{cout} tiled x{threads}"), 1, 10, || {
        gemm::dense_int8(&pw, &ctx, &qx, &mut qout, threads);
        std::hint::black_box(&qout);
    });
    i8_par.print();
    let i8_speedup = i8_scalar.mean_us / i8_tiled.mean_us.max(1e-9);
    println!("int8 tiled speedup (single thread): {}\n", f2(i8_speedup));

    // ------------------------------------------- fused batched execution
    // one (k·n, cin) GEMM vs k sequential dispatches of the vote artifact,
    // against the stage graph's priced k-scalability (batch_fold on the
    // host device model)
    let iters = common::scene_budget(8);
    let seeds = Tensor::zeros(vec![m.num_seeds, m.seed_feat]);
    let art = "synrgbd_pointsplit_vote_fp32";
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let graph = StageGraph::build(m, &cfg, 2048, false).expect("graph");
    let base = bench_fn("fused k=1 (vote fp32)", 1, iters.max(4), || {
        std::hint::black_box(rt.run_batch_with_spec(art, &[&seeds], None, 1).unwrap());
    });
    base.print();
    let mut batch_rows = Vec::new();
    let mut within = 0usize;
    let mut fused_beats_seq = false;
    for k in [2usize, 4, 8] {
        let inputs: Vec<Tensor> = (0..k).map(|_| seeds.clone()).collect();
        let refs: Vec<&Tensor> = inputs.iter().collect();
        let seq = bench_fn(&format!("sequential x{k} (vote fp32)"), 1, iters.max(4), || {
            for x in &inputs {
                std::hint::black_box(rt.run_with_spec(art, &[x], None).unwrap());
            }
        });
        seq.print();
        let fused = bench_fn(&format!("fused batch k={k} (vote fp32)"), 1, iters.max(4), || {
            std::hint::black_box(rt.run_batch_with_spec(art, &refs, None, 1).unwrap());
        });
        fused.print();
        let measured = fused.mean_us / base.mean_us.max(1e-9);
        let priced = graph.priced_batch_scaling(k);
        let rel = (measured / priced - 1.0).abs();
        if rel <= 0.25 {
            within += 1;
        }
        if k == 8 {
            fused_beats_seq = fused.mean_us < seq.mean_us;
        }
        println!(
            "  k={k}: measured scaling {} vs priced {} (rel err {})",
            f2(measured),
            f2(priced),
            f2(rel)
        );
        batch_rows.push((
            format!("k{k}"),
            Json::obj(vec![
                ("seq_ms", Json::Num(seq.mean_us / 1e3)),
                ("fused_ms", Json::Num(fused.mean_us / 1e3)),
                ("measured_scaling", Json::Num(measured)),
                ("priced_scaling", Json::Num(priced)),
            ]),
        ));
    }
    println!(
        "\nacceptance: fused batch-of-8 beats 8 sequential -> {}; priced-vs-measured within \
         25% on {}/3 of k in {{2,4,8}}",
        if fused_beats_seq { "PASS" } else { "below (smoke settings)" },
        within
    );

    let (hits2, misses2) = gemm::cache_stats();
    let payload = Json::obj(vec![
        ("bench", Json::Str("perf_gemm".to_string())),
        ("n", Json::Num(n as f64)),
        ("threads", Json::Num(threads as f64)),
        ("cache_cold_pack_ms", Json::Num(cold.mean_us / 1e3)),
        ("cache_warm_hit_ms", Json::Num(warm.mean_us / 1e3)),
        ("cache_hits", Json::Num(hits2 as f64)),
        ("cache_misses", Json::Num(misses2 as f64)),
        ("fp32", Json::obj(fp_rows)),
        ("fp32_wins", Json::Num(fp_wins as f64)),
        ("fp32_pass", Json::Bool(fp_wins >= 2)),
        ("int8_speedup_tiled", Json::Num(i8_speedup)),
        (
            "fused",
            Json::obj(
                batch_rows.iter().map(|(k, v)| (k.as_str(), v.clone())).collect::<Vec<_>>(),
            ),
        ),
        ("fused_beats_sequential_k8", Json::Bool(fused_beats_seq)),
        ("fused_within_25pct", Json::Num(within as f64)),
    ]);
    update_bench_json("BENCH_gemm.json", "perf_gemm", payload);
}
