"""Cross-language parity fixtures.

For a subset of exported artifacts, runs the jax reference at deterministic
probe inputs and records output summaries in ``artifacts/fixtures.json``.
The Rust integration tests (and the Table 3 implementation-parity bench)
execute the same artifacts through PJRT with identical inputs and assert the
numbers match — the analog of the paper's "our TF implementation matches the
original PyTorch VoteNet" claim (Table 3).

Probe inputs use an index formula both sides implement independently:
``x[i] = sin(0.1 + 0.001 * i)`` over the flattened buffer, cast to f32.

Usage: ``cd python && python -m compile.fixtures --out-dir ../artifacts``
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np


def probe(shape) -> np.ndarray:
    n = int(np.prod(shape))
    idx = np.arange(n, dtype=np.float64)
    return np.sin(0.1 + 0.001 * idx).astype(np.float32).reshape(shape)


# artifact name suffixes to fixture (dataset-prefixed below)
TARGETS = [
    "seg_fp32",
    "pointsplit_sa1_half_fp32",
    "pointsplit_sa1_half_int8",
    "pointsplit_sa4_full_fp32",
    "pointsplit_fp_fc_fp32",
    "pointsplit_vote_fp32",
    "pointsplit_vote_int8_role",
    "pointsplit_vote_int8_layer",
    "pointsplit_prop_fp32",
    "pointsplit_prop_int8_role",
    "votenet_sa1_full_fp32",
    "painted_vote_fp32",
]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    args = ap.parse_args()

    from jax._src.lib import xla_client as xc

    manifest = json.load(open(os.path.join(args.out_dir, "manifest.json")))
    arts = {a["name"]: a for a in manifest["artifacts"]}
    fixtures = {}
    for ds in ("synrgbd", "synscan"):
        for suffix in TARGETS:
            name = f"{ds}_{suffix}"
            if name not in arts:
                continue
            meta = arts[name]
            inputs = [probe(i["shape"]) for i in meta["inputs"]]
            # execute the artifact's own HLO text via the python XLA client —
            # the exact program the rust runtime compiles
            with open(os.path.join(args.out_dir, meta["file"])) as f:
                hlo_text = f.read()
            comp = xc.XlaComputation(
                xc._xla.hlo_module_from_text(hlo_text).as_serialized_hlo_module_proto()
            )
            client = jax.devices()[0].client
            exe = client.compile(comp)
            outs = exe.execute([client.buffer_from_pyval(x) for x in inputs])
            out = np.asarray(outs[0])
            fixtures[name] = {
                "output_shape": list(out.shape),
                "mean": float(out.mean()),
                "std": float(out.std()),
                "first": [float(v) for v in out.flatten()[:12]],
                "l1": float(np.abs(out).mean()),
            }
            print(f"fixture {name}: shape {out.shape} mean {out.mean():.5f}")
    with open(os.path.join(args.out_dir, "fixtures.json"), "w") as f:
        json.dump(fixtures, f, indent=1)
    print(f"wrote {len(fixtures)} fixtures")


if __name__ == "__main__":
    main()
