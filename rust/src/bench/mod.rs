//! Micro-benchmark harness + table printer (criterion is not vendored).
//!
//! `bench_fn` runs warmup + timed iterations and reports mean/p50/p99.
//! `Table` prints paper-style rows used by every `rust/benches/*` target.
//! `write_bench_json` persists machine-readable `BENCH_*.json` payloads so
//! bench trajectories survive re-anchors and regressions are diffable.

use std::path::PathBuf;
use std::time::Instant;

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean_us: f64,
    pub p50_us: f64,
    pub p99_us: f64,
}

/// Time `f` for `iters` iterations after `warmup` runs.
pub fn bench_fn<F: FnMut()>(name: &str, warmup: usize, iters: usize, mut f: F) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed().as_secs_f64() * 1e6);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = samples.len();
    BenchResult {
        name: name.to_string(),
        iters,
        mean_us: samples.iter().sum::<f64>() / n as f64,
        p50_us: samples[n / 2],
        p99_us: samples[(n * 99 / 100).min(n - 1)],
    }
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:40} {:>10.1} us/iter  (p50 {:>9.1}, p99 {:>9.1}, n={})",
            self.name, self.mean_us, self.p50_us, self.p99_us, self.iters
        );
    }
}

/// Fixed-width table printer for paper-style result tables.
pub struct Table {
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(headers: &[&str]) -> Table {
        Table { headers: headers.iter().map(|s| s.to_string()).collect(), rows: Vec::new() }
    }

    pub fn row(&mut self, cells: Vec<String>) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells);
    }

    pub fn print(&self, title: &str) {
        println!("\n=== {title} ===");
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let line = |cells: &[String]| {
            let mut s = String::new();
            for (i, c) in cells.iter().enumerate() {
                if i == 0 {
                    s.push_str(&format!("{:<w$}", c, w = widths[i]));
                } else {
                    s.push_str(&format!("  {:>w$}", c, w = widths[i]));
                }
            }
            s
        };
        println!("{}", line(&self.headers));
        println!("{}", "-".repeat(widths.iter().sum::<usize>() + 2 * (widths.len() - 1)));
        for r in &self.rows {
            println!("{}", line(r));
        }
    }
}

/// Write a machine-readable bench payload to `file` (e.g.
/// `BENCH_serving.json`) in `POINTSPLIT_BENCH_DIR` (default: the current
/// directory). Serialization failures are warned about, never fatal — a
/// bench must still print its tables on a read-only checkout.
pub fn write_bench_json(file: &str, payload: &Json) -> Option<PathBuf> {
    let dir = std::env::var("POINTSPLIT_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let path = PathBuf::from(dir).join(file);
    match std::fs::write(&path, format!("{payload}\n")) {
        Ok(()) => {
            println!("bench JSON written to {}", path.display());
            Some(path)
        }
        Err(e) => {
            eprintln!("warning: could not write {}: {e}", path.display());
            None
        }
    }
}

/// Merge `payload` under `section` in the top-level object parsed from
/// `existing` (unparseable or non-object contents are replaced wholesale).
fn merge_section(existing: Option<&str>, section: &str, payload: Json) -> Json {
    let mut map = existing
        .and_then(|text| Json::parse(text).ok())
        .and_then(|j| match j {
            Json::Obj(m) => Some(m),
            _ => None,
        })
        .unwrap_or_default();
    map.insert(section.to_string(), payload);
    Json::Obj(map)
}

/// Read-modify-write one `section` of `file`'s top-level JSON object
/// (creating the file if absent). Lets several bench binaries share one
/// `BENCH_*.json` — e.g. `perf_hotpath` and `pointops_parallel` both record
/// their kernel trajectories into `BENCH_hotpath.json`.
pub fn update_bench_json(file: &str, section: &str, payload: Json) -> Option<PathBuf> {
    let dir = std::env::var("POINTSPLIT_BENCH_DIR").unwrap_or_else(|_| ".".to_string());
    let path = PathBuf::from(dir).join(file);
    let existing = std::fs::read_to_string(&path).ok();
    write_bench_json(file, &merge_section(existing.as_deref(), section, payload))
}

/// `f(x)` formatted with fixed decimals, convenience for table cells.
pub fn f1(x: f64) -> String {
    format!("{x:.1}")
}

pub fn f2(x: f64) -> String {
    format!("{x:.2}")
}

/// mAP values are conventionally reported x100.
pub fn pct(x: f64) -> String {
    format!("{:.1}", x * 100.0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_produces_ordered_percentiles() {
        let r = bench_fn("spin", 2, 20, || {
            std::hint::black_box((0..1000).sum::<u64>());
        });
        assert!(r.p50_us <= r.p99_us);
        assert!(r.mean_us > 0.0);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new(&["a", "b"]);
        t.row(vec!["x".into()]);
    }

    #[test]
    fn merge_section_preserves_other_sections() {
        let first = merge_section(None, "a", Json::Num(1.0));
        let text = format!("{first}");
        let both = merge_section(Some(&text), "b", Json::Num(2.0));
        assert_eq!(both.req("a").as_f64(), Some(1.0));
        assert_eq!(both.req("b").as_f64(), Some(2.0));
        // same-key update replaces, garbage input is replaced wholesale
        let upd = merge_section(Some(&format!("{both}")), "a", Json::Num(3.0));
        assert_eq!(upd.req("a").as_f64(), Some(3.0));
        let fresh = merge_section(Some("not json"), "x", Json::Bool(true));
        assert_eq!(fresh.req("x").as_bool(), Some(true));
    }
}
