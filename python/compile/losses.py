"""VoteNet losses (per-scene, jax) for the mini detector.

Follows the original VoteNet loss decomposition: vote regression, objectness,
center (both-direction chamfer), heading bin cls+reg, size cls+reg, semantic
classification. GT comes padded to MAX_OBJ boxes with a validity mask.
"""

from __future__ import annotations

from typing import Dict

import jax
import jax.numpy as jnp

from . import common
from .common import NUM_CLASS, NUM_HEADING_BIN

MAX_OBJ = 14
NEAR_THRESH = 0.3
FAR_THRESH = 0.6

# loss weights (VoteNet defaults, box-loss style)
W_VOTE = 1.0
W_OBJ = 0.5
W_CENTER = 1.0
W_HEAD_CLS = 0.1
W_HEAD_REG = 1.0
W_SIZE_CLS = 0.1
W_SIZE_REG = 1.0
W_SEM = 0.1


def huber(x, delta: float = 1.0):
    a = jnp.abs(x)
    return jnp.where(a < delta, 0.5 * a * a, delta * (a - 0.5 * delta))


def _point_in_box(points, centers, sizes, headings, slack: float = 0.1):
    """points (N,3) vs boxes (K,...) -> inside (N,K) bool."""
    d = points[:, None, :] - centers[None, :, :]  # (N,K,3)
    c, s = jnp.cos(-headings), jnp.sin(-headings)
    lx = d[..., 0] * c[None, :] - d[..., 1] * s[None, :]
    ly = d[..., 0] * s[None, :] + d[..., 1] * c[None, :]
    return (
        (jnp.abs(lx) < sizes[None, :, 0] / 2 + slack)
        & (jnp.abs(ly) < sizes[None, :, 1] / 2 + slack)
        & (jnp.abs(d[..., 2]) < sizes[None, :, 2] / 2 + slack)
    )


def scene_loss(end_points: Dict, gt: Dict, mean_sizes: jnp.ndarray) -> Dict[str, jnp.ndarray]:
    """Per-scene loss. gt: centers (K,3), sizes (K,3), headings (K,),
    classes (K,) int32, mask (K,) float. Returns dict with 'total' + parts."""
    centers, sizes = gt["centers"], gt["sizes"]
    headings, classes, mask = gt["headings"], gt["classes"], gt["mask"]
    big = jnp.float32(1e6)

    # --- vote loss: seeds inside a GT box must vote for its center
    seed_xyz = end_points["seed_xyz"]
    vote_xyz = end_points["vote_xyz"]
    inside = _point_in_box(seed_xyz, centers, sizes, headings) & (mask[None, :] > 0.5)
    d2_seed = jnp.sum((seed_xyz[:, None, :] - centers[None, :, :]) ** 2, -1)
    d2_seed = jnp.where(inside, d2_seed, big)
    owner = jnp.argmin(d2_seed, axis=1)
    has_owner = jnp.any(inside, axis=1).astype(jnp.float32)
    target = centers[owner]
    vote_loss = jnp.sum(
        huber(vote_xyz - target).sum(-1) * has_owner
    ) / jnp.maximum(jnp.sum(has_owner), 1.0)

    # --- objectness: proposals near a GT center are positive
    cl_xyz = end_points["cluster_xyz"]
    d2 = jnp.sum((cl_xyz[:, None, :] - centers[None, :, :]) ** 2, -1)
    d2 = jnp.where(mask[None, :] > 0.5, d2, big)
    nearest = jnp.argmin(d2, axis=1)
    ndist = jnp.sqrt(jnp.min(d2, axis=1))
    pos = (ndist < NEAR_THRESH).astype(jnp.float32)
    neg = (ndist > FAR_THRESH).astype(jnp.float32)
    prop = end_points["proposal"]
    obj_logits = prop[:, slice(*common.SLICE_OBJECTNESS)]
    logp = jax.nn.log_softmax(obj_logits, axis=-1)
    obj_loss = -(pos * logp[:, 1] + neg * logp[:, 0])
    obj_loss = jnp.sum(obj_loss) / jnp.maximum(jnp.sum(pos + neg), 1.0)

    npos = jnp.maximum(jnp.sum(pos), 1.0)

    # --- center: predicted centers of positives -> their GT, and every GT ->
    # nearest prediction (coverage term)
    pred_center = cl_xyz + prop[:, slice(*common.SLICE_CENTER)]
    tgt_center = centers[nearest]
    center_loss = jnp.sum(huber(pred_center - tgt_center).sum(-1) * pos) / npos
    d2_cov = jnp.sum((centers[:, None, :] - pred_center[None, :, :]) ** 2, -1)
    cov = jnp.sqrt(jnp.min(d2_cov, axis=1) + 1e-8)
    center_loss = center_loss + jnp.sum(huber(cov) * mask) / jnp.maximum(jnp.sum(mask), 1.0)

    # --- heading
    gt_heading = headings[nearest] % (2 * jnp.pi)
    per = 2 * jnp.pi / NUM_HEADING_BIN
    hbin = jnp.floor(gt_heading / per).astype(jnp.int32) % NUM_HEADING_BIN
    hres = (gt_heading - (hbin * per + per / 2)) / (per / 2)  # in [-1, 1]
    h_logits = prop[:, slice(*common.SLICE_HEADING_CLS)]
    h_logp = jax.nn.log_softmax(h_logits, axis=-1)
    head_cls_loss = jnp.sum(-jnp.take_along_axis(h_logp, hbin[:, None], 1)[:, 0] * pos) / npos
    h_reg = prop[:, slice(*common.SLICE_HEADING_REG)]
    h_reg_sel = jnp.take_along_axis(h_reg, hbin[:, None], 1)[:, 0]
    head_reg_loss = jnp.sum(huber(h_reg_sel - hres) * pos) / npos

    # --- size (class-anchored, VoteNet style)
    gt_cls = classes[nearest]
    s_logits = prop[:, slice(*common.SLICE_SIZE_CLS)]
    s_logp = jax.nn.log_softmax(s_logits, axis=-1)
    size_cls_loss = jnp.sum(-jnp.take_along_axis(s_logp, gt_cls[:, None], 1)[:, 0] * pos) / npos
    s_reg = prop[:, slice(*common.SLICE_SIZE_REG)].reshape(-1, NUM_CLASS, 3)
    s_reg_sel = jnp.take_along_axis(s_reg, gt_cls[:, None, None].repeat(3, -1), 1)[:, 0]
    tgt_res = sizes[nearest] / mean_sizes[gt_cls] - 1.0
    size_reg_loss = jnp.sum(huber(s_reg_sel - tgt_res).sum(-1) * pos) / npos

    # --- semantic class
    sem_logits = prop[:, slice(*common.SLICE_SEM_CLS)]
    sem_logp = jax.nn.log_softmax(sem_logits, axis=-1)
    sem_loss = jnp.sum(-jnp.take_along_axis(sem_logp, gt_cls[:, None], 1)[:, 0] * pos) / npos

    total = (
        W_VOTE * vote_loss
        + W_OBJ * obj_loss
        + W_CENTER * center_loss
        + W_HEAD_CLS * head_cls_loss
        + W_HEAD_REG * head_reg_loss
        + W_SIZE_CLS * size_cls_loss
        + W_SIZE_REG * size_reg_loss
        + W_SEM * sem_loss
    )
    return {
        "total": total,
        "vote": vote_loss,
        "objectness": obj_loss,
        "center": center_loss,
        "heading_cls": head_cls_loss,
        "heading_reg": head_reg_loss,
        "size_cls": size_cls_loss,
        "size_reg": size_reg_loss,
        "sem": sem_loss,
    }


def seg_loss(logits: jnp.ndarray, mask: jnp.ndarray) -> jnp.ndarray:
    """Pixel cross-entropy with 3x weight on foreground pixels (the class
    imbalance trick standing in for the paper's oversampling of rare classes)."""
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, mask[..., None], axis=-1)[..., 0]
    w = jnp.where(mask > 0, 3.0, 1.0)
    return -jnp.sum(ll * w) / jnp.sum(w)
