//! Typed view of `artifacts/manifest.json` (written by python/compile/aot.py).
//!
//! The manifest is the single contract between the build-time Python stack
//! and the Rust request path: artifact shapes + workload descriptors for the
//! device simulator, plus every model constant the coordinator needs
//! (SA configs, head layout, role groups, dataset parameters).

use std::collections::HashMap;

use anyhow::{anyhow, Result};

use crate::util::json::Json;

#[derive(Debug, Clone)]
pub struct ArtifactMeta {
    pub name: String,
    pub file: String,
    pub dataset: String,
    pub model: String,
    pub net: String,
    pub precision: String,
    pub input_shapes: Vec<Vec<usize>>,
    pub flops: u64,
    pub bytes_in: u64,
    /// bytes per element on the interconnect (1 for int8 executables)
    pub wire_bytes_per_elem: u64,
}

#[derive(Debug, Clone)]
pub struct SaConfig {
    pub m: usize,
    pub radius: f32,
    pub k: usize,
    pub mlp: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct DatasetMeta {
    pub num_points: usize,
    pub room_min: f64,
    pub room_max: f64,
    pub min_objects: usize,
    pub max_objects: usize,
    pub single_view: bool,
    pub depth_noise: f64,
    pub seg_noise: f64,
}

#[derive(Debug, Clone, Copy)]
pub struct HeadLayout {
    pub center: (usize, usize),
    pub objectness: (usize, usize),
    pub heading_cls: (usize, usize),
    pub heading_reg: (usize, usize),
    pub size_cls: (usize, usize),
    pub size_reg: (usize, usize),
    pub sem_cls: (usize, usize),
}

#[derive(Debug, Clone)]
pub struct Manifest {
    pub classes: Vec<String>,
    pub mean_sizes: Vec<[f32; 3]>,
    pub num_heading_bin: usize,
    pub num_seg_classes: usize,
    pub img_size: usize,
    pub sa_configs: Vec<SaConfig>,
    pub num_seeds: usize,
    pub num_proposals: usize,
    pub proposal_radius: f32,
    pub proposal_k: usize,
    pub seed_feat: usize,
    pub fp_in: usize,
    pub feat_dim_painted: usize,
    pub feat_dim_plain: usize,
    pub head_layout: HeadLayout,
    pub role_groups_vote: Vec<Vec<usize>>,
    pub role_groups_prop: Vec<Vec<usize>>,
    pub quant_param_count: HashMap<String, usize>,
    /// (params, madds) for orig / pointsplit FP stage at mini & paper scale
    pub fp_layer_cost_mini: ((u64, u64), (u64, u64)),
    pub fp_layer_cost_paper: ((u64, u64), (u64, u64)),
    pub datasets: HashMap<String, DatasetMeta>,
    pub default_w0: f32,
    pub default_bias_layers: usize,
    pub artifacts: Vec<ArtifactMeta>,
    by_name: HashMap<String, usize>,
}

fn pair(j: &Json) -> (usize, usize) {
    let v = j.usize_vec();
    (v[0], v[1])
}

fn cost_pair(j: &Json) -> ((u64, u64), (u64, u64)) {
    let o = j.req("orig").f64_vec();
    let p = j.req("pointsplit").f64_vec();
    ((o[0] as u64, o[1] as u64), (p[0] as u64, p[1] as u64))
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow!("manifest: {e}"))?;
        let classes = j
            .req("classes")
            .as_arr()
            .unwrap()
            .iter()
            .map(|c| c.as_str().unwrap().to_string())
            .collect();
        let mean_sizes = j
            .req("mean_sizes")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| {
                let v = s.f64_vec();
                [v[0] as f32, v[1] as f32, v[2] as f32]
            })
            .collect();
        let sa_configs = j
            .req("sa_configs")
            .as_arr()
            .unwrap()
            .iter()
            .map(|s| SaConfig {
                m: s.req("m").as_usize().unwrap(),
                radius: s.req("radius").as_f64().unwrap() as f32,
                k: s.req("k").as_usize().unwrap(),
                mlp: s.req("mlp").usize_vec(),
            })
            .collect();
        let hl = j.req("head_layout");
        let head_layout = HeadLayout {
            center: pair(hl.req("center")),
            objectness: pair(hl.req("objectness")),
            heading_cls: pair(hl.req("heading_cls")),
            heading_reg: pair(hl.req("heading_reg")),
            size_cls: pair(hl.req("size_cls")),
            size_reg: pair(hl.req("size_reg")),
            sem_cls: pair(hl.req("sem_cls")),
        };
        let rg = j.req("role_groups");
        let groups = |key: &str| -> Vec<Vec<usize>> {
            rg.req(key).as_arr().unwrap().iter().map(|g| g.usize_vec()).collect()
        };
        let quant_param_count = j
            .req("quant_param_count")
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, v)| (k.clone(), v.as_usize().unwrap()))
            .collect();
        let datasets = j
            .req("datasets")
            .as_obj()
            .unwrap()
            .iter()
            .map(|(k, v)| {
                (
                    k.clone(),
                    DatasetMeta {
                        num_points: v.req("num_points").as_usize().unwrap(),
                        room_min: v.req("room_min").as_f64().unwrap(),
                        room_max: v.req("room_max").as_f64().unwrap(),
                        min_objects: v.req("min_objects").as_usize().unwrap(),
                        max_objects: v.req("max_objects").as_usize().unwrap(),
                        single_view: v.req("single_view").as_bool().unwrap(),
                        depth_noise: v.req("depth_noise").as_f64().unwrap(),
                        seg_noise: v.req("seg_noise").as_f64().unwrap(),
                    },
                )
            })
            .collect();
        let artifacts: Vec<ArtifactMeta> = j
            .req("artifacts")
            .as_arr()
            .unwrap()
            .iter()
            .map(|a| ArtifactMeta {
                name: a.req("name").as_str().unwrap().to_string(),
                file: a.req("file").as_str().unwrap().to_string(),
                dataset: a.req("dataset").as_str().unwrap().to_string(),
                model: a.req("model").as_str().unwrap().to_string(),
                net: a.req("net").as_str().unwrap().to_string(),
                precision: a.req("precision").as_str().unwrap().to_string(),
                input_shapes: a
                    .req("inputs")
                    .as_arr()
                    .unwrap()
                    .iter()
                    .map(|i| i.req("shape").usize_vec())
                    .collect(),
                flops: a.req("flops").as_f64().unwrap() as u64,
                bytes_in: a.req("bytes_in").as_f64().unwrap() as u64,
                wire_bytes_per_elem: a.req("wire_bytes_per_elem").as_f64().unwrap() as u64,
            })
            .collect();
        let by_name = artifacts.iter().enumerate().map(|(i, a)| (a.name.clone(), i)).collect();
        let fpc = j.req("fp_layer_cost");
        Ok(Manifest {
            classes,
            mean_sizes,
            num_heading_bin: j.req("num_heading_bin").as_usize().unwrap(),
            num_seg_classes: j.req("num_seg_classes").as_usize().unwrap(),
            img_size: j.req("img_size").as_usize().unwrap(),
            sa_configs,
            num_seeds: j.req("num_seeds").as_usize().unwrap(),
            num_proposals: j.req("num_proposals").as_usize().unwrap(),
            proposal_radius: j.req("proposal_radius").as_f64().unwrap() as f32,
            proposal_k: j.req("proposal_k").as_usize().unwrap(),
            seed_feat: j.req("seed_feat").as_usize().unwrap(),
            fp_in: j.req("fp_in").as_usize().unwrap(),
            feat_dim_painted: j.req("feat_dim_painted").as_usize().unwrap(),
            feat_dim_plain: j.req("feat_dim_plain").as_usize().unwrap(),
            head_layout,
            role_groups_vote: groups("vote"),
            role_groups_prop: groups("prop"),
            quant_param_count,
            fp_layer_cost_mini: cost_pair(fpc.req("mini")),
            fp_layer_cost_paper: cost_pair(fpc.req("paper_scale")),
            datasets,
            default_w0: j.req("default_w0").as_f64().unwrap() as f32,
            default_bias_layers: j.req("default_bias_layers").as_usize().unwrap(),
            artifacts,
            by_name,
        })
    }

    pub fn artifact(&self, name: &str) -> Option<&ArtifactMeta> {
        self.by_name.get(name).map(|&i| &self.artifacts[i])
    }

    /// Resolve an artifact by (dataset, model, net, precision).
    pub fn find(&self, dataset: &str, model: &str, net: &str, precision: &str) -> Option<&ArtifactMeta> {
        self.artifact(&format!("{dataset}_{model}_{net}_{precision}"))
    }

    pub fn num_class(&self) -> usize {
        self.classes.len()
    }
}
