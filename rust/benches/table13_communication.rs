//! Paper Table 13: communication vs computation split per processor when
//! PointSplit processes one scene (sequential SA pipelines, no segmenter —
//! matching the paper's measurement protocol).
//!
//! Expected shape: EdgeTPU communication dominates its computation (PCIe
//! Gen2 x1 per-transfer setup), making comm >50% of total — the paper's
//! argument that better interconnects nearly double PointSplit's speed.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scene = generate_scene(17, &SYNRGBD);
    // sequential (no multithreading), as in the paper's Table 13 protocol
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let out = ScenePipeline::new(&rt, cfg).run(&scene, 17).expect("pipeline");
    let tl = &out.timeline;
    // exclude the segmenter stage, as the paper does
    let seg_ms = tl.stage("seg").map(|s| s.end_ms - s.compute_start_ms).unwrap_or(0.0);
    let mut t = Table::new(&["processor", "comm (ms)", "comp (ms)", "total", "paper"]);
    for (kind, paper) in [(DeviceKind::Gpu, "80 / 248 / 328"), (DeviceKind::EdgeTpu, "360 / 121 / 481")] {
        let comm = tl.comm_ms.get(&kind).copied().unwrap_or(0.0);
        let mut comp = tl.busy_ms.get(&kind).copied().unwrap_or(0.0);
        if kind == DeviceKind::EdgeTpu {
            comp -= seg_ms;
        }
        t.row(vec![
            kind.name().into(),
            format!("{comm:.0}"),
            format!("{comp:.0}"),
            format!("{:.0}", comm + comp),
            paper.into(),
        ]);
    }
    t.print("Table 13 — communication vs computation, PointSplit single scene (simulated)");
    let comm_total: f64 = tl.comm_ms.values().sum();
    let comp_total: f64 = tl.busy_ms.values().sum::<f64>() - seg_ms;
    println!(
        "\ncommunication share: {:.1}% (paper: 54.4%)",
        100.0 * comm_total / (comm_total + comp_total)
    );
}
