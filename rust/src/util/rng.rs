//! Deterministic PRNG substrate (the `rand` crate is not vendored).
//!
//! SplitMix64 core with helpers for the distributions the scene generator
//! and property tests need: uniforms, normals (Box–Muller), integer ranges,
//! choice without replacement, and multinomial draws.

#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    /// cached second normal from Box–Muller
    spare: Option<f64>,
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        Rng { state: seed.wrapping_add(0x9E3779B97F4A7C15), spare: None }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    #[inline]
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Uniform in [lo, hi).
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        (self.next_u64() % n as u64) as usize
    }

    /// Uniform integer in [lo, hi] inclusive.
    pub fn int_range(&mut self, lo: i64, hi: i64) -> i64 {
        lo + (self.next_u64() % (hi - lo + 1) as u64) as i64
    }

    /// Standard normal.
    pub fn normal(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        loop {
            let u = 2.0 * self.f64() - 1.0;
            let v = 2.0 * self.f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                self.spare = Some(v * f);
                return u * f;
            }
        }
    }

    pub fn normal_scaled(&mut self, mu: f64, sigma: f64) -> f64 {
        mu + sigma * self.normal()
    }

    /// k distinct indices from [0, n) (partial Fisher–Yates).
    pub fn choice_no_replace(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }

    /// k indices from [0, n) with replacement.
    pub fn choice_replace(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.below(n)).collect()
    }

    /// Multinomial: distribute n draws over weights (need not be normalized).
    pub fn multinomial(&mut self, n: usize, weights: &[f64]) -> Vec<usize> {
        let total: f64 = weights.iter().sum();
        let mut counts = vec![0usize; weights.len()];
        if total <= 0.0 {
            return counts;
        }
        // cumulative inverse sampling
        let cum: Vec<f64> = weights
            .iter()
            .scan(0.0, |acc, w| {
                *acc += w / total;
                Some(*acc)
            })
            .collect();
        for _ in 0..n {
            let u = self.f64();
            let j = cum.partition_point(|c| *c < u).min(weights.len() - 1);
            counts[j] += 1;
        }
        counts
    }

    /// Weighted index draw.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut u = self.f64() * total;
        for (i, w) in weights.iter().enumerate() {
            if u < *w {
                return i;
            }
            u -= w;
        }
        weights.len() - 1
    }

    /// Fork a statistically independent stream (for parallel workers).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic() {
        let mut a = Rng::new(42);
        let mut b = Rng::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn uniform_bounds() {
        let mut r = Rng::new(1);
        for _ in 0..10_000 {
            let x = r.uniform(2.0, 3.0);
            assert!((2.0..3.0).contains(&x));
        }
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(7);
        let n = 50_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn choice_no_replace_distinct() {
        let mut r = Rng::new(3);
        let c = r.choice_no_replace(100, 50);
        let mut s = c.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 50);
        assert!(c.iter().all(|&i| i < 100));
    }

    #[test]
    fn multinomial_total() {
        let mut r = Rng::new(9);
        let c = r.multinomial(1000, &[1.0, 2.0, 7.0]);
        assert_eq!(c.iter().sum::<usize>(), 1000);
        // heaviest bucket should dominate
        assert!(c[2] > c[0] && c[2] > c[1]);
    }
}
