//! PointPainting: project 3D points into the 2D segmentation output and
//! append per-pixel class scores to each point (mirror of scene.paint_points).

use crate::data::Scene;
use crate::util::tensor::Tensor;

/// seg_scores: (H, W, C) softmax scores from the segmenter artifact.
/// Returns (N, C) painted scores; out-of-view points get one-hot background.
pub fn paint_points(scene: &Scene, seg_scores: &Tensor) -> Tensor {
    let (h, w, c) = (seg_scores.shape[0], seg_scores.shape[1], seg_scores.shape[2]);
    let mut out = Vec::with_capacity(scene.points.len() * c);
    for p in &scene.points {
        let (u, v, z) = scene.project(*p);
        let inside = u >= 0.0 && u < w as f64 && v >= 0.0 && v < h as f64 && z > 0.0;
        if inside {
            let ui = (u.floor() as usize).min(w - 1);
            let vi = (v.floor() as usize).min(h - 1);
            let base = (vi * w + ui) * c;
            out.extend_from_slice(&seg_scores.data[base..base + c]);
        } else {
            out.push(1.0);
            out.extend(std::iter::repeat(0.0).take(c - 1));
        }
    }
    Tensor::new(vec![scene.points.len(), c], out)
}

/// PARTIAL-frame painting for the temporal reuse path: recompute the
/// projection only for `dirty` points (those whose grid-occupancy cell
/// changed since the cached frame) and copy the remaining rows from the
/// previous frame's painted scores. With an all-true mask this is exactly
/// [`paint_points`]; with an all-false mask it is a row copy of `prev`.
pub fn paint_points_partial(
    scene: &Scene,
    seg_scores: &Tensor,
    prev: &Tensor,
    dirty: &[bool],
) -> Tensor {
    let (h, w, c) = (seg_scores.shape[0], seg_scores.shape[1], seg_scores.shape[2]);
    debug_assert_eq!(prev.rows(), scene.points.len());
    debug_assert_eq!(prev.row_len(), c);
    debug_assert_eq!(dirty.len(), scene.points.len());
    let mut out = Vec::with_capacity(scene.points.len() * c);
    for (i, p) in scene.points.iter().enumerate() {
        if !dirty.get(i).copied().unwrap_or(true) {
            out.extend_from_slice(prev.row(i));
            continue;
        }
        let (u, v, z) = scene.project(*p);
        let inside = u >= 0.0 && u < w as f64 && v >= 0.0 && v < h as f64 && z > 0.0;
        if inside {
            let ui = (u.floor() as usize).min(w - 1);
            let vi = (v.floor() as usize).min(h - 1);
            let base = (vi * w + ui) * c;
            out.extend_from_slice(&seg_scores.data[base..base + c]);
        } else {
            out.push(1.0);
            out.extend(std::iter::repeat(0.0).take(c - 1));
        }
    }
    Tensor::new(vec![scene.points.len(), c], out)
}

/// Foreground mask from painted scores: P(not background) > thresh.
pub fn fg_mask(scores: &Tensor, thresh: f32) -> Vec<f32> {
    (0..scores.rows())
        .map(|i| if 1.0 - scores.row(i)[0] > thresh { 1.0 } else { 0.0 })
        .collect()
}

/// Build the detector input features: height ++ (optionally) painted scores.
pub fn build_features(scene: &Scene, painted: Option<&Tensor>) -> Tensor {
    let n = scene.points.len();
    let c = 1 + painted.map_or(0, |p| p.row_len());
    let mut data = Vec::with_capacity(n * c);
    for (i, p) in scene.points.iter().enumerate() {
        data.push(p[2]); // height above floor (z=0)
        if let Some(paint) = painted {
            data.extend_from_slice(paint.row(i));
        }
    }
    Tensor::new(vec![n, c], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_scene, IMG_SIZE, SYNRGBD};

    fn gt_scores(scene: &Scene) -> Tensor {
        // one-hot scores straight from the GT mask (an oracle segmenter)
        let c = crate::data::NUM_CLASS + 1;
        let mut data = vec![0.0f32; IMG_SIZE * IMG_SIZE * c];
        for (i, &m) in scene.seg_mask.iter().enumerate() {
            data[i * c + m as usize] = 1.0;
        }
        Tensor::new(vec![IMG_SIZE, IMG_SIZE, c], data)
    }

    #[test]
    fn painted_scores_are_distributions() {
        let s = generate_scene(1, &SYNRGBD);
        let paint = paint_points(&s, &gt_scores(&s));
        assert_eq!(paint.shape, vec![s.points.len(), crate::data::NUM_CLASS + 1]);
        for i in 0..paint.rows() {
            let sum: f32 = paint.row(i).iter().sum();
            assert!((sum - 1.0).abs() < 1e-4);
        }
    }

    #[test]
    fn oracle_paint_marks_object_points_foreground() {
        let s = generate_scene(2, &SYNRGBD);
        let paint = paint_points(&s, &gt_scores(&s));
        let fg = fg_mask(&paint, 0.5);
        // most object points should paint as foreground with an oracle mask
        let mut hit = 0;
        let mut tot = 0;
        for (i, &oi) in s.point_obj.iter().enumerate() {
            if oi >= 0 {
                tot += 1;
                if fg[i] > 0.5 {
                    hit += 1;
                }
            }
        }
        assert!(tot > 0);
        assert!(
            hit as f32 / tot as f32 > 0.5,
            "oracle painting should label most object points fg ({hit}/{tot})"
        );
    }

    #[test]
    fn partial_paint_matches_full_on_all_dirty_and_copies_on_clean() {
        let s = generate_scene(4, &SYNRGBD);
        let scores = gt_scores(&s);
        let full = paint_points(&s, &scores);
        let n = s.points.len();
        let all_dirty = paint_points_partial(&s, &scores, &full, &vec![true; n]);
        assert_eq!(all_dirty.data, full.data, "all-dirty partial must equal full paint");
        // with a stale prev and an all-clean mask, rows come from prev
        let stale = Tensor::new(vec![n, full.row_len()], vec![0.25; n * full.row_len()]);
        let clean = paint_points_partial(&s, &scores, &stale, &vec![false; n]);
        assert_eq!(clean.data, stale.data);
        // mixed: dirty rows recomputed, clean rows from prev
        let mut mask = vec![false; n];
        mask[0] = true;
        let mixed = paint_points_partial(&s, &scores, &stale, &mask);
        assert_eq!(mixed.row(0), full.row(0));
        assert_eq!(mixed.row(1), stale.row(1));
    }

    #[test]
    fn features_have_height_first() {
        let s = generate_scene(3, &SYNRGBD);
        let f = build_features(&s, None);
        assert_eq!(f.shape, vec![s.points.len(), 1]);
        assert!((f.row(0)[0] - s.points[0][2]).abs() < 1e-6);
    }
}
