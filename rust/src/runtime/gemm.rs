//! Tiled SIMD GEMM + pre-packed weight cache for the surrogate hot path.
//!
//! The surrogate's dense layers used to re-derive every weight from the
//! hash generator on every call and run naive triple loops — a sequential
//! f32 dependency chain the compiler cannot vectorize, plus per-element
//! `i64` widening on the int8 path. This module is the real GEMM layer
//! underneath (`runtime::surrogate` only prepares activations and
//! dispatches here):
//!
//! - **Weight cache** — a process-wide map from the logical key
//!   `(weight key, cin, cout, precision)` to [`PackedWeights`]: the fp32
//!   matrix pre-packed tile-transposed for the lane kernel, the symmetric
//!   per-output-row `i8` quantization (codes + scales), and the bias.
//!   Because precision variants of an artifact execute the *same* weights,
//!   the precision component of the key collapses — one entry holds both
//!   packings and serves every variant, so the map is keyed by
//!   `(key, cin, cout)` and a scheme swap (the serving degrade path) never
//!   re-generates or re-quantizes anything.
//! - **fp32 lane kernel** — plain std Rust over `[f32; LANES]` chunks in
//!   the PR-8 point-op style: [`UNROLL`] independent accumulator vectors
//!   walk the input channels, combine pairwise, and a scalar tail finishes.
//!   The per-lane operation order is fixed, so the kernel is bit-identical
//!   to [`dense_fp32_scalar`] (the canonical-order oracle) for any row
//!   tiling and any thread count. Against the pre-PR sequential-order
//!   loop (kept as [`dense_fp32_naive`]) results differ only by f32
//!   reassociation — within 1e-5, pinned by tests.
//! - **int8 kernel** — `i32` tile accumulators spilling to `i64` every
//!   [`I8_TILE`] channels. Integer sums reassociate exactly, so the tiled
//!   kernel is **bit-identical** to the per-element `i64` reference
//!   ([`dense_int8_scalar`], the pre-PR accumulation): same `i64` dot per
//!   channel group, then the same f32 dequantization sequence.
//! - **Row-tile parallelism** — both kernels fan rows out through
//!   [`crate::exec::par_map`] with the same thread-budget clamping as the
//!   point ops ([`crate::exec::row_tiles`]); results are bit-identical for
//!   any thread count by construction.
//!
//! Fused batched execution (packing k scenes into one `(k*n, cin)` call)
//! lives a layer up in [`super::surrogate::run_batch_with_spec`]; it lands
//! here as a single kernel invocation over the packed rows.

use std::collections::HashMap;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock, PoisonError};

use crate::exec;

/// Output-channel tile width of the fp32 lane kernel (matches the point-op
/// lane width: wide enough for every SIMD ISA the host build targets).
pub const LANES: usize = 8;
/// Independent accumulator vectors per lane tile — hides FMA latency; the
/// fixed pairwise combine defines the canonical reduction order.
pub const UNROLL: usize = 4;
/// Channels per `i32` partial accumulator on the int8 path. `i8 * i8`
/// products are at most 127 * 127, so a tile of 4096 stays at least 30x
/// under `i32::MAX` before spilling into the `i64` total.
pub const I8_TILE: usize = 4096;
/// Minimum output rows a parallel row tile is worth spawning for (a GEMM
/// row costs `cin * cout` FLOPs — far heavier than a point-op row, so the
/// threshold sits lower than the point-op kernels').
const MIN_ROWS_PER_TILE: usize = 64;

// ---------------------------------------------------------------- weights

/// SplitMix64 finalizer (shared with the surrogate's weight generator).
#[inline]
pub(crate) fn mix(mut z: u64) -> u64 {
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// FNV-1a string hash — the artifact-identity half of a weight key.
pub(crate) fn hash_str(s: &str) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for b in s.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

/// Pseudo-random weight in [-1, 1] for (weight key, out channel, in channel).
#[inline]
pub(crate) fn weight(key: u64, j: u64, c: u64) -> f32 {
    let h = mix(
        key ^ j.wrapping_mul(0x9E3779B97F4A7C15) ^ c.wrapping_mul(0xD1B54A32D192ED03),
    );
    ((h >> 11) as f64 * (2.0 / (1u64 << 53) as f64) - 1.0) as f32
}

pub(crate) fn bias_vec(key: u64, cout: usize) -> Vec<f32> {
    (0..cout).map(|j| 0.1 * weight(key ^ 0xB1A5, j as u64, 0)).collect()
}

/// One dense layer's weights in every form the kernels consume, generated
/// once per `(key, cin, cout)` and shared across scenes, threads, and
/// precision variants.
#[derive(Debug)]
pub struct PackedWeights {
    pub cin: usize,
    pub cout: usize,
    /// fp32 matrix, tile-transposed: tile `t` holds output channels
    /// `t*LANES..t*LANES+LANES` as `cin` consecutive lane groups —
    /// `wpack[t*cin*LANES + c*LANES + l] = W[t*LANES + l][c]` (zero for
    /// lanes past `cout`), so the kernel streams one contiguous block per
    /// tile with unit stride.
    pub wpack: Vec<f32>,
    /// Row-major `i8` codes, symmetric per output row (the exact
    /// quantization the pre-PR int8 path computed per call).
    pub wq: Vec<i8>,
    /// Per-output-row weight scales for `wq`.
    pub sw: Vec<f32>,
    pub bias: Vec<f32>,
    /// The layer's `1/sqrt(cin)` normalizer.
    pub scale: f32,
}

impl PackedWeights {
    pub fn generate(key: u64, cin: usize, cout: usize) -> PackedWeights {
        let tiles = cout.div_ceil(LANES);
        let mut wpack = vec![0.0f32; tiles * cin * LANES];
        let mut wq: Vec<i8> = Vec::with_capacity(cout * cin);
        let mut sw = Vec::with_capacity(cout);
        let mut row = vec![0.0f32; cin];
        for j in 0..cout {
            for (c, v) in row.iter_mut().enumerate() {
                *v = weight(key, j as u64, c as u64);
            }
            let (t, l) = (j / LANES, j % LANES);
            let tile = &mut wpack[t * cin * LANES..(t + 1) * cin * LANES];
            for (c, &v) in row.iter().enumerate() {
                tile[c * LANES + l] = v;
            }
            let amax = row.iter().fold(0.0f32, |a, &v| a.max(v.abs()));
            let s = (amax / 127.0).max(1e-12);
            sw.push(s);
            wq.extend(row.iter().map(|&v| (v / s).round().clamp(-127.0, 127.0) as i8));
        }
        PackedWeights {
            cin,
            cout,
            wpack,
            wq,
            sw,
            bias: bias_vec(key, cout),
            scale: 1.0 / (cin.max(1) as f32).sqrt(),
        }
    }

    fn tiles(&self) -> usize {
        self.cout.div_ceil(LANES)
    }

    /// Bytes this entry holds resident (the S007 footprint accounting).
    pub fn resident_bytes(&self) -> u64 {
        packed_weight_bytes(self.cin, self.cout, false)
            + packed_weight_bytes(self.cin, self.cout, true)
    }
}

/// Canonical packed size of one dense layer's weights at a precision:
/// fp32 counts the lane-padded tile-transposed matrix plus bias; int8
/// counts the row-major codes plus per-row scales and the f32 bias. This
/// is the number the S007 verifier rule and the workload accounting
/// ([`crate::coordinator::arch::nn_workload_of`]) agree on.
pub fn packed_weight_bytes(cin: usize, cout: usize, int8: bool) -> u64 {
    if int8 {
        (cout * cin) as u64 + (cout * 4) as u64 + (cout * 4) as u64
    } else {
        (cout.div_ceil(LANES) * LANES * cin * 4) as u64 + (cout * 4) as u64
    }
}

/// Packed-weight + input-activation footprint of one dense stage execution
/// (`rows` activations of `cin` channels at the stage precision). Output
/// rows are the *next* stage's input and are accounted there.
pub fn nn_footprint_bytes(rows: usize, cin: usize, cout: usize, int8: bool) -> u64 {
    let per_elem = if int8 { 1u64 } else { 4u64 };
    packed_weight_bytes(cin, cout, int8) + (rows * cin) as u64 * per_elem
}

// ----------------------------------------------------------------- cache

type CacheMap = HashMap<(u64, usize, usize), Arc<PackedWeights>>;

static CACHE: OnceLock<Mutex<CacheMap>> = OnceLock::new();
static HITS: AtomicU64 = AtomicU64::new(0);
static MISSES: AtomicU64 = AtomicU64::new(0);

fn cache() -> &'static Mutex<CacheMap> {
    CACHE.get_or_init(|| Mutex::new(HashMap::new()))
}

/// Fetch (or generate once) the packed weights for `(key, cin, cout)`.
/// Generation happens under the map lock so concurrent cold misses for the
/// same layer produce exactly one entry; a hit is a lock + clone of the
/// `Arc`. A thread that panicked while holding the lock cannot leave the
/// map partially written (insertion is a single `HashMap::insert`), so
/// poisoning is ignored rather than propagated.
pub fn packed(key: u64, cin: usize, cout: usize) -> Arc<PackedWeights> {
    let mut map = cache().lock().unwrap_or_else(PoisonError::into_inner);
    if let Some(p) = map.get(&(key, cin, cout)) {
        HITS.fetch_add(1, Ordering::Relaxed);
        return p.clone();
    }
    MISSES.fetch_add(1, Ordering::Relaxed);
    let p = Arc::new(PackedWeights::generate(key, cin, cout));
    map.insert((key, cin, cout), p.clone());
    p
}

/// `(hits, misses)` since process start — monotonic, shared by every
/// runtime in the process.
pub fn cache_stats() -> (u64, u64) {
    (HITS.load(Ordering::Relaxed), MISSES.load(Ordering::Relaxed))
}

/// Number of resident entries.
pub fn cache_len() -> usize {
    cache().lock().unwrap_or_else(PoisonError::into_inner).len()
}

/// Drop every cached entry (tests force cold misses with this; correctness
/// never depends on residency — a dropped entry regenerates bit-identically).
pub fn clear_cache() {
    cache().lock().unwrap_or_else(PoisonError::into_inner).clear();
}

// ------------------------------------------------------------ fp32 kernel

/// Canonical-order scalar oracle: per output channel, [`UNROLL`]
/// independent partial sums over the channel main body, combined pairwise
/// `(a0+a1)+(a2+a3)`, then a sequential tail. [`dense_fp32`] reproduces
/// exactly this arithmetic per lane, so oracle and lane kernel are
/// bit-identical.
pub fn dense_fp32_scalar(pw: &PackedWeights, data: &[f32], out: &mut [f32]) {
    let (cin, cout) = (pw.cin, pw.cout);
    let main = cin - (cin % UNROLL);
    for (row, orow) in data.chunks_exact(cin).zip(out.chunks_exact_mut(cout)) {
        for j in 0..cout {
            // read weights from the packed layout so the oracle needs no
            // second copy of the matrix
            let (t, l) = (j / LANES, j % LANES);
            let tile = &pw.wpack[t * cin * LANES..(t + 1) * cin * LANES];
            let mut acc = [0.0f32; UNROLL];
            let mut c = 0;
            while c < main {
                for (u, a) in acc.iter_mut().enumerate() {
                    *a += tile[(c + u) * LANES + l] * row[c + u];
                }
                c += UNROLL;
            }
            let mut s = (acc[0] + acc[1]) + (acc[2] + acc[3]);
            for (c, &xv) in row.iter().enumerate().skip(main) {
                s += tile[c * LANES + l] * xv;
            }
            orow[j] = (s * pw.scale + pw.bias[j]).tanh();
        }
    }
}

/// The pre-PR fp32 path, verbatim: weights re-derived from the generator
/// per call, sequential left-to-right dot. Kept as the old-order oracle
/// (the canonical kernels must agree with it within 1e-5) and as the bench
/// baseline the trajectory is measured against.
pub fn dense_fp32_naive(key: u64, cin: usize, cout: usize, data: &[f32]) -> Vec<f32> {
    let mut w = Vec::with_capacity(cout * cin);
    for j in 0..cout {
        for c in 0..cin {
            w.push(weight(key, j as u64, c as u64));
        }
    }
    let bias = bias_vec(key, cout);
    let scale = 1.0 / (cin.max(1) as f32).sqrt();
    let n = data.len() / cin.max(1);
    let mut out = Vec::with_capacity(n * cout);
    for row in data.chunks_exact(cin.max(1)) {
        for j in 0..cout {
            let wrow = &w[j * cin..(j + 1) * cin];
            let mut acc = 0.0f32;
            for (wv, xv) in wrow.iter().zip(row.iter()) {
                acc += wv * xv;
            }
            out.push((acc * scale + bias[j]).tanh());
        }
    }
    out
}

fn fp32_rows(pw: &PackedWeights, data: &[f32], out: &mut [f32]) {
    let (cin, cout) = (pw.cin, pw.cout);
    let tiles = pw.tiles();
    let main = cin - (cin % UNROLL);
    for (row, orow) in data.chunks_exact(cin).zip(out.chunks_exact_mut(cout)) {
        for t in 0..tiles {
            let wp = &pw.wpack[t * cin * LANES..(t + 1) * cin * LANES];
            let mut acc = [[0.0f32; LANES]; UNROLL];
            let mut c = 0;
            while c < main {
                for (u, a) in acc.iter_mut().enumerate() {
                    let xv = row[c + u];
                    let wl = &wp[(c + u) * LANES..(c + u) * LANES + LANES];
                    for l in 0..LANES {
                        a[l] += wl[l] * xv;
                    }
                }
                c += UNROLL;
            }
            let mut s = [0.0f32; LANES];
            for l in 0..LANES {
                s[l] = (acc[0][l] + acc[1][l]) + (acc[2][l] + acc[3][l]);
            }
            for (c, &xv) in row.iter().enumerate().skip(main) {
                let wl = &wp[c * LANES..c * LANES + LANES];
                for l in 0..LANES {
                    s[l] += wl[l] * xv;
                }
            }
            let j0 = t * LANES;
            for (l, sv) in s.iter().enumerate().take(cout - j0) {
                orow[j0 + l] = (sv * pw.scale + pw.bias[j0 + l]).tanh();
            }
        }
    }
}

/// Tiled fp32 dense: `out[r] = tanh(W @ data[r] * scale + bias)` over the
/// lane kernel, rows fanned out across up to `threads` exec-pool threads.
/// Bit-identical to [`dense_fp32_scalar`] for any `threads`.
pub fn dense_fp32(pw: &PackedWeights, data: &[f32], out: &mut [f32], threads: usize) {
    let cin = pw.cin.max(1);
    let n = data.len() / cin;
    debug_assert_eq!(out.len(), n * pw.cout);
    let ranges = exec::row_tiles(n, threads, MIN_ROWS_PER_TILE);
    if ranges.len() <= 1 {
        fp32_rows(pw, data, out);
        return;
    }
    let parts = exec::par_map(&ranges, ranges.len(), |_, &(a, b)| {
        let mut part = vec![0.0f32; (b - a) * pw.cout];
        fp32_rows(pw, &data[a * cin..b * cin], &mut part);
        part
    });
    for (&(a, _), part) in ranges.iter().zip(parts.iter()) {
        out[a * pw.cout..a * pw.cout + part.len()].copy_from_slice(part);
    }
}

// ------------------------------------------------------------ int8 kernel

/// `i64` dot product of two `i8` slices via `i32` tile accumulators: each
/// [`I8_TILE`]-channel tile sums in `i32` (overflow-free by construction)
/// and spills into the `i64` total. Integer addition is associative, so
/// this equals the per-element `i64` accumulation bit-for-bit.
#[inline]
fn dot_i8(w: &[i8], x: &[i8]) -> i64 {
    let mut total = 0i64;
    for (wc, xc) in w.chunks(I8_TILE).zip(x.chunks(I8_TILE)) {
        let mut t = 0i32;
        for (a, b) in wc.iter().zip(xc.iter()) {
            t += *a as i32 * *b as i32;
        }
        total += t as i64;
    }
    total
}

/// Per-group quantization context of one int8 dense call: the channel
/// groups (with contiguous runs detected once, not per row), the shared
/// group scale/zero, and the per-(output, group) integer weight sums.
pub struct Int8Ctx<'a> {
    pub groups: &'a [Vec<usize>],
    pub gscale: &'a [f32],
    pub gzero: &'a [i64],
    /// `wsum[j * groups.len() + gi]`
    pub wsum: &'a [i64],
    /// `Some((start, end))` when group `gi` is a contiguous ascending run.
    runs: Vec<Option<(usize, usize)>>,
}

impl<'a> Int8Ctx<'a> {
    pub fn new(
        groups: &'a [Vec<usize>],
        gscale: &'a [f32],
        gzero: &'a [i64],
        wsum: &'a [i64],
    ) -> Int8Ctx<'a> {
        let runs = groups
            .iter()
            .map(|g| {
                let contig = g.windows(2).all(|w| w[1] == w[0] + 1);
                (contig && !g.is_empty()).then(|| (g[0], g[g.len() - 1] + 1))
            })
            .collect();
        Int8Ctx { groups, gscale, gzero, wsum, runs }
    }
}

fn int8_rows(pw: &PackedWeights, ctx: &Int8Ctx<'_>, qx: &[i8], out: &mut [f32]) {
    let (cin, cout) = (pw.cin, pw.cout);
    let ng = ctx.groups.len().max(1);
    for (x, orow) in qx.chunks_exact(cin).zip(out.chunks_exact_mut(cout)) {
        for j in 0..cout {
            let wrow = &pw.wq[j * cin..(j + 1) * cin];
            let mut acc = 0.0f32;
            for (gi, g) in ctx.groups.iter().enumerate() {
                let dot = match ctx.runs[gi] {
                    Some((s, e)) => dot_i8(&wrow[s..e], &x[s..e]),
                    None => {
                        // scattered role group: gather, still in i32 tiles
                        let mut total = 0i64;
                        for idx in g.chunks(I8_TILE) {
                            let mut t = 0i32;
                            for &c in idx {
                                t += wrow[c] as i32 * x[c] as i32;
                            }
                            total += t as i64;
                        }
                        total
                    }
                };
                acc += ctx.gscale[gi] * (dot - ctx.gzero[gi] * ctx.wsum[j * ng + gi]) as f32;
            }
            orow[j] = (pw.sw[j] * acc * pw.scale + pw.bias[j]).tanh();
        }
    }
}

/// Tiled int8 dense over pre-quantized activation codes. Bit-identical to
/// [`dense_int8_scalar`] (and therefore to the pre-PR int8 path) for any
/// row tiling and thread count.
pub fn dense_int8(
    pw: &PackedWeights,
    ctx: &Int8Ctx<'_>,
    qx: &[i8],
    out: &mut [f32],
    threads: usize,
) {
    let cin = pw.cin.max(1);
    let n = qx.len() / cin;
    debug_assert_eq!(out.len(), n * pw.cout);
    let ranges = exec::row_tiles(n, threads, MIN_ROWS_PER_TILE);
    if ranges.len() <= 1 {
        int8_rows(pw, ctx, qx, out);
        return;
    }
    let parts = exec::par_map(&ranges, ranges.len(), |_, &(a, b)| {
        let mut part = vec![0.0f32; (b - a) * pw.cout];
        int8_rows(pw, ctx, &qx[a * cin..b * cin], &mut part);
        part
    });
    for (&(a, _), part) in ranges.iter().zip(parts.iter()) {
        out[a * pw.cout..a * pw.cout + part.len()].copy_from_slice(part);
    }
}

/// Per-element `i64` reference — the pre-PR int8 accumulation, verbatim.
/// Retained as the oracle the tiled kernel is pinned against.
pub fn dense_int8_scalar(pw: &PackedWeights, ctx: &Int8Ctx<'_>, qx: &[i8], out: &mut [f32]) {
    let (cin, cout) = (pw.cin, pw.cout);
    let ng = ctx.groups.len().max(1);
    for (x, orow) in qx.chunks_exact(cin).zip(out.chunks_exact_mut(cout)) {
        for j in 0..cout {
            let wrow = &pw.wq[j * cin..(j + 1) * cin];
            let mut acc = 0.0f32;
            for (gi, g) in ctx.groups.iter().enumerate() {
                let mut dot = 0i64;
                for &c in g {
                    dot += wrow[c] as i64 * x[c] as i64;
                }
                acc += ctx.gscale[gi] * (dot - ctx.gzero[gi] * ctx.wsum[j * ng + gi]) as f32;
            }
            orow[j] = (pw.sw[j] * acc * pw.scale + pw.bias[j]).tanh();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::{check, PropConfig};
    use crate::util::rng::Rng;

    fn rand_rows(rng: &mut Rng, n: usize, cin: usize) -> Vec<f32> {
        (0..n * cin).map(|_| rng.f32() * 4.0 - 2.0).collect()
    }

    #[test]
    fn tiled_fp32_bitwise_equals_canonical_scalar() {
        check("fp32 tiled == canonical scalar", PropConfig::default(), |rng, size| {
            let (cin, cout) = (1 + size % 67, 1 + (size * 3) % 41);
            let n = 1 + size % 19;
            let key = rng.next_u64();
            let pw = PackedWeights::generate(key, cin, cout);
            let data = rand_rows(rng, n, cin);
            let mut a = vec![0.0f32; n * cout];
            let mut b = vec![0.0f32; n * cout];
            dense_fp32_scalar(&pw, &data, &mut a);
            for threads in [1usize, 3, 8] {
                dense_fp32(&pw, &data, &mut b, threads);
                if a != b {
                    return Err(format!(
                        "tiled (threads={threads}) diverged from scalar at cin={cin} cout={cout} n={n}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn canonical_order_tracks_naive_within_1e5() {
        check("fp32 canonical vs naive 1e-5", PropConfig::default(), |rng, size| {
            let (cin, cout) = (1 + size % 120, 1 + size % 33);
            let n = 1 + size % 9;
            let key = rng.next_u64();
            let pw = PackedWeights::generate(key, cin, cout);
            let data = rand_rows(rng, n, cin);
            let mut a = vec![0.0f32; n * cout];
            dense_fp32(&pw, &data, &mut a, 1);
            let b = dense_fp32_naive(key, cin, cout, &data);
            for (x, y) in a.iter().zip(b.iter()) {
                if (x - y).abs() > 1e-5 {
                    return Err(format!("canonical {x} vs naive {y} past 1e-5"));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn tiled_int8_bitwise_equals_scalar_across_seeds() {
        check("int8 tiled == scalar", PropConfig { cases: 48, seed: 0x5EED }, |rng, size| {
            let (cin, cout) = (2 + size % 50, 1 + size % 23);
            let n = 1 + size % 17;
            let key = rng.next_u64();
            let pw = PackedWeights::generate(key, cin, cout);
            let qx: Vec<i8> = (0..n * cin).map(|_| (rng.next_u64() % 255) as i8).collect();
            // random channel partition: contiguous halves or a scattered pair
            let groups: Vec<Vec<usize>> = if size % 2 == 0 {
                let cut = 1 + size % cin;
                vec![(0..cut.min(cin)).collect(), (cut.min(cin)..cin).collect()]
            } else {
                let a: Vec<usize> = (0..cin).filter(|c| c % 3 == 0).collect();
                let b: Vec<usize> = (0..cin).filter(|c| c % 3 != 0).collect();
                vec![a, b]
            };
            let groups: Vec<Vec<usize>> =
                groups.into_iter().filter(|g| !g.is_empty()).collect();
            let ng = groups.len();
            let gscale: Vec<f32> = (0..ng).map(|_| rng.f32() * 0.05 + 1e-4).collect();
            let gzero: Vec<i64> = (0..ng).map(|_| (rng.next_u64() % 31) as i64 - 15).collect();
            let mut wsum = vec![0i64; cout * ng];
            for j in 0..cout {
                for (gi, g) in groups.iter().enumerate() {
                    wsum[j * ng + gi] =
                        g.iter().map(|&c| pw.wq[j * cin + c] as i64).sum();
                }
            }
            let ctx = Int8Ctx::new(&groups, &gscale, &gzero, &wsum);
            let mut a = vec![0.0f32; n * cout];
            let mut b = vec![0.0f32; n * cout];
            dense_int8_scalar(&pw, &ctx, &qx, &mut a);
            for threads in [1usize, 4] {
                dense_int8(&pw, &ctx, &qx, &mut b, threads);
                if a != b {
                    return Err(format!(
                        "int8 tiled (threads={threads}) diverged at cin={cin} cout={cout}"
                    ));
                }
            }
            Ok(())
        });
    }

    #[test]
    fn cache_hits_return_the_same_entry() {
        let key = hash_str("gemm-cache-test-unique");
        let (h0, m0) = cache_stats();
        let a = packed(key, 37, 13);
        let b = packed(key, 37, 13);
        assert!(Arc::ptr_eq(&a, &b), "hit must return the resident entry");
        let (h1, m1) = cache_stats();
        assert!(m1 > m0, "first fetch is a miss");
        assert!(h1 > h0, "second fetch is a hit");
        // regeneration after eviction is bit-identical
        let before = (a.wpack.clone(), a.wq.clone(), a.sw.clone(), a.bias.clone());
        clear_cache();
        let c = packed(key, 37, 13);
        assert_eq!(before.0, c.wpack);
        assert_eq!(before.1, c.wq);
        assert_eq!(before.2, c.sw);
        assert_eq!(before.3, c.bias);
    }

    #[test]
    fn packed_layout_matches_generator() {
        let key = hash_str("gemm-layout");
        let (cin, cout) = (11, 19); // deliberately non-multiples of LANES
        let pw = PackedWeights::generate(key, cin, cout);
        assert_eq!(pw.wpack.len(), cout.div_ceil(LANES) * cin * LANES);
        for j in 0..cout {
            let (t, l) = (j / LANES, j % LANES);
            for c in 0..cin {
                assert_eq!(
                    pw.wpack[t * cin * LANES + c * LANES + l],
                    weight(key, j as u64, c as u64)
                );
            }
        }
        // padding lanes are zero
        let last = cout.div_ceil(LANES) - 1;
        for c in 0..cin {
            for l in (cout - last * LANES)..LANES {
                assert_eq!(pw.wpack[last * cin * LANES + c * LANES + l], 0.0);
            }
        }
    }

    #[test]
    fn footprint_accounts_weights_and_activations() {
        // fp32: lane-padded pack + bias; int8: codes + scales + bias
        assert_eq!(packed_weight_bytes(10, 16, false), (16 * 10 * 4 + 16 * 4) as u64);
        assert_eq!(packed_weight_bytes(10, 17, false), (24 * 10 * 4 + 17 * 4) as u64);
        assert_eq!(packed_weight_bytes(10, 16, true), (16 * 10 + 16 * 4 + 16 * 4) as u64);
        assert_eq!(
            nn_footprint_bytes(100, 10, 16, false),
            packed_weight_bytes(10, 16, false) + 100 * 10 * 4
        );
        assert_eq!(
            nn_footprint_bytes(100, 10, 16, true),
            packed_weight_bytes(10, 16, true) + 100 * 10
        );
    }
}
