//! Ball query: nearest-K-within-radius grouping (PointNet++ convention).
//!
//! Mirrors python/compile/sampling.py `ball_query`: for each center, take the
//! K nearest points within `radius`; unfilled slots repeat the nearest valid
//! member; an empty ball falls back to the globally nearest point.
//!
//! §Perf: a uniform grid (cell size = radius) prunes the candidate set from
//! N to the 27 neighboring cells, turning the O(M*N) scan into ~O(M*K) for
//! indoor point densities (see EXPERIMENTS.md §Perf for the before/after).
//! `ball_query_par` additionally spreads the per-center loop over scoped
//! threads — every center's result is independent, so the output is
//! identical for any thread count. The [`Grid`] is shared with
//! `pointops::interp`'s 3-NN search.

use std::collections::HashMap;

use crate::exec::par_map;

/// Uniform hash grid over a point cloud.
pub(crate) struct Grid {
    cell: f32,
    cells: HashMap<(i32, i32, i32), Vec<u32>>,
}

impl Grid {
    pub(crate) fn build(xyz: &[[f32; 3]], cell: f32) -> Grid {
        let mut cells: HashMap<(i32, i32, i32), Vec<u32>> =
            HashMap::with_capacity(xyz.len() / 2);
        for (i, p) in xyz.iter().enumerate() {
            cells
                .entry(Self::key(p, cell))
                .or_default()
                .push(i as u32);
        }
        Grid { cell, cells }
    }

    pub(crate) fn cell_size(&self) -> f32 {
        self.cell
    }

    #[inline]
    pub(crate) fn key(p: &[f32; 3], cell: f32) -> (i32, i32, i32) {
        (
            (p[0] / cell).floor() as i32,
            (p[1] / cell).floor() as i32,
            (p[2] / cell).floor() as i32,
        )
    }

    /// Visit all points in the 27 cells around `c`.
    #[inline]
    pub(crate) fn neighbors(&self, c: &[f32; 3], mut f: impl FnMut(u32)) {
        let (kx, ky, kz) = Self::key(c, self.cell);
        for dx in -1..=1 {
            for dy in -1..=1 {
                for dz in -1..=1 {
                    if let Some(v) = self.cells.get(&(kx + dx, ky + dy, kz + dz)) {
                        for &i in v {
                            f(i);
                        }
                    }
                }
            }
        }
    }

    /// Visit all points in cells at Chebyshev distance exactly `ring` from
    /// the cell containing `c` (ring 0 = the center cell itself). Used by
    /// the expanding 3-NN search in `interp`. Enumerates only the shell's
    /// six faces — O(ring²) cells, not O(ring³).
    pub(crate) fn ring(&self, c: &[f32; 3], ring: i32, mut f: impl FnMut(u32)) {
        let (kx, ky, kz) = Self::key(c, self.cell);
        let mut cell = |dx: i32, dy: i32, dz: i32| {
            if let Some(v) = self.cells.get(&(kx + dx, ky + dy, kz + dz)) {
                for &i in v {
                    f(i);
                }
            }
        };
        if ring == 0 {
            cell(0, 0, 0);
            return;
        }
        // z = ±ring full faces; y = ±ring minus the z edges; x = ±ring core
        for dx in -ring..=ring {
            for dy in -ring..=ring {
                cell(dx, dy, -ring);
                cell(dx, dy, ring);
            }
        }
        for dx in -ring..=ring {
            for dz in -(ring - 1)..=(ring - 1) {
                cell(dx, -ring, dz);
                cell(dx, ring, dz);
            }
        }
        for dy in -(ring - 1)..=(ring - 1) {
            for dz in -(ring - 1)..=(ring - 1) {
                cell(-ring, dy, dz);
                cell(ring, dy, dz);
            }
        }
    }
}

/// One center's group: K nearest in-radius members (grid-pruned candidates).
fn query_one(
    grid: &Grid,
    xyz: &[[f32; 3]],
    ci: usize,
    r2: f32,
    k: usize,
    hits: &mut Vec<(f32, usize)>,
) -> Vec<usize> {
    let c = xyz[ci];
    hits.clear();
    grid.neighbors(&c, |j| {
        let p = xyz[j as usize];
        let dx = p[0] - c[0];
        let dy = p[1] - c[1];
        let dz = p[2] - c[2];
        let d2 = dx * dx + dy * dy + dz * dz;
        if d2 <= r2 {
            hits.push((d2, j as usize));
        }
    });
    hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
    let mut out: Vec<usize> = hits.iter().take(k).map(|&(_, j)| j).collect();
    let fill = out.first().copied().unwrap_or_else(|| {
        // empty ball (rare): brute-force global nearest
        let mut nearest = (f32::INFINITY, ci);
        for (j, p) in xyz.iter().enumerate() {
            let dx = p[0] - c[0];
            let dy = p[1] - c[1];
            let dz = p[2] - c[2];
            let d2 = dx * dx + dy * dy + dz * dz;
            if d2 < nearest.0 {
                nearest = (d2, j);
            }
        }
        nearest.1
    });
    out.resize(k, fill);
    out
}

/// Returns (M, K) neighbor indices for each center index.
pub fn ball_query(
    xyz: &[[f32; 3]],
    centers: &[usize],
    radius: f32,
    k: usize,
) -> Vec<Vec<usize>> {
    ball_query_par(xyz, centers, radius, k, 1)
}

/// `ball_query` with the per-center loop spread over up to `threads`
/// scoped threads. Identical output for any thread count.
pub fn ball_query_par(
    xyz: &[[f32; 3]],
    centers: &[usize],
    radius: f32,
    k: usize,
    threads: usize,
) -> Vec<Vec<usize>> {
    let r2 = radius * radius;
    let grid = Grid::build(xyz, radius);
    if threads <= 1 || centers.len() < 64 {
        let mut hits: Vec<(f32, usize)> = Vec::with_capacity(64);
        return centers
            .iter()
            .map(|&ci| query_one(&grid, xyz, ci, r2, k, &mut hits))
            .collect();
    }
    par_map(centers, threads, |_, &ci| {
        let mut hits: Vec<(f32, usize)> = Vec::with_capacity(64);
        query_one(&grid, xyz, ci, r2, k, &mut hits)
    })
}

/// Reference O(M*N) implementation kept for tests and the §Perf comparison.
pub fn ball_query_bruteforce(
    xyz: &[[f32; 3]],
    centers: &[usize],
    radius: f32,
    k: usize,
) -> Vec<Vec<usize>> {
    let r2 = radius * radius;
    centers
        .iter()
        .map(|&ci| {
            let c = xyz[ci];
            let mut hits: Vec<(f32, usize)> = Vec::with_capacity(k * 2);
            let mut nearest = (f32::INFINITY, ci);
            for (j, p) in xyz.iter().enumerate() {
                let dx = p[0] - c[0];
                let dy = p[1] - c[1];
                let dz = p[2] - c[2];
                let d2 = dx * dx + dy * dy + dz * dz;
                if d2 < nearest.0 {
                    nearest = (d2, j);
                }
                if d2 <= r2 {
                    hits.push((d2, j));
                }
            }
            hits.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap().then(a.1.cmp(&b.1)));
            hits.truncate(k);
            let mut out: Vec<usize> = hits.iter().map(|&(_, j)| j).collect();
            let fill = out.first().copied().unwrap_or(nearest.1);
            out.resize(k, fill);
            out
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    fn cloud(n: usize, seed: u64) -> Vec<[f32; 3]> {
        let mut r = Rng::new(seed);
        (0..n).map(|_| [r.f32() * 2.0, r.f32() * 2.0, r.f32()]).collect()
    }

    fn d2(a: [f32; 3], b: [f32; 3]) -> f32 {
        (a[0] - b[0]).powi(2) + (a[1] - b[1]).powi(2) + (a[2] - b[2]).powi(2)
    }

    #[test]
    fn grid_matches_bruteforce() {
        for seed in 0..6 {
            let pts = cloud(500, seed);
            let centers: Vec<usize> = (0..32).map(|i| i * 15).collect();
            for (r, k) in [(0.15, 8), (0.4, 16), (0.9, 4)] {
                let a = ball_query(&pts, &centers, r, k);
                let b = ball_query_bruteforce(&pts, &centers, r, k);
                assert_eq!(a, b, "seed {seed} r {r} k {k}");
            }
        }
    }

    #[test]
    fn parallel_matches_sequential() {
        let pts = cloud(2000, 11);
        let centers: Vec<usize> = (0..200).map(|i| i * 10).collect();
        let seq = ball_query(&pts, &centers, 0.35, 12);
        for threads in [2, 3, 8] {
            assert_eq!(ball_query_par(&pts, &centers, 0.35, 12, threads), seq);
        }
    }

    #[test]
    fn all_members_within_radius_or_fill() {
        let pts = cloud(400, 1);
        let centers = vec![0, 5, 100];
        let r = 0.4;
        let groups = ball_query(&pts, &centers, r, 16);
        for (g, &ci) in groups.iter().zip(centers.iter()) {
            assert_eq!(g.len(), 16);
            let first = g[0];
            for &j in g {
                assert!(d2(pts[j], pts[ci]) <= r * r + 1e-6 || j == first);
            }
        }
    }

    #[test]
    fn center_is_own_nearest_member() {
        let pts = cloud(200, 2);
        let groups = ball_query(&pts, &[7], 1.0, 8);
        assert_eq!(groups[0][0], 7, "nearest in-radius point is the center itself");
    }

    #[test]
    fn empty_ball_falls_back_to_nearest() {
        let mut pts = cloud(50, 3);
        pts.push([100.0, 100.0, 100.0]); // isolated center
        let groups = ball_query(&pts, &[50], 0.1, 4);
        assert!(groups[0].iter().all(|&j| j == 50));
    }

    #[test]
    fn members_sorted_by_distance() {
        let pts = cloud(300, 4);
        let groups = ball_query(&pts, &[3], 0.8, 12);
        let g = &groups[0];
        for w in g.windows(2) {
            let (a, b) = (d2(pts[w[0]], pts[3]), d2(pts[w[1]], pts[3]));
            assert!(a <= b + 1e-6 || w[1] == g[0]);
        }
    }

    #[test]
    fn negative_coordinates_handled() {
        let mut r = Rng::new(9);
        let pts: Vec<[f32; 3]> = (0..300)
            .map(|_| [r.f32() * 4.0 - 2.0, r.f32() * 4.0 - 2.0, r.f32() - 0.5])
            .collect();
        let centers = vec![0, 10, 200];
        assert_eq!(
            ball_query(&pts, &centers, 0.5, 8),
            ball_query_bruteforce(&pts, &centers, 0.5, 8)
        );
    }

    #[test]
    fn ring_zero_is_center_cell_and_rings_partition() {
        // visiting rings 0..=R must hit every point exactly once once R
        // spans the cloud
        let pts = cloud(300, 12);
        let grid = Grid::build(&pts, 0.5);
        let c = [1.0f32, 1.0, 0.5];
        let mut seen = vec![0usize; pts.len()];
        for ring in 0..8 {
            grid.ring(&c, ring, |j| seen[j as usize] += 1);
        }
        assert!(seen.iter().all(|&s| s == 1), "rings must partition the grid");
    }
}
