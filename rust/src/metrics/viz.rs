//! Terminal visualization: bird's-eye-view scene renderer and timeline
//! Gantt strips. Used by `pointsplit detect --viz` and the quickstart.

use crate::data::{Box3, Scene};
use crate::sim::{DeviceKind, Timeline};

/// Render a BEV ASCII map: ground-truth boxes as lowercase class initials,
/// detections (score > thresh) as uppercase, '.' background points.
pub fn bev_ascii(scene: &Scene, detections: &[Box3], thresh: f32, width: usize) -> String {
    let height = width / 2;
    let mut lo = [f32::INFINITY; 2];
    let mut hi = [f32::NEG_INFINITY; 2];
    for p in &scene.points {
        for d in 0..2 {
            lo[d] = lo[d].min(p[d]);
            hi[d] = hi[d].max(p[d]);
        }
    }
    let span = [(hi[0] - lo[0]).max(1e-3), (hi[1] - lo[1]).max(1e-3)];
    let mut grid = vec![vec![' '; width]; height];
    let to_cell = |x: f32, y: f32| -> (usize, usize) {
        let cx = (((x - lo[0]) / span[0]) * (width - 1) as f32) as usize;
        let cy = (((y - lo[1]) / span[1]) * (height - 1) as f32) as usize;
        (cx.min(width - 1), cy.min(height - 1))
    };
    for p in &scene.points {
        let (cx, cy) = to_cell(p[0], p[1]);
        if grid[cy][cx] == ' ' {
            grid[cy][cx] = '.';
        }
    }
    let initial = |class: usize| crate::data::CLASS_NAMES[class].chars().next().unwrap();
    for o in &scene.objects {
        let (cx, cy) = to_cell(o.center[0], o.center[1]);
        grid[cy][cx] = initial(o.class);
    }
    for d in detections.iter().filter(|d| d.score > thresh) {
        let (cx, cy) = to_cell(d.center[0], d.center[1]);
        grid[cy][cx] = initial(d.class).to_ascii_uppercase();
    }
    let mut out = String::new();
    out.push_str(&format!(
        "BEV {}x{} (lowercase = GT center, UPPERCASE = detection > {thresh}):\n",
        width, height
    ));
    for row in grid {
        out.push_str(&row.into_iter().collect::<String>());
        out.push('\n');
    }
    out
}

/// One-line-per-device Gantt strip of a simulated timeline.
pub fn gantt_ascii(tl: &Timeline, width: usize) -> String {
    let scale = width as f64 / tl.total_ms.max(1e-9);
    let mut out = String::new();
    for kind in [DeviceKind::Gpu, DeviceKind::EdgeTpu, DeviceKind::Cpu] {
        let stages: Vec<_> = tl.stages.iter().filter(|s| s.device == kind).collect();
        if stages.is_empty() {
            continue;
        }
        let mut row = vec![' '; width];
        for s in &stages {
            let a = (s.compute_start_ms * scale) as usize;
            let b = ((s.end_ms * scale) as usize).min(width.saturating_sub(1));
            let c = s.name.chars().next().unwrap_or('#');
            for cell in row.iter_mut().take(b + 1).skip(a.min(width - 1)) {
                *cell = c;
            }
            // transfer prefix
            let ta = (s.start_ms * scale) as usize;
            for cell in row.iter_mut().take(a.min(width)).skip(ta.min(width - 1)) {
                if *cell == ' ' {
                    *cell = '~';
                }
            }
        }
        out.push_str(&format!(
            "{:<8} |{}| {:.0} ms busy\n",
            kind.name(),
            row.into_iter().collect::<String>(),
            tl.busy_ms.get(&kind).copied().unwrap_or(0.0)
        ));
    }
    out.push_str(&format!("total: {:.0} ms ('~' = PCIe transfer)\n", tl.total_ms));
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{generate_scene, SYNRGBD};
    use crate::sim::{Precision, ScheduleSim, StageSpec, Workload, WorkloadKind};

    #[test]
    fn bev_contains_gt_markers() {
        let scene = generate_scene(3, &SYNRGBD);
        let s = bev_ascii(&scene, &[], 0.5, 60);
        assert!(s.lines().count() > 20);
        // at least one lowercase class initial appears
        let initials: Vec<char> =
            crate::data::CLASS_NAMES.iter().map(|n| n.chars().next().unwrap()).collect();
        assert!(s.chars().any(|c| initials.contains(&c)));
    }

    #[test]
    fn gantt_has_device_rows() {
        let stages = vec![StageSpec {
            name: "x".into(),
            device: DeviceKind::Gpu,
            precision: Precision::Fp32,
            workload: Workload {
                kind: WorkloadKind::PointOp,
                flops: 1_000_000,
                mem_bytes: 0,
                wire_bytes: 0,
            },
            deps: vec![],
        }];
        let tl = ScheduleSim::new().run(&stages);
        let g = gantt_ascii(&tl, 40);
        assert!(g.contains("GPU"));
        assert!(g.contains("total:"));
    }
}
