//! Request routing across the fleet.
//!
//! The default policy is **config-affinity**: rendezvous (highest-random-
//! weight) hashing ranks the alive boxes per config key, each key is served
//! by its top-`width` boxes, and the least-loaded of those wins the
//! request. Two properties matter:
//!
//! - **Batcher locality** — a key's traffic concentrates on few boxes, so
//!   each box's dynamic batcher sees enough same-config arrivals to form
//!   full batches. Random routing scatters K keys over all N boxes and
//!   every batcher starves (the affinity-beats-random assertion lives in
//!   `tests/cluster.rs`).
//! - **Failover stability** — rendezvous scores are per (key, box) and
//!   membership-independent, so removing a dead box moves *only* the keys
//!   it served (to their next-ranked box); every other key keeps its boxes.
//!
//! `Random` and pure `LeastLoaded` are kept as baselines for the bench.
//!
//! Streaming sessions route differently: a session's frame cache lives on
//! exactly one box, so [`Router::route_session`] gives each client a
//! **sticky binding** — rendezvous-chosen on first contact, then pinned as
//! long as the box is alive. Load and membership growth never move a bound
//! session (scale-up must not strand warm caches); only the bound box's
//! death forces a re-bind.

use std::collections::HashMap;

use crate::util::rng::Rng;

/// Load-balancing policy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RouterPolicy {
    /// Rendezvous-hash each config key to `width` boxes, least-loaded wins.
    ConfigAffinity,
    /// Uniform random box per request (batcher-hostile baseline).
    Random,
    /// Globally least-loaded box regardless of key.
    LeastLoaded,
}

impl RouterPolicy {
    pub fn parse(s: &str) -> Option<RouterPolicy> {
        match s.to_ascii_lowercase().as_str() {
            "affinity" | "config-affinity" | "rendezvous" => Some(RouterPolicy::ConfigAffinity),
            "random" | "rand" => Some(RouterPolicy::Random),
            "least-loaded" | "leastloaded" | "ll" => Some(RouterPolicy::LeastLoaded),
            _ => None,
        }
    }

    pub fn name(&self) -> &'static str {
        match self {
            RouterPolicy::ConfigAffinity => "affinity",
            RouterPolicy::Random => "random",
            RouterPolicy::LeastLoaded => "least-loaded",
        }
    }
}

/// A routable box as the router sees it at decision time.
#[derive(Debug, Clone, Copy)]
pub struct RouteTarget {
    /// Stable box id (survives membership changes — never reused).
    pub id: usize,
    pub queue_len: usize,
}

/// Per-(key, box) rendezvous score: one SplitMix64 finalization over the
/// pair. Deterministic and membership-independent — a box's score for a
/// key never changes, so fleet changes only re-rank the affected key/box.
fn affinity_score(key: usize, box_id: usize) -> u64 {
    let mut z = (key as u64)
        .wrapping_mul(0x9E3779B97F4A7C15)
        .wrapping_add((box_id as u64).wrapping_mul(0xD1B54A32D192ED03))
        .wrapping_add(0x2545F4914F6CDD1D);
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
    z ^ (z >> 31)
}

/// Stateful router (the RNG only feeds the `Random` baseline; affinity and
/// least-loaded are pure functions of the targets; session bindings are
/// sticky state).
pub struct Router {
    policy: RouterPolicy,
    rng: Rng,
    width: usize,
    /// Sticky client → box bindings for streaming sessions.
    bindings: HashMap<u64, usize>,
    /// Bindings re-made because the bound box left the fleet.
    rebinds: usize,
}

impl Router {
    pub fn new(policy: RouterPolicy, seed: u64) -> Router {
        Router {
            policy,
            rng: Rng::new(seed ^ 0xC1A5_7E12_0B0E_5EED),
            width: 2,
            bindings: HashMap::new(),
            rebinds: 0,
        }
    }

    /// Affinity spread: each key may land on at most this many boxes while
    /// membership is stable (default 2 — enough for least-loaded slack
    /// without scattering the key).
    pub fn with_width(mut self, width: usize) -> Router {
        self.width = width.max(1);
        self
    }

    pub fn policy(&self) -> RouterPolicy {
        self.policy
    }

    /// Pick a box for `key` among the alive targets; returns the chosen
    /// box id, or `None` when the fleet is empty.
    pub fn route(&mut self, key: usize, targets: &[RouteTarget]) -> Option<usize> {
        if targets.is_empty() {
            return None;
        }
        match self.policy {
            RouterPolicy::Random => Some(targets[self.rng.below(targets.len())].id),
            RouterPolicy::LeastLoaded => {
                targets.iter().min_by_key(|t| (t.queue_len, t.id)).map(|t| t.id)
            }
            RouterPolicy::ConfigAffinity => {
                let mut ranked: Vec<&RouteTarget> = targets.iter().collect();
                ranked.sort_by_key(|t| std::cmp::Reverse(affinity_score(key, t.id)));
                ranked.truncate(self.width);
                // least-loaded within the affinity set; ties keep affinity order
                let mut best = 0usize;
                for i in 1..ranked.len() {
                    if ranked[i].queue_len < ranked[best].queue_len {
                        best = i;
                    }
                }
                Some(ranked[best].id)
            }
        }
    }

    /// Pick a box for a streaming client among the alive targets.
    ///
    /// An existing binding to an alive box always wins, regardless of load
    /// or of better-ranked newcomers — the client's frame cache is warm
    /// there and moving it costs a FULL recompute. Otherwise (first contact
    /// or bound box dead) the client binds to its rendezvous-top alive box;
    /// width 1, load ignored, so the choice is a pure function of
    /// (client, membership) and cannot bounce between boxes.
    pub fn route_session(&mut self, client: u64, targets: &[RouteTarget]) -> Option<usize> {
        if targets.is_empty() {
            return None;
        }
        if let Some(&id) = self.bindings.get(&client) {
            if targets.iter().any(|t| t.id == id) {
                return Some(id);
            }
            self.rebinds += 1;
        }
        let chosen = targets
            .iter()
            .max_by_key(|t| (affinity_score(client as usize, t.id), t.id))
            .map(|t| t.id)?;
        self.bindings.insert(client, chosen);
        Some(chosen)
    }

    /// Sessions re-bound after losing their box (fleet-health signal).
    pub fn session_rebinds(&self) -> usize {
        self.rebinds
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn fleet(n: usize) -> Vec<RouteTarget> {
        (0..n).map(|id| RouteTarget { id, queue_len: 0 }).collect()
    }

    #[test]
    fn affinity_pins_each_key_to_width_boxes() {
        let mut r = Router::new(RouterPolicy::ConfigAffinity, 7);
        let targets = fleet(8);
        for key in 0..16 {
            let mut seen: Vec<usize> = (0..100)
                .map(|_| r.route(key, &targets).unwrap())
                .collect();
            seen.sort_unstable();
            seen.dedup();
            assert!(seen.len() <= 2, "key {key} spread over {} boxes", seen.len());
        }
    }

    #[test]
    fn affinity_failover_moves_only_the_dead_boxs_keys() {
        let mut r = Router::new(RouterPolicy::ConfigAffinity, 7);
        let full = fleet(6);
        let keys: Vec<usize> = (0..32).collect();
        let before: Vec<usize> = keys.iter().map(|&k| r.route(k, &full).unwrap()).collect();
        let dead = before[0];
        let survivors: Vec<RouteTarget> =
            full.iter().copied().filter(|t| t.id != dead).collect();
        let after: Vec<usize> = keys.iter().map(|&k| r.route(k, &survivors).unwrap()).collect();
        for (i, &k) in keys.iter().enumerate() {
            assert_ne!(after[i], dead, "key {k} routed to the dead box");
            if before[i] != dead {
                assert_eq!(
                    before[i], after[i],
                    "key {k} moved although its box survived (rendezvous must be stable)"
                );
            }
        }
    }

    #[test]
    fn affinity_prefers_less_loaded_box_in_set() {
        let mut r = Router::new(RouterPolicy::ConfigAffinity, 7);
        // find key 0's two-box affinity set on an idle fleet
        let idle = fleet(4);
        let first = r.route(0, &idle).unwrap();
        // pile load onto the preferred box; the alternate must take over
        let loaded: Vec<RouteTarget> = idle
            .iter()
            .map(|t| RouteTarget { id: t.id, queue_len: if t.id == first { 10 } else { 0 } })
            .collect();
        let second = r.route(0, &loaded).unwrap();
        assert_ne!(second, first, "least-loaded tie-break must divert inside the set");
    }

    #[test]
    fn empty_fleet_routes_nowhere() {
        for p in [RouterPolicy::ConfigAffinity, RouterPolicy::Random, RouterPolicy::LeastLoaded] {
            let mut r = Router::new(p, 1);
            assert!(r.route(0, &[]).is_none());
        }
    }

    #[test]
    fn session_binding_survives_scale_up_and_load() {
        let mut r = Router::new(RouterPolicy::ConfigAffinity, 7);
        let small = fleet(2);
        let bound = r.route_session(42, &small).unwrap();
        // membership grows and the bound box becomes the most loaded —
        // the session must stay put (its cache is warm there)
        let mut grown = fleet(8);
        for t in &mut grown {
            t.queue_len = if t.id == bound { 50 } else { 0 };
        }
        for _ in 0..20 {
            assert_eq!(r.route_session(42, &grown), Some(bound));
        }
        assert_eq!(r.session_rebinds(), 0);
    }

    #[test]
    fn session_rebinds_only_when_its_box_dies() {
        let mut r = Router::new(RouterPolicy::ConfigAffinity, 3);
        let full = fleet(4);
        let clients: Vec<u64> = (1..=12).collect();
        let before: Vec<usize> =
            clients.iter().map(|&c| r.route_session(c, &full).unwrap()).collect();
        let dead = before[0];
        let survivors: Vec<RouteTarget> =
            full.iter().copied().filter(|t| t.id != dead).collect();
        let after: Vec<usize> =
            clients.iter().map(|&c| r.route_session(c, &survivors).unwrap()).collect();
        let mut moved = 0;
        for (i, &c) in clients.iter().enumerate() {
            assert_ne!(after[i], dead, "client {c} routed to the dead box");
            if before[i] != dead {
                assert_eq!(before[i], after[i], "client {c} moved although its box survived");
            } else {
                moved += 1;
            }
        }
        assert!(moved > 0);
        assert_eq!(r.session_rebinds(), moved);
    }

    #[test]
    fn sessions_spread_across_the_fleet() {
        let mut r = Router::new(RouterPolicy::ConfigAffinity, 1);
        let targets = fleet(4);
        let mut seen: Vec<usize> =
            (1..=64).map(|c| r.route_session(c, &targets).unwrap()).collect();
        seen.sort_unstable();
        seen.dedup();
        assert!(seen.len() >= 3, "64 clients should use most of a 4-box fleet");
    }

    #[test]
    fn least_loaded_picks_minimum() {
        let mut r = Router::new(RouterPolicy::LeastLoaded, 1);
        let targets = vec![
            RouteTarget { id: 0, queue_len: 4 },
            RouteTarget { id: 1, queue_len: 1 },
            RouteTarget { id: 2, queue_len: 9 },
        ];
        assert_eq!(r.route(5, &targets), Some(1));
    }
}
