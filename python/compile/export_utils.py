"""HLO-text export helpers (the AOT bridge to the Rust runtime).

HLO *text* is the interchange format — NOT ``lowered.compile().serialize()``:
jax >= 0.5 emits HloModuleProto with 64-bit instruction ids which the
``xla`` crate's xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``). The
text parser reassigns ids, so text round-trips cleanly (see
/opt/xla-example/README.md and gen_hlo.py).
"""

from __future__ import annotations

import jax
from jax._src.lib import xla_client as xc


def to_hlo_text(lowered) -> str:
    """Lower a jitted function to XLA HLO text with a tuple root.

    ``print_large_constants=True`` is ESSENTIAL: the default HLO printer
    elides big literals as ``constant({...})`` and the xla_extension 0.5.1
    text parser silently zero-fills them — every baked weight would read as
    zero on the Rust side.
    """
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    text = comp.as_hlo_text(print_large_constants=True)
    assert "{...}" not in text, "HLO printer elided a constant"
    return text


def export_fn(fn, specs, path: str) -> str:
    """jit-lower ``fn`` at the given ShapeDtypeStructs and write HLO text."""
    lowered = jax.jit(fn).lower(*specs)
    text = to_hlo_text(lowered)
    with open(path, "w") as f:
        f.write(text)
    return text
