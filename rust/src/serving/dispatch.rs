//! Virtual-time dispatcher: drains the admission queue through the batcher
//! and SLO policy, charging every batch into the calibrated device timeline.
//!
//! The loop runs on the **simulated clock**. Each dispatched batch is costed
//! by the [`ServicePlanner`] (the same stage DAG `ScenePipeline` records,
//! scaled by batch size); its critical path sets request latency and its
//! bottleneck-device occupancy sets when the *next* batch may enter. That
//! second number is the two-lane overlap: while a batch's NPU tail is still
//! draining, the following batch's GPU point-manipulation front has already
//! started — exactly the Fig. 3 pipelining, applied across requests instead
//! of within one scene.
//!
//! A request's life ends in exactly one of four ways — completed, rejected
//! at admission, expired in queue, or shed by the SLO policy — and the
//! dispatcher emits one [`RequestOutcome`] per arrival (property-tested in
//! `rust/tests/proptests.rs`).

use std::collections::HashMap;
use std::sync::{mpsc, Arc, Mutex};

use anyhow::{anyhow, Result};

use crate::coordinator::{DetectorConfig, ScenePipeline};
use crate::data::{generate_scene, Box3, DatasetCfg};
use crate::eval::{eval_map, Detection};
use crate::exec::HostExec;
use crate::graph::StageGraph;
use crate::runtime::{Runtime, RuntimeSource};
use crate::util::stats::Stats;

use super::batcher::{self, BatchPolicy};
use super::loadgen::{LoadGen, Request};
use super::plan::ServicePlanner;
use super::queue::{AdmissionQueue, AdmitResult};
use super::slo::{self, SloPolicy};

/// One open-loop serving experiment.
#[derive(Debug, Clone)]
pub struct TrafficScenario {
    pub name: String,
    /// Detector configurations addressable by `Request::key`.
    pub configs: Vec<DetectorConfig>,
    /// Points per scene (from the dataset config).
    pub num_points: usize,
    pub load: LoadGen,
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    pub policy: SloPolicy,
}

/// How a single request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutcomeKind {
    Completed,
    RejectedFull,
    Expired,
    ShedSlo,
}

/// Terminal record for one arrival.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub id: u64,
    pub kind: OutcomeKind,
    /// Completed within its deadline (always false for non-completions).
    pub on_time: bool,
}

/// Aggregated result of one scenario run.
#[derive(Debug, Clone)]
pub struct ServeTrafficReport {
    pub scenario: String,
    pub pattern: &'static str,
    pub policy: &'static str,
    pub offered_rps: f64,
    /// Steady-state capacity of config 0 at the full batch size.
    pub capacity_rps: f64,
    /// Arrival-window length, seconds (simulated).
    pub duration_s: f64,
    /// Time the last batch finished, seconds (simulated).
    pub makespan_s: f64,
    pub arrivals: usize,
    pub completed: usize,
    pub on_time: usize,
    pub rejected_full: usize,
    pub expired: usize,
    pub shed_slo: usize,
    /// Requests served on the degraded fast path.
    pub degraded: usize,
    pub batches: usize,
    pub mean_batch: f64,
    /// End-to-end (arrival -> batch completion) simulated latency.
    pub latency_ms: Stats,
    /// Arrival -> dispatch delay (queueing + batching).
    pub queue_wait_ms: Stats,
    /// On-time completions / arrivals.
    pub slo_attainment: f64,
    /// On-time completions per simulated second.
    pub goodput_rps: f64,
    pub util_gpu: f64,
    pub util_npu: f64,
    pub max_queue_depth: usize,
    /// mAP@0.25 over functionally executed scenes (None without a real
    /// PJRT backend + artifacts).
    pub map_25: Option<f64>,
}

impl ServeTrafficReport {
    /// Human-readable block (mirrors `cmd_serve`'s style).
    pub fn print(&self) {
        println!(
            "--- {} [{} arrivals, pattern={}, policy={}] ---",
            self.scenario, self.arrivals, self.pattern, self.policy
        );
        println!(
            "offered {:.1} rps vs capacity {:.1} rps ({:.0}% load), {:.1}s window, {:.1}s makespan",
            self.offered_rps,
            self.capacity_rps,
            100.0 * self.offered_rps / self.capacity_rps.max(1e-9),
            self.duration_s,
            self.makespan_s
        );
        println!(
            "completed {} ({} on time)  rejected {}  expired {}  shed {}  degraded {}",
            self.completed, self.on_time, self.rejected_full, self.expired, self.shed_slo,
            self.degraded
        );
        println!(
            "latency: p50 {:.0} ms  p95 {:.0}  p99 {:.0}  (queue wait p95 {:.0} ms)",
            self.latency_ms.p50, self.latency_ms.p95, self.latency_ms.p99, self.queue_wait_ms.p95
        );
        println!(
            "SLO attainment {:.1}%  goodput {:.1} rps  mean batch {:.2} over {} batches",
            100.0 * self.slo_attainment,
            self.goodput_rps,
            self.mean_batch,
            self.batches
        );
        println!(
            "device util: GPU {:.0}%  NPU {:.0}%  peak queue depth {}",
            100.0 * self.util_gpu,
            100.0 * self.util_npu,
            self.max_queue_depth
        );
        match self.map_25 {
            Some(m) => println!("mAP@0.25 (functional) = {:.1}", m * 100.0),
            None => println!("mAP: n/a (simulated-time run; needs artifacts + PJRT)"),
        }
    }
}

/// One scene execution request handed to the worker pool.
struct ExecJob {
    cfg: DetectorConfig,
    seed: u64,
    slot: usize,
}

type ExecResult = (usize, Result<(Vec<Box3>, Vec<Box3>)>);

/// Cache key discriminating every config field that changes pipeline
/// behaviour (the planner keys its cost cache by the stage graph's
/// fingerprint; here a config-derived string suffices — both discriminate
/// the full QuantScheme).
fn pipe_key(cfg: &DetectorConfig) -> String {
    format!(
        "{}|{}|{}|{:?}|{}|{}|{}",
        cfg.dataset,
        cfg.variant.name(),
        cfg.scheme.key(),
        cfg.schedule,
        cfg.w0,
        cfg.bias_layers,
        cfg.seg_passes
    )
}

/// Functional batch executor: runs dispatched scenes through the real
/// [`ScenePipeline`] on a pool of long-lived worker threads, so serving
/// throughput scales with host cores (each worker owns a private runtime —
/// PJRT handles are not `Send` with a real `xla` backend — and a pipeline
/// cache keyed by config). Reports then carry accuracy next to simulated
/// latency. Without a real PJRT backend the runtime's deterministic host
/// surrogate executes the NN stages, so this works offline too; if a worker
/// cannot open a runtime at all, execution errors surface on the first
/// batch and the dispatcher falls back to simulation-only (`map_25 = None`).
pub struct PipelineExecutor {
    job_tx: Option<mpsc::Sender<ExecJob>>,
    res_rx: mpsc::Receiver<ExecResult>,
    workers: Vec<std::thread::JoinHandle<()>>,
}

impl PipelineExecutor {
    /// Pool sized to the host (capped at 4 workers).
    pub fn new(rt: &Runtime, ds: &'static DatasetCfg) -> PipelineExecutor {
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        PipelineExecutor::with_workers(rt, ds, cores.min(4))
    }

    /// Pool with an explicit per-scene worker count.
    pub fn with_workers(
        rt: &Runtime,
        ds: &'static DatasetCfg,
        workers: usize,
    ) -> PipelineExecutor {
        let workers = workers.max(1);
        let cores = std::thread::available_parallelism().map(|p| p.get()).unwrap_or(1);
        // split the host's threads between scene-level and stage-level
        // parallelism so a full batch doesn't oversubscribe
        let per_worker = (cores / workers).clamp(1, 4);
        let host_exec = if per_worker > 1 {
            HostExec::Parallel { threads: per_worker }
        } else {
            HostExec::Sequential
        };
        let (job_tx, job_rx) = mpsc::channel::<ExecJob>();
        let job_rx = Arc::new(Mutex::new(job_rx));
        let (res_tx, res_rx) = mpsc::channel::<ExecResult>();
        let handles = (0..workers)
            .map(|_| {
                let rx = job_rx.clone();
                let tx = res_tx.clone();
                let source: RuntimeSource = rt.source();
                std::thread::spawn(move || worker_loop(source, ds, host_exec, &rx, &tx))
            })
            .collect();
        PipelineExecutor { job_tx: Some(job_tx), res_rx, workers: handles }
    }

    /// Execute each request's scene; returns (detections, ground truth) per
    /// request in order. Scenes of one batch run concurrently across the
    /// worker pool.
    ///
    /// Fidelity caveat: degraded batches run with the degraded *precisions*
    /// (the dispatcher passes the fast config), but at the full point budget
    /// and with fresh 2D segmentation — the accuracy reported for degraded
    /// traffic is therefore an upper bound on the fast path's true mAP.
    #[allow(clippy::type_complexity)]
    pub fn execute(
        &self,
        cfg: &DetectorConfig,
        reqs: &[Request],
    ) -> Result<Vec<(Vec<Box3>, Vec<Box3>)>> {
        let tx = self.job_tx.as_ref().expect("executor pool alive");
        for (slot, r) in reqs.iter().enumerate() {
            tx.send(ExecJob { cfg: cfg.clone(), seed: r.seed, slot })
                .map_err(|_| anyhow!("pipeline executor workers exited"))?;
        }
        let mut out: Vec<Option<(Vec<Box3>, Vec<Box3>)>> =
            (0..reqs.len()).map(|_| None).collect();
        let mut first_err: Option<anyhow::Error> = None;
        // drain exactly one result per job even on error, so a failed batch
        // cannot leak stale results into the next one
        for _ in 0..reqs.len() {
            match self.res_rx.recv() {
                Ok((slot, Ok(pair))) => out[slot] = Some(pair),
                Ok((_, Err(e))) => {
                    if first_err.is_none() {
                        first_err = Some(e);
                    }
                }
                Err(_) => return Err(anyhow!("pipeline executor workers exited")),
            }
        }
        if let Some(e) = first_err {
            return Err(e);
        }
        Ok(out.into_iter().map(|o| o.expect("every slot filled")).collect())
    }
}

impl Drop for PipelineExecutor {
    fn drop(&mut self) {
        self.job_tx.take(); // close the channel; workers drain and exit
        for h in self.workers.drain(..) {
            h.join().ok();
        }
    }
}

fn worker_loop(
    source: RuntimeSource,
    ds: &'static DatasetCfg,
    host_exec: HostExec,
    rx: &Mutex<mpsc::Receiver<ExecJob>>,
    tx: &mpsc::Sender<ExecResult>,
) {
    let rt = match source.open() {
        Ok(rt) => rt,
        Err(e) => {
            // still answer every job so the dispatcher never blocks
            let msg = format!("{e:#}");
            loop {
                let job = { rx.lock().unwrap().recv() };
                let Ok(job) = job else { return };
                let err = anyhow!("worker runtime unavailable: {msg}");
                if tx.send((job.slot, Err(err))).is_err() {
                    return;
                }
            }
        }
    };
    let mut pipes: HashMap<String, ScenePipeline<'_>> = HashMap::new();
    loop {
        let job = { rx.lock().unwrap().recv() };
        let Ok(job) = job else { return };
        let pipe = pipes.entry(pipe_key(&job.cfg)).or_insert_with(|| {
            ScenePipeline::new(&rt, job.cfg.clone()).with_host_exec(host_exec)
        });
        let scene = generate_scene(job.seed, ds);
        let gt = scene.gt_boxes();
        // a panic inside the pipeline must still produce a result, or the
        // dispatcher's recv() for this slot would block forever
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pipe.run(&scene, job.seed)
        }))
        .unwrap_or_else(|_| Err(anyhow!("worker panicked executing scene {}", job.seed)))
        .map(|out| (out.detections, gt));
        if tx.send((job.slot, res)).is_err() {
            return;
        }
    }
}

/// Run a scenario to completion on the simulated clock. Returns the report
/// plus one terminal outcome per arrival (in resolution order).
///
/// A configuration the planner cannot cost (malformed manifest, unknown
/// dataset) surfaces as an error instead of panicking a serving worker.
pub fn run_traffic_trace(
    sc: &TrafficScenario,
    planner: &ServicePlanner,
    exec: Option<&PipelineExecutor>,
) -> Result<(ServeTrafficReport, Vec<RequestOutcome>)> {
    assert!(!sc.configs.is_empty(), "scenario needs at least one detector config");
    // Build each config's stage graphs once, up front — full path and
    // degraded fast path. Per-batch costing on the hot path is then a
    // cache lookup / simulation over these; no graph construction per
    // dispatch event, and a malformed config fails the whole run here
    // instead of killing a worker mid-traffic.
    let fast_pts = slo::degraded_points(sc.num_points);
    let mut plans: Vec<(StageGraph, DetectorConfig, StageGraph)> =
        Vec::with_capacity(sc.configs.len());
    for cfg in &sc.configs {
        let full = planner.graph(cfg, sc.num_points, false)?;
        let fast_cfg = slo::degraded_config(cfg);
        let fast = planner.graph(&fast_cfg, fast_pts, true)?;
        plans.push((full, fast_cfg, fast));
    }
    let arrivals = sc.load.generate();
    let total = arrivals.len();
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(total);
    let mut queue = AdmissionQueue::new(sc.queue_capacity, 2);
    let mut now = 0.0f64;
    let mut lane_free = 0.0f64;
    let mut i = 0usize;

    let mut makespan_ms = 0.0f64;
    let mut busy_gpu = 0.0f64;
    let mut busy_npu = 0.0f64;
    let mut lat: Vec<f64> = Vec::new();
    let mut qwait: Vec<f64> = Vec::new();
    let (mut completed, mut on_time, mut shed_slo, mut degraded) = (0usize, 0usize, 0usize, 0usize);
    let (mut batches, mut batched_reqs) = (0usize, 0usize);

    // functional-accuracy accumulators (only with a working executor)
    let mut exec_ok = exec.is_some();
    let mut gts: Vec<Vec<Box3>> = Vec::new();
    let mut dets: Vec<Detection> = Vec::new();

    loop {
        // 1) ingest every arrival due at or before `now`
        while i < total && arrivals[i].arrival_ms <= now {
            let r = arrivals[i].clone();
            i += 1;
            if queue.offer(r) == AdmitResult::RejectedFull {
                outcomes.push(RequestOutcome {
                    id: arrivals[i - 1].id,
                    kind: OutcomeKind::RejectedFull,
                    on_time: false,
                });
            }
        }
        // 2) expire requests whose deadline passed while queued
        for r in queue.expire(now) {
            outcomes.push(RequestOutcome { id: r.id, kind: OutcomeKind::Expired, on_time: false });
        }
        // 3) dispatch while the lane is open
        let mut wait_hint: Option<f64> = None;
        while lane_free <= now {
            match batcher::decide(&mut queue, &sc.batch, now) {
                batcher::BatchDecision::Dispatch(batch) => {
                    let ci = batch.key.min(sc.configs.len() - 1);
                    let cfg = &sc.configs[ci];
                    let (full_graph, fast_cfg, fast_graph) = &plans[ci];
                    let k0 = batch.reqs.len();
                    let full = planner.cost_of_graph(full_graph, k0);
                    let fast = planner.cost_of_graph(fast_graph, k0);
                    let dec = slo::apply(sc.policy, batch.reqs, now, full.total_ms, fast.total_ms);
                    for r in &dec.shed {
                        shed_slo += 1;
                        outcomes.push(RequestOutcome {
                            id: r.id,
                            kind: OutcomeKind::ShedSlo,
                            on_time: false,
                        });
                    }
                    if dec.dispatch.is_empty() {
                        continue; // whole batch shed; lane still open
                    }
                    let k = dec.dispatch.len();
                    let (run_cfg, cost) = if dec.degraded {
                        (fast_cfg, planner.cost_of_graph(fast_graph, k))
                    } else {
                        (cfg, planner.cost_of_graph(full_graph, k))
                    };
                    let done = now + cost.total_ms;
                    lane_free = now + cost.bottleneck_ms;
                    makespan_ms = makespan_ms.max(done);
                    busy_gpu += cost.busy_gpu_ms;
                    busy_npu += cost.busy_npu_ms;
                    batches += 1;
                    batched_reqs += k;
                    if exec_ok {
                        match exec.expect("exec_ok implies executor").execute(run_cfg, &dec.dispatch)
                        {
                            Ok(pairs) => {
                                for (d, gt) in pairs {
                                    let scene_idx = gts.len();
                                    gts.push(gt);
                                    dets.extend(
                                        d.into_iter().map(|b| Detection { scene: scene_idx, b }),
                                    );
                                }
                            }
                            Err(e) => {
                                eprintln!(
                                    "functional execution disabled ({e:#}); continuing simulated-only"
                                );
                                exec_ok = false;
                            }
                        }
                    }
                    for r in &dec.dispatch {
                        lat.push(done - r.arrival_ms);
                        qwait.push(now - r.arrival_ms);
                        completed += 1;
                        let met = done <= r.deadline_ms;
                        if met {
                            on_time += 1;
                        }
                        if dec.degraded {
                            degraded += 1;
                        }
                        outcomes.push(RequestOutcome {
                            id: r.id,
                            kind: OutcomeKind::Completed,
                            on_time: met,
                        });
                    }
                }
                batcher::BatchDecision::WaitUntil(t) => {
                    wait_hint = Some(t);
                    break;
                }
                batcher::BatchDecision::Idle => break,
            }
        }
        // 4) advance the clock to the next event
        let mut t_next = f64::INFINITY;
        if let Some(r) = arrivals.get(i) {
            t_next = t_next.min(r.arrival_ms);
        }
        if !queue.is_empty() {
            if lane_free > now {
                t_next = t_next.min(lane_free);
            }
            if let Some(t) = wait_hint {
                t_next = t_next.min(t);
            }
        }
        if !t_next.is_finite() {
            break;
        }
        debug_assert!(t_next > now, "virtual clock must advance ({t_next} vs {now})");
        now = t_next;
    }

    let map_25 = if exec_ok && !gts.is_empty() {
        Some(eval_map(&dets, &gts, planner.manifest().num_class(), 0.25).map)
    } else {
        None
    };
    let makespan_s = (makespan_ms / 1000.0).max(sc.load.duration_ms / 1000.0).max(1e-9);
    let report = ServeTrafficReport {
        scenario: sc.name.clone(),
        pattern: sc.load.pattern.name(),
        policy: sc.policy.name(),
        offered_rps: sc.load.pattern.mean_rps(),
        capacity_rps: planner.capacity_rps(&sc.configs[0], sc.num_points, sc.batch.max_batch)?,
        duration_s: sc.load.duration_ms / 1000.0,
        makespan_s,
        arrivals: total,
        completed,
        on_time,
        rejected_full: queue.stats.rejected_full as usize,
        expired: queue.stats.expired as usize,
        shed_slo,
        degraded,
        batches,
        mean_batch: if batches > 0 { batched_reqs as f64 / batches as f64 } else { 0.0 },
        latency_ms: Stats::from(lat),
        queue_wait_ms: Stats::from(qwait),
        slo_attainment: if total > 0 { on_time as f64 / total as f64 } else { 1.0 },
        goodput_rps: on_time as f64 / makespan_s,
        util_gpu: busy_gpu / 1000.0 / makespan_s,
        util_npu: busy_npu / 1000.0 / makespan_s,
        max_queue_depth: queue.stats.max_depth,
        map_25,
    };
    Ok((report, outcomes))
}

/// Run a scenario and return just the report.
pub fn run_traffic(
    sc: &TrafficScenario,
    planner: &ServicePlanner,
    exec: Option<&PipelineExecutor>,
) -> Result<ServeTrafficReport> {
    Ok(run_traffic_trace(sc, planner, exec)?.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Schedule, Variant};
    use crate::serving::loadgen::ArrivalPattern;
    use crate::sim::DeviceKind;

    fn scenario(rate_mult: f64, policy: SloPolicy, seed: u64) -> TrafficScenario {
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        let planner = ServicePlanner::synthetic();
        let cap = planner.capacity_rps(&cfg, 2048, 4).unwrap();
        TrafficScenario {
            name: format!("test-{rate_mult}x"),
            configs: vec![cfg],
            num_points: 2048,
            load: LoadGen::simple(
                ArrivalPattern::Poisson { rate_rps: cap * rate_mult },
                20_000.0,
                2_000.0,
                seed,
            ),
            queue_capacity: 32,
            batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
            policy,
        }
    }

    #[test]
    fn underload_meets_slo() {
        let planner = ServicePlanner::synthetic();
        let sc = scenario(0.25, SloPolicy::None, 3);
        let (rep, outcomes) = run_traffic_trace(&sc, &planner, None).unwrap();
        assert_eq!(outcomes.len(), rep.arrivals);
        assert!(rep.arrivals > 0);
        assert!(rep.slo_attainment > 0.9, "underload attainment {}", rep.slo_attainment);
        assert_eq!(rep.completed + rep.rejected_full + rep.expired + rep.shed_slo, rep.arrivals);
        assert!(rep.map_25.is_none());
    }

    #[test]
    fn deterministic_runs() {
        let planner = ServicePlanner::synthetic();
        let sc = scenario(1.2, SloPolicy::Degrade, 9);
        let a = run_traffic(&sc, &planner, None).unwrap();
        let b = run_traffic(&sc, &planner, None).unwrap();
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.latency_ms.p99, b.latency_ms.p99);
    }

    #[test]
    fn overload_policy_beats_none() {
        let planner = ServicePlanner::synthetic();
        let none = run_traffic(&scenario(2.0, SloPolicy::None, 17), &planner, None).unwrap();
        let deg = run_traffic(&scenario(2.0, SloPolicy::Degrade, 17), &planner, None).unwrap();
        assert!(
            deg.goodput_rps > none.goodput_rps,
            "degradation must raise goodput under 2x overload: {} vs {}",
            deg.goodput_rps,
            none.goodput_rps
        );
        assert!(deg.degraded > 0, "2x overload must trigger degradation");
    }

    #[test]
    fn overload_batches_grow() {
        let planner = ServicePlanner::synthetic();
        let under = run_traffic(&scenario(0.3, SloPolicy::None, 21), &planner, None).unwrap();
        let over = run_traffic(&scenario(1.8, SloPolicy::None, 21), &planner, None).unwrap();
        assert!(
            over.mean_batch > under.mean_batch,
            "queueing pressure should fill batches: {} vs {}",
            over.mean_batch,
            under.mean_batch
        );
    }
}
