"""Procedural RGB-D scene generator (SynRGBD / SynScan).

Substitute for SUN RGB-D / ScanNet V2 (see DESIGN.md §2). A scene is a room
(floor + two walls) populated with parametric furniture of 10 classes. Each
object is a composition of axis-aligned cuboid *parts* in a canonical frame,
rotated by a yaw heading and translated onto the floor. Points are sampled on
all surfaces; SynRGBD applies single-viewpoint visibility culling + depth
noise, SynScan keeps full coverage (multi-view scan). A 64x64 RGB render and
a ground-truth segmentation mask are produced by splatting points through a
pinhole camera with a z-buffer.

The Rust mirror lives in rust/src/data/; the two generators are
*distributionally* identical (same shape programs, same parameter ranges) —
parity is asserted statistically in tests on both sides.
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

import numpy as np

from . import common
from .common import IMG_SIZE, NUM_CLASS, DatasetConfig

# ---------------------------------------------------------------------------
# Shape programs: each returns a list of cuboid parts
# (cx, cy, cz, sx, sy, sz) in the object canonical frame (z up, resting on
# z=0, footprint centered on the origin). Sizes (w, d, h) are the overall
# bounding dims of the object.
# ---------------------------------------------------------------------------


def _legs(w: float, d: float, h: float, t: float = 0.05) -> List[Tuple[float, ...]]:
    """Four legs of thickness t under a top at height h."""
    dx, dy = w / 2 - t / 2, d / 2 - t / 2
    return [(sx * dx, sy * dy, h / 2, t, t, h) for sx in (-1, 1) for sy in (-1, 1)]


def _parts_bed(w, d, h):
    # mattress + headboard at -y end
    return [(0, 0, h * 0.35, w, d, h * 0.7), (0, -d / 2 + 0.05, h * 0.85, w, 0.1, h * 1.7)]


def _parts_table(w, d, h):
    top_t = 0.06
    return [(0, 0, h - top_t / 2, w, d, top_t)] + _legs(w, d, h - top_t)


def _parts_sofa(w, d, h):
    seat_h = h * 0.55
    parts = [(0, 0, seat_h / 2, w, d, seat_h)]
    parts.append((0, -d / 2 + 0.08, h / 2 + seat_h * 0.2, w, 0.16, h))  # back
    arm_w = 0.12
    for s in (-1, 1):
        parts.append((s * (w / 2 - arm_w / 2), 0, h * 0.4, arm_w, d, h * 0.8))
    return parts


def _parts_chair(w, d, h):
    seat_h = h * 0.55
    seat_t = 0.05
    parts = [(0, 0, seat_h - seat_t / 2, w, d, seat_t)]
    parts += _legs(w, d, seat_h - seat_t)
    parts.append((0, -d / 2 + 0.025, seat_h + (h - seat_h) / 2, w, 0.05, h - seat_h))
    return parts


def _parts_toilet(w, d, h):
    bowl_h = h * 0.55
    return [
        (0, d * 0.1, bowl_h / 2, w, d * 0.8, bowl_h),
        (0, -d / 2 + 0.07, bowl_h + (h - bowl_h) / 2, w, 0.14, h - bowl_h),
    ]


def _parts_desk(w, d, h):
    top_t = 0.05
    parts = [(0, 0, h - top_t / 2, w, d, top_t)]
    parts += _legs(w, d, h - top_t)
    # side panel (drawer column)
    parts.append((w / 2 - 0.15, 0, (h - top_t) / 2, 0.3, d * 0.9, h - top_t))
    return parts


def _parts_box(w, d, h):
    return [(0, 0, h / 2, w, d, h)]


# size ranges per class: ((w_lo, w_hi), (d_lo, d_hi), (h_lo, h_hi))
_CLASS_SPECS = [
    ("bed", _parts_bed, (1.6, 2.1), (1.4, 1.9), (0.4, 0.6)),
    ("table", _parts_table, (1.0, 1.8), (0.6, 1.1), (0.65, 0.78)),
    ("sofa", _parts_sofa, (1.5, 2.2), (0.8, 1.0), (0.7, 0.8)),
    ("chair", _parts_chair, (0.4, 0.55), (0.4, 0.55), (0.75, 0.95)),
    ("toilet", _parts_toilet, (0.35, 0.45), (0.5, 0.6), (0.7, 0.8)),
    ("desk", _parts_desk, (1.1, 1.5), (0.6, 0.8), (0.7, 0.78)),
    ("dresser", _parts_box, (0.8, 1.2), (0.4, 0.6), (0.8, 1.1)),
    ("nightstand", _parts_box, (0.4, 0.6), (0.4, 0.6), (0.5, 0.7)),
    ("bookshelf", _parts_box, (0.6, 1.0), (0.25, 0.35), (1.5, 2.0)),
    ("bathtub", _parts_box, (1.4, 1.8), (0.7, 0.9), (0.5, 0.6)),
]
assert [s[0] for s in _CLASS_SPECS] == common.CLASSES

# Base RGB color per class for the render (plus background gray).
_CLASS_COLORS = np.array(
    [
        [0.85, 0.30, 0.30],  # bed
        [0.55, 0.35, 0.20],  # table
        [0.30, 0.55, 0.85],  # sofa
        [0.90, 0.65, 0.20],  # chair
        [0.90, 0.90, 0.95],  # toilet
        [0.45, 0.30, 0.55],  # desk
        [0.35, 0.60, 0.35],  # dresser
        [0.70, 0.55, 0.35],  # nightstand
        [0.60, 0.20, 0.45],  # bookshelf
        [0.25, 0.75, 0.75],  # bathtub
    ],
    dtype=np.float32,
)
_BG_COLOR = np.array([0.55, 0.55, 0.58], dtype=np.float32)


@dataclasses.dataclass
class SceneObject:
    cls: int
    center: np.ndarray  # (3,) bbox center
    size: np.ndarray  # (3,) full extents (w, d, h)
    heading: float  # yaw, radians in [0, 2pi)
    parts: np.ndarray  # (P, 6) canonical cuboids


@dataclasses.dataclass
class Scene:
    """One synthetic RGB-D scene with full ground truth."""

    points: np.ndarray  # (N, 3) float32
    point_obj: np.ndarray  # (N,) int32 index into objects, -1 for background
    image: np.ndarray  # (H, W, 3) float32 RGB in [0,1]
    seg_mask: np.ndarray  # (H, W) int32, 0 = background, 1+cls otherwise
    objects: List[SceneObject]
    cam_pos: np.ndarray  # (3,)
    cam_rot: np.ndarray  # (3, 3) world->camera
    fx: float

    def boxes(self) -> np.ndarray:
        """(num_obj, 8): cx cy cz w d h heading cls."""
        if not self.objects:
            return np.zeros((0, 8), dtype=np.float32)
        return np.stack(
            [
                np.concatenate([o.center, o.size, [o.heading, float(o.cls)]]).astype(np.float32)
                for o in self.objects
            ]
        )


def _rot_z(theta: float) -> np.ndarray:
    c, s = np.cos(theta), np.sin(theta)
    return np.array([[c, -s, 0.0], [s, c, 0.0], [0.0, 0.0, 1.0]], dtype=np.float64)


def _sample_cuboid_surface(rng: np.random.Generator, part, n: int) -> Tuple[np.ndarray, np.ndarray]:
    """Sample n points on the surface of an axis-aligned cuboid part.

    Returns (points (n,3), normals (n,3)). Faces are chosen proportionally to
    area; the bottom face is skipped (never visible indoors).
    """
    cx, cy, cz, sx, sy, sz = part
    # faces: +x -x +y -y +z  (skip -z)
    areas = np.array([sy * sz, sy * sz, sx * sz, sx * sz, sx * sy], dtype=np.float64)
    face = rng.choice(5, size=n, p=areas / areas.sum())
    u = rng.uniform(-0.5, 0.5, size=n)
    v = rng.uniform(-0.5, 0.5, size=n)
    pts = np.empty((n, 3), dtype=np.float64)
    nrm = np.zeros((n, 3), dtype=np.float64)
    for f, (axis, sign) in enumerate([(0, 1), (0, -1), (1, 1), (1, -1), (2, 1)]):
        m = face == f
        if not m.any():
            continue
        p = np.empty((m.sum(), 3))
        if axis == 0:
            p[:, 0] = sign * sx / 2
            p[:, 1] = u[m] * sy
            p[:, 2] = v[m] * sz
        elif axis == 1:
            p[:, 0] = u[m] * sx
            p[:, 1] = sign * sy / 2
            p[:, 2] = v[m] * sz
        else:
            p[:, 0] = u[m] * sx
            p[:, 1] = v[m] * sy
            p[:, 2] = sign * sz / 2
        pts[m] = p + np.array([cx, cy, cz])
        nrm[m, axis] = sign
    return pts, nrm


def _place_objects(rng: np.random.Generator, cfg: DatasetConfig, room: float) -> List[SceneObject]:
    n_obj = int(rng.integers(cfg.min_objects, cfg.max_objects + 1))
    objects: List[SceneObject] = []
    tries = 0
    while len(objects) < n_obj and tries < 80:
        tries += 1
        cls = int(rng.integers(0, NUM_CLASS))
        _, prog, wr, dr, hr = _CLASS_SPECS[cls]
        w = float(rng.uniform(*wr))
        d = float(rng.uniform(*dr))
        h = float(rng.uniform(*hr))
        heading = float(rng.uniform(0.0, 2 * np.pi))
        # keep footprint inside the room with margin
        rad = 0.5 * np.hypot(w, d)
        if room / 2 - rad - 0.1 <= 0.3:
            continue
        cx = float(rng.uniform(-(room / 2 - rad - 0.1), room / 2 - rad - 0.1))
        cy = float(rng.uniform(-(room / 2 - rad - 0.1), room / 2 - rad - 0.1))
        # overlap rejection on circumscribed circles
        ok = True
        for o in objects:
            orad = 0.5 * np.hypot(o.size[0], o.size[1])
            if np.hypot(cx - o.center[0], cy - o.center[1]) < rad + orad + 0.05:
                ok = False
                break
        if not ok:
            continue
        parts = np.array(prog(w, d, h), dtype=np.float64)
        objects.append(
            SceneObject(
                cls=cls,
                center=np.array([cx, cy, h / 2], dtype=np.float32),
                size=np.array([w, d, h], dtype=np.float32),
                heading=heading,
                parts=parts,
            )
        )
    return objects


def _camera(rng: np.random.Generator, room: float) -> Tuple[np.ndarray, np.ndarray, float]:
    """Camera on the room boundary at eye height looking at the center."""
    ang = float(rng.uniform(0, 2 * np.pi))
    cam = np.array(
        [np.cos(ang) * room * 0.55, np.sin(ang) * room * 0.55, float(rng.uniform(1.2, 1.7))]
    )
    target = np.array([0.0, 0.0, 0.8])
    fwd = target - cam
    fwd /= np.linalg.norm(fwd)
    right = np.cross(fwd, np.array([0.0, 0.0, 1.0]))
    right /= np.linalg.norm(right)
    up = np.cross(right, fwd)
    # world->camera rows: x=right, y=down(-up), z=forward
    rot = np.stack([right, -up, fwd])
    fx = IMG_SIZE * 0.9  # ~58 deg horizontal FoV
    return cam, rot, fx


def generate_scene(seed: int, cfg: DatasetConfig) -> Scene:
    """Generate one deterministic scene."""
    rng = np.random.default_rng(seed)
    room = float(rng.uniform(cfg.room_min, cfg.room_max))
    objects = _place_objects(rng, cfg, room)
    cam, rot, fx = _camera(rng, room)

    n_target = cfg.num_points
    raw = 6 * n_target  # candidate pool before culling/subsampling
    # budget: 55% objects, 45% background (floor + 2 walls)
    pts_list, nrm_list, obj_list = [], [], []

    total_area = sum(
        float(np.sum(2 * (p[:, 3] * p[:, 4] + p[:, 4] * p[:, 5] + p[:, 3] * p[:, 5])))
        for o in objects
        for p in [o.parts]
    )
    n_obj_pts = int(raw * 0.55)
    for oi, o in enumerate(objects):
        area = float(np.sum(2 * (o.parts[:, 3] * o.parts[:, 4] + o.parts[:, 4] * o.parts[:, 5] + o.parts[:, 3] * o.parts[:, 5])))
        n_o = max(32, int(n_obj_pts * area / max(total_area, 1e-6)))
        part_areas = 2 * (o.parts[:, 3] * o.parts[:, 4] + o.parts[:, 4] * o.parts[:, 5] + o.parts[:, 3] * o.parts[:, 5])
        counts = rng.multinomial(n_o, part_areas / part_areas.sum())
        R = _rot_z(o.heading)
        for part, c in zip(o.parts, counts):
            if c == 0:
                continue
            p, nr = _sample_cuboid_surface(rng, part, int(c))
            p = p @ R.T + np.array([o.center[0], o.center[1], 0.0])
            nr = nr @ R.T
            pts_list.append(p)
            nrm_list.append(nr)
            obj_list.append(np.full(int(c), oi, dtype=np.int32))

    # background: floor + two walls behind the scene (opposite the camera)
    n_bg = raw - sum(len(p) for p in pts_list)
    n_floor = int(n_bg * 0.6)
    floor = np.stack(
        [
            rng.uniform(-room / 2, room / 2, n_floor),
            rng.uniform(-room / 2, room / 2, n_floor),
            np.zeros(n_floor),
        ],
        axis=1,
    )
    pts_list.append(floor)
    nrm_list.append(np.tile([0.0, 0.0, 1.0], (n_floor, 1)))
    obj_list.append(np.full(n_floor, -1, dtype=np.int32))
    n_wall = n_bg - n_floor
    # wall planes on the far side from the camera
    wx = -np.sign(cam[0]) * room / 2
    wy = -np.sign(cam[1]) * room / 2
    half = n_wall // 2
    wall1 = np.stack(
        [np.full(half, wx), rng.uniform(-room / 2, room / 2, half), rng.uniform(0, 2.2, half)],
        axis=1,
    )
    wall2 = np.stack(
        [
            rng.uniform(-room / 2, room / 2, n_wall - half),
            np.full(n_wall - half, wy),
            rng.uniform(0, 2.2, n_wall - half),
        ],
        axis=1,
    )
    pts_list += [wall1, wall2]
    nrm_list += [
        np.tile([np.sign(cam[0]), 0.0, 0.0], (half, 1)),
        np.tile([0.0, np.sign(cam[1]), 0.0], (n_wall - half, 1)),
    ]
    obj_list += [np.full(half, -1, dtype=np.int32), np.full(n_wall - half, -1, dtype=np.int32)]

    pts = np.concatenate(pts_list)
    nrm = np.concatenate(nrm_list)
    obj = np.concatenate(obj_list)

    if cfg.single_view:
        # visibility: surface must face the camera and be in front of it
        to_cam = cam[None, :] - pts
        facing = np.einsum("nd,nd->n", to_cam, nrm) > 0
        in_front = (pts - cam[None, :]) @ rot[2] > 0.3
        keep = facing & in_front
        pts, obj = pts[keep], obj[keep]

    # render BEFORE subsampling so the image has dense coverage
    image, seg = _render(rng, pts, obj, objects, cam, rot, fx, cfg)

    # subsample to the dataset budget
    if len(pts) >= cfg.num_points:
        sel = rng.choice(len(pts), cfg.num_points, replace=False)
    else:
        sel = rng.choice(max(len(pts), 1), cfg.num_points, replace=True)
    pts, obj = pts[sel], obj[sel]
    pts = pts + rng.normal(0, cfg.depth_noise, pts.shape)

    return Scene(
        points=pts.astype(np.float32),
        point_obj=obj,
        image=image,
        seg_mask=seg,
        objects=objects,
        cam_pos=cam.astype(np.float32),
        cam_rot=rot.astype(np.float32),
        fx=fx,
    )


def project(points: np.ndarray, cam: np.ndarray, rot: np.ndarray, fx: float) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Pinhole projection. Returns (u, v, depth) as float arrays."""
    pc = (points - cam[None, :]) @ rot.T
    z = np.maximum(pc[:, 2], 1e-6)
    u = fx * pc[:, 0] / z + IMG_SIZE / 2
    v = fx * pc[:, 1] / z + IMG_SIZE / 2
    return u, v, pc[:, 2]


def _render(rng, pts, obj, objects, cam, rot, fx, cfg):
    """Z-buffered point splat -> RGB image + GT segmentation mask."""
    u, v, z = project(pts, cam, rot, fx)
    ui = np.floor(u).astype(np.int64)
    vi = np.floor(v).astype(np.int64)
    ok = (ui >= 0) & (ui < IMG_SIZE) & (vi >= 0) & (vi < IMG_SIZE) & (z > 0.05)
    ui, vi, zi, oi = ui[ok], vi[ok], z[ok], obj[ok]
    flat = vi * IMG_SIZE + ui
    order = np.argsort(-zi)  # far first so near points overwrite
    flat, oi, zi = flat[order], oi[order], zi[order]
    seg = np.zeros(IMG_SIZE * IMG_SIZE, dtype=np.int32)
    img = np.tile(_BG_COLOR, (IMG_SIZE * IMG_SIZE, 1)).copy()
    # background shading gradient
    yy = np.repeat(np.linspace(0.9, 1.1, IMG_SIZE), IMG_SIZE)
    img *= yy[:, None]
    cls_of = np.array([o.cls for o in objects] + [-1], dtype=np.int32)
    lab = np.where(oi >= 0, cls_of[oi], -1)
    seg[flat] = lab + 1
    shade = np.clip(1.0 - zi / 12.0, 0.45, 1.0)
    color = np.where(
        (lab >= 0)[:, None],
        _CLASS_COLORS[np.clip(lab, 0, NUM_CLASS - 1)] * shade[:, None],
        img[flat],
    )
    img[flat] = color
    img += rng.normal(0, 0.03, img.shape)
    # label-noise: corrupt a fraction of mask pixels (sensor/annotation noise)
    n_noise = int(cfg.seg_noise * IMG_SIZE * IMG_SIZE)
    idx = rng.integers(0, IMG_SIZE * IMG_SIZE, n_noise)
    seg[idx] = rng.integers(0, NUM_CLASS + 1, n_noise)
    return (
        np.clip(img, 0, 1).astype(np.float32).reshape(IMG_SIZE, IMG_SIZE, 3),
        seg.reshape(IMG_SIZE, IMG_SIZE),
    )


def paint_points(points: np.ndarray, seg_scores: np.ndarray, cam, rot, fx) -> np.ndarray:
    """PointPainting: append per-pixel segmentation scores to each 3D point.

    seg_scores: (H, W, NUM_SEG_CLASSES) softmax scores. Points projecting
    outside the image get a one-hot background vector.
    """
    u, v, z = project(points, cam, rot, fx)
    ui = np.clip(np.floor(u).astype(np.int64), 0, IMG_SIZE - 1)
    vi = np.clip(np.floor(v).astype(np.int64), 0, IMG_SIZE - 1)
    inside = (u >= 0) & (u < IMG_SIZE) & (v >= 0) & (v < IMG_SIZE) & (z > 0)
    out = seg_scores[vi, ui].astype(np.float32)
    bg = np.zeros_like(out)
    bg[:, 0] = 1.0
    return np.where(inside[:, None], out, bg)


def point_fg_mask(scores: np.ndarray, thresh: float = 0.5) -> np.ndarray:
    """Foreground mask from painted scores: P(not background) > thresh."""
    return (1.0 - scores[:, 0]) > thresh


def vote_targets(points: np.ndarray, scene: Scene) -> Tuple[np.ndarray, np.ndarray]:
    """Per-point vote supervision: (mask (N,), offset to owning box center (N,3)).

    A point votes if it belongs to an object (is inside any GT box, using the
    generator's point->object assignment transferred by proximity).
    """
    n = len(points)
    mask = np.zeros(n, dtype=np.float32)
    off = np.zeros((n, 3), dtype=np.float32)
    for o in scene.objects:
        R = _rot_z(o.heading)[:2, :2]
        local = (points[:, :2] - o.center[None, :2]) @ R  # rotate into box frame
        inside = (
            (np.abs(local[:, 0]) < o.size[0] / 2 + 0.05)
            & (np.abs(local[:, 1]) < o.size[1] / 2 + 0.05)
            & (points[:, 2] > -0.05)
            & (points[:, 2] < o.size[2] + 0.05)
        )
        new = inside & (mask < 0.5)
        mask[new] = 1.0
        off[new] = o.center[None, :] - points[new]
    return mask, off
