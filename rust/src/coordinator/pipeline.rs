//! Per-scene detection pipeline: functional execution + simulated timeline.
//!
//! Every stage is executed for real (Rust point ops / PJRT executables) and
//! simultaneously recorded as a [`StageSpec`] so the calibrated device model
//! can replay the schedule. The PointSplit schedule reproduces Fig. 3:
//! SA-normal point manipulation jump-starts concurrently with 2D
//! segmentation; afterwards the GPU lane (point manip) and NPU lane
//! (PointNet) alternate between the two half-pipelines.

use anyhow::{anyhow, Result};

use super::arch::{nn_workload, peak_memory_mb, sa_pointmanip_workload, small_pointop};
use super::decode::decode_detections;
use super::{Schedule, Variant};
use crate::data::{Box3, Scene};
use crate::pointops;
use crate::runtime::Runtime;
use crate::sim::{DeviceKind, ScheduleSim, StageSpec, Timeline};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Full configuration of one detector instantiation.
#[derive(Debug, Clone)]
pub struct DetectorConfig {
    pub dataset: String,
    pub variant: Variant,
    /// "fp32" or "int8" (backbone / segmenter artifacts)
    pub precision_backbone: String,
    /// "fp32", "int8_layer", "int8_group", "int8_channel", "int8_role"
    pub precision_head: String,
    pub schedule: Schedule,
    pub w0: f32,
    pub bias_layers: usize,
    pub obj_thresh: f32,
    pub nms_iou: f64,
    /// number of segmentation passes per scene (paper: 3 for ScanNet)
    pub seg_passes: usize,
}

impl DetectorConfig {
    pub fn new(dataset: &str, variant: Variant, int8: bool, schedule: Schedule) -> Self {
        DetectorConfig {
            dataset: dataset.to_string(),
            variant,
            precision_backbone: if int8 { "int8" } else { "fp32" }.to_string(),
            precision_head: if int8 {
                // paper Table 7: role-based for PointSplit, layer-wise others
                if variant == Variant::PointSplit { "int8_role" } else { "int8_layer" }
            } else {
                "fp32"
            }
            .to_string(),
            schedule,
            w0: 2.0,
            bias_layers: 2,
            obj_thresh: 0.02,
            nms_iou: 0.25,
            seg_passes: if dataset == "synscan" { 3 } else { 1 },
        }
    }

    /// Artifact name for one of this configuration's networks (shared with
    /// the serving planner, which builds the same DAG without executing it).
    pub(crate) fn art(&self, net: &str) -> String {
        let prec = match net {
            "vote" | "prop" => self.precision_head.as_str(),
            _ => self.precision_backbone.as_str(),
        };
        format!("{}_{}_{}_{}", self.dataset, self.variant.model_name(), net, prec)
    }

    pub(crate) fn seg_art(&self) -> String {
        format!("{}_seg_{}", self.dataset, self.precision_backbone)
    }

    pub fn int8(&self) -> bool {
        self.precision_backbone == "int8"
    }
}

/// Result of running one scene through the pipeline.
#[derive(Debug, Clone)]
pub struct PipelineOutput {
    pub detections: Vec<Box3>,
    pub timeline: Timeline,
    pub peak_memory_mb: f64,
    /// wall-clock of the functional execution on this host (for §Perf)
    pub host_ms: f64,
}

/// One SA pipeline's rolling state.
struct PipeState {
    xyz: Vec<[f32; 3]>,
    feats: Option<Tensor>,
    fg: Vec<f32>,
    /// simulator stage index of the last NN stage in this pipeline
    last_nn: Option<usize>,
}

pub struct ScenePipeline<'a> {
    pub rt: &'a Runtime,
    pub cfg: DetectorConfig,
    sim: ScheduleSim,
}

impl<'a> ScenePipeline<'a> {
    pub fn new(rt: &'a Runtime, cfg: DetectorConfig) -> Self {
        ScenePipeline { rt, cfg, sim: ScheduleSim::new() }
    }

    /// Run one scene. `seed` feeds the RandomSplit permutation.
    pub fn run(&self, scene: &Scene, seed: u64) -> Result<PipelineOutput> {
        self.run_with_scores(scene, seed, None).map(|(out, _)| out)
    }

    /// Run one scene, optionally reusing 2D segmentation scores from a
    /// previous frame ("consecutive matching", paper §3.2): when
    /// `prev_scores` is given, the segmenter stage is skipped entirely —
    /// zero NPU time for 2D — at the cost of stale semantics. Returns the
    /// pipeline output plus the scores used (for the caller to carry
    /// forward to the next frame).
    pub fn run_with_scores(
        &self,
        scene: &Scene,
        seed: u64,
        prev_scores: Option<&Tensor>,
    ) -> Result<(PipelineOutput, Option<Tensor>)> {
        let t_host = std::time::Instant::now();
        let cfg = &self.cfg;
        let m = &self.rt.manifest;
        let point_dev = cfg.schedule.point_dev();
        // the EdgeTPU executes int8 only (the paper's motivation for full
        // quantization); fp32 configurations fall back to the point device
        let mut nn_dev = cfg.schedule.nn_dev();
        if !cfg.int8() && nn_dev == DeviceKind::EdgeTpu {
            nn_dev = point_dev;
        }
        let mut stages: Vec<StageSpec> = Vec::new();
        let mut prev_any: Option<usize> = None; // strict chaining when sequential
        let sequential = !cfg.schedule.overlapped();

        let mut push = |stages: &mut Vec<StageSpec>,
                        name: String,
                        device: DeviceKind,
                        workload: crate::sim::Workload,
                        mut deps: Vec<usize>|
         -> usize {
            if sequential {
                if let Some(p) = prev_any {
                    if !deps.contains(&p) {
                        deps.push(p);
                    }
                }
            }
            stages.push(StageSpec { name, device, workload, deps });
            prev_any = Some(stages.len() - 1);
            stages.len() - 1
        };

        // ------------------------------------------------------ 2D segment
        let mut used_scores: Option<Tensor> = None;
        let (paint, fg, seg_stage) = if cfg.variant.painted() {
            let scores2d = match prev_scores {
                // consecutive matching: reuse the previous frame's scores
                Some(prev) => prev.clone(),
                None => {
                    let img =
                        Tensor::new(vec![m.img_size, m.img_size, 3], scene.image.clone());
                    self.rt.run(&cfg.seg_art(), &[&img])?.remove(0)
                }
            };
            let deps_paint = if prev_scores.is_none() {
                let mut wl = nn_workload(m, &cfg.seg_art());
                wl.flops *= cfg.seg_passes as u64;
                vec![push(&mut stages, "seg".into(), nn_dev, wl, vec![])]
            } else {
                Vec::new() // no 2D work this frame
            };
            let paint = pointops::paint_points(scene, &scores2d);
            let fg = pointops::fg_mask(&paint, 0.5);
            let p = push(
                &mut stages,
                "paint".into(),
                point_dev,
                small_pointop(
                    (scene.points.len() * 8) as u64,
                    (scene.points.len() * m.num_seg_classes) as u64,
                ),
                deps_paint,
            );
            used_scores = Some(scores2d);
            (Some(paint), fg, Some(p))
        } else {
            (None, vec![0.0; scene.points.len()], None)
        };
        let feats = pointops::build_features(scene, paint.as_ref());

        // ------------------------------------------------------ backbone
        let (sa2, sa3) = match cfg.variant {
            Variant::VoteNet | Variant::PointPainting => {
                let init = PipeState {
                    xyz: scene.points.clone(),
                    feats: Some(feats),
                    fg,
                    last_nn: seg_stage,
                };
                let levels = self.run_sa_chain(
                    &mut stages,
                    &mut push,
                    init,
                    "full",
                    false,
                    1.0,
                    point_dev,
                    nn_dev,
                    seg_stage,
                )?;
                (levels.0, levels.1)
            }
            Variant::PointSplit => {
                // SA-normal jump-starts (its point manip does not need seg);
                // SA-bias waits for painting (biased FPS needs fg)
                let sn = PipeState {
                    xyz: scene.points.clone(),
                    feats: Some(feats.clone()),
                    fg: fg.clone(),
                    last_nn: seg_stage,
                };
                let sb = PipeState {
                    xyz: scene.points.clone(),
                    feats: Some(feats),
                    fg,
                    last_nn: seg_stage,
                };
                let ln = self.run_sa_chain(
                    &mut stages, &mut push, sn, "normal", false, 1.0, point_dev, nn_dev, seg_stage,
                )?;
                let lb = self.run_sa_chain(
                    &mut stages, &mut push, sb, "bias", true, cfg.w0, point_dev, nn_dev, seg_stage,
                )?;
                (merge(ln.0, lb.0), merge(ln.1, lb.1))
            }
            Variant::RandomSplit => {
                let mut rng = Rng::new(seed ^ 0xB5);
                let perm = rng.choice_no_replace(scene.points.len(), scene.points.len());
                let half = scene.points.len() / 2;
                let mk = |idx: &[usize]| PipeState {
                    xyz: idx.iter().map(|&i| scene.points[i]).collect(),
                    feats: Some(feats.gather_rows(idx)),
                    fg: idx.iter().map(|&i| fg[i]).collect(),
                    last_nn: seg_stage,
                };
                let la = self.run_sa_chain(
                    &mut stages, &mut push, mk(&perm[..half]), "randA", false, 1.0, point_dev,
                    nn_dev, seg_stage,
                )?;
                let lb = self.run_sa_chain(
                    &mut stages, &mut push, mk(&perm[half..]), "randB", false, 1.0, point_dev,
                    nn_dev, seg_stage,
                )?;
                (merge(la.0, lb.0), merge(la.1, lb.1))
            }
        };

        // SA4 over the fused SA3 set (biased only in the Table 10 "all SA
        // layers" ablation: bias_layers >= 4)
        let sa4cfg = &m.sa_configs[3];
        let deps4 = sa3.last_nn.into_iter().collect::<Vec<_>>();
        let idx4 = if cfg.bias_layers >= 4 && cfg.variant == Variant::PointSplit {
            pointops::biased_fps(&sa3.xyz, sa4cfg.m, &sa3.fg, cfg.w0)
        } else {
            pointops::fps(&sa3.xyz, sa4cfg.m)
        };
        let groups4 = pointops::ball_query(&sa3.xyz, &idx4, sa4cfg.radius, sa4cfg.k);
        let g4 = pointops::group_features(&sa3.xyz, sa3.feats.as_ref(), &idx4, &groups4);
        let pm4 = push(
            &mut stages,
            "sa4_pm".into(),
            point_dev,
            sa_pointmanip_workload(sa3.xyz.len(), sa4cfg.m, sa4cfg.k, sa3.feats.as_ref().unwrap().row_len()),
            deps4,
        );
        let sa4_feats = self.rt.run(&cfg.art("sa4_full"), &[&g4])?.remove(0);
        let nn4 = push(
            &mut stages,
            "sa4_nn".into(),
            nn_dev,
            nn_workload(m, &cfg.art("sa4_full")),
            vec![pm4],
        );
        let sa4_xyz: Vec<[f32; 3]> = idx4.iter().map(|&i| sa3.xyz[i]).collect();

        // ------------------------------------------------------ FP + heads
        let f3up = pointops::three_nn_interpolate(&sa3.xyz, &sa4_xyz, &sa4_feats);
        let f3 = hconcat(sa3.feats.as_ref().unwrap(), &f3up);
        let f2up = pointops::three_nn_interpolate(&sa2.xyz, &sa3.xyz, &f3);
        let f2 = hconcat(sa2.feats.as_ref().unwrap(), &f2up);
        let fp_pm = push(
            &mut stages,
            "fp_interp".into(),
            point_dev,
            small_pointop(
                (sa2.xyz.len() * sa3.xyz.len() * 4) as u64,
                (f2.len() * 4) as u64,
            ),
            vec![nn4],
        );
        let seeds = self.rt.run(&cfg.art("fp_fc"), &[&f2])?.remove(0);
        let fp_nn = push(
            &mut stages,
            "fp_fc".into(),
            nn_dev,
            nn_workload(m, &cfg.art("fp_fc")),
            vec![fp_pm],
        );

        let vote_out = self.rt.run(&cfg.art("vote"), &[&seeds])?.remove(0);
        let vote_nn = push(
            &mut stages,
            "vote".into(),
            nn_dev,
            nn_workload(m, &cfg.art("vote")),
            vec![fp_nn],
        );
        let seed_xyz = &sa2.xyz;
        let mut vote_xyz: Vec<[f32; 3]> = Vec::with_capacity(seed_xyz.len());
        let cfeat = seeds.row_len();
        let mut vote_feats = Tensor::zeros(vec![seed_xyz.len(), cfeat]);
        for i in 0..seed_xyz.len() {
            let row = vote_out.row(i);
            vote_xyz.push([
                seed_xyz[i][0] + row[0],
                seed_xyz[i][1] + row[1],
                seed_xyz[i][2] + row[2],
            ]);
            for c in 0..cfeat {
                vote_feats.row_mut(i)[c] = seeds.row(i)[c] + row[3 + c];
            }
        }

        // proposal: cluster votes (point manip) then PointNet+head (NN)
        let pidx = pointops::fps(&vote_xyz, m.num_proposals);
        let pgroups = pointops::ball_query(&vote_xyz, &pidx, m.proposal_radius, m.proposal_k);
        let pg = pointops::group_features(&vote_xyz, Some(&vote_feats), &pidx, &pgroups);
        let prop_pm = push(
            &mut stages,
            "prop_pm".into(),
            point_dev,
            sa_pointmanip_workload(vote_xyz.len(), m.num_proposals, m.proposal_k, cfeat),
            vec![vote_nn],
        );
        let prop = self.rt.run(&cfg.art("prop"), &[&pg])?.remove(0);
        let prop_nn = push(
            &mut stages,
            "prop".into(),
            nn_dev,
            nn_workload(m, &cfg.art("prop")),
            vec![prop_pm],
        );
        let cluster_xyz: Vec<[f32; 3]> = pidx.iter().map(|&i| vote_xyz[i]).collect();

        // decode + NMS on the host CPU
        push(
            &mut stages,
            "decode".into(),
            DeviceKind::Cpu,
            small_pointop((m.num_proposals * m.num_proposals) as u64 * 20, 4096),
            vec![prop_nn],
        );

        let detections =
            decode_detections(m, &cluster_xyz, &prop, cfg.obj_thresh, cfg.nms_iou);
        let timeline = self.sim.run(&stages);
        let fp32_framework = !cfg.int8() && matches!(cfg.schedule, Schedule::SingleDevice(_));
        let peak = peak_memory_mb(m, cfg.variant.painted(), fp32_framework, scene.points.len());
        Ok((
            PipelineOutput {
                detections,
                timeline,
                peak_memory_mb: peak,
                host_ms: t_host.elapsed().as_secs_f64() * 1000.0,
            },
            used_scores,
        ))
    }

    /// SA1..SA3 of one pipeline (full or half centroid budget).
    #[allow(clippy::too_many_arguments)]
    fn run_sa_chain(
        &self,
        stages: &mut Vec<StageSpec>,
        push: &mut dyn FnMut(
            &mut Vec<StageSpec>,
            String,
            DeviceKind,
            crate::sim::Workload,
            Vec<usize>,
        ) -> usize,
        mut state: PipeState,
        tag: &str,
        biased: bool,
        w0: f32,
        point_dev: DeviceKind,
        nn_dev: DeviceKind,
        seg_stage: Option<usize>,
    ) -> Result<(PipeState, PipeState)> {
        let cfg = &self.cfg;
        let m = &self.rt.manifest;
        let halves = cfg.variant.split();
        let shape = if halves { "half" } else { "full" };
        let mut sa2_state = None;
        for l in 0..3 {
            let sac = &m.sa_configs[l];
            let mm = if halves { sac.m / 2 } else { sac.m };
            let use_bias = biased && l < cfg.bias_layers && w0 != 1.0;
            // the SA-bias pipeline's SA1 starts FPS at n/2 so the two views
            // decorrelate even where the bias weight has no effect (mirrors
            // model.backbone_forward's run_pipeline)
            let start = if biased && l == 0 { state.xyz.len() / 2 } else { 0 };
            let idx = if use_bias {
                pointops::biased_fps_from(&state.xyz, mm, &state.fg, w0, start)
            } else {
                pointops::fps_from(&state.xyz, mm, start)
            };
            let groups = pointops::ball_query(&state.xyz, &idx, sac.radius, sac.k);
            let g = pointops::group_features(&state.xyz, state.feats.as_ref(), &idx, &groups);
            // point-manip deps: previous NN of this pipeline produced the
            // features we gather; biased FPS additionally needs the painted
            // fg mask (jump-start rule, Fig. 3)
            let mut deps: Vec<usize> = state.last_nn.into_iter().collect();
            if use_bias {
                if let Some(s) = seg_stage {
                    if !deps.contains(&s) {
                        deps.push(s);
                    }
                }
            }
            // SA1-normal point manip of a painted pipeline needs nothing: it
            // jump-starts before segmentation finishes (gather happens in the
            // NN stage's transfer) — but its PointNet needs the paint.
            let deps_pm = if l == 0 && !use_bias { Vec::new() } else { deps.clone() };
            let cin = state.feats.as_ref().map_or(0, |f| f.row_len());
            let pm = push(
                stages,
                format!("sa{}_{}_pm", l + 1, tag),
                point_dev,
                sa_pointmanip_workload(state.xyz.len(), mm, sac.k, cin),
                deps_pm,
            );
            let art = cfg.art(&format!("sa{}_{}", l + 1, shape));
            let feats_new = self.run_maybe_padded(&art, &g, mm)?;
            let mut deps_nn = vec![pm];
            if l == 0 {
                if let Some(s) = seg_stage {
                    deps_nn.push(s); // painted features required
                }
            }
            let nn = push(
                stages,
                format!("sa{}_{}_nn", l + 1, tag),
                nn_dev,
                nn_workload(m, &art),
                deps_nn,
            );
            state = PipeState {
                xyz: idx.iter().map(|&i| state.xyz[i]).collect(),
                feats: Some(feats_new),
                fg: idx.iter().map(|&i| state.fg[i]).collect(),
                last_nn: Some(nn),
            };
            if l == 1 {
                sa2_state = Some(PipeState {
                    xyz: state.xyz.clone(),
                    feats: state.feats.clone(),
                    fg: state.fg.clone(),
                    last_nn: state.last_nn,
                });
            }
        }
        Ok((sa2_state.unwrap(), state))
    }

    /// Execute an SA artifact whose ball-batch dimension may exceed ours
    /// (RandomSplit halves reuse the `half` artifacts of matching size; the
    /// padding path covers residual mismatches defensively).
    fn run_maybe_padded(&self, art: &str, g: &Tensor, b: usize) -> Result<Tensor> {
        let meta = self
            .rt
            .manifest
            .artifact(art)
            .ok_or_else(|| anyhow!("artifact '{art}' missing"))?;
        let want = meta.input_shapes[0][0];
        if want == b {
            return Ok(self.rt.run(art, &[g])?.remove(0));
        }
        assert!(want > b, "artifact {art} smaller than workload");
        let mut padded = Tensor::zeros(vec![want, g.shape[1], g.shape[2]]);
        padded.data[..g.data.len()].copy_from_slice(&g.data);
        let out = self.rt.run(art, &[&padded])?.remove(0);
        let rows: Vec<usize> = (0..b).collect();
        Ok(out.gather_rows(&rows))
    }
}

/// Concatenate two pipeline states (fusion before SA4).
fn merge(a: PipeState, b: PipeState) -> PipeState {
    let mut xyz = a.xyz;
    xyz.extend_from_slice(&b.xyz);
    let feats = Tensor::concat0(&[a.feats.as_ref().unwrap(), b.feats.as_ref().unwrap()]);
    let mut fg = a.fg;
    fg.extend_from_slice(&b.fg);
    // the merged set is ready when the later of the two pipelines is done
    let last_nn = match (a.last_nn, b.last_nn) {
        (Some(x), Some(y)) => Some(x.max(y)),
        (x, y) => x.or(y),
    };
    PipeState { xyz, feats: Some(feats), fg, last_nn }
}

/// Horizontal concat of two (N, C) tensors.
fn hconcat(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rows(), b.rows());
    let (ca, cb) = (a.row_len(), b.row_len());
    let mut data = Vec::with_capacity(a.rows() * (ca + cb));
    for i in 0..a.rows() {
        data.extend_from_slice(a.row(i));
        data.extend_from_slice(b.row(i));
    }
    Tensor::new(vec![a.rows(), ca + cb], data)
}
