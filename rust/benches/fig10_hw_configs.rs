//! Paper Fig. 10: PointPainting(INT8) vs PointSplit(INT8) across the four
//! processor pairings (CPU-CPU, CPU-EdgeTPU, GPU-CPU, GPU-EdgeTPU).
//!
//! Expected shape: PointSplit reduces latency on EVERY pairing; largest
//! relative gains where the "first" processor is the bottleneck (paper:
//! 1.7x on CPU-CPU, 1.8x on CPU-EdgeTPU).
//!
//! The pairings are hand-picked points of the placement-search space; the
//! second half of this bench runs the search itself
//! (`graph::place::search`) and checks it recovers the paper's
//! GPU+EdgeTPU pipeline as optimal.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::{DetectorConfig, ScenePipeline, Schedule, Variant};
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::graph::place::{self, Objective};
use pointsplit::sim::DeviceKind;

fn main() {
    let rt = common::open_runtime();
    let scenes = common::scene_budget(4);
    let pairs = [
        ("CPU-CPU", DeviceKind::Cpu, DeviceKind::Cpu),
        ("CPU-EdgeTPU", DeviceKind::Cpu, DeviceKind::EdgeTpu),
        ("GPU-CPU", DeviceKind::Gpu, DeviceKind::Cpu),
        ("GPU-EdgeTPU", DeviceKind::Gpu, DeviceKind::EdgeTpu),
    ];
    let paper = [(8545.0, 5016.0), (4243.0, 2407.0), (4341.0, 3563.0), (1224.0, 1113.0)];
    let mut t = Table::new(&[
        "config",
        "PointPainting (ms)",
        "PointSplit (ms)",
        "speedup",
        "paper speedup",
    ]);
    for ((name, pd, nd), (ppp, pps)) in pairs.iter().zip(paper.iter()) {
        let mut pp = 0.0;
        let mut ps = 0.0;
        for seed in 0..scenes as u64 {
            let scene = generate_scene(70_000 + seed, &SYNRGBD);
            let cfg_pp = DetectorConfig::new(
                "synrgbd",
                Variant::PointPainting,
                true,
                Schedule::Sequential { point_dev: *pd, nn_dev: *nd },
            );
            let cfg_ps = DetectorConfig::new(
                "synrgbd",
                Variant::PointSplit,
                true,
                Schedule::Pipelined { point_dev: *pd, nn_dev: *nd },
            );
            pp += ScenePipeline::new(&rt, cfg_pp).run(&scene, seed).unwrap().timeline.total_ms;
            ps += ScenePipeline::new(&rt, cfg_ps).run(&scene, seed).unwrap().timeline.total_ms;
        }
        pp /= scenes as f64;
        ps /= scenes as f64;
        t.row(vec![
            name.to_string(),
            format!("{pp:.0}"),
            format!("{ps:.0}"),
            format!("{:.2}x", pp / ps),
            format!("{:.2}x", ppp / pps),
        ]);
    }
    t.print(&format!("Fig. 10 — latency across processor pairings, INT8 ({scenes} scenes)"));

    // ------------------------------------------------ placement search
    let cfg = DetectorConfig::new(
        "synrgbd",
        Variant::PointSplit,
        true,
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
    );
    let avail = [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::EdgeTpu];
    let search = place::search(
        &rt.manifest,
        &cfg,
        SYNRGBD.num_points,
        1,
        &avail,
        Objective::Latency,
    )
    .expect("placement search");
    let mut ps = Table::new(&["placement", "total ms", "bottleneck ms", "comm ms"]);
    for (i, c) in search.candidates.iter().enumerate() {
        ps.row(vec![
            format!("{:?}{}", c.schedule, if i == 0 { " *" } else { "" }),
            format!("{:.0}", c.cost.total_ms),
            format!("{:.0}", c.cost.bottleneck_ms),
            format!("{:.0}", c.cost.comm_ms),
        ]);
    }
    ps.print("placement search over the same stage graph (best first)");
    println!("{} assignments rejected by capability/memory constraints", search.rejected.len());
    let best = search.best().expect("feasible placement");
    let paper = Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
    let verdict = if best.schedule == paper {
        "OK: matches the paper's GPU+NPU pipeline"
    } else {
        "REGRESSION: paper assignment not recovered"
    };
    println!("optimal: {:?}  [{verdict}]", best.schedule);
}
