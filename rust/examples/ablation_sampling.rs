//! Ablation: what does 2D-semantics-biased FPS actually sample?
//!
//! Reproduces the Fig. 4 intuition quantitatively: sweeping w0 changes the
//! fraction of foreground points in the sampled set and the spatial coverage
//! of the background, producing distinct "views" of the same scene.
//!
//! ```bash
//! cargo run --release --example ablation_sampling
//! ```

use pointsplit::bench::Table;
use pointsplit::data::{generate_scene, SYNRGBD};
use pointsplit::pointops::{biased_fps, fg_mask, fps, paint_points};
use pointsplit::runtime::Runtime;
use pointsplit::util::tensor::Tensor;

fn main() -> anyhow::Result<()> {
    let rt = Runtime::open("artifacts")?;
    let scene = generate_scene(7, &SYNRGBD);
    // real segmenter painting (not the GT oracle)
    let img = Tensor::new(vec![64, 64, 3], scene.image.clone());
    let scores = rt.run("synrgbd_seg_fp32", &[&img])?.remove(0);
    let paint = paint_points(&scene, &scores);
    let fg = fg_mask(&paint, 0.5);
    let fg_total = fg.iter().sum::<f32>() / fg.len() as f32;
    println!(
        "scene: {} objects, {:.0}% of points painted foreground",
        scene.objects.len(),
        fg_total * 100.0
    );

    let m = 256;
    let mut table = Table::new(&["w0", "fg fraction", "fg gain", "bg coverage (m)"]);
    for w0 in [0.5f32, 1.0, 2.0, 3.5, 10.0] {
        let idx =
            if w0 == 1.0 { fps(&scene.points, m) } else { biased_fps(&scene.points, m, &fg, w0) };
        let frac = idx.iter().map(|&i| fg[i]).sum::<f32>() / m as f32;
        // background coverage: max distance from any bg point to the nearest
        // sampled bg point (lower = better covered)
        let bg_samples: Vec<[f32; 3]> =
            idx.iter().filter(|&&i| fg[i] < 0.5).map(|&i| scene.points[i]).collect();
        let mut cover = 0.0f32;
        for (p, f) in scene.points.iter().zip(fg.iter()) {
            if *f > 0.5 || bg_samples.is_empty() {
                continue;
            }
            let d = bg_samples
                .iter()
                .map(|q| {
                    ((p[0] - q[0]).powi(2) + (p[1] - q[1]).powi(2) + (p[2] - q[2]).powi(2)).sqrt()
                })
                .fold(f32::INFINITY, f32::min);
            cover = cover.max(d);
        }
        table.row(vec![
            format!("{w0}"),
            format!("{:.1}%", frac * 100.0),
            format!("{:.2}x", frac / fg_total),
            format!("{cover:.2}"),
        ]);
    }
    table.print("biased FPS views of one scene (Fig. 4 analog, 256 samples)");
    println!(
        "\nreading: w0>1 over-samples painted (object) points — the SA-bias view;\n\
         w0=1 is regular FPS — the SA-normal view; very large w0 abandons the\n\
         background (hurts context, cf. Table 9's peak at w0=2)."
    );
    Ok(())
}
