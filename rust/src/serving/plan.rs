//! Analytic service model: the per-scene stage DAG of
//! `coordinator::pipeline`, rebuilt without functional execution and timed by
//! the calibrated [`ScheduleSim`].
//!
//! The dispatcher needs to know — *before* committing accelerator time —
//! what a batch will cost on each device. This planner mirrors the exact
//! stage graph `ScenePipeline::run` records (same jump-start rules, same
//! device fallbacks, same workload descriptors from the manifest), so its
//! timelines match what the pipeline itself would report, but it needs no
//! PJRT artifacts: with `Manifest::synthetic()` it runs anywhere.
//!
//! Batching model: a batch of `k` compatible scenes folds into one DAG with
//! every stage's FLOPs/bytes scaled by `k` while per-stage dispatch and
//! transfer *setup* costs are paid once. That is precisely where dynamic
//! batching wins on this hardware — the EdgeTPU's 20 ms per-transfer setup
//! and the GPU's 14 ms per-dispatch overhead amortize across the batch.

use std::cell::RefCell;
use std::collections::HashMap;

use crate::coordinator::arch::{nn_precision, nn_workload, sa_pointmanip_workload, small_pointop};
use crate::coordinator::{DetectorConfig, Variant};
use crate::runtime::Manifest;
use crate::sim::{DeviceKind, Precision, ScheduleSim, StageSpec, Timeline, Workload};

/// Per-batch cost summary extracted from a simulated [`Timeline`].
#[derive(Debug, Clone, Copy)]
pub struct PlanCost {
    /// Critical-path latency of the batch, ms.
    pub total_ms: f64,
    pub busy_gpu_ms: f64,
    pub busy_npu_ms: f64,
    pub busy_cpu_ms: f64,
    /// Total interconnect time charged, ms.
    pub comm_ms: f64,
    /// Largest per-device occupancy (compute + transfers), ms. In steady
    /// state the pipeline admits a new batch every `bottleneck_ms`, so this
    /// sets the gateway's service rate while `total_ms` sets its latency.
    pub bottleneck_ms: f64,
}

/// Stage-DAG planner with a per-configuration cost cache.
pub struct ServicePlanner {
    manifest: Manifest,
    sim: ScheduleSim,
    cache: RefCell<HashMap<String, PlanCost>>,
}

/// Rolling per-pipeline planning state (mirrors `pipeline::ChainLevel`).
struct PlanLevel {
    n: usize,
    cin: usize,
    /// sim indices of the NN stages that must finish before the next
    /// point-manip may consume this level (one per contributing pipeline)
    last_nn: Vec<usize>,
}

/// Stage-DAG accumulator with the sequential-schedule chaining rule.
struct DagBuilder {
    stages: Vec<StageSpec>,
    sequential: bool,
    prev: Option<usize>,
}

impl DagBuilder {
    fn push(
        &mut self,
        name: String,
        device: DeviceKind,
        precision: Precision,
        workload: Workload,
        mut deps: Vec<usize>,
    ) -> usize {
        if self.sequential {
            if let Some(p) = self.prev {
                if !deps.contains(&p) {
                    deps.push(p);
                }
            }
        }
        self.stages.push(StageSpec { name, device, precision, workload, deps });
        self.prev = Some(self.stages.len() - 1);
        self.stages.len() - 1
    }
}

impl ServicePlanner {
    pub fn new(manifest: Manifest) -> ServicePlanner {
        ServicePlanner { manifest, sim: ScheduleSim::new(), cache: RefCell::new(HashMap::new()) }
    }

    /// Planner over the synthetic manifest (no exported artifacts needed).
    pub fn synthetic() -> ServicePlanner {
        ServicePlanner::new(Manifest::synthetic())
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    /// Simulated cost of running `batch` compatible scenes of `num_points`
    /// points under `cfg`. `skip_seg` models consecutive matching (2D scores
    /// reused from a previous frame — the degraded fast path).
    pub fn cost(
        &self,
        cfg: &DetectorConfig,
        num_points: usize,
        batch: usize,
        skip_seg: bool,
    ) -> PlanCost {
        let key = format!(
            "{}|{}|{}|{:?}|{}|{}|{}|{}|{}|{}",
            cfg.dataset,
            cfg.variant.name(),
            cfg.scheme.key(),
            cfg.schedule,
            cfg.w0,
            cfg.bias_layers,
            cfg.seg_passes,
            num_points,
            batch,
            skip_seg
        );
        if let Some(c) = self.cache.borrow().get(&key) {
            return *c;
        }
        let mut stages = self.stages(cfg, num_points, skip_seg);
        for s in &mut stages {
            s.workload.flops *= batch as u64;
            s.workload.mem_bytes *= batch as u64;
            s.workload.wire_bytes *= batch as u64;
        }
        let cost = cost_of(&self.sim.run(&stages));
        self.cache.borrow_mut().insert(key, cost);
        cost
    }

    /// Steady-state service capacity (requests/sec) at a given batch size:
    /// the pipeline finishes `batch` requests every `bottleneck_ms`.
    pub fn capacity_rps(&self, cfg: &DetectorConfig, num_points: usize, batch: usize) -> f64 {
        let c = self.cost(cfg, num_points, batch.max(1), false);
        batch.max(1) as f64 / c.bottleneck_ms * 1000.0
    }

    /// Build the single-scene stage DAG (mirror of `ScenePipeline::run`'s
    /// recording side).
    pub fn stages(&self, cfg: &DetectorConfig, num_points: usize, skip_seg: bool) -> Vec<StageSpec> {
        let m = &self.manifest;
        let point_dev = cfg.schedule.point_dev();
        // EdgeTPU executes int8 only; placement is per stage precision
        // (mirrors ScenePipeline exactly)
        let nn_dev_raw = cfg.schedule.nn_dev();
        let nn_dev_for = |p: Precision| {
            if p == Precision::Fp32 && nn_dev_raw == DeviceKind::EdgeTpu {
                point_dev
            } else {
                nn_dev_raw
            }
        };
        let nn_dev = nn_dev_for(cfg.scheme.backbone.sim());
        let mut dag = DagBuilder {
            stages: Vec::new(),
            sequential: !cfg.schedule.overlapped(),
            prev: None,
        };

        // ---------------------------------------------------- 2D segment
        let seg_stage = if cfg.variant.painted() && !skip_seg {
            let mut wl = nn_workload(m, &cfg.seg_art());
            wl.flops *= cfg.seg_passes as u64;
            Some(dag.push("seg".into(), nn_dev, nn_precision(m, &cfg.seg_art()), wl, vec![]))
        } else {
            None
        };
        let paint_deps: Vec<usize> = seg_stage.into_iter().collect();
        if cfg.variant.painted() {
            dag.push(
                "paint".into(),
                point_dev,
                Precision::Fp32,
                small_pointop((num_points * 8) as u64, (num_points * m.num_seg_classes) as u64),
                paint_deps,
            );
        }
        let feat = if cfg.variant.painted() { m.feat_dim_painted } else { m.feat_dim_plain };

        // ---------------------------------------------------- backbone
        let (sa2, sa3) = match cfg.variant {
            Variant::VoteNet | Variant::PointPainting => self.plan_sa_chain(
                &mut dag, cfg, num_points, feat, "full", false, point_dev, nn_dev, seg_stage,
            ),
            Variant::PointSplit => {
                let ln = self.plan_sa_chain(
                    &mut dag, cfg, num_points, feat, "normal", false, point_dev, nn_dev, seg_stage,
                );
                let lb = self.plan_sa_chain(
                    &mut dag, cfg, num_points, feat, "bias", true, point_dev, nn_dev, seg_stage,
                );
                (merge(ln.0, lb.0), merge(ln.1, lb.1))
            }
            Variant::RandomSplit => {
                let half = num_points / 2;
                let la = self.plan_sa_chain(
                    &mut dag, cfg, half, feat, "randA", false, point_dev, nn_dev, seg_stage,
                );
                let lb = self.plan_sa_chain(
                    &mut dag, cfg, half, feat, "randB", false, point_dev, nn_dev, seg_stage,
                );
                (merge(la.0, lb.0), merge(la.1, lb.1))
            }
        };

        // SA4 over the fused SA3 set: it must wait for **both** pipelines'
        // SA3 PointNets (the old single `max(a, b)` dependency let sa4_pm
        // start before the slower pipeline finished)
        let sa4cfg = &m.sa_configs[3];
        let mut deps4 = sa3.last_nn.clone();
        deps4.sort_unstable();
        let pm4 = dag.push(
            "sa4_pm".into(),
            point_dev,
            Precision::Fp32,
            sa_pointmanip_workload(sa3.n, sa4cfg.m, sa4cfg.k, sa3.cin),
            deps4,
        );
        let sa4_art = cfg.art("sa4_full");
        let nn4 = dag.push(
            "sa4_nn".into(),
            nn_dev,
            nn_precision(m, &sa4_art),
            nn_workload(m, &sa4_art),
            vec![pm4],
        );

        // ---------------------------------------------------- FP + heads
        let fp_pm = dag.push(
            "fp_interp".into(),
            point_dev,
            Precision::Fp32,
            small_pointop((sa2.n * sa3.n * 4) as u64, (sa2.n * m.fp_in * 4) as u64),
            vec![nn4],
        );
        let fp_art = cfg.art("fp_fc");
        let fp_nn = dag.push(
            "fp_fc".into(),
            nn_dev,
            nn_precision(m, &fp_art),
            nn_workload(m, &fp_art),
            vec![fp_pm],
        );
        let vote_art = cfg.art("vote");
        let vote_prec = nn_precision(m, &vote_art);
        let vote_nn = dag.push(
            "vote".into(),
            nn_dev_for(vote_prec),
            vote_prec,
            nn_workload(m, &vote_art),
            vec![fp_nn],
        );
        let prop_pm = dag.push(
            "prop_pm".into(),
            point_dev,
            Precision::Fp32,
            sa_pointmanip_workload(sa2.n, m.num_proposals, m.proposal_k, m.seed_feat),
            vec![vote_nn],
        );
        let prop_art = cfg.art("prop");
        let prop_prec = nn_precision(m, &prop_art);
        let prop_nn = dag.push(
            "prop".into(),
            nn_dev_for(prop_prec),
            prop_prec,
            nn_workload(m, &prop_art),
            vec![prop_pm],
        );
        dag.push(
            "decode".into(),
            DeviceKind::Cpu,
            Precision::Fp32,
            small_pointop((m.num_proposals * m.num_proposals) as u64 * 20, 4096),
            vec![prop_nn],
        );
        dag.stages
    }

    /// SA1..SA3 of one pipeline (mirror of `ScenePipeline::run_sa_chain`):
    /// returns the SA2 and SA3 levels for the FP stage.
    #[allow(clippy::too_many_arguments)]
    fn plan_sa_chain(
        &self,
        dag: &mut DagBuilder,
        cfg: &DetectorConfig,
        n0: usize,
        feat: usize,
        tag: &str,
        biased: bool,
        point_dev: DeviceKind,
        nn_dev: DeviceKind,
        seg_stage: Option<usize>,
    ) -> (PlanLevel, PlanLevel) {
        let m = &self.manifest;
        let halves = cfg.variant.split();
        let shape = if halves { "half" } else { "full" };
        let mut state =
            PlanLevel { n: n0, cin: feat, last_nn: seg_stage.into_iter().collect() };
        let mut sa2 = None;
        for l in 0..3 {
            let sac = &m.sa_configs[l];
            let mm = if halves { sac.m / 2 } else { sac.m };
            let use_bias = biased && l < cfg.bias_layers && cfg.w0 != 1.0;
            let mut deps: Vec<usize> = state.last_nn.clone();
            if use_bias {
                if let Some(s) = seg_stage {
                    if !deps.contains(&s) {
                        deps.push(s);
                    }
                }
            }
            // SA1-normal jump-starts before segmentation finishes
            let deps_pm = if l == 0 && !use_bias { Vec::new() } else { deps };
            let pm = dag.push(
                format!("sa{}_{}_pm", l + 1, tag),
                point_dev,
                Precision::Fp32,
                sa_pointmanip_workload(state.n, mm, sac.k, state.cin),
                deps_pm,
            );
            let mut deps_nn = vec![pm];
            if l == 0 {
                if let Some(s) = seg_stage {
                    deps_nn.push(s); // painted features required
                }
            }
            let art = cfg.art(&format!("sa{}_{shape}", l + 1));
            let nn = dag.push(
                format!("sa{}_{}_nn", l + 1, tag),
                nn_dev,
                nn_precision(m, &art),
                nn_workload(m, &art),
                deps_nn,
            );
            state = PlanLevel { n: mm, cin: *sac.mlp.last().unwrap(), last_nn: vec![nn] };
            if l == 1 {
                sa2 = Some(PlanLevel {
                    n: state.n,
                    cin: state.cin,
                    last_nn: state.last_nn.clone(),
                });
            }
        }
        (sa2.expect("three SA levels planned"), state)
    }
}

/// Fuse two pipelines' levels: the merged set depends on **every**
/// contributing pipeline's last NN stage. (The old code kept only
/// `max(a, b)`, so a downstream stage could be scheduled before the slower
/// pipeline's SA3 finished — the regression is pinned by
/// `tests/parallelism.rs::sa4_waits_for_both_pipelines`.)
fn merge(a: PlanLevel, b: PlanLevel) -> PlanLevel {
    let mut last_nn = a.last_nn;
    last_nn.extend_from_slice(&b.last_nn);
    last_nn.sort_unstable();
    last_nn.dedup();
    PlanLevel { n: a.n + b.n, cin: a.cin, last_nn }
}

/// Reduce a simulated timeline to the dispatcher's cost summary.
pub fn cost_of(tl: &Timeline) -> PlanCost {
    let busy = |k: DeviceKind| tl.busy_ms.get(&k).copied().unwrap_or(0.0);
    let comm = |k: DeviceKind| tl.comm_ms.get(&k).copied().unwrap_or(0.0);
    let occupancy = |k: DeviceKind| busy(k) + comm(k);
    let bottleneck = [DeviceKind::Cpu, DeviceKind::Gpu, DeviceKind::EdgeTpu]
        .into_iter()
        .map(occupancy)
        .fold(0.0, f64::max);
    PlanCost {
        total_ms: tl.total_ms,
        busy_gpu_ms: busy(DeviceKind::Gpu),
        busy_npu_ms: busy(DeviceKind::EdgeTpu),
        busy_cpu_ms: busy(DeviceKind::Cpu),
        comm_ms: tl.comm_ms.values().sum(),
        bottleneck_ms: bottleneck.max(1e-6),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Schedule;
    use crate::sim::DeviceKind;

    fn planner() -> ServicePlanner {
        ServicePlanner::synthetic()
    }

    fn split_cfg() -> DetectorConfig {
        DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        )
    }

    #[test]
    fn plan_produces_connected_dag() {
        let p = planner();
        let stages = p.stages(&split_cfg(), 2048, false);
        assert!(stages.len() > 15, "expected a full two-pipeline DAG, got {}", stages.len());
        for (i, s) in stages.iter().enumerate() {
            for &d in &s.deps {
                assert!(d < i, "stage {i} depends forward on {d}");
            }
        }
        assert!(stages.iter().any(|s| s.name == "seg"));
        assert!(stages.iter().any(|s| s.name == "decode"));
    }

    #[test]
    fn cost_is_cached_and_deterministic() {
        let p = planner();
        let a = p.cost(&split_cfg(), 2048, 2, false);
        let b = p.cost(&split_cfg(), 2048, 2, false);
        assert_eq!(a.total_ms, b.total_ms);
        assert!(a.total_ms > 0.0 && a.bottleneck_ms > 0.0);
        assert!(a.bottleneck_ms <= a.total_ms + 1e-9);
    }

    #[test]
    fn batching_amortizes_overheads() {
        let p = planner();
        let one = p.cost(&split_cfg(), 2048, 1, false);
        let four = p.cost(&split_cfg(), 2048, 4, false);
        assert!(four.total_ms > one.total_ms, "bigger batch cannot be faster in latency");
        assert!(
            four.total_ms < 4.0 * one.total_ms * 0.9,
            "batch of 4 ({:.0} ms) should beat 4x single ({:.0} ms) by >10%",
            four.total_ms,
            4.0 * one.total_ms
        );
        // throughput must improve with batch size
        assert!(p.capacity_rps(&split_cfg(), 2048, 4) > p.capacity_rps(&split_cfg(), 2048, 1));
    }

    #[test]
    fn skip_seg_is_faster_when_sequential() {
        // on the sequential schedule every stage sits on the critical path,
        // so dropping the 2D segmenter must strictly cut latency (in the
        // overlapped schedule it can hide behind the GPU lane)
        let p = planner();
        let mut cfg = split_cfg();
        cfg.schedule =
            Schedule::Sequential { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu };
        let full = p.cost(&cfg, 2048, 1, false);
        let skip = p.cost(&cfg, 2048, 1, true);
        assert!(skip.total_ms < full.total_ms, "skipping 2D work must cut latency");
    }

    #[test]
    fn degraded_fast_path_is_faster() {
        // the SLO fast path = int8 + role heads + consecutive matching +
        // half point budget; it must beat the full path on latency AND on
        // the bottleneck (i.e. it raises capacity, not just responsiveness)
        let p = planner();
        let cfg = split_cfg();
        let fast_cfg = crate::serving::slo::degraded_config(&cfg);
        let fast_pts = crate::serving::slo::degraded_points(2048);
        for (batch, factor) in [(1usize, 0.9), (4, 0.8)] {
            // at batch 1 the serial NN tail (fixed dispatch + PCIe setup
            // costs) floors the gain; at batch 4 those amortize and the
            // halved GPU lane dominates
            let full = p.cost(&cfg, 2048, batch, false);
            let fast = p.cost(&fast_cfg, fast_pts, batch, true);
            assert!(
                fast.total_ms < factor * full.total_ms,
                "batch {batch}: fast {:.0} ms vs full {:.0} ms",
                fast.total_ms,
                full.total_ms
            );
            assert!(fast.bottleneck_ms < full.bottleneck_ms);
        }
    }

    #[test]
    fn fp32_single_device_slower_than_int8_split() {
        let p = planner();
        let fp32 = DetectorConfig::new(
            "synrgbd",
            Variant::PointPainting,
            false,
            Schedule::SingleDevice(DeviceKind::Gpu),
        );
        let slow = p.cost(&fp32, 2048, 1, false);
        let fast = p.cost(&split_cfg(), 2048, 1, false);
        assert!(
            slow.total_ms > 3.0 * fast.total_ms,
            "paper direction: fp32 GPU-only ({:.0} ms) >> int8 split ({:.0} ms)",
            slow.total_ms,
            fast.total_ms
        );
    }

    #[test]
    fn all_variants_plan_on_both_datasets() {
        let p = planner();
        for ds in ["synrgbd", "synscan"] {
            let n = p.manifest().datasets[ds].num_points;
            for v in
                [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit]
            {
                for int8 in [false, true] {
                    let cfg = DetectorConfig::new(
                        ds,
                        v,
                        int8,
                        Schedule::Pipelined {
                            point_dev: DeviceKind::Gpu,
                            nn_dev: DeviceKind::EdgeTpu,
                        },
                    );
                    let c = p.cost(&cfg, n, 1, false);
                    assert!(c.total_ms > 0.0, "{ds}/{v:?}/int8={int8}");
                }
            }
        }
    }
}
