"""Pallas kernel: group-wise INT8 quantize-dequantize head layer.

The paper's role-based group-wise quantization (§4.3) is a *kernel-level*
concern on the EdgeTPU: the final voting/proposal layers execute with int8
weights and requantized int8 outputs whose scales are chosen per channel
group. This kernel fuses (weight QDQ) matmul + bias + (activation QDQ) in one
VMEM pass. Any granularity — layer / even-group / channel / role-based — is
expressed through the per-channel scale vectors (a group's scale repeated
across its member channels), so the kernel is granularity-agnostic.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 32


def _qmlp_kernel(x_ref, w_ref, b_ref, ws_ref, as_ref, az_ref, o_ref):
    x = x_ref[...]
    w = w_ref[...]
    ws = ws_ref[...]
    # weight QDQ (symmetric, per output channel)
    wq = jnp.clip(jnp.round(w / ws[None, :]), -127.0, 127.0) * ws[None, :]
    y = jnp.dot(x, wq, preferred_element_type=jnp.float32) + b_ref[...]
    # activation QDQ (affine, per output channel)
    sa = as_ref[...]
    za = az_ref[...]
    q = jnp.clip(jnp.round(y / sa + za), -128.0, 127.0)
    o_ref[...] = (q - za) * sa


def qmlp_pallas(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    w_scale: jnp.ndarray,
    a_scale: jnp.ndarray,
    a_zero: jnp.ndarray,
    block_n: int = DEFAULT_BLOCK_N,
) -> jnp.ndarray:
    """Quantized head layer. x: (N, C_in) -> (N, C_out)."""
    n, cin = x.shape
    cout = w.shape[1]
    if n % block_n != 0:
        block_n = next(bb for bb in range(min(block_n, n), 0, -1) if n % bb == 0)
    full = lambda a: pl.BlockSpec(a.shape, lambda i, nd=a.ndim: (0,) * nd)
    return pl.pallas_call(
        _qmlp_kernel,
        grid=(n // block_n,),
        in_specs=[
            pl.BlockSpec((block_n, cin), lambda i: (i, 0)),
            full(w),
            full(b),
            full(w_scale),
            full(a_scale),
            full(a_zero),
        ],
        out_specs=pl.BlockSpec((block_n, cout), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, cout), jnp.float32),
        interpret=True,
    )(x, w, b, w_scale, a_scale, a_zero)
