//! Per-scene detection pipeline: functional execution + simulated timeline.
//!
//! The stage DAG itself lives in [`crate::graph::StageGraph`] — built
//! exactly once per configuration and shared with the serving planner.
//! This module is the **lower-to-exec pass**: it walks the graph's nodes
//! and attaches one compute closure per [`StageClass`], producing the
//! [`StageDecl`]s the [`exec::DagExecutor`] runs on the host (in parallel
//! when dependencies allow — the SA-normal / SA-bias chains of PointSplit
//! and the two RandomSplit halves overlap on host threads, mirroring the
//! paper's two-lane GPU/NPU overlap, Fig. 3).
//!
//! The embedded [`StageSpec`]s replay through the calibrated
//! [`ScheduleSim`] device model. Because the executed DAG, the simulated
//! DAG, and the serving planner's DAG are all the same [`StageGraph`],
//! dependency drift between them is impossible by construction (the class
//! of bug where `merge()` collapsed two pipelines' last NN stages into
//! `max(a, b)` and let `sa4_pm` start before the slower pipeline finished —
//! and the class where the planner's hand-written mirror of this file
//! could rot).
//!
//! Stage closures exchange data through single-producer [`Slot`]s, so
//! parallel execution is bit-identical to sequential execution (see
//! `rust/tests/parallelism.rs`).

use std::sync::Arc;

use anyhow::{anyhow, Result};

use super::arch::peak_memory_mb;
use super::decode::decode_detections;
use super::{Schedule, Variant};
use crate::data::{Box3, Scene};
use crate::exec::{Compute, DagExecutor, HostExec, Slot, StageDecl};
use crate::graph::{StageClass, StageGraph};
use crate::pointops;
use crate::quant::{Granularity, QuantScheme, QuantSpec, StagePrecision};
use crate::runtime::Runtime;
use crate::sim::{ScheduleSim, StageSpec, Timeline};
use crate::temporal::{FrameCache, FrameClass, StreamArtifacts};
use crate::util::rng::Rng;
use crate::util::tensor::Tensor;

/// Full configuration of one detector instantiation.
#[derive(Debug, Clone)]
pub struct DetectorConfig {
    pub dataset: String,
    pub variant: Variant,
    /// Per-stage-class precision assignment (paper §4.3 as an execution
    /// property, not a config flag): backbone, vote head, proposal head.
    pub scheme: QuantScheme,
    pub schedule: Schedule,
    pub w0: f32,
    pub bias_layers: usize,
    pub obj_thresh: f32,
    pub nms_iou: f64,
    /// number of segmentation passes per scene (paper: 3 for ScanNet)
    pub seg_passes: usize,
}

impl DetectorConfig {
    pub fn new(dataset: &str, variant: Variant, int8: bool, schedule: Schedule) -> Self {
        DetectorConfig {
            dataset: dataset.to_string(),
            variant,
            scheme: if int8 {
                // paper Table 7: role-based for PointSplit, layer-wise others
                QuantScheme::int8(if variant == Variant::PointSplit {
                    Granularity::Role
                } else {
                    Granularity::Layer
                })
            } else {
                QuantScheme::fp32()
            },
            schedule,
            w0: 2.0,
            bias_layers: 2,
            obj_thresh: 0.02,
            nms_iou: 0.25,
            seg_passes: if dataset == "synscan" { 3 } else { 1 },
        }
    }

    /// Artifact name for one of this configuration's networks (resolved by
    /// the shared [`StageGraph`] constructor).
    pub(crate) fn art(&self, net: &str) -> String {
        let prec = match net {
            "vote" | "prop" => self.scheme.for_net(net).head_name(),
            _ => self.scheme.backbone.backbone_name(),
        };
        format!("{}_{}_{}_{}", self.dataset, self.variant.model_name(), net, prec)
    }

    pub(crate) fn seg_art(&self) -> String {
        format!("{}_seg_{}", self.dataset, self.scheme.backbone.backbone_name())
    }

    pub fn int8(&self) -> bool {
        self.scheme.backbone.is_int8()
    }

    /// Set both head stages' precision from an artifact label
    /// ("fp32", "int8_layer", "int8_group", "int8_channel", "int8_role").
    pub fn set_head_precision(&mut self, name: &str) -> Result<()> {
        let p = StagePrecision::parse(name)
            .ok_or_else(|| anyhow!("unknown head precision '{name}'"))?;
        self.scheme = self.scheme.with_head(p);
        Ok(())
    }
}

/// Result of running one scene through the pipeline.
#[derive(Debug, Clone)]
pub struct PipelineOutput {
    pub detections: Vec<Box3>,
    pub timeline: Timeline,
    /// The stage DAG as declared (same object the executor ran, the
    /// simulator timed, and the serving planner costs).
    pub stage_specs: Vec<StageSpec>,
    pub peak_memory_mb: f64,
    /// wall-clock of the functional execution on this host (for §Perf)
    pub host_ms: f64,
}

/// Chain-local geometry after a sampling step: positions plus the composed
/// index of every point back into the original cloud (so any stage can look
/// up per-point metadata like the painted fg mask without carrying it).
/// Positions are SoA so every downstream point op takes the SIMD fast path
/// without a conversion copy.
#[derive(Clone)]
struct Geo {
    xyz: pointops::PointsSoA,
    src: Vec<usize>,
}

/// Where an SA chain's level-0 points come from.
#[derive(Clone)]
enum ChainInput {
    /// the full original cloud
    Full,
    /// a fixed subset of the original cloud (RandomSplit halves)
    Subset(Arc<Vec<usize>>),
}

/// What a streaming frame inherits from the previous one (the `run_impl`
/// input that selects the paint/segment behaviour; see `crate::temporal`).
#[derive(Clone, Copy)]
enum ReuseMode<'p> {
    /// cold frame: full pipeline, segmenter included
    Cold,
    /// consecutive matching (paper §3.2): previous frame's 2D scores reused,
    /// the cloud is repainted in full
    Scores(&'p Tensor),
    /// temporal PARTIAL frame: previous scores *and* previous paint carried
    /// over; only points in dirty grid cells are re-projected
    Partial { scores: &'p Tensor, prev_paint: &'p Tensor, dirty: &'p [bool] },
}

/// Per-chain slot set wiring the SA-level closures together (one slot per
/// graph [`crate::graph::LevelInfo`]).
#[allow(clippy::type_complexity)]
struct ChainSlots {
    geo: Vec<Slot<Geo>>,
    grp: Vec<Slot<(Vec<usize>, Vec<Vec<usize>>)>>,
    feats: Vec<Slot<Tensor>>,
}

pub struct ScenePipeline<'a> {
    pub rt: &'a Runtime,
    pub cfg: DetectorConfig,
    sim: ScheduleSim,
    host_exec: HostExec,
}

impl<'a> ScenePipeline<'a> {
    pub fn new(rt: &'a Runtime, cfg: DetectorConfig) -> Self {
        ScenePipeline { rt, cfg, sim: ScheduleSim::new(), host_exec: HostExec::auto() }
    }

    /// Override the host execution policy (sequential / parallel).
    pub fn with_host_exec(mut self, host_exec: HostExec) -> Self {
        self.host_exec = host_exec;
        self
    }

    pub fn host_exec(&self) -> HostExec {
        self.host_exec
    }

    /// Run one scene. `seed` feeds the RandomSplit permutation.
    pub fn run(&self, scene: &Scene, seed: u64) -> Result<PipelineOutput> {
        self.run_with_scores(scene, seed, None).map(|(out, _)| out)
    }

    /// Run one scene, optionally reusing 2D segmentation scores from a
    /// previous frame ("consecutive matching", paper §3.2): when
    /// `prev_scores` is given, the segmenter stage is skipped entirely —
    /// zero NPU time for 2D — at the cost of stale semantics. Returns the
    /// pipeline output plus the scores used (for the caller to carry
    /// forward to the next frame).
    pub fn run_with_scores(
        &self,
        scene: &Scene,
        seed: u64,
        prev_scores: Option<&Tensor>,
    ) -> Result<(PipelineOutput, Option<Tensor>)> {
        let mode = match prev_scores {
            Some(s) => ReuseMode::Scores(s),
            None => ReuseMode::Cold,
        };
        self.run_impl(scene, seed, mode, None)
    }

    /// The lower-to-exec pass proper. `reuse` selects how much 2D work the
    /// frame inherits (nothing / scores / scores + partial paint); `capture`
    /// optionally harvests the stream artifacts (painted scores, fg mask,
    /// seed index set, seed features) the temporal cache stores for the next
    /// frame. With `ReuseMode::Cold` the executed DAG and its outputs are
    /// bit-identical to [`ScenePipeline::run`] whether or not capture is on
    /// (capture only clones values out of the existing slots).
    fn run_impl(
        &self,
        scene: &Scene,
        seed: u64,
        reuse: ReuseMode<'_>,
        capture: Option<&mut StreamArtifacts>,
    ) -> Result<(PipelineOutput, Option<Tensor>)> {
        let t_host = std::time::Instant::now();
        let cfg = &self.cfg;
        let m = &self.rt.manifest;
        let threads = self.host_exec.threads();
        let painted = cfg.variant.painted();
        let n = scene.points.len();

        // the one stage-graph construction: this same object is what the
        // serving planner builds for this configuration
        let graph = StageGraph::build(m, cfg, n, !matches!(reuse, ReuseMode::Cold))?;

        // ---------------------------------------------------------- slots
        // scores_slot: segmenter output (or the previous frame's scores);
        // feat_slot: per-point detector features + fg mask of the full cloud
        let scores_slot: Slot<Tensor> = Slot::new("seg scores");
        let feat_slot: Slot<(Tensor, Vec<f32>)> = Slot::new("point features");
        if painted {
            match reuse {
                // consecutive matching: reuse the previous frame's scores
                ReuseMode::Scores(prev) => scores_slot.set(prev.clone()),
                ReuseMode::Partial { scores, .. } => scores_slot.set(scores.clone()),
                ReuseMode::Cold => {}
            }
        } else {
            feat_slot.set((pointops::build_features(scene, None), vec![0.0; n]));
        }
        // PARTIAL frames re-project only dirty points inside the paint stage
        let partial_paint: Option<(&Tensor, &[bool])> = match reuse {
            ReuseMode::Partial { prev_paint, dirty, .. } => Some((prev_paint, dirty)),
            _ => None,
        };
        // capture slots live alongside the pipeline's own: existing stages
        // clone values into them, so the DAG the executor runs is unchanged
        let capture_paint: Option<Slot<Tensor>> =
            capture.is_some().then(|| Slot::new("capture paint"));
        let capture_seeds: Option<Slot<Tensor>> =
            capture.is_some().then(|| Slot::new("capture seeds"));
        let chain_slots: Vec<ChainSlots> = graph
            .chains
            .iter()
            .map(|c| ChainSlots {
                geo: c.levels.iter().map(|_| Slot::new("chain geo")).collect(),
                grp: c.levels.iter().map(|_| Slot::new("chain groups")).collect(),
                feats: c.levels.iter().map(|_| Slot::new("chain feats")).collect(),
            })
            .collect();
        // RandomSplit: a fixed random partition of the cloud per seed
        let subsets: Option<(Arc<Vec<usize>>, Arc<Vec<usize>>)> =
            if graph.chains.iter().any(|c| c.subset.is_some()) {
                let mut rng = Rng::new(seed ^ 0xB5);
                let perm = rng.choice_no_replace(n, n);
                let half = n / 2;
                Some((Arc::new(perm[..half].to_vec()), Arc::new(perm[half..].to_vec())))
            } else {
                None
            };
        let inputs: Vec<ChainInput> = graph
            .chains
            .iter()
            .map(|c| match c.subset {
                None => ChainInput::Full,
                Some(0) => ChainInput::Subset(subsets.as_ref().expect("subset perm").0.clone()),
                Some(_) => ChainInput::Subset(subsets.as_ref().expect("subset perm").1.clone()),
            })
            .collect();
        let sa3_fused: Slot<Geo> = Slot::new("sa3 fused geo");
        let grp4: Slot<(Vec<usize>, Vec<Vec<usize>>)> = Slot::new("sa4 groups");
        let geo4: Slot<Geo> = Slot::new("sa4 geo");
        let sa3_feats_fused: Slot<Tensor> = Slot::new("sa3 fused feats");
        let sa4_feats: Slot<Tensor> = Slot::new("sa4 feats");
        let f2_slot: Slot<Tensor> = Slot::new("fp features");
        let seed_xyz_slot: Slot<pointops::PointsSoA> = Slot::new("seed xyz");
        let seeds_slot: Slot<Tensor> = Slot::new("seeds");
        let vote_slot: Slot<(Vec<[f32; 3]>, Tensor)> = Slot::new("votes");
        let pgrp_slot: Slot<(Vec<usize>, Vec<Vec<usize>>)> = Slot::new("proposal groups");
        let cluster_slot: Slot<Vec<[f32; 3]>> = Slot::new("cluster xyz");
        let prop_slot: Slot<Tensor> = Slot::new("proposals");
        let det_slot: Slot<Vec<Box3>> = Slot::new("detections");

        // ------------------------------------------- lower-to-exec pass
        let mut decls: Vec<StageDecl<'_>> = Vec::with_capacity(graph.nodes.len());
        for node in &graph.nodes {
            let art = node.artifact.clone();
            let qspec = node.qspec.clone();
            let compute: Compute<'_> = match node.class {
                StageClass::Seg => {
                    let art = art.expect("seg artifact");
                    let sl = scores_slot.clone();
                    let img_size = m.img_size;
                    Compute::Host(Box::new(move || {
                        let img = Tensor::new(vec![img_size, img_size, 3], scene.image.clone());
                        sl.set(
                            self.rt.run_with_spec_t(&art, &[&img], qspec.as_ref(), threads)?.remove(0),
                        );
                        Ok(())
                    }))
                }
                StageClass::Paint => {
                    let sl = scores_slot.clone();
                    let fs = feat_slot.clone();
                    let cap = capture_paint.clone();
                    Compute::Pool(Box::new(move || {
                        sl.with(|scores| {
                            let paint = match partial_paint {
                                Some((prev, dirty)) => {
                                    pointops::paint_points_partial(scene, scores, prev, dirty)
                                }
                                None => pointops::paint_points(scene, scores),
                            };
                            let fg = pointops::fg_mask(&paint, 0.5);
                            let feats = pointops::build_features(scene, Some(&paint));
                            if let Some(c) = &cap {
                                c.set(paint);
                            }
                            fs.set((feats, fg));
                        });
                        Ok(())
                    }))
                }
                StageClass::SaPm { chain, level } => {
                    let lvl = &graph.chains[chain].levels[level];
                    let sac = &m.sa_configs[level];
                    let geo_out = chain_slots[chain].geo[level].clone();
                    let grp_out = chain_slots[chain].grp[level].clone();
                    let prev_geo = (level > 0).then(|| chain_slots[chain].geo[level - 1].clone());
                    let input = inputs[chain].clone();
                    // biased FPS reads the painted fg mask (jump-start rule)
                    let fgsrc = lvl.use_bias.then(|| feat_slot.clone());
                    let (mm, radius, k, w0, start) = (lvl.m, sac.radius, sac.k, cfg.w0, lvl.start);
                    Compute::Pool(Box::new(move || {
                        let geo = resolve_geo(&prev_geo, &input, scene);
                        let idx = match &fgsrc {
                            Some(fs) => {
                                let fg: Vec<f32> =
                                    fs.with(|(_, fg)| geo.src.iter().map(|&i| fg[i]).collect());
                                pointops::biased_fps_soa(&geo.xyz, mm, &fg, w0, start, threads)
                            }
                            None => pointops::fps_soa(&geo.xyz, mm, start, threads),
                        };
                        let groups = pointops::ball_query_soa(&geo.xyz, &idx, radius, k, threads);
                        geo_out.set(Geo {
                            xyz: geo.xyz.gather(&idx),
                            src: idx.iter().map(|&i| geo.src[i]).collect(),
                        });
                        grp_out.set((idx, groups));
                        Ok(())
                    }))
                }
                StageClass::SaNn { chain, level } => {
                    let art = art.expect("sa artifact");
                    let feats_out = chain_slots[chain].feats[level].clone();
                    let grp_out = chain_slots[chain].grp[level].clone();
                    // level > 0 gathers from the previous level's chain-local
                    // geometry and features; level 0 gathers straight from
                    // the (possibly subsetted) original cloud
                    let prev = (level > 0).then(|| {
                        (
                            chain_slots[chain].geo[level - 1].clone(),
                            chain_slots[chain].feats[level - 1].clone(),
                        )
                    });
                    let input = inputs[chain].clone();
                    let feat_src = feat_slot.clone();
                    let mm = graph.chains[chain].levels[level].m;
                    Compute::Host(Box::new(move || {
                        let (idx, groups) = grp_out.take();
                        let g = match &prev {
                            Some((pgeo, pfeats)) => pgeo.with(|geo| {
                                pfeats.with(|f| {
                                    pointops::group_features_soa(&geo.xyz, Some(f), &idx, &groups)
                                })
                            }),
                            None => match &input {
                                ChainInput::Full => feat_src.with(|(f, _)| {
                                    pointops::group_features(
                                        &scene.points,
                                        Some(f),
                                        &idx,
                                        &groups,
                                    )
                                }),
                                ChainInput::Subset(sub) => {
                                    let xyz: Vec<[f32; 3]> =
                                        sub.iter().map(|&i| scene.points[i]).collect();
                                    let f = feat_src.with(|(f, _)| f.gather_rows(sub));
                                    pointops::group_features(&xyz, Some(&f), &idx, &groups)
                                }
                            },
                        };
                        feats_out.set(self.run_maybe_padded(&art, &g, mm, qspec.as_ref(), threads)?);
                        Ok(())
                    }))
                }
                StageClass::Sa4Pm => {
                    let sa3_geos: Vec<Slot<Geo>> =
                        chain_slots.iter().map(|c| c.geo[2].clone()).collect();
                    let (sa3_fused, grp4, geo4) = (sa3_fused.clone(), grp4.clone(), geo4.clone());
                    // the same flag that shaped the node's host-ordering
                    // edges — never re-derived here
                    let fgsrc = graph.sa4_bias.then(|| feat_slot.clone());
                    let sa4cfg = &m.sa_configs[3];
                    let (m4, r4, k4, w0) = (sa4cfg.m, sa4cfg.radius, sa4cfg.k, cfg.w0);
                    Compute::Pool(Box::new(move || {
                        let mut xyz = pointops::PointsSoA::new();
                        let mut src = Vec::new();
                        for g in &sa3_geos {
                            g.with(|geo| {
                                xyz.append(&geo.xyz);
                                src.extend_from_slice(&geo.src);
                            });
                        }
                        let idx4 = match &fgsrc {
                            Some(fs) => {
                                let fg: Vec<f32> =
                                    fs.with(|(_, fg)| src.iter().map(|&i| fg[i]).collect());
                                pointops::biased_fps_soa(&xyz, m4, &fg, w0, 0, threads)
                            }
                            None => pointops::fps_soa(&xyz, m4, 0, threads),
                        };
                        let groups4 = pointops::ball_query_soa(&xyz, &idx4, r4, k4, threads);
                        geo4.set(Geo {
                            xyz: xyz.gather(&idx4),
                            src: idx4.iter().map(|&i| src[i]).collect(),
                        });
                        grp4.set((idx4, groups4));
                        sa3_fused.set(Geo { xyz, src });
                        Ok(())
                    }))
                }
                StageClass::Sa4Nn => {
                    let art = art.expect("sa4 artifact");
                    let sa3_fs: Vec<Slot<Tensor>> =
                        chain_slots.iter().map(|c| c.feats[2].clone()).collect();
                    let (sa3_fused, sa3_feats_fused, grp4, sa4_feats) = (
                        sa3_fused.clone(),
                        sa3_feats_fused.clone(),
                        grp4.clone(),
                        sa4_feats.clone(),
                    );
                    Compute::Host(Box::new(move || {
                        let parts: Vec<Tensor> = sa3_fs.iter().map(|f| f.cloned()).collect();
                        let refs: Vec<&Tensor> = parts.iter().collect();
                        let fused = Tensor::concat0(&refs);
                        let (idx4, groups4) = grp4.take();
                        let g4 = sa3_fused.with(|geo| {
                            pointops::group_features_soa(&geo.xyz, Some(&fused), &idx4, &groups4)
                        });
                        sa4_feats
                            .set(self.rt.run_with_spec_t(&art, &[&g4], qspec.as_ref(), threads)?.remove(0));
                        sa3_feats_fused.set(fused);
                        Ok(())
                    }))
                }
                StageClass::FpInterp => {
                    let sa2_geos: Vec<Slot<Geo>> =
                        chain_slots.iter().map(|c| c.geo[1].clone()).collect();
                    let sa2_feats: Vec<Slot<Tensor>> =
                        chain_slots.iter().map(|c| c.feats[1].clone()).collect();
                    let (sa3_fused, sa3_feats_fused, geo4, sa4_feats) = (
                        sa3_fused.clone(),
                        sa3_feats_fused.clone(),
                        geo4.clone(),
                        sa4_feats.clone(),
                    );
                    let (f2_slot, seed_xyz_slot) = (f2_slot.clone(), seed_xyz_slot.clone());
                    Compute::Pool(Box::new(move || {
                        let sa4_f = sa4_feats.take();
                        let sa4_xyz = geo4.with(|g| g.xyz.clone());
                        let sa3_f = sa3_feats_fused.take();
                        let f3 = sa3_fused.with(|sa3| {
                            let f3up = pointops::three_nn_interpolate_soa(
                                &sa3.xyz, &sa4_xyz, &sa4_f, threads,
                            );
                            hconcat(&sa3_f, &f3up)
                        });
                        let mut sa2_xyz = pointops::PointsSoA::new();
                        for g in &sa2_geos {
                            g.with(|geo| sa2_xyz.append(&geo.xyz));
                        }
                        let f2up = sa3_fused.with(|sa3| {
                            pointops::three_nn_interpolate_soa(&sa2_xyz, &sa3.xyz, &f3, threads)
                        });
                        let parts: Vec<Tensor> = sa2_feats.iter().map(|f| f.cloned()).collect();
                        let refs: Vec<&Tensor> = parts.iter().collect();
                        let sa2_f = Tensor::concat0(&refs);
                        f2_slot.set(hconcat(&sa2_f, &f2up));
                        seed_xyz_slot.set(sa2_xyz);
                        Ok(())
                    }))
                }
                StageClass::FpFc => {
                    let art = art.expect("fp_fc artifact");
                    let (f2_slot, seeds_slot) = (f2_slot.clone(), seeds_slot.clone());
                    Compute::Host(Box::new(move || {
                        let f2 = f2_slot.take();
                        seeds_slot
                            .set(self.rt.run_with_spec_t(&art, &[&f2], qspec.as_ref(), threads)?.remove(0));
                        Ok(())
                    }))
                }
                StageClass::Vote => {
                    let art = art.expect("vote artifact");
                    let (seeds_slot, seed_xyz_slot, vote_slot) =
                        (seeds_slot.clone(), seed_xyz_slot.clone(), vote_slot.clone());
                    let cap = capture_seeds.clone();
                    Compute::Host(Box::new(move || {
                        let seeds = seeds_slot.take();
                        if let Some(c) = &cap {
                            c.set(seeds.clone());
                        }
                        let vote_out =
                            self.rt.run_with_spec_t(&art, &[&seeds], qspec.as_ref(), threads)?.remove(0);
                        let seed_xyz = seed_xyz_slot.take();
                        let cfeat = seeds.row_len();
                        let mut vote_xyz: Vec<[f32; 3]> = Vec::with_capacity(seed_xyz.len());
                        let mut vote_feats = Tensor::zeros(vec![seed_xyz.len(), cfeat]);
                        for i in 0..seed_xyz.len() {
                            let row = vote_out.row(i);
                            let s = seed_xyz.get(i);
                            vote_xyz.push([s[0] + row[0], s[1] + row[1], s[2] + row[2]]);
                            for c in 0..cfeat {
                                vote_feats.row_mut(i)[c] = seeds.row(i)[c] + row[3 + c];
                            }
                        }
                        vote_slot.set((vote_xyz, vote_feats));
                        Ok(())
                    }))
                }
                StageClass::PropPm => {
                    let (vote_slot, pgrp_slot, cluster_slot) =
                        (vote_slot.clone(), pgrp_slot.clone(), cluster_slot.clone());
                    let (np, pr, pk) = (m.num_proposals, m.proposal_radius, m.proposal_k);
                    Compute::Pool(Box::new(move || {
                        vote_slot.with(|(vote_xyz, _)| {
                            let pidx = pointops::fps_par(vote_xyz, np, threads);
                            let pgroups =
                                pointops::ball_query_par(vote_xyz, &pidx, pr, pk, threads);
                            cluster_slot.set(pidx.iter().map(|&i| vote_xyz[i]).collect());
                            pgrp_slot.set((pidx, pgroups));
                        });
                        Ok(())
                    }))
                }
                StageClass::Prop => {
                    let art = art.expect("prop artifact");
                    let (vote_slot, pgrp_slot, prop_slot) =
                        (vote_slot.clone(), pgrp_slot.clone(), prop_slot.clone());
                    Compute::Host(Box::new(move || {
                        let (pidx, pgroups) = pgrp_slot.take();
                        let pg = vote_slot.with(|(vote_xyz, vote_feats)| {
                            pointops::group_features(vote_xyz, Some(vote_feats), &pidx, &pgroups)
                        });
                        prop_slot
                            .set(self.rt.run_with_spec_t(&art, &[&pg], qspec.as_ref(), threads)?.remove(0));
                        Ok(())
                    }))
                }
                StageClass::Decode => {
                    let (cluster_slot, prop_slot, det_slot) =
                        (cluster_slot.clone(), prop_slot.clone(), det_slot.clone());
                    let (obj_thresh, nms_iou) = (cfg.obj_thresh, cfg.nms_iou);
                    Compute::Pool(Box::new(move || {
                        let cluster_xyz = cluster_slot.take();
                        let prop = prop_slot.take();
                        det_slot
                            .set(decode_detections(m, &cluster_xyz, &prop, obj_thresh, nms_iou));
                        Ok(())
                    }))
                }
            };
            decls.push(StageDecl {
                spec: node.spec.clone(),
                extra_deps: node.extra_deps.clone(),
                compute,
            });
        }

        // ---------------------------------------------- execute + simulate
        let specs = DagExecutor::new(self.host_exec).run(decls)?;
        let detections = det_slot.take();
        let used_scores = if painted { Some(scores_slot.take()) } else { None };
        if let Some(arts) = capture {
            arts.paint = match &capture_paint {
                Some(s) if painted => Some(s.take()),
                _ => None,
            };
            arts.seeds = capture_seeds.as_ref().map(|s| s.take());
            arts.fg = feat_slot.with(|(_, fg)| fg.clone());
            // the seed index set, in the same chain order FpInterp fused the
            // SA2 geometries — row i of `seeds` is point `seed_src[i]`
            let mut seed_src = Vec::new();
            for (ci, _) in graph.chains.iter().enumerate() {
                chain_slots[ci].geo[1].with(|g| seed_src.extend_from_slice(&g.src));
            }
            arts.seed_src = seed_src;
            arts.points = pointops::PointsSoA::from_points(&scene.points);
        }
        let timeline = self.sim.run(&specs);
        let fp32_framework = !cfg.int8() && matches!(cfg.schedule, Schedule::SingleDevice(_));
        let peak = peak_memory_mb(m, painted, fp32_framework, n);
        Ok((
            PipelineOutput {
                detections,
                timeline,
                stage_specs: specs,
                peak_memory_mb: peak,
                host_ms: t_host.elapsed().as_secs_f64() * 1000.0,
            },
            used_scores,
        ))
    }

    /// Run one frame of a temporal stream against a per-session cache.
    ///
    /// The cache's delta estimator classifies the frame; the class actually
    /// *served* (returned alongside the output) may degrade to FULL when the
    /// cache cannot back the verdict (cold session, missing artifacts, index
    /// drift). FULL frames run the existing single-scene pipeline
    /// bit-identically — the cache only observes them, never influences them
    /// — and refresh the cache. PARTIAL frames skip the segmenter and
    /// repaint only dirty grid cells. REUSE frames execute only the
    /// stream-tail sub-graph from cached seed features.
    pub fn run_stream(
        &self,
        scene: &Scene,
        seed: u64,
        cache: &mut FrameCache,
    ) -> Result<(PipelineOutput, FrameClass)> {
        let delta = cache.classify(&scene.points);
        let painted = self.cfg.variant.painted();
        let n = scene.points.len();
        let class = match delta.class {
            FrameClass::Reuse
                if cache.artifacts().is_some_and(|a| {
                    a.seeds.is_some()
                        && !a.seed_src.is_empty()
                        && a.seed_src.iter().all(|&i| i < n)
                }) =>
            {
                FrameClass::Reuse
            }
            FrameClass::Partial
                if painted
                    && cache.artifacts().is_some_and(|a| {
                        a.scores.is_some()
                            && a.paint.as_ref().is_some_and(|p| p.rows() == n)
                            && delta.dirty.len() == n
                    }) =>
            {
                FrameClass::Partial
            }
            _ => FrameClass::Full,
        };
        match class {
            FrameClass::Full => {
                let mut arts = StreamArtifacts::default();
                let (out, used_scores) =
                    self.run_impl(scene, seed, ReuseMode::Cold, Some(&mut arts))?;
                arts.scores = used_scores;
                cache.install(&scene.points, arts);
                cache.record(FrameClass::Full);
                Ok((out, FrameClass::Full))
            }
            FrameClass::Partial => {
                let prev = cache
                    .take_artifacts()
                    .ok_or_else(|| anyhow!("partial frame without cached artifacts"))?;
                let scores = prev
                    .scores
                    .as_ref()
                    .ok_or_else(|| anyhow!("partial frame without cached scores"))?;
                let prev_paint = prev
                    .paint
                    .as_ref()
                    .ok_or_else(|| anyhow!("partial frame without cached paint"))?;
                let mut arts = StreamArtifacts::default();
                let mode =
                    ReuseMode::Partial { scores, prev_paint, dirty: &delta.dirty };
                let (out, used_scores) = self.run_impl(scene, seed, mode, Some(&mut arts))?;
                arts.scores = used_scores;
                cache.install(&scene.points, arts);
                cache.record(FrameClass::Partial);
                Ok((out, FrameClass::Partial))
            }
            FrameClass::Reuse => {
                let arts = cache
                    .artifacts()
                    .ok_or_else(|| anyhow!("reuse frame without cached artifacts"))?;
                let out = self.run_stream_reuse(scene, arts)?;
                cache.record(FrameClass::Reuse);
                Ok((out, FrameClass::Reuse))
            }
        }
    }

    /// REUSE-frame fast path: execute only the stream-tail sub-graph (vote →
    /// proposal clustering → proposal net → decode) from the cached seed
    /// features. Seed *centers* are re-gathered from the **current** cloud
    /// through the cached biased-sampling indices — within a shot, point
    /// index identity makes that gather the exact ego-motion + object-motion
    /// transform of the cached centers, so votes track the moving scene even
    /// though the SA features are a frame old.
    fn run_stream_reuse(&self, scene: &Scene, arts: &StreamArtifacts) -> Result<PipelineOutput> {
        let t_host = std::time::Instant::now();
        let cfg = &self.cfg;
        let m = &self.rt.manifest;
        let threads = self.host_exec.threads();
        let n = scene.points.len();
        let tail = StageGraph::build(m, cfg, n, true)?.stream_tail();
        let node = |class: StageClass| {
            tail.nodes
                .iter()
                .find(|nd| nd.class == class)
                .ok_or_else(|| anyhow!("stream tail missing a {class:?} stage"))
        };
        let vote_node = node(StageClass::Vote)?;
        let prop_node = node(StageClass::Prop)?;
        let vote_art =
            vote_node.artifact.as_deref().ok_or_else(|| anyhow!("vote artifact missing"))?;
        let prop_art =
            prop_node.artifact.as_deref().ok_or_else(|| anyhow!("prop artifact missing"))?;
        let seeds =
            arts.seeds.as_ref().ok_or_else(|| anyhow!("reuse frame without cached seeds"))?;
        if arts.seed_src.iter().any(|&i| i >= n) {
            return Err(anyhow!("cached seed indices out of range for this frame"));
        }
        let seed_xyz = pointops::PointsSoA::from_indexed(&scene.points, &arts.seed_src);
        if seed_xyz.len() != seeds.rows() {
            return Err(anyhow!(
                "cached seeds ({} rows) disagree with seed index set ({})",
                seeds.rows(),
                seed_xyz.len()
            ));
        }
        // vote head — same math as the Vote closure of the full pipeline
        let vote_out =
            self.rt.run_with_spec_t(vote_art, &[seeds], vote_node.qspec.as_ref(), threads)?.remove(0);
        let cfeat = seeds.row_len();
        let mut vote_xyz: Vec<[f32; 3]> = Vec::with_capacity(seed_xyz.len());
        let mut vote_feats = Tensor::zeros(vec![seed_xyz.len(), cfeat]);
        for i in 0..seed_xyz.len() {
            let row = vote_out.row(i);
            let s = seed_xyz.get(i);
            vote_xyz.push([s[0] + row[0], s[1] + row[1], s[2] + row[2]]);
            for c in 0..cfeat {
                vote_feats.row_mut(i)[c] = seeds.row(i)[c] + row[3 + c];
            }
        }
        let (np, pr, pk) = (m.num_proposals, m.proposal_radius, m.proposal_k);
        let pidx = pointops::fps_par(&vote_xyz, np, threads);
        let pgroups = pointops::ball_query_par(&vote_xyz, &pidx, pr, pk, threads);
        let cluster_xyz: Vec<[f32; 3]> = pidx.iter().map(|&i| vote_xyz[i]).collect();
        let pg = pointops::group_features(&vote_xyz, Some(&vote_feats), &pidx, &pgroups);
        let prop =
            self.rt.run_with_spec_t(prop_art, &[&pg], prop_node.qspec.as_ref(), threads)?.remove(0);
        let detections = decode_detections(m, &cluster_xyz, &prop, cfg.obj_thresh, cfg.nms_iou);
        let specs = tail.specs();
        let timeline = self.sim.run(&specs);
        let fp32_framework = !cfg.int8() && matches!(cfg.schedule, Schedule::SingleDevice(_));
        let peak = peak_memory_mb(m, cfg.variant.painted(), fp32_framework, n);
        Ok(PipelineOutput {
            detections,
            timeline,
            stage_specs: specs,
            peak_memory_mb: peak,
            host_ms: t_host.elapsed().as_secs_f64() * 1000.0,
        })
    }

    /// Execute an SA artifact whose ball-batch dimension may exceed ours
    /// (RandomSplit halves reuse the `half` artifacts of matching size; the
    /// padding path covers residual mismatches defensively). A *smaller*
    /// artifact is a malformed export — reported as an error, not a panic,
    /// so the serving path degrades instead of dying.
    fn run_maybe_padded(
        &self,
        art: &str,
        g: &Tensor,
        b: usize,
        spec: Option<&QuantSpec>,
        threads: usize,
    ) -> Result<Tensor> {
        let meta = self
            .rt
            .manifest
            .artifact(art)
            .ok_or_else(|| anyhow!("artifact '{art}' missing"))?;
        let want = meta.input_shapes[0][0];
        if want == b {
            return Ok(self.rt.run_with_spec_t(art, &[g], spec, threads)?.remove(0));
        }
        if want < b {
            return Err(anyhow!(
                "artifact '{art}' ball dimension {want} smaller than workload {b} \
                 (malformed export?)"
            ));
        }
        let mut padded = Tensor::zeros(vec![want, g.shape[1], g.shape[2]]);
        padded.data[..g.data.len()].copy_from_slice(&g.data);
        let out = self.rt.run_with_spec_t(art, &[&padded], spec, threads)?.remove(0);
        let rows: Vec<usize> = (0..b).collect();
        Ok(out.gather_rows(&rows))
    }
}

/// Resolve a level's input geometry: the previous level's output, or the
/// (possibly subsetted) original cloud for level 0.
fn resolve_geo(prev: &Option<Slot<Geo>>, input: &ChainInput, scene: &Scene) -> Geo {
    match prev {
        Some(s) => s.cloned(),
        None => match input {
            ChainInput::Full => Geo {
                xyz: pointops::PointsSoA::from_points(&scene.points),
                src: (0..scene.points.len()).collect(),
            },
            ChainInput::Subset(idx) => Geo {
                xyz: pointops::PointsSoA::from_indexed(&scene.points, idx),
                src: idx.as_ref().clone(),
            },
        },
    }
}

/// Horizontal concat of two (N, C) tensors.
fn hconcat(a: &Tensor, b: &Tensor) -> Tensor {
    assert_eq!(a.rows(), b.rows());
    let (ca, cb) = (a.row_len(), b.row_len());
    let mut data = Vec::with_capacity(a.rows() * (ca + cb));
    for i in 0..a.rows() {
        data.extend_from_slice(a.row(i));
        data.extend_from_slice(b.row(i));
    }
    Tensor::new(vec![a.rows(), ca + cb], data)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::DeviceKind;

    fn pipeline(rt: &Runtime) -> ScenePipeline<'_> {
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        ScenePipeline::new(rt, cfg)
    }

    #[test]
    fn run_maybe_padded_pads_smaller_workloads() {
        let rt = Runtime::synthetic();
        let p = pipeline(&rt);
        // sa1_full expects 256 balls of (32, 15); feed 200
        let g = Tensor::zeros(vec![200, 32, 15]);
        let out = p
            .run_maybe_padded("synrgbd_pointsplit_sa1_full_int8", &g, 200, None, 1)
            .unwrap();
        assert_eq!(out.rows(), 200);
    }

    #[test]
    fn run_maybe_padded_rejects_oversized_workloads_gracefully() {
        let rt = Runtime::synthetic();
        let p = pipeline(&rt);
        let g = Tensor::zeros(vec![300, 32, 15]);
        let err = p
            .run_maybe_padded("synrgbd_pointsplit_sa1_full_int8", &g, 300, None, 1)
            .unwrap_err();
        let msg = format!("{err:#}");
        assert!(msg.contains("smaller than workload"), "unexpected error: {msg}");
    }

    /// The executed DAG is the graph's DAG, verbatim.
    #[test]
    fn executed_specs_equal_graph_specs() {
        let rt = Runtime::synthetic();
        let p = pipeline(&rt);
        let ds = crate::data::dataset("synrgbd").unwrap();
        let scene = crate::data::generate_scene(9, ds);
        let out = p.run(&scene, 9).unwrap();
        let g = StageGraph::build(&rt.manifest, &p.cfg, scene.points.len(), false).unwrap();
        assert_eq!(out.stage_specs, g.specs());
    }
}
