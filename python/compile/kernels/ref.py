"""Pure-jnp correctness oracles for every Pallas kernel in this package.

These are the ground truth for pytest (kernel vs ref allclose) and are also
used by the L2 model when ``use_pallas=False`` — keeping one numerical
definition of each op.
"""

from __future__ import annotations

import jax.numpy as jnp


def pointnet_ref(groups: jnp.ndarray, weights) -> jnp.ndarray:
    """Shared-MLP + max-pool PointNet core.

    groups:  (B, K, C_in) grouped point features (B balls, K neighbors)
    weights: sequence of (W, b) with W: (C_l, C_{l+1})
    returns: (B, C_out) = max over K of MLP(point)
    """
    x = groups
    for w, b in weights:
        x = jnp.maximum(jnp.dot(x, w) + b, 0.0)
    return jnp.max(x, axis=1)


def mlp_ref(x: jnp.ndarray, weights, relu_last: bool = True) -> jnp.ndarray:
    """Plain per-point shared MLP (no pooling). x: (N, C_in)."""
    n = len(weights)
    for i, (w, b) in enumerate(weights):
        x = jnp.dot(x, w) + b
        if relu_last or i + 1 < n:
            x = jnp.maximum(x, 0.0)
    return x


def qdq_weight(w: jnp.ndarray, scale: jnp.ndarray) -> jnp.ndarray:
    """Symmetric INT8 quantize-dequantize of a weight matrix.

    scale: per-output-channel (C_out,) scale vector (any granularity is
    encoded by repeating a group's scale across its channels).
    """
    q = jnp.clip(jnp.round(w / scale[None, :]), -127, 127)
    return q * scale[None, :]


def qdq_act(x: jnp.ndarray, scale: jnp.ndarray, zero: jnp.ndarray) -> jnp.ndarray:
    """Affine INT8 quantize-dequantize of activations along the last axis."""
    q = jnp.clip(jnp.round(x / scale + zero), -128, 127)
    return (q - zero) * scale


def qmlp_ref(
    x: jnp.ndarray,
    w: jnp.ndarray,
    b: jnp.ndarray,
    w_scale: jnp.ndarray,
    a_scale: jnp.ndarray,
    a_zero: jnp.ndarray,
) -> jnp.ndarray:
    """Quantized head layer: QDQ(weights) matmul + bias, QDQ(output).

    This models a fully-integer EdgeTPU layer: the achievable numerics are
    exactly those of (dequantized int8 weights, int8-requantized outputs).
    """
    wq = qdq_weight(w, w_scale)
    y = jnp.dot(x, wq) + b
    return qdq_act(y, a_scale, a_zero)


def pairwise_dist2_ref(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Squared euclidean distances. a: (N, 3), b: (M, 3) -> (N, M)."""
    d = a[:, None, :] - b[None, :, :]
    return jnp.sum(d * d, axis=-1)
