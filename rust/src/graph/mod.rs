//! One stage-graph IR for execution, simulation, and serving.
//!
//! [`StageGraph`] is the single source of truth for the detector's stage
//! DAG. It is built **exactly once** per ([`DetectorConfig`], [`Manifest`],
//! point budget) by [`StageGraph::build`], and every consumer is a *pass*
//! over the same graph instead of a parallel construction:
//!
//! - **lower-to-exec** — `coordinator::pipeline` walks the nodes and
//!   attaches a compute closure per [`StageClass`], feeding
//!   [`crate::exec::DagExecutor`];
//! - **lower-to-sim** — [`StageGraph::specs`] hands the embedded
//!   [`StageSpec`]s to [`crate::sim::ScheduleSim`], so the pipeline's and
//!   the serving planner's timelines are identical *by construction*;
//! - **batch-fold(k)** — [`StageGraph::batch_fold`] scales FLOPs/bytes by
//!   the batch size while per-stage dispatch and transfer *setup* costs
//!   are paid once (the dynamic-batching win on this hardware);
//! - **quant-rewrite** — [`StageGraph::quant_rewrite`] swaps the
//!   [`QuantScheme`] on the same topology (the SLO degrade move, see
//!   [`crate::serving::slo`]);
//! - **placement-search** — [`place`] enumerates per-stage-class device
//!   assignments under capability/memory constraints and picks the best
//!   [`crate::coordinator::Schedule`] (the paper's Fig. 10 pairings become
//!   named points in this search space).
//!
//! Before this module existed the graph was encoded twice — once in
//! `coordinator/pipeline.rs` (executed + simulated) and once hand-mirrored
//! in `serving/plan.rs` — recreating the dependency-drift bug class the
//! `merge()` fix closed. A second construction site can no longer drift
//! because there is no second construction site.
//!
//! See `docs/ARCHITECTURE.md` for the IR's invariants and how to add a
//! pass.

use anyhow::{anyhow, Result};

use crate::coordinator::arch::{nn_workload_of, sa_pointmanip_workload, small_pointop};
use crate::coordinator::{DetectorConfig, Variant};
use crate::quant::{QuantScheme, QuantSpec, StagePrecision};
use crate::runtime::Manifest;
use crate::sim::{DeviceKind, Precision, StageSpec, Workload};

pub mod place;

/// What a stage *is*, independent of where it runs: the handle passes use
/// to rewrite specs (quant-rewrite resolves artifacts per class) and the
/// executor uses to attach the right compute closure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StageClass {
    /// 2D semantic segmentation of the RGB frame.
    Seg,
    /// Point painting: append per-point class scores + build features.
    Paint,
    /// SA-level point manipulation (FPS + ball query + gather) of a chain.
    SaPm { chain: usize, level: usize },
    /// SA-level PointNet of a chain.
    SaNn { chain: usize, level: usize },
    /// SA4 point manipulation over the fused SA3 set.
    Sa4Pm,
    /// SA4 PointNet over the fused SA3 set.
    Sa4Nn,
    /// Feature-propagation interpolation (point op).
    FpInterp,
    /// Feature-propagation shared FC (the paper's Table 1 simplification).
    FpFc,
    /// Vote head.
    Vote,
    /// Proposal clustering (point op).
    PropPm,
    /// Proposal PointNet + head.
    Prop,
    /// Box decode + NMS on the host CPU.
    Decode,
}

impl StageClass {
    /// Manifest network label of an NN stage class (None for point ops).
    /// `split` selects the half-budget SA artifacts of the two-pipeline
    /// variants.
    pub fn net(self, split: bool) -> Option<String> {
        let shape = if split { "half" } else { "full" };
        Some(match self {
            StageClass::Seg => "seg".to_string(),
            StageClass::SaNn { level, .. } => format!("sa{}_{shape}", level + 1),
            StageClass::Sa4Nn => "sa4_full".to_string(),
            StageClass::FpFc => "fp_fc".to_string(),
            StageClass::Vote => "vote".to_string(),
            StageClass::Prop => "prop".to_string(),
            _ => return None,
        })
    }
}

/// One node of the IR: the simulator spec plus everything a pass needs to
/// re-derive or execute it.
#[derive(Debug, Clone, PartialEq)]
pub struct StageNode {
    /// What the calibrated device model simulates — name, device,
    /// precision, workload, and the *timeline* dependencies.
    pub spec: StageSpec,
    pub class: StageClass,
    /// Manifest artifact an NN stage executes (None for point ops).
    pub artifact: Option<String>,
    /// Explicit quant spec handed to the runtime for NN stages (the
    /// scheme's granularity may refine what the artifact name encodes).
    pub qspec: Option<QuantSpec>,
    /// Host-ordering dependencies beyond `spec.deps`: data produced by a
    /// stage the simulated timeline does not wait for (e.g. painted
    /// features gathered during an NN stage's transfer window).
    pub extra_deps: Vec<usize>,
}

/// One declared SA level of a backbone chain, as the exec lowering needs
/// it: node indices plus the static geometry budget.
#[derive(Debug, Clone, PartialEq)]
pub struct LevelInfo {
    /// node index of the point-manipulation stage
    pub pm: usize,
    /// node index of the PointNet stage
    pub nn: usize,
    /// points entering this level
    pub n_in: usize,
    /// centroids sampled by this level
    pub m: usize,
    /// feature width after this level's PointNet
    pub c: usize,
    /// FPS start index (SA-bias decorrelation rule)
    pub start: usize,
    /// whether this level's FPS is biased by the painted fg mask
    pub use_bias: bool,
}

/// One backbone chain (SA1..SA3) of the graph.
#[derive(Debug, Clone, PartialEq)]
pub struct ChainInfo {
    pub tag: &'static str,
    pub biased: bool,
    /// RandomSplit half index (0/1); None = the full cloud feeds level 0.
    pub subset: Option<usize>,
    /// points entering the chain
    pub n0: usize,
    /// exactly three SA levels
    pub levels: Vec<LevelInfo>,
}

/// The stage-graph IR. Immutable once built; passes produce new data
/// (spec lists, rewritten graphs) rather than mutating in place.
#[derive(Debug, Clone)]
pub struct StageGraph {
    pub nodes: Vec<StageNode>,
    pub chains: Vec<ChainInfo>,
    /// Whether SA4's fused FPS is biased by the painted fg mask (Table 10
    /// "all SA layers" ablation) — declared here so the exec lowering
    /// reads the same flag that shaped `sa4_pm`'s host-ordering edges.
    pub sa4_bias: bool,
    cfg: DetectorConfig,
    num_points: usize,
    skip_seg: bool,
}

/// Everything an NN node derives from the manifest for its class under a
/// configuration's scheme: artifact name, simulated precision, workload
/// (seg-pass scaling applied), and the runtime quant spec. `Ok(None)` for
/// point-op classes. This is the **only** derivation path — shared by
/// [`StageGraph::build`] and [`StageGraph::quant_rewrite`], so the rewrite
/// pass cannot drift from the constructor.
#[allow(clippy::type_complexity)]
pub(crate) fn nn_assign(
    m: &Manifest,
    cfg: &DetectorConfig,
    class: StageClass,
) -> Result<Option<(String, Precision, Workload, QuantSpec)>> {
    let Some(net) = class.net(cfg.variant.split()) else { return Ok(None) };
    let art = if class == StageClass::Seg { cfg.seg_art() } else { cfg.art(&net) };
    let sp = match class {
        StageClass::Vote => cfg.scheme.vote,
        StageClass::Prop => cfg.scheme.prop,
        _ => cfg.scheme.backbone,
    };
    let meta = m
        .artifact(&art)
        .ok_or_else(|| anyhow!("artifact '{art}' missing from manifest"))?;
    let precision =
        StagePrecision::parse(&meta.precision).map_or(Precision::Fp32, StagePrecision::sim);
    let mut wl = nn_workload_of(m, meta);
    if class == StageClass::Seg {
        wl.flops *= cfg.seg_passes as u64;
    }
    Ok(Some((art, precision, wl, m.stage_quant_for(meta, sp))))
}

/// Device an NN stage sits on. The EdgeTPU executes int8 only (the paper's
/// motivation for full quantization), so fp32 NN work falls back to the
/// point device; placement is decided *per stage*: head stages (vote/prop)
/// place by their own precision, backbone-class stages by the scheme's
/// backbone precision — a mixed scheme keeps int8 stages on the NPU while
/// fp32 ones fall back.
pub(crate) fn nn_device(
    cfg: &DetectorConfig,
    class: StageClass,
    precision: Precision,
) -> DeviceKind {
    let point_dev = cfg.schedule.point_dev();
    let nn_dev_raw = cfg.schedule.nn_dev();
    let fall = |p: Precision| {
        if p == Precision::Fp32 && nn_dev_raw == DeviceKind::EdgeTpu {
            point_dev
        } else {
            nn_dev_raw
        }
    };
    match class {
        StageClass::Vote | StageClass::Prop => fall(precision),
        _ => fall(cfg.scheme.backbone.sim()),
    }
}

/// Node-list accumulator with the sequential-schedule chaining rule: on a
/// non-overlapped schedule every stage also depends on the previously
/// declared one (Fig. 2's naive split).
struct GraphBuilder {
    nodes: Vec<StageNode>,
    sequential: bool,
    prev: Option<usize>,
}

impl GraphBuilder {
    #[allow(clippy::too_many_arguments)]
    fn push(
        &mut self,
        name: String,
        class: StageClass,
        device: DeviceKind,
        precision: Precision,
        workload: Workload,
        mut deps: Vec<usize>,
        extra_deps: Vec<usize>,
        artifact: Option<String>,
        qspec: Option<QuantSpec>,
    ) -> usize {
        if self.sequential {
            if let Some(p) = self.prev {
                if !deps.contains(&p) {
                    deps.push(p);
                }
            }
        }
        let idx = self.nodes.len();
        self.nodes.push(StageNode {
            spec: StageSpec { name, device, precision, workload, deps },
            class,
            artifact,
            qspec,
            extra_deps,
        });
        self.prev = Some(idx);
        idx
    }
}

impl StageGraph {
    /// Build the graph for one configuration — the only place in the crate
    /// where the detector's stage topology is spelled out.
    ///
    /// `skip_seg` models consecutive matching (2D scores reused from a
    /// previous frame, paper §3.2): the segmenter node is omitted while the
    /// paint node remains (it consumes the carried-over scores).
    ///
    /// A malformed or incomplete manifest is a recoverable error, not a
    /// panic — serving workers degrade instead of dying.
    pub fn build(
        m: &Manifest,
        cfg: &DetectorConfig,
        num_points: usize,
        skip_seg: bool,
    ) -> Result<StageGraph> {
        let point_dev = cfg.schedule.point_dev();
        let painted = cfg.variant.painted();
        let n = num_points;
        let mut b = GraphBuilder {
            nodes: Vec::new(),
            sequential: !cfg.schedule.overlapped(),
            prev: None,
        };
        // every NN node's (artifact, precision, workload, qspec) and its
        // device come from the shared per-class derivation (`nn_assign` /
        // `nn_device`) — the same path `quant_rewrite` re-applies

        // ------------------------------------------------------ 2D segment
        let seg = if painted && !skip_seg {
            let (art, prec, wl, qspec) =
                nn_assign(m, cfg, StageClass::Seg)?.expect("seg is an NN class");
            Some(b.push(
                "seg".into(),
                StageClass::Seg,
                nn_device(cfg, StageClass::Seg, prec),
                prec,
                wl,
                vec![],
                vec![],
                Some(art),
                Some(qspec),
            ))
        } else {
            None
        };
        let paint = if painted {
            Some(b.push(
                "paint".into(),
                StageClass::Paint,
                point_dev,
                Precision::Fp32,
                small_pointop((n * 8) as u64, (n * m.num_seg_classes) as u64),
                seg.into_iter().collect(),
                vec![],
                None,
                None,
            ))
        } else {
            None
        };
        let c0 = if painted { m.feat_dim_painted } else { m.feat_dim_plain };

        // ------------------------------------------------------ backbone
        let chain_descs: Vec<(&'static str, bool, Option<usize>, usize)> = match cfg.variant {
            Variant::VoteNet | Variant::PointPainting => vec![("full", false, None, n)],
            Variant::PointSplit => vec![("normal", false, None, n), ("bias", true, None, n)],
            Variant::RandomSplit => {
                let half = n / 2;
                vec![("randA", false, Some(0), half), ("randB", false, Some(1), n - half)]
            }
        };
        let halves = cfg.variant.split();
        let mut chains: Vec<ChainInfo> = Vec::with_capacity(chain_descs.len());
        for (ci, (tag, biased, subset, n0)) in chain_descs.into_iter().enumerate() {
            let mut levels = Vec::with_capacity(3);
            let (mut n_in, mut c_in) = (n0, c0);
            let mut prev_nn: Option<usize> = None;
            for l in 0..3 {
                let sac = &m.sa_configs[l];
                let mm = if halves { sac.m / 2 } else { sac.m };
                let use_bias = biased && l < cfg.bias_layers && cfg.w0 != 1.0;
                // the SA-bias pipeline's SA1 starts FPS at n/2 so the two
                // views decorrelate even where the bias weight has no effect
                let start = if biased && l == 0 { n_in / 2 } else { 0 };
                // point-manip deps: previous NN of this chain produced the
                // features we gather; biased FPS additionally needs the
                // painted fg mask (jump-start rule, Fig. 3)
                let mut deps: Vec<usize> = match prev_nn {
                    Some(p) => vec![p],
                    None => seg.into_iter().collect(),
                };
                if use_bias {
                    if let Some(s) = seg {
                        if !deps.contains(&s) {
                            deps.push(s);
                        }
                    }
                }
                // SA1-normal point manip of a painted pipeline needs
                // nothing: it jump-starts before segmentation finishes
                let deps_pm = if l == 0 && !use_bias { Vec::new() } else { deps };
                // host-ordering: biased FPS reads the fg mask built by paint
                let extra_pm: Vec<usize> = if use_bias && painted {
                    paint.into_iter().collect()
                } else {
                    Vec::new()
                };
                let pm = b.push(
                    format!("sa{}_{}_pm", l + 1, tag),
                    StageClass::SaPm { chain: ci, level: l },
                    point_dev,
                    Precision::Fp32,
                    sa_pointmanip_workload(n_in, mm, sac.k, c_in),
                    deps_pm,
                    extra_pm,
                    None,
                    None,
                );
                let mut deps_nn = vec![pm];
                if l == 0 {
                    if let Some(s) = seg {
                        deps_nn.push(s); // painted features required
                    }
                }
                // host-ordering: the level-0 gather reads features built by
                // the paint stage (seg alone finishing is not enough)
                let extra_nn: Vec<usize> = if l == 0 && painted {
                    paint.into_iter().collect()
                } else {
                    Vec::new()
                };
                let class = StageClass::SaNn { chain: ci, level: l };
                let (art, prec, wl, qspec) =
                    nn_assign(m, cfg, class)?.expect("sa levels are NN classes");
                let nn = b.push(
                    format!("sa{}_{}_nn", l + 1, tag),
                    class,
                    nn_device(cfg, class, prec),
                    prec,
                    wl,
                    deps_nn,
                    extra_nn,
                    Some(art),
                    Some(qspec),
                );
                let c_out = *sac.mlp.last().expect("sa mlp widths");
                levels.push(LevelInfo { pm, nn, n_in, m: mm, c: c_out, start, use_bias });
                n_in = mm;
                c_in = c_out;
                prev_nn = Some(nn);
            }
            chains.push(ChainInfo { tag, biased, subset, n0, levels });
        }
        let sa2_n: usize = chains.iter().map(|c| c.levels[1].m).sum();
        let sa3_n: usize = chains.iter().map(|c| c.levels[2].m).sum();
        let sa3_c = chains[0].levels[2].c;

        // SA4 over the fused SA3 set: it must wait for **every**
        // contributing chain's SA3 PointNet (the old single `max(a, b)`
        // dependency let sa4_pm start before the slower pipeline finished)
        let sa4cfg = &m.sa_configs[3];
        let mut deps4: Vec<usize> = chains.iter().map(|c| c.levels[2].nn).collect();
        deps4.sort_unstable();
        let use_bias4 = cfg.bias_layers >= 4 && cfg.variant == Variant::PointSplit;
        let extra4: Vec<usize> = if use_bias4 && painted {
            paint.into_iter().collect()
        } else {
            Vec::new()
        };
        let pm4 = b.push(
            "sa4_pm".into(),
            StageClass::Sa4Pm,
            point_dev,
            Precision::Fp32,
            sa_pointmanip_workload(sa3_n, sa4cfg.m, sa4cfg.k, sa3_c),
            deps4,
            extra4,
            None,
            None,
        );
        let (art4, prec4, wl4, q4) =
            nn_assign(m, cfg, StageClass::Sa4Nn)?.expect("sa4_nn is an NN class");
        let nn4 = b.push(
            "sa4_nn".into(),
            StageClass::Sa4Nn,
            nn_device(cfg, StageClass::Sa4Nn, prec4),
            prec4,
            wl4,
            vec![pm4],
            vec![],
            Some(art4),
            Some(q4),
        );

        // ------------------------------------------------------ FP + heads
        let fp_pm = b.push(
            "fp_interp".into(),
            StageClass::FpInterp,
            point_dev,
            Precision::Fp32,
            small_pointop((sa2_n * sa3_n * 4) as u64, (sa2_n * m.fp_in * 4) as u64),
            vec![nn4],
            vec![],
            None,
            None,
        );
        let (art_fp, prec_fp, wl_fp, q_fp) =
            nn_assign(m, cfg, StageClass::FpFc)?.expect("fp_fc is an NN class");
        let fp_nn = b.push(
            "fp_fc".into(),
            StageClass::FpFc,
            nn_device(cfg, StageClass::FpFc, prec_fp),
            prec_fp,
            wl_fp,
            vec![fp_pm],
            vec![],
            Some(art_fp),
            Some(q_fp),
        );
        let (art_vote, prec_v, wl_v, q_v) =
            nn_assign(m, cfg, StageClass::Vote)?.expect("vote is an NN class");
        let vote = b.push(
            "vote".into(),
            StageClass::Vote,
            nn_device(cfg, StageClass::Vote, prec_v),
            prec_v,
            wl_v,
            vec![fp_nn],
            vec![],
            Some(art_vote),
            Some(q_v),
        );
        let prop_pm = b.push(
            "prop_pm".into(),
            StageClass::PropPm,
            point_dev,
            Precision::Fp32,
            sa_pointmanip_workload(sa2_n, m.num_proposals, m.proposal_k, m.seed_feat),
            vec![vote],
            vec![],
            None,
            None,
        );
        let (art_prop, prec_p, wl_p, q_p) =
            nn_assign(m, cfg, StageClass::Prop)?.expect("prop is an NN class");
        let prop = b.push(
            "prop".into(),
            StageClass::Prop,
            nn_device(cfg, StageClass::Prop, prec_p),
            prec_p,
            wl_p,
            vec![prop_pm],
            vec![],
            Some(art_prop),
            Some(q_p),
        );
        b.push(
            "decode".into(),
            StageClass::Decode,
            DeviceKind::Cpu,
            Precision::Fp32,
            small_pointop((m.num_proposals * m.num_proposals) as u64 * 20, 4096),
            vec![prop],
            vec![],
            None,
            None,
        );
        let g = StageGraph {
            nodes: b.nodes,
            chains,
            sa4_bias: use_bias4,
            cfg: cfg.clone(),
            num_points,
            skip_seg,
        };
        g.debug_verify(m);
        Ok(g)
    }

    /// Pass self-verification: every constructor/rewrite output is checked
    /// against the placement-independent rule set in debug builds (tests,
    /// CI) at zero release cost. A violation here is a bug in the pass
    /// itself, so it asserts rather than returning a `Result`.
    #[inline]
    fn debug_verify(&self, m: &Manifest) {
        #[cfg(debug_assertions)]
        {
            let rep = crate::verify::verify_structure(m, self);
            debug_assert!(!rep.has_errors(), "pass output failed verification:\n{rep}");
        }
        #[cfg(not(debug_assertions))]
        let _ = m;
    }

    pub fn cfg(&self) -> &DetectorConfig {
        &self.cfg
    }

    pub fn num_points(&self) -> usize {
        self.num_points
    }

    pub fn skip_seg(&self) -> bool {
        self.skip_seg
    }

    /// **lower-to-sim**: the `StageSpec` sequence [`crate::sim::ScheduleSim`]
    /// replays — the same objects the executor's declarations embed.
    pub fn specs(&self) -> Vec<StageSpec> {
        self.nodes.iter().map(|n| n.spec.clone()).collect()
    }

    /// **batch-fold(k)**: `k` compatible scenes folded into one DAG.
    /// Every stage's FLOPs/bytes scale by `k`, while per-stage dispatch
    /// (`Device::overhead_ms`) and transfer setup (`link_overhead_ms`) are
    /// paid once per stage — precisely where dynamic batching wins on this
    /// hardware (EdgeTPU: 20 ms per transfer, GPU: 14 ms per dispatch).
    pub fn batch_fold(&self, batch: usize) -> Vec<StageSpec> {
        let k = batch.max(1) as u64;
        let folded: Vec<StageSpec> = self
            .nodes
            .iter()
            .map(|n| {
                let mut s = n.spec.clone();
                s.workload.flops *= k;
                s.workload.mem_bytes *= k;
                s.workload.wire_bytes *= k;
                s
            })
            .collect();
        #[cfg(debug_assertions)]
        {
            let rep = crate::verify::check_fold(&self.specs(), &folded, batch.max(1));
            debug_assert!(!rep.has_errors(), "batch_fold output failed verification:\n{rep}");
        }
        folded
    }

    /// Priced k-scalability of the graph's NN stages on the host device:
    /// the [`StageGraph::batch_fold`] compute time of every NN node on
    /// [`crate::sim::Device::cpu`] divided by the unfolded total. Sub-linear
    /// in `k` (the per-stage dispatch overhead is paid once per fold), this
    /// is the number the fused-batch GEMM path is validated against —
    /// `benches/perf_gemm.rs` compares measured batched host time to this
    /// ratio for k ∈ {2, 4, 8}. Priced on the CPU device regardless of the
    /// graph's placement because the measurement runs on the host surrogate.
    pub fn priced_batch_scaling(&self, batch: usize) -> f64 {
        let k = batch.max(1);
        let cpu = crate::sim::Device::cpu();
        let folded = self.batch_fold(k);
        let mut base_ms = 0.0f64;
        let mut fold_ms = 0.0f64;
        for (n, f) in self.nodes.iter().zip(folded.iter()) {
            if n.spec.workload.kind != crate::sim::WorkloadKind::NeuralNet {
                continue;
            }
            base_ms += cpu.compute_ms(&n.spec.workload, n.spec.precision);
            fold_ms += cpu.compute_ms(&f.workload, f.precision);
        }
        if base_ms <= 0.0 {
            return k as f64;
        }
        fold_ms / base_ms
    }

    /// **quant-rewrite**: the same topology under a different
    /// [`QuantScheme`]. Every NN node's artifact, precision, workload and
    /// quant spec are re-derived from the new scheme; devices are re-placed
    /// by the per-stage precision rule; point-op nodes and all dependency
    /// edges are untouched. This is the SLO degrade move as a graph pass
    /// (see [`crate::serving::slo::degraded_graph`]); it is equivalent to
    /// rebuilding with the new scheme (pinned by
    /// `quant_rewrite_matches_rebuild`).
    pub fn quant_rewrite(&self, m: &Manifest, scheme: QuantScheme) -> Result<StageGraph> {
        let mut cfg = self.cfg.clone();
        cfg.scheme = scheme;
        let mut nodes = self.nodes.clone();
        for node in &mut nodes {
            // the same per-class derivation `build` uses — not a copy of it
            let Some((art, precision, wl, qspec)) = nn_assign(m, &cfg, node.class)? else {
                continue;
            };
            node.spec.device = nn_device(&cfg, node.class, precision);
            node.spec.precision = precision;
            node.spec.workload = wl;
            node.artifact = Some(art);
            node.qspec = Some(qspec);
        }
        let g = StageGraph {
            nodes,
            chains: self.chains.clone(),
            sa4_bias: self.sa4_bias,
            cfg,
            num_points: self.num_points,
            skip_seg: self.skip_seg,
        };
        g.debug_verify(m);
        Ok(g)
    }

    /// **stream-tail**: the sub-graph a REUSE frame of a temporal stream
    /// executes — vote head, proposal clustering, proposal net, and decode.
    /// Paint, biased FPS, and the whole SA backbone are skipped; the cached
    /// seed features warm-start the vote stage (see
    /// `coordinator::pipeline::run_stream` and `crate::temporal`).
    /// Dependencies on dropped nodes are removed and the surviving edges
    /// re-indexed, so the tail prices through the serving planner unchanged;
    /// its fingerprint differs from the full graph's (different node set),
    /// so plan caches never conflate the two.
    pub fn stream_tail(&self) -> StageGraph {
        let keep = |c: StageClass| {
            matches!(
                c,
                StageClass::Vote | StageClass::PropPm | StageClass::Prop | StageClass::Decode
            )
        };
        let mut map = vec![usize::MAX; self.nodes.len()];
        let mut nodes: Vec<StageNode> = Vec::with_capacity(4);
        for (i, n) in self.nodes.iter().enumerate() {
            if !keep(n.class) {
                continue;
            }
            let mut node = n.clone();
            node.spec.deps =
                n.spec.deps.iter().map(|&d| map[d]).filter(|&d| d != usize::MAX).collect();
            node.extra_deps =
                n.extra_deps.iter().map(|&d| map[d]).filter(|&d| d != usize::MAX).collect();
            map[i] = nodes.len();
            nodes.push(node);
        }
        StageGraph {
            nodes,
            chains: Vec::new(),
            sa4_bias: self.sa4_bias,
            cfg: self.cfg.clone(),
            num_points: self.num_points,
            skip_seg: self.skip_seg,
        }
    }

    /// Structural fingerprint of the graph: everything that changes what
    /// the simulator or executor would do — stage names, devices,
    /// precisions, workloads, dependency edges, artifact names and quant
    /// specs — plus the point budget, seg-skip flag, the executor-visible
    /// config knobs (`w0`, `bias_layers`, `obj_thresh`, `nms_iou`) and the
    /// full SA-chain metadata. Two configurations differing **only** in
    /// `QuantScheme` granularity produce different fingerprints even when
    /// their timing-visible specs coincide (the quant specs differ), so
    /// plan caches keyed by this value can never conflate them. The
    /// `fingerprint_covers_*` tests pin this completeness.
    pub fn fingerprint(&self) -> u64 {
        let mut h = Fnv::new();
        h.u64(self.num_points as u64);
        h.u64(self.skip_seg as u64);
        h.u64(self.sa4_bias as u64);
        // executor-visible config knobs that specs alone don't capture:
        // sampling-bias strength, bias depth, and the decode thresholds all
        // change the detections a replayed plan produces
        h.u64(self.cfg.w0.to_bits() as u64);
        h.u64(self.cfg.bias_layers as u64);
        h.u64(self.cfg.obj_thresh.to_bits() as u64);
        h.u64(self.cfg.nms_iou.to_bits());
        for c in &self.chains {
            h.bytes(c.tag.as_bytes());
            h.u64(c.biased as u64);
            h.u64(c.subset.map_or(u64::MAX, |s| s as u64));
            h.u64(c.n0 as u64);
            for l in &c.levels {
                for v in [l.pm, l.nn, l.n_in, l.m, l.c, l.start] {
                    h.u64(v as u64);
                }
                h.u64(l.use_bias as u64);
            }
        }
        for node in &self.nodes {
            let s = &node.spec;
            h.bytes(s.name.as_bytes());
            h.u64(s.device as u64);
            h.u64(s.precision as u64);
            h.u64(s.workload.kind as u64);
            h.u64(s.workload.flops);
            h.u64(s.workload.mem_bytes);
            h.u64(s.workload.wire_bytes);
            h.u64(s.deps.len() as u64);
            for &d in &s.deps {
                h.u64(d as u64);
            }
            h.u64(node.extra_deps.len() as u64);
            for &d in &node.extra_deps {
                h.u64(d as u64);
            }
            if let Some(a) = &node.artifact {
                h.bytes(a.as_bytes());
            }
            if let Some(q) = &node.qspec {
                h.bytes(q.precision.key_name().as_bytes());
                h.u64(q.cout as u64);
                h.u64(q.roles.len() as u64);
                for g in &q.roles {
                    h.u64(g.len() as u64);
                    for &c in g {
                        h.u64(c as u64);
                    }
                }
            }
        }
        h.finish()
    }
}

/// FNV-1a 64-bit (no external deps; collision odds are negligible for the
/// handful of configurations a planner cache ever sees).
struct Fnv(u64);

impl Fnv {
    fn new() -> Fnv {
        Fnv(0xcbf2_9ce4_8422_2325)
    }

    fn bytes(&mut self, b: &[u8]) {
        for &x in b {
            self.0 ^= x as u64;
            self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
        }
        // length terminator so ("ab","c") != ("a","bc")
        self.0 ^= 0xff;
        self.0 = self.0.wrapping_mul(0x0000_0100_0000_01b3);
    }

    fn u64(&mut self, v: u64) {
        self.bytes(&v.to_le_bytes());
    }

    fn finish(&self) -> u64 {
        self.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::Schedule;
    use crate::quant::Granularity;

    fn pipelined() -> Schedule {
        Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu }
    }

    fn split_cfg() -> DetectorConfig {
        DetectorConfig::new("synrgbd", Variant::PointSplit, true, pipelined())
    }

    #[test]
    fn build_produces_connected_dag_for_every_variant() {
        let m = Manifest::synthetic();
        for v in
            [Variant::VoteNet, Variant::PointPainting, Variant::RandomSplit, Variant::PointSplit]
        {
            for int8 in [false, true] {
                let cfg = DetectorConfig::new("synrgbd", v, int8, pipelined());
                let g = StageGraph::build(&m, &cfg, 2048, false).expect("build");
                for (i, n) in g.nodes.iter().enumerate() {
                    for &d in n.spec.deps.iter().chain(n.extra_deps.iter()) {
                        assert!(d < i, "{v:?}: node {i} depends forward on {d}");
                    }
                }
                assert!(g.nodes.iter().any(|n| n.class == StageClass::Decode));
                let expected_chains = if cfg.variant.split() { 2 } else { 1 };
                assert_eq!(g.chains.len(), expected_chains, "{v:?}");
                for c in &g.chains {
                    assert_eq!(c.levels.len(), 3);
                    for lvl in &c.levels {
                        assert_eq!(g.nodes[lvl.nn].spec.deps.first(), Some(&lvl.pm));
                    }
                }
                // NN nodes carry artifact + quant spec, point ops do not
                for n in &g.nodes {
                    let is_nn = n.class.net(cfg.variant.split()).is_some();
                    assert_eq!(n.artifact.is_some(), is_nn, "{:?}", n.class);
                    assert_eq!(n.qspec.is_some(), is_nn, "{:?}", n.class);
                }
            }
        }
    }

    #[test]
    fn skip_seg_drops_only_the_segmenter() {
        let m = Manifest::synthetic();
        let full = StageGraph::build(&m, &split_cfg(), 2048, false).unwrap();
        let skip = StageGraph::build(&m, &split_cfg(), 2048, true).unwrap();
        assert!(full.nodes.iter().any(|n| n.class == StageClass::Seg));
        assert!(!skip.nodes.iter().any(|n| n.class == StageClass::Seg));
        assert_eq!(full.nodes.len(), skip.nodes.len() + 1);
        assert!(skip.nodes.iter().any(|n| n.class == StageClass::Paint));
        assert_ne!(full.fingerprint(), skip.fingerprint());
    }

    #[test]
    fn batch_fold_scales_workloads_only() {
        let m = Manifest::synthetic();
        let g = StageGraph::build(&m, &split_cfg(), 2048, false).unwrap();
        let one = g.specs();
        let four = g.batch_fold(4);
        assert_eq!(one.len(), four.len());
        for (a, b) in one.iter().zip(four.iter()) {
            assert_eq!(a.name, b.name);
            assert_eq!(a.deps, b.deps);
            assert_eq!(a.device, b.device);
            assert_eq!(b.workload.flops, 4 * a.workload.flops);
            assert_eq!(b.workload.wire_bytes, 4 * a.workload.wire_bytes);
        }
    }

    #[test]
    fn priced_batch_scaling_is_sublinear_and_monotonic() {
        let m = Manifest::synthetic();
        let g = StageGraph::build(&m, &split_cfg(), 2048, false).unwrap();
        let mut prev = 1.0f64;
        for k in [2usize, 4, 8] {
            let r = g.priced_batch_scaling(k);
            // folding k scenes costs more than one but less than k separate
            // runs: the per-stage dispatch overhead is paid once
            assert!(r > prev, "scaling must grow with k: k={k} r={r} prev={prev}");
            assert!(r < k as f64, "k={k}: priced scaling {r} must be sub-linear");
            prev = r;
        }
        assert!((g.priced_batch_scaling(1) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn missing_artifact_is_an_error_not_a_panic() {
        let m = Manifest::synthetic();
        let mut cfg = split_cfg();
        cfg.dataset = "nosuch".to_string();
        let err = StageGraph::build(&m, &cfg, 2048, false).unwrap_err();
        assert!(format!("{err:#}").contains("missing from manifest"), "{err:#}");
    }

    #[test]
    fn fingerprint_discriminates_quant_scheme_granularity() {
        let m = Manifest::synthetic();
        // backbone Layer vs Group(4): identical artifact names and identical
        // timing-visible specs — only the quant spec differs
        let a = StageGraph::build(&m, &split_cfg(), 2048, false).unwrap();
        let mut cfg_b = split_cfg();
        cfg_b.scheme.backbone = StagePrecision::Int8(Granularity::Group(4));
        let b = StageGraph::build(&m, &cfg_b, 2048, false).unwrap();
        assert_eq!(a.specs(), b.specs(), "granularity is timing-invisible by design");
        assert_ne!(a.fingerprint(), b.fingerprint(), "fingerprint must still discriminate");
        // and head granularity (different artifacts)
        let mut cfg_c = split_cfg();
        cfg_c.scheme = cfg_c.scheme.with_head(StagePrecision::Int8(Granularity::Group(2)));
        let c = StageGraph::build(&m, &cfg_c, 2048, false).unwrap();
        assert_ne!(a.fingerprint(), c.fingerprint());
        // determinism
        let a2 = StageGraph::build(&m, &split_cfg(), 2048, false).unwrap();
        assert_eq!(a.fingerprint(), a2.fingerprint());
    }

    #[test]
    fn fingerprint_covers_executor_visible_config_knobs() {
        // Regression: w0 / obj_thresh / nms_iou change what the executor
        // *outputs* without changing a single StageSpec, so a plan cache
        // keyed by a spec-only fingerprint would silently serve one
        // config's plan (and accuracy expectations) for the other.
        let m = Manifest::synthetic();
        let base = StageGraph::build(&m, &split_cfg(), 2048, false).unwrap();
        let tweaks: [(&str, fn(&mut DetectorConfig)); 4] = [
            ("w0", |c| c.w0 = 3.0),
            ("bias_layers", |c| c.bias_layers = 3),
            ("obj_thresh", |c| c.obj_thresh = 0.05),
            ("nms_iou", |c| c.nms_iou = 0.5),
        ];
        for (knob, tweak) in tweaks {
            let mut cfg = split_cfg();
            tweak(&mut cfg);
            let g = StageGraph::build(&m, &cfg, 2048, false).unwrap();
            assert_ne!(
                base.fingerprint(),
                g.fingerprint(),
                "fingerprint must discriminate on {knob}"
            );
            if knob == "obj_thresh" || knob == "nms_iou" {
                assert_eq!(base.specs(), g.specs(), "{knob} is timing-invisible by design");
            }
        }
    }

    #[test]
    fn stream_tail_keeps_only_the_head() {
        let m = Manifest::synthetic();
        for v in [Variant::PointSplit, Variant::PointPainting, Variant::VoteNet] {
            let cfg = DetectorConfig::new("synrgbd", v, true, pipelined());
            let g = StageGraph::build(&m, &cfg, 2048, false).unwrap();
            let tail = g.stream_tail();
            let classes: Vec<StageClass> = tail.nodes.iter().map(|n| n.class).collect();
            assert_eq!(
                classes,
                vec![StageClass::Vote, StageClass::PropPm, StageClass::Prop, StageClass::Decode],
                "{v:?}"
            );
            // edges re-indexed into a valid DAG over the surviving nodes
            for (i, n) in tail.nodes.iter().enumerate() {
                for &d in n.spec.deps.iter().chain(n.extra_deps.iter()) {
                    assert!(d < i, "{v:?}: tail node {i} depends forward on {d}");
                }
            }
            assert_eq!(tail.nodes[1].spec.deps, vec![0], "prop_pm waits for vote");
            assert_eq!(tail.nodes[2].spec.deps, vec![1]);
            assert_eq!(tail.nodes[3].spec.deps, vec![2]);
            // surviving specs are byte-identical to the full graph's
            for n in &tail.nodes {
                let orig = g.nodes.iter().find(|o| o.spec.name == n.spec.name).unwrap();
                assert_eq!(orig.spec.workload, n.spec.workload);
                assert_eq!(orig.artifact, n.artifact);
                assert_eq!(orig.qspec, n.qspec);
            }
            assert_ne!(tail.fingerprint(), g.fingerprint());
            // the tail still batch-folds (the planner prices it unchanged)
            assert_eq!(tail.batch_fold(4).len(), 4);
        }
    }

    #[test]
    fn quant_rewrite_matches_rebuild() {
        let m = Manifest::synthetic();
        for base_int8 in [false, true] {
            for v in [Variant::PointSplit, Variant::PointPainting] {
                let cfg = DetectorConfig::new("synrgbd", v, base_int8, pipelined());
                let g = StageGraph::build(&m, &cfg, 2048, false).unwrap();
                for scheme in [
                    cfg.scheme.degraded(),
                    QuantScheme::fp32(),
                    QuantScheme::int8(Granularity::Role),
                ] {
                    let rewritten = g.quant_rewrite(&m, scheme).expect("rewrite");
                    let mut cfg2 = cfg.clone();
                    cfg2.scheme = scheme;
                    let rebuilt = StageGraph::build(&m, &cfg2, 2048, false).unwrap();
                    assert_eq!(
                        rewritten.nodes, rebuilt.nodes,
                        "{v:?} int8={base_int8}: rewrite drifted from rebuild"
                    );
                    assert_eq!(rewritten.fingerprint(), rebuilt.fingerprint());
                }
            }
        }
    }

    #[test]
    fn quant_rewrite_moves_fp32_heads_back_to_the_npu() {
        let m = Manifest::synthetic();
        let cfg = DetectorConfig::new("synrgbd", Variant::PointSplit, false, pipelined());
        let g = StageGraph::build(&m, &cfg, 2048, false).unwrap();
        let vote = |g: &StageGraph| {
            g.nodes.iter().find(|n| n.class == StageClass::Vote).unwrap().spec.clone()
        };
        assert_eq!(vote(&g).device, DeviceKind::Gpu, "fp32 vote falls back to the point device");
        let fast = g.quant_rewrite(&m, cfg.scheme.degraded()).unwrap();
        let v = vote(&fast);
        assert_eq!(v.device, DeviceKind::EdgeTpu, "role-int8 vote belongs on the NPU");
        assert_eq!(v.precision, Precision::Int8);
        let q = fast.nodes.iter().find(|n| n.class == StageClass::Vote).unwrap();
        assert_eq!(q.qspec.as_ref().unwrap().precision, StagePrecision::Int8(Granularity::Role));
    }
}
