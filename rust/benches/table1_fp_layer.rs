//! Paper Table 1: feature-propagation (FP) stage cost — PointNet++'s two FP
//! PointNets vs PointSplit's single modified PointNet (shared FC).
//!
//! Reported at two scales: the original VoteNet widths (the paper's absolute
//! numbers: 398,336 params / 304 MAdd vs 197,888 / 202 M) and this repo's
//! VoteNet-mini widths.

mod common;

use pointsplit::bench::Table;
use pointsplit::coordinator::arch::fp_layer_cost;

fn main() {
    let rt = common::open_runtime();
    let mut t = Table::new(&["scale", "variant", "# params", "MAdd", "paper"]);
    for (scale, paper_p, paper_m) in
        [("paper (VoteNet widths)", "398,336 / 197,888", "304M / 202M"), ("mini (this repo)", "-", "-")]
    {
        let c = fp_layer_cost(&rt.manifest, scale.starts_with("paper"));
        t.row(vec![
            scale.into(),
            "PointNet++ (two PointNets)".into(),
            c.orig_params.to_string(),
            format!("{:.0}M", c.orig_madds as f64 / 1e6),
            paper_p.into(),
        ]);
        t.row(vec![
            scale.into(),
            "PointSplit (one shared FC)".into(),
            c.ps_params.to_string(),
            format!("{:.0}M", c.ps_madds as f64 / 1e6),
            paper_m.into(),
        ]);
        let dp = 100.0 * (1.0 - c.ps_params as f64 / c.orig_params as f64);
        let dm = 100.0 * (1.0 - c.ps_madds as f64 / c.orig_madds as f64);
        t.row(vec![
            scale.into(),
            "reduction".into(),
            format!("{dp:.1}%"),
            format!("{dm:.1}%"),
            "50.3% / 33.6%".into(),
        ]);
    }
    t.print("Table 1 — FP layer cost: PointNet++ vs PointSplit");
}
