//! Virtual-time dispatcher: drains the admission queue through the batcher
//! and SLO policy, charging every batch into the calibrated device timeline.
//!
//! The loop runs on the **simulated clock**. Each dispatched batch is costed
//! by the [`ServicePlanner`] (the same stage DAG `ScenePipeline` records,
//! scaled by batch size); its critical path sets request latency and its
//! bottleneck-device occupancy sets when the *next* batch may enter. That
//! second number is the two-lane overlap: while a batch's NPU tail is still
//! draining, the following batch's GPU point-manipulation front has already
//! started — exactly the Fig. 3 pipelining, applied across requests instead
//! of within one scene.
//!
//! A request's life ends in exactly one of four ways — completed, rejected
//! at admission, expired in queue, or shed by the SLO policy — and the
//! dispatcher emits one [`RequestOutcome`] per arrival (property-tested in
//! `rust/tests/proptests.rs`).

use std::cell::RefCell;
use std::collections::HashMap;

use anyhow::Result;

use crate::coordinator::{DetectorConfig, ScenePipeline};
use crate::data::{generate_scene, Box3, DatasetCfg};
use crate::eval::{eval_map, Detection};
use crate::runtime::Runtime;
use crate::util::stats::Stats;

use super::batcher::{self, BatchPolicy};
use super::loadgen::{LoadGen, Request};
use super::plan::ServicePlanner;
use super::queue::{AdmissionQueue, AdmitResult};
use super::slo::{self, SloPolicy};

/// One open-loop serving experiment.
#[derive(Debug, Clone)]
pub struct TrafficScenario {
    pub name: String,
    /// Detector configurations addressable by `Request::key`.
    pub configs: Vec<DetectorConfig>,
    /// Points per scene (from the dataset config).
    pub num_points: usize,
    pub load: LoadGen,
    pub queue_capacity: usize,
    pub batch: BatchPolicy,
    pub policy: SloPolicy,
}

/// How a single request left the system.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum OutcomeKind {
    Completed,
    RejectedFull,
    Expired,
    ShedSlo,
}

/// Terminal record for one arrival.
#[derive(Debug, Clone, Copy)]
pub struct RequestOutcome {
    pub id: u64,
    pub kind: OutcomeKind,
    /// Completed within its deadline (always false for non-completions).
    pub on_time: bool,
}

/// Aggregated result of one scenario run.
#[derive(Debug, Clone)]
pub struct ServeTrafficReport {
    pub scenario: String,
    pub pattern: &'static str,
    pub policy: &'static str,
    pub offered_rps: f64,
    /// Steady-state capacity of config 0 at the full batch size.
    pub capacity_rps: f64,
    /// Arrival-window length, seconds (simulated).
    pub duration_s: f64,
    /// Time the last batch finished, seconds (simulated).
    pub makespan_s: f64,
    pub arrivals: usize,
    pub completed: usize,
    pub on_time: usize,
    pub rejected_full: usize,
    pub expired: usize,
    pub shed_slo: usize,
    /// Requests served on the degraded fast path.
    pub degraded: usize,
    pub batches: usize,
    pub mean_batch: f64,
    /// End-to-end (arrival -> batch completion) simulated latency.
    pub latency_ms: Stats,
    /// Arrival -> dispatch delay (queueing + batching).
    pub queue_wait_ms: Stats,
    /// On-time completions / arrivals.
    pub slo_attainment: f64,
    /// On-time completions per simulated second.
    pub goodput_rps: f64,
    pub util_gpu: f64,
    pub util_npu: f64,
    pub max_queue_depth: usize,
    /// mAP@0.25 over functionally executed scenes (None without a real
    /// PJRT backend + artifacts).
    pub map_25: Option<f64>,
}

impl ServeTrafficReport {
    /// Human-readable block (mirrors `cmd_serve`'s style).
    pub fn print(&self) {
        println!(
            "--- {} [{} arrivals, pattern={}, policy={}] ---",
            self.scenario, self.arrivals, self.pattern, self.policy
        );
        println!(
            "offered {:.1} rps vs capacity {:.1} rps ({:.0}% load), {:.1}s window, {:.1}s makespan",
            self.offered_rps,
            self.capacity_rps,
            100.0 * self.offered_rps / self.capacity_rps.max(1e-9),
            self.duration_s,
            self.makespan_s
        );
        println!(
            "completed {} ({} on time)  rejected {}  expired {}  shed {}  degraded {}",
            self.completed, self.on_time, self.rejected_full, self.expired, self.shed_slo,
            self.degraded
        );
        println!(
            "latency: p50 {:.0} ms  p95 {:.0}  p99 {:.0}  (queue wait p95 {:.0} ms)",
            self.latency_ms.p50, self.latency_ms.p95, self.latency_ms.p99, self.queue_wait_ms.p95
        );
        println!(
            "SLO attainment {:.1}%  goodput {:.1} rps  mean batch {:.2} over {} batches",
            100.0 * self.slo_attainment,
            self.goodput_rps,
            self.mean_batch,
            self.batches
        );
        println!(
            "device util: GPU {:.0}%  NPU {:.0}%  peak queue depth {}",
            100.0 * self.util_gpu,
            100.0 * self.util_npu,
            self.max_queue_depth
        );
        match self.map_25 {
            Some(m) => println!("mAP@0.25 (functional) = {:.1}", m * 100.0),
            None => println!("mAP: n/a (simulated-time run; needs artifacts + PJRT)"),
        }
    }
}

/// Functional batch executor: runs dispatched scenes through the real
/// [`ScenePipeline`] so reports carry accuracy next to simulated latency.
/// Requires exported artifacts and a real PJRT backend (the vendored `xla`
/// stub makes every execution fail, in which case the dispatcher falls back
/// to simulation-only and reports `map_25 = None`).
pub struct PipelineExecutor<'a> {
    rt: &'a Runtime,
    ds: &'static DatasetCfg,
    pipes: RefCell<HashMap<String, ScenePipeline<'a>>>,
}

impl<'a> PipelineExecutor<'a> {
    pub fn new(rt: &'a Runtime, ds: &'static DatasetCfg) -> PipelineExecutor<'a> {
        PipelineExecutor { rt, ds, pipes: RefCell::new(HashMap::new()) }
    }

    /// Execute each request's scene; returns (detections, ground truth) per
    /// request in order.
    ///
    /// Fidelity caveat: degraded batches run with the degraded *precisions*
    /// (the dispatcher passes the fast config), but at the full point budget
    /// and with fresh 2D segmentation — the accuracy reported for degraded
    /// traffic is therefore an upper bound on the fast path's true mAP.
    #[allow(clippy::type_complexity)]
    pub fn execute(
        &self,
        cfg: &DetectorConfig,
        reqs: &[Request],
    ) -> Result<Vec<(Vec<Box3>, Vec<Box3>)>> {
        // must discriminate every field that changes pipeline behaviour
        // (mirrors ServicePlanner::cost's cache key)
        let key = format!(
            "{}|{}|{}|{}|{:?}|{}|{}|{}",
            cfg.dataset,
            cfg.variant.name(),
            cfg.precision_backbone,
            cfg.precision_head,
            cfg.schedule,
            cfg.w0,
            cfg.bias_layers,
            cfg.seg_passes
        );
        let mut pipes = self.pipes.borrow_mut();
        let pipe = pipes
            .entry(key)
            .or_insert_with(|| ScenePipeline::new(self.rt, cfg.clone()));
        let mut out = Vec::with_capacity(reqs.len());
        for r in reqs {
            let scene = generate_scene(r.seed, self.ds);
            let gt = scene.gt_boxes();
            let res = pipe.run(&scene, r.seed)?;
            out.push((res.detections, gt));
        }
        Ok(out)
    }
}

/// Run a scenario to completion on the simulated clock. Returns the report
/// plus one terminal outcome per arrival (in resolution order).
pub fn run_traffic_trace(
    sc: &TrafficScenario,
    planner: &ServicePlanner,
    exec: Option<&PipelineExecutor>,
) -> (ServeTrafficReport, Vec<RequestOutcome>) {
    assert!(!sc.configs.is_empty(), "scenario needs at least one detector config");
    let arrivals = sc.load.generate();
    let total = arrivals.len();
    let mut outcomes: Vec<RequestOutcome> = Vec::with_capacity(total);
    let mut queue = AdmissionQueue::new(sc.queue_capacity, 2);
    let mut now = 0.0f64;
    let mut lane_free = 0.0f64;
    let mut i = 0usize;

    let mut makespan_ms = 0.0f64;
    let mut busy_gpu = 0.0f64;
    let mut busy_npu = 0.0f64;
    let mut lat: Vec<f64> = Vec::new();
    let mut qwait: Vec<f64> = Vec::new();
    let (mut completed, mut on_time, mut shed_slo, mut degraded) = (0usize, 0usize, 0usize, 0usize);
    let (mut batches, mut batched_reqs) = (0usize, 0usize);

    // functional-accuracy accumulators (only with a working executor)
    let mut exec_ok = exec.is_some();
    let mut gts: Vec<Vec<Box3>> = Vec::new();
    let mut dets: Vec<Detection> = Vec::new();

    loop {
        // 1) ingest every arrival due at or before `now`
        while i < total && arrivals[i].arrival_ms <= now {
            let r = arrivals[i].clone();
            i += 1;
            if queue.offer(r) == AdmitResult::RejectedFull {
                outcomes.push(RequestOutcome {
                    id: arrivals[i - 1].id,
                    kind: OutcomeKind::RejectedFull,
                    on_time: false,
                });
            }
        }
        // 2) expire requests whose deadline passed while queued
        for r in queue.expire(now) {
            outcomes.push(RequestOutcome { id: r.id, kind: OutcomeKind::Expired, on_time: false });
        }
        // 3) dispatch while the lane is open
        let mut wait_hint: Option<f64> = None;
        while lane_free <= now {
            match batcher::decide(&mut queue, &sc.batch, now) {
                batcher::BatchDecision::Dispatch(batch) => {
                    let cfg = &sc.configs[batch.key.min(sc.configs.len() - 1)];
                    let k0 = batch.reqs.len();
                    let fast_pts = slo::degraded_points(sc.num_points);
                    let full = planner.cost(cfg, sc.num_points, k0, false);
                    let fast_cfg = slo::degraded_config(cfg);
                    let fast = planner.cost(&fast_cfg, fast_pts, k0, true);
                    let dec = slo::apply(sc.policy, batch.reqs, now, full.total_ms, fast.total_ms);
                    for r in &dec.shed {
                        shed_slo += 1;
                        outcomes.push(RequestOutcome {
                            id: r.id,
                            kind: OutcomeKind::ShedSlo,
                            on_time: false,
                        });
                    }
                    if dec.dispatch.is_empty() {
                        continue; // whole batch shed; lane still open
                    }
                    let k = dec.dispatch.len();
                    let (run_cfg, cost) = if dec.degraded {
                        (&fast_cfg, planner.cost(&fast_cfg, fast_pts, k, true))
                    } else {
                        (cfg, planner.cost(cfg, sc.num_points, k, false))
                    };
                    let done = now + cost.total_ms;
                    lane_free = now + cost.bottleneck_ms;
                    makespan_ms = makespan_ms.max(done);
                    busy_gpu += cost.busy_gpu_ms;
                    busy_npu += cost.busy_npu_ms;
                    batches += 1;
                    batched_reqs += k;
                    if exec_ok {
                        match exec.expect("exec_ok implies executor").execute(run_cfg, &dec.dispatch)
                        {
                            Ok(pairs) => {
                                for (d, gt) in pairs {
                                    let scene_idx = gts.len();
                                    gts.push(gt);
                                    dets.extend(
                                        d.into_iter().map(|b| Detection { scene: scene_idx, b }),
                                    );
                                }
                            }
                            Err(e) => {
                                eprintln!(
                                    "functional execution disabled ({e:#}); continuing simulated-only"
                                );
                                exec_ok = false;
                            }
                        }
                    }
                    for r in &dec.dispatch {
                        lat.push(done - r.arrival_ms);
                        qwait.push(now - r.arrival_ms);
                        completed += 1;
                        let met = done <= r.deadline_ms;
                        if met {
                            on_time += 1;
                        }
                        if dec.degraded {
                            degraded += 1;
                        }
                        outcomes.push(RequestOutcome {
                            id: r.id,
                            kind: OutcomeKind::Completed,
                            on_time: met,
                        });
                    }
                }
                batcher::BatchDecision::WaitUntil(t) => {
                    wait_hint = Some(t);
                    break;
                }
                batcher::BatchDecision::Idle => break,
            }
        }
        // 4) advance the clock to the next event
        let mut t_next = f64::INFINITY;
        if let Some(r) = arrivals.get(i) {
            t_next = t_next.min(r.arrival_ms);
        }
        if !queue.is_empty() {
            if lane_free > now {
                t_next = t_next.min(lane_free);
            }
            if let Some(t) = wait_hint {
                t_next = t_next.min(t);
            }
        }
        if !t_next.is_finite() {
            break;
        }
        debug_assert!(t_next > now, "virtual clock must advance ({t_next} vs {now})");
        now = t_next;
    }

    let map_25 = if exec_ok && !gts.is_empty() {
        Some(eval_map(&dets, &gts, planner.manifest().num_class(), 0.25).map)
    } else {
        None
    };
    let makespan_s = (makespan_ms / 1000.0).max(sc.load.duration_ms / 1000.0).max(1e-9);
    let report = ServeTrafficReport {
        scenario: sc.name.clone(),
        pattern: sc.load.pattern.name(),
        policy: sc.policy.name(),
        offered_rps: sc.load.pattern.mean_rps(),
        capacity_rps: planner.capacity_rps(&sc.configs[0], sc.num_points, sc.batch.max_batch),
        duration_s: sc.load.duration_ms / 1000.0,
        makespan_s,
        arrivals: total,
        completed,
        on_time,
        rejected_full: queue.stats.rejected_full as usize,
        expired: queue.stats.expired as usize,
        shed_slo,
        degraded,
        batches,
        mean_batch: if batches > 0 { batched_reqs as f64 / batches as f64 } else { 0.0 },
        latency_ms: Stats::from(lat),
        queue_wait_ms: Stats::from(qwait),
        slo_attainment: if total > 0 { on_time as f64 / total as f64 } else { 1.0 },
        goodput_rps: on_time as f64 / makespan_s,
        util_gpu: busy_gpu / 1000.0 / makespan_s,
        util_npu: busy_npu / 1000.0 / makespan_s,
        max_queue_depth: queue.stats.max_depth,
        map_25,
    };
    (report, outcomes)
}

/// Run a scenario and return just the report.
pub fn run_traffic(
    sc: &TrafficScenario,
    planner: &ServicePlanner,
    exec: Option<&PipelineExecutor>,
) -> ServeTrafficReport {
    run_traffic_trace(sc, planner, exec).0
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::{Schedule, Variant};
    use crate::serving::loadgen::ArrivalPattern;
    use crate::sim::DeviceKind;

    fn scenario(rate_mult: f64, policy: SloPolicy, seed: u64) -> TrafficScenario {
        let cfg = DetectorConfig::new(
            "synrgbd",
            Variant::PointSplit,
            true,
            Schedule::Pipelined { point_dev: DeviceKind::Gpu, nn_dev: DeviceKind::EdgeTpu },
        );
        let planner = ServicePlanner::synthetic();
        let cap = planner.capacity_rps(&cfg, 2048, 4);
        TrafficScenario {
            name: format!("test-{rate_mult}x"),
            configs: vec![cfg],
            num_points: 2048,
            load: LoadGen::simple(
                ArrivalPattern::Poisson { rate_rps: cap * rate_mult },
                20_000.0,
                2_000.0,
                seed,
            ),
            queue_capacity: 32,
            batch: BatchPolicy { max_batch: 4, max_wait_ms: 25.0 },
            policy,
        }
    }

    #[test]
    fn underload_meets_slo() {
        let planner = ServicePlanner::synthetic();
        let sc = scenario(0.25, SloPolicy::None, 3);
        let (rep, outcomes) = run_traffic_trace(&sc, &planner, None);
        assert_eq!(outcomes.len(), rep.arrivals);
        assert!(rep.arrivals > 0);
        assert!(rep.slo_attainment > 0.9, "underload attainment {}", rep.slo_attainment);
        assert_eq!(rep.completed + rep.rejected_full + rep.expired + rep.shed_slo, rep.arrivals);
        assert!(rep.map_25.is_none());
    }

    #[test]
    fn deterministic_runs() {
        let planner = ServicePlanner::synthetic();
        let sc = scenario(1.2, SloPolicy::Degrade, 9);
        let a = run_traffic(&sc, &planner, None);
        let b = run_traffic(&sc, &planner, None);
        assert_eq!(a.arrivals, b.arrivals);
        assert_eq!(a.completed, b.completed);
        assert_eq!(a.on_time, b.on_time);
        assert_eq!(a.latency_ms.p99, b.latency_ms.p99);
    }

    #[test]
    fn overload_policy_beats_none() {
        let planner = ServicePlanner::synthetic();
        let none = run_traffic(&scenario(2.0, SloPolicy::None, 17), &planner, None);
        let deg = run_traffic(&scenario(2.0, SloPolicy::Degrade, 17), &planner, None);
        assert!(
            deg.goodput_rps > none.goodput_rps,
            "degradation must raise goodput under 2x overload: {} vs {}",
            deg.goodput_rps,
            none.goodput_rps
        );
        assert!(deg.degraded > 0, "2x overload must trigger degradation");
    }

    #[test]
    fn overload_batches_grow() {
        let planner = ServicePlanner::synthetic();
        let under = run_traffic(&scenario(0.3, SloPolicy::None, 21), &planner, None);
        let over = run_traffic(&scenario(1.8, SloPolicy::None, 21), &planner, None);
        assert!(
            over.mean_batch > under.mean_batch,
            "queueing pressure should fill batches: {} vs {}",
            over.mean_batch,
            under.mean_batch
        );
    }
}
